"""Training benchmark: the differentiable OT layer inside a train loop.

Two seeded scenarios, each emitting deterministic counters plus
informational wall-clock (docs/training.md):

* ``danskin_grad`` — ``jax.value_and_grad`` of :func:`repro.ot.ot_loss`
  on the golden dense problem.  The layer's contract is O(1) solver
  launches per training step: the forward pass runs ONE dual solve and
  the Danskin backward pass is closed-form plan recovery, so
  ``solves_per_step`` (from ``repro.ot.diff.solve_count``) is gated at
  EXACTLY 1 — any unrolling or re-solve regression shows up as an
  integer jump.  The value/gradient magnitudes are tolerance-gated.
* ``train_smoke`` — a tiny LM ``Trainer`` run with ``ot_align=True``
  (the full stack: features -> OTLayer.from_samples -> AdamW).
  ``loss_decreased`` (mean of the last half of per-step losses below
  the mean of the first half — per-batch CE is noisy at this scale, the
  half-means are not) is a single bit gated EXACTLY; the loss means are
  tolerance-gated; per-step wall time is reported, never gated.

``benchmarks/check_regression.py`` re-runs this at the committed
``BENCH_training.json``'s scale and compares.
"""
from __future__ import annotations

import argparse
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax
import jax.numpy as jnp
import numpy as np

FULL = dict(grad_steps=3, train_steps=10)
SMOKE = dict(grad_steps=2, train_steps=4)


def danskin_grad_scenario(grad_steps: int) -> dict:
    """value_and_grad steps on the golden dense problem; count solves."""
    import repro.ot as ot
    from repro.core.regularizers import GroupSparseReg
    from repro.ot import diff

    L, g, n = 3, 8, 20
    rng = np.random.default_rng(0)
    C = jnp.asarray(rng.random((L * g, n), dtype=np.float32))
    reg = GroupSparseReg.from_rho(1.0, 0.6)
    layer = diff.OTLayer(L, g, n, reg, plan=ot.ExecutionPlan(
        grad_impl="screened", gtol=1e-7, max_iters=2000, ftol=1e-12))
    vg = jax.value_and_grad(layer)

    value, grad = vg(C)           # warm the jitted solver program
    jax.block_until_ready(grad)
    diff.reset_solve_count()
    t0 = time.perf_counter()
    for _ in range(grad_steps):
        value, grad = vg(C)
        jax.block_until_ready(grad)
    wall_us = (time.perf_counter() - t0) / grad_steps * 1e6
    solves = diff.solve_count()

    return {
        "scenario": "danskin_grad",
        "L": L, "g": g, "n": n, "steps": grad_steps,
        "counters": {
            "solves_per_step": solves // grad_steps,
            "value_milli": round(float(value) * 1e3, 3),
            "grad_inf_milli": round(float(jnp.abs(grad).max()) * 1e3, 3),
        },
        "wall": {"step_us": round(wall_us, 1)},
    }


def train_smoke_scenario(train_steps: int) -> dict:
    """Tiny Trainer run with the OT alignment loss; gate the loss bit."""
    from repro.configs import get_config
    from repro.configs.base import OptimizerConfig, TrainConfig
    from repro.data.pipeline import SyntheticLM, SyntheticLMConfig

    from repro.training.trainer import Trainer

    cfg = get_config("smollm-135m").reduced(
        num_layers=2, d_model=64, d_ff=128, vocab_size=128)
    tcfg = TrainConfig(
        optimizer=OptimizerConfig(lr=1e-3, warmup_steps=2,
                                  decay_steps=train_steps),
        steps=train_steps, log_every=1, checkpoint_every=10 ** 6,
        ot_align=True, ot_align_weight=0.05,
    )
    data = SyntheticLM(SyntheticLMConfig(vocab_size=128, seq_len=32,
                                         global_batch=4, num_classes=8))
    trainer = Trainer(cfg, tcfg, data)
    t0 = time.perf_counter()
    trainer.run()
    wall = time.perf_counter() - t0
    hist = trainer.metrics_history
    losses = [h["loss"] for h in hist]
    # per-batch CE is noisy at this scale, so the improvement bit compares
    # half-means (deterministic: seeded data + f32 CPU arithmetic)
    half = len(losses) // 2
    first_mean = float(np.mean(losses[:half]))
    final_mean = float(np.mean(losses[half:]))

    return {
        "scenario": "train_smoke",
        "steps": train_steps,
        "counters": {
            "loss_decreased": int(final_mean < first_mean),
            "loss_first_milli": round(first_mean * 1e3, 1),
            "loss_final_milli": round(final_mean * 1e3, 1),
            "ot_distance_milli": round(hist[-1]["ot_distance"] * 1e3, 1),
        },
        "wall": {"step_us": round(wall / train_steps * 1e6, 1)},
    }


def main(smoke: bool = False, out: str | None = "BENCH_training.json",
         grad_steps: int | None = None, train_steps: int | None = None):
    """Run both scenarios; returns the rows (and writes ``out`` if set)."""
    base = SMOKE if smoke else FULL
    grad_steps = base["grad_steps"] if grad_steps is None else grad_steps
    train_steps = base["train_steps"] if train_steps is None else train_steps

    rows = [
        danskin_grad_scenario(grad_steps),
        train_smoke_scenario(train_steps),
    ]
    for r in rows:
        r["smoke"] = smoke
        print(f"{r['scenario']}: counters={r['counters']} wall={r['wall']}")
    if out:
        try:
            from benchmarks.bench_io import write_bench_json
        except ImportError:          # invoked as a script from benchmarks/
            from bench_io import write_bench_json

        write_bench_json(out, rows)
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--out", default="BENCH_training.json")
    args = ap.parse_args()
    main(smoke=args.smoke, out=args.out or None)
