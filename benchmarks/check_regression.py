"""CI perf-regression gate on the kernel benchmark's deterministic counters.

  PYTHONPATH=src python benchmarks/check_regression.py [--baseline PATH]

Re-runs ``bench_kernels`` at the geometry recorded in the committed
``BENCH_kernels.json`` and compares the DETERMINISTIC counters — grid
steps issued, modeled C-bytes (HBM traffic), live/total tile counts —
row by row against the baseline.  Any counter moving more than
``--tolerance`` (default 20%) against the committed value fails the gate:
those counters are pure functions of the screening/compaction logic, so a
jump means the scaling contract (work proportional to surviving tiles)
regressed.  The fused oracle's ``launches_per_eval`` counters are held to
EXACT equality (the 2 -> 1 launch reduction is the fused route's
contract).  Wall-clock fields — including the new warmed, fully-synced
``device_wall_us`` — are REPORTED for context but never gated — CI
machines are too noisy for that.

The sharded baseline (``BENCH_sharded.json``, from
``benchmarks/bench_sharded.py``) is gated the same way: per-problem round
counts and screening-verdict totals under 4 forced host devices, plus two
exact invariants — ``launches`` (a sharded solve is ONE program) and
``bitwise_mismatches`` (sharded == unsharded per problem), which must
match the baseline exactly regardless of tolerance.

The geometry baseline (``BENCH_geometry.json``, from
``benchmarks/bench_geometry.py``) is held to EXACT equality: every
recorded counter — live/total tiles, compact grid steps, modeled operand
and traffic bytes for the dense vs factorized cost geometries — is a pure
function of the seeded flags and the byte models, so any drift means the
memory-model contract (on-the-fly bytes scale with live tiles, not n)
changed and the committed baseline must be regenerated deliberately.

The serving baseline (``BENCH_serving.json``, from
``benchmarks/bench_serving.py``) gates the SLO counters of three seeded
traffic scenarios (steady / overload / chaos): terminal-status totals,
tick-denominated latency percentiles, launches and retry attempts within
tolerance, plus one exact invariant — ``unterminated`` (requests that
never reached a terminal status) must stay at its committed value of 0.

The training baseline (``BENCH_training.json``, from
``benchmarks/bench_training.py``) gates the differentiable-layer
contract: ``solves_per_step`` (the Danskin backward pass adds ZERO
solver launches — one solve per value_and_grad step) and
``loss_decreased`` (the OT-augmented tiny Trainer strictly improves its
loss) are EXACT; value/loss magnitudes are tolerance-gated; step wall
time is informational.

Exit code 0 = clean, 1 = regression (or unreadable/mismatched baseline).
"""
from __future__ import annotations

import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))
sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

# counters that must be stable; everything else (wall_us, interpret_wall_us,
# device_wall_us, v5e_hbm_us is derived from c_bytes) is informational
GATED_FIELDS = ("grid_steps", "c_bytes")
ROW_FIELDS = ("live_tiles", "total_tiles")
# launches-per-evaluation is a property of the compiled program (the fused
# oracle's 2 -> 1 claim), not a workload magnitude — no tolerance applies
KERNEL_EXACT = ("launches_per_eval",)


def _row_key(row: dict) -> str:
    return str(row.get("density"))


def compare(baseline_rows, fresh_rows, tolerance: float):
    """Yield (key, field, old, new, ok) for every gated counter."""
    fresh_by_key = {_row_key(r): r for r in fresh_rows}
    for row in baseline_rows:
        key = _row_key(row)
        fresh = fresh_by_key.get(key)
        if fresh is None:
            yield key, "<row>", "present", "missing", False
            continue
        for f in ROW_FIELDS:
            if f in row:
                old, new = row[f], fresh.get(f)
                ok = new is not None and _within(old, new, tolerance)
                yield key, f, old, new, ok
        for impl, counters in row.get("impl", {}).items():
            fresh_impl = fresh.get("impl", {}).get(impl, {})
            for f in GATED_FIELDS:
                if f in counters:
                    old, new = counters[f], fresh_impl.get(f)
                    ok = new is not None and _within(old, new, tolerance)
                    yield key, f"{impl}.{f}", old, new, ok
            for f in KERNEL_EXACT:
                if f in counters:
                    old, new = counters[f], fresh_impl.get(f)
                    yield key, f"{impl}.{f}", old, new, new == old


def _within(old, new, tolerance: float) -> bool:
    if old == new:
        return True
    denom = max(abs(float(old)), 1.0)
    return abs(float(new) - float(old)) / denom <= tolerance


# sharded counters that must match the baseline EXACTLY (invariants of the
# sharding design, not workload-dependent magnitudes)
SHARDED_EXACT = ("launches", "bitwise_mismatches")


def _sharded_key(row: dict) -> str:
    return f"{row.get('workload')}/{row.get('grad_impl')}"


def compare_sharded(baseline_rows, fresh_rows, tolerance: float):
    """Yield (key, field, old, new, ok) for every sharded counter."""
    fresh_by_key = {_sharded_key(r): r for r in fresh_rows}
    for row in baseline_rows:
        key = _sharded_key(row)
        fresh = fresh_by_key.get(key)
        if fresh is None:
            yield key, "<row>", "present", "missing", False
            continue
        for f, old in row.get("counters", {}).items():
            new = fresh.get("counters", {}).get(f)
            if f in SHARDED_EXACT:
                ok = new == old
            else:
                ok = new is not None and _within(old, new, tolerance)
            yield key, f, old, new, ok


# geometry counters: ALL exact — each is a deterministic function of the
# seeded screening flags and the shape-level byte models, so tolerance
# would only mask a changed memory-model contract
GEOMETRY_SCALARS = ("live_tiles", "total_tiles", "grid_steps")
GEOMETRY_NESTED = ("operand_bytes", "traffic_bytes")


def _geometry_key(row: dict) -> str:
    return f"n{row.get('n')}/d{row.get('density')}"


def compare_geometry(baseline_rows, fresh_rows):
    """Yield (key, field, old, new, ok) — exact equality on every counter."""
    fresh_by_key = {_geometry_key(r): r for r in fresh_rows}
    for row in baseline_rows:
        key = _geometry_key(row)
        fresh = fresh_by_key.get(key)
        if fresh is None:
            yield key, "<row>", "present", "missing", False
            continue
        for f in GEOMETRY_SCALARS:
            if f in row:
                old, new = row[f], fresh.get(f)
                yield key, f, old, new, new == old
        for group in GEOMETRY_NESTED:
            for f, old in row.get(group, {}).items():
                new = fresh.get(group, {}).get(f)
                yield key, f"{group}.{f}", old, new, new == old


# training counters that must match the baseline EXACTLY: both are
# contract bits, not magnitudes — ``solves_per_step`` counts solver
# launches per value_and_grad step (Danskin = 1; unrolling would jump it)
# and ``loss_decreased`` is the train-smoke improvement bit
TRAINING_EXACT = ("solves_per_step", "loss_decreased")


def _training_key(row: dict) -> str:
    return str(row.get("scenario"))


def compare_training(baseline_rows, fresh_rows, tolerance: float):
    """Yield (key, field, old, new, ok) for every training counter."""
    fresh_by_key = {_training_key(r): r for r in fresh_rows}
    for row in baseline_rows:
        key = _training_key(row)
        fresh = fresh_by_key.get(key)
        if fresh is None:
            yield key, "<row>", "present", "missing", False
            continue
        for f, old in row.get("counters", {}).items():
            new = fresh.get("counters", {}).get(f)
            if f in TRAINING_EXACT:
                ok = new == old
            else:
                ok = new is not None and _within(old, new, tolerance)
            yield key, f, old, new, ok


# serving counters that must match the baseline EXACTLY: ``unterminated``
# counts lifecycle-invariant violations (a request that never reached a
# terminal status), which no tolerance can excuse
SERVING_EXACT = ("unterminated",)


def _serving_key(row: dict) -> str:
    return str(row.get("scenario"))


def compare_serving(baseline_rows, fresh_rows, tolerance: float):
    """Yield (key, field, old, new, ok) for every serving counter."""
    fresh_by_key = {_serving_key(r): r for r in fresh_rows}
    for row in baseline_rows:
        key = _serving_key(row)
        fresh = fresh_by_key.get(key)
        if fresh is None:
            yield key, "<row>", "present", "missing", False
            continue
        for f, old in row.get("counters", {}).items():
            new = fresh.get("counters", {}).get(f)
            if f in SERVING_EXACT:
                ok = new == old
            else:
                ok = new is not None and _within(old, new, tolerance)
            yield key, f, old, new, ok


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", default="BENCH_kernels.json")
    ap.add_argument("--sharded-baseline", default="BENCH_sharded.json")
    ap.add_argument("--serving-baseline", default="BENCH_serving.json")
    ap.add_argument("--geometry-baseline", default="BENCH_geometry.json")
    ap.add_argument("--training-baseline", default="BENCH_training.json")
    ap.add_argument("--tolerance", type=float, default=0.20)
    args = ap.parse_args()

    from benchmarks.bench_io import read_bench_json

    try:
        baseline_rows, version = read_bench_json(args.baseline)
    except (OSError, ValueError) as e:
        print(f"REGRESSION GATE: cannot read baseline {args.baseline}: {e}")
        return 1
    if not baseline_rows:
        print("REGRESSION GATE: baseline has no rows")
        return 1

    head = baseline_rows[0]
    L, g, n = head["L"], head["g"], head["n"]
    print(f"baseline: {args.baseline} (schema_version={version}, "
          f"L={L} g={g} n={n}, {len(baseline_rows)} rows)")

    from benchmarks import bench_kernels

    fresh_rows = bench_kernels.main(L=L, g=g, n=n, out=None)

    failures = []
    for key, field, old, new, ok in compare(
        baseline_rows, fresh_rows, args.tolerance
    ):
        status = "ok" if ok else "REGRESSION"
        print(f"  [{status}] density={key} {field}: {old} -> {new}")
        if not ok:
            failures.append((key, field, old, new))

    # wall-clock context (never gated — CPU CI runners are too noisy, and
    # device_wall_us is interpret-mode Python off-TPU)
    for row in fresh_rows:
        for impl, counters in row.get("impl", {}).items():
            for f in ("wall_us", "interpret_wall_us", "device_wall_us"):
                if f in counters:
                    print(f"  (info) density={row.get('density')} "
                          f"{impl}.{f}={counters[f]}")

    # sharded invariants (4 forced host devices, run in a subprocess)
    try:
        sharded_base, sver = read_bench_json(args.sharded_baseline)
    except (OSError, ValueError) as e:
        print(f"REGRESSION GATE: cannot read sharded baseline "
              f"{args.sharded_baseline}: {e}")
        return 1
    if not sharded_base:
        print("REGRESSION GATE: sharded baseline has no rows")
        return 1
    head = sharded_base[0]
    print(f"sharded baseline: {args.sharded_baseline} (schema_version={sver}, "
          f"{head['workload']}, {len(sharded_base)} rows)")

    from benchmarks import bench_sharded

    fresh_sharded = bench_sharded.main(
        B=head["B"], L=head["L"], g=head["g"], n=head["n"], out=None,
        impls=tuple(r["grad_impl"] for r in sharded_base),
    )
    for key, field, old, new, ok in compare_sharded(
        sharded_base, fresh_sharded, args.tolerance
    ):
        status = "ok" if ok else "REGRESSION"
        print(f"  [{status}] sharded={key} {field}: {old} -> {new}")
        if not ok:
            failures.append((key, field, old, new))

    # geometry memory-model counters (exact: deterministic byte models)
    try:
        geo_base, gver = read_bench_json(args.geometry_baseline)
    except (OSError, ValueError) as e:
        print(f"REGRESSION GATE: cannot read geometry baseline "
              f"{args.geometry_baseline}: {e}")
        return 1
    if not geo_base:
        print("REGRESSION GATE: geometry baseline has no rows")
        return 1
    head = geo_base[0]
    n_sweep = tuple(dict.fromkeys(r["n"] for r in geo_base))
    densities = tuple(dict.fromkeys(r["density"] for r in geo_base))
    print(f"geometry baseline: {args.geometry_baseline} "
          f"(schema_version={gver}, L={head['L']} g={head['g']} "
          f"n_sweep={list(n_sweep)}, {len(geo_base)} rows)")

    from benchmarks import bench_geometry

    fresh_geo = bench_geometry.main(
        L=head["L"], g=head["g"], n_sweep=n_sweep, densities=densities,
        out=None,
    )
    for key, field, old, new, ok in compare_geometry(geo_base, fresh_geo):
        status = "ok" if ok else "REGRESSION"
        print(f"  [{status}] geometry={key} {field}: {old} -> {new}")
        if not ok:
            failures.append((key, field, old, new))

    # serving SLO counters (deterministic seeded traffic, in-process)
    try:
        serving_base, pver = read_bench_json(args.serving_baseline)
    except (OSError, ValueError) as e:
        print(f"REGRESSION GATE: cannot read serving baseline "
              f"{args.serving_baseline}: {e}")
        return 1
    if not serving_base:
        print("REGRESSION GATE: serving baseline has no rows")
        return 1
    head = serving_base[0]
    print(f"serving baseline: {args.serving_baseline} (schema_version={pver}, "
          f"{len(serving_base)} scenarios, smoke={head.get('smoke', False)})")

    from benchmarks import bench_serving

    fresh_serving = bench_serving.main(
        smoke=bool(head.get("smoke", False)), out=None
    )
    for key, field, old, new, ok in compare_serving(
        serving_base, fresh_serving, args.tolerance
    ):
        status = "ok" if ok else "REGRESSION"
        print(f"  [{status}] serving={key} {field}: {old} -> {new}")
        if not ok:
            failures.append((key, field, old, new))

    # training-loop contract bits (deterministic seeded run, in-process)
    try:
        training_base, tver = read_bench_json(args.training_baseline)
    except (OSError, ValueError) as e:
        print(f"REGRESSION GATE: cannot read training baseline "
              f"{args.training_baseline}: {e}")
        return 1
    if not training_base:
        print("REGRESSION GATE: training baseline has no rows")
        return 1
    head = training_base[0]
    print(f"training baseline: {args.training_baseline} "
          f"(schema_version={tver}, {len(training_base)} scenarios, "
          f"smoke={head.get('smoke', False)})")

    from benchmarks import bench_training

    fresh_training = bench_training.main(
        smoke=bool(head.get("smoke", False)), out=None
    )
    for key, field, old, new, ok in compare_training(
        training_base, fresh_training, args.tolerance
    ):
        status = "ok" if ok else "REGRESSION"
        print(f"  [{status}] training={key} {field}: {old} -> {new}")
        if not ok:
            failures.append((key, field, old, new))
    for row in fresh_training:
        print(f"  (info) training={row['scenario']} "
              f"step_us={row['wall']['step_us']}")

    if failures:
        print(f"REGRESSION GATE: {len(failures)} counter(s) moved more than "
              f"{args.tolerance:.0%} vs the committed baselines")
        return 1
    print("REGRESSION GATE: clean")
    return 0


if __name__ == "__main__":
    sys.exit(main())
