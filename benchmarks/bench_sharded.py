"""Sharded-solver benchmark row: deterministic counters over 4 host devices.

  PYTHONPATH=src python -m benchmarks.bench_sharded [--smoke] [--out PATH]

The sharded path's perf contract is not wall-clock (interpret-mode CPU is
meaningless for that) but *invariants*: a batch sharded over D devices must
run the exact same per-problem work as unsharded — same round counts, same
screening verdict totals, ONE program launch, zero bitwise mismatches
against the unsharded batched solve.  Those are pure functions of the
solver logic, so they are committed to ``BENCH_sharded.json`` and gated by
``check_regression.py`` like the kernel counters.

The workload runs in a subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count=4`` (host device count
must be set before jax initializes); the child prints one JSON document on
stdout and the parent assembles the benchmark rows.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import textwrap
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))
sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

DEVICES = 4

_CHILD = """
    import json
    import numpy as np, jax, jax.numpy as jnp
    import repro.ot as rot
    from repro.core import groups as G
    from repro.core.ot import squared_euclidean_cost
    from repro.core.regularizers import GroupSparseReg

    B, L, g, n = {B}, {L}, {g}, {n}
    impls = {impls}
    assert jax.device_count() == {devices}, jax.device_count()

    rng = np.random.default_rng(0)
    m = L * g
    labels = np.repeat(np.arange(L), g)
    spec = G.spec_from_labels(labels, pad_to=8)
    reg = GroupSparseReg.from_rho(1.0, 0.6)
    problems = []
    for _ in range(B):
        Xs = rng.normal(size=(m, 2)) + labels[:, None] * 3.0
        Xt = rng.normal(size=(n, 2)) + rng.integers(0, L, n)[:, None] * 3.0
        C = squared_euclidean_cost(Xs, Xt).astype(np.float32)
        C /= C.max()
        problems.append(rot.Problem.from_padded(
            G.pad_cost_matrix(C, labels, spec),
            G.pad_marginal(np.full(m, 1/m, np.float32), labels, spec),
            np.full(n, 1/n, np.float32), spec, reg,
        ))

    rows = []
    for gi in impls:
        exs = rot.compile(problems[0], rot.ExecutionPlan(
            grad_impl=gi, max_iters=150, devices="all"
        ))
        sols_s = exs.solve_many(problems)
        launches = exs.stats()["launches"]
        exb = rot.compile(problems[0], rot.ExecutionPlan(
            grad_impl=gi, max_iters=150
        ))
        sols_b = exb.solve_many(problems)
        mismatches = sum(
            int(bool(jnp.any(s.result.lbfgs_state.x != u.result.lbfgs_state.x))
                or s.value != u.value or s.rounds != u.rounds)
            for s, u in zip(sols_s, sols_b)
        )
        rows.append({{
            "grad_impl": gi,
            "counters": {{
                "rounds_total": sum(s.rounds for s in sols_s),
                "rounds_max": max(s.rounds for s in sols_s),
                "zero": sum(s.stats["zero"] for s in sols_s),
                "check": sum(s.stats["check"] for s in sols_s),
                "active": sum(s.stats["active"] for s in sols_s),
                "launches": launches,
                "bitwise_mismatches": mismatches,
            }},
        }})
    print("BENCH_JSON " + json.dumps(rows))
"""


def _run_child(B: int, L: int, g: int, n: int, impls) -> list:
    src = str(Path(__file__).resolve().parents[1] / "src")
    env = dict(
        os.environ,
        XLA_FLAGS=f"--xla_force_host_platform_device_count={DEVICES}",
        JAX_PLATFORMS="cpu",
        PYTHONPATH=src + os.pathsep + os.environ.get("PYTHONPATH", ""),
    )
    code = textwrap.dedent(_CHILD).format(
        B=B, L=L, g=g, n=n, impls=list(impls), devices=DEVICES
    )
    r = subprocess.run(
        [sys.executable, "-c", code],
        env=env, capture_output=True, text=True, timeout=900,
    )
    if r.returncode != 0:
        raise RuntimeError(f"sharded bench child failed:\n{r.stderr[-3000:]}")
    for line in r.stdout.splitlines():
        if line.startswith("BENCH_JSON "):
            return json.loads(line[len("BENCH_JSON "):])
    raise RuntimeError(f"no BENCH_JSON line in child output:\n{r.stdout[-2000:]}")


def main(
    B: int = 8, L: int = 6, g: int = 8, n: int = 64,
    out: str | None = "BENCH_sharded.json",
    smoke: bool = False,
    impls=("screened", "pallas"),
) -> list:
    """Run the sharded benchmark; returns (and optionally writes) rows."""
    if smoke:
        B, L, g, n = 4, 4, 8, 32
        impls = ("screened",)
    rows = _run_child(B, L, g, n, impls)
    header = {
        "workload": f"B{B}_L{L}_g{g}_n{n}",
        "devices": DEVICES,
        "B": B, "L": L, "g": g, "n": n,
    }
    rows = [dict(header, **r) for r in rows]
    for r in rows:
        c = r["counters"]
        print(
            f"sharded {r['workload']} {r['grad_impl']}: "
            f"rounds={c['rounds_total']} launches={c['launches']} "
            f"bitwise_mismatches={c['bitwise_mismatches']}"
        )
    if out:
        try:
            from benchmarks.bench_io import write_bench_json
        except ImportError:          # invoked as a script from benchmarks/
            from bench_io import write_bench_json

        write_bench_json(out, rows)
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--out", default="BENCH_sharded.json")
    args = ap.parse_args()
    main(smoke=args.smoke, out=None if args.smoke else args.out)
