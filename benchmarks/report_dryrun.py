"""Emit the EXPERIMENTS.md §Dry-run table from dryrun_artifacts/."""
from __future__ import annotations

import argparse
import json
from pathlib import Path

from repro.configs import list_archs, SHAPES


def gib(x):
    return f"{x / 2**30:.2f}"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--artifacts", default="dryrun_artifacts")
    args = ap.parse_args()
    art = Path(args.artifacts)

    rows = [
        "| arch | shape | mesh | status | compile_s | args GiB/dev | temp GiB/dev | HLO flops/dev | wire B/dev | collectives |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    counts = {"ok": 0, "skipped": 0, "error": 0, "missing": 0}
    for arch in list_archs():
        for shape in [s.name for s in SHAPES]:
            for mesh in ("pod16x16", "pod2x16x16"):
                p = art / f"{arch}__{shape}__{mesh}.json"
                if not p.exists():
                    counts["missing"] += 1
                    continue
                r = json.loads(p.read_text())
                counts[r["status"]] += 1
                if r["status"] == "skipped":
                    rows.append(f"| {arch} | {shape} | {mesh} | skipped | — | — | — | — | — | {r['reason']} |")
                    continue
                if r["status"] == "error":
                    rows.append(f"| {arch} | {shape} | {mesh} | ERROR | — | — | — | — | — | {r['error'][:60]} |")
                    continue
                m = r["memory_analysis"]
                c = r["collectives"]
                kinds = ", ".join(
                    f"{k}x{v['count']}" for k, v in c.items()
                    if isinstance(v, dict) and v["count"]
                )
                rows.append(
                    f"| {arch} | {shape} | {mesh} | ok | {r['compile_s']} | "
                    f"{gib(m.get('argument_size_in_bytes', 0))} | "
                    f"{gib(m.get('temp_size_in_bytes', 0))} | "
                    f"{r['cost_analysis'].get('flops', 0):.2e} | "
                    f"{c['total_wire_bytes']:.2e} | {kinds} |"
                )
    print("\n".join(rows))
    print(f"\ntotals: {counts}")


if __name__ == "__main__":
    main()
