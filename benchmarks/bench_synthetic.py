"""Paper Figure 2 / Figure A: processing-time gain on the synthetic dataset.

Sweeps the number of classes |L| (Fig. 2) or samples-per-class g (Fig. A)
and reports wall-clock gain of the screened solver (Algorithm 1) over the
original method, at the paper's hyperparameter grid (trimmed by default for
CPU-container budgets; --full restores the paper's grid).
"""
from __future__ import annotations

import argparse

import numpy as np

from repro.core import groups as G
from repro.core.cpu_baseline import fast_solve, origin_solve
from repro.core.ot import squared_euclidean_cost
from repro.core.regularizers import GroupSparseReg
from repro.data.pipeline import DomainPairConfig, make_domain_pair


def _problem(L, g, seed=0):
    Xs, ys, Xt, _ = make_domain_pair(
        DomainPairConfig(num_classes=L, samples_per_class=g, seed=seed)
    )
    C = squared_euclidean_cost(Xs, Xt)
    C /= C.max()
    spec = G.spec_from_labels(ys, pad_to=8)
    m = n = L * g
    return (
        G.pad_cost_matrix(C, ys, spec),
        G.pad_marginal(np.full(m, 1 / m), ys, spec),
        np.full(n, 1 / n),
        spec,
    )


def run_sweep(sweep: str, values, gammas, rhos, maxiter=1000):
    rows = []
    for v in values:
        L, g = (v, 10) if sweep == "L" else (10, v)
        C, a, b, spec = _problem(L, g)
        t_o = t_f = 0.0
        match = True
        for gamma in gammas:
            for rho in rhos:
                reg = GroupSparseReg.from_rho(gamma, rho)
                r0 = origin_solve(C, a, b, spec, reg, maxiter=maxiter)
                r1 = fast_solve(C, a, b, spec, reg, maxiter=maxiter)
                t_o += r0.wall_time
                t_f += r1.wall_time
                match &= abs(r0.value - r1.value) <= 1e-7 * max(1, abs(r0.value))
        rows.append({
            "sweep": sweep, "value": v, "origin_s": round(t_o, 3),
            "fast_s": round(t_f, 3), "gain": round(t_o / max(t_f, 1e-9), 2),
            "objective_match": bool(match),
        })
        print(f"  {sweep}={v:5d}: origin={t_o:7.2f}s fast={t_f:7.2f}s "
              f"gain={t_o/max(t_f,1e-9):5.2f}x match={match}")
    return rows


def main(full: bool = False, out: str | None = None, smoke: bool = False):
    if smoke:
        values_L, values_g = [10], [10]
        gammas, rhos = [1.0], [0.8]
        maxiter = 200
    elif full:
        values_L = [10, 20, 40, 80, 160, 320]
        values_g = [10, 20, 40, 80, 160]
        gammas = [1e-2, 1e-1, 1e0, 1e1]
        rhos = [0.2, 0.4, 0.6, 0.8]
        maxiter = 1000
    else:
        values_L = [10, 20, 40, 80]
        values_g = [10, 20, 40]
        gammas = [0.1, 1.0]
        rhos = [0.4, 0.8]
        maxiter = 1000
    print("Figure 2 (|L| sweep, g=10):")
    rows = run_sweep("L", values_L, gammas, rhos, maxiter=maxiter)
    print("Figure A (g sweep, |L|=10):")
    rows += run_sweep("g", values_g, gammas, rhos, maxiter=maxiter)
    if out:
        try:
            from benchmarks.bench_io import write_bench_json
        except ImportError:          # invoked as a script from benchmarks/
            from bench_io import write_bench_json

        write_bench_json(out, rows)
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--out", default="bench_synthetic.json")
    args = ap.parse_args()
    main(args.full, args.out, smoke=args.smoke)
