"""Benchmark harness entry point: one function per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run [--full]

Prints ``name,metric,derived`` CSV rows per experiment and writes JSON
artifacts next to the repo root.  --full restores the paper's grids (slow
on one CPU core); default grids are trimmed but cover every figure's
qualitative claim.
"""
from __future__ import annotations

import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))
sys.path.insert(0, str(Path(__file__).resolve().parents[1]))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--skip", nargs="*", default=[],
                    choices=["synthetic", "gradcount", "objective", "kernels"])
    args = ap.parse_args()

    print("name,metric,derived")

    if "synthetic" not in args.skip:
        from benchmarks import bench_synthetic

        rows = bench_synthetic.main(full=args.full, out="bench_synthetic.json")
        for r in rows:
            print(f"fig2_{r['sweep']}{r['value']},{r['fast_s']},gain={r['gain']}x")

    if "gradcount" not in args.skip:
        from benchmarks import bench_gradcount

        rows = bench_gradcount.main(out="bench_gradcount.json")
        for r in rows:
            if r["fig"] == "6":
                print(f"fig6_rho{r['rho']},{r['ours_blocks']},"
                      f"computed_frac={r['computed_frac']}")
            else:
                print(f"figD_gamma{r['gamma']},{r['fast_with_lower_s']},"
                      f"gain={r['gain_with_lower']}x")

    if "objective" not in args.skip:
        from benchmarks import bench_objective

        rows = bench_objective.main(full=args.full, out="bench_objective.json")
        for r in rows:
            print(f"table1_L{r['classes']},{r['ours']:.6e},match={r['match']}")

    if "kernels" not in args.skip:
        from benchmarks import bench_kernels

        rows = bench_kernels.main(out="BENCH_kernels.json")
        for r in rows:
            c = r["impl"]["pallas_compact"]
            d = r["impl"]["xla_dense"]
            speedup = round(d["c_bytes"] / max(c["c_bytes"], 1), 2)
            print(f"kernel_gradpsi_d{r['density']},{c['grid_steps']},"
                  f"modeled_tpu_speedup={speedup}x")


if __name__ == "__main__":
    main()
