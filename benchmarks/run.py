"""Benchmark harness entry point: one function per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run [--full | --smoke]

Prints ``name,metric,derived`` CSV rows per experiment and writes JSON
artifacts next to the repo root (stable key order + schema_version via
benchmarks.bench_io, so the CI regression gate diffs cleanly).  --full
restores the paper's grids (slow on one CPU core); default grids are
trimmed but cover every figure's qualitative claim; --smoke runs tiny
shapes in seconds (CI sanity — no JSON artifacts are written, so the
committed perf-trajectory files are never clobbered by a smoke run).
"""
from __future__ import annotations

import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))
sys.path.insert(0, str(Path(__file__).resolve().parents[1]))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes, no JSON artifacts (CI sanity)")
    ap.add_argument("--skip", nargs="*", default=[],
                    choices=["synthetic", "gradcount", "objective", "kernels",
                             "sharded", "geometry"])
    args = ap.parse_args()
    if args.full and args.smoke:
        ap.error("--full and --smoke are mutually exclusive")
    smoke = args.smoke

    print("name,metric,derived")

    if "synthetic" not in args.skip:
        from benchmarks import bench_synthetic

        rows = bench_synthetic.main(
            full=args.full, smoke=smoke,
            out=None if smoke else "bench_synthetic.json",
        )
        for r in rows:
            print(f"fig2_{r['sweep']}{r['value']},{r['fast_s']},gain={r['gain']}x")

    if "gradcount" not in args.skip:
        from benchmarks import bench_gradcount

        rows = bench_gradcount.main(
            smoke=smoke, out=None if smoke else "bench_gradcount.json"
        )
        for r in rows:
            if r["fig"] == "6":
                print(f"fig6_rho{r['rho']},{r['ours_blocks']},"
                      f"computed_frac={r['computed_frac']}")
            else:
                print(f"figD_gamma{r['gamma']},{r['fast_with_lower_s']},"
                      f"gain={r['gain_with_lower']}x")

    if "objective" not in args.skip:
        from benchmarks import bench_objective

        rows = bench_objective.main(
            full=args.full, smoke=smoke,
            out=None if smoke else "bench_objective.json",
        )
        for r in rows:
            print(f"table1_L{r['classes']},{r['ours']:.6e},match={r['match']}")

    if "kernels" not in args.skip:
        from benchmarks import bench_kernels

        if smoke:
            rows = bench_kernels.main(
                L=8, g=8, n=256, out=None, densities=(1.0, 0.25), batch=2
            )
        else:
            rows = bench_kernels.main(out="BENCH_kernels.json")
        for r in rows:
            impl = r["impl"]
            if "pallas_compact" in impl:
                c = impl["pallas_compact"]
                d = impl["xla_dense"]
                speedup = round(d["c_bytes"] / max(c["c_bytes"], 1), 2)
                print(f"kernel_gradpsi_d{r['density']},{c['grid_steps']},"
                      f"modeled_tpu_speedup={speedup}x")
            else:
                c = impl["pallas_compact_batched"]
                print(f"kernel_gradpsi_{r['density']},{c['grid_steps']},"
                      f"live={r['live_tiles']}/{r['total_tiles']}")

    if "geometry" not in args.skip:
        from benchmarks import bench_geometry

        rows = bench_geometry.main(
            smoke=smoke, out=None if smoke else "BENCH_geometry.json"
        )
        for r in rows:
            ob = r["operand_bytes"]
            save = round(ob["dense"] / max(ob["factorized"], 1), 1)
            print(f"geometry_n{r['n']}_d{r['density']},{r['grid_steps']},"
                  f"operand_save={save}x")

    if "sharded" not in args.skip:
        from benchmarks import bench_sharded

        rows = bench_sharded.main(
            smoke=smoke, out=None if smoke else "BENCH_sharded.json"
        )
        for r in rows:
            c = r["counters"]
            print(f"sharded_{r['workload']}_{r['grad_impl']},"
                  f"{c['rounds_total']},"
                  f"bitwise_mismatches={c['bitwise_mismatches']}")


if __name__ == "__main__":
    main()
