"""Stable JSON artifacts for the benchmark suite.

Every ``BENCH_*.json`` / ``bench_*.json`` the harness writes goes through
:func:`write_bench_json`: a top-level ``{"schema_version": N, "rows":
[...]}`` envelope, keys sorted, fixed indent — so the CI regression gate
(`benchmarks/check_regression.py`) and PR diffs compare cleanly across
runs instead of churning on dict ordering.

``read_bench_json`` also accepts the pre-envelope format (a bare row
list, schema_version 0) so the gate can diff against artifacts committed
before the envelope existed.
"""
from __future__ import annotations

import json
from typing import Any, Tuple

BENCH_SCHEMA_VERSION = 2


def write_bench_json(path: str, rows: Any) -> None:
    """Write rows under the versioned envelope with a stable key order."""
    payload = {"schema_version": BENCH_SCHEMA_VERSION, "rows": rows}
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
        f.write("\n")


def read_bench_json(path: str) -> Tuple[Any, int]:
    """Read a benchmark artifact -> (rows, schema_version)."""
    with open(path) as f:
        payload = json.load(f)
    if isinstance(payload, dict) and "schema_version" in payload:
        return payload["rows"], int(payload["schema_version"])
    return payload, 0
