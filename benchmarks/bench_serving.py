"""Serving SLO benchmark: deterministic latency-proxy counters under load.

  PYTHONPATH=src python benchmarks/bench_serving.py [--smoke] [--out PATH]

Replays three seeded traffic scenarios against :class:`OTServingEngine`
and records DETERMINISTIC serving counters per scenario:

  * ``steady``   — arrival rate below slot throughput, no faults: the
    happy-path envelope (everything DONE, zero shed/failed),
  * ``overload`` — 4x the steady arrival rate into a tiny pending queue
    with mixed priorities and deadlines: exercises priority shedding and
    queue-side deadline expiry,
  * ``chaos``    — the overload mix plus every fault kind from
    :mod:`repro.utils.faults` on a bounded budget: exercises quarantine,
    the retry ladder and the slow-bucket path.

Counters are tick-denominated latency proxies (``p50_ticks`` /
``p99_ticks`` of submission->terminal), per-terminal-status totals,
engine launches and retry attempts — all pure functions of the seeded
trace and the solver's deterministic round counts, so
``benchmarks/check_regression.py`` gates them against the committed
``BENCH_serving.json`` (20% tolerance).  ``unterminated`` is gated
EXACTLY at its committed value of 0: it counts requests that failed to
reach a terminal status, i.e. violations of the serving lifecycle
invariant.  No wall-clock is recorded — the point is the counter
envelope, not machine speed.
"""
from __future__ import annotations

import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))
sys.path.insert(0, str(Path(__file__).resolve().parents[1]))


def _scenarios(smoke: bool):
    """The benchmark matrix: (name, traffic spec, policy, fault specs)."""
    from repro.serving.policy import ServingPolicy
    from repro.serving.traffic import TrafficSpec
    from repro.utils.faults import FaultSpec

    n_req = 6 if smoke else 12
    shapes = ((12, 20, 3), (16, 24, 4))
    return [
        (
            "steady",
            TrafficSpec(num_requests=n_req, arrival_rate=1.0, seed=7,
                        shapes=shapes),
            ServingPolicy(),
            (),
        ),
        (
            "overload",
            TrafficSpec(num_requests=n_req, arrival_rate=4.0, seed=7,
                        shapes=shapes, deadline=4, deadline_fraction=0.5,
                        priorities=(0, 1, 2)),
            ServingPolicy(max_pending=3),
            (),
        ),
        (
            "chaos",
            TrafficSpec(num_requests=n_req, arrival_rate=4.0, seed=7,
                        shapes=shapes, deadline=6, deadline_fraction=0.5,
                        priorities=(0, 1, 2)),
            ServingPolicy(max_pending=4, max_attempts=3),
            (
                FaultSpec("nan_cost", count=2),
                FaultSpec("lbfgs_fail", count=1, after_tick=1),
                FaultSpec("admit_fail", count=2),
                FaultSpec("slow_bucket", count=2, after_tick=2),
            ),
        ),
    ]


def _run_scenario(name, spec, policy, fault_specs) -> dict:
    import numpy as np

    from repro.core.lbfgs import LbfgsOptions
    from repro.core.regularizers import GroupSparseReg
    from repro.core.solver import SolveOptions
    from repro.serving.ot_engine import OTServingEngine
    from repro.serving.traffic import drive, make_trace
    from repro.utils.faults import injected

    opts = SolveOptions(grad_impl="screened",
                        lbfgs=LbfgsOptions(max_iters=150))
    engine = OTServingEngine(GroupSparseReg.from_rho(1.0, 0.6), opts,
                             max_batch=2, policy=policy)
    trace = make_trace(spec)
    with injected(*fault_specs):
        done = drive(engine, trace, max_ticks=1000)

    stats = engine.stats()
    ticks = sorted(r.ticks_in_flight for r in done
                   if r.ticks_in_flight is not None)
    pct = lambda q: int(np.percentile(ticks, q)) if ticks else 0
    counters = {
        "submitted": stats["submitted"],
        "done": stats["status"]["DONE"],
        "failed": stats["status"]["FAILED"],
        "shed": stats["status"]["SHED"],
        "deadline_exceeded": stats["status"]["DEADLINE_EXCEEDED"],
        # the lifecycle invariant: every submitted request must have come
        # back terminal.  Gated EXACTLY at 0 by check_regression.py.
        "unterminated": spec.num_requests - len(done),
        "p50_ticks": pct(50),
        "p99_ticks": pct(99),
        "ticks": stats["ticks"],
        "launches": stats["launches"],
        "retry_attempts": stats["retry_attempts"],
        "evictions": stats["evictions"],
    }
    return {"scenario": name, "config": spec.config(),
            "policy": policy.config(), "counters": counters,
            "smoke": None}          # filled by main(): gate replays same mode


def main(smoke: bool = False, out: str = "BENCH_serving.json"):
    """Run the scenario matrix; write ``out`` unless None; return rows."""
    rows = []
    for name, spec, policy, fault_specs in _scenarios(smoke):
        row = _run_scenario(name, spec, policy, fault_specs)
        row["smoke"] = bool(smoke)
        c = row["counters"]
        print(f"[{name:9s}] done={c['done']} failed={c['failed']} "
              f"shed={c['shed']} deadline={c['deadline_exceeded']} "
              f"unterminated={c['unterminated']} p50={c['p50_ticks']} "
              f"p99={c['p99_ticks']} launches={c['launches']} "
              f"retries={c['retry_attempts']}")
        rows.append(row)
    if out is not None:
        from benchmarks.bench_io import write_bench_json

        write_bench_json(out, rows)
        print(f"wrote {out}")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small traces (CI bench job)")
    ap.add_argument("--out", default="BENCH_serving.json")
    args = ap.parse_args()
    main(smoke=args.smoke, out=args.out)
