"""Paper Table 1: maximum objective values after convergence, origin vs ours.

The paper reports identical max objective values across the hyperparameter
grid for every class count — Theorem 2's empirical check.  We reproduce the
table (class counts trimmed by default; --full goes to 1280 like the paper).
"""
from __future__ import annotations

import argparse

import numpy as np

from repro.core import groups as G
from repro.core.cpu_baseline import fast_solve, origin_solve
from repro.core.ot import squared_euclidean_cost
from repro.core.regularizers import GroupSparseReg
from repro.data.pipeline import DomainPairConfig, make_domain_pair


def main(full: bool = False, out: str | None = None, smoke: bool = False):
    if smoke:
        counts, gammas, rhos = [10], [1.0], [0.8]
    else:
        counts = [10, 20, 40, 80, 160, 320, 640, 1280] if full else [10, 20, 40, 80]
        gammas = [1e-2, 1e-1, 1e0, 1e1] if full else [0.1, 1.0]
        rhos = [0.2, 0.4, 0.6, 0.8] if full else [0.4, 0.8]
    rows = []
    print("Table 1: max objective after convergence (origin vs ours)")
    for L in counts:
        Xs, ys, Xt, _ = make_domain_pair(
            DomainPairConfig(num_classes=L, samples_per_class=10)
        )
        C = squared_euclidean_cost(Xs, Xt)
        C /= C.max()
        spec = G.spec_from_labels(ys, pad_to=8)
        m = n = L * 10
        C_pad = G.pad_cost_matrix(C, ys, spec)
        a = G.pad_marginal(np.full(m, 1 / m), ys, spec)
        b = np.full(n, 1 / n)
        best_o = best_f = -np.inf
        for gamma in gammas:
            for rho in rhos:
                reg = GroupSparseReg.from_rho(gamma, rho)
                best_o = max(best_o, origin_solve(C_pad, a, b, spec, reg).value)
                best_f = max(best_f, fast_solve(C_pad, a, b, spec, reg).value)
        rows.append({
            "classes": L,
            "origin": float(best_o),
            "ours": float(best_f),
            "match": bool(abs(best_o - best_f) <= 1e-7 * max(1, abs(best_o))),
        })
        print(f"  |L|={L:5d}: origin={best_o:.6e} ours={best_f:.6e} "
              f"match={rows[-1]['match']}")
    if out:
        try:
            from benchmarks.bench_io import write_bench_json
        except ImportError:          # invoked as a script from benchmarks/
            from bench_io import write_bench_json

        write_bench_json(out, rows)
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--out", default="bench_objective.json")
    args = ap.parse_args()
    main(args.full, args.out, smoke=args.smoke)
