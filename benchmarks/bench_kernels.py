"""Kernel-path benchmark: dense vs screened XLA vs the Pallas grid modes
(dense grid / compacted grid / fused single-launch) across densities.

Interpret-mode Pallas wall-clock is Python-per-grid-step, so it is reported
separately (``interpret_wall_us``) and is meaningful only *relatively*: the
compacted grid issues fewer steps, so its interpret time drops with density
exactly like its TPU step count would.  The TPU-facing numbers are modeled:
bytes-of-C read (what the v5e roofline converts to time for this ~1.2
flop/byte, bandwidth-bound kernel) and grid steps issued (the compact
kernel's count is read back from its in-kernel step counter, not assumed).

The ``real_iterate`` row additionally compares the steady-state oracle
schedules: the fused screen+gradient mega-kernel (``pallas_fused``, ONE
Pallas launch per L-BFGS evaluation) vs the two-launch reference
(``oracle_two_launch``, screen kernel then gradient kernel).  Their
``launches_per_eval`` counters come from the kernel dispatch registry and
are gated exactly by check_regression; the warmed, fully-synced
``device_wall_us`` timings ride along informationally (CPU CI runs the
kernels in interpret mode, so only a TPU run makes them roofline-meaningful).

Writes ``BENCH_kernels.json`` — a list of rows, one per density plus one at
a real mid-optimization iterate — tracked across PRs for perf trajectory.
"""
from __future__ import annotations

import argparse
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import groups as G
from repro.core.dual import DualProblem, dual_value_and_grad
from repro.core.ot import squared_euclidean_cost
from repro.core.regularizers import GroupSparseReg
from repro.data.pipeline import DomainPairConfig, make_domain_pair
from repro.kernels import ops as kops
from repro.kernels.gradpsi import build_tile_schedule, gradpsi_pallas, gradpsi_pallas_compact

V5E_HBM = 819e9


def _time(fn, *args, iters=10):
    # sync EVERY output leaf: block_until_ready() on the first leaf alone
    # lets the remaining outputs of a multi-output kernel finish inside (or
    # after) the timed region, under-counting the warmup and mis-attributing
    # work across the t0 boundary.
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def _density_row(alpha, beta, a, b, C_pad, prob, pp, flags, label, *,
                 t_dense_us, iters=3):
    """One BENCH row: steps/bytes/wall for each impl at the given flags."""
    Lt, Nt = pp.grid
    total = Lt * Nt
    live = int(jnp.sum(flags != 0))
    tile_bytes = pp.tile_l * pp.g * pp.tile_n * jnp.dtype(pp.Cp.dtype).itemsize

    # XLA screened reference (masked closed form) at this density
    mask = jnp.repeat(jnp.repeat(flags == 0, pp.tile_l, 0), pp.tile_n, 1)
    mask = mask[: prob.num_groups, : prob.n]
    screened = jax.jit(
        lambda al, be: dual_value_and_grad(
            al, be, C_pad, a, b, prob, zero_mask=mask
        )
    )
    t_screened = _time(screened, alpha, beta)

    # pallas kernels, interpret mode (CPU container) — relative wall only
    alphap, betap = kops.pad_tile_inputs(alpha, beta, pp)
    kw = dict(num_groups=pp.L_pad, group_size=pp.g,
              tau=prob.reg.tau, gamma=prob.reg.gamma,
              tile_l=pp.tile_l, tile_n=pp.tile_n, interpret=True)
    grid_fn = jax.jit(lambda f: gradpsi_pallas(alphap, betap, pp.Cp, f, **kw))
    t_grid = _time(grid_fn, flags, iters=iters)

    sched, nact = build_tile_schedule(flags)
    compact_fn = jax.jit(
        lambda s_, n_: gradpsi_pallas_compact(alphap, betap, pp.Cp, s_, n_, **kw)
    )
    *_, steps = compact_fn(sched, nact)
    t_compact = _time(compact_fn, sched, nact, iters=iters)
    steps = int(steps)

    bytes_dense = total * tile_bytes
    bytes_grid = max(live, 1) * tile_bytes      # skipped steps elide the DMA
    bytes_compact = steps * tile_bytes

    return {
        "density": label,
        "live_tiles": live,
        "total_tiles": total,
        "live_frac": round(live / total, 4),
        "impl": {
            "xla_dense": {
                "wall_us": round(t_dense_us, 1),
                "grid_steps": total,
                "c_bytes": bytes_dense,
                "v5e_hbm_us": round(bytes_dense / V5E_HBM * 1e6, 2),
            },
            "xla_screened": {
                "wall_us": round(t_screened * 1e6, 1),
                "grid_steps": total,
                "c_bytes": bytes_dense,   # XLA reads all of C, masks after
                "v5e_hbm_us": round(bytes_dense / V5E_HBM * 1e6, 2),
            },
            "pallas_grid": {
                "interpret_wall_us": round(t_grid * 1e6, 1),
                "grid_steps": total,
                "c_bytes": bytes_grid,
                "v5e_hbm_us": round(bytes_grid / V5E_HBM * 1e6, 2),
            },
            "pallas_compact": {
                "interpret_wall_us": round(t_compact * 1e6, 1),
                "grid_steps": steps,
                "c_bytes": bytes_compact,
                "v5e_hbm_us": round(bytes_compact / V5E_HBM * 1e6, 2),
            },
        },
    }


def _fused_oracle_entries(alpha, beta, a, b, pstate, pp, prob, iters=3):
    """Steady-state oracle comparison at a real iterate: launches + wall.

    ``kops.dual_value_and_grad_fused`` exposes both schedules behind one
    entry point: ``impl='grid'`` is the fused single-launch mega-kernel
    (verdicts in-register), ``impl='compact'`` the two-launch
    screen -> gradient reference.  ``launches_per_eval`` is read from the
    kernel dispatch registry after a cache-clean trace — a property of the
    program, not a timing — so check_regression gates it EXACTLY (2 -> 1
    is the whole point of the fused route).  ``device_wall_us`` is a
    warmed, fully-synced wall-clock on whatever backend is running
    (interpret-mode Python on CPU CI; real kernels on TPU) and is recorded
    informationally, never gated.
    """
    from repro.kernels import gradpsi as gk

    entries = {}
    for name, impl in (("fused", "grid"), ("two_launch", "compact")):
        fn = jax.jit(
            lambda al, be, impl=impl: kops.dual_value_and_grad_fused(
                al, be, a, b, pstate, pp, prob, impl=impl
            )
        )
        jax.clear_caches()
        gk.reset_launch_counts()
        jax.block_until_ready(fn(alpha, beta))
        launches = sum(gk.launch_counts().values())
        t = _time(fn, alpha, beta, iters=iters)
        entries[name] = {
            "launches_per_eval": int(launches),
            "device_wall_us": round(t * 1e6, 1),
        }
    return entries


def _batch_row(pp, prob, alpha, beta, B, densities, rng):
    """Batched compact path: one dynamic grid over B problems' active lists.

    The deterministic contract: total grid steps == the batch's total
    surviving tiles (a heavily-screened problem contributes its few tiles,
    not a worst-case padding).  Counters only — wall-clock of the batched
    interpret path is dominated by Python per-step cost.
    """
    import jax.numpy as jnp

    from repro.kernels.gradpsi import (
        build_batch_tile_schedule,
        gradpsi_pallas_compact_batched,
    )

    flags = np.stack(
        [(rng.random(pp.grid) < d).astype(np.int32) for d in densities[:B]]
    )
    live = int(flags.sum())
    alphap, betap = kops.pad_tile_inputs(alpha, beta, pp)
    alphab = jnp.broadcast_to(alphap, (B,) + alphap.shape)
    betab = jnp.broadcast_to(betap, (B,) + betap.shape)
    Cb = jnp.broadcast_to(pp.Cp, (B,) + pp.Cp.shape)
    sched, nact = build_batch_tile_schedule(jnp.asarray(flags))
    *_, steps = gradpsi_pallas_compact_batched(
        alphab, betab, Cb, sched, nact,
        num_groups=pp.L_pad, group_size=pp.g,
        tau=prob.reg.tau, gamma=prob.reg.gamma,
        tile_l=pp.tile_l, tile_n=pp.tile_n, interpret=True,
    )
    tile_bytes = pp.tile_l * pp.g * pp.tile_n * jnp.dtype(pp.Cp.dtype).itemsize
    return {
        "density": "batch_mixed",
        "batch": B,
        "per_problem_density": list(densities[:B]),
        "live_tiles": live,
        "total_tiles": B * pp.num_tiles,
        "live_frac": round(live / (B * pp.num_tiles), 4),
        "impl": {
            "pallas_compact_batched": {
                "grid_steps": int(steps),
                "c_bytes": int(steps) * tile_bytes,
                "v5e_hbm_us": round(int(steps) * tile_bytes / V5E_HBM * 1e6, 2),
            },
        },
    }


def main(L: int = 64, g: int = 16, n: int = 1024,
         out: str | None = "BENCH_kernels.json",
         densities=(1.0, 0.5, 0.25, 0.1, 0.02), batch: int = 4):
    Xs, ys, Xt, _ = make_domain_pair(
        DomainPairConfig(num_classes=L, samples_per_class=g, dim=8)
    )
    Xt = Xt[:n] if n <= len(Xt) else np.tile(Xt, (n // len(Xt) + 1, 1))[:n]
    C = squared_euclidean_cost(Xs, Xt).astype(np.float32)
    C /= C.max()
    spec = G.spec_from_labels(ys, pad_to=8)
    m = L * g
    C_pad = jnp.asarray(G.pad_cost_matrix(C, ys, spec))
    a = jnp.asarray(G.pad_marginal(np.full(m, 1 / m, np.float32), ys, spec))
    b = jnp.asarray(np.full(n, 1 / n, np.float32))
    reg = GroupSparseReg.from_rho(1.0, 0.8)
    prob = DualProblem(spec.num_groups, spec.group_size, n, reg)
    sqrt_g = jnp.asarray(spec.sqrt_sizes())

    pp = kops.prepare_padded_problem(C_pad, prob)
    rng = np.random.default_rng(0)
    alpha = jnp.asarray(rng.normal(size=spec.m_pad).astype(np.float32) * 0.1)
    beta = jnp.asarray(rng.normal(size=n).astype(np.float32) * 0.1)

    dense = jax.jit(lambda al, be: dual_value_and_grad(al, be, C_pad, a, b, prob))
    t_dense_us = _time(dense, alpha, beta) * 1e6

    rows = []
    for d in densities:
        f = (rng.random(pp.grid) < d).astype(np.int32)
        rows.append(_density_row(
            alpha, beta, a, b, C_pad, prob, pp, jnp.asarray(f), d,
            t_dense_us=t_dense_us,
        ))

    # one row at a REAL mid-optimization iterate (a random point screens
    # ~everything and says nothing about the working regime)
    from repro.core.lbfgs import LbfgsOptions
    from repro.core.solver import SolveOptions, solve_dual

    res = solve_dual(
        C_pad, a, b, spec, reg,
        SolveOptions(grad_impl="screened",
                     lbfgs=LbfgsOptions(max_iters=20, gtol=0.0)),
    )
    st = res.screen_state
    pstate = kops.pad_screen_state(st, sqrt_g, pp)
    flags_real = kops.screen_tile_flags(
        pstate, res.alpha, res.beta, pp, reg.tau
    )
    rows.append(_density_row(
        res.alpha, res.beta, a, b, C_pad, prob, pp, flags_real, "real_iterate",
        t_dense_us=t_dense_us,
    ))

    # fused vs two-launch steady-state oracle at the SAME real iterate.
    # The fused dense grid issues every step and DMAs every cost tile
    # (BlockSpec index maps cannot see the in-register verdict), so its
    # deterministic counters are total-shaped; the win it is gated on is
    # launches_per_eval == 1 vs the reference's 2.
    tile_bytes = pp.tile_l * pp.g * pp.tile_n * jnp.dtype(pp.Cp.dtype).itemsize
    total = pp.num_tiles
    live_real = int(jnp.sum(flags_real != 0))
    oracle = _fused_oracle_entries(res.alpha, res.beta, a, b, pstate, pp, prob)
    rows[-1]["impl"]["pallas_fused"] = dict(
        oracle["fused"],
        grid_steps=total,
        c_bytes=total * tile_bytes,
        compute_tiles=live_real,
        v5e_hbm_us=round(total * tile_bytes / V5E_HBM * 1e6, 2),
    )
    rows[-1]["impl"]["oracle_two_launch"] = oracle["two_launch"]

    # batched compact path: one grid over B problems at mixed densities
    if batch > 1:
        rows.append(_batch_row(
            pp, prob, alpha, beta, batch, list(densities) + [0.02] * batch, rng
        ))

    header = {
        "L": spec.num_groups, "g": spec.group_size, "n": n,
        "tile_l": pp.tile_l, "tile_n": pp.tile_n,
        "backend": jax.default_backend(),
    }
    rows = [dict(header, **r) for r in rows]
    for r in rows:
        c = r["impl"].get("pallas_compact") or r["impl"]["pallas_compact_batched"]
        print(f"density={r['density']} live={r['live_tiles']}/{r['total_tiles']}"
              f" compact_steps={c['grid_steps']} compact_bytes={c['c_bytes']}")
    if out:
        try:
            from benchmarks.bench_io import write_bench_json
        except ImportError:          # invoked as a script from benchmarks/
            from bench_io import write_bench_json

        write_bench_json(out, rows)
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--L", type=int, default=64)
    ap.add_argument("--g", type=int, default=16)
    ap.add_argument("--n", type=int, default=1024)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--out", default="BENCH_kernels.json")
    args = ap.parse_args()
    main(args.L, args.g, args.n, args.out, batch=args.batch)
