"""Kernel-path microbenchmark: screened vs dense dual gradient on XLA-CPU,
plus the modeled TPU HBM-traffic saving of the block-masked Pallas kernel.

Interpret-mode Pallas timing is meaningless (Python per-block), so the
wall-clock comparison here uses the XLA paths; the Pallas kernel's benefit
is reported as bytes-of-C-not-read, which is what the v5e roofline converts
to time (the kernel is ~1.2 flop/byte, firmly bandwidth-bound).
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import groups as G
from repro.core import screening as S
from repro.core.dual import DualProblem, dual_value_and_grad, snapshot_norms
from repro.core.ot import squared_euclidean_cost
from repro.core.regularizers import GroupSparseReg
from repro.data.pipeline import DomainPairConfig, make_domain_pair

V5E_HBM = 819e9


def _time(fn, *args, iters=20):
    fn(*args)[0].block_until_ready()
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.tree_util.tree_leaves(out)[0].block_until_ready()
    return (time.perf_counter() - t0) / iters


def main(L: int = 64, g: int = 16, n: int = 1024, out: str | None = None):
    Xs, ys, Xt, _ = make_domain_pair(
        DomainPairConfig(num_classes=L, samples_per_class=g, dim=8)
    )
    Xt = Xt[:n] if n <= len(Xt) else np.tile(Xt, (n // len(Xt) + 1, 1))[:n]
    C = squared_euclidean_cost(Xs, Xt).astype(np.float32)
    C /= C.max()
    spec = G.spec_from_labels(ys, pad_to=8)
    m = L * g
    C_pad = jnp.asarray(G.pad_cost_matrix(C, ys, spec))
    a = jnp.asarray(G.pad_marginal(np.full(m, 1 / m, np.float32), ys, spec))
    b = jnp.asarray(np.full(n, 1 / n, np.float32))
    reg = GroupSparseReg.from_rho(1.0, 0.8)
    prob = DualProblem(spec.num_groups, spec.group_size, n, reg)
    row_mask = jnp.asarray(spec.row_mask().reshape(-1))
    sqrt_g = jnp.asarray(spec.sqrt_sizes())

    # measure screening at a REAL mid-optimization iterate (a random point
    # screens ~everything and says nothing about the working regime)
    from repro.core.lbfgs import LbfgsOptions
    from repro.core.solver import SolveOptions, solve_dual

    res = solve_dual(
        C_pad, a, b, spec, reg,
        SolveOptions(grad_impl="screened",
                     lbfgs=LbfgsOptions(max_iters=20, gtol=0.0)),
    )
    st = res.screen_state
    a2, b2 = res.alpha, res.beta
    verdict = S.verdicts(st, a2, b2, sqrt_g, reg.tau)
    zero_frac = float(jnp.mean(verdict == S.ZERO))

    dense = jax.jit(lambda al, be: dual_value_and_grad(al, be, C_pad, a, b, prob))
    t_dense = _time(dense, a2, b2)

    from repro.core.screening import tile_flags
    flags = tile_flags(verdict, 8, 128)
    tile_live = float(jnp.mean(flags))
    bytes_full = C_pad.size * 4
    bytes_masked = bytes_full * tile_live

    rows = [{
        "L": spec.num_groups, "g": spec.group_size, "n": n,
        "zero_frac": round(zero_frac, 4),
        "tile_live_frac": round(tile_live, 4),
        "xla_dense_us": round(t_dense * 1e6, 1),
        "C_bytes_full": int(bytes_full),
        "C_bytes_masked": int(bytes_masked),
        "v5e_time_full_us": round(bytes_full / V5E_HBM * 1e6, 2),
        "v5e_time_masked_us": round(bytes_masked / V5E_HBM * 1e6, 2),
        # cap at the tile-count granularity: one live tile is the floor
        "modeled_speedup": round(
            1.0 / max(tile_live, 1.0 / max(flags.size, 1)), 2
        ),
    }]
    print(json.dumps(rows[0], indent=2))
    if out:
        with open(out, "w") as f:
            json.dump(rows, f, indent=2)
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--L", type=int, default=64)
    ap.add_argument("--g", type=int, default=16)
    ap.add_argument("--n", type=int, default=1024)
    ap.add_argument("--out", default="bench_kernels.json")
    args = ap.parse_args()
    main(args.L, args.g, args.n, args.out)
