"""Paper Figure 6 / Figure C / Figure D: gradient-computation bookkeeping.

Counts gradient group-block computations for origin vs Algorithm 1 across
rho (Fig. 6), per-round skip trajectories (Fig. C's flavor), and the
with/without-lower-bound ablation (Fig. D).
"""
from __future__ import annotations

import argparse

import numpy as np

from repro.core import groups as G
from repro.core.cpu_baseline import fast_solve, origin_solve
from repro.core.ot import squared_euclidean_cost
from repro.core.regularizers import GroupSparseReg
from repro.data.pipeline import DomainPairConfig, make_domain_pair


def _problem(L=10, g=50, seed=0):
    """Digit-recognition-like scale stand-in (10 classes, many samples)."""
    Xs, ys, Xt, _ = make_domain_pair(
        DomainPairConfig(num_classes=L, samples_per_class=g, dim=16, seed=seed)
    )
    C = squared_euclidean_cost(Xs, Xt)
    C /= C.max()
    spec = G.spec_from_labels(ys, pad_to=8)
    m = n = L * g
    return (
        G.pad_cost_matrix(C, ys, spec),
        G.pad_marginal(np.full(m, 1 / m), ys, spec),
        np.full(n, 1 / n),
        spec,
    )


def main(gamma: float = 0.1, out: str | None = None, smoke: bool = False):
    C, a, b, spec = _problem(L=5, g=10) if smoke else _problem()
    rows = []
    rhos = (0.8,) if smoke else (0.2, 0.4, 0.6, 0.8)
    gammas_d = (0.1,) if smoke else (0.001, 0.01, 0.1)
    print(f"Figure 6: gradient-computation counts (gamma={gamma}):")
    for rho in rhos:
        reg = GroupSparseReg.from_rho(gamma, rho)
        r0 = origin_solve(C, a, b, spec, reg)
        r1 = fast_solve(C, a, b, spec, reg)
        frac = r1.n_blocks_computed / max(r0.n_blocks_computed, 1)
        rows.append({
            "fig": "6", "rho": rho,
            "origin_blocks": r0.n_blocks_computed,
            "ours_blocks": r1.n_blocks_computed,
            "ours_active": r1.n_blocks_active,
            "computed_frac": round(frac, 5),
            "objective_match": bool(
                abs(r0.value - r1.value) <= 1e-7 * max(1, abs(r0.value))
            ),
        })
        print(f"  rho={rho}: origin={r0.n_blocks_computed} "
              f"ours={r1.n_blocks_computed} ({100*frac:.2f}%) "
              f"active={r1.n_blocks_active}")

    print("Figure D: lower-bound (idea 2) ablation (|L|=10):")
    for gamma_d in gammas_d:
        reg = GroupSparseReg.from_rho(gamma_d, 0.8)
        r0 = origin_solve(C, a, b, spec, reg)
        r_no = fast_solve(C, a, b, spec, reg, use_lower=False)
        r_yes = fast_solve(C, a, b, spec, reg, use_lower=True)
        rows.append({
            "fig": "D", "gamma": gamma_d,
            "origin_s": round(r0.wall_time, 3),
            "fast_no_lower_s": round(r_no.wall_time, 3),
            "fast_with_lower_s": round(r_yes.wall_time, 3),
            "gain_no_lower": round(r0.wall_time / max(r_no.wall_time, 1e-9), 2),
            "gain_with_lower": round(r0.wall_time / max(r_yes.wall_time, 1e-9), 2),
        })
        print(f"  gamma={gamma_d}: gain w/o lower={rows[-1]['gain_no_lower']}x, "
              f"with lower={rows[-1]['gain_with_lower']}x")
    if out:
        try:
            from benchmarks.bench_io import write_bench_json
        except ImportError:          # invoked as a script from benchmarks/
            from bench_io import write_bench_json

        write_bench_json(out, rows)
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--gamma", type=float, default=0.1)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--out", default="bench_gradcount.json")
    args = ap.parse_args()
    main(args.gamma, args.out, smoke=args.smoke)
