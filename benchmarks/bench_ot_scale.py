"""The paper's workload at production scale (§Perf pick: 'most
representative of the paper's technique').

Two measurements combined:

1. MEASURED screening effectiveness at the paper's largest published scale
   (|L| = 1280, g = 10, m = n = 12800): run the JAX screened solver and
   record verdict fractions per round + live tile fractions for the Pallas
   kernel's 8x128 tiles.

2. COMPILED production-scale distribution: lower one screened dual
   evaluation for m = n = 131072, L = 1024 on the 16x16 production mesh and
   extract the roofline terms (the solve is C-streaming-bound; collective
   traffic is O(m + n) per the design claim).

The beyond-paper speedup model: Pallas tile-skipping turns the HBM term
down by the measured live-tile fraction — that product is the §Perf number.
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

HW = dict(PEAK=197e12, HBM=819e9, ICI=50e9, CHIPS=256)


def measure_screening(L=1280, g=10, n=None, gamma=0.1, rho=0.8, rounds=12):
    import jax.numpy as jnp

    from repro.core import groups as G
    from repro.core.lbfgs import LbfgsOptions
    from repro.core.ot import squared_euclidean_cost
    from repro.core.regularizers import GroupSparseReg
    from repro.core.screening import tile_flags
    from repro.core.solver import SolveOptions, solve_dual
    from repro.core import screening as S
    from repro.data.pipeline import DomainPairConfig, make_domain_pair

    n = n or L * g
    Xs, ys, Xt, _ = make_domain_pair(
        DomainPairConfig(num_classes=L, samples_per_class=g, seed=0)
    )
    Xt = Xt[:n]
    C = squared_euclidean_cost(Xs, Xt).astype(np.float32)
    C /= C.max()
    spec = G.spec_from_labels(ys, pad_to=8)
    m = L * g
    C_pad = jnp.asarray(G.pad_cost_matrix(C, ys, spec))
    a = jnp.asarray(G.pad_marginal(np.full(m, 1 / m, np.float32), ys, spec))
    b = jnp.asarray(np.full(n, 1 / n, np.float32))
    reg = GroupSparseReg.from_rho(gamma, rho)

    opts = SolveOptions(grad_impl="screened",
                        lbfgs=LbfgsOptions(max_iters=rounds * 10, gtol=1e-6))
    # warmup solve: the first call pays jit tracing + compilation, which
    # would otherwise dominate the reported wall-clock; then time with
    # perf_counter (monotonic, not wall-of-day) and sync the async
    # dispatch before stopping the clock.
    import jax

    jax.block_until_ready(solve_dual(C_pad, a, b, spec, reg, opts).lbfgs_state.x)
    t0 = time.perf_counter()
    res = solve_dual(C_pad, a, b, spec, reg, opts)
    jax.block_until_ready(res.lbfgs_state.x)
    wall = time.perf_counter() - t0
    total = sum(res.stats.values())
    zero_frac = res.stats["zero"] / max(total, 1)

    # tile-level live fraction at the converged iterate, swept over tile
    # shapes: smaller tiles skip at finer granularity (lower live fraction)
    # but row tiles below 8 sublanes / col tiles below 128 lanes waste the
    # VPU -> the sweep quantifies the §Perf trade-off.
    sqrt_g = jnp.asarray(spec.sqrt_sizes())
    verd = S.verdicts(res.screen_state, res.alpha, res.beta, sqrt_g, reg.tau)
    sweep = {}
    for tl in (1, 2, 4, 8, 16):
        for tn in (128, 256, 512):
            if L % tl or n % tn:
                continue
            flags = tile_flags(verd, tl, tn)
            sweep[f"{tl}x{tn}"] = round(float(jnp.mean(flags.astype(jnp.float32))), 4)
    live = sweep.get("8x128", min(sweep.values()))
    return {
        "L": L, "g": g, "n": n, "gamma": gamma, "rho": rho,
        "iters": res.iterations, "rounds": res.rounds, "wall_s": round(wall, 1),
        "value": float(res.value),
        "entry_zero_frac": round(float(zero_frac), 4),
        "tile_live_frac": live,
        "tile_live_sweep": sweep,
    }


def lower_production(L=1024, g=128, n=131072):

    from repro.core.distributed import lower_dual_step
    from repro.core.dual import DualProblem
    from repro.core.regularizers import GroupSparseReg
    from repro.launch.mesh import make_production_mesh
    from repro.launch.dryrun import parse_collectives

    mesh = make_production_mesh(multi_pod=False)
    prob = DualProblem(L, g, n, GroupSparseReg(1.0, 1.0))
    lowered = lower_dual_step(mesh, prob)
    compiled = lowered.compile()
    cost = dict(compiled.cost_analysis() or {})
    coll = parse_collectives(compiled.as_text())
    flops = float(cost.get("flops", 0.0))
    bytes_ = float(cost.get("bytes accessed", 0.0))
    wire = coll["total_wire_bytes"]
    return {
        "m": L * g, "n": n, "devices": int(mesh.size),
        "flops_per_dev": flops, "bytes_per_dev": bytes_, "wire_per_dev": wire,
        "t_compute_s": flops / HW["PEAK"],
        "t_memory_s": bytes_ / HW["HBM"],
        "t_collective_s": wire / HW["ICI"],
    }


def main(out: str | None = None, quick: bool = False):
    meas = measure_screening(L=320 if quick else 1280)
    print("measured screening:", json.dumps(meas, indent=2))
    prod = lower_production()
    print("production-scale dual step:", json.dumps(prod, indent=2))
    dominant = max(
        ("compute", prod["t_compute_s"]), ("memory", prod["t_memory_s"]),
        ("collective", prod["t_collective_s"]), key=lambda kv: kv[1],
    )[0]
    t_base = max(prod["t_memory_s"], prod["t_compute_s"], prod["t_collective_s"])
    t_screened = max(
        prod["t_memory_s"] * meas["tile_live_frac"],
        prod["t_compute_s"] * meas["tile_live_frac"],
        prod["t_collective_s"],
    )
    summary = {
        "dominant": dominant,
        "t_eval_paper_faithful_s": t_base,
        "t_eval_screened_pallas_s": t_screened,
        "modeled_speedup": round(t_base / max(t_screened, 1e-12), 2),
        "measured": meas, "production": prod,
    }
    print("summary:", json.dumps(
        {k: v for k, v in summary.items() if not isinstance(v, dict)}, indent=2))
    if out:
        with open(out, "w") as f:
            json.dump(summary, f, indent=2)
    return summary


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="bench_ot_scale.json")
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    main(args.out, args.quick)
