"""Geometry benchmark: dense vs materialization-free cost operands.

Two modeled byte counters per row, both pure functions of the shape and
the screening flags (docs/geometry.md):

* ``operand_bytes`` — what HBM must HOLD for the solve-time cost operand.
  Dense is ``m_pad * n * 4`` (the (m, n) product); factorized is
  ``(m_pad + n)(d + 1) * 4`` (linear in m + n), via the geometry objects'
  own :meth:`~repro.ot.geometry.CostGeometry.hbm_bytes`.
* ``traffic_bytes`` — what one screened gradient evaluation STREAMS.
  Both routes issue one grid step per surviving tile; the dense kernel
  DMAs a ``(TILE_L, g, TILE_N)`` C tile per step while the factorized
  kernel DMAs the ``(TILE_L * g, d + 1)`` x-block and ``(TILE_N, d + 1)``
  y-block — per-step bytes independent of n, so factorized traffic scales
  with LIVE TILES, not problem width.

Grid steps are read back from the compact factorized kernel's in-kernel
step counter (interpret mode), never assumed.  Every recorded counter is
deterministic (seeded flags + byte models — no wall-clock), so the CI
gate (``benchmarks/check_regression.py``) holds them to EXACT equality
against the committed ``BENCH_geometry.json``.
"""
from __future__ import annotations

import argparse

import numpy as np
import jax.numpy as jnp

from repro.core import groups as G
from repro.core.dual import DualProblem
from repro.core.regularizers import GroupSparseReg
from repro.data.pipeline import DomainPairConfig, make_domain_pair
from repro.kernels import ops as kops
from repro.kernels.gradpsi import build_tile_schedule, gradpsi_fact_pallas_compact
from repro.ot.geometry import SquaredL2Geometry

FULL = dict(L=32, g=16, n_sweep=(512, 1024, 2048),
            densities=(1.0, 0.25, 0.05))
SMOKE = dict(L=4, g=8, n_sweep=(128, 256), densities=(1.0, 0.25))


def _geometry_row(geom, prob, spec, n, density):
    """One BENCH row: steps + modeled operand/traffic bytes at ``density``."""
    fc = kops.FactorizedCost(*(jnp.asarray(v) for v in geom.operands()))
    fp = kops.prepare_factorized_problem(fc, prob)
    rng = np.random.default_rng(1000 * n + int(round(100 * density)))
    alpha = jnp.asarray(rng.normal(size=spec.m_pad).astype(np.float32) * 0.1)
    beta = jnp.asarray(rng.normal(size=n).astype(np.float32) * 0.1)
    alphap, betap = kops.pad_tile_inputs(alpha, beta, fp)

    flags = jnp.asarray((rng.random(fp.grid) < density).astype(np.int32))
    live = int(jnp.sum(flags != 0))
    sched, nact = build_tile_schedule(flags)
    *_, steps = gradpsi_fact_pallas_compact(
        alphap, betap, fp.x, fp.x_sq, fp.y, fp.y_sq, sched, nact,
        num_groups=fp.L_pad, group_size=fp.g,
        tau=prob.reg.tau, gamma=prob.reg.gamma,
        tile_l=fp.tile_l, tile_n=fp.tile_n, interpret=True,
    )
    steps = int(steps)

    d = geom.dim
    c_tile_bytes = fp.tile_l * fp.g * fp.tile_n * 4
    fact_tile_bytes = (fp.tile_l * fp.g * (d + 1) + fp.tile_n * (d + 1)) * 4
    return {
        "n": n,
        "m_pad": spec.m_pad,
        "d": d,
        "L": prob.num_groups,
        "g": prob.group_size,
        "tile_l": fp.tile_l,
        "tile_n": fp.tile_n,
        "density": density,
        "live_tiles": live,
        "total_tiles": fp.num_tiles,
        "grid_steps": steps,
        "operand_bytes": {
            "dense": spec.m_pad * n * 4,
            "factorized": geom.hbm_bytes(),
        },
        "traffic_bytes": {
            "dense": steps * c_tile_bytes,
            "factorized": steps * fact_tile_bytes,
        },
    }


def main(smoke: bool = False, out: str | None = "BENCH_geometry.json",
         L: int | None = None, g: int | None = None,
         n_sweep=None, densities=None):
    base = SMOKE if smoke else FULL
    L = base["L"] if L is None else L
    g = base["g"] if g is None else g
    n_sweep = base["n_sweep"] if n_sweep is None else n_sweep
    densities = base["densities"] if densities is None else densities

    Xs, ys, Xt, _ = make_domain_pair(
        DomainPairConfig(num_classes=L, samples_per_class=g, dim=8, seed=0)
    )
    spec = G.spec_from_labels(ys, pad_to=8)
    reg = GroupSparseReg.from_rho(1.0, 0.8)

    rows = []
    for n in n_sweep:
        Y = Xt[:n] if n <= len(Xt) else np.tile(Xt, (n // len(Xt) + 1, 1))[:n]
        geom = SquaredL2Geometry.from_samples(Xs, ys, Y, spec)
        prob = DualProblem(spec.num_groups, spec.group_size, n, reg)
        for dens in densities:
            rows.append(_geometry_row(geom, prob, spec, n, dens))

    for r in rows:
        ob, tb = r["operand_bytes"], r["traffic_bytes"]
        print(f"n={r['n']} density={r['density']} "
              f"live={r['live_tiles']}/{r['total_tiles']} "
              f"steps={r['grid_steps']} "
              f"operand_bytes dense={ob['dense']} fact={ob['factorized']} "
              f"traffic_bytes dense={tb['dense']} fact={tb['factorized']}")
    if out:
        try:
            from benchmarks.bench_io import write_bench_json
        except ImportError:          # invoked as a script from benchmarks/
            from bench_io import write_bench_json

        write_bench_json(out, rows)
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--out", default="BENCH_geometry.json")
    args = ap.parse_args()
    main(smoke=args.smoke, out=args.out or None)
