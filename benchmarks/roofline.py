"""Roofline analysis from the dry-run artifacts (deliverable g).

Terms per (arch x shape), single-pod 16x16 mesh, per the assignment:

  compute term    = HLO_FLOPs  / (chips * 197e12  bf16 FLOP/s)
  memory term     = HLO_bytes  / (chips * 819e9   B/s HBM)
  collective term = wire_bytes / (chips * 50e9    B/s ICI link)

cost_analysis() numbers are per-DEVICE (verified against analytic counts),
so terms divide by per-chip peaks directly.

XLA's cost analysis counts a while-loop body ONCE regardless of trip count;
dryrun --probe lowers every cell at 1 and 2 scan steps, and this module
linearly extrapolates:  body = p2 - p1, outside = 2*p1 - p2,
full = outside + body * trips.  Inner *sequence* scans (mamba / sLSTM /
mLSTM-chunk) are additionally corrected analytically (formulas below) —
their bodies are also counted once per outer body.

MODEL_FLOPS = 6*N(active)*D for training, 2*N(active)*D for inference.
"""
from __future__ import annotations

import argparse
import json
import math
from pathlib import Path

from repro.configs import SHAPES_BY_NAME, get_config, list_archs

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9
CHIPS = 256  # single-pod roofline per assignment


def scan_trips(cfg) -> int:
    if cfg.family == "hybrid":
        return cfg.num_layers // cfg.attn_period
    if cfg.family == "ssm":
        return cfg.num_layers // cfg.ssm.slstm_every
    if cfg.family == "vlm":
        return cfg.num_layers // cfg.cross_attn_period
    return cfg.num_layers


def active_params(cfg) -> float:
    """Active (per-token) parameter count; analytic per family."""
    d, V = cfg.d_model, cfg.vocab_size
    hd = cfg.resolved_head_dim
    H, K = cfg.num_heads, cfg.num_kv_heads
    attn = d * hd * (H + 2 * K) + H * hd * d
    emb = V * d * (1 if cfg.tie_embeddings else 2)

    def moe_active(m):
        ff = m.expert_d_ff or cfg.d_ff
        routed = 3 * d * ff * m.top_k
        shared = 3 * d * (m.shared_d_ff or 0) + (d if m.num_shared_experts else 0)
        return routed + shared + d * m.num_experts  # + router

    if cfg.family in ("dense",):
        if cfg.mla is not None:
            m = cfg.mla
            qk = m.qk_nope_head_dim + m.qk_rope_head_dim
            attn = (d * m.q_lora_rank + m.q_lora_rank * H * qk
                    + d * (m.kv_lora_rank + m.qk_rope_head_dim)
                    + m.kv_lora_rank * H * (m.qk_nope_head_dim + m.v_head_dim)
                    + H * m.v_head_dim * d)
        mlp = 3 * d * cfg.d_ff
        return emb + cfg.num_layers * (attn + mlp)
    if cfg.family == "moe":
        return emb + cfg.num_layers * (attn + moe_active(cfg.moe))
    if cfg.family == "hybrid":
        period = cfg.attn_period
        n_per = cfg.num_layers // period
        di = cfg.ssm.expand * d
        dtr = cfg.ssm.dt_rank or math.ceil(d / 16)
        mamba = (2 * d * di + di * cfg.ssm.d_conv
                 + di * (dtr + 2 * cfg.ssm.d_state) + dtr * di + di * d)
        moe_l = moe_active(cfg.moe)
        mlp_l = 3 * d * cfg.d_ff
        per_period = attn + (period - 1) * mamba + (period // 2) * (moe_l + mlp_l)
        return emb + n_per * per_period
    if cfg.family == "ssm":
        period = cfg.ssm.slstm_every
        n_per = cfg.num_layers // period
        di = int(cfg.ssm.proj_factor * d)
        dh = di // H
        mlstm = 2 * d * di + 4 * di + 3 * H * dh * dh + 2 * di * H + di * d
        dhs = d // H
        f = -(-4 * d // 3 // 8) * 8
        slstm = 4 * (d * d + H * dhs * dhs) + 3 * d * f
        return emb + n_per * (slstm + (period - 1) * mlstm)
    if cfg.family == "vlm":
        period = cfg.cross_attn_period
        n_per = cfg.num_layers // period
        mlp = 3 * d * cfg.d_ff
        per = (period - 1) * (attn + mlp) + attn + mlp
        return emb + n_per * per
    if cfg.family == "encdec":
        mlp = 2 * d * cfg.d_ff
        return emb + cfg.num_layers * (2 * attn + mlp) + cfg.encoder_layers * (attn + mlp)
    raise ValueError(cfg.family)


def inner_scan_flops(cfg, shape) -> float:
    """Analytic per-DEVICE flops of inner sequence scans (counted once by
    XLA).  Train: x4 (fwd + remat recompute + ~2x bwd); decode: single step
    already fully counted (no inner loop) -> 0."""
    if shape.kind == "decode":
        return 0.0
    S = shape.seq_len
    Bl = shape.global_batch / CHIPS  # batch shards over data axes
    mult = 4.0 if shape.kind == "train" else 1.0
    d = cfg.d_model
    total = 0.0
    if cfg.family == "hybrid":
        di = cfg.ssm.expand * d
        st = cfg.ssm.d_state
        per_layer = 9.0 * S * Bl * di * st / 16.0  # di shards over model=16
        n_mamba = cfg.num_layers * (cfg.attn_period - 1) // cfg.attn_period
        total += per_layer * n_mamba
    if cfg.family == "ssm":
        H = cfg.num_heads
        di = int(cfg.ssm.proj_factor * d)
        dh = di // H
        c = cfg.ssm.mlstm_chunk
        # mLSTM chunk body ~ B*H*(6 c^2 dh + 6 c dh^2), times S/c chunks
        n_mlstm = cfg.num_layers * (cfg.ssm.slstm_every - 1) // cfg.ssm.slstm_every
        total += n_mlstm * (S / c) * Bl * H * (6 * c * c * dh + 6 * c * dh * dh)
        # sLSTM per step ~ 8*B*d*dh_s
        dhs = d // H
        n_slstm = cfg.num_layers // cfg.ssm.slstm_every
        total += n_slstm * S * Bl * 8 * d * dhs
    return total * mult


def load(art_dir: Path, arch: str, shape: str, tag: str = "") -> dict | None:
    p = art_dir / f"{arch}__{shape}__pod16x16{tag}.json"
    if not p.exists():
        return None
    return json.loads(p.read_text())


def corrected_cell(art_dir: Path, arch: str, shape_name: str) -> dict | None:
    full = load(art_dir, arch, shape_name)
    if full is None or full["status"] != "ok":
        return full
    p1 = load(art_dir, arch, shape_name, "__probe1")
    p2 = load(art_dir, arch, shape_name, "__probe2")
    cfg = get_config(arch)
    shape = SHAPES_BY_NAME[shape_name]
    trips = scan_trips(cfg)

    def extract(rec):
        ca = rec["cost_analysis"]
        return {
            "flops": ca.get("flops", 0.0),
            "bytes": ca.get("bytes accessed", 0.0),
            "wire": rec["collectives"]["total_wire_bytes"],
        }

    raw = extract(full)
    if p1 and p2 and p1["status"] == "ok" and p2["status"] == "ok":
        m1, m2 = extract(p1), extract(p2)
        corr = {
            k: max((2 * m1[k] - m2[k]) + (m2[k] - m1[k]) * trips, raw[k])
            for k in raw
        }
        method = "probe-extrapolated"
    else:
        corr, method = dict(raw), "raw (probes missing)"
    corr["flops"] += inner_scan_flops(cfg, shape)

    D = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    n_act = active_params(cfg)
    mf = (6 if shape.kind == "train" else 2) * n_act * D

    # Fused-TPU memory estimate: XLA-CPU 'bytes accessed' is an UNFUSED
    # upper bound (the CPU backend materializes nearly every intermediate).
    # A deployed TPU step's HBM traffic ~= read/write its resident arguments
    # once (params + opt states + caches, already per-device in
    # memory_analysis) + activation streaming: ~6 major ops per layer
    # touching (tokens x d_model) bf16 in+out, x1.5 for remat recompute
    # => 24 B per token-layer-d_model unit.  Attention assumed flash-style
    # (no S^2 materialization) — that is how the Pallas/TPU deployment runs.
    A = full["memory_analysis"].get("argument_size_in_bytes", 0)
    data_shards = 16
    tokens_local = (
        shape.global_batch * shape.seq_len / data_shards
        if shape.kind != "decode"
        else max(shape.global_batch / data_shards, 1)
    )
    act_bytes = tokens_local * cfg.d_model * cfg.num_layers * 24
    bytes_fused = 2 * A + act_bytes

    t_c = corr["flops"] / PEAK_FLOPS
    t_m_xla = corr["bytes"] / HBM_BW
    t_m = bytes_fused / HBM_BW
    t_x = corr["wire"] / ICI_BW
    dom = max(("compute", t_c), ("memory", t_m), ("collective", t_x),
              key=lambda kv: kv[1])[0]
    bound = max(t_c, t_m, t_x)
    return {
        "arch": arch, "shape": shape_name, "status": "ok", "method": method,
        "flops_per_chip": corr["flops"], "bytes_per_chip_xla": corr["bytes"],
        "bytes_per_chip_fused": bytes_fused,
        "wire_per_chip": corr["wire"],
        "t_compute_s": t_c, "t_memory_s": t_m, "t_memory_xla_s": t_m_xla,
        "t_collective_s": t_x,
        "dominant": dom,
        "model_flops_global": mf,
        "useful_ratio": mf / max(corr["flops"] * CHIPS, 1.0),
        "mfu_upper_bound": (mf / CHIPS / PEAK_FLOPS) / max(bound, 1e-12),
        "memory_analysis": full["memory_analysis"],
    }


NOTES = {
    ("compute", "train"): "compute-bound: raise MFU via fused attention / less remat recompute",
    ("compute", "prefill"): "compute-bound: batch-level pipelining of layers would overlap the tail",
    ("memory", "train"): "HBM-bound: shrink activation traffic (fusion, bf16 intermediates, less remat)",
    ("memory", "prefill"): "HBM-bound: KV-write + activation traffic dominates; fuse projections",
    ("memory", "decode"): "HBM-bound (expected): decode streams params+KV; raise batch or quantize KV",
    ("collective", "train"): "ICI-bound: FSDP all-gathers dominate; switch to ZeRO-1/params-stay-sharded or overlap",
    ("collective", "prefill"): "ICI-bound: TP all-reduces; overlap with compute via async collectives",
    ("collective", "decode"): "ICI-bound: TP all-reduces per token; shrink TP degree for decode",
}


def build_table(art_dir: Path):
    rows = []
    for arch in list_archs():
        for shape in ("train_4k", "prefill_32k", "decode_32k", "long_500k"):
            rec = corrected_cell(art_dir, arch, shape)
            if rec is None:
                continue
            rows.append(rec)
    return rows


def to_markdown(rows) -> str:
    out = [
        "| arch | shape | t_compute | t_memory(fused) | t_mem(xla-ub) | "
        "t_collective | dominant | MODEL/HLO | MFU bound | note |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        if r.get("status") == "skipped":
            out.append(
                f"| {r['arch']} | {r['shape']} | — | — | — | — | skipped | — | — | {r['reason']} |"
            )
            continue
        note = NOTES.get((r["dominant"], SHAPES_BY_NAME[r["shape"]].kind), "")
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute_s']:.3e} | "
            f"{r['t_memory_s']:.3e} | {r['t_memory_xla_s']:.3e} | "
            f"{r['t_collective_s']:.3e} | "
            f"{r['dominant']} | {r['useful_ratio']:.2f} | "
            f"{r['mfu_upper_bound']:.2f} | {note} |"
        )
    return "\n".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--artifacts", default="dryrun_artifacts")
    ap.add_argument("--json-out", default="roofline_table.json")
    args = ap.parse_args()
    rows = build_table(Path(args.artifacts))
    Path(args.json_out).write_text(json.dumps(rows, indent=2, default=str))
    print(to_markdown(rows))


if __name__ == "__main__":
    main()
