"""Distributed group-sparse OT on the production mesh.

The smooth relaxed dual separates over target columns j, so the natural
partition on a ("pod", "data", "model") mesh is:

  C     (m_pad, n): rows (groups) over "model", columns over ("pod","data")
  a     (m_pad,):   over "model"         (alpha likewise)
  b     (n,):       over ("pod","data")  (beta likewise)
  Z/bounds (L, n):  L over "model", n over ("pod","data")

Groups are aligned to row shards (the padded group count is a multiple of the
"model" shard count), so group norms never cross shards.  Per L-BFGS step the
only collectives are:

  * psum of grad_alpha partial column-sums over ("pod","data")  (m floats),
  * psum of grad_beta partial row-sums over "model"             (n floats),
  * a handful of scalar psums (objective, L-BFGS dot products).

Cross-pod traffic is therefore O(m + n) per step vs the O(m n / devices)
local gradient work — the solve is overwhelmingly memory-bound (see
EXPERIMENTS.md §Roofline).

Implementation: the solver in repro.core.solver is pure jnp, so we drive it
through GSPMD — jit with NamedShardings on the inputs; XLA inserts exactly
the collectives above (asserted by tests/test_distributed.py on a host-device
mesh, and inspectable via .lower().as_text()).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.dual import DualProblem
from repro.core.groups import GroupSpec, PAD_COST
from repro.core.regularizers import Regularizer
from repro.core.solver import OTResult, SolveOptions, _solve_jit, _split


#: Mesh-axis name of the problem (batch) dimension used by the sharded
#: batched solver (``repro.core.sharded``) and the multi-device serving
#: engine.  One name, defined once, so mesh construction, partition rules,
#: and shard_map specs always agree.
BATCH_AXIS = "batch"


def make_batch_mesh(num_devices: int | None = None) -> Mesh:
    """Build the 1-D problem-axis mesh for sharded batched solving.

    Parameters
    ----------
    num_devices : int, optional
        How many local devices to span.  Defaults to every local device
        (``jax.local_device_count()``).

    Returns
    -------
    jax.sharding.Mesh
        A 1-D mesh whose single axis is named :data:`BATCH_AXIS`.  The
        batched solver shards the problem axis ``B`` over it; everything
        else in a solve is per-problem state and needs no other axis.
    """
    from repro.utils.compat import make_mesh

    if num_devices is None:
        num_devices = jax.local_device_count()
    return make_mesh((num_devices,), (BATCH_AXIS,))


def _data_axes(mesh: Mesh):
    """All mesh axes that shard the column dimension n."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def pad_for_mesh(spec: GroupSpec, mesh: Mesh) -> GroupSpec:
    """Pad the group COUNT so L divides the 'model' axis size.

    Padding groups are empty (size 0): their rows carry PAD_COST and zero
    mass, so they are invisible to the optimizer (see groups.py).
    """
    if "model" not in mesh.axis_names:
        return spec
    t = mesh.shape["model"]
    L_pad = -(-spec.num_groups // t) * t
    if L_pad == spec.num_groups:
        return spec
    sizes = tuple(spec.sizes) + (0,) * (L_pad - spec.num_groups)
    return dataclasses.replace(
        spec, num_groups=L_pad, sizes=sizes
    )


def pad_arrays_for_mesh(C, a, spec: GroupSpec, spec_padded: GroupSpec):
    """Extend C/a with the empty padding groups from :func:`pad_for_mesh`."""
    import numpy as np

    extra = spec_padded.m_pad - spec.m_pad
    if extra == 0:
        return C, a
    C2 = np.concatenate(
        [np.asarray(C), np.full((extra, C.shape[1]), PAD_COST, C.dtype)], axis=0
    )
    a2 = np.concatenate([np.asarray(a), np.zeros((extra,), a.dtype)])
    return C2, a2


def shardings(mesh: Mesh, prob: DualProblem):
    """NamedShardings for (C, a, b, row_mask, sqrt_g) + the result vector."""
    daxes = _data_axes(mesh)
    model = "model" if "model" in mesh.axis_names else None
    s = lambda *spec: NamedSharding(mesh, P(*spec))
    return {
        "C": s(model, daxes),
        "a": s(model),
        "b": s(daxes),
        "row_mask": s(model),
        "sqrt_g": s(model),
    }


def solve_dual_distributed(
    C,
    a,
    b,
    spec: GroupSpec,
    reg: Regularizer,
    mesh: Mesh,
    opts: SolveOptions = SolveOptions(),
) -> OTResult:
    """GSPMD-sharded variant of :func:`repro.core.solver.solve_dual`."""
    import numpy as np

    spec_p = pad_for_mesh(spec, mesh)
    C, a = pad_arrays_for_mesh(C, a, spec, spec_p)

    prob = DualProblem(
        num_groups=spec_p.num_groups,
        group_size=spec_p.group_size,
        n=int(C.shape[1]),
        reg=reg,
    )
    sh = shardings(mesh, prob)
    row_mask = np.asarray(spec_p.row_mask().reshape(-1))
    sqrt_g = np.asarray(spec_p.sqrt_sizes(), np.float32)

    Cd = jax.device_put(np.asarray(C), sh["C"])
    ad = jax.device_put(np.asarray(a), sh["a"])
    bd = jax.device_put(np.asarray(b), sh["b"])
    md = jax.device_put(row_mask, sh["row_mask"])
    gd = jax.device_put(sqrt_g, sh["sqrt_g"])

    with mesh:
        lb, scr, rounds, stats = _solve_jit(Cd, ad, bd, md, gd, prob, opts)
    alpha, beta = _split(lb.x, prob.m_pad)
    stats_dict = {
        "zero": int(stats[0]),
        "check": int(stats[1]),
        "active": int(stats[2]),
    }
    return OTResult(alpha, beta, -lb.f, lb, scr, int(rounds), stats_dict)


def lower_dual_step(
    mesh: Mesh,
    prob: DualProblem,
    opts: Optional[SolveOptions] = None,
    dtype=jnp.float32,
):
    """Lower (not run) one sharded value_and_grad for dry-run/roofline use.

    Returns the jax.stages.Lowered for a single screened dual gradient step
    on ShapeDtypeStruct inputs — no allocation; used by launch/dryrun.py to
    extract cost analysis and the collective schedule at production scale.
    """
    from repro.core import screening
    from repro.core.solver import make_value_and_grad

    daxes = _data_axes(mesh)
    model = "model" if "model" in mesh.axis_names else None
    m_pad, n, L = prob.m_pad, prob.n, prob.num_groups
    sds = jax.ShapeDtypeStruct

    def step(x, C, a, b, sqrt_g, scr):
        vag = make_value_and_grad(C, a, b, prob, sqrt_g, "screened", scr)
        return vag(x)

    s = lambda *spec: NamedSharding(mesh, P(*spec))
    scr_sh = screening.ScreenState(
        alpha_snap=sds((m_pad,), dtype, sharding=s(model)),
        beta_snap=sds((n,), dtype, sharding=s(daxes)),
        z_snap=sds((L, n), dtype, sharding=s(model, daxes)),
        k_snap=sds((L, n), dtype, sharding=s(model, daxes)),
        o_snap=sds((L, n), dtype, sharding=s(model, daxes)),
        active=sds((L, n), bool, sharding=s(model, daxes)),
    )
    args = (
        sds((m_pad + n,), dtype, sharding=s(None)),
        sds((m_pad, n), dtype, sharding=s(model, daxes)),
        sds((m_pad,), dtype, sharding=s(model)),
        sds((n,), dtype, sharding=s(daxes)),
        sds((L,), dtype, sharding=s(model)),
        scr_sh,
    )
    with mesh:
        return jax.jit(step).lower(*args)
