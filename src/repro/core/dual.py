"""Smooth relaxed dual of group-sparse regularized OT (paper Eq. 4).

    max_{alpha, beta}  alpha^T a + beta^T b - sum_j psi(alpha + beta_j 1 - c_j)

All computations use the uniform padded group layout from
:mod:`repro.core.groups`: the cost matrix is (m_pad, n) with m_pad = L * g,
padded rows carrying +PAD_COST so they never contribute.

Every function in this module is *batch-polymorphic*: inputs may carry any
leading batch dims (``alpha (..., m_pad)``, ``beta (..., n)``,
``C (..., m_pad, n)``), and all reductions run over trailing axes.  The
dual is separable across problems, so a batch axis is nothing more than a
leading dim — and because a solo call and a batched call execute the same
per-problem reduction shapes, their results match bitwise (the contract
behind ``solve_batch``; see tests/test_solve_batch.py).

Three gradient implementations share this module's plumbing:

  * ``dense``      -- full O(m n) jnp computation (the "origin" method).
  * ``screened``   -- paper Algorithms 1/2 expressed with masks: entries whose
                      upper bound certifies zero are *not* trusted from the
                      dense path but set to exact 0; returns skip statistics.
                      (On XLA-CPU this is the accounting reference; actual
                      work-skipping happens in the Pallas kernel and the numpy
                      CPU baseline.)
  * ``pallas``     -- kernels/gradpsi.py, block-masked (wired via ops.py).

The value/gradient contract is *exact* under screening (paper Thm. 2): masks
only zero entries that the closed form would also produce as zero.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp

from repro.core.regularizers import Regularizer


@dataclasses.dataclass(frozen=True)
class DualProblem:
    """Static problem description (shapes only; arrays passed separately).

    num_groups: L
    group_size: g (padded, uniform)
    n:          number of target samples
    reg:        regularizer (any :class:`repro.core.regularizers.Regularizer`;
                hashable, so the problem stays a static jit argument and
                compiled programs specialize per regularizer)
    """

    num_groups: int
    group_size: int
    n: int
    reg: Regularizer

    def tau_vec(self) -> jnp.ndarray:
        """Per-group screening thresholds ``tau_l`` as an ``(L,)`` array.

        The single quantity screening and the kernels need from the
        regularizer at run time (everything else folds into the compiled
        program through the static ``reg``).
        """
        return jnp.asarray(self.reg.tau_vec(self.num_groups))

    @property
    def m_pad(self) -> int:
        return self.num_groups * self.group_size

    def tile_padded_shape(self, tile_l: int, tile_n: int) -> Tuple[int, int]:
        """(L_pad, n_pad): group/column counts rounded up to tile multiples.

        The single definition of the kernel-facing problem geometry — the
        padded cost matrix, the screening snapshots, and the tile-flag grid
        all derive their shapes from it (see kernels/ops.py).
        """
        L_pad = -(-self.num_groups // tile_l) * tile_l
        n_pad = -(-self.n // tile_n) * tile_n
        return L_pad, n_pad


def _group_norms_relu(F: jnp.ndarray, L: int, g: int) -> jnp.ndarray:
    """Z[l, j] = ||[F]_+ rows of group l, column j||_2 for F of (..., L*g, n)."""
    Fp = jnp.maximum(F, 0.0)
    Fg = Fp.reshape(F.shape[:-2] + (L, g, F.shape[-1]))
    # tiny clamp keeps sqrt' finite at 0 so the AD test-oracle stays NaN-free
    return jnp.sqrt(
        jnp.maximum(jnp.sum(Fg * Fg, axis=-2), jnp.finfo(F.dtype).tiny)
    )


def _outer_f(alpha: jnp.ndarray, beta: jnp.ndarray, C: jnp.ndarray):
    """f = alpha + beta_j - c with leading batch dims: (..., m_pad, n)."""
    return alpha[..., :, None] + beta[..., None, :] - C


def dual_value_and_grad(
    alpha: jnp.ndarray,
    beta: jnp.ndarray,
    C: jnp.ndarray,
    a: jnp.ndarray,
    b: jnp.ndarray,
    prob: DualProblem,
    zero_mask: Optional[jnp.ndarray] = None,
) -> Tuple[jnp.ndarray, Tuple[jnp.ndarray, jnp.ndarray]]:
    """Dense closed-form value and gradient of the (maximization) dual.

    All inputs may carry leading batch dims (alpha (..., m_pad), C
    (..., m_pad, n), ...); value is then (...,) and grads are batched.

    zero_mask: optional (..., L, n) bool, True where the gradient block is
      *known* to be zero (screened).  Entries are forced to exact zero — by
      Lemma 2 this does not change the result; it exists so the screened
      path and the dense path share one code path in tests.

    Returns (value, (grad_alpha, grad_beta)) for the MAXIMIZATION problem.
    """
    L, g = prob.num_groups, prob.group_size
    F = _outer_f(alpha, beta, C)                    # (..., m_pad, n)
    Z = _group_norms_relu(F, L, g)                  # (..., L, n)
    s = prob.reg.scale_from_z(Z)                    # (..., L, n)
    if zero_mask is not None:
        s = jnp.where(zero_mask, 0.0, s)
    # T = grad psi per column = s * [F]_+ / gamma, shape (..., m_pad, n)
    T = (
        jnp.repeat(s, g, axis=-2) * jnp.maximum(F, 0.0) / prob.reg.gamma
    )
    psi = prob.reg.psi_from_z(Z)
    if zero_mask is not None:
        psi = jnp.where(zero_mask, 0.0, psi)
    value = (
        jnp.sum(alpha * a, axis=-1)
        + jnp.sum(beta * b, axis=-1)
        - jnp.sum(psi, axis=(-2, -1))
    )
    grad_alpha = a - jnp.sum(T, axis=-1)
    grad_beta = b - jnp.sum(T, axis=-2)
    return value, (grad_alpha, grad_beta)


def plan_from_duals(
    alpha: jnp.ndarray,
    beta: jnp.ndarray,
    C: jnp.ndarray,
    prob: DualProblem,
) -> jnp.ndarray:
    """Recover the primal transportation plan T* (paper: t_j* = grad psi(f_j)).

    Batch-polymorphic: (..., m_pad), (..., n), (..., m_pad, n) inputs give a
    (..., m_pad, n) plan.
    """
    L, g = prob.num_groups, prob.group_size
    F = _outer_f(alpha, beta, C)
    Z = _group_norms_relu(F, L, g)
    s = prob.reg.scale_from_z(Z)
    return jnp.repeat(s, g, axis=-2) * jnp.maximum(F, 0.0) / prob.reg.gamma


def group_norm_matrix(
    alpha: jnp.ndarray, beta: jnp.ndarray, C: jnp.ndarray, prob: DualProblem
) -> jnp.ndarray:
    """Exact Z (..., L, n) — used for snapshots z~ in Definition 1."""
    F = _outer_f(alpha, beta, C)
    return _group_norms_relu(F, prob.num_groups, prob.group_size)


def snapshot_norms(
    alpha: jnp.ndarray,
    beta: jnp.ndarray,
    C: jnp.ndarray,
    prob: DualProblem,
    row_mask: jnp.ndarray,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Snapshot quantities of Definitions 1-2:  (z~, k~, o~), each (..., L, n).

      z~[l,j] = ||[f_[l]]_+||_2      (relu -> padding rows vanish naturally)
      k~[l,j] = ||f_[l]||_2          over REAL rows only (row_mask)
      o~[l,j] = ||[f_[l]]_-||_2      over REAL rows only

    ``row_mask`` is (..., m_pad) (broadcast over any leading batch dims, or
    batched per problem — the serving engine packs problems with different
    true group sizes into one batch).

    Masking k~/o~ to real rows keeps the bounds tight: padded rows carry
    f ~ -PAD_COST which would otherwise blow up k~ and o~ and (through fp32
    cancellation) destroy the lower bound.  Restricted to real rows the
    problem is exactly the unpadded one (padding has a == 0, alpha == 0,
    grad == 0 throughout; see groups.py docstring).
    """
    L, g = prob.num_groups, prob.group_size
    F = _outer_f(alpha, beta, C)
    Fg = F.reshape(F.shape[:-2] + (L, g, F.shape[-1]))
    mask = row_mask.reshape(row_mask.shape[:-1] + (L, g, 1))
    Fm = jnp.where(mask, Fg, 0.0)
    z = jnp.sqrt(jnp.sum(jnp.square(jnp.maximum(Fm, 0.0)), axis=-2))
    k = jnp.sqrt(jnp.sum(jnp.square(Fm), axis=-2))
    o = jnp.sqrt(jnp.sum(jnp.square(jnp.minimum(Fm, 0.0)), axis=-2))
    return z, k, o


def primal_objective(
    T: jnp.ndarray, C: jnp.ndarray, prob: DualProblem, row_mask: jnp.ndarray
) -> jnp.ndarray:
    """<T, C>_F + sum_j Psi(t_j) on real rows (duality-gap checks)."""
    Tm = jnp.where(row_mask[:, None], T, 0.0)
    cost = jnp.sum(Tm * jnp.where(row_mask[:, None], C, 0.0))
    return cost + prob.reg.primal(Tm, prob.num_groups)
