"""Multi-device sharded batched OT solving: the problem axis over a mesh.

The batched solver (:mod:`repro.core.solver`) advances ``B`` independent
problems in one jitted program; nothing in a round couples batch members
(the dual is separable, screening state is per problem, convergence is
masked per problem).  That makes the batch axis *embarrassingly shardable*:
this module runs ``solve_batch`` / the round-step API under ``shard_map``
with ``B`` split over a 1-D device mesh, and each device executes the
ordinary batched solver on its local slice —

  * per-shard screening state: snapshots and the active set N live with
    their problems, no replication,
  * per-shard compact tile schedules: the dynamic-grid compact kernel
    already runs an independent (b, l, j) list per launch, so each shard
    builds its own list over its local problems and its grid steps scale
    with the shard's surviving tiles,
  * per-problem convergence with masked freezing: a shard whose problems
    all finish simply idles through the masked ops; no cross-device sync
    happens inside a round.

The only cross-device data movement is at round boundaries, when a caller
(the serving engine) reads the ``(B,)`` ``converged`` / ``failed`` flags —
a gather of a few bytes per device, handled by the host read of the
sharded output.

Bitwise contract: a problem solved sharded is bitwise-identical to the
same problem in an unsharded ``solve_batch`` (and hence to its solo
``solve_dual``).  Per-problem math reduces only over trailing axes, and
the two Pallas grid modes produce bitwise-equal outputs, so even the
``impl='auto'`` density switch — which sees shard-local live counts
instead of batch-global ones — cannot break parity.  The same holds for
the fused backend's runtime switch (both of its branches are bitwise
equal too).  Asserted for all ``grad_impl`` backends by
tests/test_sharded.py on 4 forced host devices.

Mesh construction is wired through :func:`repro.core.distributed.make_batch_mesh`
(the 1-D :data:`~repro.core.distributed.BATCH_AXIS` mesh) and
:func:`repro.sharding.partition.batch_solve_rules` (the ``problems``
logical axis), so no caller hand-rolls device lists or axis names.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec

from repro.core import screening
from repro.core import solver as slv
from repro.core.distributed import make_batch_mesh
from repro.core.dual import DualProblem
from repro.core.groups import PAD_COST, GroupSpec
from repro.core.lbfgs import state_pspecs as lbfgs_pspecs
from repro.core.regularizers import Regularizer
from repro.sharding.partition import batch_solve_rules
from repro.utils.compat import shard_map


def problem_pspec(mesh: Mesh) -> PartitionSpec:
    """PartitionSpec sharding a leading problem axis over ``mesh``.

    Derived through the :func:`~repro.sharding.partition.batch_solve_rules`
    table (logical axis ``problems`` -> mesh batch axis), not hard-coded.

    Parameters
    ----------
    mesh : jax.sharding.Mesh
        A mesh containing the batch axis (see
        :func:`repro.core.distributed.make_batch_mesh`).

    Returns
    -------
    jax.sharding.PartitionSpec
        Spec for arrays whose axis 0 is the problem axis; used both as a
        shard_map prefix spec and to build NamedShardings.
    """
    return batch_solve_rules(mesh.axis_names).spec(("problems",))


def batch_sharding(mesh: Mesh) -> NamedSharding:
    """NamedSharding placing a ``(B, ...)`` array's axis 0 over the mesh."""
    return NamedSharding(mesh, problem_pspec(mesh))


def device_put_batch(tree, mesh: Mesh):
    """Place every leaf of ``tree`` with its axis 0 sharded over ``mesh``.

    Parameters
    ----------
    tree : pytree of arrays
        Each leaf must have a leading problem axis divisible by the mesh
        size.
    mesh : jax.sharding.Mesh
        The 1-D batch mesh.

    Returns
    -------
    pytree of jax.Array
        Same structure, leaves committed to the mesh devices.
    """
    sh = batch_sharding(mesh)
    return jax.tree_util.tree_map(lambda x: jax.device_put(x, sh), tree)


def state_pspecs(spec) -> slv.BatchSolveState:
    """Flattened shard_map specs for a :class:`~repro.core.solver.BatchSolveState`.

    Composes the per-component flatteners
    (:func:`repro.core.lbfgs.state_pspecs`,
    :func:`repro.core.screening.state_pspecs`) — every leaf of the solver
    state carries the leading problem axis, so the whole state shards with
    one leading-axis spec per leaf.
    """
    return slv.BatchSolveState(
        lb=lbfgs_pspecs(spec),
        scr=screening.state_pspecs(spec),
        rounds=spec,
        stats=spec,
    )


def pad_batch_to_devices(
    C: jnp.ndarray,
    a: jnp.ndarray,
    b: jnp.ndarray,
    row_mask: jnp.ndarray,
    sqrt_g: jnp.ndarray,
    num_devices: int,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray, int]:
    """Pad a ragged batch up to a device-count multiple with dummy problems.

    Dummy problems are the serving engine's empty-slot construction:
    ``PAD_COST`` costs and zero marginals give an identically-zero dual
    gradient, so they converge at initialization and ride along for free
    without perturbing real problems (no cross-problem coupling exists).

    Parameters
    ----------
    C, a, b : jnp.ndarray
        Batched problem arrays ``(B, m_pad, n)`` / ``(B, m_pad)`` / ``(B, n)``.
    row_mask, sqrt_g : jnp.ndarray
        Per-problem ``(B, m_pad)`` bool mask and ``(B, L)`` group norms.
    num_devices : int
        Mesh size the padded batch must divide.

    Returns
    -------
    tuple
        ``(C, a, b, row_mask, sqrt_g, B_orig)`` with the leading axis
        padded to the next multiple of ``num_devices``.
    """
    B = C.shape[0]
    B_pad = -(-B // num_devices) * num_devices
    extra = B_pad - B
    if extra == 0:
        return C, a, b, row_mask, sqrt_g, B
    padB = lambda x, v: jnp.concatenate(
        [x, jnp.full((extra,) + x.shape[1:], v, x.dtype)], axis=0
    )
    from repro.kernels import ops as kops

    if isinstance(C, kops.FactorizedCost):
        # factorized dummy = zero samples + PAD_COST squared norms: every
        # cost entry is >= PAD_COST, same as the dense PAD_COST fill
        C_pad = kops.FactorizedCost(
            x=padB(C.x, 0), x_sq=padB(C.x_sq, PAD_COST),
            y=padB(C.y, 0), y_sq=padB(C.y_sq, PAD_COST),
        )
    else:
        C_pad = padB(C, PAD_COST)
    return (
        C_pad,
        padB(a, 0),
        padB(b, 0),
        padB(row_mask, False),
        padB(sqrt_g, 0),
        B,
    )


@functools.lru_cache(maxsize=64)
def _sharded_programs(mesh: Mesh, prob: DualProblem, opts: slv.SolveOptions):
    """Jitted shard_map'd (solve, init, round) programs for one geometry.

    Cached on ``(mesh, prob, opts)`` — all hashable statics — so long-lived
    callers (the serving engine ticks one of these per round) reuse the
    compiled executable.  ``check_vma=False``: the body is collective-free
    by construction (each shard runs the plain batched solver on its local
    problems), so the replication checker has nothing to verify and would
    reject the interpret-mode Pallas calls on CPU.
    """
    A = problem_pspec(mesh)
    ST = state_pspecs(A)

    def local_solve(C, a, b, rm, sg):
        return slv._solve_batch_impl(C, a, b, rm, sg, prob, opts)

    def local_init(C, a, b, rm, sg, padded):
        return slv._init_batch_state(C, a, b, rm, sg, prob, opts, padded)

    def local_round(state, C, a, b, rm, sg, padded):
        return slv._round_body(state, C, a, b, rm, sg, prob, opts, padded)

    arrs = (A, A, A, A, A)
    # `A` as a pytree-prefix spec covers the PaddedProblem arg (its single
    # leaf Cp carries the leading problem axis; geometry fields are static)
    # and degenerates to "no leaves" when padded is None (non-pallas).
    solve = jax.jit(
        shard_map(
            local_solve, mesh=mesh, in_specs=arrs,
            out_specs=(lbfgs_pspecs(A), screening.state_pspecs(A), A, A),
            check_vma=False,
        )
    )
    init = jax.jit(
        shard_map(
            local_init, mesh=mesh, in_specs=arrs + (A,), out_specs=ST,
            check_vma=False,
        )
    )
    rnd = jax.jit(
        shard_map(
            local_round, mesh=mesh, in_specs=(ST,) + arrs + (A,),
            out_specs=ST, check_vma=False,
        )
    )
    return solve, init, rnd


def prepare_padded_sharded(C: jnp.ndarray, prob: DualProblem, mesh: Mesh,
                           precision: str = "f32"):
    """Build the batched PaddedProblem with its cost matrix mesh-sharded.

    The pallas/fused backends' tile-padded cost copy is the largest array
    in a solve; long-lived callers (engine buckets) build it once and keep
    its ``Cp`` committed shard-wise so a tick never re-pads or re-uploads.

    Parameters
    ----------
    C : jnp.ndarray
        ``(B, m_pad, n)`` batched costs (host or device).
    prob : DualProblem
        Static problem geometry.
    mesh : jax.sharding.Mesh
        The 1-D batch mesh.
    precision : {'f32', 'bf16'}
        Cost-operand storage; 'bf16' downcasts the prepared cost leaves
        exactly as :func:`repro.core.solver._prepare_padded` does, so a
        sharded bf16 solve sees the same rounded cost as an unsharded one.

    Returns
    -------
    repro.kernels.ops.PaddedProblem or repro.kernels.ops.FactorizedProblem
        Dense costs yield a PaddedProblem with ``Cp`` of shape
        ``(B, L_pad * g, n_pad)`` sharded over axis 0; factorized costs a
        FactorizedProblem whose four sample/norm leaves are sharded the
        same way (every leaf carries the leading problem axis).
    """
    import dataclasses

    from repro.kernels import ops as kops

    if isinstance(C, kops.FactorizedCost):
        pp = kops.prepare_factorized_problem(C, prob)
        if precision == "bf16":
            pp = dataclasses.replace(
                pp,
                x=pp.x.astype(jnp.bfloat16),
                x_sq=pp.x_sq.astype(jnp.bfloat16),
                y=pp.y.astype(jnp.bfloat16),
                y_sq=pp.y_sq.astype(jnp.bfloat16),
            )
    else:
        pp = kops.prepare_padded_problem_batched(jnp.asarray(C), prob)
        if precision == "bf16":
            pp = dataclasses.replace(pp, Cp=pp.Cp.astype(jnp.bfloat16))
    return device_put_batch(pp, mesh)


def init_batch_state_sharded(
    C, a, b, row_mask, sqrt_g, prob: DualProblem, opts: slv.SolveOptions,
    mesh: Mesh, padded=None,
):
    """Sharded counterpart of :func:`repro.core.solver.init_batch_state`.

    One program launch; every input/output leaf has its problem axis over
    ``mesh``.  ``row_mask`` / ``sqrt_g`` must be per-problem ``(B, ...)``
    here (shared forms cannot shard over the problem axis).

    Parameters
    ----------
    C, a, b : jnp.ndarray
        ``(B, m_pad, n)`` / ``(B, m_pad)`` / ``(B, n)``, ``B`` divisible by
        the mesh size.
    row_mask, sqrt_g : jnp.ndarray
        ``(B, m_pad)`` bool / ``(B, L)`` float32.
    prob, opts :
        Static solve description (hashable dataclasses).
    mesh : jax.sharding.Mesh
        1-D batch mesh from :func:`~repro.core.distributed.make_batch_mesh`.
    padded : PaddedProblem, optional
        Pre-built sharded padded problem (pallas backend); see
        :func:`prepare_padded_sharded`.

    Returns
    -------
    repro.core.solver.BatchSolveState
        Sharded initial state (valid snapshots + first oracle evaluation).
    """
    if padded is None and opts.grad_impl in ("pallas", "fused"):
        padded = prepare_padded_sharded(C, prob, mesh,
                                        precision=opts.precision)
    _, init, _ = _sharded_programs(mesh, prob, opts)
    return init(C, a, b, row_mask, sqrt_g, padded)


def batch_round_sharded(
    state, C, a, b, row_mask, sqrt_g, prob: DualProblem,
    opts: slv.SolveOptions, mesh: Mesh, padded=None,
):
    """Sharded counterpart of :func:`repro.core.solver.batch_round`.

    One fused Algorithm-1 round for the whole sharded batch in a single
    launch: each device runs the L-BFGS segment + screening refresh +
    snapshot for its local problems, frozen problems masked.  No
    collective appears inside the round; the caller reads the sharded
    ``converged`` flags afterwards (the round-boundary gather).

    Parameters are as in :func:`init_batch_state_sharded`, with ``state``
    the sharded :class:`~repro.core.solver.BatchSolveState` to advance.

    Returns
    -------
    repro.core.solver.BatchSolveState
        The advanced sharded state.
    """
    if padded is None and opts.grad_impl in ("pallas", "fused"):
        padded = prepare_padded_sharded(C, prob, mesh,
                                        precision=opts.precision)
    _, _, rnd = _sharded_programs(mesh, prob, opts)
    return rnd(state, C, a, b, row_mask, sqrt_g, padded)


def solve_batch_sharded(
    C: jnp.ndarray,
    a: jnp.ndarray,
    b: jnp.ndarray,
    spec: GroupSpec,
    reg: Regularizer,
    opts: slv.SolveOptions = slv.SolveOptions(),
    mesh: Optional[Mesh] = None,
) -> slv.BatchOTResult:
    """Solve B same-shape problems with the batch sharded across devices.

    The multi-device form of :func:`repro.core.solver.solve_batch`: one
    jitted ``shard_map`` program runs every problem to convergence, the
    problem axis split over a 1-D device mesh.  Per problem the result is
    bitwise-identical to the unsharded batched solve (and hence to
    :func:`~repro.core.solver.solve_dual`); see the module docstring for
    why the sharding cannot perturb the trajectory.

    Parameters
    ----------
    C : jnp.ndarray
        ``(B, m_pad, n)`` padded cost matrices, float32.
    a : jnp.ndarray
        ``(B, m_pad)`` padded source marginals.
    b : jnp.ndarray
        ``(B, n)`` target marginals.
    spec : GroupSpec
        Shared group layout (static geometry the program compiles for).
    reg : Regularizer
        Regularizer parameters.
    opts : SolveOptions, optional
        Any ``grad_impl`` backend
        ('dense' | 'screened' | 'pallas' | 'fused').
    mesh : jax.sharding.Mesh, optional
        1-D batch mesh; defaults to
        :func:`~repro.core.distributed.make_batch_mesh` over every local
        device.  ``B`` not divisible by the mesh size is padded with dummy
        problems (zero gradient, converged at init) and un-padded on
        return.

    Returns
    -------
    repro.core.solver.BatchOTResult
        Result container whose leaves remain device-sharded; indexing
        (``result[i]``) and the host conversions gather transparently.

    .. deprecated:: use :meth:`repro.ot.Executor.solve_many` with a mesh
       (``ExecutionPlan(devices='all')`` or ``compile(..., mesh=mesh)``)
       — this shim delegates there and emits a ``DeprecationWarning``.
    """
    import warnings

    warnings.warn(
        "solve_batch_sharded() is deprecated; use repro.ot "
        "(compile(..., ExecutionPlan(devices='all')).solve_many) instead",
        DeprecationWarning, stacklevel=2,
    )
    assert C.ndim == 3, f"expected (B, m_pad, n) costs, got {C.shape}"
    if mesh is None:
        mesh = make_batch_mesh()
    from repro.ot.executor import Executor
    from repro.ot.plan import ExecutionPlan

    ex = Executor(
        spec, int(C.shape[2]), reg, ExecutionPlan.from_solve_options(opts),
        mesh=mesh,
    )
    lb, scr, rounds, stats = ex._solve_padded_batch_sharded(C, a, b)
    alpha, beta = slv._split(lb.x, ex._prob.m_pad)
    return slv.BatchOTResult(alpha, beta, -lb.f, lb, scr, rounds, stats)


def _clear_program_cache() -> None:
    """Drop cached sharded executables (tests that rebuild meshes)."""
    _sharded_programs.cache_clear()


# number of local devices a default mesh would span — convenience for
# callers sizing batches/buckets without building a mesh first
def default_device_count() -> int:
    """``jax.local_device_count()`` (the default 1-D mesh size)."""
    return jax.local_device_count()
