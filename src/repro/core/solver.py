"""Algorithm 1 of the paper: screened L-BFGS for the group-sparse OT dual.

Outer loop (rounds): run the solver for ``r`` iterations with the current
screen state frozen  ->  refresh the active set N from lower bounds
(Definition 3)  ->  take new snapshots (Definition 1/2)  ->  repeat until the
solver converges.

The gradient oracle inside a round evaluates, per Algorithm 2:
  * ACTIVE entries (in N): exact gradient, no bound check,
  * other entries: Eq. 6 upper bound; ZERO-certified blocks are skipped
    (exact zeros), the rest computed exactly.

``grad_impl`` selects the execution backend:
  'dense'     original (unscreened) method — the paper's "origin",
  'screened'  screening with masked XLA ops (accounting-exact reference),
  'pallas'    the block-masked Pallas kernels from repro.kernels
              (two launches per evaluation: screen, then gradient),
  'fused'     the single-launch mega-kernel — verdicts computed
              in-register inside the gradient grid step (DESIGN.md §10).

By Theorem 2 all backends return identical objective values and iterates
(screening only ever zeroes provably-zero entries); tests assert this.

Batching: the dual is separable over problems, so B same-shape problems
solve in ONE jitted program — every array carries a leading B axis, the
L-BFGS segment masks per-problem convergence (``core.lbfgs``), and the
screening state is per-problem.  :func:`solve_dual` is the B = 1 slice of
:func:`solve_batch`; because both run the identical batched op sequence,
a problem solved solo and the same problem solved inside a batch produce
bitwise-identical iterates (asserted by tests/test_solve_batch.py).  The
round-step API (:func:`init_batch_state` / :func:`batch_round`) exposes
one fused round per call for the OT serving engine
(``repro.serving.ot_engine``), which retires converged problems and
recycles their slots between rounds.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import screening
from repro.core.dual import (
    DualProblem,
    dual_value_and_grad,
    plan_from_duals,
    snapshot_norms,
)
from repro.core.groups import GroupSpec
from repro.core.lbfgs import (
    LbfgsOptions,
    LbfgsState,
    init_state_batched,
    run_segment_batched,
    where_state,
)
from repro.core.regularizers import Regularizer


@dataclasses.dataclass(frozen=True)
class SolveOptions:
    """Static solver configuration (jitted programs specialize on it).

    Parameters
    ----------
    snapshot_every : int
        ``r`` in Algorithm 1 — L-BFGS iterations per screening round.
    max_rounds : int
        Cap on the number of rounds (``s_r``).
    grad_impl : {'dense', 'screened', 'pallas', 'fused'}
        Gradient oracle backend: the paper's unscreened origin, the
        masked-XLA screened reference, the two-launch Pallas pipeline
        (screen kernel -> gradient kernel), or the fused single-launch
        mega-kernel (verdicts computed in-register, DESIGN.md §10).
    pallas_impl : {'grid', 'compact', 'auto'}
        Kernel grid mode for ``grad_impl='pallas'`` (see kernels/ops.py).
        For ``grad_impl='fused'``: 'grid' is the fused dense grid,
        'compact' the two-launch reference, 'auto' a runtime switch on the
        snapshot-point live-tile density.
    tight_active_refresh : bool
        Beyond-paper tighter active-set refresh (off for paper fidelity).
    precision : {'f32', 'bf16'}
        Cost-operand storage precision for the pallas/fused backends:
        'bf16' stores the prepared cost (or factorized sample blocks) in
        bfloat16 while every kernel still upcasts on load and accumulates
        T/psi in f32.  Screening snapshots are taken against the SAME
        bf16-rounded cost, so the Eq. 6 bounds stay exactly safe w.r.t.
        the cost the gradient actually sees (docs/geometry.md numerics
        policy).  Rejected for the dense/screened reference backends.
    lbfgs : LbfgsOptions
        Inner optimizer configuration.
    """

    snapshot_every: int = 10          # r in Algorithm 1
    max_rounds: int = 200             # cap on s_r
    grad_impl: str = "screened"       # 'dense' | 'screened' | 'pallas' | 'fused'
    pallas_impl: str = "auto"         # 'grid' | 'compact' | 'auto': kernel
    #   grid mode for grad_impl='pallas'/'fused' (see kernels/ops.py docstring)
    tight_active_refresh: bool = False  # beyond-paper: refresh N *after* the
    #   snapshot update (Delta = 0 => lower bound k~ - o~, strictly tighter
    #   than Eq. 7 evaluated pre-update; N stays a performance hint so
    #   exactness is unaffected).  Off by default for paper fidelity.
    precision: str = "f32"            # 'f32' | 'bf16' cost-operand storage
    #   (pallas/fused only; accumulation is always f32)
    lbfgs: LbfgsOptions = dataclasses.field(default_factory=LbfgsOptions)


# host->device program launches issued through this module's public entry
# points (one per jitted call).  The batched solver's whole point is that a
# B-problem solve is ONE launch instead of B; tests assert the ratio here.
_DISPATCHES = {"count": 0}


def dispatch_count() -> int:
    """Number of jitted-program launches since :func:`reset_dispatch_count`."""
    return _DISPATCHES["count"]


def reset_dispatch_count() -> None:
    """Zero the launch counter (tests / benchmarks bracket work with it)."""
    _DISPATCHES["count"] = 0


def _launch(fn, *args):
    _DISPATCHES["count"] += 1
    return fn(*args)


class OTResult:
    """Solution container (host-side convenience wrapper).

    Attributes
    ----------
    alpha : jnp.ndarray
        ``(m_pad,)`` optimal source duals (padded layout).
    beta : jnp.ndarray
        ``(n,)`` optimal target duals.
    value : jnp.ndarray
        Scalar dual objective at the solution (maximization sign).
    lbfgs_state : LbfgsState
        Final optimizer state (iterates, history, convergence flags).
    screen_state : ScreenState
        Final screening snapshots + active set.
    rounds : int
        Algorithm-1 rounds run.
    stats : dict
        Accumulated screening verdict counts ``{'zero','check','active'}``.
    """

    def __init__(self, alpha, beta, value, state, screen_state, rounds, stats):
        self.alpha = alpha
        self.beta = beta
        self.value = value
        self.lbfgs_state = state
        self.screen_state = screen_state
        self.rounds = rounds
        self.stats = stats

    @property
    def iterations(self):
        """Total L-BFGS iterations taken."""
        return int(self.lbfgs_state.iter)

    @property
    def n_evals(self):
        """Total value_and_grad oracle evaluations."""
        return int(self.lbfgs_state.n_evals)

    @property
    def converged(self):
        """Whether the dual solve converged (vs. failed / hit caps)."""
        return bool(self.lbfgs_state.converged)


class BatchOTResult:
    """Batched solution container: B independent problems, one solve.

    ``result[i]`` materializes the i-th problem as a solo :class:`OTResult`
    (leaf slicing only; no recomputation).
    """

    def __init__(self, alpha, beta, values, lb, scr, rounds, stats):
        self.alpha = alpha              # (B, m_pad)
        self.beta = beta                # (B, n)
        self.values = values            # (B,)
        self.lbfgs_state = lb           # batched leaves
        self.screen_state = scr         # batched leaves
        self.rounds = rounds            # (B,) int
        self.stats = stats              # (B, 3) int [zero, check, active]

    def __len__(self):
        return int(self.alpha.shape[0])

    @property
    def converged(self):
        """``(B,)`` bool — per-problem convergence flags."""
        return self.lbfgs_state.converged

    def __getitem__(self, i: int) -> OTResult:
        sl = lambda t: jax.tree_util.tree_map(lambda v: v[i], t)
        stats = {
            "zero": int(self.stats[i, 0]),
            "check": int(self.stats[i, 1]),
            "active": int(self.stats[i, 2]),
        }
        return OTResult(
            self.alpha[i], self.beta[i], self.values[i],
            sl(self.lbfgs_state), sl(self.screen_state),
            int(self.rounds[i]), stats,
        )


class BatchSolveState(NamedTuple):
    """Device-side state of a batch of solves between rounds."""

    lb: LbfgsState                  # batched L-BFGS state
    scr: screening.ScreenState      # batched screening state
    rounds: jnp.ndarray             # (B,) int32 rounds each problem ran
    stats: jnp.ndarray              # (B, 3) int32 [zero, check, active]


def _split(x: jnp.ndarray, m_pad: int):
    return x[..., :m_pad], x[..., m_pad:]


def make_value_and_grad(
    C: jnp.ndarray,
    a: jnp.ndarray,
    b: jnp.ndarray,
    prob: DualProblem,
    sqrt_g: jnp.ndarray,
    grad_impl: str,
    screen_state: Optional[screening.ScreenState],
    padded=None,                       # kernels.ops.PaddedProblem (pallas)
    pallas_impl: str = "auto",
):
    """Build the (negated, minimized) value_and_grad oracle for L-BFGS.

    Single-problem variant (x is (m_pad + n,)): used by the distributed
    driver and the roofline lowering.  The solver's own loop uses
    :func:`make_value_and_grad_batched`.
    """
    m_pad = prob.m_pad

    if grad_impl == "dense":
        _reject_factorized(C, grad_impl)

        def vag(x):
            alpha, beta = _split(x, m_pad)
            v, (ga, gb) = dual_value_and_grad(alpha, beta, C, a, b, prob)
            return -v, -jnp.concatenate([ga, gb])

        return vag

    if grad_impl == "screened":
        assert screen_state is not None
        _reject_factorized(C, grad_impl)

        def vag(x):
            alpha, beta = _split(x, m_pad)
            verdict = screening.verdicts(
                screen_state, alpha, beta, sqrt_g, prob.tau_vec()
            )
            zero_mask = verdict == screening.ZERO
            v, (ga, gb) = dual_value_and_grad(
                alpha, beta, C, a, b, prob, zero_mask=zero_mask
            )
            return -v, -jnp.concatenate([ga, gb])

        return vag

    if grad_impl in ("pallas", "fused"):
        assert screen_state is not None
        from repro.kernels import ops as kops

        pp = padded
        if pp is None:
            pp = (
                kops.prepare_factorized_problem(C, prob)
                if _is_factorized(C)
                else kops.prepare_padded_problem(C, prob)
            )
        pstate = kops.pad_screen_state(screen_state, sqrt_g, pp)

        if grad_impl == "fused":
            # single-launch oracle: verdicts computed in-register inside the
            # gradient grid step (DESIGN.md §10); no standalone screen pass.
            def vag(x):
                alpha, beta = _split(x, m_pad)
                v, ga, gb = kops.dual_value_and_grad_fused(
                    alpha, beta, a, b, pstate, pp, prob, impl=pallas_impl
                )
                return -v, -jnp.concatenate([ga, gb])

            return vag

        grad_fn = (
            kops.dual_value_and_grad_factorized
            if isinstance(pp, kops.FactorizedProblem)
            else kops.dual_value_and_grad_padded
        )

        def vag(x):
            alpha, beta = _split(x, m_pad)
            flags = kops.screen_tile_flags(
                pstate, alpha, beta, pp, prob.tau_vec()
            )
            v, ga, gb = grad_fn(
                alpha, beta, a, b, flags, pp, prob, impl=pallas_impl
            )
            return -v, -jnp.concatenate([ga, gb])

        return vag

    raise ValueError(f"unknown grad_impl: {grad_impl}")


def make_value_and_grad_batched(
    C: jnp.ndarray,                    # (B, m_pad, n)
    a: jnp.ndarray,                    # (B, m_pad)
    b: jnp.ndarray,                    # (B, n)
    prob: DualProblem,
    sqrt_g: jnp.ndarray,               # (L,) shared or (B, L) per problem
    grad_impl: str,
    screen_state: Optional[screening.ScreenState],   # batched leaves
    padded=None,                       # kernels.ops.PaddedProblem (B, ...) Cp
    pallas_impl: str = "auto",
):
    """Batched oracle: x (B, m_pad + n) -> ((B,) value, (B, d) grad).

    For the pallas impl the batched screening state is padded to the kernel
    grid HERE — once per snapshot round — so each evaluation only computes
    the O(B (L + n)) delta norms, runs the vmapped screening kernel for
    per-problem tile flags, and feeds them straight to the batched gradient
    kernel (one dynamic grid over the batch's concatenated active tiles in
    compact mode).
    """
    m_pad = prob.m_pad

    if grad_impl == "dense":
        _reject_factorized(C, grad_impl)

        def vag(x):
            alpha, beta = _split(x, m_pad)
            v, (ga, gb) = dual_value_and_grad(alpha, beta, C, a, b, prob)
            return -v, -jnp.concatenate([ga, gb], axis=-1)

        return vag

    if grad_impl == "screened":
        assert screen_state is not None
        _reject_factorized(C, grad_impl)

        def vag(x):
            alpha, beta = _split(x, m_pad)
            verdict = screening.verdicts(
                screen_state, alpha, beta, sqrt_g, prob.tau_vec()
            )
            zero_mask = verdict == screening.ZERO
            v, (ga, gb) = dual_value_and_grad(
                alpha, beta, C, a, b, prob, zero_mask=zero_mask
            )
            return -v, -jnp.concatenate([ga, gb], axis=-1)

        return vag

    if grad_impl in ("pallas", "fused"):
        assert screen_state is not None
        from repro.kernels import ops as kops

        B = C.shape[0]
        pp = padded
        if pp is None:
            pp = (
                kops.prepare_factorized_problem(C, prob)
                if _is_factorized(C)
                else kops.prepare_padded_problem_batched(C, prob)
            )
        sqb = jnp.broadcast_to(sqrt_g, (B, prob.num_groups))
        pstate = kops.pad_screen_state_batched(screen_state, sqb, pp)

        if grad_impl == "fused":
            def vag(x):
                alpha, beta = _split(x, m_pad)
                v, ga, gb = kops.dual_value_and_grad_fused_batched(
                    alpha, beta, a, b, pstate, pp, prob, impl=pallas_impl
                )
                return -v, -jnp.concatenate([ga, gb], axis=-1)

            return vag

        grad_fn = (
            kops.dual_value_and_grad_factorized_batched
            if isinstance(pp, kops.FactorizedProblem)
            else kops.dual_value_and_grad_padded_batched
        )

        def vag(x):
            alpha, beta = _split(x, m_pad)
            flags = kops.screen_tile_flags_batched(
                pstate, alpha, beta, pp, prob.tau_vec()
            )
            v, ga, gb = grad_fn(
                alpha, beta, a, b, flags, pp, prob, impl=pallas_impl
            )
            return -v, -jnp.concatenate([ga, gb], axis=-1)

        return vag

    raise ValueError(f"unknown grad_impl: {grad_impl}")


def _is_factorized(C) -> bool:
    """True when the cost operand is a materialization-free FactorizedCost."""
    from repro.kernels import ops as kops

    return isinstance(C, kops.FactorizedCost)


def _reject_factorized(C, grad_impl: str) -> None:
    """Trace-time guard: only the pallas backend lowers factorized costs.

    The facade's executor materializes the cost (chunked) before routing a
    factorized geometry to the dense/screened reference backends, so this
    is reached only by callers bypassing the executor.
    """
    if _is_factorized(C):
        raise TypeError(
            f"grad_impl='{grad_impl}' cannot consume a FactorizedCost; use "
            "grad_impl='pallas' or materialize the geometry first "
            "(SquaredL2Geometry.materialize)."
        )


def _snapshot_norms_any(alpha, beta, C, prob, row_mask, padded,
                        precision="f32"):
    """Eq. 6 snapshot norms for either cost representation.

    Dense costs use the closed-form ``dual.snapshot_norms``; factorized
    costs run the materialization-free Pallas snapshot kernel against the
    prepared :class:`~repro.kernels.ops.FactorizedProblem` (building one on
    the fly if the caller had no pallas preparation).

    ``precision='bf16'`` rounds the dense cost through bfloat16 first so
    the snapshot bounds describe EXACTLY the cost the kernels integrate
    (``_prepare_padded`` stored ``Cp`` in bf16) — screening correctness is
    then exact with respect to the rounded problem, not approximate with
    respect to the f32 one.  The factorized route is consistent for free:
    the snapshot kernel reads the same (possibly bf16) prepared leaves.
    """
    if _is_factorized(C):
        from repro.kernels import ops as kops

        fp = padded
        if fp is None:
            fp = kops.prepare_factorized_problem(C, prob)
        return kops.snapshot_norms_factorized(alpha, beta, fp, prob, row_mask)
    if precision == "bf16":
        C = C.astype(jnp.bfloat16).astype(C.dtype)
    return snapshot_norms(alpha, beta, C, prob, row_mask)


def _prepare_padded(C, prob, opts):
    """One-time padded-problem preparation for the pallas/fused backends.

    The padded copy of C (the largest array in the problem) is made once
    per solve / per engine round, outside the L-BFGS evaluation loop.
    Factorized costs get a tile-padded :class:`FactorizedProblem` instead
    — no (m, n) array is ever built.

    ``opts.precision == 'bf16'`` downcasts the prepared cost operands
    (``Cp`` or the factorized ``x/x_sq/y/y_sq`` blocks) to bfloat16 HERE,
    once, so every downstream consumer — snapshot norms, screening bounds,
    and the gradient kernels — sees the SAME rounded cost.  Kernels upcast
    on load and accumulate T/psi in f32 (docs/api.md "precision").
    """
    if opts.grad_impl not in ("pallas", "fused"):
        if opts.precision != "f32":
            raise ValueError(
                "precision='bf16' requires grad_impl='pallas' or 'fused' "
                f"(got grad_impl={opts.grad_impl!r}); the dense/screened "
                "reference backends are f32-only."
            )
        return None
    from repro.kernels import ops as kops

    if _is_factorized(C):
        fp = kops.prepare_factorized_problem(C, prob)
        if opts.precision == "bf16":
            fp = dataclasses.replace(
                fp,
                x=fp.x.astype(jnp.bfloat16),
                x_sq=fp.x_sq.astype(jnp.bfloat16),
                y=fp.y.astype(jnp.bfloat16),
                y_sq=fp.y_sq.astype(jnp.bfloat16),
            )
        return fp
    pp = kops.prepare_padded_problem_batched(C, prob)
    if opts.precision == "bf16":
        pp = dataclasses.replace(pp, Cp=pp.Cp.astype(jnp.bfloat16))
    return pp


def _init_batch_state(C, a, b, row_mask, sqrt_g, prob, opts, padded):
    """Initial BatchSolveState: valid snapshots + first oracle evaluation."""
    B = C.shape[0]
    m_pad, n, L = prob.m_pad, prob.n, prob.num_groups
    x0 = jnp.zeros((B, m_pad + n), C.dtype)

    screen0 = screening.init_state(m_pad, n, L, C.dtype, batch_shape=(B,))
    # valid snapshots at the init point (alpha = beta = 0)
    z0, k0, o0 = _snapshot_norms_any(
        jnp.zeros((B, m_pad), C.dtype), jnp.zeros((B, n), C.dtype),
        C, prob, row_mask, padded, opts.precision,
    )
    screen0 = screening.take_snapshot(
        screen0, x0[..., :m_pad], x0[..., m_pad:], z0, k0, o0
    )

    vag0 = make_value_and_grad_batched(
        C, a, b, prob, sqrt_g, opts.grad_impl, screen0,
        padded=padded, pallas_impl=opts.pallas_impl,
    )
    lb0 = init_state_batched(x0, vag0, opts.lbfgs)
    return BatchSolveState(
        lb=lb0,
        scr=screen0,
        rounds=jnp.zeros((B,), jnp.int32),
        stats=jnp.zeros((B, 3), jnp.int32),
    )


def _round_body(state, C, a, b, row_mask, sqrt_g, prob, opts, padded):
    """One Algorithm-1 round over the whole batch, frozen problems masked.

    A problem alive at round start runs the full round (segment + screening
    refresh + snapshot), even if it converges mid-segment — exactly the
    rounds a solo solve of that problem would run.  Problems finished
    before the round keep their state bit-for-bit.
    """
    lb, scr, rounds, stats = state
    m_pad = prob.m_pad
    alive = jnp.logical_and(~lb.converged, ~lb.failed)      # (B,)

    vag = make_value_and_grad_batched(
        C, a, b, prob, sqrt_g, opts.grad_impl, scr,
        padded=padded, pallas_impl=opts.pallas_impl,
    )
    lb = run_segment_batched(vag, lb, opts.snapshot_every, opts.lbfgs)

    alpha, beta = _split(lb.x, m_pad)

    if opts.grad_impl != "dense":
        if not opts.tight_active_refresh:
            # paper order: refresh N w.r.t. OLD snapshots (Eq. 7), then
            # take the new snapshot (Algorithm 1 lines 6-15).
            scr_new = screening.refresh_active(
                scr, alpha, beta, sqrt_g, prob.tau_vec()
            )
            z, k, o = _snapshot_norms_any(alpha, beta, C, prob, row_mask,
                                          padded, opts.precision)
            scr_new = screening.take_snapshot(scr_new, alpha, beta, z, k, o)
        else:
            # beyond-paper: snapshot first => Delta = 0 => lower bound
            # becomes k~ - o~ exactly (Theorem 4's fixed point), tighter N.
            z, k, o = _snapshot_norms_any(alpha, beta, C, prob, row_mask,
                                          padded, opts.precision)
            scr_new = screening.take_snapshot(scr, alpha, beta, z, k, o)
            scr_new = screening.refresh_active(
                scr_new, alpha, beta, sqrt_g, prob.tau_vec()
            )
        verdict = screening.verdicts(
            scr_new, alpha, beta, sqrt_g, prob.tau_vec()
        )
        delta = jnp.stack(
            [
                jnp.sum(verdict == screening.ZERO, axis=(-2, -1)),
                jnp.sum(verdict == screening.CHECK, axis=(-2, -1)),
                jnp.sum(verdict == screening.ACTIVE, axis=(-2, -1)),
            ],
            axis=-1,
        ).astype(jnp.int32)
        scr = where_state(alive, scr_new, scr)
        stats = stats + jnp.where(alive[:, None], delta, 0)

    rounds = rounds + alive.astype(jnp.int32)
    return BatchSolveState(lb=lb, scr=scr, rounds=rounds, stats=stats)


def _solve_batch_impl(C, a, b, row_mask, sqrt_g, prob, opts):
    padded = _prepare_padded(C, prob, opts)
    st0 = _init_batch_state(C, a, b, row_mask, sqrt_g, prob, opts, padded)

    def cond(carry):
        st, rnd = carry
        alive = jnp.logical_and(~st.lb.converged, ~st.lb.failed)
        return jnp.logical_and(rnd < opts.max_rounds, jnp.any(alive))

    def body(carry):
        st, rnd = carry
        st = _round_body(st, C, a, b, row_mask, sqrt_g, prob, opts, padded)
        return (st, rnd + 1)

    st, _ = jax.lax.while_loop(cond, body, (st0, jnp.zeros((), jnp.int32)))
    return st.lb, st.scr, st.rounds, st.stats


@functools.partial(jax.jit, static_argnames=("prob", "opts"))
def _solve_batch_jit(C, a, b, row_mask, sqrt_g, prob, opts):
    """One program: solve B same-shape problems to convergence."""
    return _solve_batch_impl(C, a, b, row_mask, sqrt_g, prob, opts)


@functools.partial(jax.jit, static_argnames=("prob", "opts"))
def _solve_jit(
    C: jnp.ndarray,
    a: jnp.ndarray,
    b: jnp.ndarray,
    row_mask: jnp.ndarray,
    sqrt_g: jnp.ndarray,
    prob: DualProblem,
    opts: SolveOptions,
):
    """Single-problem entry point: the B = 1 slice of the batched solver.

    Kept for the distributed driver (GSPMD shards the unbatched operands)
    and any caller wanting unbatched outputs; returns (lb, scr, rounds,
    stats) with unbatched leaves and a scalar round count.
    """
    C1 = jax.tree_util.tree_map(lambda v: v[None], C)
    lb, scr, rounds, stats = _solve_batch_impl(
        C1, a[None], b[None], row_mask, sqrt_g, prob, opts
    )
    one = lambda t: jax.tree_util.tree_map(lambda v: v[0], t)
    return one(lb), one(scr), rounds[0], stats[0]


@functools.partial(jax.jit, static_argnames=("prob", "opts"))
def init_batch_state(C, a, b, row_mask, sqrt_g, prob, opts, padded=None):
    """Jitted initial state for the round-step API (one launch).

    ``row_mask`` / ``sqrt_g`` may be shared ((m_pad,) / (L,)) or per-problem
    ((B, m_pad) / (B, L)) — the serving engine packs problems with
    different true group sizes into one bucket.  ``padded`` may carry a
    pre-built batched PaddedProblem (pallas backend) so long-lived callers
    like the serving engine don't re-pad C per call.
    """
    if padded is None:
        padded = _prepare_padded(C, prob, opts)
    return _init_batch_state(C, a, b, row_mask, sqrt_g, prob, opts, padded)


@functools.partial(jax.jit, static_argnames=("prob", "opts"))
def batch_round(state, C, a, b, row_mask, sqrt_g, prob, opts, padded=None):
    """Jitted single round over the batch (one launch per engine tick).

    ``padded`` as in :func:`init_batch_state` — the engine passes its
    cached copy so the (largest-array) re-pad doesn't run every tick.
    """
    if padded is None:
        padded = _prepare_padded(C, prob, opts)
    return _round_body(state, C, a, b, row_mask, sqrt_g, prob, opts, padded)


def _solve_solo(C, a, b, spec, reg, opts, launch) -> OTResult:
    """Shared solo-solve body: operand construction, launch, packing.

    ``launch`` is the launcher wrapper — the module-level :func:`_launch`
    for :func:`solve_dual`, or an ``Executor._launch`` bound method so the
    façade counts the program against its own stats.  Keeping ONE copy of
    this op sequence is what makes ``Executor.solve`` bitwise-identical
    to ``solve_dual`` by construction.
    """
    prob = DualProblem(
        num_groups=spec.num_groups,
        group_size=spec.group_size,
        n=int(C.shape[1]),
        reg=reg,
    )
    row_mask = jnp.asarray(spec.row_mask().reshape(-1))
    sqrt_g = jnp.asarray(spec.sqrt_sizes(), C.dtype)

    lb, scr, rounds, stats = launch(
        _solve_jit, C, a, b, row_mask, sqrt_g, prob, opts
    )
    alpha, beta = _split(lb.x, prob.m_pad)
    stats_dict = {
        "zero": int(stats[0]),
        "check": int(stats[1]),
        "active": int(stats[2]),
    }
    return OTResult(alpha, beta, -lb.f, lb, scr, int(rounds), stats_dict)


def solve_dual(
    C: jnp.ndarray,
    a: jnp.ndarray,
    b: jnp.ndarray,
    spec: GroupSpec,
    reg: Regularizer,
    opts: SolveOptions = SolveOptions(),
) -> OTResult:
    """Solve the group-sparse OT dual on padded inputs (one problem).

    The B = 1 slice of :func:`solve_batch` — identical op sequence, so a
    problem solved solo matches the same problem inside any batch bitwise.

    Parameters
    ----------
    C : jnp.ndarray
        ``(m_pad, n)`` float32 padded cost matrix (see
        :func:`repro.core.groups.pad_cost_matrix`).
    a : jnp.ndarray
        ``(m_pad,)`` padded source marginal (zero mass on padded rows).
    b : jnp.ndarray
        ``(n,)`` target marginal.
    spec : GroupSpec
        Group layout of the padded rows.
    reg : Regularizer
        Regularizer (group-sparse, pure-l2, or elastic-net; see
        :mod:`repro.core.regularizers`).
    opts : SolveOptions, optional
        Backend and schedule configuration.

    Returns
    -------
    OTResult
        Optimal duals, objective, final solver/screening state, stats.
    """
    return _solve_solo(C, a, b, spec, reg, opts, _launch)


def solve_batch(
    C: jnp.ndarray,
    a: jnp.ndarray,
    b: jnp.ndarray,
    spec: GroupSpec,
    reg: Regularizer,
    opts: SolveOptions = SolveOptions(),
) -> BatchOTResult:
    """Solve B same-shape group-sparse OT problems in ONE jitted program.

    All problems share the group layout ``spec`` and regularizer ``reg``
    (the static geometry the program is compiled for); marginals and
    costs vary freely.  Per problem the result is bitwise-identical to
    :func:`solve_dual` on the same inputs: the batch axis only adds a
    leading dim to every op, and converged problems freeze via masking
    rather than early exit.  For the multi-device variant see
    :func:`repro.core.sharded.solve_batch_sharded`.

    Parameters
    ----------
    C : jnp.ndarray
        ``(B, m_pad, n)`` float32 padded cost matrices.
    a : jnp.ndarray
        ``(B, m_pad)`` padded source marginals.
    b : jnp.ndarray
        ``(B, n)`` target marginals.
    spec : GroupSpec
        Shared group layout.
    reg : Regularizer
        Regularizer (any :class:`~repro.core.regularizers.Regularizer`).
    opts : SolveOptions, optional
        Backend and schedule configuration.

    Returns
    -------
    BatchOTResult
        Batched result; ``result[i]`` views problem i as an OTResult.

    .. deprecated:: use :meth:`repro.ot.Executor.solve_many` — this shim
       delegates there and emits a ``DeprecationWarning``.
    """
    import warnings

    warnings.warn(
        "solve_batch() is deprecated; use repro.ot "
        "(compile(...).solve_many) instead",
        DeprecationWarning, stacklevel=2,
    )
    assert C.ndim == 3, f"solve_batch expects (B, m_pad, n) costs, got {C.shape}"
    from repro.ot.executor import Executor
    from repro.ot.plan import ExecutionPlan

    ex = Executor(spec, int(C.shape[2]), reg, ExecutionPlan.from_solve_options(opts))
    lb, scr, rounds, stats = ex._solve_padded_batch(C, a, b)
    alpha, beta = _split(lb.x, ex._prob.m_pad)
    return BatchOTResult(alpha, beta, -lb.f, lb, scr, rounds, stats)


def recover_plan(result: OTResult, C: jnp.ndarray, spec: GroupSpec, reg: Regularizer):
    """Primal plan T* = grad psi(alpha* + beta_j* 1 - c_j) (padded rows incl.)."""
    prob = DualProblem(spec.num_groups, spec.group_size, int(C.shape[1]), reg)
    return plan_from_duals(result.alpha, result.beta, C, prob)


def recover_plan_batch(
    result: BatchOTResult, C: jnp.ndarray, spec: GroupSpec, reg: Regularizer
):
    """Batched primal plans (B, m_pad, n) from a :class:`BatchOTResult`."""
    prob = DualProblem(spec.num_groups, spec.group_size, int(C.shape[2]), reg)
    return plan_from_duals(result.alpha, result.beta, C, prob)


def describe(
    spec: GroupSpec,
    n: int,
    reg: Regularizer,
    opts: SolveOptions = SolveOptions(),
    result=None,
) -> str:
    """One diagnostic block: padded geometry, tile counts, live density.

    Docs examples and bug reports print this so everyone looks at the
    same numbers (see also the compact ``repr`` of :class:`GroupSpec` and
    ``ScreenState``).

    Parameters
    ----------
    spec : GroupSpec
        Group layout of the (padded) problem.
    n : int
        Number of target columns.
    reg : Regularizer
        Regularizer (any :class:`~repro.core.regularizers.Regularizer`).
    opts : SolveOptions, optional
        Shown so reports pin down the backend that ran.
    result : OTResult or BatchOTResult, optional
        When given, appends convergence and screening-verdict totals —
        the live-density line is the fraction of gradient blocks the
        screened oracle actually computed over the whole solve.

    Returns
    -------
    str
        A multi-line human-readable report.
    """
    from repro.kernels.gradpsi import DEFAULT_TILE_N, resolve_tile_l

    prob = DualProblem(spec.num_groups, spec.group_size, int(n), reg)
    tile_l = resolve_tile_l(
        prob.num_groups, prob.group_size, DEFAULT_TILE_N, 4
    )
    L_pad, n_pad = prob.tile_padded_shape(tile_l, DEFAULT_TILE_N)
    lt, nt = L_pad // tile_l, n_pad // DEFAULT_TILE_N
    lines = [
        f"problem:  {spec!r}",
        f"dual:     m_pad={prob.m_pad} n={prob.n} "
        f"(x dim {prob.m_pad + prob.n}), reg={reg!r} "
        f"(kind={type(reg).kind}, tau_max={reg.tau_max:g})",
        f"tiles:    ({tile_l} groups x {DEFAULT_TILE_N} cols) grid "
        f"{lt} x {nt} = {lt * nt} tiles "
        f"(L padded {prob.num_groups}->{L_pad}, n padded {prob.n}->{n_pad})",
        f"backend:  grad_impl={opts.grad_impl} pallas_impl={opts.pallas_impl} "
        f"precision={opts.precision} snapshot_every={opts.snapshot_every}",
    ]
    if result is not None:
        if isinstance(result.stats, dict):
            zero = result.stats["zero"]
            check = result.stats["check"]
            act = result.stats["active"]
            conv, rounds = result.converged, result.rounds
        else:
            import numpy as _np

            s = _np.asarray(result.stats)
            zero, check, act = (int(v) for v in s.sum(axis=0))
            conv = bool(jnp.all(result.converged))
            rounds = int(jnp.sum(result.rounds))
        total = max(zero + check + act, 1)
        lines += [
            f"solve:    rounds={rounds} converged={conv}",
            f"verdicts: zero={zero} check={check} active={act} "
            f"-> live density {(check + act) / total:.1%}",
        ]
    return "\n".join(lines)
