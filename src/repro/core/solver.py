"""Algorithm 1 of the paper: screened L-BFGS for the group-sparse OT dual.

Outer loop (rounds): run the solver for ``r`` iterations with the current
screen state frozen  ->  refresh the active set N from lower bounds
(Definition 3)  ->  take new snapshots (Definition 1/2)  ->  repeat until the
solver converges.

The gradient oracle inside a round evaluates, per Algorithm 2:
  * ACTIVE entries (in N): exact gradient, no bound check,
  * other entries: Eq. 6 upper bound; ZERO-certified blocks are skipped
    (exact zeros), the rest computed exactly.

``grad_impl`` selects the execution backend:
  'dense'     original (unscreened) method — the paper's "origin",
  'screened'  screening with masked XLA ops (accounting-exact reference),
  'pallas'    the block-masked Pallas kernel from repro.kernels.

By Theorem 2 all three return identical objective values and iterates
(screening only ever zeroes provably-zero entries); tests assert this.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import screening
from repro.core.dual import (
    DualProblem,
    dual_value_and_grad,
    plan_from_duals,
    snapshot_norms,
)
from repro.core.groups import GroupSpec
from repro.core.lbfgs import LbfgsOptions, LbfgsState, init_state, run_segment
from repro.core.regularizers import GroupSparseReg


@dataclasses.dataclass(frozen=True)
class SolveOptions:
    snapshot_every: int = 10          # r in Algorithm 1
    max_rounds: int = 200             # cap on s_r
    grad_impl: str = "screened"       # 'dense' | 'screened' | 'pallas'
    pallas_impl: str = "auto"         # 'grid' | 'compact' | 'auto': kernel
    #   grid mode for grad_impl='pallas' (see kernels/ops.py docstring)
    tight_active_refresh: bool = False  # beyond-paper: refresh N *after* the
    #   snapshot update (Delta = 0 => lower bound k~ - o~, strictly tighter
    #   than Eq. 7 evaluated pre-update; N stays a performance hint so
    #   exactness is unaffected).  Off by default for paper fidelity.
    lbfgs: LbfgsOptions = dataclasses.field(default_factory=LbfgsOptions)


class OTResult:
    """Solution container (host-side convenience wrapper)."""

    def __init__(self, alpha, beta, value, state, screen_state, rounds, stats):
        self.alpha = alpha
        self.beta = beta
        self.value = value
        self.lbfgs_state = state
        self.screen_state = screen_state
        self.rounds = rounds
        self.stats = stats

    @property
    def iterations(self):
        return int(self.lbfgs_state.iter)

    @property
    def n_evals(self):
        return int(self.lbfgs_state.n_evals)

    @property
    def converged(self):
        return bool(self.lbfgs_state.converged)


def _split(x: jnp.ndarray, m_pad: int):
    return x[:m_pad], x[m_pad:]


def make_value_and_grad(
    C: jnp.ndarray,
    a: jnp.ndarray,
    b: jnp.ndarray,
    prob: DualProblem,
    sqrt_g: jnp.ndarray,
    grad_impl: str,
    screen_state: Optional[screening.ScreenState],
    padded=None,                       # kernels.ops.PaddedProblem (pallas)
    pallas_impl: str = "auto",
):
    """Build the (negated, minimized) value_and_grad oracle for L-BFGS.

    For the pallas impl the screening state is padded to the kernel grid
    HERE — once per snapshot round — so each evaluation only computes the
    O(L + n) delta norms, runs the fused screening kernel for tile flags,
    and feeds them straight to the gradient kernel.  The padded cost matrix
    (``padded``) is prepared once per solve by :func:`solve_dual`.
    """
    m_pad = prob.m_pad

    if grad_impl == "dense":

        def vag(x):
            alpha, beta = _split(x, m_pad)
            v, (ga, gb) = dual_value_and_grad(alpha, beta, C, a, b, prob)
            return -v, -jnp.concatenate([ga, gb])

        return vag

    if grad_impl == "screened":
        assert screen_state is not None

        def vag(x):
            alpha, beta = _split(x, m_pad)
            verdict = screening.verdicts(
                screen_state, alpha, beta, sqrt_g, prob.reg.tau
            )
            zero_mask = verdict == screening.ZERO
            v, (ga, gb) = dual_value_and_grad(
                alpha, beta, C, a, b, prob, zero_mask=zero_mask
            )
            return -v, -jnp.concatenate([ga, gb])

        return vag

    if grad_impl == "pallas":
        assert screen_state is not None
        from repro.kernels import ops as kops

        pp = padded
        if pp is None:
            pp = kops.prepare_padded_problem(C, prob)
        pstate = kops.pad_screen_state(screen_state, sqrt_g, pp)

        def vag(x):
            alpha, beta = _split(x, m_pad)
            flags = kops.screen_tile_flags(
                pstate, alpha, beta, pp, prob.reg.tau
            )
            v, ga, gb = kops.dual_value_and_grad_padded(
                alpha, beta, a, b, flags, pp, prob, impl=pallas_impl
            )
            return -v, -jnp.concatenate([ga, gb])

        return vag

    raise ValueError(f"unknown grad_impl: {grad_impl}")


@functools.partial(
    jax.jit,
    static_argnames=("prob", "opts"),
)
def _solve_jit(
    C: jnp.ndarray,
    a: jnp.ndarray,
    b: jnp.ndarray,
    row_mask: jnp.ndarray,
    sqrt_g: jnp.ndarray,
    prob: DualProblem,
    opts: SolveOptions,
):
    m_pad, n, L = prob.m_pad, prob.n, prob.num_groups
    x0 = jnp.zeros((m_pad + n,), C.dtype)

    # one-time padded-problem preparation: the padded copy of C (the largest
    # array in the problem) is made here, outside the round loop, instead of
    # once per gradient evaluation.
    padded = None
    if opts.grad_impl == "pallas":
        from repro.kernels import ops as kops

        padded = kops.prepare_padded_problem(C, prob)

    screen0 = screening.init_state(m_pad, n, L, C.dtype)
    # valid snapshots at the init point (alpha = beta = 0)
    z0, k0, o0 = snapshot_norms(
        jnp.zeros((m_pad,), C.dtype), jnp.zeros((n,), C.dtype), C, prob, row_mask
    )
    screen0 = screening.take_snapshot(screen0, x0[:m_pad], x0[m_pad:], z0, k0, o0)

    vag0 = make_value_and_grad(
        C, a, b, prob, sqrt_g, opts.grad_impl, screen0,
        padded=padded, pallas_impl=opts.pallas_impl,
    )
    lb0 = init_state(x0, vag0, opts.lbfgs)

    # stats: [zero, check, active] verdict counts accumulated per round
    stats0 = jnp.zeros((3,), jnp.int32)

    def round_body(carry):
        lb, scr, rnd, stats = carry
        vag = make_value_and_grad(
            C, a, b, prob, sqrt_g, opts.grad_impl, scr,
            padded=padded, pallas_impl=opts.pallas_impl,
        )
        lb = run_segment(vag, lb, opts.snapshot_every, opts.lbfgs)

        alpha, beta = _split(lb.x, m_pad)

        if opts.grad_impl != "dense":
            if not opts.tight_active_refresh:
                # paper order: refresh N w.r.t. OLD snapshots (Eq. 7), then
                # take the new snapshot (Algorithm 1 lines 6-15).
                scr = screening.refresh_active(scr, alpha, beta, sqrt_g, prob.reg.tau)
                z, k, o = snapshot_norms(alpha, beta, C, prob, row_mask)
                scr = screening.take_snapshot(scr, alpha, beta, z, k, o)
            else:
                # beyond-paper: snapshot first => Delta = 0 => lower bound
                # becomes k~ - o~ exactly (Theorem 4's fixed point), tighter N.
                z, k, o = snapshot_norms(alpha, beta, C, prob, row_mask)
                scr = screening.take_snapshot(scr, alpha, beta, z, k, o)
                scr = screening.refresh_active(scr, alpha, beta, sqrt_g, prob.reg.tau)
            verdict = screening.verdicts(scr, alpha, beta, sqrt_g, prob.reg.tau)
            stats = stats + jnp.stack(
                [
                    jnp.sum(verdict == screening.ZERO),
                    jnp.sum(verdict == screening.CHECK),
                    jnp.sum(verdict == screening.ACTIVE),
                ]
            ).astype(jnp.int32)

        return (lb, scr, rnd + 1, stats)

    def round_cond(carry):
        lb, _, rnd, _ = carry
        return jnp.logical_and(
            rnd < opts.max_rounds,
            jnp.logical_and(~lb.converged, ~lb.failed),
        )

    lb, scr, rounds, stats = jax.lax.while_loop(
        round_cond, round_body, (lb0, screen0, jnp.zeros((), jnp.int32), stats0)
    )
    return lb, scr, rounds, stats


def solve_dual(
    C: jnp.ndarray,
    a: jnp.ndarray,
    b: jnp.ndarray,
    spec: GroupSpec,
    reg: GroupSparseReg,
    opts: SolveOptions = SolveOptions(),
) -> OTResult:
    """Solve the group-sparse OT dual on padded inputs.

    C: (m_pad, n) padded cost matrix; a: (m_pad,) padded source marginal;
    b: (n,) target marginal.
    """
    prob = DualProblem(
        num_groups=spec.num_groups,
        group_size=spec.group_size,
        n=int(C.shape[1]),
        reg=reg,
    )
    row_mask = jnp.asarray(spec.row_mask().reshape(-1))
    sqrt_g = jnp.asarray(spec.sqrt_sizes(), C.dtype)

    lb, scr, rounds, stats = _solve_jit(C, a, b, row_mask, sqrt_g, prob, opts)
    alpha, beta = _split(lb.x, prob.m_pad)
    stats_dict = {
        "zero": int(stats[0]),
        "check": int(stats[1]),
        "active": int(stats[2]),
    }
    return OTResult(alpha, beta, -lb.f, lb, scr, int(rounds), stats_dict)


def recover_plan(result: OTResult, C: jnp.ndarray, spec: GroupSpec, reg: GroupSparseReg):
    """Primal plan T* = grad psi(alpha* + beta_j* 1 - c_j) (padded rows incl.)."""
    prob = DualProblem(spec.num_groups, spec.group_size, int(C.shape[1]), reg)
    return plan_from_duals(result.alpha, result.beta, C, prob)
