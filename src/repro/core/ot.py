"""High-level API: group-sparse regularized OT from raw samples.

.. deprecated::
    :func:`solve_groupsparse_ot` is a thin shim over the :mod:`repro.ot`
    façade — build a :class:`repro.ot.Problem` (``Problem.from_samples``)
    and solve it through :func:`repro.ot.compile` / :func:`repro.ot.solve`
    instead.  The shim stays bitwise-identical to the pre-façade
    implementation and will keep working for one release cycle.

Mirrors the paper's experimental pipeline:

  X_S (m, d) labeled source samples, y_S (m,) class labels in {0..L-1},
  X_T (n, d) unlabeled target samples.

  a = 1/m, b = 1/n (uniform marginals), c_ij = ||x_S_i - x_T_j||_2^2.

``solve_groupsparse_ot`` pads/sorts per :mod:`repro.core.groups`, solves the
smooth relaxed dual with the screened solver, and returns duals + plan +
distance in the ORIGINAL row order.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Optional

import numpy as np

from repro.core import groups as G
from repro.core.regularizers import GroupSparseReg, Regularizer
from repro.core.solver import OTResult, SolveOptions


@dataclasses.dataclass
class GroupSparseOTSolution:
    plan: np.ndarray          # (m, n) in original row order
    value: float              # dual objective at convergence
    distance: float           # <T, C>_F transport cost
    result: OTResult
    spec: G.GroupSpec
    perm: np.ndarray          # padded-row -> original-row map (-1 = pad)

    def transport_sources(self, X_S: np.ndarray) -> np.ndarray:
        """Barycentric map of targets: X_T_hat = n * T^T X_S (paper §Prelim)."""
        n = self.plan.shape[1]
        return n * (self.plan.T @ X_S)


def squared_euclidean_cost(X_S: np.ndarray, X_T: np.ndarray) -> np.ndarray:
    """c_ij = ||x_S_i - x_T_j||_2^2, numerically-stable expansion."""
    s2 = np.sum(X_S**2, axis=1)[:, None]
    t2 = np.sum(X_T**2, axis=1)[None, :]
    C = s2 + t2 - 2.0 * (X_S @ X_T.T)
    return np.maximum(C, 0.0)


def solve_groupsparse_ot(
    X_S: np.ndarray,
    y_S: np.ndarray,
    X_T: np.ndarray,
    *,
    gamma: Optional[float] = None,
    rho: Optional[float] = None,
    mu: Optional[float] = None,
    reg: Optional[Regularizer] = None,
    normalize_cost: bool = True,
    opts: SolveOptions = SolveOptions(),
    pad_to: int = 8,
) -> GroupSparseOTSolution:
    """End-to-end solve.  Provide exactly one of rho (paper experiments),
    mu, or a full ``reg`` (any :class:`repro.core.regularizers.Regularizer`
    — pure-l2 or elastic-net group weights ride the same pipeline).
    ``gamma`` (default 1.0) only applies with rho/mu; a full ``reg``
    carries its own gamma, so combining the two is rejected rather than
    silently ignoring one.

    .. deprecated:: use :mod:`repro.ot` (``Problem.from_samples`` +
       ``compile``/``solve``) — this shim delegates there and emits a
       ``DeprecationWarning``."""
    warnings.warn(
        "solve_groupsparse_ot() is deprecated; use repro.ot "
        "(Problem.from_samples + compile/solve) instead",
        DeprecationWarning, stacklevel=2,
    )
    if sum(p is not None for p in (rho, mu, reg)) != 1:
        raise ValueError("provide exactly one of rho / mu / reg")
    if reg is not None:
        if gamma is not None:
            raise ValueError("gamma is part of reg; don't pass both")
    else:
        gamma = 1.0 if gamma is None else gamma
        reg = (
            GroupSparseReg.from_rho(gamma, rho)
            if rho is not None
            else GroupSparseReg(gamma=gamma, mu=mu)
        )

    from repro import ot as facade

    problem = facade.Problem.from_samples(
        X_S, y_S, X_T, reg=reg, normalize_cost=normalize_cost, pad_to=pad_to
    )
    plan = facade.ExecutionPlan.from_solve_options(opts)
    sol = facade.compile(problem, plan).solve()
    return GroupSparseOTSolution(
        plan=sol.plan,
        value=sol.value,
        distance=sol.distance,
        result=sol.result,
        spec=sol.spec,
        perm=sol.perm,
    )


def group_sparsity(sol: GroupSparseOTSolution, y_S: np.ndarray, tol: float = 1e-9) -> float:
    """Fraction of (class, target) blocks that are entirely zero — the
    quantity the group-lasso term drives up (paper Fig. 1's structure)."""
    labels = np.asarray(y_S)
    L = labels.max() + 1
    zero_blocks = 0
    for l in range(L):
        rows = sol.plan[labels == l]
        zero_blocks += int(np.sum(np.max(np.abs(rows), axis=0) <= tol))
    return zero_blocks / float(L * sol.plan.shape[1])
