"""Entropic OT (Cuturi 2013) — the baseline the paper compares against.

Implemented in log-space (stabilized; Schmitzer 2019) because the paper
explicitly notes that the plain Sinkhorn iteration was numerically unstable
across most of their hyperparameter grid.  Pure JAX, jit/shard-friendly.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp


class SinkhornResult(NamedTuple):
    f: jnp.ndarray            # (m,) dual potential
    g: jnp.ndarray            # (n,) dual potential
    plan: jnp.ndarray         # (m, n)
    n_iters: jnp.ndarray
    err: jnp.ndarray          # final marginal violation (L1)


@functools.partial(jax.jit, static_argnames=("max_iters",))
def sinkhorn_log(
    C: jnp.ndarray,
    a: jnp.ndarray,
    b: jnp.ndarray,
    eps: float = 1e-2,
    max_iters: int = 2000,
    tol: float = 1e-8,
) -> SinkhornResult:
    """Log-domain Sinkhorn for  min <T,C> + eps * KL(T | a b^T)."""
    loga = jnp.log(jnp.clip(a, 1e-38))
    logb = jnp.log(jnp.clip(b, 1e-38))

    def body(carry):
        f, g, it, err = carry
        # f-update: f_i = -eps logsumexp_j ((g_j - C_ij)/eps) + eps log a_i
        Mf = (g[None, :] - C) / eps
        f = eps * (loga - jax.scipy.special.logsumexp(Mf, axis=1))
        Mg = (f[:, None] - C) / eps
        g = eps * (logb - jax.scipy.special.logsumexp(Mg, axis=0))
        # marginal error of the implied plan
        logT = (f[:, None] + g[None, :] - C) / eps
        row = jnp.exp(jax.scipy.special.logsumexp(logT, axis=1))
        err = jnp.sum(jnp.abs(row - a))
        return f, g, it + 1, err

    def cond(carry):
        _, _, it, err = carry
        return jnp.logical_and(it < max_iters, err > tol)

    f0 = jnp.zeros_like(a)
    g0 = jnp.zeros_like(b)
    f, g, it, err = jax.lax.while_loop(
        cond, body, (f0, g0, jnp.zeros((), jnp.int32), jnp.asarray(jnp.inf))
    )
    plan = jnp.exp((f[:, None] + g[None, :] - C) / eps)
    return SinkhornResult(f=f, g=g, plan=plan, n_iters=it, err=err)
