"""Pure-JAX L-BFGS (two-loop recursion, backtracking Armijo line search).

No optax / jaxopt in this environment, and the solver must (a) live on
device, (b) shard under shard_map, and (c) expose per-iteration hooks for the
paper's snapshot/screening schedule.  So we implement L-BFGS directly with
``jax.lax``-native control flow and fixed-size circular history buffers.

Conventions: we MINIMIZE ``fun`` (the OT dual is maximized by passing its
negation).  Parameters are a flat fp32 vector; the OT solver concatenates
(alpha, beta).

The implementation intentionally mirrors the reference structure of
Liu & Nocedal (1989): history size ``h``, gamma-scaled initial Hessian,
curvature-pair rejection when s^T y <= eps * ||s|| ||y||.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class LbfgsState(NamedTuple):
    x: jnp.ndarray            # (d,) current point
    f: jnp.ndarray            # scalar current value
    g: jnp.ndarray            # (d,) current gradient
    S: jnp.ndarray            # (h, d) s-history (x_{k+1} - x_k)
    Y: jnp.ndarray            # (h, d) y-history (g_{k+1} - g_k)
    rho: jnp.ndarray          # (h,) 1 / s^T y (0 for unused slots)
    head: jnp.ndarray         # int32 next write slot
    count: jnp.ndarray        # int32 number of valid pairs (<= h)
    iter: jnp.ndarray         # int32 iteration counter
    n_evals: jnp.ndarray      # int32 value_and_grad call counter
    converged: jnp.ndarray    # bool
    failed: jnp.ndarray       # bool (line search failure)


@dataclasses.dataclass(frozen=True)
class LbfgsOptions:
    history: int = 10
    max_iters: int = 500
    gtol: float = 1e-6          # ||g||_inf convergence
    ftol: float = 1e-10         # relative objective-change convergence
    c1: float = 1e-4            # sufficient-decrease (Wolfe 1)
    c2: float = 0.9             # curvature (Wolfe 2)
    max_linesearch: int = 25    # bracket + zoom evaluation budget
    init_step: float = 1.0


def _two_loop(g, S, Y, rho, head, count, h):
    """Two-loop recursion: r = H_k g with circular history."""
    # iterate from newest (head-1) to oldest
    def bwd(i, carry):
        q, a = carry
        idx = (head - 1 - i) % h
        valid = i < count
        ai = jnp.where(valid, rho[idx] * jnp.dot(S[idx], q), 0.0)
        q = q - ai * Y[idx]
        a = a.at[idx].set(ai)
        return (q, a)

    q, a = jax.lax.fori_loop(0, h, bwd, (g, jnp.zeros((h,), g.dtype)))

    # gamma scaling from the newest pair
    newest = (head - 1) % h
    sy = jnp.where(count > 0, 1.0 / jnp.maximum(rho[newest], 1e-30), 1.0)
    yy = jnp.where(count > 0, jnp.dot(Y[newest], Y[newest]), 1.0)
    gamma = jnp.where(count > 0, sy / jnp.maximum(yy, 1e-30), 1.0)
    r = gamma * q

    def fwd(i, r):
        idx = (head - count + i) % h     # oldest to newest
        valid = i < count
        bi = jnp.where(valid, rho[idx] * jnp.dot(Y[idx], r), 0.0)
        return r + jnp.where(valid, (a[idx] - bi), 0.0) * S[idx]

    return jax.lax.fori_loop(0, h, fwd, r)


def init_state(
    x0: jnp.ndarray,
    value_and_grad: Callable[[jnp.ndarray], Tuple[jnp.ndarray, jnp.ndarray]],
    opts: LbfgsOptions,
) -> LbfgsState:
    f0, g0 = value_and_grad(x0)
    h, d = opts.history, x0.shape[0]
    z = jnp.zeros
    return LbfgsState(
        x=x0, f=f0, g=g0,
        S=z((h, d), x0.dtype), Y=z((h, d), x0.dtype), rho=z((h,), x0.dtype),
        head=jnp.zeros((), jnp.int32), count=jnp.zeros((), jnp.int32),
        iter=jnp.zeros((), jnp.int32), n_evals=jnp.ones((), jnp.int32),
        converged=jnp.zeros((), bool), failed=jnp.zeros((), bool),
    )


def _wolfe_linesearch(value_and_grad, x, f0, g0, d, opts: LbfgsOptions):
    """Strong-Wolfe line search (Nocedal & Wright Alg. 3.5/3.6).

    Single while_loop state machine: phase 0 = bracketing (grow t), phase 1 =
    zoom (bisect the bracket).  Returns (t, f, g, n_evals, fail).
    """
    dg0 = jnp.dot(d, g0)
    c1, c2 = opts.c1, opts.c2

    # carry: (phase, lo, f_lo, dg_lo, hi, t, f_t, g_t, dg_t, prev_t, f_prev,
    #         done, n_evals, it)
    def phi(t):
        f, g = value_and_grad(x + t * d)
        return f, g, jnp.dot(d, g)

    t0 = jnp.asarray(opts.init_step, x.dtype)
    f1, g1, dg1 = phi(t0)

    def cond(c):
        return jnp.logical_and(~c["done"], c["it"] < opts.max_linesearch)

    def body(c):
        t, f_t, g_t, dg_t = c["t"], c["f_t"], c["g_t"], c["dg_t"]
        armijo = f_t <= f0 + c1 * t * dg0
        higher = jnp.logical_or(~armijo, jnp.logical_and(c["it"] > 0, f_t >= c["f_prev"]))
        curv = jnp.abs(dg_t) <= -c2 * dg0

        def bracketing(c):
            # case 1: violation -> zoom(prev, t)
            def to_zoom_hi(c):
                return dict(c, phase=1, lo=c["prev_t"], f_lo=c["f_prev"],
                            hi=t)
            # case 2: strong Wolfe satisfied -> done
            def to_done(c):
                return dict(c, done=jnp.asarray(True))
            # case 3: positive slope -> zoom(t, prev)
            def to_zoom_swap(c):
                return dict(c, phase=1, lo=t, f_lo=f_t, hi=c["prev_t"])
            # case 4: grow step
            def grow(c):
                nt = t * 2.0
                nf, ng, ndg = phi(nt)
                return dict(c, prev_t=t, f_prev=f_t, t=nt, f_t=nf, g_t=ng,
                            dg_t=ndg, n_evals=c["n_evals"] + 1)

            c = jax.lax.cond(
                higher, to_zoom_hi,
                lambda c: jax.lax.cond(
                    curv, to_done,
                    lambda c: jax.lax.cond(dg_t >= 0, to_zoom_swap, grow, c),
                    c),
                c)
            # on entering zoom, evaluate the midpoint
            def eval_mid(c):
                mt = 0.5 * (c["lo"] + c["hi"])
                mf, mg, mdg = phi(mt)
                return dict(c, t=mt, f_t=mf, g_t=mg, dg_t=mdg,
                            n_evals=c["n_evals"] + 1)
            entered_zoom = jnp.logical_and(c["phase"] == 1, ~c["done"])
            return jax.lax.cond(entered_zoom, eval_mid, lambda c: c, c)

        def zooming(c):
            def shrink_hi(c):
                return dict(c, hi=t)
            def update_lo(c):
                def swap(c):
                    return dict(c, hi=c["lo"], lo=t, f_lo=f_t)
                def keep(c):
                    return dict(c, lo=t, f_lo=f_t)
                return jax.lax.cond(dg_t * (c["hi"] - c["lo"]) >= 0, swap, keep, c)

            c = jax.lax.cond(
                jnp.logical_or(~armijo, f_t >= c["f_lo"]), shrink_hi,
                lambda c: jax.lax.cond(curv, lambda c: dict(c, done=jnp.asarray(True)),
                                       update_lo, c),
                c)
            def eval_mid(c):
                mt = 0.5 * (c["lo"] + c["hi"])
                mf, mg, mdg = phi(mt)
                return dict(c, t=mt, f_t=mf, g_t=mg, dg_t=mdg,
                            n_evals=c["n_evals"] + 1)
            return jax.lax.cond(~c["done"], eval_mid, lambda c: c, c)

        c = jax.lax.cond(c["phase"] == 0, bracketing, zooming, c)
        return dict(c, it=c["it"] + 1)

    carry = {
        "phase": jnp.asarray(0),
        "lo": jnp.zeros((), x.dtype), "f_lo": f0, "hi": jnp.zeros((), x.dtype),
        "t": t0, "f_t": f1, "g_t": g1, "dg_t": dg1,
        "prev_t": jnp.zeros((), x.dtype), "f_prev": f0,
        "done": jnp.asarray(False), "n_evals": jnp.asarray(1, jnp.int32),
        "it": jnp.asarray(0, jnp.int32),
    }
    c = jax.lax.while_loop(cond, body, carry)
    # if the budget ran out, fall back to the best Armijo point seen (t or lo)
    armijo_ok = c["f_t"] <= f0 + c1 * c["t"] * dg0
    fail = jnp.logical_and(~c["done"], ~armijo_ok)
    return c["t"], c["f_t"], c["g_t"], c["n_evals"], fail


def step(
    state: LbfgsState,
    value_and_grad: Callable[[jnp.ndarray], Tuple[jnp.ndarray, jnp.ndarray]],
    opts: LbfgsOptions,
) -> LbfgsState:
    """One L-BFGS iteration (direction + strong-Wolfe line search)."""
    h = opts.history
    d = _two_loop(state.g, state.S, state.Y, state.rho, state.head, state.count, h)
    d = -d
    dg = jnp.dot(d, state.g)
    # fall back to steepest descent if not a descent direction
    bad = dg >= 0.0
    d = jnp.where(bad, -state.g, d)
    dg = jnp.where(bad, -jnp.dot(state.g, state.g), dg)

    t, f_new, g_new, ls_evals, ls_fail = _wolfe_linesearch(
        value_and_grad, state.x, state.f, state.g, d, opts
    )
    x_new = state.x + t * d
    n_evals = state.n_evals + ls_evals

    s = x_new - state.x
    y = g_new - state.g
    sy = jnp.dot(s, y)
    good_pair = sy > 1e-10 * jnp.linalg.norm(s) * jnp.linalg.norm(y)

    S = jnp.where(good_pair, state.S.at[state.head].set(s), state.S)
    Y = jnp.where(good_pair, state.Y.at[state.head].set(y), state.Y)
    rho = jnp.where(
        good_pair, state.rho.at[state.head].set(1.0 / jnp.maximum(sy, 1e-30)),
        state.rho,
    )
    head = jnp.where(good_pair, (state.head + 1) % h, state.head)
    count = jnp.where(good_pair, jnp.minimum(state.count + 1, h), state.count)

    gnorm = jnp.max(jnp.abs(g_new))
    frel = jnp.abs(f_new - state.f) / jnp.maximum(jnp.abs(state.f), 1.0)
    converged = jnp.logical_or(gnorm <= opts.gtol, frel <= opts.ftol)

    # on line-search failure keep the old point but flag failure
    keep = ls_fail
    return LbfgsState(
        x=jnp.where(keep, state.x, x_new),
        f=jnp.where(keep, state.f, f_new),
        g=jnp.where(keep, state.g, g_new),
        S=S, Y=Y, rho=rho, head=head, count=count,
        iter=state.iter + 1,
        n_evals=n_evals,
        converged=jnp.logical_or(state.converged, converged),
        failed=jnp.logical_or(state.failed, ls_fail),
    )


def run(
    value_and_grad: Callable[[jnp.ndarray], Tuple[jnp.ndarray, jnp.ndarray]],
    x0: jnp.ndarray,
    opts: LbfgsOptions = LbfgsOptions(),
) -> LbfgsState:
    """Run L-BFGS to convergence (single jit-able while_loop)."""
    state = init_state(x0, value_and_grad, opts)

    def cond(s):
        return jnp.logical_and(
            s.iter < opts.max_iters,
            jnp.logical_and(~s.converged, ~s.failed),
        )

    return jax.lax.while_loop(cond, lambda s: step(s, value_and_grad, opts), state)


def run_segment(
    value_and_grad,
    state: LbfgsState,
    num_steps: int,
    opts: LbfgsOptions,
) -> LbfgsState:
    """Run exactly ``num_steps`` iterations from an existing state.

    Used by the paper's Algorithm 1: the solver advances ``r`` iterations
    between snapshot/active-set refreshes (history is preserved across
    segments, matching 'apply a solver ... for r iterations').
    Stops early only on convergence/failure (iterations become no-ops).
    """

    def body(_, s):
        do = jnp.logical_and(~s.converged, ~s.failed)

        def advance(s):
            return step(s, value_and_grad, opts)

        return jax.lax.cond(do, advance, lambda s: s, s)

    return jax.lax.fori_loop(0, num_steps, body, state)
