"""Pure-JAX L-BFGS (two-loop recursion, strong-Wolfe line search), batched.

No optax / jaxopt in this environment, and the solver must (a) live on
device, (b) shard under shard_map, (c) expose per-iteration hooks for the
paper's snapshot/screening schedule, and (d) advance a BATCH of independent
problems in lock-step (the dual is separable across problems, so batching
is just a leading axis).  So we implement L-BFGS directly with
``jax.lax``-native control flow and fixed-size circular history buffers.

The implementation is written once, batched: every array in
:class:`LbfgsState` carries a leading batch axis ``B`` and every scalar of
the textbook algorithm (objective, step size, line-search phase, ...)
becomes a ``(B,)`` vector.  Control flow that branches per problem in the
sequential algorithm (line-search bracketing/zoom, curvature-pair
rejection, convergence freezing) is expressed with ``jnp.where`` masks, so
converged problems freeze in place and never break the batch.  The solo
API (:func:`init_state`, :func:`step`, :func:`run`, :func:`run_segment`)
wraps the batched core with ``B = 1`` — a single solve therefore executes
the *same* op sequence as any member of a batch, which is what makes
batched and solo solves bitwise-identical per problem (asserted by
tests/test_solve_batch.py).

Conventions: we MINIMIZE ``fun`` (the OT dual is maximized by passing its
negation).  Parameters are flat fp32 vectors; the OT solver concatenates
(alpha, beta).  A batched ``value_and_grad`` maps ``(B, d) -> ((B,), (B, d))``.

The algorithm intentionally mirrors the reference structure of
Liu & Nocedal (1989): history size ``h``, gamma-scaled initial Hessian,
curvature-pair rejection when s^T y <= eps * ||s|| ||y||.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class LbfgsState(NamedTuple):
    x: jnp.ndarray            # (B, d) current point
    f: jnp.ndarray            # (B,) current value
    g: jnp.ndarray            # (B, d) current gradient
    S: jnp.ndarray            # (B, h, d) s-history (x_{k+1} - x_k)
    Y: jnp.ndarray            # (B, h, d) y-history (g_{k+1} - g_k)
    rho: jnp.ndarray          # (B, h) 1 / s^T y (0 for unused slots)
    head: jnp.ndarray         # (B,) int32 next write slot
    count: jnp.ndarray        # (B,) int32 number of valid pairs (<= h)
    iter: jnp.ndarray         # (B,) int32 iteration counter
    n_evals: jnp.ndarray      # (B,) int32 value_and_grad call counter
    converged: jnp.ndarray    # (B,) bool
    failed: jnp.ndarray       # (B,) bool (line search failure)


@dataclasses.dataclass(frozen=True)
class LbfgsOptions:
    history: int = 10
    max_iters: int = 500
    gtol: float = 1e-6          # ||g||_inf convergence
    ftol: float = 1e-10         # relative objective-change convergence
    c1: float = 1e-4            # sufficient-decrease (Wolfe 1)
    c2: float = 0.9             # curvature (Wolfe 2)
    max_linesearch: int = 25    # bracket + zoom evaluation budget
    init_step: float = 1.0


def where_state(mask: jnp.ndarray, new, old):
    """Per-problem select over a pytree of (B, ...) leaves.

    ``mask`` is (B,) bool; leaves keep ``new`` where True, ``old`` where
    False.  This is the single freezing primitive of the batched solver:
    converged problems are carried through every computation and their
    updates dropped here.
    """
    def sel(n, o):
        m = mask.reshape(mask.shape + (1,) * (n.ndim - 1))
        return jnp.where(m, n, o)

    return jax.tree_util.tree_map(sel, new, old)


def state_pspecs(spec) -> "LbfgsState":
    """Flatten the batched state for ``shard_map``: one spec per leaf.

    Every leaf of :class:`LbfgsState` carries a leading problem axis ``B``
    (including the scalar-per-problem counters — they are ``(B,)`` vectors,
    never true scalars, precisely so the state shards cleanly).  This
    returns an ``LbfgsState`` whose leaves are all ``spec`` — usable
    directly as a shard_map in/out spec for the solver state.

    Parameters
    ----------
    spec : jax.sharding.PartitionSpec
        Leading-axis spec, e.g. ``P("batch")``.

    Returns
    -------
    LbfgsState
        A state-shaped pytree of partition specs.
    """
    return LbfgsState(*([spec] * len(LbfgsState._fields)))


def _vdot(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Batched inner product (B, d), (B, d) -> (B,).

    One reduction form everywhere (``sum(a*b, -1)``) so solo (B=1) and
    batched runs reduce in the same order — a plain ``dot`` lowers to a
    different XLA op with different summation order.
    """
    return jnp.sum(a * b, axis=-1)


def _take(H: jnp.ndarray, idx: jnp.ndarray) -> jnp.ndarray:
    """H (B, h, ...) gathered at per-problem slot idx (B,) -> (B, ...)."""
    return jnp.take_along_axis(
        H, idx.reshape(idx.shape + (1,) * (H.ndim - 1)), axis=1
    ).squeeze(1)


def _two_loop(g, S, Y, rho, head, count, h):
    """Two-loop recursion: r = H_k g with per-problem circular history."""
    B = g.shape[0]
    barange = jnp.arange(B)

    # iterate from newest (head-1) to oldest
    def bwd(i, carry):
        q, a = carry
        idx = (head - 1 - i) % h                      # (B,)
        valid = i < count
        Si, Yi = _take(S, idx), _take(Y, idx)
        ri = rho[barange, idx]
        ai = jnp.where(valid, ri * _vdot(Si, q), 0.0)
        q = q - ai[:, None] * Yi
        a = a.at[barange, idx].set(jnp.where(valid, ai, a[barange, idx]))
        return (q, a)

    q, a = jax.lax.fori_loop(0, h, bwd, (g, jnp.zeros((B, h), g.dtype)))

    # gamma scaling from the newest pair
    newest = (head - 1) % h
    rn = rho[barange, newest]
    has = count > 0
    sy = jnp.where(has, 1.0 / jnp.maximum(rn, 1e-30), 1.0)
    Yn = _take(Y, newest)
    yy = jnp.where(has, _vdot(Yn, Yn), 1.0)
    gamma = jnp.where(has, sy / jnp.maximum(yy, 1e-30), 1.0)
    r = gamma[:, None] * q

    def fwd(i, r):
        idx = (head - count + i) % h                  # oldest to newest
        valid = i < count
        Si, Yi = _take(S, idx), _take(Y, idx)
        bi = jnp.where(valid, rho[barange, idx] * _vdot(Yi, r), 0.0)
        coef = jnp.where(valid, a[barange, idx] - bi, 0.0)
        return r + coef[:, None] * Si

    return jax.lax.fori_loop(0, h, fwd, r)


def init_state_batched(
    x0: jnp.ndarray,
    value_and_grad: Callable[[jnp.ndarray], Tuple[jnp.ndarray, jnp.ndarray]],
    opts: LbfgsOptions,
) -> LbfgsState:
    """Initial state for a (B, d) batch; one batched evaluation."""
    f0, g0 = value_and_grad(x0)
    B, d = x0.shape
    h = opts.history
    z = jnp.zeros
    return LbfgsState(
        x=x0, f=f0, g=g0,
        S=z((B, h, d), x0.dtype), Y=z((B, h, d), x0.dtype),
        rho=z((B, h), x0.dtype),
        head=z((B,), jnp.int32), count=z((B,), jnp.int32),
        iter=z((B,), jnp.int32), n_evals=jnp.ones((B,), jnp.int32),
        # a non-finite objective at the init point means the inputs are
        # poisoned (NaN/inf cost or marginal): flag failure immediately so
        # the problem never runs a round ("never finished" is observable
        # as failed with zero rounds); finite problems are unaffected
        converged=z((B,), bool), failed=~jnp.isfinite(f0),
    )


def _wolfe_linesearch(value_and_grad, x, f0, g0, d, opts: LbfgsOptions):
    """Strong-Wolfe line search (Nocedal & Wright Alg. 3.5/3.6), batched.

    The sequential algorithm is a per-problem state machine (phase 0 =
    bracketing, phase 1 = zoom).  Here every problem advances through its
    own machine in lock-step: each loop iteration evaluates phi once at a
    per-problem point (the grow point or the bracket midpoint) and applies
    the bracketing/zoom case analysis as masked updates.  Problems whose
    search has terminated stop updating (and stop counting evaluations)
    but still ride along in the batched phi evaluation.

    Returns (t, f, g, n_evals, fail), each batched.
    """
    dg0 = _vdot(d, g0)                                 # (B,)
    c1, c2 = opts.c1, opts.c2
    B = x.shape[0]

    def phi(t):
        f, g = value_and_grad(x + t[:, None] * d)
        return f, g, _vdot(d, g)

    t0 = jnp.full((B,), opts.init_step, x.dtype)
    f1, g1, dg1 = phi(t0)

    def cond(c):
        return jnp.logical_and(
            jnp.any(~c["done"]), c["it"] < opts.max_linesearch
        )

    def body(c):
        run = ~c["done"]                               # (B,) still searching
        t, f_t, dg_t = c["t"], c["f_t"], c["dg_t"]
        armijo = f_t <= f0 + c1 * t * dg0
        higher = jnp.logical_or(
            ~armijo, jnp.logical_and(c["it"] > 0, f_t >= c["f_prev"])
        )
        curv = jnp.abs(dg_t) <= -c2 * dg0

        br = c["phase"] == 0
        # bracketing cases (mutually exclusive, in the sequential order)
        b_zoom_hi = br & higher                       # zoom(prev, t)
        b_done = br & ~higher & curv                  # strong Wolfe holds
        b_zoom_sw = br & ~higher & ~curv & (dg_t >= 0)  # zoom(t, prev)
        b_grow = br & ~higher & ~curv & (dg_t < 0)    # grow step
        # zoom cases
        zm = ~br
        z_shrink = zm & (jnp.logical_or(~armijo, f_t >= c["f_lo"]))
        z_done = zm & ~z_shrink & curv
        z_update = zm & ~z_shrink & ~curv             # move lo to t
        z_swap = z_update & (dg_t * (c["hi"] - c["lo"]) >= 0)

        take_lo = b_zoom_sw | z_update
        lo = jnp.where(b_zoom_hi, c["prev_t"], jnp.where(take_lo, t, c["lo"]))
        f_lo = jnp.where(
            b_zoom_hi, c["f_prev"], jnp.where(take_lo, f_t, c["f_lo"])
        )
        hi = jnp.where(
            b_zoom_hi | z_shrink, t,
            jnp.where(b_zoom_sw, c["prev_t"],
                      jnp.where(z_swap, c["lo"], c["hi"])),
        )
        phase = jnp.where(b_zoom_hi | b_zoom_sw, 1, c["phase"])
        done = c["done"] | b_done | z_done
        prev_t = jnp.where(b_grow, t, c["prev_t"])
        f_prev = jnp.where(b_grow, f_t, c["f_prev"])

        # one phi evaluation per iteration, at each problem's next point:
        # the doubled step when growing, the (new) bracket midpoint otherwise
        evald = run & ~done
        nt = jnp.where(b_grow, t * 2.0, 0.5 * (lo + hi))
        t_eval = jnp.where(evald, nt, c["t"])
        f_n, g_n, dg_n = phi(t_eval)

        out = dict(
            phase=jnp.where(run, phase, c["phase"]),
            lo=jnp.where(run, lo, c["lo"]),
            f_lo=jnp.where(run, f_lo, c["f_lo"]),
            hi=jnp.where(run, hi, c["hi"]),
            t=jnp.where(evald, t_eval, c["t"]),
            f_t=jnp.where(evald, f_n, c["f_t"]),
            g_t=jnp.where(evald[:, None], g_n, c["g_t"]),
            dg_t=jnp.where(evald, dg_n, c["dg_t"]),
            prev_t=jnp.where(run, prev_t, c["prev_t"]),
            f_prev=jnp.where(run, f_prev, c["f_prev"]),
            done=done,
            n_evals=c["n_evals"] + evald.astype(jnp.int32),
            it=c["it"] + 1,
        )
        return out

    carry = {
        "phase": jnp.zeros((B,), jnp.int32),
        "lo": jnp.zeros((B,), x.dtype), "f_lo": f0,
        "hi": jnp.zeros((B,), x.dtype),
        "t": t0, "f_t": f1, "g_t": g1, "dg_t": dg1,
        "prev_t": jnp.zeros((B,), x.dtype), "f_prev": f0,
        "done": jnp.zeros((B,), bool),
        "n_evals": jnp.ones((B,), jnp.int32),
        "it": jnp.asarray(0, jnp.int32),
    }
    c = jax.lax.while_loop(cond, body, carry)
    # if the budget ran out, fall back to the best Armijo point seen (t or lo)
    armijo_ok = c["f_t"] <= f0 + c1 * c["t"] * dg0
    fail = jnp.logical_and(~c["done"], ~armijo_ok)
    return c["t"], c["f_t"], c["g_t"], c["n_evals"], fail


def step_batched(
    state: LbfgsState,
    value_and_grad: Callable[[jnp.ndarray], Tuple[jnp.ndarray, jnp.ndarray]],
    opts: LbfgsOptions,
) -> LbfgsState:
    """One batched L-BFGS iteration (direction + strong-Wolfe line search).

    Advances every problem; callers freeze finished problems via
    :func:`where_state` (see :func:`run_segment_batched`).
    """
    h = opts.history
    B = state.x.shape[0]
    barange = jnp.arange(B)
    d = _two_loop(
        state.g, state.S, state.Y, state.rho, state.head, state.count, h
    )
    d = -d
    dg = _vdot(d, state.g)
    # fall back to steepest descent if not a descent direction
    bad = dg >= 0.0
    d = jnp.where(bad[:, None], -state.g, d)
    dg = jnp.where(bad, -_vdot(state.g, state.g), dg)

    t, f_new, g_new, ls_evals, ls_fail = _wolfe_linesearch(
        value_and_grad, state.x, state.f, state.g, d, opts
    )
    x_new = state.x + t[:, None] * d
    n_evals = state.n_evals + ls_evals

    s = x_new - state.x
    y = g_new - state.g
    sy = _vdot(s, y)
    snorm = jnp.sqrt(_vdot(s, s))
    ynorm = jnp.sqrt(_vdot(y, y))
    good_pair = sy > 1e-10 * snorm * ynorm

    S = jnp.where(
        good_pair[:, None, None], state.S.at[barange, state.head].set(s),
        state.S,
    )
    Y = jnp.where(
        good_pair[:, None, None], state.Y.at[barange, state.head].set(y),
        state.Y,
    )
    rho = jnp.where(
        good_pair[:, None],
        state.rho.at[barange, state.head].set(1.0 / jnp.maximum(sy, 1e-30)),
        state.rho,
    )
    head = jnp.where(good_pair, (state.head + 1) % h, state.head)
    count = jnp.where(good_pair, jnp.minimum(state.count + 1, h), state.count)

    gnorm = jnp.max(jnp.abs(g_new), axis=-1)
    frel = jnp.abs(f_new - state.f) / jnp.maximum(jnp.abs(state.f), 1.0)
    converged = jnp.logical_or(gnorm <= opts.gtol, frel <= opts.ftol)

    # fail fast on a non-finite objective (poisoned inputs): the NaN can
    # never satisfy Wolfe or convergence tests, so without this flag the
    # problem would burn its full line-search budget every iteration and
    # still end up failed.  For finite objectives this is a no-op, so
    # healthy solves stay bitwise-identical.
    nonfinite = ~jnp.isfinite(f_new)
    converged = jnp.logical_and(converged, ~nonfinite)

    # on line-search failure (or a non-finite objective) keep the old
    # point but flag failure
    keep = jnp.logical_or(ls_fail, nonfinite)
    return LbfgsState(
        x=jnp.where(keep[:, None], state.x, x_new),
        f=jnp.where(keep, state.f, f_new),
        g=jnp.where(keep[:, None], state.g, g_new),
        S=S, Y=Y, rho=rho, head=head, count=count,
        iter=state.iter + 1,
        n_evals=n_evals,
        converged=jnp.logical_or(state.converged, converged),
        failed=jnp.logical_or(state.failed, keep),
    )


def run_segment_batched(
    value_and_grad,
    state: LbfgsState,
    num_steps: int,
    opts: LbfgsOptions,
) -> LbfgsState:
    """Run exactly ``num_steps`` batched iterations from an existing state.

    Per-problem convergence masking: problems that have converged (or whose
    line search failed) are carried through the computation and their
    updates dropped, so the batch never needs an early exit.  The step is
    skipped entirely only when EVERY problem is finished.
    """

    def body(_, s):
        do = jnp.logical_and(~s.converged, ~s.failed)

        def advance(s):
            return where_state(do, step_batched(s, value_and_grad, opts), s)

        return jax.lax.cond(jnp.any(do), advance, lambda s: s, s)

    return jax.lax.fori_loop(0, num_steps, body, state)


def run_batched(
    value_and_grad,
    x0: jnp.ndarray,
    opts: LbfgsOptions = LbfgsOptions(),
) -> LbfgsState:
    """Run batched L-BFGS to all-problem convergence (one while_loop)."""
    state = init_state_batched(x0, value_and_grad, opts)

    def active(s):
        alive = jnp.logical_and(~s.converged, ~s.failed)
        return jnp.logical_and(s.iter < opts.max_iters, alive)

    def cond(s):
        return jnp.any(active(s))

    def body(s):
        # the iteration cap is per problem: a capped-out problem freezes
        # even while batch-mates keep iterating (same stop as its solo run)
        return where_state(active(s), step_batched(s, value_and_grad, opts), s)

    return jax.lax.while_loop(cond, body, state)


# -- solo API: the B = 1 slice of the batched core ---------------------------

def _expand(state: LbfgsState) -> LbfgsState:
    return jax.tree_util.tree_map(lambda v: v[None], state)


def _squeeze(state: LbfgsState) -> LbfgsState:
    return jax.tree_util.tree_map(lambda v: v[0], state)


def _batch_vag(value_and_grad):
    def vag(x):
        f, g = value_and_grad(x[0])
        return f[None], g[None]

    return vag


def init_state(
    x0: jnp.ndarray,
    value_and_grad: Callable[[jnp.ndarray], Tuple[jnp.ndarray, jnp.ndarray]],
    opts: LbfgsOptions,
) -> LbfgsState:
    """Single-problem initial state (unbatched leaves)."""
    return _squeeze(
        init_state_batched(x0[None], _batch_vag(value_and_grad), opts)
    )


def step(
    state: LbfgsState,
    value_and_grad: Callable[[jnp.ndarray], Tuple[jnp.ndarray, jnp.ndarray]],
    opts: LbfgsOptions,
) -> LbfgsState:
    """One single-problem L-BFGS iteration."""
    return _squeeze(
        step_batched(_expand(state), _batch_vag(value_and_grad), opts)
    )


def run(
    value_and_grad,
    x0: jnp.ndarray,
    opts: LbfgsOptions = LbfgsOptions(),
) -> LbfgsState:
    """Run single-problem L-BFGS to convergence (jit-able)."""
    return _squeeze(run_batched(_batch_vag(value_and_grad), x0[None], opts))


def run_segment(
    value_and_grad,
    state: LbfgsState,
    num_steps: int,
    opts: LbfgsOptions,
) -> LbfgsState:
    """Run exactly ``num_steps`` single-problem iterations.

    Used by the paper's Algorithm 1: the solver advances ``r`` iterations
    between snapshot/active-set refreshes (history is preserved across
    segments, matching 'apply a solver ... for r iterations').
    Stops early only on convergence/failure (iterations become no-ops).
    """
    return _squeeze(
        run_segment_batched(
            _batch_vag(value_and_grad), _expand(state), num_steps, opts
        )
    )
