"""Minibatch stochastic dual ascent for the group-sparse OT dual.

The SON-regularization paper (Panahi et al., arXiv 1903.03850) observes
that clustering/OT duals of the form

    max_{alpha, beta}  alpha^T a + beta^T b - sum_j psi(alpha + beta_j - c_j)

are *column separable*: the coupling term is a plain sum over target
columns j.  A uniformly sampled subset of columns therefore yields

  * an **exact** partial gradient for the sampled ``beta_j`` (each column's
    gradient ``b_j - colsum_j`` touches no other column), and
  * an **unbiased** estimate of the ``alpha`` gradient, by rescaling the
    sampled columns' row-sums by ``n_blocks / k_blocks``.

This module implements that scheme on the repo's padded group layout:
columns are partitioned into contiguous *blocks* of ``block_cols`` and a
without-replacement minibatch of blocks is drawn each step from a per-epoch
seeded permutation, so the whole schedule is deterministic given
``StochasticOptions.seed``.  Blocks — not single columns — are the sampling
unit because a block maps 1:1 onto a kernel column tile: the Pallas
backends run their per-minibatch oracle by marking only the sampled tiles
live in the existing skip-flag grid (``tile_n = block_cols``), so a step
costs O(m * k * block_cols) instead of O(m * n).  The dense/screened
reference backends evaluate the same estimator through
``dual_value_and_grad(..., zero_mask=...)`` — identical sampled column
sets, so every backend optimizes the same stochastic trajectory.

Iterates are Polyak-averaged over the trailing ``avg_fraction`` of epochs
("epoch-averaged duals"), and the returned objective/gradient are an exact
full evaluation at the averaged point, so downstream consumers (Solution,
the Danskin layer) see a true dual value, not a minibatch estimate.

Selected via ``ExecutionPlan(solver='stochastic')``; ``solver='lbfgs'``
remains the exact default.  Notes:

  * screening is *inactive* here — duals move every step, so the
    safe-region certificates of Algorithm 2 never stabilize;
    ``grad_impl='screened'`` runs the dense oracle and ``'fused'`` runs the
    two-launch flag-driven kernels (flags carry the minibatch, not
    screening verdicts).
  * the result is packed into the same ``(lb, scr, rounds, stats)``
    contract as :func:`repro.core.solver._solve_batch_jit`, so the
    Executor's batching, plan recovery and stats plumbing are reused
    unchanged (``rounds`` counts epochs; screening stats are zero).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core import lbfgs, screening
from repro.core import solver as slv
from repro.core.dual import DualProblem, dual_value_and_grad
from repro.core.solver import OTResult, SolveOptions


@dataclasses.dataclass(frozen=True)
class StochasticOptions:
    """Knobs of the minibatch dual-ascent schedule (all static).

    epochs:        full passes over the column blocks (= solver "rounds").
    batch_blocks:  column blocks sampled per step (minibatch size k).
    block_cols:    columns per block; the Pallas oracle runs with
                   ``tile_n = block_cols`` so one block == one column tile.
    step_size:     initial step eta_0.
    decay:         eta_t = eta_0 / (1 + decay * t) with t the global step.
    avg_fraction:  trailing fraction of epochs whose end-of-epoch duals are
                   Polyak-averaged into the returned solution.
    seed:          PRNG seed for the per-epoch block permutations.
    """

    epochs: int = 60
    batch_blocks: int = 2
    block_cols: int = 128
    step_size: float = 0.5
    decay: float = 0.02
    avg_fraction: float = 0.5
    seed: int = 0

    def __post_init__(self):
        for name in ("epochs", "batch_blocks", "block_cols"):
            v = getattr(self, name)
            if not isinstance(v, int) or v < 1:
                raise ValueError(f"{name} must be a positive int, got {v!r}")
        if not (self.step_size > 0.0):
            raise ValueError(f"step_size must be > 0, got {self.step_size!r}")
        if self.decay < 0.0:
            raise ValueError(f"decay must be >= 0, got {self.decay!r}")
        if not (0.0 < self.avg_fraction <= 1.0):
            raise ValueError(
                f"avg_fraction must be in (0, 1], got {self.avg_fraction!r}"
            )
        if not isinstance(self.seed, int):
            raise ValueError(f"seed must be an int, got {self.seed!r}")


def _num_blocks(n: int, block_cols: int) -> Tuple[int, int]:
    """(block width w, number of blocks nt) for n columns."""
    w = min(block_cols, n)
    return w, -(-n // w)


def _prepare(C, prob: DualProblem, opts: SolveOptions, sopts: StochasticOptions):
    """Tile-pad the cost once with ``tile_n = block width`` (kernel paths).

    Mirrors :func:`repro.core.solver._prepare_padded` (including the bf16
    downcast-once contract) but pins the column tile width to the sampling
    block width so flags express the minibatch exactly.
    """
    if opts.grad_impl not in ("pallas", "fused"):
        if opts.precision != "f32":
            raise ValueError(
                "precision='bf16' requires grad_impl='pallas' or 'fused' "
                f"(got grad_impl={opts.grad_impl!r})."
            )
        return None
    from repro.kernels import ops as kops

    w, _ = _num_blocks(prob.n, sopts.block_cols)
    if slv._is_factorized(C):
        fp = kops.prepare_factorized_problem(C, prob, tile_n=w)
        if opts.precision == "bf16":
            fp = dataclasses.replace(
                fp,
                x=fp.x.astype(jnp.bfloat16),
                x_sq=fp.x_sq.astype(jnp.bfloat16),
                y=fp.y.astype(jnp.bfloat16),
                y_sq=fp.y_sq.astype(jnp.bfloat16),
            )
        return fp
    pp = kops.prepare_padded_problem_batched(C, prob, tile_n=w)
    if opts.precision == "bf16":
        pp = dataclasses.replace(pp, Cp=pp.Cp.astype(jnp.bfloat16))
    return pp


def _make_oracle(C, a, b, prob, opts, sopts, padded):
    """Minibatch oracle: (alpha, beta, live (nt,) bool) -> (v, ga, gb).

    Maximization-sign gradients restricted to the live column blocks
    (dead columns contribute exact zeros — the ``zero_mask`` / skip-flag
    contract of Theorem 2 reused for sampling instead of screening).
    """
    w, nt = _num_blocks(prob.n, sopts.block_cols)
    block_id = jnp.arange(prob.n) // w                      # (n,)

    if opts.grad_impl in ("pallas", "fused"):
        from repro.kernels import ops as kops

        B = C.shape[0]
        lt, nt_grid = padded.grid
        assert nt_grid == nt, (nt_grid, nt)
        kernel = (
            kops.dual_value_and_grad_factorized_batched
            if slv._is_factorized(C)
            else kops.dual_value_and_grad_padded_batched
        )

        def oracle(alpha, beta, live):
            flags = jnp.broadcast_to(
                live.astype(jnp.int32)[None, None, :], (B, lt, nt)
            )
            v, ga, gb = kernel(
                alpha, beta, a, b, flags, padded, prob,
                impl=opts.pallas_impl,
            )
            return v, ga, gb

        return oracle, block_id

    def oracle(alpha, beta, live):
        live_cols = live[block_id]                           # (n,)
        zero_mask = jnp.broadcast_to(
            ~live_cols[None, :], (prob.num_groups, prob.n)
        )
        v, (ga, gb) = dual_value_and_grad(
            alpha, beta, C, a, b, prob, zero_mask=zero_mask
        )
        return v, ga, gb

    return oracle, block_id


@functools.partial(jax.jit, static_argnames=("prob", "opts", "sopts"))
def _sgd_solve_batch_jit(C, a, b, row_mask, sqrt_g, prob, opts, sopts):
    """Batched stochastic solve: same output contract as _solve_batch_jit.

    Returns ``(lb, scr, rounds, stats)`` with leading batch axes; ``lb``
    holds the epoch-averaged duals with an exact full-gradient evaluation
    at that point (one extra oracle call), ``rounds`` counts epochs and
    the screening stats are zero (screening is inactive — see module doc).
    ``row_mask`` rides along for signature parity with the exact solver;
    padded rows self-mask through the PAD_COST sentinel.
    """
    del row_mask, sqrt_g
    B = C.shape[0]
    m_pad, n = prob.m_pad, prob.n
    w, nt = _num_blocks(n, sopts.block_cols)
    k = min(sopts.batch_blocks, nt)
    steps_per_epoch = max(nt // k, 1)
    scale = nt / k

    padded = _prepare(C, prob, opts, sopts)
    oracle, block_id = _make_oracle(C, a, b, prob, opts, sopts, padded)

    key = jax.random.PRNGKey(sopts.seed)
    avg_start = min(
        int(round(sopts.epochs * (1.0 - sopts.avg_fraction))),
        sopts.epochs - 1,
    )

    def step_body(s, carry):
        alpha, beta, perm, e = carry
        t = e * steps_per_epoch + s
        idx = jax.lax.dynamic_slice(perm, (s * k,), (k,))
        live = jnp.zeros((nt,), bool).at[idx].set(True)
        _, ga, gb = oracle(alpha, beta, live)
        eta = sopts.step_size / (1.0 + sopts.decay * t)
        # unbiased full alpha-gradient estimate: a - scale * rowsum_live
        alpha = alpha + eta * (a - scale * (a - ga))
        # exact partial gradient for the sampled columns only
        beta = beta + eta * jnp.where(live[block_id], gb, 0.0)
        return alpha, beta, perm, e

    def epoch_body(e, carry):
        alpha, beta, acc_a, acc_b, cnt = carry
        perm = jax.random.permutation(jax.random.fold_in(key, e), nt)
        alpha, beta, _, _ = jax.lax.fori_loop(
            0, steps_per_epoch, step_body, (alpha, beta, perm, e)
        )
        take = (e >= avg_start).astype(alpha.dtype)
        return (
            alpha,
            beta,
            acc_a + take * alpha,
            acc_b + take * beta,
            cnt + take,
        )

    alpha = jnp.zeros((B, m_pad), jnp.float32)
    beta = jnp.zeros((B, n), jnp.float32)
    alpha, beta, acc_a, acc_b, cnt = jax.lax.fori_loop(
        0,
        sopts.epochs,
        epoch_body,
        (alpha, beta, jnp.zeros_like(alpha), jnp.zeros_like(beta),
         jnp.zeros((), jnp.float32)),
    )
    denom = jnp.maximum(cnt, 1.0)
    x_bar = jnp.concatenate([acc_a / denom, acc_b / denom], axis=-1)

    all_live = jnp.ones((nt,), bool)

    def vag(x):
        al, be = slv._split(x, m_pad)
        v, ga, gb = oracle(al, be, all_live)
        return -v, -jnp.concatenate([ga, gb], axis=-1)

    lb = lbfgs.init_state_batched(x_bar, vag, opts.lbfgs)
    total_steps = sopts.epochs * steps_per_epoch
    ok = jnp.isfinite(lb.f)
    lb = lb._replace(
        iter=jnp.full((B,), total_steps, jnp.int32),
        converged=ok,
        failed=~ok,
    )
    scr = screening.init_state(
        m_pad, n, prob.num_groups, jnp.float32, batch_shape=(B,)
    )
    rounds = jnp.full((B,), sopts.epochs, jnp.int32)
    stats = jnp.zeros((B, 3), jnp.int32)
    return lb, scr, rounds, stats


@functools.partial(jax.jit, static_argnames=("prob", "opts", "sopts"))
def _sgd_solve_jit(C, a, b, row_mask, sqrt_g, prob, opts, sopts):
    """Single-problem entry point: the B = 1 slice of the batched solver."""
    C1 = jax.tree_util.tree_map(lambda v: v[None], C)
    lb, scr, rounds, stats = _sgd_solve_batch_jit(
        C1, a[None], b[None], row_mask, sqrt_g, prob, opts, sopts
    )
    one = lambda t: jax.tree_util.tree_map(lambda v: v[0], t)  # noqa: E731
    return one(lb), one(scr), rounds[0], stats[0]


def solve_solo(C, a, b, spec, reg, opts, sopts, launch) -> OTResult:
    """Solo stochastic solve with the façade's operand/packing contract.

    The stochastic twin of :func:`repro.core.solver._solve_solo` — same
    operand construction and :class:`OTResult` packing, so
    ``Executor.solve`` treats both solvers interchangeably.
    """
    prob = DualProblem(
        num_groups=spec.num_groups,
        group_size=spec.group_size,
        n=int(C.shape[1]),
        reg=reg,
    )
    row_mask = jnp.asarray(spec.row_mask().reshape(-1))
    sqrt_g = jnp.asarray(spec.sqrt_sizes(), jnp.float32)
    lb, scr, rounds, stats = launch(
        _sgd_solve_jit, C, a, b, row_mask, sqrt_g, prob, opts, sopts
    )
    alpha, beta = slv._split(lb.x, prob.m_pad)
    stats_dict = {
        "zero": int(stats[0]),
        "check": int(stats[1]),
        "active": int(stats[2]),
    }
    return OTResult(alpha, beta, -lb.f, lb, scr, int(rounds), stats_dict)
