"""Group structure for the group-sparse OT regularizer.

The paper indexes source samples by class label; the regularizer treats the
rows of the transportation-plan column ``t_j`` belonging to one class as one
group, ``t_{j[l]}``.  For TPU-friendly static shapes we canonicalize to a
*uniform padded* layout:

  * source samples are sorted by class label,
  * every class is padded up to ``g_pad`` rows (a multiple of the row tile),
  * padded rows carry zero mass (``a = 0``) and +BIG cost so that
    ``f = alpha + beta_j - c`` is very negative there => ``[f]_+ = 0`` =>
    padded rows contribute nothing to group norms, gradients, or the
    objective.  Their dual variable ``alpha`` then has exactly-zero gradient
    and stays at its init (0), so padding is invisible to the optimizer.

The padded view reshapes the ``m_pad``-vector into ``(L, g_pad)`` so every
grouped reduction is one axis reduction — no segment_sum, no raggedness.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax.numpy as jnp
import numpy as np

PAD_COST = 1e9  # cost assigned to padded source rows


@dataclasses.dataclass(frozen=True)
class GroupSpec:
    """Static description of the (padded) group layout.

    Attributes:
      num_groups:   |L|, number of class labels.
      group_size:   g_pad, padded rows per group (uniform).
      sizes:        true (unpadded) size of each group, shape (L,).
      m:            true number of source samples (sum(sizes)).
    """

    num_groups: int
    group_size: int
    sizes: tuple
    m: int

    @property
    def m_pad(self) -> int:
        return self.num_groups * self.group_size

    def row_mask(self) -> np.ndarray:
        """(L, g_pad) bool — True for real rows."""
        idx = np.arange(self.group_size)[None, :]
        return idx < np.asarray(self.sizes)[:, None]

    def sqrt_sizes(self) -> np.ndarray:
        """sqrt(g_l) used in the bounds (Eq. 6/7) — true sizes."""
        return np.sqrt(np.asarray(self.sizes, np.float64)).astype(np.float32)

    def __repr__(self) -> str:
        """Compact geometry summary (docs examples / bug reports).

        Shows the padded layout and how much of it is real mass-carrying
        rows; the per-group sizes tuple is elided past a few entries.
        """
        sizes = self.sizes
        shown = (
            str(tuple(sizes))
            if len(sizes) <= 6
            else f"({', '.join(map(str, sizes[:5]))}, ... x{len(sizes)})"
        )
        fill = self.m / max(self.m_pad, 1)
        return (
            f"GroupSpec(L={self.num_groups}, g_pad={self.group_size}, "
            f"m={self.m}/{self.m_pad} rows real ({fill:.1%}), sizes={shown})"
        )


def spec_from_labels(labels: Sequence[int], *, pad_to: int = 8) -> GroupSpec:
    """Build a GroupSpec from integer class labels (any order).

    ``pad_to`` rounds the max group size up to a multiple (tile alignment).
    """
    labels = np.asarray(labels)
    uniq, counts = np.unique(labels, return_counts=True)
    gmax = int(counts.max())
    g_pad = int(-(-gmax // pad_to) * pad_to)
    return GroupSpec(
        num_groups=int(uniq.size),
        group_size=g_pad,
        sizes=tuple(int(c) for c in counts),
        m=int(labels.size),
    )


def pad_sources(X: np.ndarray, labels: np.ndarray, spec: GroupSpec):
    """Sort rows by label and pad each class to g_pad.

    Returns (X_pad (L*g_pad, d), perm, row_mask_flat).  ``perm`` maps padded
    row -> original row index (or -1 for padding).
    """
    labels = np.asarray(labels)
    order = np.argsort(labels, kind="stable")
    mask = spec.row_mask()
    perm = np.full((spec.m_pad,), -1, dtype=np.int64)
    perm[mask.reshape(-1)] = order
    X_pad = np.zeros((spec.m_pad,) + X.shape[1:], X.dtype)
    X_pad[mask.reshape(-1)] = X[order]
    return X_pad, perm, mask.reshape(-1)


def padded_perm(labels: np.ndarray, spec: GroupSpec) -> np.ndarray:
    """Padded-row -> original-row map (-1 = padding), from labels alone.

    Identical to the ``perm`` returned by :func:`pad_sources` (the map is a
    pure function of the labels and the layout — sample values never enter
    it); split out so callers that only need the permutation don't build a
    padded copy of their data.
    """
    order = np.argsort(np.asarray(labels), kind="stable")
    perm = np.full((spec.m_pad,), -1, dtype=np.int64)
    perm[spec.row_mask().reshape(-1)] = order
    return perm


def pad_cost_matrix(C: np.ndarray, labels: np.ndarray, spec: GroupSpec) -> np.ndarray:
    """Sort + pad the (m, n) cost matrix rows; padded rows get PAD_COST."""
    order = np.argsort(np.asarray(labels), kind="stable")
    mask = spec.row_mask().reshape(-1)
    C_pad = np.full((spec.m_pad, C.shape[1]), PAD_COST, C.dtype)
    C_pad[mask] = C[order]
    return C_pad


def pad_marginal(a: np.ndarray, labels: np.ndarray, spec: GroupSpec) -> np.ndarray:
    """Sort + pad the source marginal; padded rows get zero mass."""
    order = np.argsort(np.asarray(labels), kind="stable")
    mask = spec.row_mask().reshape(-1)
    a_pad = np.zeros((spec.m_pad,), a.dtype)
    a_pad[mask] = a[order]
    return a_pad


def grouped(x: jnp.ndarray, spec: GroupSpec) -> jnp.ndarray:
    """View an (..., m_pad) array as (..., L, g_pad)."""
    return x.reshape(x.shape[:-1] + (spec.num_groups, spec.group_size))


def flat(x: jnp.ndarray, spec: GroupSpec) -> jnp.ndarray:
    """Inverse of :func:`grouped`."""
    return x.reshape(x.shape[:-2] + (spec.m_pad,))
