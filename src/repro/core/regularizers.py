"""Pluggable regularizers Psi and their convex conjugates psi.

The paper's screening (Eq. 6/7) only needs one structural fact about the
regularizer: the conjugate gradient of a group block is *exactly zero*
whenever the group norm ``z_{l,j} = ||[f_j]_{[l]}]_+||_2`` falls below a
per-group threshold ``tau_l`` (Lemma A).  Every regularizer here is a
member of the resulting *thresholded soft-scale family*

  Psi(t_j)  = gamma * ( 1/2 ||t_j||_2^2 + sum_l mu_l ||t_{j[l]}||_2 )
  g*_[l]    = [1 - tau_l / z_l]_+ * [f_[l]]_+ / gamma,   tau_l = mu_l * gamma
  psi_l(f)  = s z^2/gamma (1 - s/2) - mu_l s z,          s = [1 - tau_l/z]_+

parameterized by the overall strength ``gamma`` and a per-group lasso
weight vector ``mu_l >= 0``:

  * :class:`GroupSparseReg` — uniform ``mu_l = mu`` (paper Eq. 3/5),
  * :class:`L2Reg`          — ``mu_l = 0``: the quadratically-smoothed OT of
    Blondel et al. (*Smooth and Sparse Optimal Transport*).  ``tau_l = 0``
    and screening degenerates to nonnegativity skipping: a block is
    certified zero iff the Eq. 6 bound proves ``[f]_+ = 0``,
  * :class:`ElasticNetGroupReg` — per-group ``mu_l`` weights (class-
    imbalanced domain adaptation re-weights rare classes).

Because the whole family shares one closed form with a per-group threshold
vector, the entire screened / compacted / batched / sharded pipeline
(core.solver, core.screening, kernels.*) is regularizer-generic: kernels
take ``tau_l`` as a precomputed per-group vector instead of a scalar, and
the screening bounds compare against it per row.

Everything is expressed in terms of the *group norm matrix*
``Z in R^{L x n}`` with ``z_{l,j} = ||[f_j]_{[l]}]_+||_2`` because that is
the quantity the screening bounds control:

  z_{l,j} <= tau_l  =>  gradient block (l, j) is exactly zero.

The paper's experiments re-balance the two terms with rho in [0, 1):

  Psi_rho(t_j) = gamma * ( (1-rho)/2 ||t_j||^2 + rho * sum_l ||t_{j[l]}||_2 )

which is the same family under  gamma' = gamma*(1-rho),  mu' = rho/(1-rho);
the screening threshold becomes  tau = mu'*gamma' = gamma*rho.
"""
from __future__ import annotations

import dataclasses
from typing import ClassVar, Tuple

import jax.numpy as jnp
import numpy as np


def _group_broadcast(vec: np.ndarray, Z: jnp.ndarray) -> jnp.ndarray:
    """Reshape a per-group ``(L,)`` vector to broadcast against ``Z``.

    ``Z`` carries the group axis at -2 (the ``(..., L, n)`` layout of
    :mod:`repro.core.dual`) — except for 1-D inputs, where the entries ARE
    the per-group values (the ``(L,)`` layout of :func:`psi_value`).
    """
    v = jnp.asarray(vec, Z.dtype)
    if Z.ndim == 1:
        return jnp.broadcast_to(v, Z.shape)
    return jnp.broadcast_to(v[:, None], Z.shape[-2:])


@dataclasses.dataclass(frozen=True)
class Regularizer:
    """Base of the thresholded soft-scale regularizer family.

    Concrete regularizers supply :meth:`mu_vec` (per-group lasso weights);
    everything else — conjugate value/scale, primal penalty, screening
    thresholds — derives from it through the family's shared closed form.

    Instances are frozen, hashable dataclasses: they ride inside the
    static :class:`repro.core.dual.DualProblem` jit argument, so a solve
    compiles per regularizer (exactly the specialization the kernels
    need — ``gamma`` folds into the kernel, ``tau_l`` becomes an operand).

    Parameters
    ----------
    gamma : float
        Overall regularization strength (> 0).
    """

    gamma: float

    #: Stable identifier used in bucket keys / fixtures / diagnostics.
    kind: ClassVar[str] = "base"

    # -- per-group parameter vectors -----------------------------------------
    def mu_vec(self, num_groups: int) -> np.ndarray:
        """Per-group lasso weights ``mu_l`` as a float64 ``(L,)`` vector."""
        raise NotImplementedError

    def tau_vec(self, num_groups: int, dtype=np.float32) -> np.ndarray:
        """Per-group screening thresholds ``tau_l = mu_l * gamma`` ``(L,)``.

        This is the vector the screening bounds compare against and the
        Pallas kernels consume as an operand (padded groups get tau = 0,
        which — together with zero snapshots — always certifies ZERO).
        """
        return (self.mu_vec(num_groups) * float(self.gamma)).astype(dtype)

    @property
    def tau_max(self) -> float:
        """Largest per-group threshold (diagnostics / density heuristics).

        Concrete regularizers must override: the base cannot know the
        group count, and guessing one would silently misreport per-group
        subclasses.
        """
        raise NotImplementedError

    # -- conjugate family (closed form in the group norms Z) ------------------
    def scale_from_z(self, Z: jnp.ndarray) -> jnp.ndarray:
        """Soft-threshold scale ``s = [1 - tau_l / z]_+`` (0 where z <= tau_l).

        ``Z``: ``(..., L, n)`` group norms of ``[f]_+`` (or ``(L,)`` for a
        single column).  Uses the double-where pattern so reverse-mode AD
        through the untaken branch stays NaN-free (the AD path is only a
        test oracle; the solver uses the closed-form gradient).
        """
        L = Z.shape[0] if Z.ndim == 1 else Z.shape[-2]
        tau = _group_broadcast(self.tau_vec(L), Z)
        on = Z > tau
        safe = jnp.where(on, Z, jnp.ones_like(Z))
        return jnp.where(on, 1.0 - tau / safe, 0.0)

    def psi_from_z(self, Z: jnp.ndarray) -> jnp.ndarray:
        """Per-(l, j) conjugate value ``psi_l(f_j)``, closed form in z.

        With ``s = [1 - tau_l/z]_+`` and ``t_[l] = s [f]_+ / gamma``:
            f^T t      = s z^2 / gamma
            1/2||t||^2 = s^2 z^2 / (2 gamma^2)
            ||t||_2    = s z / gamma
            psi_l      = s z^2/gamma * (1 - s/2) - mu_l s z
        (zero whenever z <= tau_l, matching g* = 0; for mu_l = 0 this is
        the pure-l2 conjugate ``z^2 / (2 gamma)``).
        """
        L = Z.shape[0] if Z.ndim == 1 else Z.shape[-2]
        g = jnp.asarray(self.gamma, Z.dtype)
        tau = _group_broadcast(self.tau_vec(L), Z)
        mu = _group_broadcast(self.mu_vec(L), Z)
        on = Z > tau
        Zs = jnp.where(on, Z, jnp.ones_like(Z))      # double-where (AD-safe)
        s = 1.0 - tau / Zs
        val = s * Zs * Zs / g * (1.0 - 0.5 * s) - mu * s * Zs
        return jnp.where(on, val, 0.0)

    def primal(self, T: jnp.ndarray, num_groups: int) -> jnp.ndarray:
        """``sum_j Psi(t_j)`` for a full ``(L*g, n)`` plan (duality checks)."""
        Tg = T.reshape(num_groups, -1, T.shape[-1])
        sq = 0.5 * jnp.sum(T * T)
        mu = jnp.asarray(self.mu_vec(num_groups), T.dtype)
        gl = jnp.sum(mu[:, None] * jnp.linalg.norm(Tg, axis=1))
        return self.gamma * (sq + gl)

    # -- (de)serialization -----------------------------------------------------
    def config(self) -> dict:
        """JSON-able description (fixtures / request payloads)."""
        d = dataclasses.asdict(self)
        d["kind"] = type(self).kind
        return d


@dataclasses.dataclass(frozen=True)
class GroupSparseReg(Regularizer):
    """The paper's group-sparse regularizer (Eq. 3/5): uniform ``mu_l = mu``.

    Parameters
    ----------
    gamma : float
        Overall strength (> 0).
    mu : float
        Group-lasso weight (> 0).

    Derived: ``tau = mu * gamma`` — the screening threshold on z_{l,j}.
    """

    mu: float

    kind: ClassVar[str] = "group_sparse"

    @property
    def tau(self) -> float:
        """The (uniform) screening threshold ``mu * gamma``."""
        return self.mu * self.gamma

    @property
    def tau_max(self) -> float:
        """Largest per-group threshold — uniform, so simply ``tau``."""
        return self.tau

    def mu_vec(self, num_groups: int) -> np.ndarray:
        """Uniform ``(L,)`` weight vector ``mu_l = mu``."""
        return np.full((num_groups,), float(self.mu))

    @staticmethod
    def from_rho(gamma: float, rho: float) -> "GroupSparseReg":
        """Paper-experiment parameterization (rho in [0,1))."""
        if not 0.0 <= rho < 1.0:
            raise ValueError(f"rho must be in [0,1), got {rho}")
        return GroupSparseReg(gamma=gamma * (1.0 - rho), mu=rho / (1.0 - rho))


@dataclasses.dataclass(frozen=True)
class L2Reg(Regularizer):
    """Pure quadratic smoothing (Blondel et al. 2018): ``mu_l = 0``.

    ``Psi(t) = gamma/2 ||t||^2``, conjugate ``psi(f) = ||[f]_+||^2 / (2
    gamma)`` with gradient ``[f]_+ / gamma``.  All thresholds are zero, so
    the screening machinery degenerates exactly to *nonnegativity
    skipping*: a block is certified zero iff the Eq. 6 upper bound proves
    every entry of ``f`` is nonpositive.  The solver, kernels and serving
    engine run unchanged.
    """

    kind: ClassVar[str] = "l2"

    @property
    def tau(self) -> float:
        """Uniform threshold (identically zero for pure l2)."""
        return 0.0

    @property
    def tau_max(self) -> float:
        """Largest per-group threshold (identically zero for pure l2)."""
        return 0.0

    def mu_vec(self, num_groups: int) -> np.ndarray:
        """All-zero ``(L,)`` weight vector."""
        return np.zeros((num_groups,))


@dataclasses.dataclass(frozen=True)
class ElasticNetGroupReg(Regularizer):
    """Elastic-net group-sparse regularizer: per-group weights ``mu_l``.

    Class-imbalanced domain adaptation up-weights rare classes (their
    blocks are driven to zero more aggressively) and down-weights — or
    un-penalizes, ``mu_l = 0`` — dominant ones.  Screening thresholds are
    per group, ``tau_l = mu_l * gamma``, carried through the bounds and
    the kernels as a vector.

    Parameters
    ----------
    gamma : float
        Overall strength (> 0).
    mu_weights : tuple of float
        Per-group lasso weights, length = number of (real) groups L.
        Stored as a tuple so the regularizer stays hashable (it rides in
        the static jit arguments).
    """

    mu_weights: Tuple[float, ...]

    kind: ClassVar[str] = "elastic_net"

    def __post_init__(self):
        object.__setattr__(self, "mu_weights", tuple(float(w) for w in self.mu_weights))
        if any(w < 0 for w in self.mu_weights):
            raise ValueError(f"mu_weights must be >= 0, got {self.mu_weights}")

    def mu_vec(self, num_groups: int) -> np.ndarray:
        """The ``(L,)`` per-group weight vector (validates the length)."""
        if len(self.mu_weights) != num_groups:
            raise ValueError(
                f"ElasticNetGroupReg has {len(self.mu_weights)} group weights "
                f"but the problem has {num_groups} groups"
            )
        return np.asarray(self.mu_weights, np.float64)

    @property
    def tau_max(self) -> float:
        """Largest per-group threshold ``max_l mu_l * gamma``."""
        mx = max(self.mu_weights) if self.mu_weights else 0.0
        return float(mx) * float(self.gamma)


_KINDS = {
    cls.kind: cls for cls in (GroupSparseReg, L2Reg, ElasticNetGroupReg)
}


def from_config(cfg: dict) -> Regularizer:
    """Rebuild a regularizer from its :meth:`Regularizer.config` dict.

    Used by the golden-fixture loader and any wire format carrying a
    regularizer choice (the serving engine's request payloads).
    """
    cfg = dict(cfg)
    kind = cfg.pop("kind")
    if kind not in _KINDS:
        raise ValueError(f"unknown regularizer kind: {kind!r}")
    cls = _KINDS[kind]
    if "mu_weights" in cfg:
        cfg["mu_weights"] = tuple(cfg["mu_weights"])
    return cls(**cfg)


# -- module-level functional forms (the pre-subsystem API, kept stable) -------

def scale_from_z(Z: jnp.ndarray, reg: Regularizer) -> jnp.ndarray:
    """Functional form of :meth:`Regularizer.scale_from_z`."""
    return reg.scale_from_z(Z)


def psi_from_z(Z: jnp.ndarray, reg: Regularizer) -> jnp.ndarray:
    """Functional form of :meth:`Regularizer.psi_from_z`."""
    return reg.psi_from_z(Z)


def psi_value(f: jnp.ndarray, num_groups: int, reg: Regularizer) -> jnp.ndarray:
    """psi(f) for a single column f of length L*g (uniform padded groups)."""
    fg = f.reshape(num_groups, -1)
    Z = jnp.linalg.norm(jnp.maximum(fg, 0.0), axis=-1)
    return jnp.sum(reg.psi_from_z(Z))


def grad_psi(f: jnp.ndarray, num_groups: int, reg: Regularizer) -> jnp.ndarray:
    """Closed-form nabla psi(f) (paper Eq. 5) for one column."""
    fg = f.reshape(num_groups, -1)
    fp = jnp.maximum(fg, 0.0)
    Z = jnp.linalg.norm(fp, axis=-1)
    s = reg.scale_from_z(Z)
    return (s[:, None] * fp / reg.gamma).reshape(f.shape)


def primal_regularizer(T: jnp.ndarray, num_groups: int, reg: Regularizer) -> jnp.ndarray:
    """sum_j Psi(t_j) for a full (L*g, n) plan (used by primal-dual checks)."""
    return reg.primal(T, num_groups)
