"""Group-sparse regularizer Psi and its convex conjugate psi (paper Eq. 3/5).

  Psi(t_j) = gamma * ( 1/2 ||t_j||_2^2 + mu * sum_l ||t_{j[l]}||_2 )

Conjugate (restricted to g >= 0):

  psi(f)   = f^T g* - Psi(g*)
  g*_[l]   = [1 - mu / ||f+_[l]||_2]_+ * f+_[l],      f+ = [f]_+ / gamma
           = [1 - mu*gamma / z_l]_+ * [f_[l]]_+ / gamma,  z_l = ||[f_[l]]_+||_2

Everything here is expressed in terms of the *group norm matrix*
``Z in R^{L x n}`` with ``z_{l,j} = ||[f_j]_{[l]}]_+||_2`` because that is the
quantity the paper's screening bounds control:

  z_{l,j} <= mu*gamma  =>  gradient block (l, j) is exactly zero  (Lemma A).

The experiments in the paper re-balance the two terms with rho in [0, 1):

  Psi_rho(t_j) = gamma * ( (1-rho)/2 ||t_j||^2 + rho * sum_l ||t_{j[l]}||_2 )

which is the same family under  gamma' = gamma*(1-rho),  mu' = rho/(1-rho);
the screening threshold becomes  tau = mu'*gamma' = gamma*rho.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class GroupSparseReg:
    """Parameters of the group-sparse regularizer.

    gamma: overall strength (>0).
    mu:    group-lasso weight (>0).

    Derived:
      tau = mu * gamma -- the screening threshold on z_{l,j}.
    """

    gamma: float
    mu: float

    @property
    def tau(self) -> float:
        return self.mu * self.gamma

    @staticmethod
    def from_rho(gamma: float, rho: float) -> "GroupSparseReg":
        """Paper-experiment parameterization (rho in [0,1))."""
        if not 0.0 <= rho < 1.0:
            raise ValueError(f"rho must be in [0,1), got {rho}")
        return GroupSparseReg(gamma=gamma * (1.0 - rho), mu=rho / (1.0 - rho))


def scale_from_z(Z: jnp.ndarray, reg: GroupSparseReg) -> jnp.ndarray:
    """Soft-threshold scale  s = [1 - tau / z]_+  (0 where z <= tau, incl. z=0).

    Z: (..., L, n) group norms of [f]_+.  Uses the double-where pattern so
    reverse-mode AD through the untaken branch stays NaN-free (the AD path is
    only a test oracle; the solver uses the closed-form gradient).
    """
    tau = jnp.asarray(reg.tau, Z.dtype)
    on = Z > tau
    safe = jnp.where(on, Z, jnp.ones_like(Z))
    return jnp.where(on, 1.0 - tau / safe, 0.0)


def psi_from_z(Z: jnp.ndarray, reg: GroupSparseReg) -> jnp.ndarray:
    """Per-(l, j) conjugate value psi_l(f_j), closed form in z = z_{l,j}.

    With s = [1 - tau/z]_+ and t_[l] = s [f]_+ / gamma:
        f^T t      = s z^2 / gamma
        1/2||t||^2 = s^2 z^2 / (2 gamma^2)
        ||t||_2    = s z / gamma
        psi_l      = s z^2/gamma * (1 - s/2) - mu s z
    (zero whenever z <= tau, matching g* = 0).
    """
    g = jnp.asarray(reg.gamma, Z.dtype)
    mu = jnp.asarray(reg.mu, Z.dtype)
    on = Z > jnp.asarray(reg.tau, Z.dtype)
    Zs = jnp.where(on, Z, jnp.ones_like(Z))      # double-where (AD-safe)
    s = 1.0 - jnp.asarray(reg.tau, Z.dtype) / Zs
    val = s * Zs * Zs / g * (1.0 - 0.5 * s) - mu * s * Zs
    return jnp.where(on, val, 0.0)


def psi_value(f: jnp.ndarray, num_groups: int, reg: GroupSparseReg) -> jnp.ndarray:
    """psi(f) for a single column f of length L*g (uniform padded groups)."""
    fg = f.reshape(num_groups, -1)
    Z = jnp.linalg.norm(jnp.maximum(fg, 0.0), axis=-1)
    return jnp.sum(psi_from_z(Z, reg))


def grad_psi(f: jnp.ndarray, num_groups: int, reg: GroupSparseReg) -> jnp.ndarray:
    """Closed-form nabla psi(f) (paper Eq. 5) for one column."""
    fg = f.reshape(num_groups, -1)
    fp = jnp.maximum(fg, 0.0)
    Z = jnp.linalg.norm(fp, axis=-1)
    s = scale_from_z(Z, reg)
    return (s[:, None] * fp / reg.gamma).reshape(f.shape)


def primal_regularizer(T: jnp.ndarray, num_groups: int, reg: GroupSparseReg) -> jnp.ndarray:
    """sum_j Psi(t_j) for a full (L*g, n) plan (used by primal-dual checks)."""
    Tg = T.reshape(num_groups, -1, T.shape[-1])
    sq = 0.5 * jnp.sum(T * T)
    gl = jnp.sum(jnp.linalg.norm(Tg, axis=1))
    return reg.gamma * (sq + reg.mu * gl)
