"""Safe screening for the group-sparse OT dual (paper Definitions 1-3).

State carried between snapshot rounds (Algorithm 1):

  * snapshots  z~, k~, o~ (L, n) and the snapshot point (alpha~, beta~),
  * the active set N as a dense bool mask  active[l, j]  (mu*gamma < lower
    bound => gradient provably nonzero; Lemma 5),

Per gradient evaluation (Algorithm 2):

  * for (l, j) not in N, the upper bound  z_bar  (Eq. 6) is recomputed from
    (Delta alpha, Delta beta) in O(L (n + g)) and entries with
    z_bar <= mu*gamma are *skipped* (provably-zero gradient; Lemma 2).

The verdict matrix uses three states:
  ZERO   (0)  -- upper bound certifies a zero gradient block: skip work.
  CHECK  (1)  -- bound inconclusive: compute exactly (paper line 11).
  ACTIVE (2)  -- lower bound certifies nonzero: compute exactly, *without*
                 evaluating the upper bound (paper lines 2-4).

Tile-level reduction: a (Lt x Nt) tile may be skipped iff every entry in it
is ZERO; the Pallas kernel consumes those tile flags.

Batch axis: every function here is batch-polymorphic — a :class:`ScreenState`
whose leaves carry a leading ``B`` axis describes ``B`` independent
problems, and the bounds/verdicts broadcast over it (``sqrt_g`` may be
shared ``(L,)`` or per-problem ``(B, L)``).  Screening state never couples
problems, so the batch is just a leading dim.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

ZERO, CHECK, ACTIVE = 0, 1, 2


def broadcast_tau(tau) -> jnp.ndarray:
    """Broadcast a screening threshold against ``(..., L, n)`` bound matrices.

    ``tau`` may be a scalar (uniform threshold, the classic group-sparse
    case) or a per-group ``(L,)`` vector (elastic-net weights; zeros for
    pure-l2 nonnegativity skipping) — see
    :meth:`repro.core.regularizers.Regularizer.tau_vec`.
    """
    t = jnp.asarray(tau)
    return t[..., :, None] if t.ndim else t


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class ScreenState:
    """Snapshot state (Definition 1/2) + active-set mask (Definition 3)."""

    alpha_snap: jnp.ndarray     # (m_pad,)
    beta_snap: jnp.ndarray      # (n,)
    z_snap: jnp.ndarray         # (L, n)   z~
    k_snap: jnp.ndarray         # (L, n)   k~
    o_snap: jnp.ndarray         # (L, n)   o~
    active: jnp.ndarray         # (L, n)   bool, the set N

    def __repr__(self) -> str:
        """Geometry + active-set density, not megabytes of snapshot floats.

        The default dataclass repr prints every array; this one is the
        diagnostic line used by docs examples and bug reports (see also
        :func:`repro.core.solver.describe`).
        """
        lead = self.z_snap.shape[:-2]
        L, n = self.z_snap.shape[-2:]
        m_pad = self.alpha_snap.shape[-1]
        try:
            total = int(jnp.size(self.active))
            act = int(jnp.sum(self.active))
            density = f"{act}/{total} ({act / max(total, 1):.1%})"
        except Exception:  # abstract tracers have no concrete values
            density = "<traced>"
        batch = f"batch={lead}, " if lead else ""
        return (
            f"ScreenState({batch}L={L}, n={n}, m_pad={m_pad}, "
            f"active N={density}, dtype={self.z_snap.dtype})"
        )


def state_pspecs(spec) -> ScreenState:
    """Flatten the batched screening state for ``shard_map``.

    Returns a :class:`ScreenState`-shaped pytree with every leaf set to
    ``spec`` (each leaf of a batched state carries a leading problem axis,
    so a single leading-axis spec describes all of them).

    Parameters
    ----------
    spec : jax.sharding.PartitionSpec
        Leading-axis spec, e.g. ``P("batch")``.

    Returns
    -------
    ScreenState
        A state-shaped pytree of partition specs.
    """
    fields = [f.name for f in dataclasses.fields(ScreenState)]
    return ScreenState(**{name: spec for name in fields})


def init_state(
    m_pad: int, n: int, L: int, dtype=jnp.float32, batch_shape: Tuple[int, ...] = ()
) -> ScreenState:
    """All-zero snapshots at (alpha, beta) = 0; N = empty (paper line 1).

    ``batch_shape`` prepends leading batch dims to every leaf (a batch of
    independent problems shares no screening state).

    NOTE: all-zero snapshots correspond to z~ etc. evaluated at the actual
    init only if they are *computed* there; callers must refresh the state
    via :func:`take_snapshot` before the first screened evaluation.  The
    empty active set is always safe.
    """
    return ScreenState(
        alpha_snap=jnp.zeros(batch_shape + (m_pad,), dtype),
        beta_snap=jnp.zeros(batch_shape + (n,), dtype),
        z_snap=jnp.zeros(batch_shape + (L, n), dtype),
        k_snap=jnp.zeros(batch_shape + (L, n), dtype),
        o_snap=jnp.zeros(batch_shape + (L, n), dtype),
        active=jnp.zeros(batch_shape + (L, n), bool),
    )


def grouped_norms(x: jnp.ndarray, L: int) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """(||[x_[l]]_+||, ||x_[l]||, ||[x_[l]]_-||) per group for x (..., L*g)."""
    xg = x.reshape(x.shape[:-1] + (L, -1))
    plus = jnp.linalg.norm(jnp.maximum(xg, 0.0), axis=-1)
    full = jnp.linalg.norm(xg, axis=-1)
    neg = jnp.linalg.norm(jnp.minimum(xg, 0.0), axis=-1)
    return plus, full, neg


def delta_norms(
    state: ScreenState,
    alpha: jnp.ndarray,
    beta: jnp.ndarray,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Per-eval displacement norms feeding Eqs. 6/7:
    ``(||[d_alpha]_+||, ||d_alpha||, ||[d_alpha]_-||)`` per group plus the raw
    ``d_beta`` vector.  O(L(g+1) + n) — this is the only per-evaluation cost
    of screening once the (L, n) snapshots are frozen.
    """
    L = state.z_snap.shape[-2]
    da_plus, da_full, da_neg = grouped_norms(alpha - state.alpha_snap, L)
    return da_plus, da_full, da_neg, beta - state.beta_snap


def upper_bound(
    state: ScreenState,
    alpha: jnp.ndarray,
    beta: jnp.ndarray,
    sqrt_g: jnp.ndarray,
) -> jnp.ndarray:
    """Eq. (6):  z_bar = z~ + ||[d_alpha_[l]]_+||_2 + sqrt(g_l) [d_beta_j]_+.

    O(L (n + g)) given snapshots: two grouped reductions + one rank-1
    broadcast add over the (L, n) matrix.
    """
    da_plus, _, _, db = delta_norms(state, alpha, beta)
    db_plus = jnp.maximum(db, 0.0)
    return (
        state.z_snap
        + da_plus[..., :, None]
        + sqrt_g[..., :, None] * db_plus[..., None, :]
    )


def lower_bound(
    state: ScreenState,
    alpha: jnp.ndarray,
    beta: jnp.ndarray,
    sqrt_g: jnp.ndarray,
) -> jnp.ndarray:
    """Eq. (7):
      z_low = k~ - ||d_alpha_[l]|| - sqrt(g_l)|d_beta_j|
            - o~ - ||[d_alpha_[l]]_-|| - sqrt(g_l)[d_beta_j]_-_norm
    (for scalar d_beta_j:  ||[d_beta_j]_-||_2 = relu(-d_beta_j)).
    """
    _, da_full, da_neg, db = delta_norms(state, alpha, beta)
    db_abs = jnp.abs(db)
    db_negn = jnp.maximum(-db, 0.0)
    return (
        state.k_snap
        - da_full[..., :, None]
        - sqrt_g[..., :, None] * db_abs[..., None, :]
        - state.o_snap
        - da_neg[..., :, None]
        - sqrt_g[..., :, None] * db_negn[..., None, :]
    )


def verdicts(
    state: ScreenState,
    alpha: jnp.ndarray,
    beta: jnp.ndarray,
    sqrt_g: jnp.ndarray,
    tau,
) -> jnp.ndarray:
    """Per-entry verdict matrix (L, n) in {ZERO, CHECK, ACTIVE}.

    ACTIVE comes from the persistent set N (lower bounds, refreshed at
    snapshot time); ZERO/CHECK from the per-evaluation upper bound.
    ``tau`` is a scalar or per-group ``(L,)`` threshold (see
    :func:`broadcast_tau`).
    """
    zbar = upper_bound(state, alpha, beta, sqrt_g)
    v = jnp.where(zbar <= broadcast_tau(tau), ZERO, CHECK).astype(jnp.int32)
    return jnp.where(state.active, ACTIVE, v)


def refresh_active(
    state: ScreenState,
    alpha: jnp.ndarray,
    beta: jnp.ndarray,
    sqrt_g: jnp.ndarray,
    tau,
) -> ScreenState:
    """Recompute N from lower bounds (Algorithm 1 lines 6-14).

    ``tau`` is a scalar or per-group ``(L,)`` threshold.
    """
    zlow = lower_bound(state, alpha, beta, sqrt_g)
    return dataclasses.replace(state, active=zlow > broadcast_tau(tau))


def take_snapshot(
    state: ScreenState,
    alpha: jnp.ndarray,
    beta: jnp.ndarray,
    z: jnp.ndarray,
    k: jnp.ndarray,
    o: jnp.ndarray,
) -> ScreenState:
    """Update snapshots to the current iterate (Algorithm 1 line 15)."""
    return ScreenState(
        alpha_snap=alpha,
        beta_snap=beta,
        z_snap=z,
        k_snap=k,
        o_snap=o,
        active=state.active,
    )


def tile_flags(verdict: jnp.ndarray, tile_l: int, tile_n: int) -> jnp.ndarray:
    """Reduce per-entry verdicts to per-tile skip flags for the kernel.

    Returns (..., ceil(L/tile_l), ceil(n/tile_n)) int32: 0 = whole tile ZERO
    (skip), 1 = compute.  L and n are padded virtually with ZERO.
    """
    L, n = verdict.shape[-2:]
    Lp = -(-L // tile_l) * tile_l
    np_ = -(-n // tile_n) * tile_n
    pads = [(0, 0)] * (verdict.ndim - 2) + [(0, Lp - L), (0, np_ - n)]
    v = jnp.pad(verdict, pads, constant_values=ZERO)
    v = v.reshape(
        verdict.shape[:-2] + (Lp // tile_l, tile_l, np_ // tile_n, tile_n)
    )
    any_work = jnp.any(v != ZERO, axis=(-3, -1))
    return any_work.astype(jnp.int32)


def skip_stats(verdict: jnp.ndarray) -> dict:
    """Counters matching the paper's Theorem 1 bookkeeping (host-side ints)."""
    return {
        "zero": int(jnp.sum(verdict == ZERO)),
        "check": int(jnp.sum(verdict == CHECK)),
        "active": int(jnp.sum(verdict == ACTIVE)),
    }
