"""Faithful CPU reproduction of the paper (numpy + scipy L-BFGS-B).

This module exists for the paper-figure benchmarks: on a CPU, *skipping* a
group's gradient really does remove its work, so the wall-clock gains of
Figures 2/3/4/5/A are reproducible here.  The JAX/Pallas path (repro.core.
solver + repro.kernels) is the production TPU adaptation of the same
algorithm; both are tested to produce the same objective values (Thm. 2).

Two solvers, sharing one L-BFGS driver (scipy, as in Blondel et al.'s
reference implementation):

  * :func:`origin_solve` — dense O(|L| n g) gradient per evaluation.
  * :func:`fast_solve`   — Algorithm 1/2: upper-bound skipping + active set.

Both count gradient-block computations so benchmarks can reproduce the
paper's Figure 6 / Figure C bookkeeping exactly.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional

import numpy as np
from scipy import optimize

from repro.core import groups as G
from repro.core.regularizers import Regularizer


@dataclasses.dataclass
class CpuSolveResult:
    alpha: np.ndarray
    beta: np.ndarray
    value: float                 # dual objective (maximization)
    n_iters: int
    n_evals: int
    n_blocks_computed: int       # gradient group-blocks computed exactly
    n_blocks_skipped: int        # certified-zero blocks skipped
    n_blocks_active: int         # computed via the active set (no check)
    wall_time: float
    status: str


def _psi_terms(Z: np.ndarray, tau: np.ndarray, gamma: float):
    """(psi value per block, scale s per block) from group norms Z.

    ``tau`` broadcasts against ``Z`` — the (L, 1) column of per-group
    thresholds on the dense (L, n) path, or the per-block gather
    ``tau_l[l_idx]`` on the screened path.  ``mu_l = tau_l / gamma``
    recovers the lasso weight of the thresholded soft-scale family; for
    ``tau = 0`` (pure l2) this is the smoothed conjugate ``Z^2/(2 gamma)``
    restricted to ``Z > 0``.
    """
    s = np.where(Z > tau, 1.0 - tau / np.maximum(Z, 1e-38), 0.0)
    val = s * Z * Z / gamma * (1.0 - 0.5 * s) - (tau / gamma) * s * Z
    return np.where(Z > tau, val, 0.0), s


_SAFE = 1.0 + 1e-6   # fp32 inflation so upper bounds stay upper bounds


class _Oracle:
    """value_and_grad for scipy (negated dual), optionally screened.

    The screened path is one flat gather -> vectorized soft-threshold ->
    segment-sum pass over the K un-skipped (l, j) blocks, so its work is
    genuinely proportional to K (no per-group Python loop).  Bound matrices
    are fp32 (half the traffic of the O(|L| n) rank-1 pass); the upper bound
    is inflated by ``_SAFE`` so fp32 rounding can never flip a certified-zero
    verdict the wrong way — the ZERO mask is the only correctness-critical
    screen (Lemma 2), the active set N is a pure performance hint.
    """

    def __init__(self, C, a, b, spec: G.GroupSpec, reg: Regularizer,
                 screened: bool, use_lower: bool = True, r: int = 10):
        self.C, self.a, self.b = C, a, b
        self.spec, self.reg = spec, reg
        self.screened = screened
        self.use_lower = use_lower      # idea 2 on/off (paper Fig. D ablation)
        self.r = r
        L, g = spec.num_groups, spec.group_size
        self.L, self.g, self.n = L, g, C.shape[1]
        self.tau_l = reg.tau_vec(L, dtype=np.float64)     # (L,) thresholds
        self.tau32 = self.tau_l.astype(np.float32)
        self.m_pad = spec.m_pad
        self.Cg = C.reshape(L, g, self.n)
        if screened:
            # (L*n, g) layout: one contiguous g-row per (l, j) block
            self.C_blocks = np.ascontiguousarray(
                self.Cg.transpose(0, 2, 1).reshape(L * self.n, g)
            )
        self.row_mask = spec.row_mask()                   # (L, g)
        self.sqrt_g = spec.sqrt_sizes().astype(np.float64)
        # screening state
        self.snap_x: Optional[np.ndarray] = None
        self.z_snap = self.k_snap = self.o_snap = None
        self.active = np.zeros((L, self.n), bool)
        self.refresh_needed = True
        self.iters_since_snapshot = 0
        # counters
        self.n_evals = 0
        self.blocks_computed = 0
        self.blocks_skipped = 0
        self.blocks_active = 0

    # -- snapshot bookkeeping -------------------------------------------------
    def _take_snapshot(self, x):
        alpha, beta = x[: self.m_pad], x[self.m_pad:]
        F = alpha.reshape(self.L, self.g, 1) + beta[None, None, :] - self.Cg
        Fm = np.where(self.row_mask[:, :, None], F, 0.0)
        # inflate z~ so the fp32 upper bound remains a true upper bound
        z = np.linalg.norm(np.maximum(Fm, 0.0), axis=1) * _SAFE
        if self.use_lower:
            k = np.linalg.norm(Fm, axis=1)
            o = np.linalg.norm(np.minimum(Fm, 0.0), axis=1)
            if self.snap_x is not None:
                # Algorithm 1 order: N from lower bounds w.r.t. OLD snapshot
                self._refresh_active(x)
            self.k_snap = k.astype(np.float32)
            self.o_snap = o.astype(np.float32)
        self.z_snap = z.astype(np.float32)
        self.snap_x = x.copy()

    def _refresh_active(self, x):
        d = x - self.snap_x
        da, db = d[: self.m_pad].reshape(self.L, self.g), d[self.m_pad:]
        da_full = np.linalg.norm(da, axis=1).astype(np.float32)
        da_neg = np.linalg.norm(np.minimum(da, 0.0), axis=1).astype(np.float32)
        sg = self.sqrt_g.astype(np.float32)
        db32 = db.astype(np.float32)
        zlow = (
            self.k_snap
            - da_full[:, None]
            - sg[:, None] * np.abs(db32)[None, :]
            - self.o_snap
            - da_neg[:, None]
            - sg[:, None] * np.maximum(-db32, 0.0)[None, :]
        )
        self.active = zlow > (self.tau32 * np.float32(_SAFE))[:, None]

    def on_iteration(self, _xk=None):
        """scipy callback: snapshot every r solver iterations (Alg. 1 line 3)."""
        self.iters_since_snapshot += 1
        if self.iters_since_snapshot >= self.r:
            self.refresh_needed = True
            self.iters_since_snapshot = 0

    # -- the oracle ------------------------------------------------------------
    def __call__(self, x):
        self.n_evals += 1
        alpha, beta = x[: self.m_pad], x[self.m_pad:]
        reg, L, g, n = self.reg, self.L, self.g, self.n

        if not self.screened:
            F = alpha.reshape(L, g, 1) + beta[None, None, :] - self.Cg
            Fp = np.maximum(F, 0.0)
            Z = np.linalg.norm(Fp, axis=1)
            psi, s = _psi_terms(Z, self.tau_l[:, None], reg.gamma)
            Tg = (s[:, None, :] * Fp) / reg.gamma
            self.blocks_computed += L * n
            value = alpha @ self.a + beta @ self.b - psi.sum()
            ga = self.a - Tg.sum(axis=2).reshape(-1)
            gb = self.b - Tg.sum(axis=(0, 1))
            return -value, -np.concatenate([ga, gb])

        # --- screened path (Algorithm 2) ---
        if self.refresh_needed or self.snap_x is None:
            self._take_snapshot(x)
            self.refresh_needed = False

        d = x - self.snap_x
        da, db = d[: self.m_pad].reshape(L, g), d[self.m_pad:]
        da_plus = np.linalg.norm(np.maximum(da, 0.0), axis=1).astype(np.float32)
        db_plus = np.maximum(db, 0.0).astype(np.float32)
        da_plus *= np.float32(_SAFE)
        db_plus *= np.float32(_SAFE)

        # Eq. 6 upper bounds, only conceptually for (l,j) not in N; computing
        # the (L, n) matrix densely is the O(|L| n) rank-1 pass of Lemma 3.
        sg = self.sqrt_g.astype(np.float32)
        zbar = self.z_snap + da_plus[:, None] + sg[:, None] * db_plus[None, :]
        zero = ~self.active & (zbar <= self.tau32[:, None])
        compute = ~zero

        n_active = int(self.active.sum())
        self.blocks_skipped += int(zero.sum())
        self.blocks_active += n_active
        l_idx, j_idx = np.nonzero(compute)          # row-major => l_idx sorted
        K = l_idx.size
        self.blocks_computed += K - n_active

        value = alpha @ self.a + beta @ self.b
        ga_g = np.zeros((L, g))
        gb = self.b.copy()
        if K:
            # one flat gather + vectorized soft-threshold over K blocks:
            # work scales with K, not |L| * n  (the paper's skip, batched).
            Fb = (
                alpha.reshape(L, g)[l_idx]
                + beta[j_idx][:, None]
                - self.C_blocks[l_idx * self.n + j_idx]
            )
            Fp = np.maximum(Fb, 0.0)
            z = np.sqrt(np.einsum("kg,kg->k", Fp, Fp))
            psi, s = _psi_terms(z, self.tau_l[l_idx], reg.gamma)
            Tb = (s[:, None] * Fp) / reg.gamma
            value -= psi.sum()
            gb -= np.bincount(j_idx, weights=Tb.sum(axis=1), minlength=self.n)
            # segment-sum over contiguous l runs (l_idx ascending)
            counts = np.bincount(l_idx, minlength=L)
            present = counts > 0
            offsets = np.concatenate([[0], np.cumsum(counts)[:-1]])[present]
            ga_g[present] = np.add.reduceat(Tb, offsets, axis=0)
        ga = self.a - ga_g.reshape(-1)
        return -value, -np.concatenate([ga, gb])


def _solve(C, a, b, spec, reg, screened, r, use_lower, maxiter, gtol):
    oracle = _Oracle(C.astype(np.float64), a.astype(np.float64),
                     b.astype(np.float64), spec, reg, screened,
                     use_lower=use_lower, r=r)
    x0 = np.zeros((spec.m_pad + C.shape[1],))
    t0 = time.perf_counter()
    res = optimize.minimize(
        oracle, x0, jac=True, method="L-BFGS-B",
        callback=oracle.on_iteration,
        options={"maxiter": maxiter, "gtol": gtol, "ftol": 1e-12, "maxcor": 10},
    )
    wall = time.perf_counter() - t0
    return CpuSolveResult(
        alpha=res.x[: spec.m_pad], beta=res.x[spec.m_pad:],
        value=-float(res.fun), n_iters=int(res.nit), n_evals=oracle.n_evals,
        n_blocks_computed=oracle.blocks_computed,
        n_blocks_skipped=oracle.blocks_skipped,
        n_blocks_active=oracle.blocks_active,
        wall_time=wall, status=str(res.message),
    )


def factorized_squared_l2_cost(X_S: np.ndarray, X_T: np.ndarray) -> np.ndarray:
    """Float64 reference for the kernels' factorized squared-l2 recipe.

    Computes ``|x|^2 + |y|^2 - 2 <x, y>`` (clamped at zero) with the same
    elementwise-product-and-reduce structure as
    :func:`repro.kernels.gradpsi.factorized_cost_tile`, but in f64 — the
    golden fixture the differential harness (tests/test_geometry.py) pins
    the f32 on-the-fly route against at tolerance.

    Parameters
    ----------
    X_S : np.ndarray
        ``(m, d)`` source samples.
    X_T : np.ndarray
        ``(n, d)`` target samples.

    Returns
    -------
    np.ndarray
        ``(m, n)`` float64 squared-Euclidean cost.
    """
    x = np.asarray(X_S, np.float64)
    y = np.asarray(X_T, np.float64)
    x_sq = np.sum(x * x, axis=-1)
    y_sq = np.sum(y * y, axis=-1)
    xy = np.sum(x[:, None, :] * y[None, :, :], axis=-1)
    return np.maximum(x_sq[:, None] + y_sq[None, :] - 2.0 * xy, 0.0)


def fast_solve_from_samples(
    X_S, labels, X_T, reg: Regularizer, *, pad_to: int = 8,
    normalize_cost: bool = True, r: int = 10, maxiter: int = 1000,
    gtol: float = 1e-6,
) -> CpuSolveResult:
    """Paper pipeline from raw samples via the f64 factorized cost.

    Builds the cost with :func:`factorized_squared_l2_cost` (max-normalized
    when ``normalize_cost``), pads to the uniform group layout, and runs
    :func:`fast_solve` — the f64 end-to-end reference the on-the-fly f32
    route is differentially tested against.
    """
    labels = np.asarray(labels)
    spec = G.spec_from_labels(labels, pad_to=pad_to)
    C = factorized_squared_l2_cost(X_S, X_T)
    if normalize_cost:
        C = C / max(C.max(), 1e-12)
    m, n = C.shape
    C_pad = G.pad_cost_matrix(C.astype(np.float32), labels, spec)
    a = G.pad_marginal(np.full((m,), 1.0 / m, np.float32), labels, spec)
    b = np.full((n,), 1.0 / n, np.float32)
    return fast_solve(C_pad, a, b, spec, reg, r=r, maxiter=maxiter, gtol=gtol)


def origin_solve(C, a, b, spec: G.GroupSpec, reg: Regularizer,
                 maxiter: int = 1000, gtol: float = 1e-6) -> CpuSolveResult:
    """The original (unscreened) method of Blondel et al. 2018."""
    return _solve(C, a, b, spec, reg, screened=False, r=10,
                  use_lower=True, maxiter=maxiter, gtol=gtol)


def fast_solve(C, a, b, spec: G.GroupSpec, reg: Regularizer,
               r: int = 10, use_lower: bool = True,
               maxiter: int = 1000, gtol: float = 1e-6) -> CpuSolveResult:
    """The paper's Algorithm 1 (r = snapshot interval; use_lower = idea 2)."""
    return _solve(C, a, b, spec, reg, screened=True, r=r,
                  use_lower=use_lower, maxiter=maxiter, gtol=gtol)
