"""Core library: the paper's contribution.

Fast regularized discrete OT with group-sparse regularizers (Ida et al.,
AAAI 2023): smooth relaxed dual (Blondel et al. 2018) + safe screening
(upper bounds -> certified-zero gradient blocks skipped; lower bounds ->
persistent active set), exact by Theorem 2.
"""
from repro.core.groups import GroupSpec, spec_from_labels
from repro.core.regularizers import (
    ElasticNetGroupReg,
    GroupSparseReg,
    L2Reg,
    Regularizer,
)
from repro.core.dual import DualProblem, dual_value_and_grad, plan_from_duals
from repro.core.solver import SolveOptions, solve_dual, recover_plan
from repro.core.ot import (
    GroupSparseOTSolution,
    solve_groupsparse_ot,
    squared_euclidean_cost,
    group_sparsity,
)
from repro.core.sinkhorn import sinkhorn_log

__all__ = [
    "GroupSpec",
    "spec_from_labels",
    "Regularizer",
    "GroupSparseReg",
    "L2Reg",
    "ElasticNetGroupReg",
    "DualProblem",
    "dual_value_and_grad",
    "plan_from_duals",
    "SolveOptions",
    "solve_dual",
    "recover_plan",
    "GroupSparseOTSolution",
    "solve_groupsparse_ot",
    "squared_euclidean_cost",
    "group_sparsity",
    "sinkhorn_log",
]
