"""Named perf variants for the §Perf hillclimb (reproducible as
``python -m repro.launch.dryrun --arch A --shape S --variant NAME``).

Each variant transforms (ModelConfig, Rules) before lowering; artifacts are
tagged ``__v_NAME`` so baselines stay untouched.  The §Perf iteration log in
EXPERIMENTS.md references these names.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Tuple

from repro.configs.base import ModelConfig
from repro.sharding.partition import Rules


def _replace_rule(rules: Rules, name: str, axes: Tuple[str, ...]) -> Rules:
    table = tuple((k, v) for k, v in rules.table if k != name)
    return Rules(table=table + ((name, axes),))


def grad_rs(cfg: ModelConfig, rules: Rules):
    """Constrain gradient leaves to param shardings (AR+slice -> RS)."""
    return cfg, rules, {"constrain_grads": True}


def fp8_params(cfg: ModelConfig, rules: Rules):
    """Store params in fp8-e4m3: FSDP all-gather bytes halve vs bf16.

    Deployment recipe: fp8 storage + fp32 Adam moments (master-weightless),
    dequant on use (model code already casts params to compute dtype at
    every use site).  FP8-LM-style; documented accuracy caveat in
    EXPERIMENTS.md §Perf."""
    return dataclasses.replace(cfg, param_dtype="float8_e4m3fn"), rules


def kv_int8(cfg: ModelConfig, rules: Rules):
    """int8 KV cache for decode: ~1.9x less KV HBM traffic + 2x less cache
    memory; per-(token, head) scales, dequant on read."""
    return dataclasses.replace(cfg, kv_quant=True), rules


def cap1(cfg: ModelConfig, rules: Rules):
    """MoE capacity factor 1.25 -> 1.0 (drops more tokens, -20% expert FLOPs)."""
    assert cfg.moe is not None
    return (
        dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=1.0)
        ),
        rules,
    )


def embed_tp(cfg: ModelConfig, rules: Rules):
    """Shard embedding over 'model' only (no FSDP AG of the vocab table on
    the data axes; logits matmul becomes pure TP)."""
    return cfg, _replace_rule(rules, "embed", ("model",))


def seq_shard_train(cfg: ModelConfig, rules: Rules):
    """Sequence parallelism for activations: shard 'seq' over 'model' between
    attention blocks (norms/elementwise run seq-sharded; GSPMD inserts
    gather/scatter at attention boundaries)."""
    return cfg, _replace_rule(rules, "seq", ("model",))


def moe_local(cfg: ModelConfig, rules: Rules):
    """Shard-local MoE dispatch (kills the global-scatter all-reduce)."""
    assert cfg.moe is not None
    return (
        dataclasses.replace(cfg, moe=dataclasses.replace(cfg.moe, local_dispatch=True)),
        rules,
        {"constrain_grads": True},
    )


def fp8_grad_rs(cfg: ModelConfig, rules: Rules):
    """fp8 param storage + reduce-scattered grads (combined winner check)."""
    cfg, rules = fp8_params(cfg, rules)[:2]
    return cfg, rules, {"constrain_grads": True}


def moe_local_fp8(cfg: ModelConfig, rules: Rules):
    """Stacked winners: local dispatch + grad RS + fp8 param storage."""
    cfg, rules, tk = moe_local(cfg, rules)
    cfg, rules = fp8_params(cfg, rules)[:2]
    return cfg, rules, tk


def moe_local_sp(cfg: ModelConfig, rules: Rules):
    """moe_local + sequence-parallel activations (stack the two winners)."""
    cfg, rules, tk = moe_local(cfg, rules)
    return cfg, _replace_rule(rules, "seq", ("model",)), tk


VARIANTS: Dict[str, Callable] = {
    "moe_local_sp": moe_local_sp,
    "grad_rs": grad_rs,
    "fp8_params": fp8_params,
    "fp8_grad_rs": fp8_grad_rs,
    "moe_local": moe_local,
    "moe_local_fp8": moe_local_fp8,
    "kv_int8": kv_int8,
    "cap1": cap1,
    "embed_tp": embed_tp,
    "seq_shard_train": seq_shard_train,
}


def apply_variant(name: Optional[str], cfg: ModelConfig, rules: Rules):
    """Returns (cfg, rules, tcfg_overrides)."""
    if not name:
        return cfg, rules, {}
    out = VARIANTS[name](cfg, rules)
    if len(out) == 2:
        return out[0], out[1], {}
    return out
