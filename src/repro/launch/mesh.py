"""Production mesh construction.

Single pod: (16, 16) = 256 chips, axes ("data", "model").
Multi-pod:  (2, 16, 16) = 512 chips, axes ("pod", "data", "model").

A FUNCTION, not a module-level constant: importing this module never touches
jax device state (the dry-run overrides the platform device count before
any jax initialization; see launch/dryrun.py).
"""
from __future__ import annotations

from repro.utils.compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh(data: int = 2, model: int = 2):
    """Small mesh over host devices for tests (requires XLA host-device env)."""
    return make_mesh((data, model), ("data", "model"))
