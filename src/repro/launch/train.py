"""Training launcher.

Examples:
  # CPU-runnable end-to-end training (examples use this path):
  PYTHONPATH=src python -m repro.launch.train --arch smollm-135m --reduced \
      --steps 200 --batch 8 --seq 128 --ckpt /tmp/ckpt

  # with the paper's OT domain-alignment auxiliary loss:
  ... --ot-align

On a real TPU job the same entry point runs unreduced with
--mesh production; the dry-run (launch/dryrun.py) is the no-hardware proof
of that configuration.
"""
from __future__ import annotations

import argparse

import jax

from repro.configs import get_config
from repro.configs.base import OptimizerConfig, TrainConfig
from repro.data.pipeline import SyntheticLM, SyntheticLMConfig
from repro.training.trainer import Trainer
from repro.utils.logging import get_logger

log = get_logger("train")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--ot-align", action="store_true")
    ap.add_argument("--grad-compression", choices=["none", "int8_ef"], default="none")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    tcfg = TrainConfig(
        optimizer=OptimizerConfig(lr=args.lr, warmup_steps=min(20, args.steps // 5)),
        steps=args.steps,
        checkpoint_every=args.ckpt_every,
        ot_align=args.ot_align,
        grad_compression=args.grad_compression,
        seed=args.seed,
    )
    data = SyntheticLM(
        SyntheticLMConfig(
            vocab_size=cfg.vocab_size,
            seq_len=args.seq,
            global_batch=args.batch,
            seed=args.seed,
        )
    )
    log.info(
        "training %s (%s) for %d steps on %d device(s)",
        args.arch, "reduced" if args.reduced else "full",
        args.steps, jax.device_count(),
    )
    trainer = Trainer(cfg, tcfg, data, ckpt_dir=args.ckpt)
    final = trainer.run()
    log.info("final metrics: %s", final)


if __name__ == "__main__":
    main()
