"""Serving launcher: batched decode over a reduced or full config.

Example (CPU-runnable):
  PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m --reduced \
      --requests 6 --prompt-len 16 --new-tokens 8
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import get_config
from repro.serving.engine import Request, ServingEngine
from repro.utils.logging import get_logger

log = get_logger("serve")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=4)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    from repro.models import build_model

    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    engine = ServingEngine(
        cfg, params, max_batch=args.max_batch,
        max_len=args.prompt_len + args.new_tokens + 8,
    )
    rng = np.random.default_rng(0)
    reqs = [
        Request(
            rid=i,
            prompt=rng.integers(0, cfg.vocab_size, args.prompt_len).astype(np.int32),
            max_new_tokens=args.new_tokens,
        )
        for i in range(args.requests)
    ]
    done = engine.run(reqs)
    for r in done:
        log.info("request %d -> %s", r.rid, r.out_tokens)
    print(f"served {len(done)} requests")


if __name__ == "__main__":
    main()
