"""Step functions lowered by the dry-run and driven by train.py / serve.py."""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, OptimizerConfig, TrainConfig
from repro.models import build_model
from repro.training.optim import adamw_update, init_opt_state


def make_train_step(cfg: ModelConfig, tcfg: TrainConfig):
    """(state, batch) -> (state, metrics); state = {"params", "opt"}."""
    model = build_model(cfg)
    remat = tcfg.remat != "none"
    if tcfg.constrain_grads:
        _, param_axes = model.init(jax.random.PRNGKey(0), abstract=True)

    def train_step(state: Dict, batch: Dict):
        def loss_fn(p):
            return model.train_loss(p, batch, z_loss=tcfg.z_loss, remat=remat)

        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            state["params"]
        )
        if tcfg.constrain_grads:
            # pin grads to the param shardings: GSPMD then reduce-scatters
            # gradient partial sums instead of all-reduce + slice (§Perf)
            from repro.sharding.partition import constrain

            grads = jax.tree_util.tree_map(
                lambda g, ax: constrain(g, *ax),
                grads,
                param_axes,
                is_leaf=lambda x: isinstance(x, tuple)
                and all(isinstance(a, str) or a is None for a in x),
            )
        new_params, new_opt, om = adamw_update(
            state["params"], grads, state["opt"], tcfg.optimizer
        )
        metrics = dict(metrics, **om)
        return {"params": new_params, "opt": new_opt}, metrics

    return train_step


def make_prefill_step(cfg: ModelConfig):
    model = build_model(cfg)

    def prefill_step(params, tokens, caches, memory=None):
        if cfg.family == "encdec":
            memory = model.encode(params, memory)
        return model.prefill(params, tokens, caches, memory=memory)

    return prefill_step


def make_serve_step(cfg: ModelConfig):
    """One decode step: greedy next token + cache update."""
    model = build_model(cfg)

    def serve_step(params, token, caches, index, memory=None):
        # enc-dec: cross-attention K/V live in the cache after prefill, so the
        # encoder never runs during decode (memory stays None).
        logits, caches = model.decode_step(params, token, caches, index, memory=memory)
        next_token = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)[:, None]
        return next_token, caches

    return serve_step


def abstract_train_state(cfg: ModelConfig, ocfg: OptimizerConfig):
    """Sharding-free abstract state (dry-run attaches shardings itself)."""
    model = build_model(cfg)
    params, axes = model.init(jax.random.PRNGKey(0), abstract=True)
    opt = init_opt_state(params, ocfg, abstract=True)
    from repro.training.optim import opt_state_logical_axes

    opt_axes = opt_state_logical_axes(axes, ocfg, "master" in opt)
    return {"params": params, "opt": opt}, {"params": axes, "opt": opt_axes}
