import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)
# ^ MUST precede any jax import/init: jax locks the device count on first use.
"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this driver:
  1. builds the production mesh (16x16 single-pod or 2x16x16 multi-pod),
  2. materializes abstract, sharded param/optimizer/batch structs
     (ShapeDtypeStruct only — no allocation),
  3. jit-lowers the train/prefill/serve step and COMPILES it,
  4. records memory_analysis(), cost_analysis(), and the collective schedule
     parsed from the post-SPMD HLO, into dryrun_artifacts/<cell>.json.

EXPERIMENTS.md §Dry-run / §Roofline are generated from these artifacts
(benchmarks/roofline.py).

Usage:
  python -m repro.launch.dryrun --arch yi-9b --shape train_4k [--multi-pod]
  python -m repro.launch.dryrun --all [--multi-pod] [--out dryrun_artifacts]
"""
import argparse
import json
import re
import time
import traceback
from pathlib import Path

import jax

from repro.configs import SHAPES, SHAPES_BY_NAME, get_config, list_archs, shape_applicable
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import input_specs, param_specs, rules_for_shape
from repro.launch.steps import (
    abstract_train_state,
    make_prefill_step,
    make_serve_step,
    make_train_step,
)
from repro.configs.base import TrainConfig, OptimizerConfig
from repro.sharding.partition import sharding_tree, use_rules

SDS = jax.ShapeDtypeStruct

_COLLECTIVES = (
    "all-reduce",
    "all-gather",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

# bytes-on-the-wire multiplier per result byte (ring algorithms, large N)
_WIRE_FACTOR = {
    "all-reduce": 2.0,        # reduce-scatter + all-gather
    "all-gather": 1.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
}

_SHAPE_RE = re.compile(r"\b(pred|[suf]\d+|bf16)\[([\d,]*)\]")


def _shape_bytes(text: str) -> int:
    """Sum byte sizes of every typed shape token in an HLO result type."""
    total = 0
    for m in _SHAPE_RE.finditer(text):
        dt, dims = m.group(1), m.group(2)
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES.get(dt, 4)
    return total


_OP_RE = re.compile(
    r"=\s*(?P<type>\(?[a-z0-9\[\],{}\s/#_\.]*?\)?)\s*"
    r"(?P<op>all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?P<async>-start|-done)?\("
)


def parse_collectives(hlo_text: str) -> dict:
    """Per-collective-kind result bytes + wire-byte model from post-SPMD HLO.

    Sync ops contribute their result bytes; async '-start' ops carry an
    (operand, result) tuple type, so their byte count is halved; '-done' ops
    are skipped (the start already counted the transfer).
    """
    out = {k: {"count": 0, "result_bytes": 0, "wire_bytes": 0.0} for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        if m.group("async") == "-done":
            continue
        kind = m.group("op")
        b = _shape_bytes(m.group("type"))
        if m.group("async") == "-start":
            b //= 2
        out[kind]["count"] += 1
        out[kind]["result_bytes"] += b
        out[kind]["wire_bytes"] += b * _WIRE_FACTOR[kind]
    out["total_wire_bytes"] = sum(
        v["wire_bytes"] for v in out.values() if isinstance(v, dict)
    )
    return out


def _mem_analysis(compiled) -> dict:
    try:
        ma = compiled.memory_analysis()
    except Exception as e:  # pragma: no cover
        return {"error": str(e)}
    out = {}
    for k in (
        "argument_size_in_bytes",
        "output_size_in_bytes",
        "temp_size_in_bytes",
        "generated_code_size_in_bytes",
        "host_argument_size_in_bytes",
        "host_output_size_in_bytes",
        "host_temp_size_in_bytes",
        "alias_size_in_bytes",
    ):
        v = getattr(ma, k, None)
        if v is not None:
            out[k] = int(v)
    if not out:
        out["repr"] = str(ma)
    return out


def probe_layers(cfg, n_steps: int):
    """Config with the layer stack truncated to n_steps scan iterations.

    Used to correct XLA cost analysis, which counts a while-loop body ONCE
    regardless of trip count: lowering at 1 and 2 scan steps gives
    (outside, per-step) costs by differencing, and the full-depth cost is
    outside + per-step * trips (benchmarks/roofline.py)."""
    import dataclasses as dc

    kw = dict(unroll_layers=True)  # whole point: per-layer cost is countable
    if cfg.family == "hybrid":
        return dc.replace(cfg, num_layers=cfg.attn_period * n_steps, **kw)
    if cfg.family == "ssm":
        return dc.replace(cfg, num_layers=cfg.ssm.slstm_every * n_steps, **kw)
    if cfg.family == "vlm":
        return dc.replace(cfg, num_layers=cfg.cross_attn_period * n_steps, **kw)
    if cfg.family == "encdec":
        return dc.replace(cfg, num_layers=n_steps, encoder_layers=n_steps, **kw)
    return dc.replace(cfg, num_layers=n_steps, **kw)


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: Path,
             force: bool = False, rules_override=None, tag: str = "",
             cfg_override=None) -> dict:
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    cell_id = f"{arch}__{shape_name}__{mesh_name}{tag}"
    art = out_dir / f"{cell_id}.json"
    if art.exists() and not force:
        return json.loads(art.read_text())

    cfg = cfg_override or get_config(arch)
    shape = SHAPES_BY_NAME[shape_name]
    ok, why = shape_applicable(cfg, shape)
    record = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "kind": shape.kind, "seq_len": shape.seq_len,
        "global_batch": shape.global_batch,
    }
    if not ok:
        record.update(status="skipped", reason=why)
        art.write_text(json.dumps(record, indent=2))
        return record

    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = rules_override or rules_for_shape(mesh, shape)
    from repro.launch.variants import apply_variant

    variant = tag[3:] if tag.startswith("__v") else None
    cfg, rules, tcfg_over = apply_variant(variant and variant.lstrip("_"), cfg, rules)
    try:
        with use_rules(rules, mesh):
            pspecs, paxes = param_specs(cfg, mesh, rules)
            ins = input_specs(cfg, shape, mesh, rules)
            if shape.kind == "train":
                tcfg = TrainConfig(
                    optimizer=OptimizerConfig(
                        master_weights=(arch != "jamba-1.5-large-398b")
                    ),
                    **tcfg_over,
                )
                step = make_train_step(cfg, tcfg)
                state, state_axes = abstract_train_state(cfg, tcfg.optimizer)
                sh = sharding_tree(state_axes, rules, mesh, shapes=state)
                state = jax.tree_util.tree_map(
                    lambda s, h: SDS(tuple(s.shape), s.dtype, sharding=h), state, sh
                )
                args = (state, ins["batch"])
            elif shape.kind == "prefill":
                step = make_prefill_step(cfg)
                args = (pspecs, ins["tokens"], ins["caches"])
                if "memory" in ins:
                    args = args + (ins["memory"],)
            else:
                step = make_serve_step(cfg)
                args = (pspecs, ins["token"], ins["caches"], ins["index"])

            with mesh:
                t_lower = time.time()
                lowered = jax.jit(step).lower(*args)
                t_compile = time.time()
                compiled = lowered.compile()
                t_done = time.time()

        mem = _mem_analysis(compiled)
        try:
            cost = dict(compiled.cost_analysis() or {})
            cost = {k: float(v) for k, v in cost.items()
                    if isinstance(v, (int, float))}
        except Exception as e:
            cost = {"error": str(e)}
        coll = parse_collectives(compiled.as_text())
        record.update(
            status="ok",
            devices=int(mesh.size),
            lower_s=round(t_compile - t_lower, 2),
            compile_s=round(t_done - t_compile, 2),
            memory_analysis=mem,
            cost_analysis=cost,
            collectives=coll,
        )
    except Exception as e:
        record.update(status="error", error=f"{type(e).__name__}: {e}",
                      traceback=traceback.format_exc()[-4000:])
    record["wall_s"] = round(time.time() - t0, 2)
    art.write_text(json.dumps(record, indent=2))
    return record


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list_archs())
    ap.add_argument("--shape", choices=[s.name for s in SHAPES])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--probe", action="store_true",
                    help="lower 1- and 2-scan-step variants (cost-model probes)")
    ap.add_argument("--variant", default=None,
                    help="named perf variant (see launch/variants.py)")
    ap.add_argument("--out", default="dryrun_artifacts")
    args = ap.parse_args()

    out_dir = Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)

    cells = []
    archs = list_archs() if args.all else [args.arch]
    shapes = [s.name for s in SHAPES] if args.all else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    for a in archs:
        for s in shapes:
            for m in meshes:
                cells.append((a, s, m))

    n_ok = n_skip = n_err = 0
    for a, s, m in cells:
        if args.probe:
            cfg = get_config(a)
            for n in (1, 2):
                rec = run_cell(a, s, m, out_dir, force=args.force,
                               tag=f"__probe{n}", cfg_override=probe_layers(cfg, n))
                print(f"[{rec['status'].upper():5s}] probe{n} {a} {s}")
            continue
        tag = f"__v_{args.variant}" if args.variant else ""
        rec = run_cell(a, s, m, out_dir, force=args.force, tag=tag)
        tagm = "2x16x16" if m else "16x16"
        if rec["status"] == "ok":
            n_ok += 1
            ca = rec["cost_analysis"]
            print(
                f"[OK]   {a:26s} {s:12s} {tagm:8s} "
                f"flops={ca.get('flops', 0):.3e} "
                f"wire={rec['collectives']['total_wire_bytes']:.3e}B "
                f"compile={rec['compile_s']}s"
            )
        elif rec["status"] == "skipped":
            n_skip += 1
            print(f"[SKIP] {a:26s} {s:12s} {tagm:8s} {rec['reason']}")
        else:
            n_err += 1
            print(f"[ERR]  {a:26s} {s:12s} {tagm:8s} {rec['error']}")
    print(f"\ndry-run: {n_ok} ok, {n_skip} skipped, {n_err} errors")
    return 1 if n_err else 0


if __name__ == "__main__":
    raise SystemExit(main())
