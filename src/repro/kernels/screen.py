"""Pallas TPU kernel: screening bound matrices + verdicts (paper Eq. 6/7).

Rank-1 "outer broadcast" pass over the (L, n) bound matrices:

  z_bar[l, j] = z~[l, j] + ||[d_alpha_[l]]_+|| + sqrt(g_l) * [d_beta_j]_+
  z_low[l, j] = k~ - ||d_alpha_[l]|| - sqrt(g_l)|d_beta_j|
                - o~ - ||[d_alpha_[l]]_-|| - sqrt(g_l)[−d_beta_j]_+

  verdict = ACTIVE where active mask (N),
            ZERO   where z_bar <= tau,
            CHECK  otherwise.

One VPU pass, O(L n) bytes — this is the O(|L|(n+g)) cost of Lemma 3/6
(the per-group delta norms are O(L g) and computed outside in plain jnp).
The kernel also emits the per-tile OR-reduction consumed by gradpsi's skip
flags.  With ``emit_verdict=False`` (the solver's steady-state gradient
path) only the tile flags are written back to HBM: the (L, n) verdict
matrix lives and dies in VMEM and never round-trips between screening and
the gradient kernel.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.screening import ZERO, CHECK, ACTIVE
from repro.kernels.gradpsi import tau_row


def _verdict_tile(z_ref, k_ref, o_ref, act_ref, dap_ref, daf_ref, dan_ref,
                  db_ref, sg_ref, tau_ref):
    dap = dap_ref[...][:, None]                       # (TL, 1)
    daf = daf_ref[...][:, None]
    dan = dan_ref[...][:, None]
    sg = sg_ref[...][:, None]
    tau = tau_ref[...][:, None]                       # (TL, 1) per-group
    db = db_ref[...][None, :]                         # (1, TN)

    zbar = z_ref[...] + dap + sg * jnp.maximum(db, 0.0)
    zlow = (
        k_ref[...]
        - daf
        - sg * jnp.abs(db)
        - o_ref[...]
        - dan
        - sg * jnp.maximum(-db, 0.0)
    )
    active = act_ref[...] != 0
    v = jnp.where(zbar <= tau, ZERO, CHECK)
    v = jnp.where(active, ACTIVE, v)
    # lower bound can also certify non-zero outside N within this eval
    v = jnp.where(jnp.logical_and(v == CHECK, zlow > tau), ACTIVE, v)
    return v.astype(jnp.int32)


def _kernel_full(z_ref, k_ref, o_ref, act_ref, dap_ref, daf_ref, dan_ref,
                 db_ref, sg_ref, tau_ref, verdict_ref, flag_ref):
    v = _verdict_tile(z_ref, k_ref, o_ref, act_ref, dap_ref, daf_ref,
                      dan_ref, db_ref, sg_ref, tau_ref)
    verdict_ref[...] = v
    flag_ref[0, 0] = jnp.any(v != ZERO).astype(jnp.int32)


def _kernel_flags(z_ref, k_ref, o_ref, act_ref, dap_ref, daf_ref, dan_ref,
                  db_ref, sg_ref, tau_ref, flag_ref):
    v = _verdict_tile(z_ref, k_ref, o_ref, act_ref, dap_ref, daf_ref,
                      dan_ref, db_ref, sg_ref, tau_ref)
    flag_ref[0, 0] = jnp.any(v != ZERO).astype(jnp.int32)


@functools.partial(
    jax.jit,
    static_argnames=("tile_l", "tile_n", "interpret", "emit_verdict"),
)
def screen_pallas(
    z_snap: jnp.ndarray,       # (L, n)
    k_snap: jnp.ndarray,       # (L, n)
    o_snap: jnp.ndarray,       # (L, n)
    active: jnp.ndarray,       # (L, n) int8/bool persistent set N
    da_plus: jnp.ndarray,      # (L,)  ||[d_alpha_[l]]_+||
    da_full: jnp.ndarray,      # (L,)  ||d_alpha_[l]||
    da_neg: jnp.ndarray,       # (L,)  ||[d_alpha_[l]]_-||
    db: jnp.ndarray,           # (n,)  d_beta
    sqrt_g: jnp.ndarray,       # (L,)
    *,
    tau,
    tile_l: int = 8,
    tile_n: int = 128,
    interpret: bool = False,
    emit_verdict: bool = True,
) -> Tuple[Optional[jnp.ndarray], jnp.ndarray]:
    """Returns (verdict (L, n) int32 | None, tile_flags (L/tl, n/tn) int32).

    ``tau`` is a scalar or per-group ``(L,)`` threshold vector (the
    regularizer's screening thresholds); it rides as a row operand next to
    ``sqrt_g``.  ``emit_verdict=False`` skips the (L, n) HBM write-back
    entirely; only the tile-flag reduction leaves the chip.
    """
    L, n = z_snap.shape
    assert L % tile_l == 0 and n % tile_n == 0, (L, tile_l, n, tile_n)
    grid = (L // tile_l, n // tile_n)
    tau_g = tau_row(tau, L)

    row = pl.BlockSpec((tile_l,), lambda l, j: (l,))
    col = pl.BlockSpec((tile_n,), lambda l, j: (j,))
    mat = pl.BlockSpec((tile_l, tile_n), lambda l, j: (l, j))
    flag = pl.BlockSpec((1, 1), lambda l, j: (l, j))

    if emit_verdict:
        kernel = _kernel_full
        out_specs = [mat, flag]
        out_shape = [
            jax.ShapeDtypeStruct((L, n), jnp.int32),
            jax.ShapeDtypeStruct(grid, jnp.int32),
        ]
    else:
        kernel = _kernel_flags
        out_specs = [flag]
        out_shape = [jax.ShapeDtypeStruct(grid, jnp.int32)]

    outs = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[mat, mat, mat, mat, row, row, row, col, row, row],
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(z_snap, k_snap, o_snap, active.astype(jnp.int8),
      da_plus, da_full, da_neg, db, sqrt_g, tau_g)

    if emit_verdict:
        return outs[0], outs[1]
    return None, outs[0]
