"""Pallas TPU kernel: screening bound matrices + verdicts (paper Eq. 6/7).

Rank-1 "outer broadcast" pass over the (L, n) bound matrices:

  z_bar[l, j] = z~[l, j] + ||[d_alpha_[l]]_+|| + sqrt(g_l) * [d_beta_j]_+
  z_low[l, j] = k~ - ||d_alpha_[l]|| - sqrt(g_l)|d_beta_j|
                - o~ - ||[d_alpha_[l]]_-|| - sqrt(g_l)[−d_beta_j]_+

  verdict = ACTIVE where active mask (N),
            ZERO   where z_bar <= tau,
            CHECK  otherwise.

One VPU pass, O(L n) bytes — this is the O(|L|(n+g)) cost of Lemma 3/6
(the per-group delta norms are O(L g) and computed outside in plain jnp).
The kernel also emits the per-tile OR-reduction consumed by gradpsi's skip
flags.  With ``emit_verdict=False`` (the solver's steady-state gradient
path) only the tile flags are written back to HBM: the (L, n) verdict
matrix lives and dies in VMEM and never round-trips between screening and
the gradient kernel.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.screening import ZERO
from repro.kernels.gradpsi import (
    _record_launch,
    _verdict_tile,
    factorized_cost_tile,
    tau_row,
)


def _kernel_full(z_ref, k_ref, o_ref, act_ref, dap_ref, daf_ref, dan_ref,
                 db_ref, sg_ref, tau_ref, verdict_ref, flag_ref):
    # gradpsi._verdict_tile is THE verdict math — the fused kernels call the
    # same function on identically-blocked operands, which is what keeps the
    # standalone and fused flag outputs bitwise-interchangeable.
    v = _verdict_tile(z_ref[...], k_ref[...], o_ref[...], act_ref[...],
                      dap_ref[...], daf_ref[...], dan_ref[...],
                      db_ref[...], sg_ref[...], tau_ref[...])
    verdict_ref[...] = v
    flag_ref[0, 0] = jnp.any(v != ZERO).astype(jnp.int32)


def _kernel_flags(z_ref, k_ref, o_ref, act_ref, dap_ref, daf_ref, dan_ref,
                  db_ref, sg_ref, tau_ref, flag_ref):
    v = _verdict_tile(z_ref[...], k_ref[...], o_ref[...], act_ref[...],
                      dap_ref[...], daf_ref[...], dan_ref[...],
                      db_ref[...], sg_ref[...], tau_ref[...])
    flag_ref[0, 0] = jnp.any(v != ZERO).astype(jnp.int32)


@functools.partial(
    jax.jit,
    static_argnames=("tile_l", "tile_n", "interpret", "emit_verdict"),
)
def screen_pallas(
    z_snap: jnp.ndarray,       # (L, n)
    k_snap: jnp.ndarray,       # (L, n)
    o_snap: jnp.ndarray,       # (L, n)
    active: jnp.ndarray,       # (L, n) int8/bool persistent set N
    da_plus: jnp.ndarray,      # (L,)  ||[d_alpha_[l]]_+||
    da_full: jnp.ndarray,      # (L,)  ||d_alpha_[l]||
    da_neg: jnp.ndarray,       # (L,)  ||[d_alpha_[l]]_-||
    db: jnp.ndarray,           # (n,)  d_beta
    sqrt_g: jnp.ndarray,       # (L,)
    *,
    tau,
    tile_l: int = 8,
    tile_n: int = 128,
    interpret: bool = False,
    emit_verdict: bool = True,
) -> Tuple[Optional[jnp.ndarray], jnp.ndarray]:
    """Returns (verdict (L, n) int32 | None, tile_flags (L/tl, n/tn) int32).

    ``tau`` is a scalar or per-group ``(L,)`` threshold vector (the
    regularizer's screening thresholds); it rides as a row operand next to
    ``sqrt_g``.  ``emit_verdict=False`` skips the (L, n) HBM write-back
    entirely; only the tile-flag reduction leaves the chip.
    """
    _record_launch("screen_pallas")
    L, n = z_snap.shape
    assert L % tile_l == 0 and n % tile_n == 0, (L, tile_l, n, tile_n)
    grid = (L // tile_l, n // tile_n)
    tau_g = tau_row(tau, L)

    row = pl.BlockSpec((tile_l,), lambda l, j: (l,))
    col = pl.BlockSpec((tile_n,), lambda l, j: (j,))
    mat = pl.BlockSpec((tile_l, tile_n), lambda l, j: (l, j))
    flag = pl.BlockSpec((1, 1), lambda l, j: (l, j))

    if emit_verdict:
        kernel = _kernel_full
        out_specs = [mat, flag]
        out_shape = [
            jax.ShapeDtypeStruct((L, n), jnp.int32),
            jax.ShapeDtypeStruct(grid, jnp.int32),
        ]
    else:
        kernel = _kernel_flags
        out_specs = [flag]
        out_shape = [jax.ShapeDtypeStruct(grid, jnp.int32)]

    outs = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[mat, mat, mat, mat, row, row, row, col, row, row],
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(z_snap, k_snap, o_snap, active.astype(jnp.int8),
      da_plus, da_full, da_neg, db, sqrt_g, tau_g)

    if emit_verdict:
        return outs[0], outs[1]
    return None, outs[0]


# -- factorized snapshot-norms kernel (materialization-free route) -------------
#
# The dense solver snapshots the Eq. 6 bound matrices via dual.snapshot_norms,
# which reads the full (m_pad, n) C.  On the on-the-fly route there is no C:
# this kernel rebuilds each cost tile from sample blocks (the same
# factorized_cost_tile recipe as the gradient kernels) and reduces the three
# per-group norms in VMEM, so the only (L, n)-sized HBM traffic is the three
# bound matrices themselves — exactly what the dense route also writes.


def _snapshot_kernel_fact(alpha_ref, beta_ref, x_ref, xsq_ref, y_ref, ysq_ref,
                          mask_ref, z_ref, k_ref, o_ref):
    c = factorized_cost_tile(
        x_ref[...].astype(jnp.float32),                  # (TL, g, d)
        xsq_ref[...].astype(jnp.float32),                # (TL, g)
        y_ref[...].astype(jnp.float32),                  # (TN, d)
        ysq_ref[...].astype(jnp.float32),                # (TN,)
    )
    f = (alpha_ref[...].astype(jnp.float32)[:, :, None]
         + beta_ref[...].astype(jnp.float32)[None, None, :]
         - c)                                            # (TL, g, TN)
    fm = jnp.where(mask_ref[...][:, :, None] != 0, f, 0.0)
    z_ref[...] = jnp.sqrt(jnp.sum(jnp.square(jnp.maximum(fm, 0.0)), axis=1))
    k_ref[...] = jnp.sqrt(jnp.sum(jnp.square(fm), axis=1))
    o_ref[...] = jnp.sqrt(jnp.sum(jnp.square(jnp.minimum(fm, 0.0)), axis=1))


@functools.partial(
    jax.jit,
    static_argnames=("num_groups", "group_size",
                     "tile_l", "tile_n", "interpret"),
)
def snapshot_norms_fact_pallas(
    alpha: jnp.ndarray,        # (L_pad*g,) fp32 tile-padded duals
    beta: jnp.ndarray,         # (n_pad,) fp32
    x: jnp.ndarray,            # (L_pad*g, d) fp32 scaled source samples
    x_sq: jnp.ndarray,         # (L_pad*g,) fp32
    y: jnp.ndarray,            # (n_pad, d) fp32 scaled target samples
    y_sq: jnp.ndarray,         # (n_pad,) fp32
    mask: jnp.ndarray,         # (L_pad*g,) int8 real-row mask
    *,
    num_groups: int,           # L_pad (tile-padded group count)
    group_size: int,
    tile_l: int = 8,
    tile_n: int = 128,
    interpret: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Factorized snapshot norms: returns (z, k, o) each (L_pad, n_pad).

    Per-element math replicates :func:`repro.core.dual.snapshot_norms` on a
    cost materialized with :func:`factorized_cost_tile` — F is masked to zero
    on padded group members BEFORE the three reductions, so k~/o~ never see
    the PAD_COST sentinel rows.  Callers slice ``[:L, :n]``.
    """
    _record_launch("snapshot_norms_fact_pallas")
    L, g = num_groups, group_size
    d = x.shape[-1]
    n_pad = beta.shape[0]
    assert L % tile_l == 0 and n_pad % tile_n == 0, (L, tile_l, n_pad, tile_n)
    grid = (L // tile_l, n_pad // tile_n)

    alpha_g = alpha.reshape(L, g)
    x3 = x.reshape(L, g, d)
    xsq_g = x_sq.reshape(L, g)
    mask_g = mask.reshape(L, g).astype(jnp.int8)

    row_g = pl.BlockSpec((tile_l, g), lambda l, j: (l, 0))
    col = pl.BlockSpec((tile_n,), lambda l, j: (j,))
    mat = pl.BlockSpec((tile_l, tile_n), lambda l, j: (l, j))

    z, k, o = pl.pallas_call(
        _snapshot_kernel_fact,
        grid=grid,
        in_specs=[
            row_g,                                           # alpha
            col,                                             # beta
            pl.BlockSpec((tile_l, g, d), lambda l, j: (l, 0, 0)),  # x
            row_g,                                           # x_sq
            pl.BlockSpec((tile_n, d), lambda l, j: (j, 0)),  # y
            col,                                             # y_sq
            row_g,                                           # mask
        ],
        out_specs=[mat, mat, mat],
        out_shape=[
            jax.ShapeDtypeStruct((L, n_pad), jnp.float32),
            jax.ShapeDtypeStruct((L, n_pad), jnp.float32),
            jax.ShapeDtypeStruct((L, n_pad), jnp.float32),
        ],
        interpret=interpret,
    )(alpha_g, beta, x3, xsq_g, y, y_sq, mask_g)

    return z, k, o
