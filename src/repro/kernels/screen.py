"""Pallas TPU kernel: screening bound matrices + verdicts (paper Eq. 6/7).

Rank-1 "outer broadcast" pass over the (L, n) bound matrices:

  z_bar[l, j] = z~[l, j] + ||[d_alpha_[l]]_+|| + sqrt(g_l) * [d_beta_j]_+
  z_low[l, j] = k~ - ||d_alpha_[l]|| - sqrt(g_l)|d_beta_j|
                - o~ - ||[d_alpha_[l]]_-|| - sqrt(g_l)[−d_beta_j]_+

  verdict = ACTIVE where active mask (N),
            ZERO   where z_bar <= tau,
            CHECK  otherwise.

One VPU pass, O(L n) bytes — this is the O(|L|(n+g)) cost of Lemma 3/6
(the per-group delta norms are O(L g) and computed outside in plain jnp).
The kernel also emits the per-tile OR-reduction consumed by gradpsi's skip
flags, so the verdict matrix never has to round-trip through HBM twice.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.screening import ZERO, CHECK, ACTIVE


def _kernel(z_ref, k_ref, o_ref, act_ref, dap_ref, daf_ref, dan_ref,
            db_ref, sg_ref, verdict_ref, flag_ref, *, tau: float):
    dap = dap_ref[...][:, None]                       # (TL, 1)
    daf = daf_ref[...][:, None]
    dan = dan_ref[...][:, None]
    sg = sg_ref[...][:, None]
    db = db_ref[...][None, :]                         # (1, TN)

    zbar = z_ref[...] + dap + sg * jnp.maximum(db, 0.0)
    zlow = (
        k_ref[...]
        - daf
        - sg * jnp.abs(db)
        - o_ref[...]
        - dan
        - sg * jnp.maximum(-db, 0.0)
    )
    active = act_ref[...] != 0
    v = jnp.where(zbar <= tau, ZERO, CHECK)
    v = jnp.where(active, ACTIVE, v)
    # lower bound can also certify non-zero outside N within this eval
    v = jnp.where(jnp.logical_and(v == CHECK, zlow > tau), ACTIVE, v)
    verdict_ref[...] = v.astype(jnp.int32)
    flag_ref[0, 0] = jnp.any(v != ZERO).astype(jnp.int32)


@functools.partial(
    jax.jit, static_argnames=("tau", "tile_l", "tile_n", "interpret")
)
def screen_pallas(
    z_snap: jnp.ndarray,       # (L, n)
    k_snap: jnp.ndarray,       # (L, n)
    o_snap: jnp.ndarray,       # (L, n)
    active: jnp.ndarray,       # (L, n) int8/bool persistent set N
    da_plus: jnp.ndarray,      # (L,)  ||[d_alpha_[l]]_+||
    da_full: jnp.ndarray,      # (L,)  ||d_alpha_[l]||
    da_neg: jnp.ndarray,       # (L,)  ||[d_alpha_[l]]_-||
    db: jnp.ndarray,           # (n,)  d_beta
    sqrt_g: jnp.ndarray,       # (L,)
    *,
    tau: float,
    tile_l: int = 8,
    tile_n: int = 128,
    interpret: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (verdict (L, n) int32, tile_flags (L/tile_l, n/tile_n) int32)."""
    L, n = z_snap.shape
    assert L % tile_l == 0 and n % tile_n == 0, (L, tile_l, n, tile_n)
    grid = (L // tile_l, n // tile_n)

    row = pl.BlockSpec((tile_l,), lambda l, j: (l,))
    col = pl.BlockSpec((tile_n,), lambda l, j: (j,))
    mat = pl.BlockSpec((tile_l, tile_n), lambda l, j: (l, j))

    verdict, flags = pl.pallas_call(
        functools.partial(_kernel, tau=float(tau)),
        grid=grid,
        in_specs=[mat, mat, mat, mat, row, row, row, col, row],
        out_specs=[mat, pl.BlockSpec((1, 1), lambda l, j: (l, j))],
        out_shape=[
            jax.ShapeDtypeStruct((L, n), jnp.int32),
            jax.ShapeDtypeStruct(grid, jnp.int32),
        ],
        interpret=interpret,
    )(z_snap, k_snap, o_snap, active.astype(jnp.int8),
      da_plus, da_full, da_neg, db, sqrt_g)
    return verdict, flags
