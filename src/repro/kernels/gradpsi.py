"""Pallas TPU kernels: fused, screened dual gradient for group-sparse OT.

This is the paper's Algorithm 2 adapted to the TPU memory hierarchy (see
DESIGN.md §2).  One kernel instance owns a (TILE_L groups x g rows) x TILE_N
columns tile and fuses the whole gradient pipeline in VMEM:

    F = alpha + beta_j - c          (VPU broadcast add)
    Z = ||[F_group]_+||_2           (relu + per-group reduction)
    s = [1 - tau/Z]_+               (soft threshold, Eq. 5)
    T = s * [F]_+ / gamma           (the gradient block / plan block)
    psi contribution                (closed form in Z)

Screening enters through per-tile skip flags (int32, 0 = every (l, j) in the
tile is certified-zero by the Eq. 6 upper bound).  Two execution modes share
the math (DESIGN.md §3):

``gradpsi_pallas`` — dense grid (L_tiles, N_tiles).  Skipped tiles run no
  compute (``@pl.when``) and remap their C-tile index to (l, 0, 0), so
  consecutive skipped steps request the same block and Mosaic's revisit
  elision drops the HBM->VMEM DMA.  FLOPs and HBM traffic scale with
  surviving tiles, but the *grid itself* still issues one step per tile.

``gradpsi_pallas_compact`` — compacted grid.  :func:`build_tile_schedule`
  packs the coordinates of surviving tiles into a scalar-prefetched list
  (on-device cumsum + scatter) and the kernel runs a *dynamic* 1-D grid of
  exactly ``max(num_active, 1)`` steps, so grid steps — not just FLOPs and
  DMAs — are proportional to surviving tiles.  Each step writes its partial
  results into a per-step slot; a masked scatter-add outside the kernel
  assembles them (unvisited slots hold garbage and are dropped, never read).

Outputs are partials assembled by ops.py:
  T_rowsum (m_pad,), T_colsum (n,), psi_total scalar — callers form
  value = alpha@a + beta@b - psi, grad_alpha = a - rowsum, grad_beta = b -
  colsum.  The compact kernel additionally returns the grid-step count
  actually issued (the scaling contract asserted by tests).

Batched variants (``solve_batch`` / the OT serving engine) extend both
modes with a leading problem axis B over same-shape problems:

``gradpsi_pallas_batched`` — dense grid (B, L_tiles, N_tiles) with a
  (B, L_tiles, N_tiles) flag matrix; per-(b, l, j) skip/DMA-remap exactly
  as in the solo kernel.

``gradpsi_pallas_compact_batched`` — ONE dynamic grid over the
  concatenated active list of the whole batch: :func:`build_batch_tile_schedule`
  compacts the (B, Lt, Nt) flags into a scalar-prefetched (3, B*T) list of
  (b, l, j) coordinates, so total grid steps equal the batch's total
  surviving tiles.  A heavily-screened problem contributes almost no steps
  instead of padding the batch to its worst member.

Fused screen+gradient mega-kernels (``gradpsi_fused_*``, DESIGN.md §10)
collapse the steady-state oracle's two launches into one: the per-tile
screening verdict (paper Eq. 6/7 — the same :func:`_verdict_tile` math the
standalone screen kernel runs) is computed IN-REGISTER at the top of every
grid step from the snapshot-bound tiles, a tile whose bound test fails
writes zeros without its F/T working set ever leaving VMEM, and the
verdict's per-tile OR lands in a flag output that replaces the standalone
screen launch.  All four operand layouts are covered
(``gradpsi_fused_pallas[_batched]`` dense, ``gradpsi_fused_fact_pallas
[_batched]`` factorized).  The tradeoff: BlockSpec index maps cannot see
in-kernel verdicts, so the fused dense grid cannot remap a skipped tile's
cost (or sample-block) DMA onto a resident block the way the two-launch
grid does — skipped tiles still pay their cost-tile HBM read.  Fused wins
when live density is high or launch overhead dominates; the two-launch
compact path wins under heavy screening.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.screening import ZERO, CHECK, ACTIVE

DEFAULT_TILE_N = 128
VMEM_BUDGET_BYTES = 8 * 1024 * 1024  # one grid step's full working set

# Mosaic's automatic pipelining keeps the NEXT step's blocks in flight while
# the current step computes; granting the compact kernels two working sets of
# VMEM is what lets that double-buffering actually happen for their dynamic
# (scalar-prefetched) schedules instead of serializing DMA behind compute.
COMPACT_PIPELINE_BUFFERS = 2

# Above this fraction of live tiles the dense grid wins: compaction pays an
# O(T) schedule build plus per-step partial-output traffic, while the dense
# grid's only overhead for a skipped tile is an empty (DMA-elided) grid step.
# See DESIGN.md §3 for the model behind the 0.5 crossover.
COMPACT_DENSITY_THRESHOLD = 0.5

# -- trace-time launch accounting ----------------------------------------------
# Each jitted wrapper below bumps its counter ONCE PER FRESH TRACE, so after
# ``jax.clear_caches()`` one solver evaluation records exactly the set of
# pallas_call launches its oracle issues per eval (2 for the two-launch
# screen+grad path, 1 for the fused path).  bench_kernels.py gates on this.

_LAUNCHES: dict = {}


def _record_launch(name: str) -> None:
    """Bump the trace-time launch counter for one Pallas kernel wrapper."""
    _LAUNCHES[name] = _LAUNCHES.get(name, 0) + 1


def launch_counts() -> dict:
    """Snapshot of {kernel wrapper name: traces since last reset}."""
    return dict(_LAUNCHES)


def reset_launch_counts() -> None:
    """Zero the trace-time launch counters (pair with ``jax.clear_caches()``)."""
    _LAUNCHES.clear()


def tile_working_set_bytes(tile_l: int, g: int, tile_n: int, d=None,
                           dtype_bytes: int = 4) -> int:
    """Explicit per-route VMEM bytes held by ONE grid step at TILE_L=tile_l.

    The single byte model shared by :func:`pick_tile_l` (dense route,
    ``d=None``) and :func:`pick_tile_l_factorized` (on-the-fly route,
    ``d`` = sample dimension), pinned by a unit test so the accounting
    cannot silently drift from the kernels:

    - F and T intermediates of :func:`_gradpsi_tile`, always f32;
    - the cost operand: a dense ``(TILE_L, g, TILE_N)`` tile in the cost
      dtype, or — factorized — the f32 product intermediate of
      :func:`factorized_cost_tile` plus the ``(x, x_sq, y, y_sq)`` blocks
      in the sample dtype;
    - dual rows/cols and the tau row;
    - the ga/gb/psi output blocks;
    - the fused route's screening operands (z/k/o f32 tiles, int8 active
      tile, three delta-norm rows + sqrt_g row, db column, flag cell) —
      budgeted unconditionally so fused and two-launch kernels agree on
      tiling and screening flag grids stay interchangeable.
    """
    ft = 2 * tile_l * g * tile_n * 4
    if d is None:
        cost = tile_l * g * tile_n * dtype_bytes
    else:
        cost = (tile_l * g * tile_n * d * 4
                + (tile_l * g + tile_n) * (d + 1) * dtype_bytes)
    duals = (tile_l * g + tile_n + tile_l) * 4
    outputs = (tile_l * g + tile_n + 1) * 4
    screen = (3 * tile_l * tile_n * 4 + tile_l * tile_n
              + (4 * tile_l + tile_n) * 4 + 4)
    return ft + cost + duals + outputs + screen


def pick_tile_l(g: int, tile_n: int, dtype_bytes: int = 4) -> int:
    """Largest TILE_L (power of two, <=8) whose working set fits VMEM."""
    for cand in (8, 4, 2, 1):
        if tile_working_set_bytes(cand, g, tile_n,
                                  dtype_bytes=dtype_bytes) <= VMEM_BUDGET_BYTES:
            return cand
    return 1


def resolve_tile_l(L: int, g: int, tile_n: int, dtype_bytes: int = 4) -> int:
    """VMEM-fitting TILE_L, halved until it divides L (minimizes padding).

    Shared by ops.py and the solver so the screening flag grid and the
    gradient grid always agree on tiling.
    """
    t = pick_tile_l(g, tile_n, dtype_bytes)
    t = min(t, L)
    while t > 1 and L % t:
        t //= 2
    return max(t, 1)


def _gradpsi_tile(alpha, beta, c, tau, *, gamma: float):
    """Shared per-tile math: returns (T (TL, g, TN), psi_sum scalar).

    ``tau`` is the per-group threshold row (TL,) — uniform for the paper's
    group-sparse Psi, zero for pure-l2 (nonnegativity skipping), mixed for
    elastic-net group weights (see core.regularizers).
    """
    f = alpha[:, :, None] + beta[None, None, :] - c
    fp = jnp.maximum(f, 0.0)
    zsq = jnp.sum(fp * fp, axis=1)                   # (TL, TN)
    z = jnp.sqrt(zsq)
    tau_c = tau[:, None]                             # (TL, 1)
    on = z > tau_c
    zs = jnp.where(on, z, 1.0)
    s = jnp.where(on, 1.0 - tau_c / zs, 0.0)         # (TL, TN)
    t = s[:, None, :] * fp * (1.0 / gamma)           # (TL, g, TN)
    # psi closed form (regularizers.psi_from_z)
    mu_s_z = (tau_c / gamma) * s * zs                # mu_l*s*z, tau_l=mu_l*gamma
    psi = jnp.where(on, s * zs * zs / gamma * (1.0 - 0.5 * s) - mu_s_z, 0.0)
    return t, jnp.sum(psi)


def tau_row(tau, L: int) -> jnp.ndarray:
    """Normalize ``tau`` (scalar or per-group ``(L,)``) to an (L,) fp32 row.

    The single definition of the kernel-facing threshold layout — shared
    by the gradient kernels here, the screening kernel, ops.py's padding,
    and the ref.py oracles, so the normalization cannot drift between the
    kernels and the oracles the parity tests compare against.
    """
    return jnp.broadcast_to(jnp.asarray(tau, jnp.float32), (L,))


def _verdict_tile(z, k, o, act, da_plus, da_full, da_neg, db, sqrt_g, tau):
    """Per-tile screening verdicts (paper Eq. 6/7) from loaded VMEM arrays.

    THE single definition of the verdict math: the standalone screen kernel
    (screen.py) and the fused ``gradpsi_fused_*`` kernels both call it on
    identically-blocked operands, which is what makes the fused route's tile
    flags bitwise-equal to the two-launch route's.  ``z``/``k``/``o`` are
    (TL, TN) f32 bound tiles, ``act`` an int8 (TL, TN) persistent-set tile,
    ``da_plus``/``da_full``/``da_neg``/``sqrt_g``/``tau`` (TL,) rows and
    ``db`` a (TN,) column; returns (TL, TN) int32 verdicts.
    """
    dap = da_plus[:, None]                            # (TL, 1)
    daf = da_full[:, None]
    dan = da_neg[:, None]
    sg = sqrt_g[:, None]
    tau_c = tau[:, None]                              # (TL, 1) per-group
    db_r = db[None, :]                                # (1, TN)

    zbar = z + dap + sg * jnp.maximum(db_r, 0.0)
    zlow = (
        k
        - daf
        - sg * jnp.abs(db_r)
        - o
        - dan
        - sg * jnp.maximum(-db_r, 0.0)
    )
    active = act != 0
    v = jnp.where(zbar <= tau_c, ZERO, CHECK)
    v = jnp.where(active, ACTIVE, v)
    # lower bound can also certify non-zero outside N within this eval
    v = jnp.where(jnp.logical_and(v == CHECK, zlow > tau_c), ACTIVE, v)
    return v.astype(jnp.int32)


def _dense_kernel(flags_ref, alpha_ref, beta_ref, c_ref, tau_ref,
                  ga_ref, gb_ref, psi_ref, *, gamma: float):
    l = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init_ga():
        ga_ref[...] = jnp.zeros_like(ga_ref)

    @pl.when(jnp.logical_and(l == 0, j == 0))
    def _init_psi():
        psi_ref[...] = jnp.zeros_like(psi_ref)

    gb_ref[...] = jnp.zeros_like(gb_ref)

    flag = flags_ref[l, j]

    @pl.when(flag != 0)
    def _compute():
        alpha = alpha_ref[...].astype(jnp.float32)       # (TL, g)
        beta = beta_ref[...].astype(jnp.float32)         # (TN,)
        c = c_ref[...].astype(jnp.float32)               # (TL, g, TN)
        tau = tau_ref[...].astype(jnp.float32)           # (TL,)
        t, psi = _gradpsi_tile(alpha, beta, c, tau, gamma=gamma)
        psi_ref[0, 0] += psi
        ga_ref[...] += jnp.sum(t, axis=2)                # (TL, g)
        gb_ref[...] = jnp.sum(t, axis=(0, 1))[None, :]   # (1, TN)


@functools.partial(
    jax.jit,
    static_argnames=("num_groups", "group_size", "gamma",
                     "tile_l", "tile_n", "interpret"),
)
def gradpsi_pallas(
    alpha: jnp.ndarray,        # (m_pad,) fp32
    beta: jnp.ndarray,         # (n,) fp32
    C: jnp.ndarray,            # (m_pad, n) fp32 or bf16
    flags: jnp.ndarray,        # (L_tiles, N_tiles) int32 tile skip flags
    *,
    num_groups: int,
    group_size: int,
    tau,
    gamma: float,
    tile_l: int = 0,
    tile_n: int = DEFAULT_TILE_N,
    interpret: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Dense-grid kernel: returns (T_rowsum (m_pad,), T_colsum (n,), psi).

    n and L must be padded to tile multiples (ops.py handles padding).
    ``tau`` is a scalar or a per-group ``(L,)`` threshold vector (the
    regularizer subsystem's per-group screening thresholds); it is a
    kernel *operand*, loaded one (tile_l,) row per tile.
    """
    _record_launch("gradpsi_pallas")
    L, g = num_groups, group_size
    n = beta.shape[0]
    tau_g = tau_row(tau, L)
    if tile_l == 0:
        tile_l = pick_tile_l(g, tile_n, jnp.dtype(C.dtype).itemsize)
    assert L % tile_l == 0 and n % tile_n == 0, (L, tile_l, n, tile_n)
    grid = (L // tile_l, n // tile_n)
    assert flags.shape == grid, (flags.shape, grid)

    alpha_g = alpha.reshape(L, g)
    C3 = C.reshape(L, g, n)

    def c_index(l, j, flags_ref):
        # remap skipped tiles to (l, 0, 0): consecutive skipped steps request
        # the same block => the DMA is elided (revisit optimization).
        active = flags_ref[l, j] != 0
        return (l, 0, jnp.where(active, j, 0))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_l, g), lambda l, j, f: (l, 0)),
            pl.BlockSpec((tile_n,), lambda l, j, f: (j,)),
            pl.BlockSpec((tile_l, g, tile_n), c_index),
            pl.BlockSpec((tile_l,), lambda l, j, f: (l,)),
        ],
        out_specs=[
            pl.BlockSpec((tile_l, g), lambda l, j, f: (l, 0)),
            pl.BlockSpec((1, tile_n), lambda l, j, f: (l, j)),
            pl.BlockSpec((1, 1), lambda l, j, f: (0, 0)),
        ],
    )

    ga_part, gb_part, psi = pl.pallas_call(
        functools.partial(_dense_kernel, gamma=float(gamma)),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((L, g), jnp.float32),
            jax.ShapeDtypeStruct((grid[0], n), jnp.float32),
            jax.ShapeDtypeStruct((1, 1), jnp.float32),
        ],
        interpret=interpret,
    )(flags, alpha_g, beta, C3, tau_g)

    return ga_part.reshape(-1), jnp.sum(gb_part, axis=0), psi[0, 0]


def build_tile_schedule(flags: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Compact the (L_tiles, N_tiles) flag matrix into an active-tile list.

    Returns ``(sched (2, T) int32, num_active () int32)`` where
    ``sched[:, s] = (l, j)`` of the s-th surviving tile in row-major order
    and entries past ``num_active`` repeat the last surviving coordinate
    (so the pipeline's block lookahead lands on an already-resident tile).
    All on-device: one cumsum + one scatter, O(T) with T = L_tiles * N_tiles.
    """
    Lt, Nt = flags.shape
    T = Lt * Nt
    flat = flags.reshape(-1) != 0
    num_active = jnp.sum(flat).astype(jnp.int32)
    pos = jnp.cumsum(flat).astype(jnp.int32) - 1      # rank among survivors
    idx = jnp.arange(T, dtype=jnp.int32)
    dest = jnp.where(flat, pos, T)                    # dead tiles -> dropped
    order = jnp.zeros((T,), jnp.int32).at[dest].set(idx, mode="drop")
    last = jnp.where(num_active > 0, order[jnp.maximum(num_active - 1, 0)], 0)
    order = jnp.where(idx < num_active, order, last)
    sched = jnp.stack([order // Nt, order % Nt])
    return sched, num_active


def _compact_kernel(sched_ref, nact_ref, alpha_ref, beta_ref, c_ref, tau_ref,
                    ga_ref, gb_ref, psi_ref, steps_ref,
                    *, gamma: float):
    s = pl.program_id(0)

    @pl.when(s == 0)
    def _init_steps():
        steps_ref[0, 0] = 0

    steps_ref[0, 0] += 1

    alpha = alpha_ref[...].astype(jnp.float32)           # (TL, g)
    beta = beta_ref[...].astype(jnp.float32)             # (TN,)
    c = c_ref[...].astype(jnp.float32)                   # (TL, g, TN)
    tau = tau_ref[...].astype(jnp.float32)               # (TL,)
    t, psi = _gradpsi_tile(alpha, beta, c, tau, gamma=gamma)
    # per-step slots: every visited block is written exactly once, so no
    # cross-step accumulation state and no uninitialized revisits.
    ga_ref[...] = jnp.sum(t, axis=2)[None]               # (1, TL, g)
    gb_ref[...] = jnp.sum(t, axis=(0, 1))[None, :]       # (1, TN)
    psi_ref[0, 0] = psi


@functools.partial(
    jax.jit,
    static_argnames=("num_groups", "group_size", "gamma",
                     "tile_l", "tile_n", "interpret"),
)
def gradpsi_pallas_compact(
    alpha: jnp.ndarray,        # (m_pad,) fp32
    beta: jnp.ndarray,         # (n,) fp32
    C: jnp.ndarray,            # (m_pad, n) fp32 or bf16
    sched: jnp.ndarray,        # (2, T) int32 from build_tile_schedule
    num_active: jnp.ndarray,   # () int32 surviving-tile count
    *,
    num_groups: int,
    group_size: int,
    tau,
    gamma: float,
    tile_l: int = 0,
    tile_n: int = DEFAULT_TILE_N,
    interpret: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Compacted-grid kernel: grid steps scale with surviving tiles.

    Returns (T_rowsum (m_pad,), T_colsum (n,), psi, steps_issued ()).
    With ``num_active == 0`` one sentinel step runs (a grid cannot be empty)
    and its outputs are masked to exact zeros.  ``tau`` is a scalar or a
    per-group ``(L,)`` threshold vector, gathered per scheduled tile.
    """
    _record_launch("gradpsi_pallas_compact")
    L, g = num_groups, group_size
    n = beta.shape[0]
    tau_g = tau_row(tau, L)
    if tile_l == 0:
        tile_l = pick_tile_l(g, tile_n, jnp.dtype(C.dtype).itemsize)
    assert L % tile_l == 0 and n % tile_n == 0, (L, tile_l, n, tile_n)
    Lt, Nt = L // tile_l, n // tile_n
    T = Lt * Nt
    assert sched.shape == (2, T), (sched.shape, (2, T))

    alpha_g = alpha.reshape(L, g)
    C3 = C.reshape(L, g, n)
    num_active = num_active.astype(jnp.int32)
    nact = num_active.reshape(1)
    num_steps = jnp.maximum(num_active, 1)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(num_steps,),
        in_specs=[
            pl.BlockSpec((tile_l, g), lambda s, sc, na: (sc[0, s], 0)),
            pl.BlockSpec((tile_n,), lambda s, sc, na: (sc[1, s],)),
            pl.BlockSpec((tile_l, g, tile_n),
                         lambda s, sc, na: (sc[0, s], 0, sc[1, s])),
            pl.BlockSpec((tile_l,), lambda s, sc, na: (sc[0, s],)),
        ],
        out_specs=[
            pl.BlockSpec((1, tile_l, g), lambda s, sc, na: (s, 0, 0)),
            pl.BlockSpec((1, tile_n), lambda s, sc, na: (s, 0)),
            pl.BlockSpec((1, 1), lambda s, sc, na: (s, 0)),
            pl.BlockSpec((1, 1), lambda s, sc, na: (0, 0)),
        ],
    )

    ga_steps, gb_steps, psi_steps, steps = pl.pallas_call(
        functools.partial(_compact_kernel, gamma=float(gamma)),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((T, tile_l, g), jnp.float32),
            jax.ShapeDtypeStruct((T, tile_n), jnp.float32),
            jax.ShapeDtypeStruct((T, 1), jnp.float32),
            jax.ShapeDtypeStruct((1, 1), jnp.int32),
        ],
        compiler_params=pltpu.TPUCompilerParams(
            vmem_limit_bytes=COMPACT_PIPELINE_BUFFERS * VMEM_BUDGET_BYTES,
        ),
        interpret=interpret,
    )(sched, nact, alpha_g, beta, C3, tau_g)

    # assemble: slots past num_active were never visited (garbage) — route
    # them to an out-of-range segment so the scatter drops them.
    idx = jnp.arange(T, dtype=jnp.int32)
    valid = idx < num_active
    seg_l = jnp.where(valid, sched[0], Lt)
    seg_n = jnp.where(valid, sched[1], Nt)
    ga = jnp.zeros((Lt, tile_l, g), jnp.float32).at[seg_l].add(
        ga_steps, mode="drop"
    )
    gb = jnp.zeros((Nt, tile_n), jnp.float32).at[seg_n].add(
        gb_steps, mode="drop"
    )
    psi = jnp.sum(jnp.where(valid[:, None], psi_steps, 0.0))
    return ga.reshape(-1), gb.reshape(-1), psi, steps[0, 0]


# -- batched variants (leading problem axis B) --------------------------------

def _dense_kernel_batched(flags_ref, alpha_ref, beta_ref, c_ref, tau_ref,
                          ga_ref, gb_ref, psi_ref, *, gamma: float):
    bi = pl.program_id(0)
    l = pl.program_id(1)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init_ga():
        ga_ref[...] = jnp.zeros_like(ga_ref)

    @pl.when(jnp.logical_and(l == 0, j == 0))
    def _init_psi():
        psi_ref[...] = jnp.zeros_like(psi_ref)

    gb_ref[...] = jnp.zeros_like(gb_ref)

    flag = flags_ref[bi, l, j]

    @pl.when(flag != 0)
    def _compute():
        alpha = alpha_ref[0].astype(jnp.float32)         # (TL, g)
        beta = beta_ref[0].astype(jnp.float32)           # (TN,)
        c = c_ref[0].astype(jnp.float32)                 # (TL, g, TN)
        tau = tau_ref[...].astype(jnp.float32)           # (TL,)
        t, psi = _gradpsi_tile(alpha, beta, c, tau, gamma=gamma)
        psi_ref[0, 0, 0] += psi
        ga_ref[...] += jnp.sum(t, axis=2)[None]          # (1, TL, g)
        gb_ref[...] = jnp.sum(t, axis=(0, 1))[None, None, :]  # (1, 1, TN)


@functools.partial(
    jax.jit,
    static_argnames=("num_groups", "group_size", "gamma",
                     "tile_l", "tile_n", "interpret"),
)
def gradpsi_pallas_batched(
    alpha: jnp.ndarray,        # (B, m_pad) fp32
    beta: jnp.ndarray,         # (B, n) fp32
    C: jnp.ndarray,            # (B, m_pad, n) fp32 or bf16
    flags: jnp.ndarray,        # (B, L_tiles, N_tiles) int32 tile skip flags
    *,
    num_groups: int,
    group_size: int,
    tau,
    gamma: float,
    tile_l: int = 0,
    tile_n: int = DEFAULT_TILE_N,
    interpret: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Dense-grid kernel over B problems: grid (B, L_tiles, N_tiles).

    Returns (T_rowsum (B, m_pad), T_colsum (B, n), psi (B,)).  Semantics
    per problem are identical to :func:`gradpsi_pallas`.  ``tau`` (scalar
    or per-group ``(L,)``) is shared by the whole batch — a bucket packs
    problems with one regularizer, so thresholds are batch-static.
    """
    _record_launch("gradpsi_pallas_batched")
    L, g = num_groups, group_size
    B, n = beta.shape
    tau_g = tau_row(tau, L)
    if tile_l == 0:
        tile_l = pick_tile_l(g, tile_n, jnp.dtype(C.dtype).itemsize)
    assert L % tile_l == 0 and n % tile_n == 0, (L, tile_l, n, tile_n)
    grid = (B, L // tile_l, n // tile_n)
    assert flags.shape == grid, (flags.shape, grid)

    alpha_g = alpha.reshape(B, L, g)
    C4 = C.reshape(B, L, g, n)

    def c_index(b, l, j, flags_ref):
        # remap skipped tiles to column 0: consecutive skipped steps request
        # the same block => the DMA is elided (revisit optimization).
        active = flags_ref[b, l, j] != 0
        return (b, l, 0, jnp.where(active, j, 0))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, tile_l, g), lambda b, l, j, f: (b, l, 0)),
            pl.BlockSpec((1, tile_n), lambda b, l, j, f: (b, j)),
            pl.BlockSpec((1, tile_l, g, tile_n), c_index),
            pl.BlockSpec((tile_l,), lambda b, l, j, f: (l,)),
        ],
        out_specs=[
            pl.BlockSpec((1, tile_l, g), lambda b, l, j, f: (b, l, 0)),
            pl.BlockSpec((1, 1, tile_n), lambda b, l, j, f: (b, l, j)),
            pl.BlockSpec((1, 1, 1), lambda b, l, j, f: (b, 0, 0)),
        ],
    )

    ga_part, gb_part, psi = pl.pallas_call(
        functools.partial(_dense_kernel_batched, gamma=float(gamma)),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((B, L, g), jnp.float32),
            jax.ShapeDtypeStruct((B, grid[1], n), jnp.float32),
            jax.ShapeDtypeStruct((B, 1, 1), jnp.float32),
        ],
        interpret=interpret,
    )(flags, alpha_g, beta, C4, tau_g)

    return (
        ga_part.reshape(B, -1),
        jnp.sum(gb_part, axis=1),
        psi[:, 0, 0],
    )


def build_batch_tile_schedule(
    flags: jnp.ndarray,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Compact (B, Lt, Nt) flags into one concatenated active-tile list.

    Returns ``(sched (3, B*T) int32, num_active () int32)`` where
    ``sched[:, s] = (b, l, j)`` of the s-th surviving tile in
    (problem-major, then row-major) order and ``num_active`` is the TOTAL
    surviving count across the batch.  Entries past ``num_active`` repeat
    the last surviving coordinate (pipeline lookahead lands on a resident
    block).  Because the list concatenates per-problem schedules, a
    heavily-screened problem contributes few steps — the batch never pads
    to its worst member.
    """
    B, Lt, Nt = flags.shape
    T = Lt * Nt
    BT = B * T
    flat = flags.reshape(-1) != 0
    num_active = jnp.sum(flat).astype(jnp.int32)
    pos = jnp.cumsum(flat).astype(jnp.int32) - 1      # rank among survivors
    idx = jnp.arange(BT, dtype=jnp.int32)
    dest = jnp.where(flat, pos, BT)                   # dead tiles -> dropped
    order = jnp.zeros((BT,), jnp.int32).at[dest].set(idx, mode="drop")
    last = jnp.where(num_active > 0, order[jnp.maximum(num_active - 1, 0)], 0)
    order = jnp.where(idx < num_active, order, last)
    sched = jnp.stack([order // T, (order % T) // Nt, order % Nt])
    return sched, num_active


def _compact_kernel_batched(sched_ref, nact_ref, alpha_ref, beta_ref, c_ref,
                            tau_ref, ga_ref, gb_ref, psi_ref, steps_ref,
                            *, gamma: float):
    s = pl.program_id(0)

    @pl.when(s == 0)
    def _init_steps():
        steps_ref[0, 0] = 0

    steps_ref[0, 0] += 1

    alpha = alpha_ref[0].astype(jnp.float32)             # (TL, g)
    beta = beta_ref[0].astype(jnp.float32)               # (TN,)
    c = c_ref[0].astype(jnp.float32)                     # (TL, g, TN)
    tau = tau_ref[...].astype(jnp.float32)               # (TL,)
    t, psi = _gradpsi_tile(alpha, beta, c, tau, gamma=gamma)
    # per-step slots: every visited block is written exactly once, so no
    # cross-step accumulation state and no uninitialized revisits.
    ga_ref[...] = jnp.sum(t, axis=2)[None]               # (1, TL, g)
    gb_ref[...] = jnp.sum(t, axis=(0, 1))[None, :]       # (1, TN)
    psi_ref[0, 0] = psi


@functools.partial(
    jax.jit,
    static_argnames=("num_groups", "group_size", "gamma",
                     "tile_l", "tile_n", "interpret"),
)
def gradpsi_pallas_compact_batched(
    alpha: jnp.ndarray,        # (B, m_pad) fp32
    beta: jnp.ndarray,         # (B, n) fp32
    C: jnp.ndarray,            # (B, m_pad, n) fp32 or bf16
    sched: jnp.ndarray,        # (3, B*T) int32 from build_batch_tile_schedule
    num_active: jnp.ndarray,   # () int32 TOTAL surviving-tile count
    *,
    num_groups: int,
    group_size: int,
    tau,
    gamma: float,
    tile_l: int = 0,
    tile_n: int = DEFAULT_TILE_N,
    interpret: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Compacted-grid kernel over B problems: ONE dynamic grid of exactly
    ``max(num_active, 1)`` steps covering the whole batch's surviving tiles.

    Returns (T_rowsum (B, m_pad), T_colsum (B, n), psi (B,), steps ()).
    With ``num_active == 0`` one sentinel step runs (a grid cannot be
    empty) and its outputs are masked to exact zeros.  ``tau`` (scalar or
    per-group ``(L,)``) is shared batch-wide, gathered per scheduled tile.
    """
    _record_launch("gradpsi_pallas_compact_batched")
    L, g = num_groups, group_size
    B, n = beta.shape
    tau_g = tau_row(tau, L)
    if tile_l == 0:
        tile_l = pick_tile_l(g, tile_n, jnp.dtype(C.dtype).itemsize)
    assert L % tile_l == 0 and n % tile_n == 0, (L, tile_l, n, tile_n)
    Lt, Nt = L // tile_l, n // tile_n
    BT = B * Lt * Nt
    assert sched.shape == (3, BT), (sched.shape, (3, BT))

    alpha_g = alpha.reshape(B, L, g)
    C4 = C.reshape(B, L, g, n)
    num_active = num_active.astype(jnp.int32)
    nact = num_active.reshape(1)
    num_steps = jnp.maximum(num_active, 1)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(num_steps,),
        in_specs=[
            pl.BlockSpec((1, tile_l, g),
                         lambda s, sc, na: (sc[0, s], sc[1, s], 0)),
            pl.BlockSpec((1, tile_n), lambda s, sc, na: (sc[0, s], sc[2, s])),
            pl.BlockSpec((1, tile_l, g, tile_n),
                         lambda s, sc, na: (sc[0, s], sc[1, s], 0, sc[2, s])),
            pl.BlockSpec((tile_l,), lambda s, sc, na: (sc[1, s],)),
        ],
        out_specs=[
            pl.BlockSpec((1, tile_l, g), lambda s, sc, na: (s, 0, 0)),
            pl.BlockSpec((1, tile_n), lambda s, sc, na: (s, 0)),
            pl.BlockSpec((1, 1), lambda s, sc, na: (s, 0)),
            pl.BlockSpec((1, 1), lambda s, sc, na: (0, 0)),
        ],
    )

    ga_steps, gb_steps, psi_steps, steps = pl.pallas_call(
        functools.partial(_compact_kernel_batched, gamma=float(gamma)),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((BT, tile_l, g), jnp.float32),
            jax.ShapeDtypeStruct((BT, tile_n), jnp.float32),
            jax.ShapeDtypeStruct((BT, 1), jnp.float32),
            jax.ShapeDtypeStruct((1, 1), jnp.int32),
        ],
        compiler_params=pltpu.TPUCompilerParams(
            vmem_limit_bytes=COMPACT_PIPELINE_BUFFERS * VMEM_BUDGET_BYTES,
        ),
        interpret=interpret,
    )(sched, nact, alpha_g, beta, C4, tau_g)

    # assemble: slots past num_active were never visited (garbage) — route
    # them to an out-of-range segment so the scatter drops them.  Segments
    # are flattened (b, l) / (b, j) / (b,) ids; each problem's steps stay in
    # schedule order, so per-problem accumulation order is batch-invariant.
    idx = jnp.arange(BT, dtype=jnp.int32)
    valid = idx < num_active
    seg_ga = jnp.where(valid, sched[0] * Lt + sched[1], B * Lt)
    seg_gb = jnp.where(valid, sched[0] * Nt + sched[2], B * Nt)
    seg_psi = jnp.where(valid, sched[0], B)
    ga = jnp.zeros((B * Lt, tile_l, g), jnp.float32).at[seg_ga].add(
        ga_steps, mode="drop"
    )
    gb = jnp.zeros((B * Nt, tile_n), jnp.float32).at[seg_gb].add(
        gb_steps, mode="drop"
    )
    psi = jnp.zeros((B,), jnp.float32).at[seg_psi].add(
        psi_steps[:, 0], mode="drop"
    )
    return ga.reshape(B, -1), gb.reshape(B, -1), psi, steps[0, 0]


# -- materialization-free (factorized squared-l2) variants ---------------------
#
# Instead of a dense (m_pad, n) C operand, these kernels take the raw sample
# blocks and precomputed squared norms of a SquaredL2Geometry (docs/geometry.md)
# and rebuild each cost tile in VMEM via the factorization
#     c[i, j] = max(|x_i|^2 + |y_j|^2 - 2 <x_i, y_j>, 0)
# so HBM traffic per tile is O((tile_l*g + tile_n) * d) instead of
# O(tile_l*g*tile_n).  `factorized_cost_tile` below is THE single definition of
# the recipe: geometry.py materializes with the same function, which is what
# makes the on-the-fly route bitwise-equal to the materialized-dense route.


def factorized_cost_tile(x, x_sq, y, y_sq):
    """On-the-fly squared-l2 cost tile: ``max(x2 + y2 - 2<x,y>, 0)``.

    ``x`` is ``(..., R, d)`` with matching ``x_sq (..., R)``; ``y`` is
    ``(TN, d)`` with ``y_sq (TN,)``; returns ``(..., R, TN)``.  The inner
    product is an elementwise product reduced over ``d`` (NOT a matmul), so
    every output element sees the identical f32 operation sequence no matter
    how the caller tiles or chunks — the bitwise contract between the Pallas
    kernels and :meth:`repro.ot.geometry.SquaredL2Geometry.materialize`.
    """
    lead = x.shape[:-1]
    x2 = x.reshape((-1, x.shape[-1]))
    xy = jnp.sum(x2[:, None, :] * y[None, :, :], axis=-1)
    c = jnp.maximum(
        x_sq.reshape((-1,))[:, None] + y_sq[None, :] - 2.0 * xy, 0.0
    )
    return c.reshape(lead + (y.shape[0],))


def pick_tile_l_factorized(g: int, tile_n: int, d: int,
                           dtype_bytes: int = 4) -> int:
    """Largest TILE_L (power of two, <=8) whose factorized tile fits VMEM.

    Same explicit byte model as :func:`pick_tile_l`
    (:func:`tile_working_set_bytes` with ``d`` set): the working set swaps
    the dense cost tile for the ``(TILE_L, g, TILE_N, d)`` product
    intermediate of :func:`factorized_cost_tile` plus its sample blocks.
    """
    for cand in (8, 4, 2, 1):
        if tile_working_set_bytes(cand, g, tile_n, d=d,
                                  dtype_bytes=dtype_bytes) <= VMEM_BUDGET_BYTES:
            return cand
    return 1


def resolve_tile_l_factorized(L: int, g: int, tile_n: int, d: int,
                              dtype_bytes: int = 4) -> int:
    """VMEM-fitting factorized TILE_L, halved until it divides L."""
    t = pick_tile_l_factorized(g, tile_n, d, dtype_bytes)
    t = min(t, L)
    while t > 1 and L % t:
        t //= 2
    return max(t, 1)


def _dense_kernel_fact(flags_ref, alpha_ref, beta_ref, x_ref, xsq_ref,
                       y_ref, ysq_ref, tau_ref,
                       ga_ref, gb_ref, psi_ref, *, gamma: float):
    l = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init_ga_f():
        ga_ref[...] = jnp.zeros_like(ga_ref)

    @pl.when(jnp.logical_and(l == 0, j == 0))
    def _init_psi_f():
        psi_ref[...] = jnp.zeros_like(psi_ref)

    gb_ref[...] = jnp.zeros_like(gb_ref)

    flag = flags_ref[l, j]

    @pl.when(flag != 0)
    def _compute_f():
        alpha = alpha_ref[...].astype(jnp.float32)       # (TL, g)
        beta = beta_ref[...].astype(jnp.float32)         # (TN,)
        c = factorized_cost_tile(
            x_ref[...].astype(jnp.float32),              # (TL, g, d)
            xsq_ref[...].astype(jnp.float32),            # (TL, g)
            y_ref[...].astype(jnp.float32),              # (TN, d)
            ysq_ref[...].astype(jnp.float32),            # (TN,)
        )
        tau = tau_ref[...].astype(jnp.float32)           # (TL,)
        t, psi = _gradpsi_tile(alpha, beta, c, tau, gamma=gamma)
        psi_ref[0, 0] += psi
        ga_ref[...] += jnp.sum(t, axis=2)                # (TL, g)
        gb_ref[...] = jnp.sum(t, axis=(0, 1))[None, :]   # (1, TN)


@functools.partial(
    jax.jit,
    static_argnames=("num_groups", "group_size", "gamma",
                     "tile_l", "tile_n", "interpret"),
)
def gradpsi_fact_pallas(
    alpha: jnp.ndarray,        # (m_pad,) fp32
    beta: jnp.ndarray,         # (n,) fp32
    x: jnp.ndarray,            # (m_pad, d) fp32 scaled source samples
    x_sq: jnp.ndarray,         # (m_pad,) fp32 scaled squared norms
    y: jnp.ndarray,            # (n, d) fp32 scaled target samples
    y_sq: jnp.ndarray,         # (n,) fp32 scaled squared norms
    flags: jnp.ndarray,        # (L_tiles, N_tiles) int32 tile skip flags
    *,
    num_groups: int,
    group_size: int,
    tau,
    gamma: float,
    tile_l: int = 0,
    tile_n: int = DEFAULT_TILE_N,
    interpret: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Dense-grid factorized kernel: cost tiles built in VMEM from samples.

    Same outputs and skip semantics as :func:`gradpsi_pallas`; the C operand
    is replaced by ``(x, x_sq, y, y_sq)`` blocked operands.  Skipped tiles
    remap the column-indexed ``y``/``y_sq`` blocks to column 0 so the DMA is
    elided exactly like the dense kernel's C tile.
    """
    _record_launch("gradpsi_fact_pallas")
    L, g = num_groups, group_size
    n = beta.shape[0]
    d = x.shape[-1]
    tau_g = tau_row(tau, L)
    if tile_l == 0:
        tile_l = pick_tile_l_factorized(g, tile_n, d,
                                        jnp.dtype(x.dtype).itemsize)
    assert L % tile_l == 0 and n % tile_n == 0, (L, tile_l, n, tile_n)
    grid = (L // tile_l, n // tile_n)
    assert flags.shape == grid, (flags.shape, grid)

    alpha_g = alpha.reshape(L, g)
    x3 = x.reshape(L, g, d)
    xsq_g = x_sq.reshape(L, g)

    def y_index(l, j, flags_ref):
        active = flags_ref[l, j] != 0
        return (jnp.where(active, j, 0), 0)

    def ysq_index(l, j, flags_ref):
        active = flags_ref[l, j] != 0
        return (jnp.where(active, j, 0),)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_l, g), lambda l, j, f: (l, 0)),
            pl.BlockSpec((tile_n,), lambda l, j, f: (j,)),
            pl.BlockSpec((tile_l, g, d), lambda l, j, f: (l, 0, 0)),
            pl.BlockSpec((tile_l, g), lambda l, j, f: (l, 0)),
            pl.BlockSpec((tile_n, d), y_index),
            pl.BlockSpec((tile_n,), ysq_index),
            pl.BlockSpec((tile_l,), lambda l, j, f: (l,)),
        ],
        out_specs=[
            pl.BlockSpec((tile_l, g), lambda l, j, f: (l, 0)),
            pl.BlockSpec((1, tile_n), lambda l, j, f: (l, j)),
            pl.BlockSpec((1, 1), lambda l, j, f: (0, 0)),
        ],
    )

    ga_part, gb_part, psi = pl.pallas_call(
        functools.partial(_dense_kernel_fact, gamma=float(gamma)),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((L, g), jnp.float32),
            jax.ShapeDtypeStruct((grid[0], n), jnp.float32),
            jax.ShapeDtypeStruct((1, 1), jnp.float32),
        ],
        interpret=interpret,
    )(flags, alpha_g, beta, x3, xsq_g, y, y_sq, tau_g)

    return ga_part.reshape(-1), jnp.sum(gb_part, axis=0), psi[0, 0]


def _compact_kernel_fact(sched_ref, nact_ref, alpha_ref, beta_ref, x_ref,
                         xsq_ref, y_ref, ysq_ref, tau_ref,
                         ga_ref, gb_ref, psi_ref, steps_ref,
                         *, gamma: float):
    s = pl.program_id(0)

    @pl.when(s == 0)
    def _init_steps_f():
        steps_ref[0, 0] = 0

    steps_ref[0, 0] += 1

    alpha = alpha_ref[...].astype(jnp.float32)           # (TL, g)
    beta = beta_ref[...].astype(jnp.float32)             # (TN,)
    c = factorized_cost_tile(
        x_ref[...].astype(jnp.float32),
        xsq_ref[...].astype(jnp.float32),
        y_ref[...].astype(jnp.float32),
        ysq_ref[...].astype(jnp.float32),
    )
    tau = tau_ref[...].astype(jnp.float32)               # (TL,)
    t, psi = _gradpsi_tile(alpha, beta, c, tau, gamma=gamma)
    ga_ref[...] = jnp.sum(t, axis=2)[None]               # (1, TL, g)
    gb_ref[...] = jnp.sum(t, axis=(0, 1))[None, :]       # (1, TN)
    psi_ref[0, 0] = psi


@functools.partial(
    jax.jit,
    static_argnames=("num_groups", "group_size", "gamma",
                     "tile_l", "tile_n", "interpret"),
)
def gradpsi_fact_pallas_compact(
    alpha: jnp.ndarray,        # (m_pad,) fp32
    beta: jnp.ndarray,         # (n,) fp32
    x: jnp.ndarray,            # (m_pad, d) fp32
    x_sq: jnp.ndarray,         # (m_pad,) fp32
    y: jnp.ndarray,            # (n, d) fp32
    y_sq: jnp.ndarray,         # (n,) fp32
    sched: jnp.ndarray,        # (2, T) int32 from build_tile_schedule
    num_active: jnp.ndarray,   # () int32 surviving-tile count
    *,
    num_groups: int,
    group_size: int,
    tau,
    gamma: float,
    tile_l: int = 0,
    tile_n: int = DEFAULT_TILE_N,
    interpret: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Compacted-grid factorized kernel: steps scale with surviving tiles.

    Same contract as :func:`gradpsi_pallas_compact` with the C operand
    replaced by ``(x, x_sq, y, y_sq)`` blocked operands.
    """
    _record_launch("gradpsi_fact_pallas_compact")
    L, g = num_groups, group_size
    n = beta.shape[0]
    d = x.shape[-1]
    tau_g = tau_row(tau, L)
    if tile_l == 0:
        tile_l = pick_tile_l_factorized(g, tile_n, d,
                                        jnp.dtype(x.dtype).itemsize)
    assert L % tile_l == 0 and n % tile_n == 0, (L, tile_l, n, tile_n)
    Lt, Nt = L // tile_l, n // tile_n
    T = Lt * Nt
    assert sched.shape == (2, T), (sched.shape, (2, T))

    alpha_g = alpha.reshape(L, g)
    x3 = x.reshape(L, g, d)
    xsq_g = x_sq.reshape(L, g)
    num_active = num_active.astype(jnp.int32)
    nact = num_active.reshape(1)
    num_steps = jnp.maximum(num_active, 1)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(num_steps,),
        in_specs=[
            pl.BlockSpec((tile_l, g), lambda s, sc, na: (sc[0, s], 0)),
            pl.BlockSpec((tile_n,), lambda s, sc, na: (sc[1, s],)),
            pl.BlockSpec((tile_l, g, d), lambda s, sc, na: (sc[0, s], 0, 0)),
            pl.BlockSpec((tile_l, g), lambda s, sc, na: (sc[0, s], 0)),
            pl.BlockSpec((tile_n, d), lambda s, sc, na: (sc[1, s], 0)),
            pl.BlockSpec((tile_n,), lambda s, sc, na: (sc[1, s],)),
            pl.BlockSpec((tile_l,), lambda s, sc, na: (sc[0, s],)),
        ],
        out_specs=[
            pl.BlockSpec((1, tile_l, g), lambda s, sc, na: (s, 0, 0)),
            pl.BlockSpec((1, tile_n), lambda s, sc, na: (s, 0)),
            pl.BlockSpec((1, 1), lambda s, sc, na: (s, 0)),
            pl.BlockSpec((1, 1), lambda s, sc, na: (0, 0)),
        ],
    )

    ga_steps, gb_steps, psi_steps, steps = pl.pallas_call(
        functools.partial(_compact_kernel_fact, gamma=float(gamma)),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((T, tile_l, g), jnp.float32),
            jax.ShapeDtypeStruct((T, tile_n), jnp.float32),
            jax.ShapeDtypeStruct((T, 1), jnp.float32),
            jax.ShapeDtypeStruct((1, 1), jnp.int32),
        ],
        compiler_params=pltpu.TPUCompilerParams(
            vmem_limit_bytes=COMPACT_PIPELINE_BUFFERS * VMEM_BUDGET_BYTES,
        ),
        interpret=interpret,
    )(sched, nact, alpha_g, beta, x3, xsq_g, y, y_sq, tau_g)

    idx = jnp.arange(T, dtype=jnp.int32)
    valid = idx < num_active
    seg_l = jnp.where(valid, sched[0], Lt)
    seg_n = jnp.where(valid, sched[1], Nt)
    ga = jnp.zeros((Lt, tile_l, g), jnp.float32).at[seg_l].add(
        ga_steps, mode="drop"
    )
    gb = jnp.zeros((Nt, tile_n), jnp.float32).at[seg_n].add(
        gb_steps, mode="drop"
    )
    psi = jnp.sum(jnp.where(valid[:, None], psi_steps, 0.0))
    return ga.reshape(-1), gb.reshape(-1), psi, steps[0, 0]


def _dense_kernel_fact_batched(flags_ref, alpha_ref, beta_ref, x_ref, xsq_ref,
                               y_ref, ysq_ref, tau_ref,
                               ga_ref, gb_ref, psi_ref, *, gamma: float):
    bi = pl.program_id(0)
    l = pl.program_id(1)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init_ga_fb():
        ga_ref[...] = jnp.zeros_like(ga_ref)

    @pl.when(jnp.logical_and(l == 0, j == 0))
    def _init_psi_fb():
        psi_ref[...] = jnp.zeros_like(psi_ref)

    gb_ref[...] = jnp.zeros_like(gb_ref)

    flag = flags_ref[bi, l, j]

    @pl.when(flag != 0)
    def _compute_fb():
        alpha = alpha_ref[0].astype(jnp.float32)         # (TL, g)
        beta = beta_ref[0].astype(jnp.float32)           # (TN,)
        c = factorized_cost_tile(
            x_ref[0].astype(jnp.float32),                # (TL, g, d)
            xsq_ref[0].astype(jnp.float32),              # (TL, g)
            y_ref[0].astype(jnp.float32),                # (TN, d)
            ysq_ref[0].astype(jnp.float32),              # (TN,)
        )
        tau = tau_ref[...].astype(jnp.float32)           # (TL,)
        t, psi = _gradpsi_tile(alpha, beta, c, tau, gamma=gamma)
        psi_ref[0, 0, 0] += psi
        ga_ref[...] += jnp.sum(t, axis=2)[None]          # (1, TL, g)
        gb_ref[...] = jnp.sum(t, axis=(0, 1))[None, None, :]  # (1, 1, TN)


@functools.partial(
    jax.jit,
    static_argnames=("num_groups", "group_size", "gamma",
                     "tile_l", "tile_n", "interpret"),
)
def gradpsi_fact_pallas_batched(
    alpha: jnp.ndarray,        # (B, m_pad) fp32
    beta: jnp.ndarray,         # (B, n) fp32
    x: jnp.ndarray,            # (B, m_pad, d) fp32
    x_sq: jnp.ndarray,         # (B, m_pad) fp32
    y: jnp.ndarray,            # (B, n, d) fp32
    y_sq: jnp.ndarray,         # (B, n) fp32
    flags: jnp.ndarray,        # (B, L_tiles, N_tiles) int32 tile skip flags
    *,
    num_groups: int,
    group_size: int,
    tau,
    gamma: float,
    tile_l: int = 0,
    tile_n: int = DEFAULT_TILE_N,
    interpret: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Dense-grid factorized kernel over B problems: grid (B, Lt, Nt).

    Per-problem semantics identical to :func:`gradpsi_fact_pallas`.
    """
    _record_launch("gradpsi_fact_pallas_batched")
    L, g = num_groups, group_size
    B, n = beta.shape
    d = x.shape[-1]
    tau_g = tau_row(tau, L)
    if tile_l == 0:
        tile_l = pick_tile_l_factorized(g, tile_n, d,
                                        jnp.dtype(x.dtype).itemsize)
    assert L % tile_l == 0 and n % tile_n == 0, (L, tile_l, n, tile_n)
    grid = (B, L // tile_l, n // tile_n)
    assert flags.shape == grid, (flags.shape, grid)

    alpha_g = alpha.reshape(B, L, g)
    x4 = x.reshape(B, L, g, d)
    xsq_g = x_sq.reshape(B, L, g)

    def y_index(b, l, j, flags_ref):
        active = flags_ref[b, l, j] != 0
        return (b, jnp.where(active, j, 0), 0)

    def ysq_index(b, l, j, flags_ref):
        active = flags_ref[b, l, j] != 0
        return (b, jnp.where(active, j, 0))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, tile_l, g), lambda b, l, j, f: (b, l, 0)),
            pl.BlockSpec((1, tile_n), lambda b, l, j, f: (b, j)),
            pl.BlockSpec((1, tile_l, g, d), lambda b, l, j, f: (b, l, 0, 0)),
            pl.BlockSpec((1, tile_l, g), lambda b, l, j, f: (b, l, 0)),
            pl.BlockSpec((1, tile_n, d), y_index),
            pl.BlockSpec((1, tile_n), ysq_index),
            pl.BlockSpec((tile_l,), lambda b, l, j, f: (l,)),
        ],
        out_specs=[
            pl.BlockSpec((1, tile_l, g), lambda b, l, j, f: (b, l, 0)),
            pl.BlockSpec((1, 1, tile_n), lambda b, l, j, f: (b, l, j)),
            pl.BlockSpec((1, 1, 1), lambda b, l, j, f: (b, 0, 0)),
        ],
    )

    ga_part, gb_part, psi = pl.pallas_call(
        functools.partial(_dense_kernel_fact_batched, gamma=float(gamma)),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((B, L, g), jnp.float32),
            jax.ShapeDtypeStruct((B, grid[1], n), jnp.float32),
            jax.ShapeDtypeStruct((B, 1, 1), jnp.float32),
        ],
        interpret=interpret,
    )(flags, alpha_g, beta, x4, xsq_g, y, y_sq, tau_g)

    return (
        ga_part.reshape(B, -1),
        jnp.sum(gb_part, axis=1),
        psi[:, 0, 0],
    )


def _compact_kernel_fact_batched(sched_ref, nact_ref, alpha_ref, beta_ref,
                                 x_ref, xsq_ref, y_ref, ysq_ref, tau_ref,
                                 ga_ref, gb_ref, psi_ref, steps_ref,
                                 *, gamma: float):
    s = pl.program_id(0)

    @pl.when(s == 0)
    def _init_steps_fb():
        steps_ref[0, 0] = 0

    steps_ref[0, 0] += 1

    alpha = alpha_ref[0].astype(jnp.float32)             # (TL, g)
    beta = beta_ref[0].astype(jnp.float32)               # (TN,)
    c = factorized_cost_tile(
        x_ref[0].astype(jnp.float32),
        xsq_ref[0].astype(jnp.float32),
        y_ref[0].astype(jnp.float32),
        ysq_ref[0].astype(jnp.float32),
    )
    tau = tau_ref[...].astype(jnp.float32)               # (TL,)
    t, psi = _gradpsi_tile(alpha, beta, c, tau, gamma=gamma)
    ga_ref[...] = jnp.sum(t, axis=2)[None]               # (1, TL, g)
    gb_ref[...] = jnp.sum(t, axis=(0, 1))[None, :]       # (1, TN)
    psi_ref[0, 0] = psi


@functools.partial(
    jax.jit,
    static_argnames=("num_groups", "group_size", "gamma",
                     "tile_l", "tile_n", "interpret"),
)
def gradpsi_fact_pallas_compact_batched(
    alpha: jnp.ndarray,        # (B, m_pad) fp32
    beta: jnp.ndarray,         # (B, n) fp32
    x: jnp.ndarray,            # (B, m_pad, d) fp32
    x_sq: jnp.ndarray,         # (B, m_pad) fp32
    y: jnp.ndarray,            # (B, n, d) fp32
    y_sq: jnp.ndarray,         # (B, n) fp32
    sched: jnp.ndarray,        # (3, B*T) int32 from build_batch_tile_schedule
    num_active: jnp.ndarray,   # () int32 TOTAL surviving-tile count
    *,
    num_groups: int,
    group_size: int,
    tau,
    gamma: float,
    tile_l: int = 0,
    tile_n: int = DEFAULT_TILE_N,
    interpret: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Compacted-grid factorized kernel over B problems (one dynamic grid).

    Same contract as :func:`gradpsi_pallas_compact_batched` with the C
    operand replaced by ``(x, x_sq, y, y_sq)`` blocked operands.
    """
    _record_launch("gradpsi_fact_pallas_compact_batched")
    L, g = num_groups, group_size
    B, n = beta.shape
    d = x.shape[-1]
    tau_g = tau_row(tau, L)
    if tile_l == 0:
        tile_l = pick_tile_l_factorized(g, tile_n, d,
                                        jnp.dtype(x.dtype).itemsize)
    assert L % tile_l == 0 and n % tile_n == 0, (L, tile_l, n, tile_n)
    Lt, Nt = L // tile_l, n // tile_n
    BT = B * Lt * Nt
    assert sched.shape == (3, BT), (sched.shape, (3, BT))

    alpha_g = alpha.reshape(B, L, g)
    x4 = x.reshape(B, L, g, d)
    xsq_g = x_sq.reshape(B, L, g)
    num_active = num_active.astype(jnp.int32)
    nact = num_active.reshape(1)
    num_steps = jnp.maximum(num_active, 1)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(num_steps,),
        in_specs=[
            pl.BlockSpec((1, tile_l, g),
                         lambda s, sc, na: (sc[0, s], sc[1, s], 0)),
            pl.BlockSpec((1, tile_n), lambda s, sc, na: (sc[0, s], sc[2, s])),
            pl.BlockSpec((1, tile_l, g, d),
                         lambda s, sc, na: (sc[0, s], sc[1, s], 0, 0)),
            pl.BlockSpec((1, tile_l, g),
                         lambda s, sc, na: (sc[0, s], sc[1, s], 0)),
            pl.BlockSpec((1, tile_n, d),
                         lambda s, sc, na: (sc[0, s], sc[2, s], 0)),
            pl.BlockSpec((1, tile_n), lambda s, sc, na: (sc[0, s], sc[2, s])),
            pl.BlockSpec((tile_l,), lambda s, sc, na: (sc[1, s],)),
        ],
        out_specs=[
            pl.BlockSpec((1, tile_l, g), lambda s, sc, na: (s, 0, 0)),
            pl.BlockSpec((1, tile_n), lambda s, sc, na: (s, 0)),
            pl.BlockSpec((1, 1), lambda s, sc, na: (s, 0)),
            pl.BlockSpec((1, 1), lambda s, sc, na: (0, 0)),
        ],
    )

    ga_steps, gb_steps, psi_steps, steps = pl.pallas_call(
        functools.partial(_compact_kernel_fact_batched, gamma=float(gamma)),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((BT, tile_l, g), jnp.float32),
            jax.ShapeDtypeStruct((BT, tile_n), jnp.float32),
            jax.ShapeDtypeStruct((BT, 1), jnp.float32),
            jax.ShapeDtypeStruct((1, 1), jnp.int32),
        ],
        compiler_params=pltpu.TPUCompilerParams(
            vmem_limit_bytes=COMPACT_PIPELINE_BUFFERS * VMEM_BUDGET_BYTES,
        ),
        interpret=interpret,
    )(sched, nact, alpha_g, beta, x4, xsq_g, y, y_sq, tau_g)

    idx = jnp.arange(BT, dtype=jnp.int32)
    valid = idx < num_active
    seg_ga = jnp.where(valid, sched[0] * Lt + sched[1], B * Lt)
    seg_gb = jnp.where(valid, sched[0] * Nt + sched[2], B * Nt)
    seg_psi = jnp.where(valid, sched[0], B)
    ga = jnp.zeros((B * Lt, tile_l, g), jnp.float32).at[seg_ga].add(
        ga_steps, mode="drop"
    )
    gb = jnp.zeros((B * Nt, tile_n), jnp.float32).at[seg_gb].add(
        gb_steps, mode="drop"
    )
    psi = jnp.zeros((B,), jnp.float32).at[seg_psi].add(
        psi_steps[:, 0], mode="drop"
    )
    return ga.reshape(B, -1), gb.reshape(B, -1), psi, steps[0, 0]


# -- fused screen+gradient mega-kernels (DESIGN.md §10) ------------------------
#
# One launch per oracle evaluation: the screening verdict is computed
# IN-REGISTER at the top of every grid step (the same _verdict_tile math the
# standalone screen kernel runs on identically-blocked operands), the tile's
# gradient work is gated on the verdict's per-tile OR, and that OR lands in a
# (L_tiles, N_tiles) flag output replacing the standalone screen launch.  The
# screen operands are the padded snapshot tiles (z/k/o/act/sqrt_g, fixed
# within a round) plus the O(L + n) per-eval delta norms; a tile whose bound
# test fails writes zeros without its F/T working set ever leaving VMEM.
# There is deliberately NO fused compact mode: a compact schedule must be
# built from flags that exist before launch, which is exactly the standalone
# screen pass the fused route removes (and a stale snapshot-point schedule
# would be unsafe — snapshot-ZERO tiles can go live as the deltas grow).


def _fused_dense_kernel(alpha_ref, beta_ref, c_ref, tau_ref, z_ref, k_ref,
                        o_ref, act_ref, dap_ref, daf_ref, dan_ref, db_ref,
                        sg_ref, ga_ref, gb_ref, psi_ref, flag_ref,
                        *, gamma: float):
    l = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init_ga_fu():
        ga_ref[...] = jnp.zeros_like(ga_ref)

    @pl.when(jnp.logical_and(l == 0, j == 0))
    def _init_psi_fu():
        psi_ref[...] = jnp.zeros_like(psi_ref)

    gb_ref[...] = jnp.zeros_like(gb_ref)

    tau = tau_ref[...].astype(jnp.float32)               # (TL,)
    v = _verdict_tile(
        z_ref[...], k_ref[...], o_ref[...], act_ref[...],
        dap_ref[...], daf_ref[...], dan_ref[...],
        db_ref[...], sg_ref[...], tau,
    )
    flag = jnp.any(v != ZERO).astype(jnp.int32)
    flag_ref[0, 0] = flag

    @pl.when(flag != 0)
    def _compute_fu():
        alpha = alpha_ref[...].astype(jnp.float32)       # (TL, g)
        beta = beta_ref[...].astype(jnp.float32)         # (TN,)
        c = c_ref[...].astype(jnp.float32)               # (TL, g, TN)
        t, psi = _gradpsi_tile(alpha, beta, c, tau, gamma=gamma)
        psi_ref[0, 0] += psi
        ga_ref[...] += jnp.sum(t, axis=2)                # (TL, g)
        gb_ref[...] = jnp.sum(t, axis=(0, 1))[None, :]   # (1, TN)


@functools.partial(
    jax.jit,
    static_argnames=("num_groups", "group_size", "gamma",
                     "tile_l", "tile_n", "interpret"),
)
def gradpsi_fused_pallas(
    alpha: jnp.ndarray,        # (m_pad,) fp32
    beta: jnp.ndarray,         # (n,) fp32
    C: jnp.ndarray,            # (m_pad, n) fp32 or bf16
    z: jnp.ndarray,            # (L, n) fp32 snapshot upper-bound matrix
    k: jnp.ndarray,            # (L, n) fp32 snapshot full-norm matrix
    o: jnp.ndarray,            # (L, n) fp32 snapshot negative-norm matrix
    active: jnp.ndarray,       # (L, n) int8/bool persistent set N
    da_plus: jnp.ndarray,      # (L,)  ||[d_alpha_[l]]_+||
    da_full: jnp.ndarray,      # (L,)  ||d_alpha_[l]||
    da_neg: jnp.ndarray,       # (L,)  ||[d_alpha_[l]]_-||
    db: jnp.ndarray,           # (n,)  d_beta
    sqrt_g: jnp.ndarray,       # (L,)
    *,
    num_groups: int,
    group_size: int,
    tau,
    gamma: float,
    tile_l: int = 0,
    tile_n: int = DEFAULT_TILE_N,
    interpret: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Fused dense-grid kernel: verdicts + gradient in ONE launch.

    Returns (T_rowsum (m_pad,), T_colsum (n,), psi, flags (Lt, Nt) int32)
    where ``flags`` is bitwise-identical to the standalone screen kernel's
    tile-flag output on the same operands and the gradient triple is
    bitwise-identical to :func:`gradpsi_pallas` fed those flags.  All
    operands must be tile-padded (ops.py handles padding); screen operands
    follow :func:`repro.kernels.screen.screen_pallas`.
    """
    _record_launch("gradpsi_fused_pallas")
    L, g = num_groups, group_size
    n = beta.shape[0]
    tau_g = tau_row(tau, L)
    if tile_l == 0:
        tile_l = pick_tile_l(g, tile_n, jnp.dtype(C.dtype).itemsize)
    assert L % tile_l == 0 and n % tile_n == 0, (L, tile_l, n, tile_n)
    grid = (L // tile_l, n // tile_n)
    assert z.shape == (L, n), (z.shape, (L, n))

    alpha_g = alpha.reshape(L, g)
    C3 = C.reshape(L, g, n)

    row = pl.BlockSpec((tile_l,), lambda l, j: (l,))
    col = pl.BlockSpec((tile_n,), lambda l, j: (j,))
    mat = pl.BlockSpec((tile_l, tile_n), lambda l, j: (l, j))

    ga_part, gb_part, psi, flags = pl.pallas_call(
        functools.partial(_fused_dense_kernel, gamma=float(gamma)),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_l, g), lambda l, j: (l, 0)),        # alpha
            col,                                                   # beta
            pl.BlockSpec((tile_l, g, tile_n), lambda l, j: (l, 0, j)),  # C
            row,                                                   # tau
            mat, mat, mat, mat,                                    # z k o act
            row, row, row,                                         # da norms
            col,                                                   # db
            row,                                                   # sqrt_g
        ],
        out_specs=[
            pl.BlockSpec((tile_l, g), lambda l, j: (l, 0)),
            pl.BlockSpec((1, tile_n), lambda l, j: (l, j)),
            pl.BlockSpec((1, 1), lambda l, j: (0, 0)),
            pl.BlockSpec((1, 1), lambda l, j: (l, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((L, g), jnp.float32),
            jax.ShapeDtypeStruct((grid[0], n), jnp.float32),
            jax.ShapeDtypeStruct((1, 1), jnp.float32),
            jax.ShapeDtypeStruct(grid, jnp.int32),
        ],
        interpret=interpret,
    )(alpha_g, beta, C3, tau_g, z, k, o, active.astype(jnp.int8),
      da_plus, da_full, da_neg, db, sqrt_g)

    return ga_part.reshape(-1), jnp.sum(gb_part, axis=0), psi[0, 0], flags


def _fused_dense_kernel_batched(alpha_ref, beta_ref, c_ref, tau_ref, z_ref,
                                k_ref, o_ref, act_ref, dap_ref, daf_ref,
                                dan_ref, db_ref, sg_ref,
                                ga_ref, gb_ref, psi_ref, flag_ref,
                                *, gamma: float):
    j = pl.program_id(2)
    lj0 = jnp.logical_and(pl.program_id(1) == 0, j == 0)

    @pl.when(j == 0)
    def _init_ga_fub():
        ga_ref[...] = jnp.zeros_like(ga_ref)

    @pl.when(lj0)
    def _init_psi_fub():
        psi_ref[...] = jnp.zeros_like(psi_ref)

    gb_ref[...] = jnp.zeros_like(gb_ref)

    tau = tau_ref[...].astype(jnp.float32)               # (TL,)
    v = _verdict_tile(
        z_ref[0], k_ref[0], o_ref[0], act_ref[0],
        dap_ref[0], daf_ref[0], dan_ref[0],
        db_ref[0], sg_ref[0], tau,
    )
    flag = jnp.any(v != ZERO).astype(jnp.int32)
    flag_ref[0, 0, 0] = flag

    @pl.when(flag != 0)
    def _compute_fub():
        alpha = alpha_ref[0].astype(jnp.float32)         # (TL, g)
        beta = beta_ref[0].astype(jnp.float32)           # (TN,)
        c = c_ref[0].astype(jnp.float32)                 # (TL, g, TN)
        t, psi = _gradpsi_tile(alpha, beta, c, tau, gamma=gamma)
        psi_ref[0, 0, 0] += psi
        ga_ref[...] += jnp.sum(t, axis=2)[None]          # (1, TL, g)
        gb_ref[...] = jnp.sum(t, axis=(0, 1))[None, None, :]  # (1, 1, TN)


@functools.partial(
    jax.jit,
    static_argnames=("num_groups", "group_size", "gamma",
                     "tile_l", "tile_n", "interpret"),
)
def gradpsi_fused_pallas_batched(
    alpha: jnp.ndarray,        # (B, m_pad) fp32
    beta: jnp.ndarray,         # (B, n) fp32
    C: jnp.ndarray,            # (B, m_pad, n) fp32 or bf16
    z: jnp.ndarray,            # (B, L, n) fp32
    k: jnp.ndarray,            # (B, L, n) fp32
    o: jnp.ndarray,            # (B, L, n) fp32
    active: jnp.ndarray,       # (B, L, n) int8/bool
    da_plus: jnp.ndarray,      # (B, L)
    da_full: jnp.ndarray,      # (B, L)
    da_neg: jnp.ndarray,       # (B, L)
    db: jnp.ndarray,           # (B, n)
    sqrt_g: jnp.ndarray,       # (B, L)
    *,
    num_groups: int,
    group_size: int,
    tau,
    gamma: float,
    tile_l: int = 0,
    tile_n: int = DEFAULT_TILE_N,
    interpret: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Fused dense-grid kernel over B problems: grid (B, Lt, Nt), ONE launch.

    Returns (T_rowsum (B, m_pad), T_colsum (B, n), psi (B,), flags
    (B, Lt, Nt) int32).  Per-problem semantics identical to
    :func:`gradpsi_fused_pallas`; ``tau`` is shared batch-wide.
    """
    _record_launch("gradpsi_fused_pallas_batched")
    L, g = num_groups, group_size
    B, n = beta.shape
    tau_g = tau_row(tau, L)
    if tile_l == 0:
        tile_l = pick_tile_l(g, tile_n, jnp.dtype(C.dtype).itemsize)
    assert L % tile_l == 0 and n % tile_n == 0, (L, tile_l, n, tile_n)
    grid = (B, L // tile_l, n // tile_n)
    assert z.shape == (B, L, n), (z.shape, (B, L, n))

    alpha_g = alpha.reshape(B, L, g)
    C4 = C.reshape(B, L, g, n)

    brow = pl.BlockSpec((1, tile_l), lambda b, l, j: (b, l))
    bcol = pl.BlockSpec((1, tile_n), lambda b, l, j: (b, j))
    bmat = pl.BlockSpec((1, tile_l, tile_n), lambda b, l, j: (b, l, j))

    ga_part, gb_part, psi, flags = pl.pallas_call(
        functools.partial(_fused_dense_kernel_batched, gamma=float(gamma)),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, tile_l, g), lambda b, l, j: (b, l, 0)),  # alpha
            bcol,                                                     # beta
            pl.BlockSpec((1, tile_l, g, tile_n),
                         lambda b, l, j: (b, l, 0, j)),               # C
            pl.BlockSpec((tile_l,), lambda b, l, j: (l,)),            # tau
            bmat, bmat, bmat, bmat,                                   # z k o act
            brow, brow, brow,                                         # da norms
            bcol,                                                     # db
            brow,                                                     # sqrt_g
        ],
        out_specs=[
            pl.BlockSpec((1, tile_l, g), lambda b, l, j: (b, l, 0)),
            pl.BlockSpec((1, 1, tile_n), lambda b, l, j: (b, l, j)),
            pl.BlockSpec((1, 1, 1), lambda b, l, j: (b, 0, 0)),
            pl.BlockSpec((1, 1, 1), lambda b, l, j: (b, l, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, L, g), jnp.float32),
            jax.ShapeDtypeStruct((B, grid[1], n), jnp.float32),
            jax.ShapeDtypeStruct((B, 1, 1), jnp.float32),
            jax.ShapeDtypeStruct(grid, jnp.int32),
        ],
        interpret=interpret,
    )(alpha_g, beta, C4, tau_g, z, k, o, active.astype(jnp.int8),
      da_plus, da_full, da_neg, db, sqrt_g)

    return (
        ga_part.reshape(B, -1),
        jnp.sum(gb_part, axis=1),
        psi[:, 0, 0],
        flags,
    )


def _fused_fact_kernel(alpha_ref, beta_ref, x_ref, xsq_ref, y_ref, ysq_ref,
                       tau_ref, z_ref, k_ref, o_ref, act_ref, dap_ref,
                       daf_ref, dan_ref, db_ref, sg_ref,
                       ga_ref, gb_ref, psi_ref, flag_ref, *, gamma: float):
    l = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init_ga_ff():
        ga_ref[...] = jnp.zeros_like(ga_ref)

    @pl.when(jnp.logical_and(l == 0, j == 0))
    def _init_psi_ff():
        psi_ref[...] = jnp.zeros_like(psi_ref)

    gb_ref[...] = jnp.zeros_like(gb_ref)

    tau = tau_ref[...].astype(jnp.float32)               # (TL,)
    v = _verdict_tile(
        z_ref[...], k_ref[...], o_ref[...], act_ref[...],
        dap_ref[...], daf_ref[...], dan_ref[...],
        db_ref[...], sg_ref[...], tau,
    )
    flag = jnp.any(v != ZERO).astype(jnp.int32)
    flag_ref[0, 0] = flag

    @pl.when(flag != 0)
    def _compute_ff():
        alpha = alpha_ref[...].astype(jnp.float32)       # (TL, g)
        beta = beta_ref[...].astype(jnp.float32)         # (TN,)
        c = factorized_cost_tile(
            x_ref[...].astype(jnp.float32),              # (TL, g, d)
            xsq_ref[...].astype(jnp.float32),            # (TL, g)
            y_ref[...].astype(jnp.float32),              # (TN, d)
            ysq_ref[...].astype(jnp.float32),            # (TN,)
        )
        t, psi = _gradpsi_tile(alpha, beta, c, tau, gamma=gamma)
        psi_ref[0, 0] += psi
        ga_ref[...] += jnp.sum(t, axis=2)                # (TL, g)
        gb_ref[...] = jnp.sum(t, axis=(0, 1))[None, :]   # (1, TN)


@functools.partial(
    jax.jit,
    static_argnames=("num_groups", "group_size", "gamma",
                     "tile_l", "tile_n", "interpret"),
)
def gradpsi_fused_fact_pallas(
    alpha: jnp.ndarray,        # (m_pad,) fp32
    beta: jnp.ndarray,         # (n,) fp32
    x: jnp.ndarray,            # (m_pad, d) fp32/bf16 scaled source samples
    x_sq: jnp.ndarray,         # (m_pad,) fp32/bf16 scaled squared norms
    y: jnp.ndarray,            # (n, d) fp32/bf16 scaled target samples
    y_sq: jnp.ndarray,         # (n,) fp32/bf16 scaled squared norms
    z: jnp.ndarray,            # (L, n) fp32
    k: jnp.ndarray,            # (L, n) fp32
    o: jnp.ndarray,            # (L, n) fp32
    active: jnp.ndarray,       # (L, n) int8/bool
    da_plus: jnp.ndarray,      # (L,)
    da_full: jnp.ndarray,      # (L,)
    da_neg: jnp.ndarray,       # (L,)
    db: jnp.ndarray,           # (n,)
    sqrt_g: jnp.ndarray,       # (L,)
    *,
    num_groups: int,
    group_size: int,
    tau,
    gamma: float,
    tile_l: int = 0,
    tile_n: int = DEFAULT_TILE_N,
    interpret: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Fused dense-grid factorized kernel: ONE launch, cost tiles in VMEM.

    Same contract as :func:`gradpsi_fused_pallas` with the C operand
    replaced by ``(x, x_sq, y, y_sq)`` blocked operands (the
    :func:`factorized_cost_tile` recipe).
    """
    _record_launch("gradpsi_fused_fact_pallas")
    L, g = num_groups, group_size
    n = beta.shape[0]
    d = x.shape[-1]
    tau_g = tau_row(tau, L)
    if tile_l == 0:
        tile_l = pick_tile_l_factorized(g, tile_n, d,
                                        jnp.dtype(x.dtype).itemsize)
    assert L % tile_l == 0 and n % tile_n == 0, (L, tile_l, n, tile_n)
    grid = (L // tile_l, n // tile_n)
    assert z.shape == (L, n), (z.shape, (L, n))

    alpha_g = alpha.reshape(L, g)
    x3 = x.reshape(L, g, d)
    xsq_g = x_sq.reshape(L, g)

    row = pl.BlockSpec((tile_l,), lambda l, j: (l,))
    row_g = pl.BlockSpec((tile_l, g), lambda l, j: (l, 0))
    col = pl.BlockSpec((tile_n,), lambda l, j: (j,))
    mat = pl.BlockSpec((tile_l, tile_n), lambda l, j: (l, j))

    ga_part, gb_part, psi, flags = pl.pallas_call(
        functools.partial(_fused_fact_kernel, gamma=float(gamma)),
        grid=grid,
        in_specs=[
            row_g,                                                 # alpha
            col,                                                   # beta
            pl.BlockSpec((tile_l, g, d), lambda l, j: (l, 0, 0)),  # x
            row_g,                                                 # x_sq
            pl.BlockSpec((tile_n, d), lambda l, j: (j, 0)),        # y
            col,                                                   # y_sq
            row,                                                   # tau
            mat, mat, mat, mat,                                    # z k o act
            row, row, row,                                         # da norms
            col,                                                   # db
            row,                                                   # sqrt_g
        ],
        out_specs=[
            pl.BlockSpec((tile_l, g), lambda l, j: (l, 0)),
            pl.BlockSpec((1, tile_n), lambda l, j: (l, j)),
            pl.BlockSpec((1, 1), lambda l, j: (0, 0)),
            pl.BlockSpec((1, 1), lambda l, j: (l, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((L, g), jnp.float32),
            jax.ShapeDtypeStruct((grid[0], n), jnp.float32),
            jax.ShapeDtypeStruct((1, 1), jnp.float32),
            jax.ShapeDtypeStruct(grid, jnp.int32),
        ],
        interpret=interpret,
    )(alpha_g, beta, x3, xsq_g, y, y_sq, tau_g,
      z, k, o, active.astype(jnp.int8),
      da_plus, da_full, da_neg, db, sqrt_g)

    return ga_part.reshape(-1), jnp.sum(gb_part, axis=0), psi[0, 0], flags


def _fused_fact_kernel_batched(alpha_ref, beta_ref, x_ref, xsq_ref, y_ref,
                               ysq_ref, tau_ref, z_ref, k_ref, o_ref,
                               act_ref, dap_ref, daf_ref, dan_ref, db_ref,
                               sg_ref, ga_ref, gb_ref, psi_ref, flag_ref,
                               *, gamma: float):
    j = pl.program_id(2)
    lj0 = jnp.logical_and(pl.program_id(1) == 0, j == 0)

    @pl.when(j == 0)
    def _init_ga_ffb():
        ga_ref[...] = jnp.zeros_like(ga_ref)

    @pl.when(lj0)
    def _init_psi_ffb():
        psi_ref[...] = jnp.zeros_like(psi_ref)

    gb_ref[...] = jnp.zeros_like(gb_ref)

    tau = tau_ref[...].astype(jnp.float32)               # (TL,)
    v = _verdict_tile(
        z_ref[0], k_ref[0], o_ref[0], act_ref[0],
        dap_ref[0], daf_ref[0], dan_ref[0],
        db_ref[0], sg_ref[0], tau,
    )
    flag = jnp.any(v != ZERO).astype(jnp.int32)
    flag_ref[0, 0, 0] = flag

    @pl.when(flag != 0)
    def _compute_ffb():
        alpha = alpha_ref[0].astype(jnp.float32)         # (TL, g)
        beta = beta_ref[0].astype(jnp.float32)           # (TN,)
        c = factorized_cost_tile(
            x_ref[0].astype(jnp.float32),                # (TL, g, d)
            xsq_ref[0].astype(jnp.float32),              # (TL, g)
            y_ref[0].astype(jnp.float32),                # (TN, d)
            ysq_ref[0].astype(jnp.float32),              # (TN,)
        )
        t, psi = _gradpsi_tile(alpha, beta, c, tau, gamma=gamma)
        psi_ref[0, 0, 0] += psi
        ga_ref[...] += jnp.sum(t, axis=2)[None]          # (1, TL, g)
        gb_ref[...] = jnp.sum(t, axis=(0, 1))[None, None, :]  # (1, 1, TN)


@functools.partial(
    jax.jit,
    static_argnames=("num_groups", "group_size", "gamma",
                     "tile_l", "tile_n", "interpret"),
)
def gradpsi_fused_fact_pallas_batched(
    alpha: jnp.ndarray,        # (B, m_pad) fp32
    beta: jnp.ndarray,         # (B, n) fp32
    x: jnp.ndarray,            # (B, m_pad, d) fp32/bf16
    x_sq: jnp.ndarray,         # (B, m_pad) fp32/bf16
    y: jnp.ndarray,            # (B, n, d) fp32/bf16
    y_sq: jnp.ndarray,         # (B, n) fp32/bf16
    z: jnp.ndarray,            # (B, L, n) fp32
    k: jnp.ndarray,            # (B, L, n) fp32
    o: jnp.ndarray,            # (B, L, n) fp32
    active: jnp.ndarray,       # (B, L, n) int8/bool
    da_plus: jnp.ndarray,      # (B, L)
    da_full: jnp.ndarray,      # (B, L)
    da_neg: jnp.ndarray,       # (B, L)
    db: jnp.ndarray,           # (B, n)
    sqrt_g: jnp.ndarray,       # (B, L)
    *,
    num_groups: int,
    group_size: int,
    tau,
    gamma: float,
    tile_l: int = 0,
    tile_n: int = DEFAULT_TILE_N,
    interpret: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Fused dense-grid factorized kernel over B problems, ONE launch.

    Per-problem semantics identical to :func:`gradpsi_fused_fact_pallas`.
    """
    _record_launch("gradpsi_fused_fact_pallas_batched")
    L, g = num_groups, group_size
    B, n = beta.shape
    d = x.shape[-1]
    tau_g = tau_row(tau, L)
    if tile_l == 0:
        tile_l = pick_tile_l_factorized(g, tile_n, d,
                                        jnp.dtype(x.dtype).itemsize)
    assert L % tile_l == 0 and n % tile_n == 0, (L, tile_l, n, tile_n)
    grid = (B, L // tile_l, n // tile_n)
    assert z.shape == (B, L, n), (z.shape, (B, L, n))

    alpha_g = alpha.reshape(B, L, g)
    x4 = x.reshape(B, L, g, d)
    xsq_g = x_sq.reshape(B, L, g)

    brow = pl.BlockSpec((1, tile_l), lambda b, l, j: (b, l))
    brow_g = pl.BlockSpec((1, tile_l, g), lambda b, l, j: (b, l, 0))
    bcol = pl.BlockSpec((1, tile_n), lambda b, l, j: (b, j))
    bmat = pl.BlockSpec((1, tile_l, tile_n), lambda b, l, j: (b, l, j))

    ga_part, gb_part, psi, flags = pl.pallas_call(
        functools.partial(_fused_fact_kernel_batched, gamma=float(gamma)),
        grid=grid,
        in_specs=[
            brow_g,                                                # alpha
            bcol,                                                  # beta
            pl.BlockSpec((1, tile_l, g, d),
                         lambda b, l, j: (b, l, 0, 0)),            # x
            brow_g,                                                # x_sq
            pl.BlockSpec((1, tile_n, d), lambda b, l, j: (b, j, 0)),  # y
            bcol,                                                  # y_sq
            pl.BlockSpec((tile_l,), lambda b, l, j: (l,)),         # tau
            bmat, bmat, bmat, bmat,                                # z k o act
            brow, brow, brow,                                      # da norms
            bcol,                                                  # db
            brow,                                                  # sqrt_g
        ],
        out_specs=[
            pl.BlockSpec((1, tile_l, g), lambda b, l, j: (b, l, 0)),
            pl.BlockSpec((1, 1, tile_n), lambda b, l, j: (b, l, j)),
            pl.BlockSpec((1, 1, 1), lambda b, l, j: (b, 0, 0)),
            pl.BlockSpec((1, 1, 1), lambda b, l, j: (b, l, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, L, g), jnp.float32),
            jax.ShapeDtypeStruct((B, grid[1], n), jnp.float32),
            jax.ShapeDtypeStruct((B, 1, 1), jnp.float32),
            jax.ShapeDtypeStruct(grid, jnp.int32),
        ],
        interpret=interpret,
    )(alpha_g, beta, x4, xsq_g, y, y_sq, tau_g,
      z, k, o, active.astype(jnp.int8),
      da_plus, da_full, da_neg, db, sqrt_g)

    return (
        ga_part.reshape(B, -1),
        jnp.sum(gb_part, axis=1),
        psi[:, 0, 0],
        flags,
    )
