"""Pallas TPU kernel: fused, block-masked dual gradient for group-sparse OT.

This is the paper's Algorithm 2 adapted to the TPU memory hierarchy (see
DESIGN.md §2).  One kernel instance owns a (TILE_L groups x g rows) x TILE_N
columns tile and fuses the whole gradient pipeline in VMEM:

    F = alpha + beta_j - c          (VPU broadcast add)
    Z = ||[F_group]_+||_2           (relu + per-group reduction)
    s = [1 - tau/Z]_+               (soft threshold, Eq. 5)
    T = s * [F]_+ / gamma           (the gradient block / plan block)
    psi contribution                (closed form in Z)

Screening enters through per-tile skip flags (int32, 0 = every (l, j) in the
tile is certified-zero by the Eq. 6 upper bound).  Skipped tiles:

  * run no compute (``@pl.when(flag != 0)``), and
  * remap their C-tile index to (l, 0, 0) — consecutive skipped steps then
    request the same block, so Mosaic's revisit elision drops the HBM->VMEM
    DMA.  That converts the paper's "skipped FLOPs" into skipped HBM traffic,
    which is what matters for this memory-bound kernel (~1.2 FLOP/byte).

Grid = (L_tiles, N_tiles), N innermost so grad_alpha accumulates per l-run.
Outputs are partials assembled by ops.py:
  ga_part  (L, g)        accumulated over the j-run for each l tile,
  gb_part  (L_tiles, n)  one row of column-sums per l tile (reduced outside),
  psi_sum  (1, 1)        accumulated over the whole grid.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_TILE_N = 128
VMEM_BUDGET_BYTES = 8 * 1024 * 1024  # C tile + T tile + slack


def pick_tile_l(g: int, tile_n: int, dtype_bytes: int = 4) -> int:
    """Largest TILE_L (power of two, <=8) whose working set fits VMEM."""
    per_l = 2 * g * tile_n * dtype_bytes  # F/T tiles dominate
    t = max(1, VMEM_BUDGET_BYTES // max(per_l, 1))
    for cand in (8, 4, 2, 1):
        if cand <= t:
            return cand
    return 1


def _kernel(flags_ref, alpha_ref, beta_ref, c_ref,
            ga_ref, gb_ref, psi_ref, *, tau: float, gamma: float):
    l = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _():
        ga_ref[...] = jnp.zeros_like(ga_ref)

    @pl.when(jnp.logical_and(l == 0, j == 0))
    def _():
        psi_ref[...] = jnp.zeros_like(psi_ref)

    gb_ref[...] = jnp.zeros_like(gb_ref)

    flag = flags_ref[l, j]

    @pl.when(flag != 0)
    def _():
        alpha = alpha_ref[...].astype(jnp.float32)       # (TL, g)
        beta = beta_ref[...].astype(jnp.float32)         # (TN,)
        c = c_ref[...].astype(jnp.float32)               # (TL, g, TN)
        f = alpha[:, :, None] + beta[None, None, :] - c
        fp = jnp.maximum(f, 0.0)
        zsq = jnp.sum(fp * fp, axis=1)                   # (TL, TN)
        z = jnp.sqrt(zsq)
        on = z > tau
        zs = jnp.where(on, z, 1.0)
        s = jnp.where(on, 1.0 - tau / zs, 0.0)           # (TL, TN)
        t = s[:, None, :] * fp * (1.0 / gamma)           # (TL, g, TN)
        # psi closed form (regularizers.psi_from_z)
        mu_s_z = (tau / gamma) * s * zs                  # mu*s*z with tau=mu*gamma
        psi = jnp.where(on, s * zs * zs / gamma * (1.0 - 0.5 * s) - mu_s_z, 0.0)
        psi_ref[0, 0] += jnp.sum(psi)
        ga_ref[...] += jnp.sum(t, axis=2)                # (TL, g)
        gb_ref[...] = jnp.sum(t, axis=(0, 1))[None, :]   # (1, TN)


@functools.partial(
    jax.jit,
    static_argnames=("num_groups", "group_size", "tau", "gamma",
                     "tile_l", "tile_n", "interpret"),
)
def gradpsi_pallas(
    alpha: jnp.ndarray,        # (m_pad,) fp32
    beta: jnp.ndarray,         # (n,) fp32
    C: jnp.ndarray,            # (m_pad, n) fp32 or bf16
    flags: jnp.ndarray,        # (L_tiles, N_tiles) int32 tile skip flags
    *,
    num_groups: int,
    group_size: int,
    tau: float,
    gamma: float,
    tile_l: int = 0,
    tile_n: int = DEFAULT_TILE_N,
    interpret: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Returns (T_rowsum (m_pad,), T_colsum (n,), psi_total scalar).

    Callers assemble: value = alpha@a + beta@b - psi_total,
                      grad_alpha = a - T_rowsum,  grad_beta = b - T_colsum.
    n and L must be padded to tile multiples (ops.py handles padding).
    """
    L, g = num_groups, group_size
    n = beta.shape[0]
    if tile_l == 0:
        tile_l = pick_tile_l(g, tile_n, jnp.dtype(C.dtype).itemsize)
    assert L % tile_l == 0 and n % tile_n == 0, (L, tile_l, n, tile_n)
    grid = (L // tile_l, n // tile_n)
    assert flags.shape == grid, (flags.shape, grid)

    alpha_g = alpha.reshape(L, g)
    C3 = C.reshape(L, g, n)

    def c_index(l, j, flags_ref):
        # remap skipped tiles to (l, 0, 0): consecutive skipped steps request
        # the same block => the DMA is elided (revisit optimization).
        active = flags_ref[l, j] != 0
        return (l, 0, jnp.where(active, j, 0))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_l, g), lambda l, j, f: (l, 0)),
            pl.BlockSpec((tile_n,), lambda l, j, f: (j,)),
            pl.BlockSpec((tile_l, g, tile_n), c_index),
        ],
        out_specs=[
            pl.BlockSpec((tile_l, g), lambda l, j, f: (l, 0)),
            pl.BlockSpec((1, tile_n), lambda l, j, f: (l, j)),
            pl.BlockSpec((1, 1), lambda l, j, f: (0, 0)),
        ],
    )

    ga_part, gb_part, psi = pl.pallas_call(
        functools.partial(_kernel, tau=float(tau), gamma=float(gamma)),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((L, g), jnp.float32),
            jax.ShapeDtypeStruct((grid[0], n), jnp.float32),
            jax.ShapeDtypeStruct((1, 1), jnp.float32),
        ],
        interpret=interpret,
    )(flags, alpha_g, beta, C3)

    return ga_part.reshape(-1), jnp.sum(gb_part, axis=0), psi[0, 0]
