"""Pallas TPU kernels for the perf-critical OT gradient path.

gradpsi:  fused block-masked dual gradient (the paper's Algorithm 2 on TPU).
screen:   Eq. 6/7 bound matrices -> verdicts -> tile skip flags.
ops:      jit'd wrappers (padding, interpret-mode fallback, assembly).
ref:      pure-jnp oracles used by the kernel test sweeps.
"""
