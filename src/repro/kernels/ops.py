"""Jit'd public wrappers around the Pallas kernels.

Handles: interpret-mode selection (CPU container -> interpret=True; real TPU
-> compiled Mosaic), padding of L and n up to tile multiples, and assembling
kernel partials into the (value, grad_alpha, grad_beta) triple the solver
consumes.  Padded tiles are marked skipped in the flag matrix, so they cost
nothing and contribute exact zeros.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.dual import DualProblem
from repro.core.screening import ZERO
from repro.kernels.gradpsi import DEFAULT_TILE_N, gradpsi_pallas, pick_tile_l
from repro.kernels.screen import screen_pallas


def default_interpret() -> bool:
    """Interpret Pallas on anything that is not a real TPU."""
    return jax.default_backend() != "tpu"


def _pad_axis(x: jnp.ndarray, axis: int, mult: int, value=0.0):
    size = x.shape[axis]
    target = -(-size // mult) * mult
    if target == size:
        return x
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, target - size)
    return jnp.pad(x, pads, constant_values=value)


@functools.partial(
    jax.jit,
    static_argnames=("prob", "tile_l", "tile_n", "interpret"),
)
def dual_value_and_grad(
    alpha: jnp.ndarray,
    beta: jnp.ndarray,
    C: jnp.ndarray,
    a: jnp.ndarray,
    b: jnp.ndarray,
    verdict: jnp.ndarray,           # (L, n) int32 from screening.verdicts
    prob: DualProblem,
    tile_l: int = 0,
    tile_n: int = DEFAULT_TILE_N,
    interpret: bool | None = None,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Block-masked Pallas evaluation of the dual value and gradients.

    Returns (value, grad_alpha, grad_beta) for the MAXIMIZATION problem —
    identical to repro.core.dual.dual_value_and_grad with the screened mask
    (Theorem 2: masked entries are provably zero).
    """
    if interpret is None:
        interpret = default_interpret()
    L, g, n = prob.num_groups, prob.group_size, prob.n
    if tile_l == 0:
        tile_l = pick_tile_l(g, tile_n, jnp.dtype(C.dtype).itemsize)
        tile_l = min(tile_l, L) if L % min(tile_l, L) == 0 else 1
        while L % tile_l:
            tile_l //= 2
        tile_l = max(tile_l, 1)

    # pad n and L to tile multiples; padded area is flagged skipped AND gets
    # +PAD_COST so f = alpha + beta - c < 0 there => exact-zero contribution
    # even inside partially-real tiles.
    from repro.core.groups import PAD_COST

    n_pad = -(-n // tile_n) * tile_n
    L_pad = -(-L // tile_l) * tile_l
    Cp = _pad_axis(
        _pad_axis(C.reshape(L, g, n), 2, tile_n, PAD_COST), 0, tile_l, PAD_COST
    )
    alphap = _pad_axis(alpha.reshape(L, g), 0, tile_l, 0.0).reshape(-1)
    betap = _pad_axis(beta, 0, tile_n, 0.0)
    vp = _pad_axis(_pad_axis(verdict, 1, tile_n, ZERO), 0, tile_l, ZERO)
    vt = vp.reshape(L_pad // tile_l, tile_l, n_pad // tile_n, tile_n)
    flags = jnp.any(vt != ZERO, axis=(1, 3)).astype(jnp.int32)

    rowsum, colsum, psi = gradpsi_pallas(
        alphap,
        betap,
        Cp.reshape(L_pad * g, n_pad),
        flags,
        num_groups=L_pad,
        group_size=g,
        tau=prob.reg.tau,
        gamma=prob.reg.gamma,
        tile_l=tile_l,
        tile_n=tile_n,
        interpret=interpret,
    )
    rowsum = rowsum.reshape(L_pad, g)[:L].reshape(-1)
    colsum = colsum[:n]
    value = alpha @ a + beta @ b - psi
    return value, a - rowsum, b - colsum


@functools.partial(
    jax.jit, static_argnames=("tau", "tile_l", "tile_n", "interpret")
)
def screen_verdicts(
    z_snap, k_snap, o_snap, active, da_plus, da_full, da_neg, db, sqrt_g,
    tau: float,
    tile_l: int = 8,
    tile_n: int = 128,
    interpret: bool | None = None,
):
    """Pallas screening pass; pads (L, n) to tile multiples transparently."""
    if interpret is None:
        interpret = default_interpret()
    L, n = z_snap.shape
    pad2 = lambda x: _pad_axis(_pad_axis(x, 1, tile_n, 0.0), 0, tile_l, 0.0)
    padL = lambda x: _pad_axis(x, 0, tile_l, 0.0)
    padN = lambda x: _pad_axis(x, 0, tile_n, 0.0)
    v, flags = screen_pallas(
        pad2(z_snap), pad2(k_snap),
        # padded k/o rows are zero => zlow <= 0 < tau => never ACTIVE
        pad2(o_snap), pad2(active.astype(jnp.int8)),
        padL(da_plus), padL(da_full), padL(da_neg), padN(db), padL(sqrt_g),
        tau=float(tau), tile_l=tile_l, tile_n=tile_n, interpret=interpret,
    )
    return v[:L, :n], flags
