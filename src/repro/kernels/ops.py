"""Jit'd public wrappers around the Pallas kernels.

Handles: interpret-mode selection (CPU container -> interpret=True; real TPU
-> compiled Mosaic), padding of L and n up to tile multiples, and assembling
kernel partials into the (value, grad_alpha, grad_beta) triple the solver
consumes.  Padded tiles are marked skipped in the flag matrix, so they cost
nothing and contribute exact zeros.

The hot path is structured around two prepared states (DESIGN.md §4):

  * :class:`PaddedProblem` — the tile-padded cost matrix plus geometry,
    built ONCE per solve by :func:`prepare_padded_problem` (previously every
    gradient evaluation re-padded and copied C, the largest array in the
    problem).
  * :class:`PaddedScreenState` — tile-padded screening snapshots, built once
    per snapshot round by :func:`pad_screen_state`; per evaluation only the
    O(L + n) delta-norm vectors are computed and fed to the fused screening
    kernel, which hands tile flags straight to the gradient kernel without
    materializing the (L, n) verdict matrix in HBM.

Gradient execution mode (``impl``):
  'grid'     dense (L_tiles, N_tiles) grid, skipped tiles elide DMA/compute,
  'compact'  dynamic grid over the compacted surviving-tile list,
  'auto'     runtime switch on surviving-tile density
             (<= COMPACT_DENSITY_THRESHOLD -> compact).

Batched entry points (``*_batched``) mirror the solo ones with a leading
problem axis B (same-shape problems): one prepared (B, ...) cost matrix,
per-problem screening snapshots, fused per-problem flag grids (the screen
kernel vmaps over B), and a gradient dispatch whose compact mode runs ONE
dynamic grid over the whole batch's concatenated surviving tiles.  These
feed ``core.solver.solve_batch`` and the OT serving engine.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core import screening
from repro.core.dual import DualProblem
from repro.core.screening import ScreenState
from repro.kernels.gradpsi import (
    COMPACT_DENSITY_THRESHOLD,
    DEFAULT_TILE_N,
    build_batch_tile_schedule,
    build_tile_schedule,
    gradpsi_pallas,
    gradpsi_pallas_batched,
    gradpsi_pallas_compact,
    gradpsi_pallas_compact_batched,
    resolve_tile_l,
    resolve_tile_l_factorized,
    tau_row,
)
from repro.kernels.screen import screen_pallas


def default_interpret() -> bool:
    """Interpret Pallas on anything that is not a real TPU."""
    return jax.default_backend() != "tpu"


def _pad_tau(tau, L: int, tile_l: int) -> jnp.ndarray:
    """Normalize ``tau`` (scalar or per-group ``(L,)``) to (L_pad,) fp32.

    Padded groups get tau = 0; together with their all-zero snapshots
    (zbar = 0 <= 0) they still always certify ZERO, so tile padding keeps
    costing nothing for every regularizer — including pure-l2, whose real
    groups also carry tau = 0.
    """
    return _pad_axis(tau_row(tau, L), 0, tile_l, 0.0)


def _pad_axis(x: jnp.ndarray, axis: int, mult: int, value=0.0):
    """Pad ``axis`` (negative axes OK — batched callers pad trailing dims)."""
    size = x.shape[axis]
    target = -(-size // mult) * mult
    if target == size:
        return x
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, target - size)
    return jnp.pad(x, pads, constant_values=value)


def _meta():
    return dataclasses.field(metadata=dict(static=True))


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class PaddedProblem:
    """One-time tile-padded problem geometry + the padded cost matrix.

    ``Cp`` is (L_pad * g, n_pad) with +PAD_COST in the padded area, so
    f = alpha + beta - c < 0 there and padded entries contribute exact
    zeros even inside partially-real tiles.
    """

    Cp: jnp.ndarray
    L: int = _meta()
    g: int = _meta()
    n: int = _meta()
    L_pad: int = _meta()
    n_pad: int = _meta()
    tile_l: int = _meta()
    tile_n: int = _meta()

    @property
    def grid(self) -> Tuple[int, int]:
        """``(L_tiles, N_tiles)`` — the kernel grid / flag-matrix shape."""
        return (self.L_pad // self.tile_l, self.n_pad // self.tile_n)

    @property
    def num_tiles(self) -> int:
        """Total tiles in the dense grid (per problem)."""
        lt, nt = self.grid
        return lt * nt


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class PaddedScreenState:
    """Tile-padded screening snapshots (fixed within a snapshot round).

    Padded rows/columns carry z~ = k~ = o~ = 0 and sqrt_g = 0, so their
    upper bound is 0 <= tau (ZERO) and their lower bound never certifies
    ACTIVE — padded-only tiles always flag as skipped.
    """

    z: jnp.ndarray              # (L_pad, n_pad)
    k: jnp.ndarray              # (L_pad, n_pad)
    o: jnp.ndarray              # (L_pad, n_pad)
    act: jnp.ndarray            # (L_pad, n_pad) int8
    sqrt_g: jnp.ndarray         # (L_pad,)
    alpha_snap: jnp.ndarray     # (m_pad,)  unpadded snapshot point
    beta_snap: jnp.ndarray      # (n,)


def prepare_padded_problem(
    C: jnp.ndarray,
    prob: DualProblem,
    tile_l: int = 0,
    tile_n: int = DEFAULT_TILE_N,
) -> PaddedProblem:
    """Pad C to tile multiples ONCE (owned by solve_dual, reused per eval)."""
    from repro.core.groups import PAD_COST

    L, g, n = prob.num_groups, prob.group_size, prob.n
    if tile_l == 0:
        tile_l = resolve_tile_l(L, g, tile_n, jnp.dtype(C.dtype).itemsize)
    L_pad, n_pad = prob.tile_padded_shape(tile_l, tile_n)
    Cp = _pad_axis(
        _pad_axis(C.reshape(L, g, n), 2, tile_n, PAD_COST), 0, tile_l, PAD_COST
    )
    return PaddedProblem(
        Cp=Cp.reshape(L_pad * g, n_pad),
        L=L, g=g, n=n, L_pad=L_pad, n_pad=n_pad,
        tile_l=tile_l, tile_n=tile_n,
    )


def pad_tile_inputs(
    alpha: jnp.ndarray, beta: jnp.ndarray, pp: PaddedProblem
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Pad the per-eval dual variables to the kernel grid of ``pp``.

    Batch-polymorphic (alpha (..., m_pad), beta (..., n)).  The single
    definition of the kernel input layout — shared by
    :func:`dual_value_and_grad_padded`, its batched variant, and the
    benchmarks.
    """
    lead = alpha.shape[:-1]
    alphap = _pad_axis(
        alpha.reshape(lead + (pp.L, pp.g)), -2, pp.tile_l, 0.0
    ).reshape(lead + (-1,))
    betap = _pad_axis(beta, -1, pp.tile_n, 0.0)
    return alphap, betap


def pad_screen_state(
    state: ScreenState, sqrt_g: jnp.ndarray, pp: PaddedProblem
) -> PaddedScreenState:
    """Pad the (L, n) snapshots to the kernel grid once per snapshot round."""
    pad2 = lambda x: _pad_axis(
        _pad_axis(x, 1, pp.tile_n, 0.0), 0, pp.tile_l, 0.0
    )
    return PaddedScreenState(
        z=pad2(state.z_snap),
        k=pad2(state.k_snap),
        o=pad2(state.o_snap),
        act=pad2(state.active.astype(jnp.int8)),
        sqrt_g=_pad_axis(sqrt_g, 0, pp.tile_l, 0.0),
        alpha_snap=state.alpha_snap,
        beta_snap=state.beta_snap,
    )


def screen_tile_flags(
    pstate: PaddedScreenState,
    alpha: jnp.ndarray,
    beta: jnp.ndarray,
    pp: PaddedProblem,
    tau,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Per-eval fused screening -> (L_tiles, N_tiles) skip flags.

    Computes the O(L + n) delta norms in jnp, then one Pallas pass over the
    padded bound matrices; the verdict matrix never reaches HBM.  ``tau``
    is a scalar or per-group ``(L,)`` threshold (see
    :meth:`repro.core.regularizers.Regularizer.tau_vec`).
    """
    if interpret is None:
        interpret = default_interpret()
    L = pp.L
    da_plus, da_full, da_neg = screening.grouped_norms(
        alpha - pstate.alpha_snap, L
    )
    db = beta - pstate.beta_snap
    padL = lambda x: _pad_axis(x, 0, pp.tile_l, 0.0)
    padN = lambda x: _pad_axis(x, 0, pp.tile_n, 0.0)
    _, flags = screen_pallas(
        pstate.z, pstate.k, pstate.o, pstate.act,
        padL(da_plus), padL(da_full), padL(da_neg), padN(db), pstate.sqrt_g,
        tau=_pad_tau(tau, L, pp.tile_l), tile_l=pp.tile_l, tile_n=pp.tile_n,
        interpret=interpret, emit_verdict=False,
    )
    return flags


@functools.partial(
    jax.jit, static_argnames=("prob", "impl", "interpret")
)
def dual_value_and_grad_padded(
    alpha: jnp.ndarray,
    beta: jnp.ndarray,
    a: jnp.ndarray,
    b: jnp.ndarray,
    flags: jnp.ndarray,             # (L_tiles, N_tiles) int32 skip flags
    pp: PaddedProblem,
    prob: DualProblem,
    impl: str = "auto",
    interpret: bool | None = None,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Screened Pallas evaluation against a prepared (pre-padded) problem.

    Identical to ``repro.core.dual.dual_value_and_grad`` with the screened
    mask (Theorem 2: masked entries are provably zero); this is the
    ``grad_impl='pallas'`` oracle of the solver.

    Parameters
    ----------
    alpha : jnp.ndarray
        ``(m_pad,)`` float32 source duals (unpadded kernel-input layout;
        tile padding happens here via :func:`pad_tile_inputs`).
    beta : jnp.ndarray
        ``(n,)`` float32 target duals.
    a, b : jnp.ndarray
        ``(m_pad,)`` / ``(n,)`` marginals.
    flags : jnp.ndarray
        ``(L_tiles, N_tiles)`` int32 tile skip flags (0 = certified-zero
        tile) from :func:`screen_tile_flags`.
    pp : PaddedProblem
        Prepared geometry + padded cost from :func:`prepare_padded_problem`.
    prob : DualProblem
        Static problem description (static jit arg).
    impl : {'grid', 'compact', 'auto'}
        Dense grid, compacted dynamic grid, or runtime density switch.
    interpret : bool, optional
        Pallas interpret mode; defaults to "not on a real TPU".

    Returns
    -------
    tuple of jnp.ndarray
        ``(value, grad_alpha, grad_beta)`` — scalar, ``(m_pad,)``,
        ``(n,)`` — for the MAXIMIZATION dual.
    """
    if interpret is None:
        interpret = default_interpret()
    L, g = pp.L, pp.g
    assert flags.shape == pp.grid, (flags.shape, pp.grid)

    alphap, betap = pad_tile_inputs(alpha, beta, pp)
    kw = dict(
        num_groups=pp.L_pad, group_size=g,
        tau=_pad_tau(prob.tau_vec(), pp.L, pp.tile_l), gamma=prob.reg.gamma,
        tile_l=pp.tile_l, tile_n=pp.tile_n, interpret=interpret,
    )

    def run_grid(flags):
        rowsum, colsum, psi = gradpsi_pallas(alphap, betap, pp.Cp, flags, **kw)
        return rowsum, colsum, psi, jnp.int32(pp.num_tiles)

    def run_compact(flags):
        sched, nact = build_tile_schedule(flags)
        return gradpsi_pallas_compact(alphap, betap, pp.Cp, sched, nact, **kw)

    if impl == "grid":
        rowsum, colsum, psi, _ = run_grid(flags)
    elif impl == "compact":
        rowsum, colsum, psi, _ = run_compact(flags)
    elif impl == "auto":
        live = jnp.sum(flags != 0)
        use_compact = live <= COMPACT_DENSITY_THRESHOLD * pp.num_tiles
        rowsum, colsum, psi, _ = jax.lax.cond(
            use_compact, run_compact, run_grid, flags
        )
    else:
        raise ValueError(f"unknown pallas impl: {impl}")

    rowsum = rowsum.reshape(pp.L_pad, g)[:L].reshape(-1)
    colsum = colsum[: pp.n]
    value = alpha @ a + beta @ b - psi
    return value, a - rowsum, b - colsum


# -- batched entry points (leading problem axis B) ----------------------------

def prepare_padded_problem_batched(
    C: jnp.ndarray,                    # (B, m_pad, n)
    prob: DualProblem,
    tile_l: int = 0,
    tile_n: int = DEFAULT_TILE_N,
) -> PaddedProblem:
    """Pad a batch of cost matrices to tile multiples once per solve.

    Returns a :class:`PaddedProblem` whose ``Cp`` is (B, L_pad * g, n_pad);
    the static geometry fields are shared by every problem in the batch.
    """
    from repro.core.groups import PAD_COST

    L, g, n = prob.num_groups, prob.group_size, prob.n
    B = C.shape[0]
    if tile_l == 0:
        tile_l = resolve_tile_l(L, g, tile_n, jnp.dtype(C.dtype).itemsize)
    L_pad, n_pad = prob.tile_padded_shape(tile_l, tile_n)
    Cp = _pad_axis(
        _pad_axis(C.reshape(B, L, g, n), -1, tile_n, PAD_COST),
        -3, tile_l, PAD_COST,
    )
    return PaddedProblem(
        Cp=Cp.reshape(B, L_pad * g, n_pad),
        L=L, g=g, n=n, L_pad=L_pad, n_pad=n_pad,
        tile_l=tile_l, tile_n=tile_n,
    )


def pad_screen_state_batched(
    state: ScreenState, sqrt_g: jnp.ndarray, pp: PaddedProblem
) -> PaddedScreenState:
    """Pad batched (B, L, n) snapshots to the kernel grid once per round.

    ``sqrt_g`` is (B, L) — per problem, because the serving engine packs
    problems with different true group sizes into one bucket.
    """
    pad2 = lambda x: _pad_axis(
        _pad_axis(x, -1, pp.tile_n, 0.0), -2, pp.tile_l, 0.0
    )
    return PaddedScreenState(
        z=pad2(state.z_snap),
        k=pad2(state.k_snap),
        o=pad2(state.o_snap),
        act=pad2(state.active.astype(jnp.int8)),
        sqrt_g=_pad_axis(sqrt_g, -1, pp.tile_l, 0.0),
        alpha_snap=state.alpha_snap,
        beta_snap=state.beta_snap,
    )


def screen_tile_flags_batched(
    pstate: PaddedScreenState,
    alpha: jnp.ndarray,                # (B, m_pad)
    beta: jnp.ndarray,                 # (B, n)
    pp: PaddedProblem,
    tau,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Per-eval fused screening for a batch -> (B, L_tiles, N_tiles) flags.

    The O(B (L + n)) delta norms run in jnp; the screening kernel vmaps
    over the problem axis (screening state never couples problems), so the
    per-problem verdict matrices still never reach HBM.  ``tau`` (scalar
    or per-group ``(L,)``) is shared by every problem in the batch — a
    bucket packs one regularizer.
    """
    if interpret is None:
        interpret = default_interpret()
    L = pp.L
    tau_p = _pad_tau(tau, L, pp.tile_l)
    da_plus, da_full, da_neg = screening.grouped_norms(
        alpha - pstate.alpha_snap, L
    )
    db = beta - pstate.beta_snap
    padL = lambda x: _pad_axis(x, -1, pp.tile_l, 0.0)
    padN = lambda x: _pad_axis(x, -1, pp.tile_n, 0.0)

    def one(z, k, o, act, dap, daf, dan, dbv, sg):
        _, flags = screen_pallas(
            z, k, o, act, dap, daf, dan, dbv, sg,
            tau=tau_p, tile_l=pp.tile_l, tile_n=pp.tile_n,
            interpret=interpret, emit_verdict=False,
        )
        return flags

    return jax.vmap(one)(
        pstate.z, pstate.k, pstate.o, pstate.act,
        padL(da_plus), padL(da_full), padL(da_neg), padN(db), pstate.sqrt_g,
    )


@functools.partial(
    jax.jit, static_argnames=("prob", "impl", "interpret")
)
def dual_value_and_grad_padded_batched(
    alpha: jnp.ndarray,                # (B, m_pad)
    beta: jnp.ndarray,                 # (B, n)
    a: jnp.ndarray,                    # (B, m_pad)
    b: jnp.ndarray,                    # (B, n)
    flags: jnp.ndarray,                # (B, L_tiles, N_tiles) int32
    pp: PaddedProblem,
    prob: DualProblem,
    impl: str = "auto",
    interpret: bool | None = None,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Screened Pallas evaluation of B problems against a prepared batch.

    Per problem identical (bitwise) to the solo padded path.  'compact'
    (and 'auto' below the density threshold) runs ONE dynamic grid over
    the concatenated surviving tiles of the whole batch, so grid steps
    scale with the batch's total live tiles.  Under ``shard_map`` each
    shard calls this on its local problems and builds its own schedule
    (see ``repro.core.sharded``).

    Parameters
    ----------
    alpha, beta : jnp.ndarray
        ``(B, m_pad)`` / ``(B, n)`` float32 duals.
    a, b : jnp.ndarray
        ``(B, m_pad)`` / ``(B, n)`` marginals.
    flags : jnp.ndarray
        ``(B, L_tiles, N_tiles)`` int32 per-problem tile skip flags from
        :func:`screen_tile_flags_batched`.
    pp : PaddedProblem
        Prepared batch geometry (``Cp`` is ``(B, L_pad*g, n_pad)``).
    prob : DualProblem
        Static problem description.
    impl : {'grid', 'compact', 'auto'}
        Gradient grid mode (both modes are bitwise-equal; 'auto' switches
        on the batch-wide live-tile fraction).
    interpret : bool, optional
        Pallas interpret mode; defaults to "not on a real TPU".

    Returns
    -------
    tuple of jnp.ndarray
        ``(value (B,), grad_alpha (B, m_pad), grad_beta (B, n))`` for the
        MAXIMIZATION dual.
    """
    if interpret is None:
        interpret = default_interpret()
    B = alpha.shape[0]
    L, g = pp.L, pp.g
    assert flags.shape == (B,) + pp.grid, (flags.shape, (B,) + pp.grid)

    alphap, betap = pad_tile_inputs(alpha, beta, pp)
    kw = dict(
        num_groups=pp.L_pad, group_size=g,
        tau=_pad_tau(prob.tau_vec(), pp.L, pp.tile_l), gamma=prob.reg.gamma,
        tile_l=pp.tile_l, tile_n=pp.tile_n, interpret=interpret,
    )

    def run_grid(flags):
        return gradpsi_pallas_batched(alphap, betap, pp.Cp, flags, **kw)

    def run_compact(flags):
        sched, nact = build_batch_tile_schedule(flags)
        rowsum, colsum, psi, _ = gradpsi_pallas_compact_batched(
            alphap, betap, pp.Cp, sched, nact, **kw
        )
        return rowsum, colsum, psi

    if impl == "grid":
        rowsum, colsum, psi = run_grid(flags)
    elif impl == "compact":
        rowsum, colsum, psi = run_compact(flags)
    elif impl == "auto":
        live = jnp.sum(flags != 0)
        use_compact = live <= COMPACT_DENSITY_THRESHOLD * B * pp.num_tiles
        rowsum, colsum, psi = jax.lax.cond(
            use_compact, run_compact, run_grid, flags
        )
    else:
        raise ValueError(f"unknown pallas impl: {impl}")

    rowsum = rowsum.reshape(B, pp.L_pad, g)[:, :L].reshape(B, -1)
    colsum = colsum[:, : pp.n]
    value = (
        jnp.sum(alpha * a, axis=-1) + jnp.sum(beta * b, axis=-1) - psi
    )
    return value, a - rowsum, b - colsum


@functools.partial(
    jax.jit,
    static_argnames=("prob", "tile_l", "tile_n", "interpret", "impl"),
)
def dual_value_and_grad(
    alpha: jnp.ndarray,
    beta: jnp.ndarray,
    C: jnp.ndarray,
    a: jnp.ndarray,
    b: jnp.ndarray,
    verdict: jnp.ndarray,           # (L, n) int32 from screening.verdicts
    prob: DualProblem,
    tile_l: int = 0,
    tile_n: int = DEFAULT_TILE_N,
    interpret: bool | None = None,
    impl: str = "auto",
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Block-masked Pallas evaluation from a raw verdict matrix.

    Convenience wrapper (tests, one-shot evaluations): pads C per call.  The
    solver's hot loop uses :func:`prepare_padded_problem` +
    :func:`dual_value_and_grad_padded` instead.
    """
    pp = prepare_padded_problem(C, prob, tile_l=tile_l, tile_n=tile_n)
    flags = screening.tile_flags(verdict, pp.tile_l, pp.tile_n)
    return dual_value_and_grad_padded(
        alpha, beta, a, b, flags, pp, prob, impl=impl, interpret=interpret
    )


@functools.partial(
    jax.jit, static_argnames=("tile_l", "tile_n", "interpret")
)
def screen_verdicts(
    z_snap, k_snap, o_snap, active, da_plus, da_full, da_neg, db, sqrt_g,
    tau,
    tile_l: int = 8,
    tile_n: int = 128,
    interpret: bool | None = None,
):
    """Pallas screening pass; pads (L, n) to tile multiples transparently.

    ``tau`` is a scalar or per-group ``(L,)`` threshold vector.
    """
    if interpret is None:
        interpret = default_interpret()
    L, n = z_snap.shape
    pad2 = lambda x: _pad_axis(_pad_axis(x, 1, tile_n, 0.0), 0, tile_l, 0.0)
    padL = lambda x: _pad_axis(x, 0, tile_l, 0.0)
    padN = lambda x: _pad_axis(x, 0, tile_n, 0.0)
    v, flags = screen_pallas(
        pad2(z_snap), pad2(k_snap),
        # padded k/o rows are zero => zlow <= 0 <= tau => never ACTIVE
        pad2(o_snap), pad2(active.astype(jnp.int8)),
        padL(da_plus), padL(da_full), padL(da_neg), padN(db), padL(sqrt_g),
        tau=_pad_tau(tau, L, tile_l), tile_l=tile_l, tile_n=tile_n,
        interpret=interpret,
    )
    return v[:L, :n], flags


# -- factorized (materialization-free) entry points ----------------------------
#
# The on-the-fly squared-l2 route (docs/geometry.md): the cost operand is a
# FactorizedCost pytree of scaled sample blocks + squared norms instead of a
# dense (m_pad, n) array.  The wrappers below mirror the padded dense ones
# one-for-one; the kernels rebuild each cost tile in VMEM via
# gradpsi.factorized_cost_tile, so HBM holds O((m + n) d) operand bytes
# instead of O(m n).


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class FactorizedCost:
    """Squared-l2 cost in factorized form — a drop-in cost-matrix pytree.

    Leaves are the scaled source/target samples and squared norms produced
    by :class:`repro.ot.geometry.SquaredL2Geometry` (normalization and
    PAD_COST sentinels are pre-folded into the stored values, so kernels
    need no extra scale or mask operands for the gradient).  Batched callers
    carry a leading problem axis on every leaf, which is what lets the
    sharded path's pytree-prefix specs and ``C[None]``-style lifts treat
    this exactly like a dense cost array.
    """

    x: jnp.ndarray      # (..., m_pad, d) fp32 scaled source samples
    x_sq: jnp.ndarray   # (..., m_pad)    fp32 scaled |x|^2 (+PAD_COST rows)
    y: jnp.ndarray      # (..., n, d)     fp32 scaled target samples
    y_sq: jnp.ndarray   # (..., n)        fp32 scaled |y|^2 (+PAD_COST cols)

    @property
    def shape(self) -> Tuple[int, ...]:
        """Shape of the equivalent dense cost array ``(..., m_pad, n)``."""
        return self.x.shape[:-1] + (self.y.shape[-2],)

    @property
    def dtype(self):
        """Dtype of the equivalent dense cost array."""
        return self.x.dtype

    @property
    def d(self) -> int:
        """Feature dimension of the sample blocks."""
        return self.x.shape[-1]


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class FactorizedProblem:
    """One-time tile-padded factorized problem (the on-the-fly PaddedProblem).

    Carries the same static geometry fields as :class:`PaddedProblem` (so
    :func:`pad_tile_inputs`, :func:`pad_screen_state_batched` and
    :func:`screen_tile_flags_batched` work unchanged) but the cost operand
    is the tile-padded sample factorization: padded rows are zero samples
    with ``x_sq = PAD_COST``, padded columns zero samples with
    ``y_sq = PAD_COST`` — every padded cost entry is >= PAD_COST, so
    f < 0 there and padded entries contribute exact zeros.
    """

    x: jnp.ndarray      # (..., L_pad*g, d)
    x_sq: jnp.ndarray   # (..., L_pad*g)
    y: jnp.ndarray      # (..., n_pad, d)
    y_sq: jnp.ndarray   # (..., n_pad)
    L: int = _meta()
    g: int = _meta()
    n: int = _meta()
    d: int = _meta()
    L_pad: int = _meta()
    n_pad: int = _meta()
    tile_l: int = _meta()
    tile_n: int = _meta()

    @property
    def grid(self) -> Tuple[int, int]:
        """``(L_tiles, N_tiles)`` — the kernel grid / flag-matrix shape."""
        return (self.L_pad // self.tile_l, self.n_pad // self.tile_n)

    @property
    def num_tiles(self) -> int:
        """Total tiles in the dense grid (per problem)."""
        lt, nt = self.grid
        return lt * nt


def prepare_factorized_problem(
    fc: FactorizedCost,
    prob: DualProblem,
    tile_l: int = 0,
    tile_n: int = DEFAULT_TILE_N,
) -> FactorizedProblem:
    """Tile-pad a factorized cost ONCE per solve (batch-polymorphic).

    The factorized analog of :func:`prepare_padded_problem` /
    :func:`prepare_padded_problem_batched`: leading batch axes on the
    ``fc`` leaves pass straight through.  TILE_L is resolved with the
    d-aware VMEM model (the kernels hold a (TILE_L, g, TILE_N, d)
    intermediate).
    """
    from repro.core.groups import PAD_COST

    L, g, n = prob.num_groups, prob.group_size, prob.n
    d = fc.d
    if tile_l == 0:
        tile_l = resolve_tile_l_factorized(
            L, g, tile_n, d, jnp.dtype(fc.dtype).itemsize
        )
    L_pad, n_pad = prob.tile_padded_shape(tile_l, tile_n)
    lead = fc.x.shape[:-2]
    x = _pad_axis(
        fc.x.reshape(lead + (L, g, d)), -3, tile_l, 0.0
    ).reshape(lead + (L_pad * g, d))
    x_sq = _pad_axis(
        fc.x_sq.reshape(lead + (L, g)), -2, tile_l, PAD_COST
    ).reshape(lead + (L_pad * g,))
    y = _pad_axis(fc.y, -2, tile_n, 0.0)
    y_sq = _pad_axis(fc.y_sq, -1, tile_n, PAD_COST)
    return FactorizedProblem(
        x=x, x_sq=x_sq, y=y, y_sq=y_sq,
        L=L, g=g, n=n, d=d, L_pad=L_pad, n_pad=n_pad,
        tile_l=tile_l, tile_n=tile_n,
    )


@functools.partial(
    jax.jit, static_argnames=("prob", "impl", "interpret")
)
def dual_value_and_grad_factorized(
    alpha: jnp.ndarray,
    beta: jnp.ndarray,
    a: jnp.ndarray,
    b: jnp.ndarray,
    flags: jnp.ndarray,             # (L_tiles, N_tiles) int32 skip flags
    fp: FactorizedProblem,
    prob: DualProblem,
    impl: str = "auto",
    interpret: bool | None = None,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Screened materialization-free evaluation (solo).

    Drop-in for :func:`dual_value_and_grad_padded` with the prepared dense
    cost replaced by a :class:`FactorizedProblem`; bitwise-equal to the
    dense path on a cost materialized with the same factorized recipe.
    """
    if interpret is None:
        interpret = default_interpret()
    from repro.kernels.gradpsi import (
        gradpsi_fact_pallas,
        gradpsi_fact_pallas_compact,
    )

    L, g = fp.L, fp.g
    assert flags.shape == fp.grid, (flags.shape, fp.grid)

    alphap, betap = pad_tile_inputs(alpha, beta, fp)
    kw = dict(
        num_groups=fp.L_pad, group_size=g,
        tau=_pad_tau(prob.tau_vec(), fp.L, fp.tile_l), gamma=prob.reg.gamma,
        tile_l=fp.tile_l, tile_n=fp.tile_n, interpret=interpret,
    )

    def run_grid(flags):
        rowsum, colsum, psi = gradpsi_fact_pallas(
            alphap, betap, fp.x, fp.x_sq, fp.y, fp.y_sq, flags, **kw
        )
        return rowsum, colsum, psi, jnp.int32(fp.num_tiles)

    def run_compact(flags):
        sched, nact = build_tile_schedule(flags)
        return gradpsi_fact_pallas_compact(
            alphap, betap, fp.x, fp.x_sq, fp.y, fp.y_sq, sched, nact, **kw
        )

    if impl == "grid":
        rowsum, colsum, psi, _ = run_grid(flags)
    elif impl == "compact":
        rowsum, colsum, psi, _ = run_compact(flags)
    elif impl == "auto":
        live = jnp.sum(flags != 0)
        use_compact = live <= COMPACT_DENSITY_THRESHOLD * fp.num_tiles
        rowsum, colsum, psi, _ = jax.lax.cond(
            use_compact, run_compact, run_grid, flags
        )
    else:
        raise ValueError(f"unknown pallas impl: {impl}")

    rowsum = rowsum.reshape(fp.L_pad, g)[:L].reshape(-1)
    colsum = colsum[: fp.n]
    value = alpha @ a + beta @ b - psi
    return value, a - rowsum, b - colsum


@functools.partial(
    jax.jit, static_argnames=("prob", "impl", "interpret")
)
def dual_value_and_grad_factorized_batched(
    alpha: jnp.ndarray,                # (B, m_pad)
    beta: jnp.ndarray,                 # (B, n)
    a: jnp.ndarray,                    # (B, m_pad)
    b: jnp.ndarray,                    # (B, n)
    flags: jnp.ndarray,                # (B, L_tiles, N_tiles) int32
    fp: FactorizedProblem,
    prob: DualProblem,
    impl: str = "auto",
    interpret: bool | None = None,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Screened materialization-free evaluation of B problems.

    Drop-in for :func:`dual_value_and_grad_padded_batched`; per problem
    bitwise-equal to the solo factorized path.
    """
    if interpret is None:
        interpret = default_interpret()
    from repro.kernels.gradpsi import (
        gradpsi_fact_pallas_batched,
        gradpsi_fact_pallas_compact_batched,
    )

    B = alpha.shape[0]
    L, g = fp.L, fp.g
    assert flags.shape == (B,) + fp.grid, (flags.shape, (B,) + fp.grid)

    alphap, betap = pad_tile_inputs(alpha, beta, fp)
    kw = dict(
        num_groups=fp.L_pad, group_size=g,
        tau=_pad_tau(prob.tau_vec(), fp.L, fp.tile_l), gamma=prob.reg.gamma,
        tile_l=fp.tile_l, tile_n=fp.tile_n, interpret=interpret,
    )

    def run_grid(flags):
        return gradpsi_fact_pallas_batched(
            alphap, betap, fp.x, fp.x_sq, fp.y, fp.y_sq, flags, **kw
        )

    def run_compact(flags):
        sched, nact = build_batch_tile_schedule(flags)
        rowsum, colsum, psi, _ = gradpsi_fact_pallas_compact_batched(
            alphap, betap, fp.x, fp.x_sq, fp.y, fp.y_sq, sched, nact, **kw
        )
        return rowsum, colsum, psi

    if impl == "grid":
        rowsum, colsum, psi = run_grid(flags)
    elif impl == "compact":
        rowsum, colsum, psi = run_compact(flags)
    elif impl == "auto":
        live = jnp.sum(flags != 0)
        use_compact = live <= COMPACT_DENSITY_THRESHOLD * B * fp.num_tiles
        rowsum, colsum, psi = jax.lax.cond(
            use_compact, run_compact, run_grid, flags
        )
    else:
        raise ValueError(f"unknown pallas impl: {impl}")

    rowsum = rowsum.reshape(B, fp.L_pad, g)[:, :L].reshape(B, -1)
    colsum = colsum[:, : fp.n]
    value = (
        jnp.sum(alpha * a, axis=-1) + jnp.sum(beta * b, axis=-1) - psi
    )
    return value, a - rowsum, b - colsum


def snapshot_norms_factorized(
    alpha: jnp.ndarray,
    beta: jnp.ndarray,
    fp: FactorizedProblem,
    prob: DualProblem,
    row_mask: jnp.ndarray,
    interpret: bool | None = None,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Materialization-free Eq. 6 snapshot norms (z~, k~, o~ each (..., L, n)).

    Drop-in for :func:`repro.core.dual.snapshot_norms` on the on-the-fly
    route: one Pallas pass rebuilds cost tiles from the sample blocks and
    reduces the three per-group norms in VMEM.  Batch-polymorphic — batched
    callers vmap the solo kernel (the existing screen-kernel idiom), and a
    shared ``(m_pad,)`` row mask broadcasts across the batch.
    """
    if interpret is None:
        interpret = default_interpret()
    from repro.kernels.screen import snapshot_norms_fact_pallas

    L, g, n = fp.L, fp.g, fp.n
    alphap, betap = pad_tile_inputs(alpha, beta, fp)
    mask = row_mask.reshape(row_mask.shape[:-1] + (L, g)).astype(jnp.int8)
    maskp = _pad_axis(mask, -2, fp.tile_l, 0)
    maskp = maskp.reshape(maskp.shape[:-2] + (-1,))

    def one(al, be, xv, xs, yv, ys, mk):
        z, k, o = snapshot_norms_fact_pallas(
            al, be, xv, xs, yv, ys, mk,
            num_groups=fp.L_pad, group_size=g,
            tile_l=fp.tile_l, tile_n=fp.tile_n, interpret=interpret,
        )
        return z[:L, :n], k[:L, :n], o[:L, :n]

    if alpha.ndim == 1:
        return one(alphap, betap, fp.x, fp.x_sq, fp.y, fp.y_sq, maskp)

    B = alphap.shape[0]
    maskb = jnp.broadcast_to(maskp, (B,) + maskp.shape[-1:])
    return jax.vmap(one)(
        alphap, betap, fp.x, fp.x_sq, fp.y, fp.y_sq, maskb
    )


# -- fused screen+gradient entry points (DESIGN.md §10) -------------------------
#
# The steady-state oracle of grad_impl='fused': ONE Pallas launch per L-BFGS
# evaluation computes the screening verdict in-register and the screened
# gradient in the same grid step.  The wrappers below mirror the two-launch
# padded/factorized entry points one-for-one and dispatch on the prepared
# problem's cost representation, so the solver needs a single fused branch.


def snapshot_live_tiles(pstate: PaddedScreenState, pp, tau) -> jnp.ndarray:
    """Live-tile count at the snapshot point (deltas = 0) — no kernel launch.

    At the snapshot point the Eq. 6 upper bound is exactly z~, so a tile is
    live iff any entry is ACTIVE or has ``z~ > tau``.  This is the fused
    route's 'auto' heuristic input: computed once per round from the padded
    snapshots with plain XLA ops, it amortizes to nothing over the round's
    evaluations, unlike the per-eval screen launch it replaces.  Counts the
    TOTAL over a leading batch axis when ``pstate`` is batched.
    """
    tau_p = _pad_tau(tau, pp.L, pp.tile_l)
    nz = jnp.logical_or(pstate.act != 0, pstate.z > tau_p[:, None])
    lt, nt = pp.grid
    lead = nz.shape[:-2]
    tiles = nz.reshape(lead + (lt, pp.tile_l, nt, pp.tile_n))
    return jnp.sum(jnp.any(tiles, axis=(-3, -1)))


@functools.partial(
    jax.jit, static_argnames=("prob", "impl", "interpret")
)
def dual_value_and_grad_fused(
    alpha: jnp.ndarray,
    beta: jnp.ndarray,
    a: jnp.ndarray,
    b: jnp.ndarray,
    pstate: PaddedScreenState,
    pp,
    prob: DualProblem,
    impl: str = "auto",
    interpret: bool | None = None,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Fused screened evaluation: verdicts + gradient in ONE Pallas launch.

    The ``grad_impl='fused'`` oracle (solo).  Consumes the padded screening
    snapshots directly instead of a precomputed flag matrix; the kernel
    computes the per-tile verdicts in-register (DESIGN.md §10).  ``pp`` may
    be a :class:`PaddedProblem` (dense cost) or :class:`FactorizedProblem`
    (on-the-fly cost) — the fused kernel layout is chosen accordingly.

    ``impl`` maps to fused execution modes:

    - ``'grid'``: the fused dense grid — one launch, every tile steps.
    - ``'compact'``: the two-launch reference (standalone screen pass +
      compacted gradient grid).  There is no fused compact mode — a compact
      schedule needs flags before launch, which is exactly the screen pass
      fused mode removes.
    - ``'auto'``: runtime :func:`jax.lax.cond` between the two on the
      snapshot-point live-tile density (:func:`snapshot_live_tiles`) —
      fused when dense, two-launch compact under heavy screening.  Both
      branches are bitwise-equal, so the switch never changes iterates.

    Returns ``(value, grad_alpha (m_pad,), grad_beta (n,))`` for the
    MAXIMIZATION dual — bitwise-identical to the two-launch
    :func:`dual_value_and_grad_padded` / :func:`dual_value_and_grad_factorized`
    oracle on the same inputs.
    """
    if interpret is None:
        interpret = default_interpret()
    from repro.kernels.gradpsi import (
        gradpsi_fact_pallas_compact,
        gradpsi_fused_fact_pallas,
        gradpsi_fused_pallas,
        gradpsi_pallas_compact,
    )

    L, g = pp.L, pp.g
    factorized = isinstance(pp, FactorizedProblem)
    cost_ops = (pp.x, pp.x_sq, pp.y, pp.y_sq) if factorized else (pp.Cp,)
    fused_fn = gradpsi_fused_fact_pallas if factorized else gradpsi_fused_pallas
    compact_fn = (
        gradpsi_fact_pallas_compact if factorized else gradpsi_pallas_compact
    )

    alphap, betap = pad_tile_inputs(alpha, beta, pp)
    tau_p = _pad_tau(prob.tau_vec(), L, pp.tile_l)
    kw = dict(
        num_groups=pp.L_pad, group_size=g, tau=tau_p, gamma=prob.reg.gamma,
        tile_l=pp.tile_l, tile_n=pp.tile_n, interpret=interpret,
    )

    da_plus, da_full, da_neg = screening.grouped_norms(
        alpha - pstate.alpha_snap, L
    )
    db = beta - pstate.beta_snap
    padL = lambda v: _pad_axis(v, 0, pp.tile_l, 0.0)
    padN = lambda v: _pad_axis(v, 0, pp.tile_n, 0.0)
    dap, daf, dan, dbp = padL(da_plus), padL(da_full), padL(da_neg), padN(db)

    def run_fused(_):
        rowsum, colsum, psi, _flags = fused_fn(
            alphap, betap, *cost_ops,
            pstate.z, pstate.k, pstate.o, pstate.act,
            dap, daf, dan, dbp, pstate.sqrt_g, **kw,
        )
        return rowsum, colsum, psi

    def run_two_launch(_):
        _, flags = screen_pallas(
            pstate.z, pstate.k, pstate.o, pstate.act,
            dap, daf, dan, dbp, pstate.sqrt_g,
            tau=tau_p, tile_l=pp.tile_l, tile_n=pp.tile_n,
            interpret=interpret, emit_verdict=False,
        )
        sched, nact = build_tile_schedule(flags)
        rowsum, colsum, psi, _ = compact_fn(
            alphap, betap, *cost_ops, sched, nact, **kw
        )
        return rowsum, colsum, psi

    if impl == "grid":
        rowsum, colsum, psi = run_fused(None)
    elif impl == "compact":
        rowsum, colsum, psi = run_two_launch(None)
    elif impl == "auto":
        live0 = snapshot_live_tiles(pstate, pp, prob.tau_vec())
        use_compact = live0 <= COMPACT_DENSITY_THRESHOLD * pp.num_tiles
        rowsum, colsum, psi = jax.lax.cond(
            use_compact, run_two_launch, run_fused, 0
        )
    else:
        raise ValueError(f"unknown pallas impl: {impl}")

    rowsum = rowsum.reshape(pp.L_pad, g)[:L].reshape(-1)
    colsum = colsum[: pp.n]
    value = alpha @ a + beta @ b - psi
    return value, a - rowsum, b - colsum


@functools.partial(
    jax.jit, static_argnames=("prob", "impl", "interpret")
)
def dual_value_and_grad_fused_batched(
    alpha: jnp.ndarray,                # (B, m_pad)
    beta: jnp.ndarray,                 # (B, n)
    a: jnp.ndarray,                    # (B, m_pad)
    b: jnp.ndarray,                    # (B, n)
    pstate: PaddedScreenState,         # batched leaves
    pp,
    prob: DualProblem,
    impl: str = "auto",
    interpret: bool | None = None,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Fused screened evaluation of B problems: ONE launch per eval.

    Batched :func:`dual_value_and_grad_fused` — the fused kernel runs a
    (B, Lt, Nt) grid; the ``'compact'``/low-density-``'auto'`` reference
    branch vmaps the standalone screen kernel and runs one dynamic grid
    over the batch's concatenated surviving tiles, exactly like the
    two-launch batched oracle.  Per problem bitwise-identical to the solo
    fused path.  Returns ``(value (B,), grad_alpha (B, m_pad), grad_beta
    (B, n))``.
    """
    if interpret is None:
        interpret = default_interpret()
    from repro.kernels.gradpsi import (
        gradpsi_fact_pallas_compact_batched,
        gradpsi_fused_fact_pallas_batched,
        gradpsi_fused_pallas_batched,
        gradpsi_pallas_compact_batched,
    )

    B = alpha.shape[0]
    L, g = pp.L, pp.g
    factorized = isinstance(pp, FactorizedProblem)
    cost_ops = (pp.x, pp.x_sq, pp.y, pp.y_sq) if factorized else (pp.Cp,)
    fused_fn = (
        gradpsi_fused_fact_pallas_batched
        if factorized
        else gradpsi_fused_pallas_batched
    )
    compact_fn = (
        gradpsi_fact_pallas_compact_batched
        if factorized
        else gradpsi_pallas_compact_batched
    )

    alphap, betap = pad_tile_inputs(alpha, beta, pp)
    tau_p = _pad_tau(prob.tau_vec(), L, pp.tile_l)
    kw = dict(
        num_groups=pp.L_pad, group_size=g, tau=tau_p, gamma=prob.reg.gamma,
        tile_l=pp.tile_l, tile_n=pp.tile_n, interpret=interpret,
    )

    da_plus, da_full, da_neg = screening.grouped_norms(
        alpha - pstate.alpha_snap, L
    )
    db = beta - pstate.beta_snap
    padL = lambda v: _pad_axis(v, -1, pp.tile_l, 0.0)
    padN = lambda v: _pad_axis(v, -1, pp.tile_n, 0.0)
    dap, daf, dan, dbp = padL(da_plus), padL(da_full), padL(da_neg), padN(db)

    def run_fused(_):
        rowsum, colsum, psi, _flags = fused_fn(
            alphap, betap, *cost_ops,
            pstate.z, pstate.k, pstate.o, pstate.act,
            dap, daf, dan, dbp, pstate.sqrt_g, **kw,
        )
        return rowsum, colsum, psi

    def run_two_launch(_):
        def one(z, k, o, act, dp, df, dn, dbv, sg):
            _, fl = screen_pallas(
                z, k, o, act, dp, df, dn, dbv, sg,
                tau=tau_p, tile_l=pp.tile_l, tile_n=pp.tile_n,
                interpret=interpret, emit_verdict=False,
            )
            return fl

        flags = jax.vmap(one)(
            pstate.z, pstate.k, pstate.o, pstate.act,
            dap, daf, dan, dbp, pstate.sqrt_g,
        )
        sched, nact = build_batch_tile_schedule(flags)
        rowsum, colsum, psi, _ = compact_fn(
            alphap, betap, *cost_ops, sched, nact, **kw
        )
        return rowsum, colsum, psi

    if impl == "grid":
        rowsum, colsum, psi = run_fused(None)
    elif impl == "compact":
        rowsum, colsum, psi = run_two_launch(None)
    elif impl == "auto":
        live0 = snapshot_live_tiles(pstate, pp, prob.tau_vec())
        use_compact = live0 <= COMPACT_DENSITY_THRESHOLD * B * pp.num_tiles
        rowsum, colsum, psi = jax.lax.cond(
            use_compact, run_two_launch, run_fused, 0
        )
    else:
        raise ValueError(f"unknown pallas impl: {impl}")

    rowsum = rowsum.reshape(B, pp.L_pad, g)[:, :L].reshape(B, -1)
    colsum = colsum[:, : pp.n]
    value = (
        jnp.sum(alpha * a, axis=-1) + jnp.sum(beta * b, axis=-1) - psi
    )
    return value, a - rowsum, b - colsum
