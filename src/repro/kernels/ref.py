"""Pure-jnp oracles for the Pallas kernels (ground truth for allclose tests)."""
from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp

from repro.core.screening import ACTIVE, CHECK, ZERO
from repro.kernels.gradpsi import tau_row


def gradpsi_ref(
    alpha: jnp.ndarray,
    beta: jnp.ndarray,
    C: jnp.ndarray,
    flags: jnp.ndarray,            # (L_tiles, N_tiles) int32
    *,
    num_groups: int,
    group_size: int,
    tau,
    gamma: float,
    tile_l: int,
    tile_n: int,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Oracle for gradpsi_pallas: same tile-masking semantics, plain jnp.

    ``tau`` is a scalar or a per-group ``(L,)`` threshold vector, exactly
    as the kernel accepts it.
    """
    L, g = num_groups, group_size
    n = beta.shape[0]
    tau_c = tau_row(tau, L)[:, None]
    F = (
        alpha.reshape(L, g)[:, :, None].astype(jnp.float32)
        + beta[None, None, :].astype(jnp.float32)
        - C.reshape(L, g, n).astype(jnp.float32)
    )
    Fp = jnp.maximum(F, 0.0)
    Z = jnp.sqrt(jnp.sum(Fp * Fp, axis=1))               # (L, n)
    on = Z > tau_c
    Zs = jnp.where(on, Z, 1.0)
    s = jnp.where(on, 1.0 - tau_c / Zs, 0.0)
    # expand tile flags to per-entry mask
    mask = jnp.repeat(jnp.repeat(flags != 0, tile_l, axis=0), tile_n, axis=1)
    s = jnp.where(mask, s, 0.0)
    T = s[:, None, :] * Fp / gamma
    psi = jnp.where(
        on, s * Zs * Zs / gamma * (1.0 - 0.5 * s) - (tau_c / gamma) * s * Zs, 0.0
    )
    psi = jnp.where(mask, psi, 0.0)
    return (
        jnp.sum(T, axis=2).reshape(-1),
        jnp.sum(T, axis=(0, 1)),
        jnp.sum(psi),
    )


def build_tile_schedule_ref(flags) -> Tuple[jnp.ndarray, int]:
    """Oracle for gradpsi.build_tile_schedule: plain Python compaction."""
    import numpy as np

    flags = np.asarray(flags)
    Lt, Nt = flags.shape
    T = Lt * Nt
    coords = [(l, j) for l in range(Lt) for j in range(Nt) if flags[l, j]]
    num_active = len(coords)
    pad = coords[-1] if coords else (0, 0)
    coords = coords + [pad] * (T - num_active)
    return jnp.asarray(np.array(coords, np.int32).T.reshape(2, T)), num_active


def screen_ref(
    z_snap, k_snap, o_snap, active, da_plus, da_full, da_neg, db, sqrt_g,
    *, tau, tile_l: int, tile_n: int,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Oracle for screen_pallas (``tau`` scalar or per-group ``(L,)``)."""
    tau_c = tau_row(tau, z_snap.shape[0])[:, None]
    zbar = z_snap + da_plus[:, None] + sqrt_g[:, None] * jnp.maximum(db, 0.0)[None, :]
    zlow = (
        k_snap
        - da_full[:, None]
        - sqrt_g[:, None] * jnp.abs(db)[None, :]
        - o_snap
        - da_neg[:, None]
        - sqrt_g[:, None] * jnp.maximum(-db, 0.0)[None, :]
    )
    v = jnp.where(zbar <= tau_c, ZERO, CHECK)
    v = jnp.where(active != 0, ACTIVE, v)
    v = jnp.where(jnp.logical_and(v == CHECK, zlow > tau_c), ACTIVE, v)
    v = v.astype(jnp.int32)
    L, n = v.shape
    vt = v.reshape(L // tile_l, tile_l, n // tile_n, tile_n)
    flags = jnp.any(vt != ZERO, axis=(1, 3)).astype(jnp.int32)
    return v, flags
