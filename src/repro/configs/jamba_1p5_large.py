"""jamba-1.5-large-398b [arXiv:2403.19887; hf]: Mamba+attn 1:7, MoE 16e top-2.

72L d_model=8192 64H (GQA kv=8) d_ff=24576 vocab=65536; attention every 8th
layer (1:7 interleave), MoE every other layer (16 experts top-2).
"""
from repro.configs.base import MoEConfig, ModelConfig, SSMConfig

CONFIG = ModelConfig(
    arch_id="jamba-1.5-large-398b",
    family="hybrid",
    num_layers=72,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=24576,
    vocab_size=65536,
    attn_period=8,
    moe=MoEConfig(num_experts=16, top_k=2, expert_d_ff=24576),
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2, chunk=128),
    attention_free_or_hybrid=True,
    use_rope=False,  # jamba attention layers use no positional encoding
)
