"""smollm-135m [hf:HuggingFaceTB/SmolLM-135M; hf]: small llama-arch.

30L d_model=576 9H (GQA kv=3) d_ff=1536 vocab=49152, tied embeddings.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="smollm-135m",
    family="dense",
    num_layers=30,
    d_model=576,
    num_heads=9,
    num_kv_heads=3,
    d_ff=1536,
    vocab_size=49152,
    tie_embeddings=True,
    rope_theta=1e4,
)
