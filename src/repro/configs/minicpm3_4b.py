"""minicpm3-4b [hf:openbmb/MiniCPM3-4B; hf]: MLA attention.

62L d_model=2560 40H d_ff=6400 vocab=73448; MLA with q_lora=768,
kv_lora=256, qk_nope=64, qk_rope=32, v_head=64 (HF config values).
"""
from repro.configs.base import MLAConfig, ModelConfig

CONFIG = ModelConfig(
    arch_id="minicpm3-4b",
    family="dense",
    num_layers=62,
    d_model=2560,
    num_heads=40,
    num_kv_heads=40,
    d_ff=6400,
    vocab_size=73448,
    mla=MLAConfig(
        q_lora_rank=768,
        kv_lora_rank=256,
        qk_nope_head_dim=64,
        qk_rope_head_dim=32,
        v_head_dim=64,
    ),
    rope_theta=1e4,
)
