"""xlstm-1.3b [arXiv:2405.04517; unverified].

48 blocks, d_model=2048, 4 heads, sLSTM:mLSTM = 1:7, no separate FFN
(d_ff=0; mLSTM blocks carry their own x2 up/down projection, sLSTM blocks a
4/3 gated FFN, following the xLSTM block design).
"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    arch_id="xlstm-1.3b",
    family="ssm",
    num_layers=48,
    d_model=2048,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    ssm=SSMConfig(slstm_every=8, proj_factor=2.0, mlstm_chunk=128),
    attention_free_or_hybrid=True,
)
