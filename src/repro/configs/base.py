"""Config system: model / shape / train / serve / mesh dataclasses.

Every assigned architecture is a ``ModelConfig`` in its own module under
repro.configs (registered in registry.py, selectable via ``--arch <id>``).
Shapes are the assignment's four input-shape cells; ``input_specs`` (in
launch/specs.py) turns (arch, shape) into ShapeDtypeStruct stand-ins.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    num_shared_experts: int = 0        # qwen2-moe: shared experts always on
    expert_d_ff: int = 0               # routed expert hidden dim
    shared_d_ff: int = 0               # shared expert hidden dim
    capacity_factor: float = 1.25
    router_z_loss: float = 1e-3
    # shard-local dispatch: capacity slots owned per data shard; removes the
    # global scatter's cross-data-shard all-reduce (§Perf); semantics change
    # only in WHICH tokens drop at capacity (per-shard vs global cutoff).
    local_dispatch: bool = False
    # beyond-paper: balance assignments with the screened group-sparse OT
    ot_balance: bool = False
    ot_gamma: float = 5.0
    ot_rho: float = 0.5


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    q_lora_rank: int = 768
    kv_lora_rank: int = 256
    qk_nope_head_dim: int = 64
    qk_rope_head_dim: int = 32
    v_head_dim: int = 64


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    # mamba (jamba) parameters
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0                   # 0 => ceil(d_model / 16)
    chunk: int = 128                   # remat chunk for the selective scan
    # xlstm parameters
    slstm_every: int = 8               # 1 sLSTM per 8 blocks (rest mLSTM)
    proj_factor: float = 2.0           # mLSTM up-projection
    mlstm_chunk: int = 128


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    arch_id: str
    family: str                        # dense|moe|ssm|hybrid|encdec|vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                  # 0 => d_model // num_heads
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    # hybrid (jamba): one attention layer per `attn_period` layers
    attn_period: int = 0
    # vlm: one cross-attn layer per `cross_attn_period` self-attn layers
    cross_attn_period: int = 0
    num_image_tokens: int = 1601       # llama-3.2 vision: 1601 patch tokens
    # enc-dec (whisper)
    encoder_layers: int = 0
    num_audio_frames: int = 1500
    rope_theta: float = 1e4
    use_rope: bool = True              # whisper uses learned positions instead
    max_decode_len: int = 32_768       # learned-position table size (enc-dec)
    rms_eps: float = 1e-5
    tie_embeddings: bool = False
    act: str = "swiglu"                # swiglu | gelu
    norm: str = "rmsnorm"              # rmsnorm | layernorm
    # sub-quadratic? (decides long_500k applicability)
    attention_free_or_hybrid: bool = False
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    # python-loop the layer stack instead of lax.scan.  Used by the dry-run
    # cost-model probes: XLA cost analysis counts a while body once, so
    # per-layer costs are only measurable from an unrolled lowering.
    unroll_layers: bool = False
    # int8 KV cache (serve-time): ~1.9x less decode HBM traffic on
    # KV-dominated cells; per-(token, head) scales; see §Perf kv_int8.
    kv_quant: bool = False

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    def reduced(self, **overrides) -> "ModelConfig":
        """Smoke-test-sized variant of the same family (tiny dims)."""
        small = dict(
            num_layers=min(self.num_layers, 4),
            d_model=128,
            num_heads=4,
            num_kv_heads=min(self.num_kv_heads, 2),
            d_ff=256,
            vocab_size=512,
            head_dim=32,
            num_image_tokens=16,
            num_audio_frames=32,
            max_decode_len=512,
            encoder_layers=min(self.encoder_layers, 2),
            attn_period=min(self.attn_period, 4) if self.attn_period else 0,
            cross_attn_period=(
                min(self.cross_attn_period, 2) if self.cross_attn_period else 0
            ),
            param_dtype="float32",
            compute_dtype="float32",
        )
        if self.moe is not None:
            small["moe"] = dataclasses.replace(
                self.moe,
                num_experts=min(self.moe.num_experts, 8),
                top_k=min(self.moe.top_k, 2),
                num_shared_experts=min(self.moe.num_shared_experts, 1),
                expert_d_ff=128,
                shared_d_ff=128,
                # no capacity drops at smoke scale: keeps teacher-forced
                # forward == prefill+decode exactly comparable in tests
                capacity_factor=4.0,
            )
        if self.mla is not None:
            small["mla"] = MLAConfig(
                q_lora_rank=48, kv_lora_rank=32,
                qk_nope_head_dim=16, qk_rope_head_dim=16, v_head_dim=16,
            )
        if self.ssm is not None:
            small["ssm"] = dataclasses.replace(
                self.ssm, d_state=8, chunk=16, mlstm_chunk=16,
                slstm_every=min(self.ssm.slstm_every, 2),
            )
        small.update(overrides)
        return dataclasses.replace(self, **small)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One assignment cell: (kind, seq_len, global_batch)."""

    name: str
    kind: str                  # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES: Tuple[ShapeConfig, ...] = (
    ShapeConfig("train_4k", "train", 4_096, 256),
    ShapeConfig("prefill_32k", "prefill", 32_768, 32),
    ShapeConfig("decode_32k", "decode", 32_768, 128),
    ShapeConfig("long_500k", "decode", 524_288, 1),
)

SHAPES_BY_NAME = {s.name: s for s in SHAPES}


def shape_applicable(model: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Assignment skip rules (documented in DESIGN.md §Arch-applicability)."""
    if shape.name == "long_500k" and not model.attention_free_or_hybrid:
        return False, "pure full-attention arch: O(S^2) at 500k out of scope"
    return True, ""


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    name: str = "adamw"
    lr: float = 3e-4
    warmup_steps: int = 100
    decay_steps: int = 10_000
    min_lr_ratio: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    # master fp32 copy of bf16 params (off for the very largest archs)
    master_weights: bool = True


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    optimizer: OptimizerConfig = dataclasses.field(default_factory=OptimizerConfig)
    microbatch: int = 0                 # 0 => no gradient accumulation
    remat: str = "block"                # none | block | full
    z_loss: float = 1e-4
    seed: int = 0
    steps: int = 100
    log_every: int = 10
    checkpoint_every: int = 50
    # paper integration: OT domain-alignment auxiliary loss (routed through
    # repro.ot.OTLayer — exact Danskin gradients; docs/training.md)
    ot_align: bool = False
    ot_align_weight: float = 0.1
    ot_gamma: float = 1.0
    ot_rho: float = 0.6
    ot_solver: str = "lbfgs"            # lbfgs | stochastic (ExecutionPlan.solver)
    ot_grad_impl: str = "screened"      # dense | screened | pallas | fused
    # cross-pod gradient compression (error-feedback int8)
    grad_compression: str = "none"      # none | int8_ef
    # constrain gradient leaves to their param shardings before the optimizer
    # (forces reduce-scatter instead of all-reduce+slice in GSPMD; §Perf)
    constrain_grads: bool = False
