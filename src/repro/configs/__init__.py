from repro.configs.base import (
    MLAConfig,
    MoEConfig,
    ModelConfig,
    OptimizerConfig,
    SHAPES,
    SHAPES_BY_NAME,
    SSMConfig,
    ShapeConfig,
    TrainConfig,
    shape_applicable,
)
from repro.configs.registry import all_configs, get_config, list_archs
