"""qwen2-moe-a2.7b [hf:Qwen/Qwen1.5-MoE-A2.7B; hf].

24L d_model=2048 16H (GQA kv=16) d_ff=1408 vocab=151936,
MoE: 4 shared + 60 routed top-4 (moe_intermediate=1408, shared=5632).
"""
from repro.configs.base import MoEConfig, ModelConfig

CONFIG = ModelConfig(
    arch_id="qwen2-moe-a2.7b",
    family="moe",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=1408,
    vocab_size=151936,
    moe=MoEConfig(
        num_experts=60,
        top_k=4,
        num_shared_experts=4,
        expert_d_ff=1408,
        shared_d_ff=5632,
    ),
    rope_theta=1e6,
)
