"""whisper-medium [arXiv:2212.04356; unverified].

Enc-dec: 24+24L d_model=1024 16H d_ff=4096 vocab=51865; GELU + layernorm;
learned decoder positions, sinusoidal encoder positions; conv frontend is a
STUB (input_specs provides precomputed frame embeddings, n_frames=1500).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="whisper-medium",
    family="encdec",
    num_layers=24,
    encoder_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=4096,
    vocab_size=51865,
    num_audio_frames=1500,
    act="gelu",
    norm="layernorm",
    use_rope=False,
    tie_embeddings=True,
)
