"""Architecture registry: ``--arch <id>`` -> ModelConfig.

One module per assigned architecture under repro.configs; ids match the
assignment sheet exactly.
"""
from __future__ import annotations

import importlib
from typing import Dict

from repro.configs.base import ModelConfig

_ARCH_MODULES = {
    "qwen2-moe-a2.7b": "repro.configs.qwen2_moe_a2p7b",
    "phi3.5-moe-42b-a6.6b": "repro.configs.phi35_moe_42b",
    "xlstm-1.3b": "repro.configs.xlstm_1p3b",
    "whisper-medium": "repro.configs.whisper_medium",
    "yi-9b": "repro.configs.yi_9b",
    "yi-6b": "repro.configs.yi_6b",
    "smollm-135m": "repro.configs.smollm_135m",
    "minicpm3-4b": "repro.configs.minicpm3_4b",
    "jamba-1.5-large-398b": "repro.configs.jamba_1p5_large",
    "llama-3.2-vision-90b": "repro.configs.llama32_vision_90b",
}


def list_archs():
    return sorted(_ARCH_MODULES)


def get_config(arch_id: str) -> ModelConfig:
    if arch_id not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {list_archs()}")
    mod = importlib.import_module(_ARCH_MODULES[arch_id])
    cfg: ModelConfig = mod.CONFIG
    assert cfg.arch_id == arch_id, (cfg.arch_id, arch_id)
    return cfg


def all_configs() -> Dict[str, ModelConfig]:
    return {a: get_config(a) for a in list_archs()}
