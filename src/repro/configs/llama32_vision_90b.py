"""llama-3.2-vision-90b [hf:meta-llama/Llama-3.2-11B-Vision; unverified].

100L (80 self + 20 cross-attn) d_model=8192 64H (GQA kv=8) d_ff=28672
vocab=128256; vision tower is a STUB (input_specs provides 1601 patch
embeddings per image); cross-attn every 5th layer with tanh gate.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="llama-3.2-vision-90b",
    family="vlm",
    num_layers=100,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=28672,
    vocab_size=128256,
    cross_attn_period=5,
    num_image_tokens=1601,
    rope_theta=5e5,
)
