"""Deterministic traffic generation for serving benchmarks and chaos tests.

Serving-robustness claims are statements about *traffic* — overload sheds
the right requests, deadlines expire at the right ticks, mixed
shape/regularizer streams pack into the right buckets — so the tests and
benchmarks need workloads that are (a) realistic enough to exercise the
bucketing and admission machinery and (b) exactly reproducible.  This
module builds such workloads: a :class:`TrafficSpec` describes the
distribution (shapes, regularizer mix, arrival rate, SLO mix) and
:func:`make_trace` expands it — via a seeded generator, no global RNG —
into a deterministic list of ``(arrival_tick, OTRequest)`` pairs.
:func:`drive` replays a trace against an engine with a bounded clock, so
even a deliberately-broken engine (chaos runs) cannot hang the caller.

Arrival ticks default to the deterministic skeleton
``floor(i / arrival_rate)``: the *rate* is the experimental knob (set it
above the engine's slot throughput to create overload), while the seed only
controls payload content.  ``arrivals='poisson'`` swaps the skeleton for a
seeded Poisson process (exponential inter-arrival gaps with mean
``1/arrival_rate``, drawn from a generator independent of the payload
stream, so the requests themselves are identical in both modes).  Either
way, two traces with the same spec are identical request-for-request, which
is what lets the benchmark gate latency-proxy counters in CI.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.core.regularizers import Regularizer
from repro.serving.ot_engine import OTRequest, OTServingEngine


@dataclasses.dataclass(frozen=True)
class TrafficSpec:
    """Distribution of a synthetic serving workload.

    Parameters
    ----------
    num_requests : int
        Trace length.
    arrival_rate : float
        Mean requests per engine tick; under ``arrivals='deterministic'``
        the arrival ticks are the schedule ``floor(i / arrival_rate)``.
        Rates above the engine's retirement throughput create sustained
        overload.
    arrivals : {'deterministic', 'poisson'}
        Arrival-process shape.  ``'poisson'`` draws seeded exponential
        inter-arrival gaps (mean ``1/arrival_rate``) from a dedicated
        generator, producing bursts and lulls at the same mean rate; the
        payload stream is untouched, so the two modes emit the same
        requests at different ticks.
    seed : int
        Seed for payload content (costs, shape choice, priority choice)
        and, under ``arrivals='poisson'``, the arrival gaps (via an
        independent sub-generator); the deterministic schedule does not
        depend on it.
    shapes : sequence of (m, n, num_classes)
        Geometry pool; each request draws one uniformly.  Distinct
        geometries land in distinct engine buckets.
    deadline : int, optional
        Tick budget attached to deadline-carrying requests.
    deadline_fraction : float
        Fraction of requests carrying ``deadline`` (0 = none, 1 = all).
    priorities : sequence of int
        Priority-class pool; each request draws one uniformly.
    """

    num_requests: int = 16
    arrival_rate: float = 1.0
    arrivals: str = "deterministic"
    seed: int = 0
    shapes: Sequence[Tuple[int, int, int]] = ((12, 20, 3), (16, 24, 4))
    deadline: Optional[int] = None
    deadline_fraction: float = 0.0
    priorities: Sequence[int] = (0,)

    def __post_init__(self):
        if self.num_requests < 0:
            raise ValueError("num_requests must be >= 0")
        if self.arrival_rate <= 0:
            raise ValueError("arrival_rate must be > 0")
        if self.arrivals not in ("deterministic", "poisson"):
            raise ValueError(
                "arrivals must be 'deterministic' or 'poisson', "
                f"got {self.arrivals!r}"
            )
        if not self.shapes:
            raise ValueError("shapes pool must be non-empty")
        if not 0.0 <= self.deadline_fraction <= 1.0:
            raise ValueError("deadline_fraction must be in [0, 1]")

    def config(self) -> dict:
        """JSON-serializable spec summary (for benchmark records)."""
        return {
            "num_requests": self.num_requests,
            "arrival_rate": self.arrival_rate,
            "arrivals": self.arrivals,
            "seed": self.seed,
            "shapes": [list(s) for s in self.shapes],
            "deadline": self.deadline,
            "deadline_fraction": self.deadline_fraction,
            "priorities": list(self.priorities),
        }


def make_trace(
    spec: TrafficSpec,
    regs: Optional[Sequence[Regularizer]] = None,
    rid_base: int = 0,
) -> List[Tuple[int, OTRequest]]:
    """Expand a :class:`TrafficSpec` into ``(arrival_tick, request)`` pairs.

    Every request is well-formed (finite uniform costs, every class
    represented in the labels, uniform marginals) — faults come from the
    :mod:`repro.utils.faults` registry, not from the traffic.

    Parameters
    ----------
    spec : TrafficSpec
        The workload distribution.
    regs : sequence of Regularizer, optional
        Regularizer pool; each request draws one uniformly (``None``
        leaves ``req.reg`` unset so the engine default applies).  A pool
        with several distinct regularizers exercises per-regularizer
        bucketing.
    rid_base : int
        First request id (ids are ``rid_base .. rid_base + n - 1``).

    Returns
    -------
    list of (int, OTRequest)
        Trace in non-decreasing arrival-tick order, ready for
        :func:`drive`.
    """
    rng = np.random.default_rng(spec.seed)
    # arrival ticks come from their own generator so switching arrival
    # modes (or rates) never perturbs the payload stream drawn from `rng`
    if spec.arrivals == "poisson":
        gaps = np.random.default_rng((spec.seed, 0xA881)).exponential(
            1.0 / spec.arrival_rate, size=spec.num_requests
        )
        ticks = np.floor(np.cumsum(gaps)).astype(int)
    else:
        ticks = (np.arange(spec.num_requests) / spec.arrival_rate).astype(int)
    trace: List[Tuple[int, OTRequest]] = []
    for i in range(spec.num_requests):
        m, n, k = spec.shapes[int(rng.integers(len(spec.shapes)))]
        if m < k:
            raise ValueError(f"shape ({m}, {n}, {k}): need m >= num_classes")
        # every class appears at least once, remainder drawn uniformly
        labels = np.concatenate(
            [np.arange(k), rng.integers(0, k, size=m - k)]
        ).astype(np.int32)
        C = rng.random((m, n)).astype(np.float64)
        deadline = None
        if spec.deadline is not None and rng.random() < spec.deadline_fraction:
            deadline = spec.deadline
        priority = int(spec.priorities[int(rng.integers(len(spec.priorities)))])
        reg = None
        if regs:
            reg = regs[int(rng.integers(len(regs)))]
        trace.append((
            int(ticks[i]),
            OTRequest(rid=rid_base + i, C=C, labels=labels, reg=reg,
                      deadline=deadline, priority=priority),
        ))
    return trace


def drive(
    engine: OTServingEngine,
    trace: Sequence[Tuple[int, OTRequest]],
    max_ticks: int = 10_000,
) -> List[OTRequest]:
    """Replay a trace against an engine until it drains (or ``max_ticks``).

    The loop enqueues each request once the engine clock reaches its
    arrival tick, admits what fits, and ticks — i.e. the same
    admit/tick/retire cadence as :meth:`OTServingEngine.run`, but with
    timed arrivals.  The engine's own machinery handles shedding,
    deadlines and quarantine; ``max_ticks`` is a hard outer bound so a
    chaos-broken engine still returns control to the caller (any request
    left non-terminal then shows up in the caller's ``unterminated``
    count — the benchmark gates that at zero).

    Parameters
    ----------
    engine : OTServingEngine
        The engine under test.
    trace : sequence of (arrival_tick, OTRequest)
        Output of :func:`make_trace` (arrival ticks non-decreasing).
    max_ticks : int
        Hard cap on engine ticks spent in this call.

    Returns
    -------
    list of OTRequest
        Requests that reached a terminal status, in completion order.
    """
    done: List[OTRequest] = []
    i = 0
    start = engine.clock
    while i < len(trace) or len(engine.pending) or engine._in_flight():
        while i < len(trace) and trace[i][0] <= engine.clock - start:
            _, shed = engine.enqueue(trace[i][1])
            done.extend(shed)
            i += 1
        engine.admit_pending()
        done.extend(engine.tick())
        if engine.clock - start >= max_ticks:
            break
    return done
