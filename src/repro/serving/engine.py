"""Batched serving engine: continuous request batching over a decode step.

A minimal production-shaped serving loop:
  * requests arrive with a prompt and a max_new_tokens budget,
  * the engine packs up to ``max_batch`` active requests into fixed slots
    (static shapes: XLA recompiles nothing as requests come and go),
  * prefill fills a slot's KV cache; every engine tick runs ONE fused decode
    step for all active slots; finished slots are recycled.

Per-slot position bookkeeping uses a length vector; the decode step runs at
a common cache index frontier per slot via per-slot masking.  For the
assignment's scale the fused-batch design (one jit'd step, slot recycling)
is the part that matters; scheduling frills (priority, chunked prefill) are
left as documented extension points.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import build_model
from repro.utils.logging import get_logger

log = get_logger("serving")


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray             # (S,) int32
    max_new_tokens: int
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params, max_batch: int = 4,
                 max_len: int = 512):
        self.cfg = cfg
        self.model = build_model(cfg)
        self.params = params
        self.max_batch = max_batch
        self.max_len = max_len
        self.slots: List[Optional[Request]] = [None] * max_batch
        self.lengths = np.zeros((max_batch,), np.int32)
        self.caches = self.model.init_cache(max_batch, max_len)
        self._last_tokens = np.zeros((max_batch, 1), np.int32)

        model = self.model

        def prefill_one(params, caches, tokens, slot):
            """Prefill a single slot (batch-1 forward into slot's cache rows)."""
            logits, new_caches = model.prefill(
                params, tokens, jax.tree_util.tree_map(lambda c: c, caches)
            )
            return logits, new_caches

        def decode(params, tokens, caches, index_vec):
            # per-slot positions: use a common frontier = per-slot length
            # (static-shape trick: index is the max; per-slot mask via cache)
            logits, caches = model.decode_step(
                params, tokens, caches, index_vec
            )
            nxt = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
            return nxt[:, None], caches

        self._decode = jax.jit(decode)

    # -- slot management -----------------------------------------------------
    def try_admit(self, req: Request) -> bool:
        for i, s in enumerate(self.slots):
            if s is None:
                self._prefill_slot(i, req)
                return True
        return False

    def _prefill_slot(self, slot: int, req: Request):
        S = len(req.prompt)
        assert S + req.max_new_tokens <= self.max_len
        tokens = jnp.asarray(req.prompt[None, :], jnp.int32)
        # batch-1 prefill into a fresh cache, then splice into the slot row
        # (the batch axis differs per leaf — recurrent states nest deeper —
        # so locate it from the cache's logical axes)
        one_cache = self.model.init_cache(1, self.max_len)
        logits, one_cache = self.model.prefill(self.params, tokens, one_cache)
        axes = self.model.cache_logical_axes()

        def splice(full, one, ax):
            b = ax.index("batch")
            sl = tuple(
                slice(slot, slot + 1) if i == b else slice(None)
                for i in range(full.ndim)
            )
            return full.at[sl].set(one)

        self.caches = jax.tree_util.tree_map(
            splice, self.caches, one_cache, axes,
            is_leaf=lambda x: not isinstance(x, dict),
        )
        nxt = int(jnp.argmax(logits[0, -1]))
        req.out_tokens.append(nxt)
        self.slots[slot] = req
        self.lengths[slot] = S
        self._last_tokens[slot, 0] = nxt
        log.info("admitted request %d into slot %d (prompt %d tokens)", req.rid, slot, S)

    # -- one engine tick -------------------------------------------------------
    def tick(self) -> List[Request]:
        """One fused decode step for all active slots; returns finished."""
        active = [i for i, s in enumerate(self.slots) if s is not None]
        if not active:
            return []
        # per-slot positions (continuous batching): each slot decodes at its
        # own frontier; inactive slots harmlessly decode at index 0 (their
        # cache rows are overwritten on the next prefill)
        index = jnp.asarray(self.lengths, jnp.int32)
        tokens = jnp.asarray(self._last_tokens)
        nxt, self.caches = self._decode(self.params, tokens, self.caches, index)
        nxt = np.asarray(nxt)
        finished = []
        for i in active:
            req = self.slots[i]
            req.out_tokens.append(int(nxt[i, 0]))
            self.lengths[i] += 1
            self._last_tokens[i, 0] = int(nxt[i, 0])
            if len(req.out_tokens) >= req.max_new_tokens:
                req.done = True
                finished.append(req)
                self.slots[i] = None
                self.lengths[i] = 0
                log.info("request %d finished (%d tokens)", req.rid, len(req.out_tokens))
        return finished

    def run(self, requests: List[Request]) -> List[Request]:
        pending = list(requests)
        done: List[Request] = []
        while pending or any(s is not None for s in self.slots):
            while pending and self.try_admit(pending[0]):
                pending.pop(0)
            done.extend(self.tick())
        return done
