"""Serving policy: SLOs, admission control, and the request state machine.

The screened solver's per-round cost is data-dependent by design (work
scales with surviving tiles, not problem size), so tick latency in
:class:`~repro.serving.ot_engine.OTServingEngine` is inherently
unpredictable — exactly the regime where a traffic-facing engine needs
deadlines, admission control, and graceful degradation.  This module is
the policy layer the engine consults; it owns no device state and no jax
imports, so its decisions are trivially unit-testable.

Three pieces:

  * :class:`RequestStatus` — the request state machine.  Every request
    moves ``QUEUED -> RUNNING -> <terminal>`` and ends in EXACTLY ONE of
    the four terminal states (``DONE`` / ``FAILED`` / ``SHED`` /
    ``DEADLINE_EXCEEDED``); the engine's invariant tests assert no
    request is ever lost or double-terminated.
  * :class:`ServingPolicy` — the knobs: bounded pending queue, default
    deadline/priority, the retry-with-fallback ladder, idle bucket
    eviction, geometry limits, and the stall guard.
  * :class:`PendingQueue` — a bounded, priority-ordered admission queue.
    Pushing beyond capacity sheds the LOWEST-priority entry (ties: the
    youngest), so under overload the engine degrades by dropping the
    least important work instead of growing without bound.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import List, Optional, Tuple


class RequestStatus(str, enum.Enum):
    """Lifecycle states of one serving request.

    ``QUEUED`` and ``RUNNING`` are transient; the other four are
    terminal — a request reaches exactly one of them, exactly once:

    * ``DONE`` — solved; ``value`` / ``plan`` are filled,
    * ``FAILED`` — quarantined after the fallback ladder was exhausted
      (non-finite duals/objective, repeated L-BFGS failure, or a
      poisoned input detected in flight),
    * ``SHED`` — dropped by admission control (queue overflow, geometry
      over engine limits, or the stall guard),
    * ``DEADLINE_EXCEEDED`` — its tick budget ran out before the solve
      finished (mid-flight or still queued).
    """

    QUEUED = "QUEUED"
    RUNNING = "RUNNING"
    DONE = "DONE"
    FAILED = "FAILED"
    SHED = "SHED"
    DEADLINE_EXCEEDED = "DEADLINE_EXCEEDED"

    @property
    def terminal(self) -> bool:
        """True for the four end states of the request state machine."""
        return self in TERMINAL_STATUSES


TERMINAL_STATUSES = frozenset(
    {
        RequestStatus.DONE,
        RequestStatus.FAILED,
        RequestStatus.SHED,
        RequestStatus.DEADLINE_EXCEEDED,
    }
)

# the retry-with-fallback ladder, in escalation order: re-init the slot's
# solver state in place (damped restart: zero duals, fresh snapshots,
# cleared L-BFGS history) -> re-solve solo on the dense-grid backend
# (no screening state to poison) -> the scipy CPU baseline (different
# optimizer, f64).  Each rung costs one attempt against ``max_attempts``.
FALLBACK_LADDER = ("restart", "dense", "cpu")


@dataclasses.dataclass(frozen=True)
class ServingPolicy:
    """Engine-wide SLO and robustness knobs (frozen; engine-lifetime).

    Parameters
    ----------
    max_pending : int
        Capacity of the pending (admission) queue.  Pushing beyond it
        sheds the lowest-priority entry — bounded memory under overload.
    default_deadline : int, optional
        Deadline (in engine ticks from submission) stamped on requests
        that carry none.  ``None`` = no deadline.
    default_priority : int
        Priority class for requests that carry none.  Higher keeps a
        request longer under overload; ties shed youngest-first.
    max_attempts : int
        Total solve attempts per request (1 initial + retries/fallbacks).
        The ladder never runs past this, whatever its length.
    fallback_ladder : tuple of str
        Escalation order over {'restart', 'dense', 'cpu'}; see
        :data:`FALLBACK_LADDER`.
    idle_evict_after : int
        Ticks a bucket may sit with zero occupied slots before the
        engine evicts it (bounds the bucket dict; compiled programs stay
        in the process-wide jax cache, so re-creation is cheap).
    max_groups / max_cols : int, optional
        Geometry ceilings: a problem with more (padded) groups/columns
        can NEVER be admitted, so it is shed at submission instead of
        pending forever.  ``None`` = unlimited.
    stall_passes : int
        Consecutive ``run()`` passes with zero admissions, zero
        retirements and zero occupied slots before the stall guard sheds
        the remaining pending requests (the loop can provably make no
        further progress).
    """

    max_pending: int = 64
    default_deadline: Optional[int] = None
    default_priority: int = 0
    max_attempts: int = 4
    fallback_ladder: Tuple[str, ...] = FALLBACK_LADDER
    idle_evict_after: int = 8
    max_groups: Optional[int] = None
    max_cols: Optional[int] = None
    stall_passes: int = 3

    def __post_init__(self):
        if self.max_pending < 1:
            raise ValueError(f"max_pending must be >= 1, got {self.max_pending}")
        if self.max_attempts < 1:
            raise ValueError(f"max_attempts must be >= 1, got {self.max_attempts}")
        if self.idle_evict_after < 1:
            raise ValueError(
                f"idle_evict_after must be >= 1, got {self.idle_evict_after}"
            )
        if self.stall_passes < 1:
            raise ValueError(f"stall_passes must be >= 1, got {self.stall_passes}")
        if self.default_deadline is not None and self.default_deadline < 1:
            raise ValueError(
                f"default_deadline must be >= 1 ticks, got {self.default_deadline}"
            )
        unknown = set(self.fallback_ladder) - set(FALLBACK_LADDER)
        if unknown:
            raise ValueError(
                f"unknown fallback ladder rungs {sorted(unknown)}; "
                f"valid rungs: {FALLBACK_LADDER}"
            )

    def within_limits(self, num_groups: int, num_cols: int) -> bool:
        """Whether a padded geometry can ever fit this engine's limits."""
        if self.max_groups is not None and num_groups > self.max_groups:
            return False
        if self.max_cols is not None and num_cols > self.max_cols:
            return False
        return True

    def config(self) -> dict:
        """JSON-able description (benchmark manifests, request wires)."""
        return dataclasses.asdict(self)


class PendingQueue:
    """Bounded priority queue of requests awaiting a slot.

    Ordering: higher priority first; within a priority class, earlier
    submission first (FIFO).  ``push`` beyond ``capacity`` evicts the
    lowest-priority entry, youngest-first — possibly the pushed request
    itself — and returns the evicted requests so the engine can mark
    them ``SHED``.

    The queue stores the engine's ``OTRequest`` objects but only reads
    their ``priority`` / ``submitted_tick`` fields, so it stays
    unit-testable with any object carrying those two attributes.
    """

    def __init__(self, capacity: int):
        if capacity < 1:
            raise ValueError(f"queue capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self._items: List = []

    def __len__(self) -> int:
        return len(self._items)

    def __iter__(self):
        """Iterate in admission-priority order (no removal)."""
        return iter(self._items)

    def _sort(self) -> None:
        # stable sort: (priority desc, submitted_tick asc); arrival order
        # breaks remaining ties because sorted() is stable
        self._items.sort(key=lambda r: (-r.priority, r.submitted_tick))

    def push(self, req) -> List:
        """Add a request; return the list of requests shed by overflow."""
        self._items.append(req)
        self._sort()
        shed = []
        while len(self._items) > self.capacity:
            shed.append(self._items.pop())       # lowest priority, youngest
        return shed

    def remove(self, req) -> None:
        """Drop a request (admitted, expired, or externally cancelled)."""
        self._items.remove(req)

    def drain(self) -> List:
        """Remove and return everything (stall guard / shutdown)."""
        items, self._items = self._items, []
        return items
