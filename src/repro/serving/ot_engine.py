"""OT request serving engine: continuous batching over solver rounds.

The batched solver (``core.solver.solve_batch``) wants B same-shape
problems; real traffic (many concurrent domain-adaptation solves) arrives
with mixed shapes and at arbitrary times.  This engine is the bridge, in
the mold of :class:`repro.serving.engine.ServingEngine` (fixed slots,
static shapes, slot recycling):

  * requests carry a raw (m, n) cost matrix + class labels (plus optional
    marginals); the engine pads each to a canonical *bucket* geometry
    (L groups x padded group size, n rounded up to ``n_quant``) so every
    problem in a bucket shares one compiled program,
  * each bucket owns a fixed grid of ``num_devices x slots_per_device``
    slots; admission writes the request's padded arrays into a free slot
    (preferring the least-loaded device) and (re)initializes that slot's
    solver state, preserving in-flight neighbours bit-for-bit,
  * every engine tick runs ONE fused ``batch_round`` per active bucket —
    a full Algorithm-1 round (L-BFGS segment + screening refresh) for all
    slots in one program launch.  With a device mesh attached, that one
    launch is a ``shard_map`` program whose problem axis is split over the
    mesh (``core.sharded``): each device advances its own slots with its
    own screening state and its own compact tile schedule, and the only
    cross-device movement is the engine's read of the ``(S,)`` converged/
    failed flags at the round boundary,
  * finished slots (converged / round cap) are retired: the request gets
    its objective value and its primal plan un-padded back to the
    caller's row order, and the slot is recycled.

On top of the batching machinery sits the ROBUSTNESS layer (this is what
turns "an engine" into "a service"; knobs in
:class:`repro.serving.policy.ServingPolicy`):

  * **lifecycle**: every request moves ``QUEUED -> RUNNING ->`` exactly
    one terminal :class:`~repro.serving.policy.RequestStatus` (``DONE`` /
    ``FAILED`` / ``SHED`` / ``DEADLINE_EXCEEDED``) — nothing is ever
    silently dropped or left hanging,
  * **SLOs**: requests carry an optional deadline (in engine ticks) and a
    priority class (``repro.ot.SubmitOptions``, or ``submit()`` /
    ``enqueue()`` keywords); deadlines are enforced both while queued and
    mid-flight,
  * **admission control**: ``enqueue()`` feeds a bounded priority queue;
    overflow sheds the lowest-priority entries, and geometry beyond the
    policy's limits is shed at submission (it could never be admitted),
  * **failure quarantine**: inputs are validated at admission
    (``Problem`` construction rejects non-finite costs/marginals);
    non-finite duals/objectives and L-BFGS failures are detected per slot
    at the round boundary and walked down a bounded retry ladder
    (in-slot damped restart -> dense-grid backend -> CPU baseline) with
    per-request attempt accounting; neighbours of a quarantined slot are
    preserved bit-for-bit (the same ``where_state`` masked merge that
    protects them during admission),
  * **stall guard + idle eviction**: ``run()`` sheds work it can prove
    will never be admitted instead of looping forever, and buckets that
    sit empty are evicted so the bucket dict cannot grow without bound.

Chaos testing hooks into :mod:`repro.utils.faults` — with an empty
registry (production) every hook is one boolean check.

Empty slots hold a dummy problem (PAD_COST costs, zero marginals) whose
gradient is identically zero, so they converge at initialization and ride
along for free.  Column padding appends zero-mass targets with PAD_COST
costs: their plan column is exactly zero and their dual variable has zero
gradient, so a padded solve equals the unpadded one on real entries (same
argument as row padding, see core/groups.py).

Slot -> (device, lane) mapping: the problem axis is sharded in contiguous
blocks, so slot ``i`` lives on device ``i // slots_per_device``, lane
``i % slots_per_device``.  Admission balances live requests across devices
because per-tick wall-clock is the *max* over devices of their local work
(the compact kernel's grid scales with each shard's surviving tiles).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import groups as G
from repro.core import solver as slv
from repro.core.dual import DualProblem, plan_from_duals
from repro.core.lbfgs import where_state
from repro.core.regularizers import Regularizer
from repro.ot.problem import Problem
from repro.serving.policy import (
    PendingQueue,
    RequestStatus,
    ServingPolicy,
    TERMINAL_STATUSES,
)
from repro.utils import faults
from repro.utils.logging import get_logger

log = get_logger("ot_serving")


@dataclasses.dataclass
class OTRequest:
    """One OT solve request (inputs in the caller's row order).

    The payload is a declarative :class:`repro.ot.Problem` — pass one via
    ``problem`` (or :meth:`from_problem`), or pass the raw ``C`` +
    ``labels`` fields and the engine lifts them into a cost-mode Problem
    at admission (the pre-façade wire format, kept for compatibility).

    Parameters
    ----------
    rid : int
        Caller-chosen request id (echoed back on retirement).
    C : np.ndarray, optional
        ``(m, n)`` float cost matrix in the caller's row/column order
        (raw form; ignored when ``problem`` is given).
    labels : np.ndarray, optional
        ``(m,)`` integer class labels of the source rows (raw form).
    a : np.ndarray, optional
        ``(m,)`` source marginal; defaults to uniform ``1/m`` (raw form).
    b : np.ndarray, optional
        ``(n,)`` target marginal; defaults to uniform ``1/n`` (raw form).
    reg : Regularizer, optional
        Per-request regularizer; defaults to the engine's.  Requests with
        different regularizers never share a bucket (the compiled program
        and the screening thresholds specialize on the regularizer), so
        mixed-regularizer traffic packs into per-regularizer batches.
    problem : repro.ot.Problem, optional
        The declarative payload; carries its own regularizer, marginals
        and group layout (``reg`` / ``C`` / ``labels`` are then unused).
    deadline : int, optional
        SLO: the request must reach a terminal status within this many
        engine ticks of submission, or it is retired
        ``DEADLINE_EXCEEDED`` (queued or mid-flight).  ``None`` defers to
        the Problem's :class:`~repro.ot.problem.SubmitOptions`, then the
        policy default.
    priority : int
        Priority class: higher admits first and sheds last under
        overload.

    Attributes
    ----------
    status : RequestStatus
        Lifecycle state; ends in exactly one terminal status.
    value : float or None
        Dual objective at convergence (filled at retirement).
    plan : np.ndarray or None
        ``(m, n)`` primal transport plan, caller's row order (filled at
        retirement).
    rounds : int
        Algorithm-1 rounds the solve ran.
    converged : bool
        Whether the solver converged (vs. retired at the round cap).
    done : bool
        Set when the request has reached a terminal status.
    attempts : int
        Solve attempts consumed (1 initial + retry-ladder rungs).
    route : str or None
        Which path produced the result: ``'slot'`` (the batched engine),
        ``'restart'``, ``'dense'`` or ``'cpu'`` (fallback rungs).
    error : str or None
        Failure / degradation detail (``None`` on a clean ``DONE``).
    submitted_tick / retired_tick : int or None
        Engine clock stamps bracketing the request's lifetime.
    """

    rid: int
    C: Optional[np.ndarray] = None     # (m, n) cost matrix (raw form)
    labels: Optional[np.ndarray] = None  # (m,) integer class labels (raw form)
    a: Optional[np.ndarray] = None     # (m,) source marginal (default 1/m)
    b: Optional[np.ndarray] = None     # (n,) target marginal (default 1/n)
    reg: Optional[Regularizer] = None  # per-request regularizer (default:
    #   the engine's; distinct regularizers go to distinct buckets)
    problem: Optional[Problem] = None  # declarative payload (preferred)
    # SLOs:
    deadline: Optional[int] = None     # tick budget (None = policy default)
    priority: int = 0                  # higher = kept longer under overload
    # filled at retirement:
    value: Optional[float] = None      # dual objective at convergence
    plan: Optional[np.ndarray] = None  # (m, n) primal plan, original order
    rounds: int = 0
    converged: bool = False
    done: bool = False
    # lifecycle bookkeeping:
    status: RequestStatus = RequestStatus.QUEUED
    attempts: int = 0                  # solve attempts consumed
    route: Optional[str] = None        # 'slot' | 'restart' | 'dense' | 'cpu'
    error: Optional[str] = None        # failure / degradation detail
    submitted_tick: Optional[int] = None
    retired_tick: Optional[int] = None
    _rung: int = 0                     # next fallback-ladder index

    @staticmethod
    def from_problem(rid: int, problem: Problem) -> "OTRequest":
        """Wrap a declarative :class:`repro.ot.Problem` as a request.

        The Problem's :class:`~repro.ot.problem.SubmitOptions` (if any)
        become the request's deadline and priority.
        """
        sub = problem.submit
        return OTRequest(
            rid=rid, problem=problem,
            deadline=sub.deadline if sub is not None else None,
            priority=sub.priority if sub is not None else 0,
        )

    @property
    def ticks_in_flight(self) -> Optional[int]:
        """Ticks from submission to retirement (the latency proxy)."""
        if self.submitted_tick is None or self.retired_tick is None:
            return None
        return self.retired_tick - self.submitted_tick


@jax.jit
def _select_slots(mask, new, old):
    """Per-slot state merge (jitted so admission is one launch)."""
    return where_state(mask, new, old)


class _Bucket:
    """Fixed-slot batch of one (padded geometry, regularizer) combination.

    The bucket key is ``(L, g_pad, n_pad, reg)``: problems only share a
    bucket — and therefore a compiled program, a screening-threshold
    vector, and a batch — when both their padded geometry AND their
    regularizer coincide.  ``num_slots`` = ``num_devices *
    slots_per_device``; with a mesh attached, slot arrays and solver state
    are committed shard-wise so an engine tick dispatches one sharded
    ``batch_round`` with no implicit resharding.
    """

    def __init__(self, key: Tuple, slots_per_device: int,
                 reg: Regularizer, opts: slv.SolveOptions, dtype,
                 mesh=None, counters: Optional[dict] = None):
        L, g_pad, n_pad = key[:3]
        self.key = key
        self.mesh = mesh
        self.num_devices = mesh.size if mesh is not None else 1
        self.slots_per_device = slots_per_device
        self.num_slots = slots_per_device * self.num_devices
        self.reg = reg
        self.opts = opts
        self.prob = DualProblem(L, g_pad, n_pad, reg)
        m_pad = self.prob.m_pad
        S = self.num_slots
        self.slots: List[Optional[OTRequest]] = [None] * S
        self._meta: List[Optional[dict]] = [None] * S   # perm/spec per slot
        self.C = np.full((S, m_pad, n_pad), G.PAD_COST, dtype)
        self.a = np.zeros((S, m_pad), dtype)
        self.b = np.zeros((S, n_pad), dtype)
        self.row_mask = np.zeros((S, m_pad), bool)
        self.sqrt_g = np.zeros((S, L), dtype)
        self.state: Optional[slv.BatchSolveState] = None
        self.idle_ticks = 0             # ticks with zero occupied slots
        # engine-owned counters (launch accounting survives eviction)
        self._counters = counters if counters is not None else {"launches": 0}
        # device-resident copies of the slot arrays + (pallas) the padded
        # problem, rebuilt only when a slot's contents change — a tick must
        # not re-upload (S, m_pad, n_pad) buffers or re-pad C every round
        self._device: Optional[tuple] = None
        self._padded = None

    def _launch(self, fn, *args):
        """One jitted program launch, counted engine-wide."""
        self._counters["launches"] = self._counters.get("launches", 0) + 1
        return slv._launch(fn, *args)

    def slot_placement(self, slot: int) -> Tuple[int, int]:
        """Map a slot index to its ``(device, lane)`` coordinates.

        The problem axis shards in contiguous blocks over the 1-D mesh, so
        this is a pure index computation — no device queries.
        """
        return slot // self.slots_per_device, slot % self.slots_per_device

    def _device_arrays(self) -> tuple:
        if self._device is None:
            arrs = (
                jnp.asarray(self.C), jnp.asarray(self.a), jnp.asarray(self.b),
                jnp.asarray(self.row_mask), jnp.asarray(self.sqrt_g),
            )
            if self.mesh is not None:
                from repro.core import sharded as shd

                arrs = shd.device_put_batch(arrs, self.mesh)
            self._device = arrs
            self._padded = None
            if self.opts.grad_impl in ("pallas", "fused"):
                if self.mesh is not None:
                    from repro.core import sharded as shd

                    self._padded = shd.prepare_padded_sharded(
                        self._device[0], self.prob, self.mesh,
                        precision=self.opts.precision,
                    )
                else:
                    self._padded = slv._prepare_padded(
                        self._device[0], self.prob, self.opts
                    )
        return self._device

    # -- admission -----------------------------------------------------------
    def free_slot(self) -> Optional[int]:
        """Pick a free slot on the least-loaded device (None if full).

        Per-tick latency is the max over devices of their local work, so
        spreading live requests keeps the sharded round balanced.  With
        one device this degenerates to first-free-slot (the original
        policy), preserving single-device behavior exactly.
        """
        free = [i for i, s in enumerate(self.slots) if s is None]
        if not free:
            return None
        load = [0] * self.num_devices
        for i, s in enumerate(self.slots):
            if s is not None:
                load[i // self.slots_per_device] += 1
        return min(free, key=lambda i: (load[i // self.slots_per_device], i))

    def admit(self, slot: int, req: OTRequest, problem: Problem):
        """Write the request's padded Problem arrays into ``slot`` (no state init)."""
        m, n = problem.num_source, problem.num_target
        dtype = self.C.dtype
        C_pad, a_pad, b, spec, perm = problem.padded(dtype=dtype)

        self.C[slot] = G.PAD_COST
        self.C[slot, :, :n] = C_pad
        self.a[slot] = a_pad
        self.b[slot] = 0.0
        self.b[slot, :n] = np.asarray(b, dtype)
        self.row_mask[slot] = spec.row_mask().reshape(-1)
        self.sqrt_g[slot] = spec.sqrt_sizes()
        self.slots[slot] = req
        self._meta[slot] = {"spec": spec, "perm": perm, "m": m, "n": n}
        self._device = None          # slot arrays changed: re-upload lazily
        dev, lane = self.slot_placement(slot)
        log.info(
            "admitted OT request %d into bucket %s slot %d "
            "(device %d lane %d, m=%d n=%d)",
            req.rid, self.key, slot, dev, lane, m, n,
        )

    def _init_state(self):
        """One jitted state init over all slots (sharded when mesh set)."""
        C, a, b, row_mask, sqrt_g = self._device_arrays()
        if self.mesh is not None:
            from repro.core import sharded as shd

            return self._launch(
                shd.init_batch_state_sharded,
                C, a, b, row_mask, sqrt_g, self.prob, self.opts,
                self.mesh, self._padded,
            )
        return self._launch(
            slv.init_batch_state,
            C, a, b, row_mask, sqrt_g, self.prob, self.opts, self._padded,
        )

    def refresh_state(self, new_mask: np.ndarray):
        """(Re)initialize solver state for slots in ``new_mask``; keep others."""
        fresh = self._init_state()
        if self.state is None:
            self.state = fresh
        else:
            self.state = _select_slots(jnp.asarray(new_mask), fresh, self.state)

    # -- one engine tick -----------------------------------------------------
    def occupied(self) -> List[int]:
        """Indices of slots currently holding a live request."""
        return [i for i, s in enumerate(self.slots) if s is not None]

    def tick(self, clock: int = 0) -> Tuple[List[OTRequest], List[Tuple[int, str]]]:
        """One fused solver round for all slots.

        Returns
        -------
        (done, bad) : tuple
            ``done`` — requests retired healthy this round (converged, or
            at the round cap), results filled in; ``bad`` — ``(slot,
            reason)`` pairs the engine must quarantine (L-BFGS failure,
            non-finite duals/objective, or an injected fault).
        """
        active = self.occupied()
        if not active or self.state is None:
            return [], []
        reg = faults.REGISTRY
        chaos = reg.enabled()
        if chaos and reg.fire("slow_bucket", bucket=self.key, tick=clock):
            # simulated slow/hung bucket: the tick passes, requests age
            # (deadlines keep counting) but no round runs
            log.warning("bucket %s: injected slow tick %d", self.key, clock)
            return [], []
        C, a, b, row_mask, sqrt_g = self._device_arrays()
        if self.mesh is not None:
            from repro.core import sharded as shd

            self.state = self._launch(
                shd.batch_round_sharded,
                self.state, C, a, b, row_mask, sqrt_g,
                self.prob, self.opts, self.mesh, self._padded,
            )
        else:
            self.state = self._launch(
                slv.batch_round,
                self.state, C, a, b, row_mask, sqrt_g,
                self.prob, self.opts, self._padded,
            )
        lb = self.state.lb
        # round-boundary gather: the only cross-device movement in a tick
        # (a few bytes per device of converged/failed/finite flags + round
        # counts).  The finite check is the quarantine tripwire: NaN/inf
        # duals or objectives must retire the offending slot, never ride
        # into another round.
        conv = np.asarray(lb.converged)
        failed = np.asarray(lb.failed)
        rounds = np.asarray(self.state.rounds)
        finite = np.asarray(
            jnp.logical_and(
                jnp.all(jnp.isfinite(lb.x), axis=-1), jnp.isfinite(lb.f)
            )
        )
        done: List[OTRequest] = []
        bad: List[Tuple[int, str]] = []
        for i in active:
            rid = self.slots[i].rid
            if chaos and reg.fire("lbfgs_fail", rid=rid, bucket=self.key,
                                  tick=clock):
                bad.append((i, "injected L-BFGS failure"))
            elif not finite[i]:
                bad.append((i, "non-finite duals/objective at round boundary"))
            elif failed[i]:
                bad.append((i, "L-BFGS line-search failure"))
            elif conv[i] or rounds[i] >= self.opts.max_rounds:
                done.append(self._retire(i, bool(conv[i]), int(rounds[i])))
        return done, bad

    def release(self, slot: int) -> Tuple[OTRequest, dict]:
        """Vacate ``slot`` (no result recovery): recycle to the dummy problem.

        The slot's arrays go back to the zero-gradient dummy, so the
        in-flight neighbours are untouched (their state freezes through
        the same masked merges as always).  Returns the evicted request
        and its padding metadata.
        """
        req, meta = self.slots[slot], self._meta[slot]
        self.slots[slot] = None
        self._meta[slot] = None
        self.C[slot] = G.PAD_COST
        self.a[slot] = 0.0
        self.b[slot] = 0.0
        self.row_mask[slot] = False
        self.sqrt_g[slot] = 0.0
        self._device = None          # slot arrays changed: re-upload lazily
        return req, meta

    def _retire(self, slot: int, converged: bool, rounds: int) -> OTRequest:
        req = self.slots[slot]
        meta = self._meta[slot]
        lb = self.state.lb
        m_pad = self.prob.m_pad
        # materialize the retiring slot's duals on host: keeps the plan
        # recovery a plain single-device computation even when lb.x is
        # committed shard-wise across the mesh
        x = np.asarray(lb.x[slot])
        alpha = jnp.asarray(x[:m_pad])
        beta = jnp.asarray(x[m_pad:])
        T_pad = np.asarray(
            plan_from_duals(alpha, beta, jnp.asarray(self.C[slot]), self.prob)
        )
        # un-pad rows back to the caller's order, drop padded columns
        m, n = meta["m"], meta["n"]
        perm = meta["perm"]
        T = np.zeros((m, n), T_pad.dtype)
        real = perm >= 0
        T[perm[real]] = T_pad[real][:, :n]
        req.value = float(-lb.f[slot])
        req.plan = T
        req.rounds = rounds
        req.converged = converged
        # recycle: dummy problem (zero gradient) until the next admission
        self.release(slot)
        log.info("OT request %d finished (rounds=%d converged=%s)",
                 req.rid, rounds, converged)
        return req


class OTServingEngine:
    """Serve a stream of OT solve requests with bucketed continuous batching.

    Requests are declarative :class:`repro.ot.Problem` objects — admitted
    directly (:meth:`submit`, or ``run`` on a list of Problems) or wrapped
    in an :class:`OTRequest` envelope (which also lifts the pre-façade raw
    ``C`` + ``labels`` wire format).  Problems whose padded geometry
    ``(L, g_pad, ceil(n / n_quant) * n_quant)`` AND regularizer coincide
    share a bucket — and therefore a compiled program and a batch
    (mixed-regularizer traffic packs into per-regularizer buckets; see
    :meth:`_bucket_key`).  Each tick
    advances every active bucket by one fused
    Algorithm-1 round in a single program launch per bucket; attached to a
    device mesh, that launch is a ``shard_map`` program with the slot axis
    split across devices (see :mod:`repro.core.sharded`).

    The robustness layer (module docstring) guarantees every request ends
    in exactly one terminal :class:`~repro.serving.policy.RequestStatus`;
    health is observable through :meth:`stats` / :meth:`describe`.

    Parameters
    ----------
    reg : Regularizer
        Default regularizer for requests that don't carry their own
        (compiled programs specialize on it per bucket).
    opts : SolveOptions, optional
        Solver options, including the ``grad_impl`` backend
        ('dense' | 'screened' | 'pallas' | 'fused').
    max_batch : int, optional
        Slots **per device** in each bucket; a bucket's total slot count
        is ``max_batch * mesh.size`` (or just ``max_batch`` without a
        mesh).
    n_quant : int, optional
        Column-padding granularity for bucket keys.
    pad_to : int, optional
        Group-size padding granularity (rows per group rounded up).
    dtype : numpy dtype, optional
        Storage dtype of the slot arrays (float32 everywhere in practice).
    mesh : jax.sharding.Mesh, optional
        A 1-D batch mesh (see
        :func:`repro.core.distributed.make_batch_mesh`).  When given,
        every bucket packs ``mesh.size * max_batch`` slots and ticks run
        sharded; when omitted the engine is single-device and its
        behavior (and results) are bit-for-bit those of the pre-mesh
        engine.
    policy : ServingPolicy, optional
        SLO / admission-control / quarantine knobs (see
        :mod:`repro.serving.policy`).

    Examples
    --------
    >>> engine = OTServingEngine(GroupSparseReg.from_rho(1.0, 0.6))
    >>> done = engine.run([OTRequest(rid=0, C=C, labels=y)])
    >>> done[0].status, done[0].value, done[0].plan.shape
    """

    def __init__(
        self,
        reg: Regularizer,
        opts: slv.SolveOptions = slv.SolveOptions(),
        max_batch: int = 4,
        n_quant: int = 64,
        pad_to: int = 8,
        dtype=np.float32,
        mesh=None,
        policy: ServingPolicy = ServingPolicy(),
    ):
        self.reg = reg
        self.opts = opts
        self.max_batch = max_batch
        self.n_quant = n_quant
        self.pad_to = pad_to
        self.dtype = dtype
        self.mesh = mesh
        self.num_devices = mesh.size if mesh is not None else 1
        self.policy = policy
        self.buckets: Dict[Tuple, _Bucket] = {}
        self.pending = PendingQueue(policy.max_pending)
        self.clock = 0
        self._next_rid = 0
        self._stats = {
            "ticks": 0, "submitted": 0, "admitted": 0, "evictions": 0,
            "retry_attempts": 0, "launches": 0,
            "status": {s.value: 0 for s in TERMINAL_STATUSES},
        }

    def _as_problem(self, req: OTRequest) -> Problem:
        """The request's declarative payload (lifting raw C + labels).

        Construction validates shapes, marginals (non-negative AND
        finite), costs (finite) and the regularizer's per-group
        parameters against the request's own group count BEFORE any
        slot/bucket mutation — a malformed request is rejected here,
        not from inside state init where it would poison a bucket.
        """
        if req.problem is not None:
            return req.problem
        if req.C is None or req.labels is None:
            raise ValueError(
                f"request {req.rid} carries neither a Problem nor raw C + labels"
            )
        reg = req.reg if req.reg is not None else self.reg
        # cache the lifted Problem on the request — run() retries admission
        # on every tick while buckets are full, and re-validating (array
        # conversions + label sort) per retry would tax the serving loop —
        # but key the cache on the resolved (reg, pad_to): the raw fields
        # stay authoritative, so reusing the request with another engine
        # (different defaults) or after changing req.reg re-lifts it
        cached = getattr(req, "_lifted", None)
        if cached is not None and cached[0] == reg and cached[1] == self.pad_to:
            return cached[2]
        problem = Problem(
            reg=reg, C=req.C, labels=req.labels, a=req.a, b=req.b,
            pad_to=self.pad_to,
        )
        req._lifted = (reg, self.pad_to, problem)
        return problem

    def _bucket_key(self, problem: Problem) -> Tuple:
        """Bucket key ``(L, g_pad, n_pad, reg)`` from the Problem geometry.

        The regularizer is part of the key (regularizers are hashable
        frozen dataclasses): two problems with identical padded geometry
        but different regularizer kinds — or the same kind with different
        parameters — must not share a batch, because the compiled solver
        program and the per-group screening thresholds specialize on the
        regularizer.
        """
        L, g_pad, n = problem.geometry()
        n_pad = -(-n // self.n_quant) * self.n_quant
        return (L, g_pad, n_pad, problem.reg)

    # -- lifecycle bookkeeping -------------------------------------------------
    def _finish(self, req: OTRequest, status: RequestStatus,
                error: Optional[str] = None) -> OTRequest:
        """Move a request into its (single) terminal status."""
        if req.status in TERMINAL_STATUSES:      # the invariant tripwire
            log.error("request %d already terminal (%s); ignoring %s",
                      req.rid, req.status.value, status.value)
            return req
        req.status = status
        req.done = True
        req.retired_tick = self.clock
        if error is not None:
            req.error = error
        self._stats["status"][status.value] += 1
        if status is not RequestStatus.DONE:
            log.warning("OT request %d -> %s (%s)",
                        req.rid, status.value, req.error)
        return req

    def _resolve_slos(self, req: OTRequest,
                      deadline: Optional[int], priority: Optional[int]) -> None:
        """Fill the request's SLO fields: kwargs > request > policy default."""
        if deadline is not None:
            req.deadline = deadline
        elif req.deadline is None:
            req.deadline = self.policy.default_deadline
        if priority is not None:
            req.priority = priority
        elif req.priority == 0:
            req.priority = self.policy.default_priority

    def _wrap(self, r) -> OTRequest:
        """Coerce a bare Problem into an engine-numbered OTRequest."""
        if isinstance(r, Problem):
            rid, self._next_rid = self._next_rid, self._next_rid + 1
            return OTRequest.from_problem(rid, r)
        return r

    # -- admission -------------------------------------------------------------
    def submit(self, problem: Problem, rid: Optional[int] = None,
               deadline: Optional[int] = None,
               priority: Optional[int] = None) -> Optional[OTRequest]:
        """Admit a declarative :class:`repro.ot.Problem` directly.

        Parameters
        ----------
        problem : repro.ot.Problem
            The problem to serve (carries its own regularizer/layout and
            optionally its SLOs via ``Problem.submit``).
        rid : int, optional
            Request id; defaults to an engine-assigned sequence number.
        deadline : int, optional
            Tick budget override (else ``problem.submit``, else the
            policy default).
        priority : int, optional
            Priority-class override (same precedence).

        Returns
        -------
        OTRequest or None
            The in-flight request handle, or None if the problem's bucket
            is full (caller retries after a tick, or uses
            :meth:`enqueue` to let the engine queue it).

        Raises
        ------
        ValueError
            If the problem's padded geometry exceeds the policy's
            ``max_groups`` / ``max_cols`` limits (it could never be
            admitted, so "retry later" would be a lie).
        """
        if rid is None:
            rid = self._next_rid
            self._next_rid += 1
        req = OTRequest.from_problem(rid, problem)
        self._resolve_slos(req, deadline, priority)
        L, _, n_pad, _ = self._bucket_key(problem)
        if not self.policy.within_limits(L, n_pad):
            raise ValueError(
                f"problem geometry (L={L}, n_pad={n_pad}) exceeds engine "
                f"limits (max_groups={self.policy.max_groups}, "
                f"max_cols={self.policy.max_cols})"
            )
        return req if self.try_admit(req) else None

    def enqueue(self, request, deadline: Optional[int] = None,
                priority: Optional[int] = None) -> Tuple[OTRequest, List[OTRequest]]:
        """Admission control: queue a request (or shed it, terminally).

        Unlike :meth:`submit` — which only succeeds when a slot is free
        right now — ``enqueue`` always disposes of the request: it either
        joins the bounded pending queue (status ``QUEUED``; admitted by
        :meth:`run` / :meth:`admit_pending` as slots free up), or it is
        immediately shed/terminated:

        * invalid payload (non-finite cost/marginals, bad shapes, bad
          regularizer) -> ``FAILED`` at admission, engine untouched,
        * geometry beyond the policy limits -> ``SHED`` (it can never be
          admitted; queueing it would stall the engine),
        * queue overflow -> the lowest-priority entry (possibly this
          one) is shed.

        Parameters
        ----------
        request : OTRequest or repro.ot.Problem
            The work item; bare Problems are wrapped with engine-assigned
            request ids.
        deadline, priority : int, optional
            SLO overrides (else the request's / Problem's own, else the
            policy defaults).

        Returns
        -------
        (request, shed) : tuple
            The (wrapped) request handle, and the list of requests that
            reached a terminal status during this call (queue overflow
            victims, or the request itself if rejected/shed).
        """
        req = self._wrap(request)
        if req.done:
            raise ValueError(
                f"request {req.rid} is already terminal ({req.status.value}); "
                "reset value/done to resubmit it"
            )
        # a request may be reused after a manual reset (done=False): restart
        # its lifecycle from scratch so stale terminal state cannot leak in
        req.status = RequestStatus.QUEUED
        req.attempts = 0
        req.route = None
        req.error = None
        req.retired_tick = None
        req._rung = 0
        self._resolve_slos(req, deadline, priority)
        req.submitted_tick = self.clock
        req.status = RequestStatus.QUEUED
        self._stats["submitted"] += 1
        try:
            problem = self._as_problem(req)
        except ValueError as e:
            self._finish(req, RequestStatus.FAILED,
                         error=f"rejected at admission: {e}")
            return req, [req]
        L, _, n_pad, _ = self._bucket_key(problem)
        if not self.policy.within_limits(L, n_pad):
            self._finish(
                req, RequestStatus.SHED,
                error=f"geometry (L={L}, n_pad={n_pad}) exceeds engine limits "
                      f"(max_groups={self.policy.max_groups}, "
                      f"max_cols={self.policy.max_cols})",
            )
            return req, [req]
        shed = self.pending.push(req)
        for victim in shed:
            self._finish(victim, RequestStatus.SHED,
                         error="shed by admission control: pending queue "
                               f"overflow (capacity {self.pending.capacity})")
        return req, shed

    def try_admit(self, req: OTRequest) -> bool:
        """Admit into the request's bucket if a slot is free (no round run).

        Parameters
        ----------
        req : OTRequest
            The request to place (Problem payload or raw C + labels).

        Returns
        -------
        bool
            True if a slot was free (the request is now in flight), False
            if the bucket is full — or an ``admit_fail`` fault fired —
            (caller retries after a tick).
        """
        problem = self._as_problem(req)
        reg = faults.REGISTRY
        if reg.enabled() and reg.fire("admit_fail", rid=req.rid,
                                      tick=self.clock):
            log.warning("request %d: injected admission failure", req.rid)
            return False
        key = self._bucket_key(problem)
        if not self.policy.within_limits(key[0], key[2]):
            return False
        bucket = self.buckets.get(key)
        if bucket is None:
            bucket = _Bucket(key, self.max_batch, key[3], self.opts,
                             self.dtype, mesh=self.mesh, counters=self._stats)
            self.buckets[key] = bucket
        slot = bucket.free_slot()
        if slot is None:
            return False
        bucket.admit(slot, req, problem)
        if reg.enabled() and reg.fire("nan_cost", rid=req.rid,
                                      bucket=bucket.key, tick=self.clock):
            # corrupt AFTER admission validation: simulates in-flight data
            # poisoning, the case the round-boundary tripwire must catch
            bucket.C[slot, 0, :] = np.nan
            bucket._device = None
            log.warning("request %d: injected NaN cost in slot %d",
                        req.rid, slot)
        if req.submitted_tick is None:
            # direct admission (submit / try_admit, no enqueue): stamp and
            # count the submission here so admitted never exceeds submitted
            req.submitted_tick = self.clock
            self._stats["submitted"] += 1
        if req.attempts == 0:
            req.attempts = 1
        req.status = RequestStatus.RUNNING
        self._stats["admitted"] += 1
        new_mask = np.zeros((bucket.num_slots,), bool)
        new_mask[slot] = True
        bucket.refresh_state(new_mask)
        return True

    def admit_pending(self) -> int:
        """Admit as many pending requests as slots allow; returns the count.

        Scans the whole queue in priority order, not just its head: a
        full bucket at the front must not starve requests whose buckets
        have free slots (no head-of-line blocking across buckets).
        """
        admitted = 0
        for req in list(self.pending):
            if self.try_admit(req):
                self.pending.remove(req)
                admitted += 1
        return admitted

    # -- failure quarantine ----------------------------------------------------
    def _next_rung(self, req: OTRequest) -> Optional[str]:
        ladder = self.policy.fallback_ladder
        return ladder[req._rung] if req._rung < len(ladder) else None

    def _quarantine(self, bucket: _Bucket, slot: int,
                    reason: str) -> Optional[OTRequest]:
        """Walk a failed slot down the retry ladder.

        Returns the request if it reached a terminal status (FAILED, or
        DONE via an off-slot fallback), or None if it was restarted
        in-slot and is still in flight.  Either way the bucket's other
        slots are untouched (state merges are masked per slot).
        """
        req = bucket.slots[slot]
        log.warning("request %d quarantined in bucket %s slot %d: %s "
                    "(attempt %d)", req.rid, bucket.key, slot, reason,
                    req.attempts)
        rung = self._next_rung(req)
        if (rung == "restart" and req.attempts < self.policy.max_attempts):
            # damped in-slot restart: zero duals, fresh snapshots, cleared
            # L-BFGS history — a fresh solve of the same slot, through the
            # same masked state merge admission uses (neighbours frozen)
            req._rung += 1
            req.attempts += 1
            req.error = reason
            self._stats["retry_attempts"] += 1
            mask = np.zeros((bucket.num_slots,), bool)
            mask[slot] = True
            bucket.refresh_state(mask)
            return None
        bucket.release(slot)
        return self._fallback(req, reason)

    def _fallback(self, req: OTRequest, reason: str) -> OTRequest:
        """Run the off-slot fallback rungs until success or exhaustion."""
        problem = self._as_problem(req)
        pa = problem.padded(self.dtype)
        error = reason
        while True:
            rung = self._next_rung(req)
            if rung is None or req.attempts >= self.policy.max_attempts:
                return self._finish(
                    req, RequestStatus.FAILED,
                    error=f"fallback ladder exhausted after {req.attempts} "
                          f"attempts; last error: {error}",
                )
            req._rung += 1
            if rung == "restart":        # in-slot only; skip once off-slot
                continue
            req.attempts += 1
            self._stats["retry_attempts"] += 1
            try:
                out = self._run_fallback(rung, problem, pa)
            except Exception as e:       # a fallback must never crash serving
                out = None
                error = f"{rung} fallback raised {type(e).__name__}: {e}"
            if out is None:
                if not error.startswith(rung):
                    error = f"{rung} fallback did not produce a finite solution"
                log.warning("request %d: %s", req.rid, error)
                continue
            value, plan, rounds = out
            req.value = value
            req.plan = plan
            if rounds is not None:
                req.rounds = rounds
            req.converged = True
            req.route = rung
            req.error = f"recovered via {rung} fallback after: {reason}"
            log.info("request %d recovered via %s fallback", req.rid, rung)
            return self._finish(req, RequestStatus.DONE)

    def _run_fallback(self, rung: str, problem: Problem, pa):
        """One fallback rung; returns (value, plan, rounds) or None."""
        m, n = problem.num_source, problem.num_target
        if rung == "dense":
            # the unscreened origin backend: no screening state to poison,
            # same device solver otherwise
            opts = dataclasses.replace(self.opts, grad_impl="dense")
            C = jnp.asarray(pa.C)
            res = slv.solve_dual(C, jnp.asarray(pa.a), jnp.asarray(pa.b),
                                 pa.spec, problem.reg, opts)
            value = float(res.value)
            if not (res.converged and np.isfinite(value)):
                return None
            T_pad = np.asarray(slv.recover_plan(res, C, pa.spec, problem.reg))
            rounds = int(res.rounds)
        elif rung == "cpu":
            # last resort: the scipy f64 CPU baseline — a different
            # optimizer on a different substrate
            from repro.core import cpu_baseline

            res = cpu_baseline.fast_solve(pa.C, pa.a, pa.b, pa.spec,
                                          problem.reg)
            value = float(res.value)
            if not np.isfinite(value):
                return None
            prob = DualProblem(pa.spec.num_groups, pa.spec.group_size,
                               int(pa.C.shape[1]), problem.reg)
            T_pad = np.asarray(plan_from_duals(
                jnp.asarray(res.alpha, self.dtype),
                jnp.asarray(res.beta, self.dtype),
                jnp.asarray(pa.C), prob,
            ))
            rounds = None
        else:
            raise ValueError(f"unknown fallback rung {rung!r}")
        if not np.all(np.isfinite(T_pad)):
            return None
        T = np.zeros((m, n), T_pad.dtype)
        real = pa.perm >= 0
        T[pa.perm[real]] = T_pad[real][:, :n]
        return value, T, rounds

    # -- the tick --------------------------------------------------------------
    def _deadline_expired(self, req: OTRequest) -> bool:
        return (
            req.deadline is not None
            and req.submitted_tick is not None
            and self.clock - req.submitted_tick >= req.deadline
        )

    def tick(self) -> List[OTRequest]:
        """One fused solver round per active bucket; returns finished.

        A tick advances the engine clock, runs one round per bucket,
        retires healthy finishers, quarantines failing slots down the
        retry ladder, expires deadlines (in-flight AND still-queued), and
        evicts idle buckets.

        Returns
        -------
        list of OTRequest
            Requests that reached a terminal status this tick, with
            ``status`` / ``value`` / ``plan`` / ``rounds`` / ``error``
            filled in as applicable.
        """
        self.clock += 1
        self._stats["ticks"] += 1
        finished: List[OTRequest] = []
        for bucket in list(self.buckets.values()):
            done, bad = bucket.tick(self.clock)
            for req in done:
                if req.route is None:
                    req.route = "slot"
                if not req.converged and req.error is None:
                    req.error = "retired at max_rounds without convergence"
                finished.append(self._finish(req, RequestStatus.DONE))
            for slot, reason in bad:
                out = self._quarantine(bucket, slot, reason)
                if out is not None:
                    finished.append(out)
        # deadline sweep: mid-flight slots first, then the pending queue
        for bucket in self.buckets.values():
            for slot in bucket.occupied():
                req = bucket.slots[slot]
                if self._deadline_expired(req):
                    bucket.release(slot)
                    finished.append(self._finish(
                        req, RequestStatus.DEADLINE_EXCEEDED,
                        error=f"deadline of {req.deadline} ticks expired "
                              f"mid-flight after {req.rounds or 0} rounds",
                    ))
        for req in [r for r in self.pending if self._deadline_expired(r)]:
            self.pending.remove(req)
            finished.append(self._finish(
                req, RequestStatus.DEADLINE_EXCEEDED,
                error=f"deadline of {req.deadline} ticks expired while queued",
            ))
        # idle eviction: an empty bucket holds device buffers and host
        # mirrors; traffic mixes shift, so the dict must not grow forever
        for key in list(self.buckets):
            bucket = self.buckets[key]
            if bucket.occupied():
                bucket.idle_ticks = 0
            else:
                bucket.idle_ticks += 1
                if bucket.idle_ticks > self.policy.idle_evict_after:
                    del self.buckets[key]
                    self._stats["evictions"] += 1
                    log.info("evicted idle bucket %s", key)
        return finished

    def _in_flight(self) -> int:
        return sum(len(b.occupied()) for b in self.buckets.values())

    def run(self, requests: List[OTRequest]) -> List[OTRequest]:
        """Drain a request list to completion (admit greedily, tick, retire).

        Every submitted request comes back with exactly one terminal
        status; ``run`` NEVER hangs — two stall guards bound it:

        * nothing in flight + no admission progress for
          ``policy.stall_passes`` consecutive passes -> the remaining
          pending requests are shed (no future pass could admit them:
          admission is deterministic in the engine state, which is not
          changing),
        * in-flight slots frozen (e.g. a fault-stalled bucket) for
          ``policy.stall_passes + opts.max_rounds`` passes -> the frozen
          slots are failed and the queue shed (safety valve: a healthy
          slot retires within ``max_rounds`` ticks by construction).

        Parameters
        ----------
        requests : list of OTRequest or repro.ot.Problem
            The workload; consumed in priority order subject to slot
            availability.  Bare Problems are wrapped with engine-assigned
            request ids.

        Returns
        -------
        list of OTRequest
            All requests, each terminal, in completion order.
        """
        done: List[OTRequest] = []
        for r in requests:
            _, shed = self.enqueue(r)
            done.extend(shed)
        stalled = 0
        while len(self.pending) or self._in_flight():
            admitted = self.admit_pending()
            retired = self.tick()
            done.extend(retired)
            stalled = 0 if (admitted or retired) else stalled + 1
            if stalled >= self.policy.stall_passes and not self._in_flight():
                for req in self.pending.drain():
                    done.append(self._finish(
                        req, RequestStatus.SHED,
                        error="stall guard: no admission progress and "
                              "nothing in flight",
                    ))
            elif stalled >= self.policy.stall_passes + self.opts.max_rounds:
                for bucket in list(self.buckets.values()):
                    for slot in bucket.occupied():
                        req, _ = bucket.release(slot)
                        done.append(self._finish(
                            req, RequestStatus.FAILED,
                            error="stall guard: bucket made no progress",
                        ))
                for req in self.pending.drain():
                    done.append(self._finish(
                        req, RequestStatus.SHED,
                        error="stall guard: engine frozen",
                    ))
        return done

    # -- observability ---------------------------------------------------------
    def stats(self) -> dict:
        """Serving-health counters (cumulative over the engine's lifetime).

        Returns
        -------
        dict
            ``ticks`` / ``submitted`` / ``admitted`` / ``evictions`` /
            ``retry_attempts`` / ``launches`` scalars, a ``status`` dict
            with one count per terminal
            :class:`~repro.serving.policy.RequestStatus`, and the live
            ``pending`` / ``in_flight`` / ``buckets`` gauges.
        """
        out = dict(self._stats)
        out["status"] = dict(self._stats["status"])
        out["pending"] = len(self.pending)
        out["in_flight"] = self._in_flight()
        out["buckets"] = len(self.buckets)
        return out

    def describe(self) -> str:
        """Human-readable serving-health block (stats + policy + buckets)."""
        s = self.stats()
        st = s["status"]
        lines = [
            f"engine:   clock={self.clock} buckets={s['buckets']} "
            f"pending={s['pending']} in_flight={s['in_flight']}",
            f"policy:   max_pending={self.policy.max_pending} "
            f"deadline={self.policy.default_deadline} "
            f"max_attempts={self.policy.max_attempts} "
            f"ladder={'/'.join(self.policy.fallback_ladder)}",
            f"terminal: done={st['DONE']} failed={st['FAILED']} "
            f"shed={st['SHED']} deadline={st['DEADLINE_EXCEEDED']}",
            f"work:     admitted={s['admitted']}/{s['submitted']} "
            f"retries={s['retry_attempts']} launches={s['launches']} "
            f"evictions={s['evictions']}",
        ]
        return "\n".join(lines)
