"""OT request serving engine: continuous batching over solver rounds.

The batched solver (``core.solver.solve_batch``) wants B same-shape
problems; real traffic (many concurrent domain-adaptation solves) arrives
with mixed shapes and at arbitrary times.  This engine is the bridge, in
the mold of :class:`repro.serving.engine.ServingEngine` (fixed slots,
static shapes, slot recycling):

  * requests carry a raw (m, n) cost matrix + class labels (plus optional
    marginals); the engine pads each to a canonical *bucket* geometry
    (L groups x padded group size, n rounded up to ``n_quant``) so every
    problem in a bucket shares one compiled program,
  * each bucket owns a fixed grid of ``num_devices x slots_per_device``
    slots; admission writes the request's padded arrays into a free slot
    (preferring the least-loaded device) and (re)initializes that slot's
    solver state, preserving in-flight neighbours bit-for-bit,
  * every engine tick runs ONE fused ``batch_round`` per active bucket —
    a full Algorithm-1 round (L-BFGS segment + screening refresh) for all
    slots in one program launch.  With a device mesh attached, that one
    launch is a ``shard_map`` program whose problem axis is split over the
    mesh (``core.sharded``): each device advances its own slots with its
    own screening state and its own compact tile schedule, and the only
    cross-device movement is the engine's read of the ``(S,)`` converged/
    failed flags at the round boundary,
  * finished slots (converged / failed / round cap) are retired: the
    request gets its objective value and its primal plan un-padded back
    to the caller's row order, and the slot is recycled.

Empty slots hold a dummy problem (PAD_COST costs, zero marginals) whose
gradient is identically zero, so they converge at initialization and ride
along for free.  Column padding appends zero-mass targets with PAD_COST
costs: their plan column is exactly zero and their dual variable has zero
gradient, so a padded solve equals the unpadded one on real entries (same
argument as row padding, see core/groups.py).

Slot -> (device, lane) mapping: the problem axis is sharded in contiguous
blocks, so slot ``i`` lives on device ``i // slots_per_device``, lane
``i % slots_per_device``.  Admission balances live requests across devices
because per-tick wall-clock is the *max* over devices of their local work
(the compact kernel's grid scales with each shard's surviving tiles).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import groups as G
from repro.core import solver as slv
from repro.core.dual import DualProblem, plan_from_duals
from repro.core.lbfgs import where_state
from repro.core.regularizers import Regularizer
from repro.ot.problem import Problem
from repro.utils.logging import get_logger

log = get_logger("ot_serving")


@dataclasses.dataclass
class OTRequest:
    """One OT solve request (inputs in the caller's row order).

    The payload is a declarative :class:`repro.ot.Problem` — pass one via
    ``problem`` (or :meth:`from_problem`), or pass the raw ``C`` +
    ``labels`` fields and the engine lifts them into a cost-mode Problem
    at admission (the pre-façade wire format, kept for compatibility).

    Parameters
    ----------
    rid : int
        Caller-chosen request id (echoed back on retirement).
    C : np.ndarray, optional
        ``(m, n)`` float cost matrix in the caller's row/column order
        (raw form; ignored when ``problem`` is given).
    labels : np.ndarray, optional
        ``(m,)`` integer class labels of the source rows (raw form).
    a : np.ndarray, optional
        ``(m,)`` source marginal; defaults to uniform ``1/m`` (raw form).
    b : np.ndarray, optional
        ``(n,)`` target marginal; defaults to uniform ``1/n`` (raw form).
    reg : Regularizer, optional
        Per-request regularizer; defaults to the engine's.  Requests with
        different regularizers never share a bucket (the compiled program
        and the screening thresholds specialize on the regularizer), so
        mixed-regularizer traffic packs into per-regularizer batches.
    problem : repro.ot.Problem, optional
        The declarative payload; carries its own regularizer, marginals
        and group layout (``reg`` / ``C`` / ``labels`` are then unused).

    Attributes
    ----------
    value : float or None
        Dual objective at convergence (filled at retirement).
    plan : np.ndarray or None
        ``(m, n)`` primal transport plan, caller's row order (filled at
        retirement).
    rounds : int
        Algorithm-1 rounds the solve ran.
    converged : bool
        Whether the solver converged (vs. failed / hit the round cap).
    done : bool
        Set when the request has been retired.
    """

    rid: int
    C: Optional[np.ndarray] = None     # (m, n) cost matrix (raw form)
    labels: Optional[np.ndarray] = None  # (m,) integer class labels (raw form)
    a: Optional[np.ndarray] = None     # (m,) source marginal (default 1/m)
    b: Optional[np.ndarray] = None     # (n,) target marginal (default 1/n)
    reg: Optional[Regularizer] = None  # per-request regularizer (default:
    #   the engine's; distinct regularizers go to distinct buckets)
    problem: Optional[Problem] = None  # declarative payload (preferred)
    # filled at retirement:
    value: Optional[float] = None      # dual objective at convergence
    plan: Optional[np.ndarray] = None  # (m, n) primal plan, original order
    rounds: int = 0
    converged: bool = False
    done: bool = False

    @staticmethod
    def from_problem(rid: int, problem: Problem) -> "OTRequest":
        """Wrap a declarative :class:`repro.ot.Problem` as a request."""
        return OTRequest(rid=rid, problem=problem)


@jax.jit
def _select_slots(mask, new, old):
    """Per-slot state merge (jitted so admission is one launch)."""
    return where_state(mask, new, old)


class _Bucket:
    """Fixed-slot batch of one (padded geometry, regularizer) combination.

    The bucket key is ``(L, g_pad, n_pad, reg)``: problems only share a
    bucket — and therefore a compiled program, a screening-threshold
    vector, and a batch — when both their padded geometry AND their
    regularizer coincide.  ``num_slots`` = ``num_devices *
    slots_per_device``; with a mesh attached, slot arrays and solver state
    are committed shard-wise so an engine tick dispatches one sharded
    ``batch_round`` with no implicit resharding.
    """

    def __init__(self, key: Tuple, slots_per_device: int,
                 reg: Regularizer, opts: slv.SolveOptions, dtype,
                 mesh=None):
        L, g_pad, n_pad = key[:3]
        self.key = key
        self.mesh = mesh
        self.num_devices = mesh.size if mesh is not None else 1
        self.slots_per_device = slots_per_device
        self.num_slots = slots_per_device * self.num_devices
        self.reg = reg
        self.opts = opts
        self.prob = DualProblem(L, g_pad, n_pad, reg)
        m_pad = self.prob.m_pad
        S = self.num_slots
        self.slots: List[Optional[OTRequest]] = [None] * S
        self._meta: List[Optional[dict]] = [None] * S   # perm/spec per slot
        self.C = np.full((S, m_pad, n_pad), G.PAD_COST, dtype)
        self.a = np.zeros((S, m_pad), dtype)
        self.b = np.zeros((S, n_pad), dtype)
        self.row_mask = np.zeros((S, m_pad), bool)
        self.sqrt_g = np.zeros((S, L), dtype)
        self.state: Optional[slv.BatchSolveState] = None
        # device-resident copies of the slot arrays + (pallas) the padded
        # problem, rebuilt only when a slot's contents change — a tick must
        # not re-upload (S, m_pad, n_pad) buffers or re-pad C every round
        self._device: Optional[tuple] = None
        self._padded = None

    def slot_placement(self, slot: int) -> Tuple[int, int]:
        """Map a slot index to its ``(device, lane)`` coordinates.

        The problem axis shards in contiguous blocks over the 1-D mesh, so
        this is a pure index computation — no device queries.
        """
        return slot // self.slots_per_device, slot % self.slots_per_device

    def _device_arrays(self) -> tuple:
        if self._device is None:
            arrs = (
                jnp.asarray(self.C), jnp.asarray(self.a), jnp.asarray(self.b),
                jnp.asarray(self.row_mask), jnp.asarray(self.sqrt_g),
            )
            if self.mesh is not None:
                from repro.core import sharded as shd

                arrs = shd.device_put_batch(arrs, self.mesh)
            self._device = arrs
            self._padded = None
            if self.opts.grad_impl == "pallas":
                if self.mesh is not None:
                    from repro.core import sharded as shd

                    self._padded = shd.prepare_padded_sharded(
                        self._device[0], self.prob, self.mesh
                    )
                else:
                    from repro.kernels import ops as kops

                    self._padded = kops.prepare_padded_problem_batched(
                        self._device[0], self.prob
                    )
        return self._device

    # -- admission -----------------------------------------------------------
    def free_slot(self) -> Optional[int]:
        """Pick a free slot on the least-loaded device (None if full).

        Per-tick latency is the max over devices of their local work, so
        spreading live requests keeps the sharded round balanced.  With
        one device this degenerates to first-free-slot (the original
        policy), preserving single-device behavior exactly.
        """
        free = [i for i, s in enumerate(self.slots) if s is None]
        if not free:
            return None
        load = [0] * self.num_devices
        for i, s in enumerate(self.slots):
            if s is not None:
                load[i // self.slots_per_device] += 1
        return min(free, key=lambda i: (load[i // self.slots_per_device], i))

    def admit(self, slot: int, req: OTRequest, problem: Problem):
        """Write the request's padded Problem arrays into ``slot`` (no state init)."""
        m, n = problem.num_source, problem.num_target
        dtype = self.C.dtype
        C_pad, a_pad, b, spec, perm = problem.padded(dtype=dtype)

        self.C[slot] = G.PAD_COST
        self.C[slot, :, :n] = C_pad
        self.a[slot] = a_pad
        self.b[slot] = 0.0
        self.b[slot, :n] = np.asarray(b, dtype)
        self.row_mask[slot] = spec.row_mask().reshape(-1)
        self.sqrt_g[slot] = spec.sqrt_sizes()
        self.slots[slot] = req
        self._meta[slot] = {"spec": spec, "perm": perm, "m": m, "n": n}
        self._device = None          # slot arrays changed: re-upload lazily
        dev, lane = self.slot_placement(slot)
        log.info(
            "admitted OT request %d into bucket %s slot %d "
            "(device %d lane %d, m=%d n=%d)",
            req.rid, self.key, slot, dev, lane, m, n,
        )

    def _init_state(self):
        """One jitted state init over all slots (sharded when mesh set)."""
        C, a, b, row_mask, sqrt_g = self._device_arrays()
        if self.mesh is not None:
            from repro.core import sharded as shd

            return slv._launch(
                shd.init_batch_state_sharded,
                C, a, b, row_mask, sqrt_g, self.prob, self.opts,
                self.mesh, self._padded,
            )
        return slv._launch(
            slv.init_batch_state,
            C, a, b, row_mask, sqrt_g, self.prob, self.opts, self._padded,
        )

    def refresh_state(self, new_mask: np.ndarray):
        """(Re)initialize solver state for slots in ``new_mask``; keep others."""
        fresh = self._init_state()
        if self.state is None:
            self.state = fresh
        else:
            self.state = _select_slots(jnp.asarray(new_mask), fresh, self.state)

    # -- one engine tick -----------------------------------------------------
    def occupied(self) -> List[int]:
        return [i for i, s in enumerate(self.slots) if s is not None]

    def tick(self) -> List[OTRequest]:
        """One fused solver round for all slots; returns retired requests."""
        active = self.occupied()
        if not active or self.state is None:
            return []
        C, a, b, row_mask, sqrt_g = self._device_arrays()
        if self.mesh is not None:
            from repro.core import sharded as shd

            self.state = slv._launch(
                shd.batch_round_sharded,
                self.state, C, a, b, row_mask, sqrt_g,
                self.prob, self.opts, self.mesh, self._padded,
            )
        else:
            self.state = slv._launch(
                slv.batch_round,
                self.state, C, a, b, row_mask, sqrt_g,
                self.prob, self.opts, self._padded,
            )
        lb = self.state.lb
        # round-boundary gather: the only cross-device movement in a tick
        # (a few bytes per device of converged/failed flags + round counts)
        conv = np.asarray(lb.converged)
        failed = np.asarray(lb.failed)
        rounds = np.asarray(self.state.rounds)
        finished = []
        for i in active:
            if not (conv[i] or failed[i] or rounds[i] >= self.opts.max_rounds):
                continue
            finished.append(self._retire(i, bool(conv[i]), int(rounds[i])))
        return finished

    def _retire(self, slot: int, converged: bool, rounds: int) -> OTRequest:
        req = self.slots[slot]
        meta = self._meta[slot]
        lb = self.state.lb
        m_pad = self.prob.m_pad
        # materialize the retiring slot's duals on host: keeps the plan
        # recovery a plain single-device computation even when lb.x is
        # committed shard-wise across the mesh
        x = np.asarray(lb.x[slot])
        alpha = jnp.asarray(x[:m_pad])
        beta = jnp.asarray(x[m_pad:])
        T_pad = np.asarray(
            plan_from_duals(alpha, beta, jnp.asarray(self.C[slot]), self.prob)
        )
        # un-pad rows back to the caller's order, drop padded columns
        m, n = meta["m"], meta["n"]
        perm = meta["perm"]
        T = np.zeros((m, n), T_pad.dtype)
        real = perm >= 0
        T[perm[real]] = T_pad[real][:, :n]
        req.value = float(-lb.f[slot])
        req.plan = T
        req.rounds = rounds
        req.converged = converged
        req.done = True
        # recycle: dummy problem (zero gradient) until the next admission
        self.slots[slot] = None
        self._meta[slot] = None
        self.C[slot] = G.PAD_COST
        self.a[slot] = 0.0
        self.b[slot] = 0.0
        self.row_mask[slot] = False
        self.sqrt_g[slot] = 0.0
        self._device = None          # slot arrays changed: re-upload lazily
        log.info("OT request %d finished (rounds=%d converged=%s)",
                 req.rid, rounds, converged)
        return req


class OTServingEngine:
    """Serve a stream of OT solve requests with bucketed continuous batching.

    Requests are declarative :class:`repro.ot.Problem` objects — admitted
    directly (:meth:`submit`, or ``run`` on a list of Problems) or wrapped
    in an :class:`OTRequest` envelope (which also lifts the pre-façade raw
    ``C`` + ``labels`` wire format).  Problems whose padded geometry
    ``(L, g_pad, ceil(n / n_quant) * n_quant)`` AND regularizer coincide
    share a bucket — and therefore a compiled program and a batch
    (mixed-regularizer traffic packs into per-regularizer buckets; see
    :meth:`_bucket_key`).  Each tick
    advances every active bucket by one fused
    Algorithm-1 round in a single program launch per bucket; attached to a
    device mesh, that launch is a ``shard_map`` program with the slot axis
    split across devices (see :mod:`repro.core.sharded`).

    Parameters
    ----------
    reg : Regularizer
        Default regularizer for requests that don't carry their own
        (compiled programs specialize on it per bucket).
    opts : SolveOptions, optional
        Solver options, including the ``grad_impl`` backend
        ('dense' | 'screened' | 'pallas').
    max_batch : int, optional
        Slots **per device** in each bucket; a bucket's total slot count
        is ``max_batch * mesh.size`` (or just ``max_batch`` without a
        mesh).
    n_quant : int, optional
        Column-padding granularity for bucket keys.
    pad_to : int, optional
        Group-size padding granularity (rows per group rounded up).
    dtype : numpy dtype, optional
        Storage dtype of the slot arrays (float32 everywhere in practice).
    mesh : jax.sharding.Mesh, optional
        A 1-D batch mesh (see
        :func:`repro.core.distributed.make_batch_mesh`).  When given,
        every bucket packs ``mesh.size * max_batch`` slots and ticks run
        sharded; when omitted the engine is single-device and its
        behavior (and results) are bit-for-bit those of the pre-mesh
        engine.

    Examples
    --------
    >>> engine = OTServingEngine(GroupSparseReg.from_rho(1.0, 0.6))
    >>> done = engine.run([OTRequest(rid=0, C=C, labels=y)])
    >>> done[0].value, done[0].plan.shape
    """

    def __init__(
        self,
        reg: Regularizer,
        opts: slv.SolveOptions = slv.SolveOptions(),
        max_batch: int = 4,
        n_quant: int = 64,
        pad_to: int = 8,
        dtype=np.float32,
        mesh=None,
    ):
        self.reg = reg
        self.opts = opts
        self.max_batch = max_batch
        self.n_quant = n_quant
        self.pad_to = pad_to
        self.dtype = dtype
        self.mesh = mesh
        self.num_devices = mesh.size if mesh is not None else 1
        self.buckets: Dict[Tuple, _Bucket] = {}
        self._next_rid = 0

    def _as_problem(self, req: OTRequest) -> Problem:
        """The request's declarative payload (lifting raw C + labels).

        Construction validates shapes, marginals and the regularizer's
        per-group parameters against the request's own group count BEFORE
        any slot/bucket mutation — a malformed request is rejected here,
        not from inside state init where it would poison a bucket.
        """
        if req.problem is not None:
            return req.problem
        if req.C is None or req.labels is None:
            raise ValueError(
                f"request {req.rid} carries neither a Problem nor raw C + labels"
            )
        reg = req.reg if req.reg is not None else self.reg
        # cache the lifted Problem on the request — run() retries admission
        # on every tick while buckets are full, and re-validating (array
        # conversions + label sort) per retry would tax the serving loop —
        # but key the cache on the resolved (reg, pad_to): the raw fields
        # stay authoritative, so reusing the request with another engine
        # (different defaults) or after changing req.reg re-lifts it
        cached = getattr(req, "_lifted", None)
        if cached is not None and cached[0] == reg and cached[1] == self.pad_to:
            return cached[2]
        problem = Problem(
            reg=reg, C=req.C, labels=req.labels, a=req.a, b=req.b,
            pad_to=self.pad_to,
        )
        req._lifted = (reg, self.pad_to, problem)
        return problem

    def _bucket_key(self, problem: Problem) -> Tuple:
        """Bucket key ``(L, g_pad, n_pad, reg)`` from the Problem geometry.

        The regularizer is part of the key (regularizers are hashable
        frozen dataclasses): two problems with identical padded geometry
        but different regularizer kinds — or the same kind with different
        parameters — must not share a batch, because the compiled solver
        program and the per-group screening thresholds specialize on the
        regularizer.
        """
        L, g_pad, n = problem.geometry()
        n_pad = -(-n // self.n_quant) * self.n_quant
        return (L, g_pad, n_pad, problem.reg)

    def submit(self, problem: Problem, rid: Optional[int] = None) -> Optional[OTRequest]:
        """Admit a declarative :class:`repro.ot.Problem` directly.

        Parameters
        ----------
        problem : repro.ot.Problem
            The problem to serve (carries its own regularizer/layout).
        rid : int, optional
            Request id; defaults to an engine-assigned sequence number.

        Returns
        -------
        OTRequest or None
            The in-flight request handle, or None if the problem's bucket
            is full (caller retries after a tick).
        """
        if rid is None:
            rid = self._next_rid
            self._next_rid += 1
        req = OTRequest.from_problem(rid, problem)
        return req if self.try_admit(req) else None

    def try_admit(self, req: OTRequest) -> bool:
        """Admit into the request's bucket if a slot is free (no round run).

        Parameters
        ----------
        req : OTRequest
            The request to place (Problem payload or raw C + labels).

        Returns
        -------
        bool
            True if a slot was free (the request is now in flight), False
            if the bucket is full (caller retries after a tick).
        """
        problem = self._as_problem(req)
        key = self._bucket_key(problem)
        bucket = self.buckets.get(key)
        if bucket is None:
            bucket = _Bucket(key, self.max_batch, key[3], self.opts,
                             self.dtype, mesh=self.mesh)
            self.buckets[key] = bucket
        slot = bucket.free_slot()
        if slot is None:
            return False
        bucket.admit(slot, req, problem)
        new_mask = np.zeros((bucket.num_slots,), bool)
        new_mask[slot] = True
        bucket.refresh_state(new_mask)
        return True

    def tick(self) -> List[OTRequest]:
        """One fused solver round per active bucket; returns finished.

        Returns
        -------
        list of OTRequest
            Requests retired this round, with ``value`` / ``plan`` /
            ``rounds`` / ``converged`` filled in.
        """
        finished: List[OTRequest] = []
        for bucket in self.buckets.values():
            finished.extend(bucket.tick())
        return finished

    def run(self, requests: List[OTRequest]) -> List[OTRequest]:
        """Drain a request list to completion (admit greedily, tick, retire).

        Admission scans the whole pending list, not just its head: a full
        bucket at the front must not starve requests whose buckets have
        free slots (no head-of-line blocking across buckets).

        Parameters
        ----------
        requests : list of OTRequest or repro.ot.Problem
            The workload; consumed in order subject to slot availability.
            Bare Problems are wrapped with engine-assigned request ids.

        Returns
        -------
        list of OTRequest
            All requests, each retired (``done=True``), in completion
            order.
        """
        pending = []
        for r in requests:
            if isinstance(r, Problem):
                rid, self._next_rid = self._next_rid, self._next_rid + 1
                r = OTRequest.from_problem(rid, r)
            pending.append(r)
        done: List[OTRequest] = []
        while pending or any(b.occupied() for b in self.buckets.values()):
            pending = [req for req in pending if not self.try_admit(req)]
            done.extend(self.tick())
        return done
