"""OT request serving engine: continuous batching over solver rounds.

The batched solver (``core.solver.solve_batch``) wants B same-shape
problems; real traffic (many concurrent domain-adaptation solves) arrives
with mixed shapes and at arbitrary times.  This engine is the bridge, in
the mold of :class:`repro.serving.engine.ServingEngine` (fixed slots,
static shapes, slot recycling):

  * requests carry a raw (m, n) cost matrix + class labels (plus optional
    marginals); the engine pads each to a canonical *bucket* geometry
    (L groups x padded group size, n rounded up to ``n_quant``) so every
    problem in a bucket shares one compiled program,
  * each bucket owns ``max_batch`` fixed slots; admission writes the
    request's padded arrays into a free slot and (re)initializes that
    slot's solver state, preserving in-flight neighbours bit-for-bit,
  * every engine tick runs ONE fused ``batch_round`` per active bucket —
    a full Algorithm-1 round (L-BFGS segment + screening refresh) for all
    slots in one program launch,
  * finished slots (converged / failed / round cap) are retired: the
    request gets its objective value and its primal plan un-padded back
    to the caller's row order, and the slot is recycled.

Empty slots hold a dummy problem (PAD_COST costs, zero marginals) whose
gradient is identically zero, so they converge at initialization and ride
along for free.  Column padding appends zero-mass targets with PAD_COST
costs: their plan column is exactly zero and their dual variable has zero
gradient, so a padded solve equals the unpadded one on real entries (same
argument as row padding, see core/groups.py).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import groups as G
from repro.core import solver as slv
from repro.core.dual import DualProblem, plan_from_duals
from repro.core.lbfgs import where_state
from repro.core.regularizers import GroupSparseReg
from repro.utils.logging import get_logger

log = get_logger("ot_serving")


@dataclasses.dataclass
class OTRequest:
    """One OT solve request (inputs in the caller's row order)."""

    rid: int
    C: np.ndarray                      # (m, n) cost matrix
    labels: np.ndarray                 # (m,) integer class labels
    a: Optional[np.ndarray] = None     # (m,) source marginal (default 1/m)
    b: Optional[np.ndarray] = None     # (n,) target marginal (default 1/n)
    # filled at retirement:
    value: Optional[float] = None      # dual objective at convergence
    plan: Optional[np.ndarray] = None  # (m, n) primal plan, original order
    rounds: int = 0
    converged: bool = False
    done: bool = False


@jax.jit
def _select_slots(mask, new, old):
    """Per-slot state merge (jitted so admission is one launch)."""
    return where_state(mask, new, old)


class _Bucket:
    """Fixed-slot batch of one padded geometry (L, g_pad, n_pad)."""

    def __init__(self, key: Tuple[int, int, int], max_batch: int,
                 reg: GroupSparseReg, opts: slv.SolveOptions, dtype):
        L, g_pad, n_pad = key
        self.key = key
        self.max_batch = max_batch
        self.reg = reg
        self.opts = opts
        self.prob = DualProblem(L, g_pad, n_pad, reg)
        m_pad = self.prob.m_pad
        S = max_batch
        self.slots: List[Optional[OTRequest]] = [None] * S
        self._meta: List[Optional[dict]] = [None] * S   # perm/spec per slot
        self.C = np.full((S, m_pad, n_pad), G.PAD_COST, dtype)
        self.a = np.zeros((S, m_pad), dtype)
        self.b = np.zeros((S, n_pad), dtype)
        self.row_mask = np.zeros((S, m_pad), bool)
        self.sqrt_g = np.zeros((S, L), dtype)
        self.state: Optional[slv.BatchSolveState] = None
        # device-resident copies of the slot arrays + (pallas) the padded
        # problem, rebuilt only when a slot's contents change — a tick must
        # not re-upload (S, m_pad, n_pad) buffers or re-pad C every round
        self._device: Optional[tuple] = None
        self._padded = None

    def _device_arrays(self) -> tuple:
        if self._device is None:
            self._device = (
                jnp.asarray(self.C), jnp.asarray(self.a), jnp.asarray(self.b),
                jnp.asarray(self.row_mask), jnp.asarray(self.sqrt_g),
            )
            self._padded = None
            if self.opts.grad_impl == "pallas":
                from repro.kernels import ops as kops

                self._padded = kops.prepare_padded_problem_batched(
                    self._device[0], self.prob
                )
        return self._device

    # -- admission -----------------------------------------------------------
    def free_slot(self) -> Optional[int]:
        for i, s in enumerate(self.slots):
            if s is None:
                return i
        return None

    def admit(self, slot: int, req: OTRequest, spec: G.GroupSpec):
        L, g_pad, n_pad = self.key
        m, n = req.C.shape
        dtype = self.C.dtype
        a = req.a if req.a is not None else np.full((m,), 1.0 / m, dtype)
        b = req.b if req.b is not None else np.full((n,), 1.0 / n, dtype)

        C_pad = G.pad_cost_matrix(np.asarray(req.C, dtype), req.labels, spec)
        a_pad = G.pad_marginal(np.asarray(a, dtype), req.labels, spec)
        _, perm, _ = G.pad_sources(np.asarray(req.C, dtype), req.labels, spec)

        self.C[slot] = G.PAD_COST
        self.C[slot, :, :n] = C_pad
        self.a[slot] = a_pad
        self.b[slot] = 0.0
        self.b[slot, :n] = np.asarray(b, dtype)
        self.row_mask[slot] = spec.row_mask().reshape(-1)
        self.sqrt_g[slot] = spec.sqrt_sizes()
        self.slots[slot] = req
        self._meta[slot] = {"spec": spec, "perm": perm, "m": m, "n": n}
        self._device = None          # slot arrays changed: re-upload lazily
        log.info("admitted OT request %d into bucket %s slot %d (m=%d n=%d)",
                 req.rid, self.key, slot, m, n)

    def refresh_state(self, new_mask: np.ndarray):
        """(Re)initialize solver state for slots in ``new_mask``; keep others."""
        C, a, b, row_mask, sqrt_g = self._device_arrays()
        fresh = slv._launch(
            slv.init_batch_state,
            C, a, b, row_mask, sqrt_g, self.prob, self.opts, self._padded,
        )
        if self.state is None:
            self.state = fresh
        else:
            self.state = _select_slots(jnp.asarray(new_mask), fresh, self.state)

    # -- one engine tick -----------------------------------------------------
    def occupied(self) -> List[int]:
        return [i for i, s in enumerate(self.slots) if s is not None]

    def tick(self) -> List[OTRequest]:
        """One fused solver round for all slots; returns retired requests."""
        active = self.occupied()
        if not active or self.state is None:
            return []
        C, a, b, row_mask, sqrt_g = self._device_arrays()
        self.state = slv._launch(
            slv.batch_round,
            self.state, C, a, b, row_mask, sqrt_g,
            self.prob, self.opts, self._padded,
        )
        lb = self.state.lb
        conv = np.asarray(lb.converged)
        failed = np.asarray(lb.failed)
        rounds = np.asarray(self.state.rounds)
        finished = []
        for i in active:
            if not (conv[i] or failed[i] or rounds[i] >= self.opts.max_rounds):
                continue
            finished.append(self._retire(i, bool(conv[i]), int(rounds[i])))
        return finished

    def _retire(self, slot: int, converged: bool, rounds: int) -> OTRequest:
        req = self.slots[slot]
        meta = self._meta[slot]
        lb = self.state.lb
        m_pad = self.prob.m_pad
        alpha = lb.x[slot, :m_pad]
        beta = lb.x[slot, m_pad:]
        T_pad = np.asarray(
            plan_from_duals(alpha, beta, jnp.asarray(self.C[slot]), self.prob)
        )
        # un-pad rows back to the caller's order, drop padded columns
        m, n = meta["m"], meta["n"]
        perm = meta["perm"]
        T = np.zeros((m, n), T_pad.dtype)
        real = perm >= 0
        T[perm[real]] = T_pad[real][:, :n]
        req.value = float(-lb.f[slot])
        req.plan = T
        req.rounds = rounds
        req.converged = converged
        req.done = True
        # recycle: dummy problem (zero gradient) until the next admission
        self.slots[slot] = None
        self._meta[slot] = None
        self.C[slot] = G.PAD_COST
        self.a[slot] = 0.0
        self.b[slot] = 0.0
        self.row_mask[slot] = False
        self.sqrt_g[slot] = 0.0
        self._device = None          # slot arrays changed: re-upload lazily
        log.info("OT request %d finished (rounds=%d converged=%s)",
                 req.rid, rounds, converged)
        return req


class OTServingEngine:
    """Serve a stream of OT solve requests with bucketed continuous batching.

    Parameters mirror the solver: one regularizer + SolveOptions per engine
    (the compiled programs are specialized on them).  ``n_quant`` is the
    column-padding granularity — requests whose padded geometry
    (L, g_pad, ceil(n / n_quant) * n_quant) coincides share a bucket and
    therefore a compiled program and a batch.
    """

    def __init__(
        self,
        reg: GroupSparseReg,
        opts: slv.SolveOptions = slv.SolveOptions(),
        max_batch: int = 4,
        n_quant: int = 64,
        pad_to: int = 8,
        dtype=np.float32,
    ):
        self.reg = reg
        self.opts = opts
        self.max_batch = max_batch
        self.n_quant = n_quant
        self.pad_to = pad_to
        self.dtype = dtype
        self.buckets: Dict[Tuple[int, int, int], _Bucket] = {}

    def _bucket_key(self, req: OTRequest) -> Tuple[Tuple[int, int, int], G.GroupSpec]:
        spec = G.spec_from_labels(req.labels, pad_to=self.pad_to)
        n = req.C.shape[1]
        n_pad = -(-n // self.n_quant) * self.n_quant
        return (spec.num_groups, spec.group_size, n_pad), spec

    def try_admit(self, req: OTRequest) -> bool:
        """Admit into the request's bucket if a slot is free (no round run)."""
        key, spec = self._bucket_key(req)
        bucket = self.buckets.get(key)
        if bucket is None:
            bucket = _Bucket(key, self.max_batch, self.reg, self.opts,
                             self.dtype)
            self.buckets[key] = bucket
        slot = bucket.free_slot()
        if slot is None:
            return False
        bucket.admit(slot, req, spec)
        new_mask = np.zeros((self.max_batch,), bool)
        new_mask[slot] = True
        bucket.refresh_state(new_mask)
        return True

    def tick(self) -> List[OTRequest]:
        """One fused solver round per active bucket; returns finished."""
        finished: List[OTRequest] = []
        for bucket in self.buckets.values():
            finished.extend(bucket.tick())
        return finished

    def run(self, requests: List[OTRequest]) -> List[OTRequest]:
        """Drain a request list to completion (admit greedily, tick, retire).

        Admission scans the whole pending list, not just its head: a full
        bucket at the front must not starve requests whose buckets have
        free slots (no head-of-line blocking across buckets).
        """
        pending = list(requests)
        done: List[OTRequest] = []
        while pending or any(b.occupied() for b in self.buckets.values()):
            pending = [req for req in pending if not self.try_admit(req)]
            done.extend(self.tick())
        return done
