from repro.utils.tree import (
    tree_bytes,
    tree_count,
    tree_global_norm,
    tree_zeros_like,
)
from repro.utils.compat import make_mesh, mesh_axis_types_kwargs
from repro.utils.logging import get_logger

__all__ = [
    "tree_bytes",
    "tree_count",
    "tree_global_norm",
    "tree_zeros_like",
    "make_mesh",
    "mesh_axis_types_kwargs",
    "get_logger",
]
