"""Lightweight structured logging for the framework."""
from __future__ import annotations

import logging
import os
import sys

_FMT = "%(asctime)s %(levelname).1s %(name)s] %(message)s"


def get_logger(name: str) -> logging.Logger:
    logger = logging.getLogger(name)
    if not logger.handlers:
        handler = logging.StreamHandler(sys.stderr)
        handler.setFormatter(logging.Formatter(_FMT, datefmt="%H:%M:%S"))
        logger.addHandler(handler)
        logger.setLevel(os.environ.get("REPRO_LOGLEVEL", "INFO"))
        logger.propagate = False
    return logger
