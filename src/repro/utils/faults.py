"""Fault-injection registry for the OT serving engine's chaos tests.

Robustness claims ("the engine never crashes or hangs; every request
reaches exactly one terminal status") are only testable if faults can be
produced on demand, deterministically, without monkeypatching engine
internals.  This module is that switchboard: tests inject
:class:`FaultSpec` entries into the process-wide :data:`REGISTRY`, and
the engine consults well-defined hook points (:meth:`FaultRegistry.fire`)
at admission and at the round boundary.  With an empty registry — the
production state — every hook is a single cheap boolean check.

Supported fault kinds (the ``kind`` field of :class:`FaultSpec`):

  * ``'nan_cost'``      — corrupt a request's slot cost with NaN AFTER
    admission validation (simulates in-flight data poisoning; admission
    itself rejects non-finite inputs, so this is the only way NaN can
    reach a live slot),
  * ``'lbfgs_fail'``    — force the slot's L-BFGS failure flag at the
    round boundary (simulates an inner-optimizer breakdown),
  * ``'admit_fail'``    — make ``try_admit`` refuse a slot (simulates a
    transient admission failure; the request stays pending and retries),
  * ``'slow_bucket'``   — make a bucket's tick do nothing (simulates a
    slow/hung device: requests age without progress, deadlines expire).

Faults are scoped by request id (``rids``), bucket key substring
(``bucket``), earliest tick (``after_tick``), and a firing budget
(``count``); every firing is logged to :attr:`FaultRegistry.fired` so
tests can assert exactly which faults hit.
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import List, Optional, Tuple


@dataclasses.dataclass
class FaultSpec:
    """One injected fault (see module docstring for the kinds).

    Parameters
    ----------
    kind : str
        One of ``'nan_cost'``, ``'lbfgs_fail'``, ``'admit_fail'``,
        ``'slow_bucket'``.
    rids : frozenset of int, optional
        Request ids the fault targets (``None`` = any request).
    bucket : str, optional
        Substring match against ``str(bucket_key)`` for bucket-scoped
        faults (``None`` = any bucket).
    after_tick : int
        Engine tick (inclusive) before which the fault never fires.
    count : int, optional
        Remaining firing budget (``None`` = unlimited).  Each
        :meth:`FaultRegistry.fire` match decrements it; at 0 the spec is
        spent and never fires again.
    """

    kind: str
    rids: Optional[frozenset] = None
    bucket: Optional[str] = None
    after_tick: int = 0
    count: Optional[int] = None

    KINDS = ("nan_cost", "lbfgs_fail", "admit_fail", "slow_bucket")

    def __post_init__(self):
        if self.kind not in self.KINDS:
            raise ValueError(
                f"unknown fault kind {self.kind!r}; valid: {self.KINDS}"
            )
        if self.rids is not None:
            self.rids = frozenset(int(r) for r in self.rids)

    def matches(self, rid: Optional[int], bucket, tick: int) -> bool:
        """Whether this spec applies to the given firing context."""
        if self.count is not None and self.count <= 0:
            return False
        if tick < self.after_tick:
            return False
        if self.rids is not None and (rid is None or rid not in self.rids):
            return False
        if self.bucket is not None and (
            bucket is None or self.bucket not in str(bucket)
        ):
            return False
        return True


class FaultRegistry:
    """Process-wide fault switchboard (one instance: :data:`REGISTRY`).

    Tests ``inject()`` specs (or use the :func:`injected` context
    manager); the engine calls :meth:`fire` at its hook points.  The
    registry is empty in production, and :meth:`enabled` lets hot paths
    skip all matching work with one branch.
    """

    def __init__(self):
        self._specs: List[FaultSpec] = []
        self.fired: List[Tuple[str, Optional[int], int]] = []

    def enabled(self) -> bool:
        """Fast-path check: any spec installed at all?"""
        return bool(self._specs)

    def inject(self, spec: FaultSpec) -> FaultSpec:
        """Install a fault spec; returns it (handy for later inspection)."""
        self._specs.append(spec)
        return spec

    def reset(self) -> None:
        """Remove every spec and clear the firing log."""
        self._specs.clear()
        self.fired.clear()

    def fire(self, kind: str, *, rid: Optional[int] = None, bucket=None,
             tick: int = 0) -> bool:
        """Consume one firing of ``kind`` in this context, if any matches.

        Returns True (and decrements the matching spec's budget, and logs
        ``(kind, rid, tick)``) when an installed spec matches; False —
        with zero side effects — otherwise.
        """
        for spec in self._specs:
            if spec.kind != kind or not spec.matches(rid, bucket, tick):
                continue
            if spec.count is not None:
                spec.count -= 1
            self.fired.append((kind, rid, tick))
            return True
        return False


REGISTRY = FaultRegistry()


@contextlib.contextmanager
def injected(*specs: FaultSpec):
    """Context manager: install ``specs``, always reset on exit.

    The reset is unconditional (the registry is process-wide state), so
    a failing test can never leak faults into its neighbours.
    """
    for s in specs:
        REGISTRY.inject(s)
    try:
        yield REGISTRY
    finally:
        REGISTRY.reset()
