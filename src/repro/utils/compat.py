"""Version-compatibility shims for JAX APIs that moved across releases.

``jax.sharding.AxisType`` (and the ``axis_types=`` kwarg of
``jax.make_mesh``) only exists in newer JAX releases; older ones create
meshes with implicitly-auto axes and reject the kwarg.  Everything in the
repo that builds a mesh goes through :func:`make_mesh` so the version probe
lives in exactly one place.
"""
from __future__ import annotations

from typing import Sequence

import jax


def mesh_axis_types_kwargs(num_axes: int) -> dict:
    """``{"axis_types": (Auto,) * num_axes}`` when supported, else ``{}``."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * num_axes}


def make_mesh(shape: Sequence[int], axis_names: Sequence[str]):
    """``jax.make_mesh`` with explicitly-Auto axes where the API allows it."""
    shape = tuple(shape)
    axis_names = tuple(axis_names)
    return jax.make_mesh(
        shape, axis_names, **mesh_axis_types_kwargs(len(axis_names))
    )


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    """``jax.shard_map`` where it exists; the legacy experimental entry point
    (whose replication-check kwarg is spelled ``check_rep``) otherwise."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_vma=check_vma,
        )
    from jax.experimental.shard_map import shard_map as _legacy_shard_map

    return _legacy_shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
        check_rep=check_vma,
    )
