"""Small pytree utilities (no optax/flax in this environment)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def tree_count(tree) -> int:
    """Total number of scalar parameters in a pytree."""
    return sum(int(x.size) for x in jax.tree_util.tree_leaves(tree))


def tree_bytes(tree) -> int:
    """Total bytes of a pytree of arrays / ShapeDtypeStructs."""
    return sum(
        int(x.size) * jnp.dtype(x.dtype).itemsize
        for x in jax.tree_util.tree_leaves(tree)
    )


def tree_zeros_like(tree):
    return jax.tree_util.tree_map(jnp.zeros_like, tree)


def tree_global_norm(tree) -> jax.Array:
    """Global L2 norm over every leaf (computed in fp32)."""
    leaves = jax.tree_util.tree_leaves(tree)
    if not leaves:
        return jnp.zeros((), jnp.float32)
    sq = sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves)
    return jnp.sqrt(sq)


def tree_add(a, b):
    return jax.tree_util.tree_map(jnp.add, a, b)


def tree_scale(tree, s):
    return jax.tree_util.tree_map(lambda x: x * s, tree)
