"""Logical-axis sharding: names -> mesh axes (MaxText-style rules).

Params and activations are annotated with LOGICAL axis names at model-def
time; a Rules table maps them to physical mesh axes.  Defaults implement
FSDP over the data axes x tensor-parallel over "model" x expert-parallel
over "model", which is what the production dry-run uses.  The perf pass
swaps rule tables without touching model code.
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Dict, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class Rules:
    """logical axis name -> tuple of mesh axis names (or () = replicated)."""

    table: Tuple[Tuple[str, Tuple[str, ...]], ...]

    def lookup(self, name: Optional[str]) -> Tuple[str, ...]:
        if name is None:
            return ()
        for k, v in self.table:
            if k == name:
                return v
        return ()

    def spec(self, axes: Sequence[Optional[str]]) -> P:
        phys = []
        used = set()
        for ax in axes:
            mesh_axes = tuple(a for a in self.lookup(ax) if a not in used)
            used.update(mesh_axes)
            if len(mesh_axes) == 0:
                phys.append(None)
            elif len(mesh_axes) == 1:
                phys.append(mesh_axes[0])
            else:
                phys.append(mesh_axes)
        return P(*phys)


def default_rules(mesh_axis_names: Sequence[str]) -> Rules:
    """FSDP(data axes) x TP(model) x EP(model)."""
    fsdp = tuple(a for a in ("pod", "data") if a in mesh_axis_names)
    model = ("model",) if "model" in mesh_axis_names else ()
    table = (
        ("batch", fsdp),
        ("vocab", model),
        ("embed", fsdp),           # ZeRO-3 style param sharding
        ("embed_act", ()),         # activation d_model stays unsharded
        ("mlp", model),
        ("heads", model),
        ("kv_heads", ()),
        ("head_dim", ()),
        ("expert", model),
        ("expert_cap", fsdp),      # capacity dim shards over data axes (EP)
        ("expert_mlp", ()),
        ("layers", ()),
        ("seq", ()),
        ("kv_seq", ()),
        ("frames", ()),
        ("image", ()),
        ("q_lora", ()),
        ("kv_lora", ()),
        ("state", ()),
        ("conv", ()),
    )
    return Rules(table=table)


def replicated_rules(mesh_axis_names: Sequence[str]) -> Rules:
    """Everything replicated — single-host smoke tests."""
    return Rules(table=(("batch", ()),))


def batch_solve_rules(mesh_axis_names: Sequence[str]) -> Rules:
    """Rules for the sharded batched OT solver's 1-D problem mesh.

    One logical axis, ``problems``, mapped to the mesh's batch axis (see
    ``repro.core.distributed.BATCH_AXIS``); every other dimension of the
    solve (duals, snapshots, L-BFGS history) is per-problem state that
    lives under the problem axis and is never sharded further.
    """
    from repro.core.distributed import BATCH_AXIS

    batch = (BATCH_AXIS,) if BATCH_AXIS in mesh_axis_names else ()
    return Rules(table=(("problems", batch),))


def fit_spec(shape, spec: P, mesh_sizes: Dict[str, int]) -> P:
    """Drop mesh axes that do not evenly divide their array dimension.

    Explicit input shardings (and some constraints) require even tiling;
    e.g. 9 attention heads cannot shard over a 16-way 'model' axis — the
    fitted spec replicates that dim instead.  Axes are dropped from the
    right (the minor-most contribution) until the product divides.
    """
    out = []
    for dim, entry in zip(shape, tuple(spec) + (None,) * (len(shape) - len(spec))):
        if entry is None:
            out.append(None)
            continue
        axes = (entry,) if isinstance(entry, str) else tuple(entry)
        while axes:
            factor = 1
            for a in axes:
                factor *= mesh_sizes.get(a, 1)
            if factor and dim % factor == 0:
                break
            axes = axes[:-1]
        if not axes:
            out.append(None)
        elif len(axes) == 1:
            out.append(axes[0])
        else:
            out.append(axes)
    return P(*out)


_ctx = threading.local()


@contextlib.contextmanager
def use_rules(rules: Optional[Rules], mesh: Optional[Mesh] = None):
    """Install rules (and mesh sizes) so model-code ``constrain`` calls
    become sharding constraints."""
    prev = getattr(_ctx, "state", None)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape)) if mesh is not None else None
    _ctx.state = (rules, sizes)
    try:
        yield
    finally:
        _ctx.state = prev


def current_rules() -> Optional[Rules]:
    st = getattr(_ctx, "state", None)
    return st[0] if st else None


def data_shard_count() -> int:
    """Product of mesh-axis sizes the 'batch' logical axis maps to (1 if no
    rules context installed) — used by shard-local MoE dispatch."""
    st = getattr(_ctx, "state", None)
    if not st or st[0] is None or st[1] is None:
        return 1
    rules, sizes = st
    n = 1
    for a in rules.lookup("batch"):
        n *= sizes.get(a, 1)
    return n


def constrain(x: jax.Array, *axes: Optional[str]) -> jax.Array:
    """with_sharding_constraint on logical axes; no-op outside use_rules."""
    st = getattr(_ctx, "state", None)
    if not st or st[0] is None:
        return x
    rules, sizes = st
    spec = rules.spec(axes)
    if sizes:
        spec = fit_spec(x.shape, spec, sizes)
    return jax.lax.with_sharding_constraint(x, spec)


def spec_tree(logical_tree, rules: Rules):
    """Map a pytree of logical-axes tuples to PartitionSpecs."""
    return jax.tree_util.tree_map(
        lambda axes: rules.spec(axes),
        logical_tree,
        is_leaf=lambda x: isinstance(x, tuple)
        and all(isinstance(a, str) or a is None for a in x),
    )


def sharding_tree(logical_tree, rules: Rules, mesh: Mesh, shapes=None):
    """Logical axes -> NamedShardings; divisibility-fitted when shapes given."""
    specs = spec_tree(logical_tree, rules)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    if shapes is not None:
        specs = jax.tree_util.tree_map(
            lambda s, sp: fit_spec(tuple(s.shape), sp, sizes),
            shapes,
            specs,
            is_leaf=lambda x: isinstance(x, P),
        )
    return jax.tree_util.tree_map(
        lambda spec: NamedSharding(mesh, spec),
        specs,
        is_leaf=lambda x: isinstance(x, P),
    )
