from repro.sharding.partition import (
    Rules,
    constrain,
    current_rules,
    default_rules,
    replicated_rules,
    sharding_tree,
    spec_tree,
    use_rules,
)
