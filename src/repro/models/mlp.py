"""Feed-forward blocks: SwiGLU (llama family) and GELU (whisper)."""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.models.common import ParamMaker, swiglu
from repro.sharding.partition import constrain


def init_mlp(mk: ParamMaker, d_model: int, d_ff: int, act: str = "swiglu"):
    if act == "swiglu":
        mk("w_gate", (d_model, d_ff), ("embed", "mlp"))
        mk("w_up", (d_model, d_ff), ("embed", "mlp"))
    else:
        mk("w_in", (d_model, d_ff), ("embed", "mlp"))
        mk("b_in", (d_ff,), ("mlp",), init="zeros")
        mk("b_out", (d_model,), ("embed_act",), init="zeros")
    mk("w_down", (d_ff, d_model), ("mlp", "embed"))


def apply_mlp(params: Dict, x: jnp.ndarray, act: str = "swiglu") -> jnp.ndarray:
    dt = x.dtype
    if act == "swiglu":
        g = jnp.einsum("bsd,df->bsf", x, params["w_gate"].astype(dt))
        u = jnp.einsum("bsd,df->bsf", x, params["w_up"].astype(dt))
        h = swiglu(g, u)
    else:
        h = jax.nn.gelu(
            jnp.einsum("bsd,df->bsf", x, params["w_in"].astype(dt))
            + params["b_in"].astype(dt)
        )
    h = constrain(h, "batch", "seq", "mlp")
    out = jnp.einsum("bsf,fd->bsd", h, params["w_down"].astype(dt))
    if act != "swiglu":
        out = out + params["b_out"].astype(dt)
    return constrain(out, "batch", "seq", "embed_act")
