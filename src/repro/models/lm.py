"""Decoder-only LM assembly for all decoder families.

One module covers: dense GQA (yi, smollm), MoE (qwen2-moe, phi3.5-moe), MLA
(minicpm3), hybrid Mamba+attn+MoE (jamba), xLSTM, and the vision-cross-attn
variant (llama-3.2-vision).  Layers are stacked and driven by ``lax.scan``
(homogeneous stacks) or scan-over-periods with an unrolled in-period pattern
(hybrid/vlm/xlstm), keeping HLO size O(1) in depth — essential for compiling
100-layer x 512-device dry-runs on one CPU.

Three entry points per model (built by ``build_lm``):
  train_loss(params, batch)                  -> (loss, metrics)
  prefill(params, tokens, extras)            -> (logits_last, caches)
  decode_step(params, token, caches, index)  -> (logits, caches)
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.common import (
    ParamMaker,
    apply_norm,
    cross_entropy,
    init_norm,
    make_stack,
)
from repro.models.mlp import apply_mlp, init_mlp
from repro.sharding.partition import constrain

AUX_KEYS = ("moe_lb_loss", "moe_z_loss", "moe_dropped_frac")


def _zero_aux():
    return jnp.zeros((len(AUX_KEYS),), jnp.float32)


def _aux_vec(aux: Dict) -> jnp.ndarray:
    return jnp.stack([aux[k].astype(jnp.float32) for k in AUX_KEYS])


# ---------------------------------------------------------------------------
# per-family layer init/apply
# Each family defines:
#   init_block(sub_mk, cfg)              one scan step's params
#   apply_block(params, x, pos, cfg, cache, index) -> (x, new_cache, aux_vec)
#   block_cache(cfg, batch, max_len, dtype, abstract) per scan step
#   num_steps(cfg)  (scan length; layers per step for periodic families)


def _init_dense_block(mk: ParamMaker, cfg: ModelConfig):
    init_norm(mk, "norm_attn", cfg.d_model, cfg.norm)
    with mk.scope("attn"):
        if cfg.mla is not None:
            attn.init_mla(mk, cfg)
        else:
            attn.init_gqa(mk, cfg)
    init_norm(mk, "norm_ffn", cfg.d_model, cfg.norm)
    if cfg.moe is not None:
        with mk.scope("moe"):
            moe_mod.init_moe(mk, cfg)
    else:
        with mk.scope("mlp"):
            init_mlp(mk, cfg.d_model, cfg.d_ff, cfg.act)


def _apply_dense_block(params, x, pos, cfg: ModelConfig, cache, index):
    h = apply_norm(params["norm_attn"], x, cfg.norm, cfg.rms_eps)
    if cfg.mla is not None:
        y, cache = attn.apply_mla(params["attn"], h, pos, cfg, cache, index)
    else:
        y, cache = attn.apply_gqa(params["attn"], h, pos, cfg, cache, index)
    x = x + y
    h = apply_norm(params["norm_ffn"], x, cfg.norm, cfg.rms_eps)
    if cfg.moe is not None:
        y, aux = moe_mod.apply_moe(params["moe"], h, cfg)
        aux_vec = _aux_vec(aux)
    else:
        y = apply_mlp(params["mlp"], h, cfg.act)
        aux_vec = _zero_aux()
    return x + y, cache, aux_vec


def _dense_cache(cfg, batch, max_len, dtype, abstract):
    fn = attn.mla_cache_struct if cfg.mla is not None else attn.cache_struct
    mk_fn = attn.mla_make_cache if cfg.mla is not None else attn.make_cache
    return (fn if abstract else mk_fn)(cfg, batch, max_len, dtype)


def _dense_cache_axes(cfg):
    return (
        attn.mla_cache_logical_axes() if cfg.mla is not None
        else attn.cache_logical_axes(cfg)
    )


# hybrid (jamba): period of `attn_period` layers, attention at the middle
# slot, the rest mamba; FFN alternates dense / MoE per in-period parity.


def _hybrid_layout(cfg: ModelConfig):
    period = cfg.attn_period
    n_periods = cfg.num_layers // period
    attn_slot = period // 2
    moe_slots = tuple(i for i in range(period) if i % 2 == 1)
    mlp_slots = tuple(i for i in range(period) if i % 2 == 0)
    return period, n_periods, attn_slot, moe_slots, mlp_slots


def _init_hybrid_block(mk: ParamMaker, cfg: ModelConfig):
    period, _, attn_slot, moe_slots, mlp_slots = _hybrid_layout(cfg)
    with mk.scope("attn"):
        attn.init_gqa(mk, cfg)
    make_stack(mk, "mamba", period - 1, lambda m: ssm_mod.init_mamba(m, cfg))
    make_stack(mk, "moe", len(moe_slots), lambda m: moe_mod.init_moe(m, cfg))
    make_stack(
        mk, "mlp", len(mlp_slots),
        lambda m: init_mlp(m, cfg.d_model, cfg.d_ff, cfg.act),
    )
    for i in range(period):
        init_norm(mk, f"norm_mix_{i}", cfg.d_model, cfg.norm)
        init_norm(mk, f"norm_ffn_{i}", cfg.d_model, cfg.norm)


def _apply_hybrid_block(params, x, pos, cfg: ModelConfig, cache, index):
    period, _, attn_slot, moe_slots, mlp_slots = _hybrid_layout(cfg)
    take = lambda tree, i: jax.tree_util.tree_map(lambda v: v[i], tree)
    aux_total = _zero_aux()
    new_cache = {"attn": None, "mamba": []}
    mamba_i = 0
    for i in range(period):
        h = apply_norm(params[f"norm_mix_{i}"], x, cfg.norm, cfg.rms_eps)
        if i == attn_slot:
            y, ac = attn.apply_gqa(
                params["attn"], h, pos, cfg,
                None if cache is None else cache["attn"], index,
            )
            new_cache["attn"] = ac
        else:
            st = None if cache is None else take(cache["mamba"], mamba_i)
            y, st = ssm_mod.apply_mamba(take(params["mamba"], mamba_i), h, cfg, st)
            new_cache["mamba"].append(st)
            mamba_i += 1
        x = x + y
        h = apply_norm(params[f"norm_ffn_{i}"], x, cfg.norm, cfg.rms_eps)
        if i in moe_slots:
            y, aux = moe_mod.apply_moe(take(params["moe"], moe_slots.index(i)), h, cfg)
            aux_total = aux_total + _aux_vec(aux)
        else:
            y = apply_mlp(take(params["mlp"], mlp_slots.index(i)), h, cfg.act)
        x = x + y
    if cache is None:
        cache_out = None
    else:
        cache_out = {
            "attn": new_cache["attn"],
            "mamba": jax.tree_util.tree_map(
                lambda *xs: jnp.stack(xs), *new_cache["mamba"]
            ),
        }
    return x, cache_out, aux_total


def _hybrid_cache(cfg, batch, max_len, dtype, abstract):
    period = cfg.attn_period
    ac = (attn.cache_struct if abstract else attn.make_cache)(cfg, batch, max_len, dtype)
    if abstract:
        st0 = ssm_mod.mamba_state_struct(cfg, batch, dtype)
        ms = jax.tree_util.tree_map(
            lambda s: jax.ShapeDtypeStruct((period - 1,) + tuple(s.shape), s.dtype), st0
        )
    else:
        st0 = ssm_mod.mamba_make_state(cfg, batch, dtype)
        ms = jax.tree_util.tree_map(
            lambda s: jnp.broadcast_to(s, (period - 1,) + s.shape).copy(), st0
        )
    return {"attn": ac, "mamba": ms}


def _hybrid_cache_axes(cfg):
    return {
        "attn": attn.cache_logical_axes(cfg),
        "mamba": jax.tree_util.tree_map(
            lambda a: (None,) + a,
            ssm_mod.mamba_state_logical_axes(),
            is_leaf=lambda x: isinstance(x, tuple),
        ),
    }


# xlstm: period of `slstm_every` blocks: slot 0 sLSTM, rest mLSTM.


def _init_xlstm_block(mk: ParamMaker, cfg: ModelConfig):
    period = cfg.ssm.slstm_every
    init_norm(mk, "norm_s", cfg.d_model, cfg.norm)
    with mk.scope("slstm"):
        ssm_mod.init_slstm(mk, cfg)
    make_stack(mk, "mlstm", period - 1, lambda m: ssm_mod.init_mlstm(m, cfg))
    for i in range(period - 1):
        init_norm(mk, f"norm_m_{i}", cfg.d_model, cfg.norm)


def _apply_xlstm_block(params, x, pos, cfg: ModelConfig, cache, index):
    period = cfg.ssm.slstm_every
    take = lambda tree, i: jax.tree_util.tree_map(lambda v: v[i], tree)
    h = apply_norm(params["norm_s"], x, cfg.norm, cfg.rms_eps)
    st = None if cache is None else cache["slstm"]
    y, st = ssm_mod.apply_slstm(params["slstm"], h, cfg, st)
    x = x + y
    new_m = []
    for i in range(period - 1):
        h = apply_norm(params[f"norm_m_{i}"], x, cfg.norm, cfg.rms_eps)
        mst = None if cache is None else take(cache["mlstm"], i)
        y, mst = ssm_mod.apply_mlstm(take(params["mlstm"], i), h, cfg, mst)
        new_m.append(mst)
        x = x + y
    if cache is None:
        cache_out = None
    else:
        cache_out = {
            "slstm": st,
            "mlstm": jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *new_m),
        }
    return x, cache_out, _zero_aux()


def _xlstm_cache(cfg, batch, max_len, dtype, abstract):
    period = cfg.ssm.slstm_every
    if abstract:
        s = ssm_mod.slstm_state_struct(cfg, batch)
        m0 = ssm_mod.mlstm_state_struct(cfg, batch, dtype)
        m = jax.tree_util.tree_map(
            lambda t: jax.ShapeDtypeStruct((period - 1,) + tuple(t.shape), t.dtype), m0
        )
    else:
        s = ssm_mod.slstm_make_state(cfg, batch)
        m0 = ssm_mod.mlstm_make_state(cfg, batch, dtype)
        m = jax.tree_util.tree_map(
            lambda t: jnp.broadcast_to(t, (period - 1,) + t.shape).copy(), m0
        )
    return {"slstm": s, "mlstm": m}


def _xlstm_cache_axes(cfg):
    return {
        "slstm": ssm_mod.slstm_state_logical_axes(),
        "mlstm": jax.tree_util.tree_map(
            lambda a: (None,) + a,
            ssm_mod.mlstm_state_logical_axes(),
            is_leaf=lambda x: isinstance(x, tuple),
        ),
    }


# vlm: period of `cross_attn_period` layers: last slot cross-attends to the
# (stub-provided) image patch embeddings.


def _init_vlm_block(mk: ParamMaker, cfg: ModelConfig):
    period = cfg.cross_attn_period
    make_stack(mk, "self", period - 1, lambda m: _init_dense_block(m, dataclasses.replace(cfg, moe=None)))
    init_norm(mk, "norm_cross", cfg.d_model, cfg.norm)
    with mk.scope("cross"):
        attn.init_cross(mk, cfg)
    init_norm(mk, "norm_cross_ffn", cfg.d_model, cfg.norm)
    with mk.scope("cross_mlp"):
        init_mlp(mk, cfg.d_model, cfg.d_ff, cfg.act)
    mk("cross_gate", (1,), (None,), init="zeros")


def _apply_vlm_block(params, x, pos, cfg: ModelConfig, cache, index, memory=None):
    period = cfg.cross_attn_period
    take = lambda tree, i: jax.tree_util.tree_map(lambda v: v[i], tree)
    new_self = []
    for i in range(period - 1):
        c = None if cache is None else take(cache["self"], i)
        x, c, _ = _apply_dense_block(take(params["self"], i), x, pos, cfg, c, index)
        new_self.append(c)
    h = apply_norm(params["norm_cross"], x, cfg.norm, cfg.rms_eps)
    mem_kv = None if cache is None else cache.get("cross_kv")
    y, mem_kv = attn.apply_cross(params["cross"], h, memory, cfg, mem_kv)
    gate = jnp.tanh(params["cross_gate"].astype(x.dtype))
    x = x + gate * y
    h = apply_norm(params["norm_cross_ffn"], x, cfg.norm, cfg.rms_eps)
    x = x + apply_mlp(params["cross_mlp"], h, cfg.act)
    if cache is None:
        cache_out = None
    else:
        cache_out = {
            "self": jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *new_self),
            "cross_kv": mem_kv,
        }
    return x, cache_out, _zero_aux()


def _vlm_cache(cfg, batch, max_len, dtype, abstract):
    period = cfg.cross_attn_period
    c0 = (attn.cache_struct if abstract else attn.make_cache)(cfg, batch, max_len, dtype)
    if abstract:
        selfc = jax.tree_util.tree_map(
            lambda s: jax.ShapeDtypeStruct((period - 1,) + tuple(s.shape), s.dtype), c0
        )
        K, hd = cfg.num_kv_heads, cfg.resolved_head_dim
        kv = {
            "k": jax.ShapeDtypeStruct((batch, cfg.num_image_tokens, K, hd), dtype),
            "v": jax.ShapeDtypeStruct((batch, cfg.num_image_tokens, K, hd), dtype),
        }
    else:
        selfc = jax.tree_util.tree_map(
            lambda s: jnp.broadcast_to(s, (period - 1,) + s.shape).copy(), c0
        )
        K, hd = cfg.num_kv_heads, cfg.resolved_head_dim
        kv = {
            "k": jnp.zeros((batch, cfg.num_image_tokens, K, hd), dtype),
            "v": jnp.zeros((batch, cfg.num_image_tokens, K, hd), dtype),
        }
    return {"self": selfc, "cross_kv": kv}


def _vlm_cache_axes(cfg):
    ca = attn.cache_logical_axes(cfg)
    return {
        "self": jax.tree_util.tree_map(
            lambda a: (None,) + a, ca, is_leaf=lambda x: isinstance(x, tuple)
        ),
        "cross_kv": {
            "k": ("batch", "image", "kv_heads", "head_dim"),
            "v": ("batch", "image", "kv_heads", "head_dim"),
        },
    }


_FAMILIES = {
    "dense": (_init_dense_block, _apply_dense_block, _dense_cache, _dense_cache_axes),
    "moe": (_init_dense_block, _apply_dense_block, _dense_cache, _dense_cache_axes),
    "hybrid": (_init_hybrid_block, _apply_hybrid_block, _hybrid_cache, _hybrid_cache_axes),
    "ssm": (_init_xlstm_block, _apply_xlstm_block, _xlstm_cache, _xlstm_cache_axes),
    "vlm": (_init_vlm_block, _apply_vlm_block, _vlm_cache, _vlm_cache_axes),
}


def num_scan_steps(cfg: ModelConfig) -> int:
    if cfg.family == "hybrid":
        return cfg.num_layers // cfg.attn_period
    if cfg.family == "ssm":
        return cfg.num_layers // cfg.ssm.slstm_every
    if cfg.family == "vlm":
        return cfg.num_layers // cfg.cross_attn_period
    return cfg.num_layers


@dataclasses.dataclass(frozen=True)
class LM:
    cfg: ModelConfig

    # -- params -------------------------------------------------------------
    def init(self, rng: jax.Array, abstract: bool = False):
        cfg = self.cfg
        mk = ParamMaker(rng, cfg.param_dtype, abstract=abstract)
        mk("embed", (cfg.vocab_size, cfg.d_model), ("vocab", "embed"))
        init_block = _FAMILIES[cfg.family][0]
        make_stack(mk, "blocks", num_scan_steps(cfg), lambda m: init_block(m, cfg))
        init_norm(mk, "final_norm", cfg.d_model, cfg.norm)
        if not cfg.tie_embeddings:
            mk("head", (cfg.d_model, cfg.vocab_size), ("embed", "vocab"))
        return mk.collect()

    # -- shared backbone ----------------------------------------------------
    def _backbone(self, params, x, pos, caches, index, memory, remat: bool):
        cfg = self.cfg
        apply_block = _FAMILIES[cfg.family][1]

        if cfg.family == "vlm":
            block_fn = functools.partial(apply_block, memory=memory)
        else:
            block_fn = apply_block

        def body(carry, xs):
            x, aux = carry
            p, c = xs
            x, c, a = block_fn(p, x, pos, cfg, c, index)
            return (x, aux + a), c

        if remat:
            body = jax.checkpoint(body)

        if cfg.unroll_layers:
            take = lambda tree, i: jax.tree_util.tree_map(lambda v: v[i], tree)
            carry = (x, _zero_aux())
            outs = []
            for i in range(num_scan_steps(cfg)):
                c_i = None if caches is None else take(caches, i)
                carry, c_i = body(carry, (take(params["blocks"], i), c_i))
                outs.append(c_i)
            x, aux = carry
            new_caches = (
                None if caches is None
                else jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *outs)
            )
            return x, aux, new_caches

        (x, aux), new_caches = jax.lax.scan(
            body, (x, _zero_aux()), (params["blocks"], caches)
        )
        return x, aux, new_caches

    def _embed(self, params, tokens):
        cfg = self.cfg
        x = params["embed"].astype(jnp.dtype(cfg.compute_dtype))[tokens]
        return constrain(x, "batch", "seq", "embed_act")

    def _logits(self, params, x):
        cfg = self.cfg
        x = apply_norm(params["final_norm"], x, cfg.norm, cfg.rms_eps)
        w = (params["embed"].T if cfg.tie_embeddings else params["head"]).astype(x.dtype)
        logits = jnp.einsum("bsd,dv->bsv", x, w)
        return constrain(logits, "batch", "seq", "vocab")

    # -- entry points ---------------------------------------------------------
    def forward(self, params, tokens, memory=None, remat: bool = False):
        B, S = tokens.shape
        pos = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
        x = self._embed(params, tokens)
        caches = _none_caches(self.cfg)
        x, aux, _ = self._backbone(params, x, pos, caches, None, memory, remat)
        return self._logits(params, x), aux

    def train_loss(self, params, batch, z_loss: float = 0.0, remat: bool = True,
                   aux_weights: Tuple[float, float] = (0.01, 1e-3)):
        tokens = batch["tokens"]
        memory = batch.get("memory")
        if "labels" in batch:
            inputs, labels = tokens, batch["labels"]
        else:
            inputs, labels = tokens[:, :-1], tokens[:, 1:]
        logits, aux = self.forward(params, inputs, memory, remat)
        loss, ce = cross_entropy(logits, labels, z_loss)
        lb, zr, dropped = aux[0], aux[1], aux[2]
        total = loss + aux_weights[0] * lb + aux_weights[1] * zr
        metrics = {
            "ce": ce, "loss": total, "moe_lb": lb, "moe_dropped": dropped,
        }
        return total, metrics

    def init_cache(self, batch: int, max_len: int, abstract: bool = False):
        cfg = self.cfg
        cache_fn = _FAMILIES[cfg.family][2]
        dtype = jnp.dtype(cfg.compute_dtype)
        steps = num_scan_steps(cfg)
        one = cache_fn(cfg, batch, max_len, dtype, abstract)
        if abstract:
            return jax.tree_util.tree_map(
                lambda s: jax.ShapeDtypeStruct((steps,) + tuple(s.shape), s.dtype), one
            )
        return jax.tree_util.tree_map(
            lambda s: jnp.broadcast_to(s, (steps,) + s.shape).copy(), one
        )

    def cache_logical_axes(self):
        axes = _FAMILIES[self.cfg.family][3](self.cfg)
        return jax.tree_util.tree_map(
            lambda a: ("layers",) + a, axes, is_leaf=lambda x: isinstance(x, tuple)
        )

    def prefill(self, params, tokens, caches, memory=None):
        """Fill caches from position 0; returns (last-token logits, caches)."""
        B, S = tokens.shape
        pos = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
        x = self._embed(params, tokens)
        x, aux, caches = self._backbone(params, x, pos, caches, 0, memory, False)
        return self._logits(params, x[:, -1:, :]), caches

    def decode_step(self, params, token, caches, index, memory=None):
        """token (B, 1) at position `index` (scalar, or (B,) per-slot vector
        for continuous batching); returns (logits (B,1,V), caches)."""
        B = token.shape[0]
        index = jnp.asarray(index)
        if index.ndim == 1:
            pos = index[:, None].astype(jnp.int32)
        else:
            pos = jnp.broadcast_to(index[None, None], (B, 1)).astype(jnp.int32)
        x = self._embed(params, token)
        x, aux, caches = self._backbone(params, x, pos, caches, index, memory, False)
        return self._logits(params, x), caches


def _none_caches(cfg: ModelConfig):
    """A scan-compatible pytree of Nones (no cache) per step: just None —
    lax.scan accepts None leaves inside xs via a tuple of Nones trick."""
    return None


def build_lm(cfg: ModelConfig) -> LM:
    assert cfg.family in _FAMILIES, cfg.family
    return LM(cfg)
