"""Attention variants: GQA, MLA (multi-head latent), and cross-attention.

All functions are pure; params are dicts built by ParamMaker.  Decode paths
take a KV cache dict {k, v, index} updated with dynamic_update_slice (MLA
caches the compressed latent instead — its whole point).  Logical sharding
constraints are applied at the activation level; rules decide physical axes.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import MLAConfig, ModelConfig
from repro.models.common import (
    ParamMaker,
    apply_rotary,
    causal_mask,
    rmsnorm,
    rotary_cos_sin,
    softmax_fp32,
)
from repro.sharding.partition import constrain


# ---------------------------------------------------------------------------
# GQA


def init_gqa(mk: ParamMaker, cfg: ModelConfig):
    d, H, K, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    mk("wq", (d, H, hd), ("embed", "heads", "head_dim"))
    mk("wk", (d, K, hd), ("embed", "kv_heads", "head_dim"))
    mk("wv", (d, K, hd), ("embed", "kv_heads", "head_dim"))
    mk("wo", (H, hd, d), ("heads", "head_dim", "embed"))


def make_cache(cfg: ModelConfig, batch: int, max_len: int, dtype) -> Dict:
    K, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    if cfg.kv_quant:
        return {
            "k": jnp.zeros((batch, max_len, K, hd), jnp.int8),
            "v": jnp.zeros((batch, max_len, K, hd), jnp.int8),
            "k_scale": jnp.zeros((batch, max_len, K), jnp.float32),
            "v_scale": jnp.zeros((batch, max_len, K), jnp.float32),
        }
    return {
        "k": jnp.zeros((batch, max_len, K, hd), dtype),
        "v": jnp.zeros((batch, max_len, K, hd), dtype),
    }


def cache_struct(cfg: ModelConfig, batch: int, max_len: int, dtype) -> Dict:
    """ShapeDtypeStruct cache stand-in (dry-run serve_step input)."""
    K, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    sds = jax.ShapeDtypeStruct
    if cfg.kv_quant:
        return {
            "k": sds((batch, max_len, K, hd), jnp.int8),
            "v": sds((batch, max_len, K, hd), jnp.int8),
            "k_scale": sds((batch, max_len, K), jnp.float32),
            "v_scale": sds((batch, max_len, K), jnp.float32),
        }
    return {
        "k": sds((batch, max_len, K, hd), dtype),
        "v": sds((batch, max_len, K, hd), dtype),
    }


def cache_logical_axes(cfg: Optional[ModelConfig] = None) -> Dict:
    axes = {
        "k": ("batch", "kv_seq", "kv_heads", "head_dim"),
        "v": ("batch", "kv_seq", "kv_heads", "head_dim"),
    }
    if cfg is not None and cfg.kv_quant:
        axes["k_scale"] = ("batch", "kv_seq", "kv_heads")
        axes["v_scale"] = ("batch", "kv_seq", "kv_heads")
    return axes


def _q8_token(x: jnp.ndarray):
    """Per-(token, head) int8 quantization of (B, S, K, hd)."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]), -127, 127)
    return q.astype(jnp.int8), scale


def _dq8(q: jnp.ndarray, scale: jnp.ndarray, dtype):
    return (q.astype(jnp.float32) * scale[..., None]).astype(dtype)


def _gqa_scores_ctx(q, k, v, mask):
    """q (B,S,H,hd), k/v (B,T,K,hd) with H = K * G."""
    B, S, H, hd = q.shape
    K = k.shape[2]
    G = H // K
    q = q.reshape(B, S, K, G, hd)
    scores = jnp.einsum("bskgh,btkh->bkgst", q, k) / jnp.sqrt(hd).astype(jnp.float32)
    scores = scores.astype(jnp.float32) + mask  # mask broadcast (S, T)
    w = softmax_fp32(scores).astype(v.dtype)
    ctx = jnp.einsum("bkgst,btkh->bskgh", w, v)
    return ctx.reshape(B, S, H, hd)


def apply_gqa(
    params: Dict,
    x: jnp.ndarray,                     # (B, S, D)
    positions: jnp.ndarray,             # (B, S) int32
    cfg: ModelConfig,
    cache: Optional[Dict] = None,
    cache_index: Optional[jnp.ndarray] = None,
    causal: bool = True,
) -> Tuple[jnp.ndarray, Optional[Dict]]:
    dt = x.dtype
    hd = cfg.resolved_head_dim
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(dt))
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"].astype(dt))
    q = constrain(q, "batch", "seq", "heads", None)

    if cfg.use_rope:
        cos, sin = rotary_cos_sin(positions, hd, cfg.rope_theta)
        q = apply_rotary(q, cos, sin)
        k = apply_rotary(k, cos, sin)

    if cache is not None:
        idx = cache_index if cache_index is not None else 0
        idx = jnp.asarray(idx)
        quant = "k_scale" in cache
        if quant:
            k_q, k_s = _q8_token(k)
            v_q, v_s = _q8_token(v)
            writes = [("k", k_q), ("v", v_q), ("k_scale", k_s), ("v_scale", v_s)]
        else:
            writes = [
                ("k", k.astype(cache["k"].dtype)),
                ("v", v.astype(cache["v"].dtype)),
            ]
        new_cache = {}
        for name, val in writes:
            if idx.ndim == 1:
                # per-slot positions (continuous batching): vmap the update
                new_cache[name] = jax.vmap(
                    lambda c, nv, i: jax.lax.dynamic_update_slice_in_dim(c, nv, i, 0)
                )(cache[name], val, idx)
            else:
                new_cache[name] = jax.lax.dynamic_update_slice_in_dim(
                    cache[name], val, idx, 1
                )
        cache = new_cache
        if quant:
            k = _dq8(cache["k"], cache["k_scale"], dt)
            v = _dq8(cache["v"], cache["v_scale"], dt)
        else:
            k, v = cache["k"].astype(dt), cache["v"].astype(dt)
        T = k.shape[1]
        if causal:
            # valid keys: position <= query position (query at idx + s)
            if idx.ndim == 1:
                q_pos = idx[:, None, None] + jnp.arange(x.shape[1])[None, :, None]
                k_pos = jnp.arange(T)[None, None, :]
                # (B, S, T) -> broadcast over (kv, group) score dims later
                mask = jnp.where(k_pos <= q_pos, 0.0, -1e30).astype(jnp.float32)
                mask = mask[:, None, None, :, :]  # (B,1,1,S,T) for bkgst scores
            else:
                q_pos = idx + jnp.arange(x.shape[1])[:, None]
                k_pos = jnp.arange(T)[None, :]
                mask = jnp.where(k_pos <= q_pos, 0.0, -1e30).astype(jnp.float32)
        else:
            mask = jnp.zeros((x.shape[1], T), jnp.float32)
    else:
        mask = (
            causal_mask(x.shape[1], x.shape[1])
            if causal
            else jnp.zeros((x.shape[1], x.shape[1]), jnp.float32)
        )

    ctx = _gqa_scores_ctx(q, k, v, mask)
    out = jnp.einsum("bshk,hkd->bsd", ctx, params["wo"].astype(dt))
    return constrain(out, "batch", "seq", "embed_act"), cache


# ---------------------------------------------------------------------------
# Cross-attention (whisper decoder / vlm layers)


def init_cross(mk: ParamMaker, cfg: ModelConfig, kv_dim: Optional[int] = None):
    d, H, hd = cfg.d_model, cfg.num_heads, cfg.resolved_head_dim
    K = cfg.num_kv_heads
    kv_dim = kv_dim or d
    mk("wq", (d, H, hd), ("embed", "heads", "head_dim"))
    mk("wk", (kv_dim, K, hd), ("embed", "kv_heads", "head_dim"))
    mk("wv", (kv_dim, K, hd), ("embed", "kv_heads", "head_dim"))
    mk("wo", (H, hd, d), ("heads", "head_dim", "embed"))
    mk("q_norm", (d,), ("embed_act",), init="ones")


def apply_cross(
    params: Dict,
    x: jnp.ndarray,                # (B, S, D) queries
    memory: jnp.ndarray,           # (B, M, Dm) keys/values source
    cfg: ModelConfig,
    memory_kv: Optional[Dict] = None,   # precomputed {k, v} (decode fast path)
) -> Tuple[jnp.ndarray, Dict]:
    dt = x.dtype
    xq = rmsnorm(x, params["q_norm"], cfg.rms_eps)
    q = jnp.einsum("bsd,dhk->bshk", xq, params["wq"].astype(dt))
    if memory_kv is None:
        k = jnp.einsum("bmd,dhk->bmhk", memory, params["wk"].astype(dt))
        v = jnp.einsum("bmd,dhk->bmhk", memory, params["wv"].astype(dt))
        memory_kv = {"k": k, "v": v}
    k, v = memory_kv["k"].astype(dt), memory_kv["v"].astype(dt)
    mask = jnp.zeros((x.shape[1], k.shape[1]), jnp.float32)
    ctx = _gqa_scores_ctx(q, k, v, mask)
    out = jnp.einsum("bshk,hkd->bsd", ctx, params["wo"].astype(dt))
    return constrain(out, "batch", "seq", "embed_act"), memory_kv


# ---------------------------------------------------------------------------
# MLA (multi-head latent attention, minicpm3 / deepseek family)


def init_mla(mk: ParamMaker, cfg: ModelConfig):
    d, H = cfg.d_model, cfg.num_heads
    m: MLAConfig = cfg.mla
    qk = m.qk_nope_head_dim + m.qk_rope_head_dim
    mk("q_down", (d, m.q_lora_rank), ("embed", "q_lora"))
    mk("q_norm", (m.q_lora_rank,), ("q_lora",), init="ones")
    mk("q_up", (m.q_lora_rank, H, qk), ("q_lora", "heads", "head_dim"))
    mk("kv_down", (d, m.kv_lora_rank + m.qk_rope_head_dim), ("embed", "kv_lora"))
    mk("kv_norm", (m.kv_lora_rank,), ("kv_lora",), init="ones")
    mk(
        "kv_up",
        (m.kv_lora_rank, H, m.qk_nope_head_dim + m.v_head_dim),
        ("kv_lora", "heads", "head_dim"),
    )
    mk("wo", (H, m.v_head_dim, d), ("heads", "head_dim", "embed"))


def mla_cache_struct(cfg: ModelConfig, batch: int, max_len: int, dtype) -> Dict:
    m = cfg.mla
    sds = jax.ShapeDtypeStruct
    return {
        "latent": sds((batch, max_len, m.kv_lora_rank), dtype),
        "k_rope": sds((batch, max_len, m.qk_rope_head_dim), dtype),
    }


def mla_make_cache(cfg: ModelConfig, batch: int, max_len: int, dtype) -> Dict:
    m = cfg.mla
    return {
        "latent": jnp.zeros((batch, max_len, m.kv_lora_rank), dtype),
        "k_rope": jnp.zeros((batch, max_len, m.qk_rope_head_dim), dtype),
    }


def mla_cache_logical_axes() -> Dict:
    return {
        "latent": ("batch", "kv_seq", "kv_lora"),
        "k_rope": ("batch", "kv_seq", None),
    }


def apply_mla(
    params: Dict,
    x: jnp.ndarray,
    positions: jnp.ndarray,
    cfg: ModelConfig,
    cache: Optional[Dict] = None,
    cache_index: Optional[jnp.ndarray] = None,
) -> Tuple[jnp.ndarray, Optional[Dict]]:
    """MLA with the 'absorbed' decode path: attention runs in latent space.

    Train/prefill: expand latent -> per-head K/V (matmul-heavy, MXU-friendly).
    Decode: absorb kv_up into q and out (scores = q_nope' . latent), so the
    per-step cost is O(T * kv_lora_rank) instead of O(T * H * head_dim).
    """
    dt = x.dtype
    m: MLAConfig = cfg.mla
    H = cfg.num_heads
    B, S, _ = x.shape

    ql = rmsnorm(jnp.einsum("bsd,dr->bsr", x, params["q_down"].astype(dt)),
                 params["q_norm"], cfg.rms_eps)
    q = jnp.einsum("bsr,rhk->bshk", ql, params["q_up"].astype(dt))
    q_nope = q[..., : m.qk_nope_head_dim]
    q_rope = q[..., m.qk_nope_head_dim:]

    kv = jnp.einsum("bsd,dr->bsr", x, params["kv_down"].astype(dt))
    latent = rmsnorm(kv[..., : m.kv_lora_rank], params["kv_norm"], cfg.rms_eps)
    k_rope = kv[..., m.kv_lora_rank:]

    cos, sin = rotary_cos_sin(positions, m.qk_rope_head_dim, cfg.rope_theta)
    q_rope = apply_rotary(q_rope, cos, sin)
    k_rope = apply_rotary(k_rope[:, :, None, :], cos, sin)[:, :, 0, :]

    if cache is not None:
        idx = jnp.asarray(cache_index if cache_index is not None else 0)
        if idx.ndim == 1:
            upd = jax.vmap(
                lambda c, new, i: jax.lax.dynamic_update_slice_in_dim(c, new, i, 0)
            )
            cl = upd(cache["latent"], latent.astype(cache["latent"].dtype), idx)
            cr = upd(cache["k_rope"], k_rope.astype(cache["k_rope"].dtype), idx)
        else:
            cl = jax.lax.dynamic_update_slice_in_dim(
                cache["latent"], latent.astype(cache["latent"].dtype), idx, 1)
            cr = jax.lax.dynamic_update_slice_in_dim(
                cache["k_rope"], k_rope.astype(cache["k_rope"].dtype), idx, 1)
        cache = {"latent": cl, "k_rope": cr}
        latent_all, k_rope_all = cl.astype(dt), cr.astype(dt)
        T = latent_all.shape[1]
        if idx.ndim == 1:
            q_pos = idx[:, None, None] + jnp.arange(S)[None, :, None]
            mask = jnp.where(jnp.arange(T)[None, None, :] <= q_pos, 0.0, -1e30)
            mask = mask[:, None]          # (B,1,S,T) for bhst scores
        else:
            q_pos = idx + jnp.arange(S)[:, None]
            mask = jnp.where(jnp.arange(T)[None, :] <= q_pos, 0.0, -1e30)
            mask = mask[None, None]       # (1,1,S,T)

        # absorbed scores: q_nope' = q_nope @ kv_up[..., :nope]  (per head)
        kv_up_k = params["kv_up"].astype(dt)[..., : m.qk_nope_head_dim]
        q_lat = jnp.einsum("bshk,rhk->bshr", q_nope, kv_up_k)
        scores = (
            jnp.einsum("bshr,btr->bhst", q_lat, latent_all)
            + jnp.einsum("bshk,btk->bhst", q_rope, k_rope_all)
        ).astype(jnp.float32)
        scale = 1.0 / jnp.sqrt(m.qk_nope_head_dim + m.qk_rope_head_dim)
        w = softmax_fp32(scores * scale + mask).astype(dt)
        ctx_lat = jnp.einsum("bhst,btr->bshr", w, latent_all)
        kv_up_v = params["kv_up"].astype(dt)[..., m.qk_nope_head_dim:]
        ctx = jnp.einsum("bshr,rhv->bshv", ctx_lat, kv_up_v)
    else:
        # train/prefill: expand latent to per-head K/V
        kvu = jnp.einsum("bsr,rhk->bshk", latent, params["kv_up"].astype(dt))
        k_nope = kvu[..., : m.qk_nope_head_dim]
        v = kvu[..., m.qk_nope_head_dim:]
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope[:, :, None, :], (B, S, H, m.qk_rope_head_dim))],
            axis=-1,
        )
        qf = jnp.concatenate([q_nope, q_rope], axis=-1)
        scale = 1.0 / jnp.sqrt(m.qk_nope_head_dim + m.qk_rope_head_dim)
        scores = jnp.einsum("bshk,bthk->bhst", qf, k).astype(jnp.float32) * scale
        scores = scores + causal_mask(S, S)[None, None]
        w = softmax_fp32(scores).astype(dt)
        ctx = jnp.einsum("bhst,bthv->bshv", w, v)

    out = jnp.einsum("bshv,hvd->bsd", ctx, params["wo"].astype(dt))
    return constrain(out, "batch", "seq", "embed_act"), cache
