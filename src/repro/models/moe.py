"""Mixture-of-Experts with sort-based (megablox-style) dispatch.

Instead of GShard's one-hot dispatch einsums — which inflate HLO FLOPs by
O(seq) and would poison the roofline's MODEL_FLOPS/HLO_FLOPS ratio — tokens
are argsorted by expert id, packed into per-expert capacity buffers with a
scatter (memory-bound, ~0 FLOPs), processed with one batched einsum per
weight, and combined with a scatter-add.  Capacity overflow drops tokens
(standard), counted in aux stats.

Expert weights are sharded over the "expert" logical axis (EP on the mesh's
"model" axis); the scatter from token space (batch-sharded) into expert
space lowers to the expected all-to-all.

Beyond-paper hook: ``ot_balance`` routes via the screened group-sparse OT
solver (tokens -> experts, classes = top-1 expert choice), using the paper's
algorithm inside the model itself; see training/ot_routing.py.
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import ParamMaker, swiglu
from repro.sharding.partition import constrain


def init_moe(mk: ParamMaker, cfg: ModelConfig):
    d = cfg.d_model
    m = cfg.moe
    E, ff = m.num_experts, m.expert_d_ff or cfg.d_ff
    mk("router", (d, E), ("embed", "expert"))
    mk("w_gate", (E, d, ff), ("expert", "embed", "expert_mlp"))
    mk("w_up", (E, d, ff), ("expert", "embed", "expert_mlp"))
    mk("w_down", (E, ff, d), ("expert", "expert_mlp", "embed"))
    if m.num_shared_experts:
        sff = m.shared_d_ff or m.num_shared_experts * ff
        mk("shared_gate", (d, sff), ("embed", "mlp"))
        mk("shared_up", (d, sff), ("embed", "mlp"))
        mk("shared_down", (sff, d), ("mlp", "embed"))
        mk("shared_gate_proj", (d, 1), ("embed", None))


def capacity(cfg: ModelConfig, tokens: int) -> int:
    m = cfg.moe
    cap = int(math.ceil(tokens * m.top_k / m.num_experts * m.capacity_factor))
    return max(8, -(-cap // 8) * 8)  # align to 8 for TPU-friendly shapes


def apply_moe(params: Dict, x: jnp.ndarray, cfg: ModelConfig) -> Tuple[jnp.ndarray, Dict]:
    """Returns (output (B,S,D), aux dict with losses/stats)."""
    dt = x.dtype
    m = cfg.moe
    B, S, d = x.shape
    T = B * S
    E, k = m.num_experts, m.top_k
    xt = x.reshape(T, d)

    logits = jnp.einsum("td,de->te", xt, params["router"].astype(dt)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    if m.ot_balance:
        # beyond-paper: balanced, sequence-local assignment via the screened
        # group-sparse OT solver (training/ot_routing.py)
        from repro.training.ot_routing import ot_route

        topi, topw = ot_route(
            logits, num_seqs=B, seq_len=S, top_k=k,
            gamma=m.ot_gamma, rho=m.ot_rho,
        )
        topw = topw.astype(jnp.float32)
    else:
        topw, topi = jax.lax.top_k(probs, k)                 # (T, k)
        topw = topw / jnp.sum(topw, axis=-1, keepdims=True)

    eid = topi.reshape(-1)                                   # (T*k,)
    wgt = topw.reshape(-1).astype(dt)

    from repro.sharding.partition import data_shard_count

    D = data_shard_count()
    if m.local_dispatch and D > 1 and T % D == 0:
        out, counts, keep_frac = _dispatch_local(
            params, xt, eid.reshape(T, k), wgt.reshape(T, k), cfg, D
        )
        dropped = 1.0 - keep_frac
    else:
        out, counts, dropped = _dispatch_global(params, xt, eid, wgt, cfg)

    if m.num_shared_experts:
        sg = jnp.einsum("td,df->tf", xt, params["shared_gate"].astype(dt))
        su = jnp.einsum("td,df->tf", xt, params["shared_up"].astype(dt))
        sy = jnp.einsum("tf,fd->td", swiglu(sg, su), params["shared_down"].astype(dt))
        gate = jax.nn.sigmoid(
            jnp.einsum("td,do->to", xt, params["shared_gate_proj"].astype(dt))
        )
        out = out + gate * sy

    # aux: switch-style load-balance + router z-loss
    frac = counts.astype(jnp.float32) / jnp.maximum(jnp.sum(counts), 1)
    pmean = jnp.mean(probs, axis=0)
    lb_loss = E * jnp.sum(frac * pmean)
    z_loss = jnp.mean(jnp.square(jax.scipy.special.logsumexp(logits, axis=-1)))
    aux = {
        "moe_lb_loss": lb_loss,
        "moe_z_loss": z_loss,
        "moe_dropped_frac": jnp.asarray(dropped, jnp.float32),
    }
    return out.reshape(B, S, d), aux


def _expert_ffn(params, h, dt):
    """Batched per-expert SwiGLU on capacity buffers h (E, C, d)."""
    g = jnp.einsum("ecd,edf->ecf", h, params["w_gate"].astype(dt))
    u = jnp.einsum("ecd,edf->ecf", h, params["w_up"].astype(dt))
    return jnp.einsum("ecf,efd->ecd", swiglu(g, u), params["w_down"].astype(dt))


def _dispatch_global(params, xt, eid, wgt, cfg: ModelConfig):
    """Global sort-based dispatch (baseline).

    Under GSPMD the global scatter into the expert/capacity buffer combines
    partial buffers with a full-size all-reduce across the data shards —
    correct but collective-heavy (see EXPERIMENTS.md §Perf iteration log);
    ``local_dispatch`` removes it."""
    dt = xt.dtype
    m = cfg.moe
    T, d = xt.shape
    E, k = m.num_experts, m.top_k
    tok = jnp.repeat(jnp.arange(T), k)

    order = jnp.argsort(eid)                                 # stable
    eid_s, tok_s, wgt_s = eid[order], tok[order], wgt[order]

    counts = jnp.zeros((E,), jnp.int32).at[eid_s].add(1)
    start = jnp.cumsum(counts) - counts
    pos = jnp.arange(T * k) - start[eid_s]
    cap = capacity(cfg, T)
    keep = pos < cap
    dest = jnp.where(keep, eid_s * cap + pos, E * cap)       # overflow slot

    buf = jnp.zeros((E * cap + 1, d), dt).at[dest].set(xt[tok_s])
    h = constrain(
        buf[: E * cap].reshape(E, cap, d), "expert", "expert_cap", "embed_act"
    )
    y = _expert_ffn(params, h, dt)
    y = constrain(y, "expert", "expert_cap", "embed_act")

    y_flat = jnp.concatenate([y.reshape(E * cap, d), jnp.zeros((1, d), dt)])
    y_tok = y_flat[dest] * wgt_s[:, None]                    # overflow -> 0
    out = jnp.zeros((T, d), dt).at[tok_s].add(y_tok)
    dropped = jnp.sum(~keep) / (T * k)
    return out, counts, dropped


def _dispatch_local(params, xt, topi, topw, cfg: ModelConfig, D: int):
    """Shard-local dispatch: tokens are packed into PER-DATA-SHARD capacity
    slots, so the scatter/gather never crosses the data axes; tokens only
    meet expert weights across the "model" axis inside the expert einsum.

    Structure: reshape tokens (T, d) -> (D, T/D, d) with dim0 pinned to the
    data axes; vmap the sort/pack/combine over dim0 (slice-local ops);
    capacity buffers carry an explicit shard dim merged into the einsum's
    capacity axis.  Eliminates the (E*cap, d) all-reduce of the global
    scatter (§Perf iteration: jamba/qwen/phi train cells)."""
    dt = xt.dtype
    m = cfg.moe
    T, d = xt.shape
    E, k = m.num_experts, m.top_k
    Tl = T // D
    cap_l = capacity(cfg, Tl)

    xs = constrain(xt.reshape(D, Tl, d), "batch", None, "embed_act")
    eid = topi.reshape(D, Tl * k)
    wgt = topw.reshape(D, Tl * k).astype(dt)

    def pack(x_l, eid_l, wgt_l):
        tok = jnp.repeat(jnp.arange(Tl), k)
        order = jnp.argsort(eid_l)
        eid_s, tok_s, wgt_s = eid_l[order], tok[order], wgt_l[order]
        counts = jnp.zeros((E,), jnp.int32).at[eid_s].add(1)
        start = jnp.cumsum(counts) - counts
        pos = jnp.arange(Tl * k) - start[eid_s]
        keep = pos < cap_l
        dest = jnp.where(keep, eid_s * cap_l + pos, E * cap_l)
        buf = jnp.zeros((E * cap_l + 1, d), dt).at[dest].set(x_l[tok_s])
        return buf[: E * cap_l].reshape(E, cap_l, d), (dest, tok_s, wgt_s, counts, keep)

    h, (dest, tok_s, wgt_s, counts, keep) = jax.vmap(pack)(xs, eid, wgt)
    # (D, E, cap_l, d) -> (E, D*cap_l, d): capacity axis carries the shard dim
    h = constrain(h, "batch", None, None, "embed_act")
    h = h.transpose(1, 0, 2, 3).reshape(E, D * cap_l, d)
    h = constrain(h, "expert", "expert_cap", "embed_act")

    y = _expert_ffn(params, h, dt)
    y = constrain(y, "expert", "expert_cap", "embed_act")

    y = y.reshape(E, D, cap_l, d).transpose(1, 0, 2, 3)      # (D, E, cap_l, d)
    y = constrain(y, "batch", None, None, "embed_act")

    def combine(y_l, dest_l, tok_l, wgt_l):
        y_flat = jnp.concatenate([y_l.reshape(E * cap_l, d), jnp.zeros((1, d), dt)])
        y_tok = y_flat[dest_l] * wgt_l[:, None]
        return jnp.zeros((Tl, d), dt).at[tok_l].add(y_tok)

    out = jax.vmap(combine)(y, dest, tok_s, wgt_s)           # (D, Tl, d)
    out = constrain(out, "batch", None, "embed_act").reshape(T, d)
    keep_frac = jnp.mean(keep.astype(jnp.float32))
    return out, jnp.sum(counts, axis=0), keep_frac
