"""Model zoo: one builder per architecture family."""
from repro.configs.base import ModelConfig
from repro.models.lm import build_lm
from repro.models.encdec import build_encdec


def build_model(cfg: ModelConfig):
    if cfg.family == "encdec":
        return build_encdec(cfg)
    return build_lm(cfg)
