"""Model-definition substrate: param construction, norms, rotary, masking.

Params are nested dicts of arrays.  ``ParamMaker`` builds them while
recording each leaf's LOGICAL sharding axes (see sharding/partition.py);
in abstract mode it produces ShapeDtypeStructs instead of arrays, which is
how the multi-pod dry-run materializes 398B-parameter trees without
allocating a byte.
"""
from __future__ import annotations

import contextlib
from typing import Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np


class ParamMaker:
    """Builds a param tree + parallel logical-axes tree.

    maker = ParamMaker(rng, dtype="bfloat16", abstract=True)
    with maker.scope("layer0"):
        w = maker("wq", (d, h), ("embed", "heads"))
    params, axes = maker.collect()
    """

    def __init__(self, rng: jax.Array, dtype: str, abstract: bool = False):
        self._rng = rng
        self.dtype = jnp.dtype(dtype)
        self.abstract = abstract
        self.params: Dict = {}
        self.axes: Dict = {}
        self._path: Tuple[str, ...] = ()

    @contextlib.contextmanager
    def scope(self, name: str):
        self._path = self._path + (name,)
        try:
            yield self
        finally:
            self._path = self._path[:-1]

    def _insert(self, tree, name, value):
        node = tree
        for part in self._path:
            node = node.setdefault(part, {})
        assert name not in node, f"duplicate param {self._path + (name,)}"
        node[name] = value

    def next_rng(self) -> jax.Array:
        self._rng, sub = jax.random.split(self._rng)
        return sub

    def __call__(
        self,
        name: str,
        shape: Sequence[int],
        axes: Sequence[Optional[str]],
        init: str = "normal",
        scale: float = 0.02,
    ) -> jax.Array:
        assert len(shape) == len(axes), (name, shape, axes)
        if self.abstract:
            value = jax.ShapeDtypeStruct(tuple(shape), self.dtype)
        elif init == "zeros":
            value = jnp.zeros(shape, self.dtype)
        elif init == "ones":
            value = jnp.ones(shape, self.dtype)
        elif init == "normal":
            value = (
                jax.random.normal(self.next_rng(), shape, jnp.float32) * scale
            ).astype(self.dtype)
        elif init == "slog":  # mamba A_log init: log(1..d_state)
            value = jnp.broadcast_to(
                jnp.log(jnp.arange(1, shape[-1] + 1, dtype=jnp.float32)), shape
            ).astype(self.dtype)
        else:
            raise ValueError(init)
        self._insert(self.params, name, value)
        self._insert(self.axes, name, tuple(axes))
        return value

    def collect(self):
        return self.params, self.axes


def _is_axes(x):
    return isinstance(x, tuple) and all(isinstance(i, str) or i is None for i in x)


def make_stack(mk: ParamMaker, name: str, n: int, init_one) -> None:
    """Build n stacked copies of a sub-module along a leading 'layers' axis.

    init_one(sub_maker) populates one layer's params.  In abstract mode a
    single layer is built and stacked by metadata (no allocation) — this is
    how 100-layer x multi-billion-param trees stay free in the dry-run.
    """
    if mk.abstract:
        sub = ParamMaker(jax.random.PRNGKey(0), str(mk.dtype), abstract=True)
        init_one(sub)
        p0, a0 = sub.collect()
        params = jax.tree_util.tree_map(
            lambda s: jax.ShapeDtypeStruct((n,) + tuple(s.shape), s.dtype), p0
        )
    else:
        outs = []
        for _ in range(n):
            sub = ParamMaker(mk.next_rng(), str(mk.dtype), abstract=False)
            init_one(sub)
            outs.append(sub.collect())
        p0, a0 = outs[0]
        params = jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs), *[p for p, _ in outs]
        )
    axes = jax.tree_util.tree_map(
        lambda a: ("layers",) + a, a0, is_leaf=_is_axes
    )
    mk._insert(mk.params, name, params)
    mk._insert(mk.axes, name, axes)


# ---------------------------------------------------------------------------
# numerics


def init_norm(mk: "ParamMaker", name: str, d: int, kind: str = "rmsnorm"):
    with mk.scope(name):
        mk("scale", (d,), ("embed_act",), init="ones")
        if kind == "layernorm":
            mk("bias", (d,), ("embed_act",), init="zeros")


def apply_norm(params: Dict, x: jnp.ndarray, kind: str = "rmsnorm", eps: float = 1e-5):
    if kind == "layernorm":
        return layernorm(x, params["scale"], params["bias"], eps)
    return rmsnorm(x, params["scale"], eps)


def rmsnorm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * scale.astype(jnp.float32)).astype(dt)


def layernorm(x, scale, bias, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)


def rotary_cos_sin(positions: jnp.ndarray, dim: int, theta: float) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """positions (..., S) -> cos/sin (..., S, dim/2), fp32."""
    inv = 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))
    ang = positions.astype(jnp.float32)[..., None] * inv
    return jnp.cos(ang), jnp.sin(ang)


def apply_rotary(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray) -> jnp.ndarray:
    """x (..., S, H, D) with cos/sin (..., S, D/2) broadcast over heads."""
    dt = x.dtype
    x = x.astype(jnp.float32)
    x1, x2 = jnp.split(x, 2, axis=-1)
    c = cos[..., None, :]
    s = sin[..., None, :]
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1).astype(dt)


def causal_mask(q_len: int, kv_len: int, dtype=jnp.float32) -> jnp.ndarray:
    """(q_len, kv_len) additive mask; queries are the LAST q_len positions."""
    q_pos = jnp.arange(q_len)[:, None] + (kv_len - q_len)
    k_pos = jnp.arange(kv_len)[None, :]
    return jnp.where(k_pos <= q_pos, 0.0, -1e30).astype(dtype)


def swiglu(x_gate: jnp.ndarray, x_up: jnp.ndarray) -> jnp.ndarray:
    return jax.nn.silu(x_gate) * x_up


def softmax_fp32(logits: jnp.ndarray, axis: int = -1) -> jnp.ndarray:
    return jax.nn.softmax(logits.astype(jnp.float32), axis=axis)


def cross_entropy(
    logits: jnp.ndarray, labels: jnp.ndarray, z_loss: float = 0.0
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Mean CE over all positions (+ optional z-loss); logits (..., V)."""
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    ce = jnp.mean(lse - ll)
    zl = z_loss * jnp.mean(jnp.square(lse)) if z_loss else 0.0
    return ce + zl, ce


def count_params(tree) -> int:
    return sum(int(np.prod(x.shape)) for x in jax.tree_util.tree_leaves(tree))
