"""Whisper-style encoder-decoder (audio backbone; conv frontend is a STUB).

Per the assignment, the modality frontend is stubbed: ``input_specs()``
provides precomputed frame embeddings (B, n_frames, d_model) — the conv
subsampler is not modeled.  The transformer backbone is faithful: bidirectional
encoder (layernorm + GELU FFN), causal decoder with cross-attention, learned
decoder positions, sinusoidal encoder positions, tied output head.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models.common import (
    ParamMaker,
    apply_norm,
    cross_entropy,
    init_norm,
    make_stack,
)
from repro.models.mlp import apply_mlp, init_mlp
from repro.sharding.partition import constrain


def _sinusoid(length: int, channels: int) -> np.ndarray:
    log_ts = np.log(10000.0) / (channels // 2 - 1)
    inv = np.exp(-log_ts * np.arange(channels // 2))
    t = np.arange(length)[:, None] * inv[None, :]
    return np.concatenate([np.sin(t), np.cos(t)], axis=1).astype(np.float32)


def _init_enc_block(mk: ParamMaker, cfg: ModelConfig):
    init_norm(mk, "norm_attn", cfg.d_model, cfg.norm)
    with mk.scope("attn"):
        attn.init_gqa(mk, cfg)
    init_norm(mk, "norm_ffn", cfg.d_model, cfg.norm)
    with mk.scope("mlp"):
        init_mlp(mk, cfg.d_model, cfg.d_ff, cfg.act)


def _init_dec_block(mk: ParamMaker, cfg: ModelConfig):
    init_norm(mk, "norm_self", cfg.d_model, cfg.norm)
    with mk.scope("self"):
        attn.init_gqa(mk, cfg)
    init_norm(mk, "norm_cross", cfg.d_model, cfg.norm)
    with mk.scope("cross"):
        attn.init_cross(mk, cfg)
    init_norm(mk, "norm_ffn", cfg.d_model, cfg.norm)
    with mk.scope("mlp"):
        init_mlp(mk, cfg.d_model, cfg.d_ff, cfg.act)


@dataclasses.dataclass(frozen=True)
class EncDec:
    cfg: ModelConfig

    def init(self, rng: jax.Array, abstract: bool = False):
        cfg = self.cfg
        mk = ParamMaker(rng, cfg.param_dtype, abstract=abstract)
        mk("embed", (cfg.vocab_size, cfg.d_model), ("vocab", "embed"))
        mk("dec_pos", (cfg.max_decode_len, cfg.d_model), ("seq", "embed"))
        make_stack(mk, "encoder", cfg.encoder_layers, lambda m: _init_enc_block(m, cfg))
        init_norm(mk, "enc_norm", cfg.d_model, cfg.norm)
        make_stack(mk, "decoder", cfg.num_layers, lambda m: _init_dec_block(m, cfg))
        init_norm(mk, "final_norm", cfg.d_model, cfg.norm)
        return mk.collect()

    # -- encoder --------------------------------------------------------------
    def encode(self, params, frames: jnp.ndarray, remat: bool = False):
        """frames (B, F, D) stub embeddings -> encoder memory (B, F, D)."""
        cfg = self.cfg
        B, F, D = frames.shape
        pos = jnp.asarray(_sinusoid(F, D))[None].astype(frames.dtype)
        x = constrain(frames + pos, "batch", "frames", "embed_act")
        fpos = jnp.broadcast_to(jnp.arange(F)[None, :], (B, F))

        def body(x, p):
            h = apply_norm(p["norm_attn"], x, cfg.norm, cfg.rms_eps)
            y, _ = attn.apply_gqa(p["attn"], h, fpos, cfg, causal=False)
            x = x + y
            h = apply_norm(p["norm_ffn"], x, cfg.norm, cfg.rms_eps)
            return x + apply_mlp(p["mlp"], h, cfg.act), None

        if remat:
            body = jax.checkpoint(body)
        if cfg.unroll_layers:
            take = lambda tree, i: jax.tree_util.tree_map(lambda v: v[i], tree)
            for i in range(cfg.encoder_layers):
                x, _ = body(x, take(params["encoder"], i))
        else:
            x, _ = jax.lax.scan(body, x, params["encoder"])
        return apply_norm(params["enc_norm"], x, cfg.norm, cfg.rms_eps)

    # -- decoder --------------------------------------------------------------
    def _dec_backbone(self, params, x, pos, memory, caches, index, remat):
        cfg = self.cfg

        def body(x, xs):
            p, c = xs
            h = apply_norm(p["norm_self"], x, cfg.norm, cfg.rms_eps)
            sc = None if c is None else c["self"]
            y, sc = attn.apply_gqa(p["self"], h, pos, cfg, sc, index)
            x = x + y
            h = apply_norm(p["norm_cross"], x, cfg.norm, cfg.rms_eps)
            kv = None if c is None else c["cross_kv"]
            y, kv = attn.apply_cross(p["cross"], h, memory, cfg, kv)
            x = x + y
            h = apply_norm(p["norm_ffn"], x, cfg.norm, cfg.rms_eps)
            x = x + apply_mlp(p["mlp"], h, cfg.act)
            c = None if c is None else {"self": sc, "cross_kv": kv}
            return x, c

        if remat:
            body = jax.checkpoint(body)
        if cfg.unroll_layers:
            take = lambda tree, i: jax.tree_util.tree_map(lambda v: v[i], tree)
            outs = []
            for i in range(cfg.num_layers):
                c_i = None if caches is None else take(caches, i)
                x, c_i = body(x, (take(params["decoder"], i), c_i))
                outs.append(c_i)
            new_caches = (
                None if caches is None
                else jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *outs)
            )
            return x, new_caches
        return jax.lax.scan(body, x, (params["decoder"], caches))

    def _embed_dec(self, params, tokens, start: int | jnp.ndarray):
        cfg = self.cfg
        dt = jnp.dtype(cfg.compute_dtype)
        B, S = tokens.shape
        x = params["embed"].astype(dt)[tokens]
        p = jax.lax.dynamic_slice_in_dim(params["dec_pos"].astype(dt), start, S, 0)
        return constrain(x + p[None], "batch", "seq", "embed_act")

    def _logits(self, params, x):
        cfg = self.cfg
        x = apply_norm(params["final_norm"], x, cfg.norm, cfg.rms_eps)
        return jnp.einsum("bsd,vd->bsv", x, params["embed"].astype(x.dtype))

    # -- entry points -----------------------------------------------------------
    def train_loss(self, params, batch, z_loss: float = 0.0, remat: bool = True,
                   aux_weights=(0.0, 0.0)):
        frames, tokens = batch["frames"], batch["tokens"]
        memory = self.encode(params, frames, remat)
        if "labels" in batch:
            inputs, labels = tokens, batch["labels"]
        else:
            inputs, labels = tokens[:, :-1], tokens[:, 1:]
        B, S = inputs.shape
        x = self._embed_dec(params, inputs, 0)
        pos = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
        x, _ = self._dec_backbone(params, x, pos, memory, None, None, remat)
        loss, ce = cross_entropy(self._logits(params, x), labels, z_loss)
        return loss, {"ce": ce, "loss": loss,
                      "moe_lb": jnp.zeros(()), "moe_dropped": jnp.zeros(())}

    def init_cache(self, batch: int, max_len: int, abstract: bool = False):
        cfg = self.cfg
        dtype = jnp.dtype(cfg.compute_dtype)
        sc = (attn.cache_struct if abstract else attn.make_cache)(cfg, batch, max_len, dtype)
        K, hd = cfg.num_kv_heads, cfg.resolved_head_dim
        F = cfg.num_audio_frames
        if abstract:
            kv = {
                "k": jax.ShapeDtypeStruct((batch, F, K, hd), dtype),
                "v": jax.ShapeDtypeStruct((batch, F, K, hd), dtype),
            }
            one = {"self": sc, "cross_kv": kv}
            return jax.tree_util.tree_map(
                lambda s: jax.ShapeDtypeStruct((cfg.num_layers,) + tuple(s.shape), s.dtype),
                one,
            )
        kv = {
            "k": jnp.zeros((batch, F, K, hd), dtype),
            "v": jnp.zeros((batch, F, K, hd), dtype),
        }
        one = {"self": sc, "cross_kv": kv}
        return jax.tree_util.tree_map(
            lambda s: jnp.broadcast_to(s, (cfg.num_layers,) + s.shape).copy(), one
        )

    def cache_logical_axes(self):
        ca = attn.cache_logical_axes(self.cfg)
        axes = {
            "self": ca,
            "cross_kv": {
                "k": ("batch", "frames", "kv_heads", "head_dim"),
                "v": ("batch", "frames", "kv_heads", "head_dim"),
            },
        }
        return jax.tree_util.tree_map(
            lambda a: ("layers",) + a, axes, is_leaf=lambda x: isinstance(x, tuple)
        )

    def prefill(self, params, tokens, caches, memory=None):
        B, S = tokens.shape
        x = self._embed_dec(params, tokens, 0)
        pos = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
        x, caches = self._dec_backbone(params, x, pos, memory, caches, 0, False)
        return self._logits(params, x[:, -1:, :]), caches

    def decode_step(self, params, token, caches, index, memory=None):
        B = token.shape[0]
        x = self._embed_dec(params, token, index)
        pos = jnp.broadcast_to(index[None, None], (B, 1)).astype(jnp.int32)
        x, caches = self._dec_backbone(params, x, pos, memory, caches, index, False)
        return self._logits(params, x), caches


def build_encdec(cfg: ModelConfig) -> EncDec:
    return EncDec(cfg)
