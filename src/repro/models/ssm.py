"""State-space / recurrent blocks: Mamba (jamba) and xLSTM (mLSTM + sLSTM).

Mamba: faithful Mamba-1 selective scan (per-(channel,state) decay), computed
as a chunked ``lax.scan`` with ``jax.checkpoint`` at chunk boundaries so the
backward pass stores only chunk-boundary states (seq/chunk x B x d_inner x
d_state) instead of every step.  DESIGN.md discusses the TPU trade-off vs
the Mamba-2/SSD matmul form (used as a §Perf beyond-paper experiment).

xLSTM: mLSTM as chunkwise gated linear attention with matrix memory and the
paper's q.n normalizer; sLSTM as a faithful exp-gated scalar-memory scan
with per-head recurrent weights and the m-stabilizer.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import ParamMaker, rmsnorm
from repro.sharding.partition import constrain


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Depthwise causal conv: x (B,S,C), w (C,K) -> (B,S,C)."""
    K = w.shape[1]
    S = x.shape[1]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    y = sum(xp[:, i : i + S, :] * w[:, i].astype(x.dtype) for i in range(K))
    return y + b.astype(x.dtype)


def _conv_step(x_t: jnp.ndarray, conv_state: jnp.ndarray, w, b):
    """Single-token conv: x_t (B,C), conv_state (B,K-1,C)."""
    window = jnp.concatenate([conv_state, x_t[:, None, :]], axis=1)  # (B,K,C)
    y = jnp.einsum("bkc,ck->bc", window, w.astype(x_t.dtype)) + b.astype(x_t.dtype)
    return y, window[:, 1:, :]


# ---------------------------------------------------------------------------
# Mamba (jamba's SSM layer)


def mamba_dims(cfg: ModelConfig) -> Tuple[int, int, int]:
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    dt_rank = s.dt_rank or math.ceil(cfg.d_model / 16)
    return d_inner, dt_rank, s.d_state


def init_mamba(mk: ParamMaker, cfg: ModelConfig):
    d = cfg.d_model
    di, dtr, st = mamba_dims(cfg)
    s = cfg.ssm
    mk("in_proj", (d, 2 * di), ("embed", "mlp"))
    mk("conv_w", (di, s.d_conv), ("mlp", "conv"))
    mk("conv_b", (di,), ("mlp",), init="zeros")
    mk("x_proj", (di, dtr + 2 * st), ("mlp", None))
    mk("dt_w", (dtr, di), (None, "mlp"))
    mk("dt_b", (di,), ("mlp",), init="zeros")
    mk("A_log", (di, st), ("mlp", "state"), init="slog")
    mk("D", (di,), ("mlp",), init="ones")
    mk("out_proj", (di, d), ("mlp", "embed"))


def _selective_scan(u, dt, A, Bm, Cm, chunk: int, h0=None):
    """Mamba-1 recurrence  h_t = exp(dt_t A) h_{t-1} + dt_t B_t u_t,
    y_t = C_t . h_t.   u/dt (B,S,di); A (di,st); Bm/Cm (B,S,st).

    Chunked scan + checkpoint: O(S/chunk) boundary states saved for bwd.
    Returns (ys (B,S,di), h_final (B,di,st)).
    """
    Bsz, S, di = u.shape
    st = A.shape[1]
    nchunks = max(S // chunk, 1)
    chunk = S // nchunks
    assert S % chunk == 0, (S, chunk)

    resh = lambda x: x.reshape(Bsz, nchunks, chunk, *x.shape[2:]).swapaxes(0, 1)
    u_c, dt_c, B_c, C_c = resh(u), resh(dt), resh(Bm), resh(Cm)

    def chunk_fn(h0, xs):
        uc, dtc, bc, cc = xs          # (B, chunk, ...)

        def step(h, inp):
            u_t, dt_t, b_t, c_t = inp               # (B,di),(B,di),(B,st),(B,st)
            dA = jnp.exp(dt_t[:, :, None] * A)      # (B,di,st)
            dBu = (dt_t * u_t)[:, :, None] * b_t[:, None, :]
            h = dA * h + dBu
            y = jnp.einsum("bds,bs->bd", h, c_t)
            return h, y

        tstep = lambda x: x.swapaxes(0, 1)          # (chunk, B, ...)
        h, ys = jax.lax.scan(step, h0, (tstep(uc), tstep(dtc), tstep(bc), tstep(cc)))
        return h, ys.swapaxes(0, 1)                 # (B, chunk, di)

    chunk_fn = jax.checkpoint(chunk_fn)
    if h0 is None:
        h0 = jnp.zeros((Bsz, di, st), jnp.float32)
    h_final, ys = jax.lax.scan(chunk_fn, h0, (u_c, dt_c, B_c, C_c))
    return ys.swapaxes(0, 1).reshape(Bsz, S, di), h_final


def apply_mamba(
    params: Dict,
    x: jnp.ndarray,
    cfg: ModelConfig,
    state: Optional[Dict] = None,
) -> Tuple[jnp.ndarray, Optional[Dict]]:
    """state: {"conv": (B,K-1,di), "ssm": (B,di,st)} or None (train).

    S > 1 with state  => prefill: full scan from the given state, state out.
    S == 1 with state => decode : single fused step.
    """
    dt_ = x.dtype
    di, dtr, st = mamba_dims(cfg)
    B, S, _ = x.shape
    xz = jnp.einsum("bsd,de->bse", x, params["in_proj"].astype(dt_))
    xin, z = jnp.split(xz, 2, axis=-1)
    xin = constrain(xin, "batch", "seq", "mlp")
    A = -jnp.exp(params["A_log"].astype(jnp.float32))

    if state is None or S > 1:
        xc = jax.nn.silu(_causal_conv(xin, params["conv_w"], params["conv_b"]))
        proj = jnp.einsum("bsd,dp->bsp", xc, params["x_proj"].astype(dt_))
        dt_raw = jnp.einsum("bsr,rd->bsd", proj[..., :dtr], params["dt_w"].astype(dt_))
        delta = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_b"].astype(jnp.float32))
        Bm = proj[..., dtr : dtr + st].astype(jnp.float32)
        Cm = proj[..., dtr + st :].astype(jnp.float32)
        h0 = None if state is None else state["ssm"]
        y, h_final = _selective_scan(
            xc.astype(jnp.float32), delta, A, Bm, Cm, cfg.ssm.chunk, h0
        )
        y = y + params["D"].astype(jnp.float32) * xc.astype(jnp.float32)
        y = (y.astype(dt_)) * jax.nn.silu(z)
        if state is None:
            new_state = None
        else:
            K = cfg.ssm.d_conv
            conv_state = xin[:, S - (K - 1):, :]
            new_state = {"conv": conv_state, "ssm": h_final}
    else:
        x_t = xin[:, 0, :]
        xc_t, conv_state = _conv_step(x_t, state["conv"], params["conv_w"], params["conv_b"])
        xc_t = jax.nn.silu(xc_t)
        proj = jnp.einsum("bd,dp->bp", xc_t, params["x_proj"].astype(dt_))
        dt_raw = jnp.einsum("br,rd->bd", proj[..., :dtr], params["dt_w"].astype(dt_))
        delta = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_b"].astype(jnp.float32))
        Bm = proj[..., dtr : dtr + st].astype(jnp.float32)
        Cm = proj[..., dtr + st :].astype(jnp.float32)
        dA = jnp.exp(delta[:, :, None] * A)
        dBu = (delta * xc_t.astype(jnp.float32))[:, :, None] * Bm[:, None, :]
        h = dA * state["ssm"] + dBu
        y = jnp.einsum("bds,bs->bd", h, Cm)
        y = y + params["D"].astype(jnp.float32) * xc_t.astype(jnp.float32)
        y = (y.astype(dt_) * jax.nn.silu(z[:, 0, :]))[:, None, :]
        new_state = {"conv": conv_state, "ssm": h}

    out = jnp.einsum("bse,ed->bsd", y if y.ndim == 3 else y, params["out_proj"].astype(dt_))
    return constrain(out, "batch", "seq", "embed_act"), new_state


def mamba_state_struct(cfg: ModelConfig, batch: int, dtype) -> Dict:
    di, _, st = mamba_dims(cfg)
    sds = jax.ShapeDtypeStruct
    return {
        "conv": sds((batch, cfg.ssm.d_conv - 1, di), dtype),
        "ssm": sds((batch, di, st), jnp.float32),
    }


def mamba_make_state(cfg: ModelConfig, batch: int, dtype) -> Dict:
    di, _, st = mamba_dims(cfg)
    return {
        "conv": jnp.zeros((batch, cfg.ssm.d_conv - 1, di), dtype),
        "ssm": jnp.zeros((batch, di, st), jnp.float32),
    }


def mamba_state_logical_axes() -> Dict:
    return {"conv": ("batch", None, "mlp"), "ssm": ("batch", "mlp", "state")}


# ---------------------------------------------------------------------------
# xLSTM: mLSTM (matrix memory, chunkwise) and sLSTM (scalar memory, scan)


def mlstm_dims(cfg: ModelConfig) -> Tuple[int, int]:
    di = int(cfg.ssm.proj_factor * cfg.d_model)
    di = -(-di // cfg.num_heads) * cfg.num_heads
    return di, di // cfg.num_heads


def init_mlstm(mk: ParamMaker, cfg: ModelConfig):
    d = cfg.d_model
    di, dh = mlstm_dims(cfg)
    H = cfg.num_heads
    mk("up_proj", (d, 2 * di), ("embed", "mlp"))
    mk("conv_w", (di, 4), ("mlp", "conv"))
    mk("conv_b", (di,), ("mlp",), init="zeros")
    # block-diagonal per-head projections (xLSTM design): (H, dh, dh)
    mk("wq", (H, dh, dh), ("heads", None, None))
    mk("wk", (H, dh, dh), ("heads", None, None))
    mk("wv", (H, dh, dh), ("heads", None, None))
    mk("w_i", (di, H), ("mlp", "heads"))
    mk("b_i", (H,), ("heads",), init="zeros")
    mk("w_f", (di, H), ("mlp", "heads"))
    mk("b_f", (H,), ("heads",), init="ones")
    mk("out_norm", (di,), ("mlp",), init="ones")
    mk("down_proj", (di, d), ("mlp", "embed"))


def _mlstm_chunkwise(q, k, v, log_f, i_gate, chunk: int, carry0=None):
    """Chunkwise gated linear attention with matrix memory + normalizer.

    q,k,v (B,S,H,dh); log_f,i_gate (B,S,H).  Recurrence per head:
      C_t = f_t C_{t-1} + i_t k_t v_t^T ,  n_t = f_t n_{t-1} + i_t k_t
      h_t = (q_t C_t) / max(|q_t . n_t|, 1)
    """
    B, S, H, dh = q.shape
    nchunks = max(S // chunk, 1)
    c = S // nchunks
    resh = lambda x: x.reshape(B, nchunks, c, *x.shape[2:]).swapaxes(0, 1)
    qc, kc, vc, fc, ic = map(resh, (q, k, v, log_f, i_gate))

    def chunk_fn(carry, xs):
        Cm, n = carry                         # (B,H,dh,dh), (B,H,dh)
        qq, kk, vv, lf, ii = xs               # (B,c,H,*)
        L = jnp.cumsum(lf, axis=1)            # (B,c,H) cumulative log decay
        dec_q = jnp.exp(L)                    # decay from chunk start to t
        # intra-chunk: A[t,s] = exp(L_t - L_s) i_s (q_t.k_s) for s<=t
        scores = jnp.einsum("bthd,bshd->bhts", qq, kk)
        decay = L[:, :, None, :] - L[:, None, :, :]           # (B,t,s,H)
        mask = jnp.tril(jnp.ones((c, c), bool))
        gates = jnp.where(mask[None, :, :, None], jnp.exp(decay), 0.0)
        A = scores * gates.transpose(0, 3, 1, 2) * ii.transpose(0, 2, 1)[:, :, None, :]
        y_intra = jnp.einsum("bhts,bshd->bthd", A, vv)
        # normalizer intra: sum_s gates[t,s] i_s k_s (no q)
        An = gates.transpose(0, 3, 1, 2) * ii.transpose(0, 2, 1)[:, :, None, :]
        n_run = jnp.einsum("bhts,bshd->bthd", An, kk)
        # inter-chunk
        y_inter = jnp.einsum("bthd,bhde->bthe", qq * dec_q[..., None], Cm)
        n_tot = n_run + dec_q[..., None] * n[:, None, :, :]
        y = y_intra + y_inter
        denom = jnp.maximum(
            jnp.abs(jnp.einsum("bthd,bthd->bth", qq, n_tot)), 1.0
        )
        h = y / denom[..., None]
        # state update to end of chunk
        Lc = L[:, -1:, :]                     # (B,1,H) total decay
        w = jnp.exp(Lc - L) * ii              # (B,c,H)
        Cm = jnp.exp(Lc)[:, 0, :, None, None] * Cm + jnp.einsum(
            "bshd,bshe->bhde", kk * w[..., None], vv
        )
        n = jnp.exp(Lc)[:, 0, :, None] * n + jnp.einsum("bshd,bsh->bhd", kk, w)
        return (Cm, n), h

    chunk_fn = jax.checkpoint(chunk_fn)
    if carry0 is None:
        carry0 = (
            jnp.zeros((B, H, dh, dh), jnp.float32),
            jnp.zeros((B, H, dh), jnp.float32),
        )
    carry, hs = jax.lax.scan(chunk_fn, carry0, (qc, kc, vc, fc, ic))
    return hs.swapaxes(0, 1).reshape(B, S, H, dh), carry


def apply_mlstm(
    params: Dict,
    x: jnp.ndarray,
    cfg: ModelConfig,
    state: Optional[Dict] = None,
) -> Tuple[jnp.ndarray, Optional[Dict]]:
    dt_ = x.dtype
    di, dh = mlstm_dims(cfg)
    H = cfg.num_heads
    B, S, _ = x.shape
    up = jnp.einsum("bsd,de->bse", x, params["up_proj"].astype(dt_))
    xu, z = jnp.split(up, 2, axis=-1)
    xu = constrain(xu, "batch", "seq", "mlp")

    if state is None or S > 1:
        xc = jax.nn.silu(_causal_conv(xu, params["conv_w"], params["conv_b"]))
        xch = xc.reshape(B, S, H, dh)
        xuh = xu.reshape(B, S, H, dh)
        q = jnp.einsum("bshd,hde->bshe", xch, params["wq"].astype(dt_))
        k = jnp.einsum("bshd,hde->bshe", xch, params["wk"].astype(dt_)) / math.sqrt(dh)
        v = jnp.einsum("bshd,hde->bshe", xuh, params["wv"].astype(dt_))
        rs = lambda t: t.astype(jnp.float32)
        log_f = jax.nn.log_sigmoid(
            jnp.einsum("bsd,dh->bsh", xc, params["w_f"].astype(dt_)).astype(jnp.float32)
            + params["b_f"].astype(jnp.float32)
        )
        i_gate = jax.nn.sigmoid(
            jnp.einsum("bsd,dh->bsh", xc, params["w_i"].astype(dt_)).astype(jnp.float32)
            + params["b_i"].astype(jnp.float32)
        )
        carry0 = None if state is None else (state["C"], state["n"])
        h, (Cf, nf) = _mlstm_chunkwise(
            rs(q), rs(k), rs(v), log_f, i_gate, cfg.ssm.mlstm_chunk, carry0
        )
        h = h.reshape(B, S, di).astype(dt_)
        if state is None:
            new_state = None
        else:
            new_state = {"conv": xu[:, S - 3:, :], "C": Cf, "n": nf}
    else:
        x_t = xu[:, 0, :]
        xc_t, conv_state = _conv_step(x_t, state["conv"], params["conv_w"], params["conv_b"])
        xc_t = jax.nn.silu(xc_t)
        xch = xc_t.reshape(B, H, dh)
        xuh = x_t.reshape(B, H, dh)
        q = jnp.einsum("bhd,hde->bhe", xch, params["wq"].astype(dt_)).astype(jnp.float32)
        k = (
            jnp.einsum("bhd,hde->bhe", xch, params["wk"].astype(dt_)).astype(jnp.float32)
            / math.sqrt(dh)
        )
        v = jnp.einsum("bhd,hde->bhe", xuh, params["wv"].astype(dt_)).astype(jnp.float32)
        f = jax.nn.sigmoid(
            (xc_t @ params["w_f"].astype(dt_)).astype(jnp.float32) + params["b_f"].astype(jnp.float32)
        )
        ig = jax.nn.sigmoid(
            (xc_t @ params["w_i"].astype(dt_)).astype(jnp.float32) + params["b_i"].astype(jnp.float32)
        )
        Cm = f[:, :, None, None] * state["C"] + ig[:, :, None, None] * jnp.einsum(
            "bhd,bhe->bhde", k, v
        )
        n = f[:, :, None] * state["n"] + ig[:, :, None] * k
        denom = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", q, n)), 1.0)
        h = (jnp.einsum("bhd,bhde->bhe", q, Cm) / denom[..., None]).reshape(B, 1, di).astype(dt_)
        new_state = {"conv": conv_state, "C": Cm, "n": n}

    h = rmsnorm(h, params["out_norm"], cfg.rms_eps) * jax.nn.silu(z)
    out = jnp.einsum("bse,ed->bsd", h, params["down_proj"].astype(dt_))
    return constrain(out, "batch", "seq", "embed_act"), new_state


def mlstm_state_struct(cfg: ModelConfig, batch: int, dtype) -> Dict:
    di, dh = mlstm_dims(cfg)
    H = cfg.num_heads
    sds = jax.ShapeDtypeStruct
    return {
        "conv": sds((batch, 3, di), dtype),
        "C": sds((batch, H, dh, dh), jnp.float32),
        "n": sds((batch, H, dh), jnp.float32),
    }


def mlstm_make_state(cfg: ModelConfig, batch: int, dtype) -> Dict:
    di, dh = mlstm_dims(cfg)
    H = cfg.num_heads
    return {
        "conv": jnp.zeros((batch, 3, di), dtype),
        "C": jnp.zeros((batch, H, dh, dh), jnp.float32),
        "n": jnp.zeros((batch, H, dh), jnp.float32),
    }


def mlstm_state_logical_axes() -> Dict:
    return {
        "conv": ("batch", None, "mlp"),
        "C": ("batch", "heads", None, None),
        "n": ("batch", "heads", None),
    }


def init_slstm(mk: ParamMaker, cfg: ModelConfig):
    d = cfg.d_model
    H = cfg.num_heads
    dh = d // H
    for gate in ("i", "f", "z", "o"):
        mk(f"w_{gate}", (d, d), ("embed", "mlp"))
        mk(f"r_{gate}", (H, dh, dh), ("heads", None, None), scale=0.01)
        mk(f"b_{gate}", (d,), ("mlp",), init="ones" if gate == "f" else "zeros")
    mk("out_norm", (d,), ("embed_act",), init="ones")
    # gated FFN (xLSTM uses ~4/3 factor after sLSTM blocks)
    f = -(-4 * d // 3 // 8) * 8
    mk("ffn_gate", (d, f), ("embed", "mlp"))
    mk("ffn_up", (d, f), ("embed", "mlp"))
    mk("ffn_down", (f, d), ("mlp", "embed"))


def apply_slstm(
    params: Dict,
    x: jnp.ndarray,
    cfg: ModelConfig,
    state: Optional[Dict] = None,
) -> Tuple[jnp.ndarray, Optional[Dict]]:
    """Faithful sLSTM: exp gating + m-stabilizer, per-head recurrence."""
    dt_ = x.dtype
    d = cfg.d_model
    H = cfg.num_heads
    dh = d // H
    B, S, _ = x.shape

    pre = {
        g: jnp.einsum("bsd,de->bse", x, params[f"w_{g}"].astype(dt_)).astype(jnp.float32)
        + params[f"b_{g}"].astype(jnp.float32)
        for g in ("i", "f", "z", "o")
    }
    R = {g: params[f"r_{g}"].astype(jnp.float32) for g in ("i", "f", "z", "o")}

    def step(carry, xs):
        h, c, n, m = carry                      # (B,H,dh) each; m stabilizer
        pi, pf, pz, po = xs                     # (B,d) fp32
        rec = lambda g: jnp.einsum("bhd,hde->bhe", h, R[g])
        it = pi.reshape(B, H, dh) + rec("i")
        ft = pf.reshape(B, H, dh) + rec("f")
        zt = jnp.tanh(pz.reshape(B, H, dh) + rec("z"))
        ot = jax.nn.sigmoid(po.reshape(B, H, dh) + rec("o"))
        m_new = jnp.maximum(ft + m, it)
        i_e = jnp.exp(it - m_new)
        f_e = jnp.exp(ft + m - m_new)
        c = f_e * c + i_e * zt
        n = f_e * n + i_e
        h = ot * c / jnp.maximum(jnp.abs(n), 1.0)
        return (h, c, n, m_new), h

    if state is None or S > 1:
        if state is None:
            z0 = jnp.zeros((B, H, dh), jnp.float32)
            carry0 = (z0, z0, z0, z0)
        else:
            carry0 = (state["h"], state["c"], state["n"], state["m"])
        xs = tuple(p.swapaxes(0, 1) for p in (pre["i"], pre["f"], pre["z"], pre["o"]))
        carry1, hs = jax.lax.scan(step, carry0, xs)
        y = hs.swapaxes(0, 1).reshape(B, S, d).astype(dt_)
        new_state = (
            None
            if state is None
            else {"h": carry1[0], "c": carry1[1], "n": carry1[2], "m": carry1[3]}
        )
    else:
        carry1, h1 = step(
            (state["h"], state["c"], state["n"], state["m"]),
            tuple(p[:, 0, :] for p in (pre["i"], pre["f"], pre["z"], pre["o"])),
        )
        y = h1.reshape(B, 1, d).astype(dt_)
        new_state = {"h": carry1[0], "c": carry1[1], "n": carry1[2], "m": carry1[3]}

    y = rmsnorm(y, params["out_norm"], cfg.rms_eps)
    g = jnp.einsum("bsd,df->bsf", y, params["ffn_gate"].astype(dt_))
    u = jnp.einsum("bsd,df->bsf", y, params["ffn_up"].astype(dt_))
    y = y + jnp.einsum(
        "bsf,fd->bsd", jax.nn.silu(g) * u, params["ffn_down"].astype(dt_)
    )
    return constrain(y, "batch", "seq", "embed_act"), new_state


def slstm_state_struct(cfg: ModelConfig, batch: int) -> Dict:
    H = cfg.num_heads
    dh = cfg.d_model // H
    sds = jax.ShapeDtypeStruct
    return {k: sds((batch, H, dh), jnp.float32) for k in ("h", "c", "n", "m")}


def slstm_make_state(cfg: ModelConfig, batch: int) -> Dict:
    H = cfg.num_heads
    dh = cfg.d_model // H
    return {k: jnp.zeros((batch, H, dh), jnp.float32) for k in ("h", "c", "n", "m")}


def slstm_state_logical_axes() -> Dict:
    return {k: ("batch", "heads", None) for k in ("h", "c", "n", "m")}
