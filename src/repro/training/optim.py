"""AdamW + LR schedules from scratch (no optax in this environment).

State layout (a plain dict so sharding specs mirror params exactly):
  {"m": like-params fp32, "v": like-params fp32,
   "master": fp32 params (only when params are low-precision and
             master_weights is on), "step": scalar int32}

Weight decay follows the usual rule: only >=2-D tensors decay (norm scales
and biases don't).  Gradient clipping is by global norm (fp32).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import OptimizerConfig
from repro.utils.tree import tree_global_norm


def lr_schedule(cfg: OptimizerConfig, step: jnp.ndarray) -> jnp.ndarray:
    """Linear warmup -> cosine decay to min_lr_ratio."""
    step = step.astype(jnp.float32)
    warm = cfg.lr * step / jnp.maximum(cfg.warmup_steps, 1)
    t = jnp.clip(
        (step - cfg.warmup_steps) / jnp.maximum(cfg.decay_steps - cfg.warmup_steps, 1),
        0.0,
        1.0,
    )
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return jnp.where(step < cfg.warmup_steps, warm, cfg.lr * cos)


def _needs_master(params, cfg: OptimizerConfig) -> bool:
    leaves = jax.tree_util.tree_leaves(params)
    return cfg.master_weights and any(l.dtype != jnp.float32 for l in leaves)


def init_opt_state(params, cfg: OptimizerConfig, abstract: bool = False) -> Dict:
    f32 = lambda x: (
        jax.ShapeDtypeStruct(tuple(x.shape), jnp.float32)
        if abstract
        else jnp.zeros(x.shape, jnp.float32)
    )
    state = {
        "m": jax.tree_util.tree_map(f32, params),
        "v": jax.tree_util.tree_map(f32, params),
        "step": (
            jax.ShapeDtypeStruct((), jnp.int32) if abstract else jnp.zeros((), jnp.int32)
        ),
    }
    if _needs_master(params, cfg):
        cast = lambda x: (
            jax.ShapeDtypeStruct(tuple(x.shape), jnp.float32)
            if abstract
            else x.astype(jnp.float32)
        )
        state["master"] = jax.tree_util.tree_map(cast, params)
    return state


def opt_state_logical_axes(param_axes, cfg: OptimizerConfig, has_master: bool) -> Dict:
    state = {"m": param_axes, "v": param_axes, "step": ()}
    if has_master:
        state["master"] = param_axes
    return state


def clip_by_global_norm(grads, max_norm: float) -> Tuple:
    gnorm = tree_global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gnorm, 1e-9))
    # scale in the leaf dtype's fp32 shadow: low-precision leaves (bf16/fp8
    # param storage) have no implicit promotion against f32
    return (
        jax.tree_util.tree_map(
            lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads
        ),
        gnorm,
    )


def adamw_update(
    params,
    grads,
    state: Dict,
    cfg: OptimizerConfig,
) -> Tuple:
    """One AdamW step; returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    lr = lr_schedule(cfg, step)
    if cfg.grad_clip > 0:
        grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    else:
        gnorm = tree_global_norm(grads)

    b1, b2, eps = cfg.b1, cfg.b2, cfg.eps
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    source = state.get("master", params)

    def upd(p_master, g, m, v):
        g = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mh = m / bc1
        vh = v / bc2
        delta = mh / (jnp.sqrt(vh) + eps)
        p32 = p_master.astype(jnp.float32)
        if p32.ndim >= 2 and cfg.weight_decay > 0:
            delta = delta + cfg.weight_decay * p32
        return p32 - lr * delta, m, v

    flat_p, treedef = jax.tree_util.tree_flatten(source)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p32 = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])

    new_state = {"m": new_m, "v": new_v, "step": step}
    if "master" in state:
        new_state["master"] = new_p32
        new_params = jax.tree_util.tree_map(
            lambda p32, p: p32.astype(p.dtype), new_p32, params
        )
    else:
        new_params = jax.tree_util.tree_map(
            lambda p32, p: p32.astype(p.dtype), new_p32, params
        )
    metrics = {"lr": lr, "grad_norm": gnorm}
    return new_params, new_state, metrics
