from repro.training.optim import adamw_update, init_opt_state, lr_schedule
from repro.training.checkpoint import CheckpointManager
from repro.training.trainer import Trainer
from repro.training.losses import ot_alignment_loss
from repro.training.compression import apply_error_feedback, init_error_state
from repro.training.elastic import StragglerWatchdog, remesh_state
from repro.training.ot_routing import ot_route
