"""Fault-tolerance runtime pieces: straggler watchdog + elastic remesh.

On a 1000-node job the failure modes this layer addresses are:
  * stragglers — one slow host gates every synchronous collective.  The
    watchdog tracks per-step wall times, flags hosts/steps beyond a robust
    z-score, and (on real deployments) feeds the decision to drop/replace
    the host into the job controller.  The detection logic is pure and
    unit-tested here with simulated clocks.
  * crash/restart — launch/train.py restores the latest committed
    checkpoint automatically (CheckpointManager is crash-atomic).
  * shrink/grow — remesh_state() re-shards a host-gathered state onto a new
    mesh (different device count/topology); with the deterministic data
    pipeline (batch = f(seed, step)) a resumed run is bitwise-reproducible
    modulo reduced batch layout.
"""
from __future__ import annotations

import dataclasses
import statistics
import time
from collections import deque
from typing import Deque, List, Optional

import jax

from repro.sharding.partition import Rules, sharding_tree
from repro.utils.logging import get_logger

log = get_logger("elastic")


@dataclasses.dataclass
class StragglerEvent:
    step: int
    duration: float
    median: float
    ratio: float


class StragglerWatchdog:
    """Flags steps whose duration exceeds ``ratio_threshold`` x rolling median.

    In a multi-host deployment each host reports durations into the same
    window (an all-gather of one float per step — negligible traffic); the
    controller acts on persistent offenders.  The pure detection logic lives
    here so it can be tested deterministically.
    """

    def __init__(self, window: int = 50, ratio_threshold: float = 2.0,
                 min_samples: int = 10):
        self.window: Deque[float] = deque(maxlen=window)
        self.ratio_threshold = ratio_threshold
        self.min_samples = min_samples
        self.events: List[StragglerEvent] = []
        self._t0: Optional[float] = None
        self._step = 0

    def step_start(self, step: int):
        self._step = step
        self._t0 = time.perf_counter()

    def step_end(self) -> Optional[StragglerEvent]:
        assert self._t0 is not None
        return self.observe(self._step, time.perf_counter() - self._t0)

    def observe(self, step: int, duration: float) -> Optional[StragglerEvent]:
        event = None
        if len(self.window) >= self.min_samples:
            med = statistics.median(self.window)
            if med > 0 and duration / med >= self.ratio_threshold:
                event = StragglerEvent(step, duration, med, duration / med)
                self.events.append(event)
                log.warning(
                    "straggler: step %d took %.3fs (%.1fx median %.3fs)",
                    step, duration, event.ratio, med,
                )
        self.window.append(duration)
        return event


def remesh_state(state, new_mesh, rules: Rules, axes_tree):
    """Re-shard a live state pytree onto a different mesh (elastic resize).

    Host-gathers each leaf (works because this framework keeps leaves
    addressable on restore paths) and device_puts with the new mesh's
    shardings.  On multi-host deployments the same logic runs from the
    checkpoint (per-shard files), never through one host's RAM.
    """
    shardings = sharding_tree(axes_tree, rules, new_mesh, shapes=state)
    import numpy as np

    return jax.tree_util.tree_map(
        lambda x, sh: jax.device_put(np.asarray(x), sh), state, shardings
    )
