"""Gradient compression for cross-pod all-reduce: int8 with error feedback.

At 2 pods x 50 GB/s ICI, all-reducing fp32 gradients of an N-param model
costs ~8N bytes on the wire; int8 + per-tensor scale cuts that 4x.  Error
feedback (Seide et al. 2014; Karimireddy et al. 2019) accumulates the
quantization residual locally and re-injects it next step, preserving
convergence (tests/test_compression.py checks the EF contraction property
and end-to-end convergence on a quadratic).

The trainer applies this to the gradient *before* the optimizer: in the
GSPMD-sharded step this models the wire format of the cross-pod reduction
(the actual all-reduce stays in XLA; on real hardware the compressor pairs
with a shard_map'ed ppermute ring over the 'pod' axis — see
DESIGN.md for the deployment note).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp


def init_error_state(params) -> Dict:
    return jax.tree_util.tree_map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def _q8(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def compress_decompress(g: jnp.ndarray, err: jnp.ndarray):
    """Returns (g_hat fp32, new_err).  g_hat = dequant(quant(g + err))."""
    x = g.astype(jnp.float32) + err
    q, scale = _q8(x)
    g_hat = q.astype(jnp.float32) * scale
    return g_hat, x - g_hat


def apply_error_feedback(grads, err_state):
    """Tree-wide int8 EF pass; returns (compressed grads, new error state)."""
    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_e = treedef.flatten_up_to(err_state)
    out = [compress_decompress(g, e) for g, e in zip(flat_g, flat_e)]
    return (
        treedef.unflatten([o[0] for o in out]),
        treedef.unflatten([o[1] for o in out]),
    )


def wire_bytes_saved(params) -> Tuple[int, int]:
    """(fp32 bytes, int8 bytes) per all-reduce for reporting."""
    n = sum(int(p.size) for p in jax.tree_util.tree_leaves(params))
    return 4 * n, n + 4 * len(jax.tree_util.tree_leaves(params))
