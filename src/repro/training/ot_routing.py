"""Beyond-paper: MoE routing as group-sparse regularized OT.

Motivation: top-k routing (a) imbalances experts (needs aux losses) and
(b) scatters each sequence's tokens across many experts, maximizing
all-to-all fan-out.  Casting routing as a regularized OT fixes both:

  * transport token mass (a = 1/T) to experts with balanced capacity
    marginals (b = 1/E)  ->  load balance is a CONSTRAINT, not a loss;
  * the paper's group-sparse regularizer with groups = sequences drives
    each sequence's block of the plan to few nonzero expert columns ->
    sequence-local expert placement, i.e. less cross-device traffic.

The plan is solved with the *screened* solver (Algorithm 1) — the paper's
technique is literally the inner loop of the router — and enters routing
through stop_gradient (assignments), while differentiable gate weights come
from the router softmax as usual.

Cost per layer: the dual over (alpha: T, beta: E) with C = -log softmax
(router logits); each evaluation is O(T x E) elementwise — about one extra
router-matmul-equivalent per L-BFGS iteration.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.dual import DualProblem, plan_from_duals
from repro.core.lbfgs import LbfgsOptions
from repro.core.regularizers import GroupSparseReg
from repro.core.solver import SolveOptions, _solve_jit, _split


@functools.partial(
    jax.jit,
    static_argnames=("num_seqs", "seq_len", "top_k", "gamma", "rho", "max_iters"),
)
def ot_route(
    logits: jnp.ndarray,          # (T, E) router logits, T = num_seqs*seq_len
    *,
    num_seqs: int,
    seq_len: int,
    top_k: int,
    gamma: float = 5.0,
    rho: float = 0.5,
    max_iters: int = 40,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (top-k expert ids (T,k), plan-derived weights (T,k))."""
    T, E = logits.shape
    assert T == num_seqs * seq_len
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    C = jax.lax.stop_gradient(-logp)              # cost: (T, E)
    C = C / jnp.maximum(jnp.max(C), 1e-9)

    # dual over columns = EXPERTS (n = E); rows = tokens grouped by sequence
    prob = DualProblem(num_seqs, seq_len, E, GroupSparseReg.from_rho(gamma, rho))
    a = jnp.full((T,), 1.0 / T, jnp.float32)
    b = jnp.full((E,), 1.0 / E, jnp.float32)      # balanced expert marginals
    row_mask = jnp.ones((T,), bool)
    sqrt_g = jnp.full((num_seqs,), jnp.sqrt(float(seq_len)), jnp.float32)
    opts = SolveOptions(
        grad_impl="screened",
        lbfgs=LbfgsOptions(max_iters=max_iters, gtol=1e-5),
        max_rounds=max(max_iters // 10, 1),
    )
    lb, _, _, _ = _solve_jit(C, a, b, row_mask, sqrt_g, prob, opts)
    alpha, beta = _split(lb.x, T)
    plan = jax.lax.stop_gradient(plan_from_duals(alpha, beta, C, prob))  # (T, E)

    topw, topi = jax.lax.top_k(plan, top_k)
    # renormalize; fall back to router softmax where the plan gives a token
    # no mass (can happen for capacity-squeezed tokens)
    wsum = jnp.sum(topw, axis=-1, keepdims=True)
    probs = jnp.take_along_axis(jax.nn.softmax(logits, axis=-1), topi, axis=-1)
    w = jnp.where(wsum > 1e-12, topw / jnp.maximum(wsum, 1e-12),
                  probs / jnp.maximum(jnp.sum(probs, -1, keepdims=True), 1e-12))
    return topi, w.astype(logits.dtype)


def routing_stats(topi: jnp.ndarray, num_experts: int, num_seqs: int,
                  seq_len: int) -> dict:
    """Balance + locality metrics for tests/benchmarks."""
    T, k = topi.shape
    counts = jnp.zeros((num_experts,), jnp.int32).at[topi.reshape(-1)].add(1)
    load_cv = jnp.std(counts.astype(jnp.float32)) / jnp.maximum(
        jnp.mean(counts.astype(jnp.float32)), 1e-9)
    per_seq = topi.reshape(num_seqs, seq_len * k)
    uniq = jnp.mean(
        jnp.sum(
            (jax.nn.one_hot(per_seq, num_experts).sum(axis=1) > 0).astype(jnp.float32),
            axis=-1,
        )
    )
    return {"load_cv": load_cv, "experts_per_seq": uniq}
