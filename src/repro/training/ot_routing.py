"""Beyond-paper: MoE routing as group-sparse regularized OT.

Motivation: top-k routing (a) imbalances experts (needs aux losses) and
(b) scatters each sequence's tokens across many experts, maximizing
all-to-all fan-out.  Casting routing as a regularized OT fixes both:

  * transport token mass (a = 1/T) to experts with balanced capacity
    marginals (b = 1/E)  ->  load balance is a CONSTRAINT, not a loss;
  * the paper's group-sparse regularizer with groups = sequences drives
    each sequence's block of the plan to few nonzero expert columns ->
    sequence-local expert placement, i.e. less cross-device traffic.

The plan is solved through :class:`repro.ot.OTLayer` (``loss_and_plan``,
one screened Algorithm-1 solve) — the paper's technique is literally the
inner loop of the router — and enters routing through the layer's detached
plan output (assignments), while differentiable gate weights come from the
router softmax as usual.

Cost per layer: the dual over (alpha: T, beta: E) with C = -log softmax
(router logits); each evaluation is O(T x E) elementwise — about one extra
router-matmul-equivalent per L-BFGS iteration.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.regularizers import GroupSparseReg
from repro.ot import ExecutionPlan, OTLayer


@functools.partial(
    jax.jit,
    static_argnames=("num_seqs", "seq_len", "top_k", "gamma", "rho", "max_iters"),
)
def ot_route(
    logits: jnp.ndarray,          # (T, E) router logits, T = num_seqs*seq_len
    *,
    num_seqs: int,
    seq_len: int,
    top_k: int,
    gamma: float = 5.0,
    rho: float = 0.5,
    max_iters: int = 40,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (top-k expert ids (T,k), plan-derived weights (T,k))."""
    T, E = logits.shape
    assert T == num_seqs * seq_len
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    C = jax.lax.stop_gradient(-logp)              # cost: (T, E)
    C = C / jnp.maximum(jnp.max(C), 1e-9)

    # dual over columns = EXPERTS (n = E); rows = tokens grouped by sequence;
    # uniform token mass, balanced expert marginals (the layer's defaults)
    layer = OTLayer(
        num_groups=num_seqs,
        group_size=seq_len,
        num_target=E,
        reg=GroupSparseReg.from_rho(gamma, rho),
        plan=ExecutionPlan(
            grad_impl="screened",
            max_iters=max_iters,
            gtol=1e-5,
            max_rounds=max(max_iters // 10, 1),
        ),
    )
    _, plan = layer.loss_and_plan(C)              # detached plan, (T, E)

    topw, topi = jax.lax.top_k(plan, top_k)
    # renormalize; fall back to router softmax where the plan gives a token
    # no mass (can happen for capacity-squeezed tokens)
    wsum = jnp.sum(topw, axis=-1, keepdims=True)
    probs = jnp.take_along_axis(jax.nn.softmax(logits, axis=-1), topi, axis=-1)
    w = jnp.where(wsum > 1e-12, topw / jnp.maximum(wsum, 1e-12),
                  probs / jnp.maximum(jnp.sum(probs, -1, keepdims=True), 1e-12))
    return topi, w.astype(logits.dtype)


def routing_stats(topi: jnp.ndarray, num_experts: int, num_seqs: int,
                  seq_len: int) -> dict:
    """Balance + locality metrics for tests/benchmarks."""
    T, k = topi.shape
    counts = jnp.zeros((num_experts,), jnp.int32).at[topi.reshape(-1)].add(1)
    load_cv = jnp.std(counts.astype(jnp.float32)) / jnp.maximum(
        jnp.mean(counts.astype(jnp.float32)), 1e-9)
    per_seq = topi.reshape(num_seqs, seq_len * k)
    uniq = jnp.mean(
        jnp.sum(
            (jax.nn.one_hot(per_seq, num_experts).sum(axis=1) > 0).astype(jnp.float32),
            axis=-1,
        )
    )
    return {"load_cv": load_cv, "experts_per_seq": uniq}
