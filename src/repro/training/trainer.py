"""Training loop: jit'd step, checkpoint/restart, watchdog, OT-align option.

Runs identically on 1 CPU device (examples/smoke) and on a production mesh
(GSPMD shards the same step function).  Fault tolerance: every run starts by
probing the checkpoint dir and resuming from the latest committed step; the
synthetic pipeline regenerates batch(step) deterministically so a restart
continues the same trajectory.
"""
from __future__ import annotations

from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, TrainConfig
from repro.data.pipeline import SyntheticLM
from repro.models import build_model
from repro.sharding.partition import Rules, use_rules
from repro.training.checkpoint import CheckpointManager
from repro.training.compression import apply_error_feedback, init_error_state
from repro.training.elastic import StragglerWatchdog
from repro.training.losses import group_features_by_class, ot_alignment_loss
from repro.training.optim import adamw_update, init_opt_state
from repro.utils.logging import get_logger

log = get_logger("trainer")


class Trainer:
    def __init__(
        self,
        cfg: ModelConfig,
        tcfg: TrainConfig,
        data: SyntheticLM,
        ckpt_dir: Optional[str] = None,
        mesh=None,
        rules: Optional[Rules] = None,
    ):
        self.cfg, self.tcfg = cfg, tcfg
        self.model = build_model(cfg)
        self.data = data
        self.mesh, self.rules = mesh, rules
        self.ckpt = CheckpointManager(ckpt_dir) if ckpt_dir else None
        self.watchdog = StragglerWatchdog()
        self.metrics_history = []

        params, self.param_axes = self.model.init(jax.random.PRNGKey(tcfg.seed))
        opt = init_opt_state(params, tcfg.optimizer)
        self.state = {"params": params, "opt": opt}
        if tcfg.grad_compression == "int8_ef":
            self.state["ef"] = init_error_state(params)

        self.step_fn = jax.jit(self._make_step())
        self.start_step = 0
        if self.ckpt and self.ckpt.latest_step() is not None:
            self.state, self.start_step = self.ckpt.restore(self.state)
            log.info("restored checkpoint at step %d", self.start_step)

    # ------------------------------------------------------------------
    def _make_step(self) -> Callable:
        cfg, tcfg, model = self.cfg, self.tcfg, self.model
        remat = tcfg.remat != "none"

        def loss_fn(params, batch):
            total, metrics = model.train_loss(
                params, batch, z_loss=tcfg.z_loss, remat=remat
            )
            if tcfg.ot_align and "class" in batch:
                # paper integration: align mean hidden representations of the
                # two halves of the batch (source half labeled by `class`)
                logits, _ = model.forward(params, batch["tokens"][:, :-1])
                del logits  # features come from embeddings below (cheap proxy)
                emb = params["embed"].astype(jnp.float32)
                feats = jnp.mean(emb[batch["tokens"][:, :-1]], axis=1)
                half = feats.shape[0] // 2
                L = int(self.data.cfg.num_classes)
                gsz = max(half // L, 1)
                h_src = group_features_by_class(
                    feats[:half], batch["class"][:half], L, gsz
                )
                ot, ot_metrics = ot_alignment_loss(
                    h_src, feats[half:],
                    num_classes=L, group_size=gsz,
                    gamma=tcfg.ot_gamma, rho=tcfg.ot_rho,
                    solver=tcfg.ot_solver, grad_impl=tcfg.ot_grad_impl,
                )
                total = total + tcfg.ot_align_weight * ot
                metrics = dict(metrics, **ot_metrics)
            return total, metrics

        def step(state, batch):
            (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                state["params"], batch
            )
            new_state = dict(state)
            if "ef" in state:
                grads, new_state["ef"] = apply_error_feedback(grads, state["ef"])
            new_params, new_opt, om = adamw_update(
                state["params"], grads, state["opt"], tcfg.optimizer
            )
            new_state["params"] = new_params
            new_state["opt"] = new_opt
            return new_state, dict(metrics, **om)

        return step

    # ------------------------------------------------------------------
    def run(self, steps: Optional[int] = None) -> Dict:
        steps = steps or self.tcfg.steps
        ctx = use_rules(self.rules, self.mesh) if self.rules else _null_ctx()
        with ctx:
            for step in range(self.start_step, steps):
                self.watchdog.step_start(step)
                batch = {
                    k: jnp.asarray(v) for k, v in self.data.batch(step).items()
                }
                self.state, metrics = self.step_fn(self.state, batch)
                # block on one scalar so the watchdog times the actual step,
                # not jax's async dispatch (sub-ms dispatch would make every
                # real fluctuation look like a straggler)
                jax.block_until_ready(metrics["loss"])
                ev = self.watchdog.step_end()
                if step % self.tcfg.log_every == 0 or step == steps - 1:
                    m = {k: float(v) for k, v in metrics.items()}
                    self.metrics_history.append({"step": step, **m})
                    log.info(
                        "step %5d loss=%.4f ce=%.4f gnorm=%.2f lr=%.2e%s",
                        step, m.get("loss", 0), m.get("ce", 0),
                        m.get("grad_norm", 0), m.get("lr", 0),
                        " [straggler]" if ev else "",
                    )
                if self.ckpt and (step + 1) % self.tcfg.checkpoint_every == 0:
                    self.ckpt.save(self.state, step + 1)
        if self.ckpt:
            self.ckpt.save(self.state, steps)
            self.ckpt.wait()
        return self.metrics_history[-1] if self.metrics_history else {}


class _null_ctx:
    def __enter__(self):
        return self

    def __exit__(self, *a):
        return False
