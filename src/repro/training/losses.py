"""Training losses beyond plain CE — the paper's OT enters training here.

``ot_alignment_loss`` is the paper's unsupervised-domain-adaptation use case
as a first-class auxiliary loss: labeled source representations are
transported to unlabeled target representations under the group-sparse
regularizer (classes = groups).  The solve routes through
:class:`repro.ot.OTLayer` — the differentiable façade over the screened
solver — so gradients are the exact Danskin/envelope gradients
(``dW/dC = T*`` chain-ruled to the feature coordinates without ever
materializing the plan for the Pallas backends; docs/training.md), and the
solver backend / stochastic schedule follow the layer's
:class:`~repro.ot.ExecutionPlan` (``TrainConfig.ot_solver`` /
``ot_grad_impl`` select them from the trainer).
"""
from __future__ import annotations

import functools
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core.regularizers import GroupSparseReg
from repro.ot import ExecutionPlan, OTLayer


def pairwise_sqdist(A: jnp.ndarray, B: jnp.ndarray) -> jnp.ndarray:
    a2 = jnp.sum(A * A, axis=1)[:, None]
    b2 = jnp.sum(B * B, axis=1)[None, :]
    return jnp.maximum(a2 + b2 - 2.0 * A @ B.T, 0.0)


def _alignment_layer(
    num_classes: int, group_size: int, num_target: int,
    gamma: float, rho: float, max_iters: int,
    solver: str, grad_impl: str,
) -> OTLayer:
    """The (hashable) layer behind ``ot_alignment_loss``.

    Equal arguments build equal layers, so every training step reuses one
    compiled solver program per configuration.
    """
    plan = ExecutionPlan(
        grad_impl=grad_impl,
        solver=solver,
        max_iters=max_iters,
        gtol=1e-5,
        max_rounds=max(max_iters // 10, 1),
    )
    return OTLayer(
        num_groups=num_classes,
        group_size=group_size,
        num_target=num_target,
        reg=GroupSparseReg.from_rho(gamma, rho),
        plan=plan,
        normalize_cost=True,
    )


@functools.partial(
    jax.jit,
    static_argnames=(
        "num_classes", "group_size", "gamma", "rho", "max_iters",
        "solver", "grad_impl",
    ),
)
def ot_alignment_loss(
    h_src: jnp.ndarray,        # (Ns, d) source features (sorted by class!)
    h_tgt: jnp.ndarray,        # (Nt, d) target features
    *,
    num_classes: int,
    group_size: int,           # uniform padded class size (Ns = L * g)
    gamma: float = 1.0,
    rho: float = 0.6,
    max_iters: int = 60,
    solver: str = "lbfgs",
    grad_impl: str = "screened",
) -> Tuple[jnp.ndarray, Dict]:
    """Group-sparse OT distance between feature clouds, differentiable.

    The value is ``OTLayer.from_samples`` on the normalized squared-l2
    geometry: its ``jax.grad`` pulls the exact optimal plan back to BOTH
    feature clouds (the legacy implementation differentiated a
    stop-gradiented ``<T, C>`` estimator; the layer gives the same
    envelope-theorem gradient from one solve, plus dual gradients and the
    materialization-free samples pullback for ``grad_impl='pallas'``).
    """
    Ns = h_src.shape[0]
    assert Ns == num_classes * group_size

    layer = _alignment_layer(
        num_classes, group_size, int(h_tgt.shape[0]),
        gamma, rho, max_iters, solver, grad_impl,
    )
    loss = layer.from_samples(
        h_src.astype(jnp.float32), h_tgt.astype(jnp.float32)
    )
    metrics = {"ot_distance": loss}
    return loss, metrics


def group_features_by_class(
    h: jnp.ndarray, labels: jnp.ndarray, num_classes: int, group_size: int
) -> jnp.ndarray:
    """Pack (N, d) features into the sorted uniform-group layout the solver
    expects, truncating/padding each class to ``group_size`` rows (padded
    rows repeat the class mean, carrying the right gradient structure)."""
    out = []
    for c in range(num_classes):
        mask = (labels == c).astype(h.dtype)[:, None]
        cnt = jnp.maximum(jnp.sum(mask), 1.0)
        mean = jnp.sum(h * mask, axis=0) / cnt
        # deterministic packing: weight rows of this class, fill with mean
        idx = jnp.argsort(jnp.where(labels == c, 0, 1), stable=True)[:group_size]
        rows = h[idx]
        ok = (labels[idx] == c)[:, None]
        out.append(jnp.where(ok, rows, mean[None, :]))
    return jnp.concatenate(out, axis=0)
