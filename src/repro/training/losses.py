"""Training losses beyond plain CE — the paper's OT enters training here.

``ot_alignment_loss`` is the paper's unsupervised-domain-adaptation use case
as a first-class auxiliary loss: labeled source representations are
transported to unlabeled target representations under the group-sparse
regularizer (classes = groups), solved with the *screened* solver
(Algorithm 1).  Gradients follow the envelope theorem: at the dual optimum
the transportation plan is treated as constant (stop_gradient), and the loss
<T*, C(features)> differentiates through the cost matrix only — the standard
OT-loss estimator (Courty et al. 2017).
"""
from __future__ import annotations

import functools
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core.dual import DualProblem, plan_from_duals
from repro.core.lbfgs import LbfgsOptions
from repro.core.regularizers import GroupSparseReg
from repro.core.solver import SolveOptions, _solve_jit, _split


def pairwise_sqdist(A: jnp.ndarray, B: jnp.ndarray) -> jnp.ndarray:
    a2 = jnp.sum(A * A, axis=1)[:, None]
    b2 = jnp.sum(B * B, axis=1)[None, :]
    return jnp.maximum(a2 + b2 - 2.0 * A @ B.T, 0.0)


@functools.partial(
    jax.jit,
    static_argnames=("num_classes", "group_size", "gamma", "rho", "max_iters"),
)
def ot_alignment_loss(
    h_src: jnp.ndarray,        # (Ns, d) source features (sorted by class!)
    h_tgt: jnp.ndarray,        # (Nt, d) target features
    *,
    num_classes: int,
    group_size: int,           # uniform padded class size (Ns = L * g)
    gamma: float = 1.0,
    rho: float = 0.6,
    max_iters: int = 60,
) -> Tuple[jnp.ndarray, Dict]:
    """Group-sparse OT distance between feature clouds (screened solver)."""
    Ns, Nt = h_src.shape[0], h_tgt.shape[0]
    assert Ns == num_classes * group_size

    C = pairwise_sqdist(h_src.astype(jnp.float32), h_tgt.astype(jnp.float32))
    Cn = C / jnp.maximum(jax.lax.stop_gradient(jnp.max(C)), 1e-9)

    reg = GroupSparseReg.from_rho(gamma, rho)
    prob = DualProblem(num_classes, group_size, Nt, reg)
    a = jnp.full((Ns,), 1.0 / Ns, jnp.float32)
    b = jnp.full((Nt,), 1.0 / Nt, jnp.float32)
    row_mask = jnp.ones((Ns,), bool)
    sqrt_g = jnp.full((num_classes,), jnp.sqrt(float(group_size)), jnp.float32)

    opts = SolveOptions(
        grad_impl="screened",
        lbfgs=LbfgsOptions(max_iters=max_iters, gtol=1e-5),
        max_rounds=max(max_iters // 10, 1),
    )
    C_solve = jax.lax.stop_gradient(Cn)
    lb, _, _, stats = _solve_jit(C_solve, a, b, row_mask, sqrt_g, prob, opts)
    alpha, beta = _split(lb.x, Ns)
    T = jax.lax.stop_gradient(plan_from_duals(alpha, beta, C_solve, prob))

    loss = jnp.sum(T * Cn)   # grads flow through Cn -> features (envelope thm)
    metrics = {
        "ot_distance": loss,
        "ot_iters": lb.iter,
        "ot_skipped": stats[0],
    }
    return loss, metrics


def group_features_by_class(
    h: jnp.ndarray, labels: jnp.ndarray, num_classes: int, group_size: int
) -> jnp.ndarray:
    """Pack (N, d) features into the sorted uniform-group layout the solver
    expects, truncating/padding each class to ``group_size`` rows (padded
    rows repeat the class mean, carrying the right gradient structure)."""
    out = []
    for c in range(num_classes):
        mask = (labels == c).astype(h.dtype)[:, None]
        cnt = jnp.maximum(jnp.sum(mask), 1.0)
        mean = jnp.sum(h * mask, axis=0) / cnt
        # deterministic packing: weight rows of this class, fill with mean
        idx = jnp.argsort(jnp.where(labels == c, 0, 1), stable=True)[:group_size]
        rows = h[idx]
        ok = (labels[idx] == c)[:, None]
        out.append(jnp.where(ok, rows, mean[None, :]))
    return jnp.concatenate(out, axis=0)
