"""Pipeline parallelism over the "pod" axis (GPipe-style, shard_map).

For pod-scale deployments where cross-pod FSDP all-gathers are too
expensive (see EXPERIMENTS.md §Roofline: cross-pod wire is the dominant
term for the largest archs), the alternative is to place CONSECUTIVE layer
blocks on different pods and stream microbatches through them:

  * each pod holds 1/P of the layer stack (no cross-pod param movement),
  * activations hop pod->pod once per microbatch per boundary
    (collective_permute — exactly the neighbour link),
  * the schedule is GPipe: P + M - 1 ticks for M microbatches, bubble
    fraction (P-1)/(M+P-1).

This module implements the schedule as a shard_map'd lax.scan: at tick t,
stage s computes microbatch (t - s) if 0 <= t - s < M, then ppermutes its
output to stage s+1.  Stages are data-parallel inside the pod as usual.

Cross-pod wire per step = 2 * M * microbatch_bytes * (P-1) (fwd + bwd) —
for jamba train_4k: 2 * 32 * (8 tok-rows x 4096 x 8192 x 2B) ~= 6.9e10 B
vs the FSDP baseline's 3.9e12: the roofline motivation for PP at this
scale.  The full-framework integration point is `stage_fn`; the unit tests
drive it with real transformer blocks at toy sizes and assert equality
with the sequential model.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P


def gpipe_forward(
    stage_fn: Callable,        # (stage_params, x) -> x
    stage_params,              # pytree with leading [P] stage axis (sharded)
    x_microbatches: jnp.ndarray,  # (M, mb, ...) microbatched input
    mesh: Mesh,
    axis: str = "pod",
):
    """Run the GPipe forward schedule under shard_map over ``axis``.

    Returns the final-stage outputs, microbatch order preserved.
    Correctness contract: equals sequentially applying all P stages
    (tests/test_pipeline.py).
    """
    Pn = mesh.shape[axis]
    M = x_microbatches.shape[0]

    def staged(params_local, x_mb):
        # params_local: this stage's params (leading axis stripped to size 1)
        params_local = jax.tree_util.tree_map(lambda p: p[0], params_local)
        stage = jax.lax.axis_index(axis)

        mb_shape = x_mb.shape[1:]
        ticks = M + Pn - 1

        def tick(carry, t):
            buf_in, outputs = carry
            # stage 0 injects microbatch t from its local copy; others use
            # what arrived over the link last tick
            mb_idx = t - stage
            active = jnp.logical_and(mb_idx >= 0, mb_idx < M)
            feed = jnp.where(
                stage == 0,
                x_mb[jnp.clip(t, 0, M - 1)],
                buf_in,
            )
            y = stage_fn(params_local, feed)
            y = jnp.where(active, y, jnp.zeros_like(y))
            # last stage records its finished microbatch
            outputs = jnp.where(
                jnp.logical_and(stage == Pn - 1, active),
                outputs.at[jnp.clip(mb_idx, 0, M - 1)].set(y),
                outputs,
            )
            # ship to the next stage (ring; last->first carries garbage,
            # ignored because stage 0 always injects fresh input)
            buf_next = jax.lax.ppermute(
                y, axis, [(i, (i + 1) % Pn) for i in range(Pn)]
            )
            return (buf_next, outputs), None

        buf0 = jnp.zeros(mb_shape, x_mb.dtype)
        outs0 = jnp.zeros((M,) + mb_shape, x_mb.dtype)
        (_, outputs), _ = jax.lax.scan(
            tick, (buf0, outs0), jnp.arange(ticks)
        )
        # every stage holds `outputs`, but only the last stage's is real;
        # broadcast it (tiny at toy scale; on real pods the consumer IS the
        # last stage, so this psum is test-convenience only)
        src = (outputs == 0).all().astype(outputs.dtype)  # unused marker
        del src
        outputs = jax.lax.psum(
            jnp.where(stage == Pn - 1, outputs, jnp.zeros_like(outputs)), axis
        )
        return outputs

    spec_params = jax.tree_util.tree_map(lambda _: P(axis), stage_params)
    from repro.utils.compat import shard_map

    fn = shard_map(
        staged,
        mesh=mesh,
        in_specs=(spec_params, P()),      # stages sharded; input replicated
        out_specs=P(),
        check_vma=False,
    )
    return fn(stage_params, x_microbatches)


def bubble_fraction(num_stages: int, num_microbatches: int) -> float:
    return (num_stages - 1) / (num_microbatches + num_stages - 1)
