"""Checkpointing: atomic, async, elastic-restorable.

Layout (one directory per step):

  <dir>/step_000123/
      index.json        tree structure, shapes, dtypes, step, mesh note
      arrays.npz        flat {path -> ndarray} (host-gathered)
      COMMITTED         sentinel written LAST -> crash-safe atomicity

Design notes for real clusters (documented, simulated here single-host):
  * per-host shard files (arrays.<host>.npz) + a global index let 1000-node
    jobs write in parallel; restore re-shards via device_put with the target
    mesh's NamedShardings, so a checkpoint taken on N hosts restores onto M
    (elastic scaling).  The single-host code path below exercises exactly
    that reshard-on-restore logic against host meshes in tests.
  * async: save() snapshots to host then hands the write to a daemon thread;
    wait() joins before the next save or exit.
  * retention: keep the most recent ``keep`` committed steps.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from pathlib import Path
from typing import Any, Dict, Optional

import jax
import numpy as np

from repro.utils.logging import get_logger

log = get_logger("checkpoint")


def _flatten(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path
        )
        flat[key] = leaf
    return flat


class CheckpointManager:
    def __init__(self, directory: str | Path, keep: int = 3, async_write: bool = True):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self.async_write = async_write
        self._thread: Optional[threading.Thread] = None

    # -- save ---------------------------------------------------------------
    def save(self, state, step: int):
        self.wait()
        flat = _flatten(state)
        host = {}
        dtypes = {}
        for k, v in flat.items():
            a = np.asarray(v)
            dtypes[k] = a.dtype.name
            if a.dtype.name == "bfloat16":   # npz can't round-trip ml_dtypes
                a = a.view(np.uint16)
            host[k] = a
        index = {
            "step": int(step),
            "arrays": {
                k: {"shape": list(v.shape), "dtype": dtypes[k]}
                for k, v in host.items()
            },
        }

        def write():
            final = self.dir / f"step_{step:08d}"
            tmp = self.dir / f".tmp_step_{step:08d}"
            if tmp.exists():
                shutil.rmtree(tmp)
            tmp.mkdir(parents=True)
            np.savez(tmp / "arrays.npz", **host)
            (tmp / "index.json").write_text(json.dumps(index, indent=2))
            (tmp / "COMMITTED").write_text("ok")
            if final.exists():
                shutil.rmtree(final)
            os.replace(tmp, final)
            log.info("checkpoint step %d written to %s", step, final)
            self._gc()

        if self.async_write:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()
        else:
            write()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = self.all_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(self.dir / f"step_{s:08d}", ignore_errors=True)

    # -- restore ------------------------------------------------------------
    def all_steps(self):
        out = []
        for p in sorted(self.dir.glob("step_*")):
            if (p / "COMMITTED").exists():
                out.append(int(p.name.split("_")[1]))
        return out

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, state_like, step: Optional[int] = None, shardings=None):
        """Restore into the structure of ``state_like``.

        ``shardings``: optional pytree of NamedSharding for the TARGET mesh —
        this is the elastic path: arrays are host-loaded full-size and
        re-sharded onto whatever mesh the restarted job has.
        """
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no committed checkpoints in {self.dir}")
        d = self.dir / f"step_{step:08d}"
        arrays = np.load(d / "arrays.npz")
        index = json.loads((d / "index.json").read_text())
        flat_keys = list(_flatten(state_like).keys())
        missing = [k for k in flat_keys if k not in arrays.files]
        if missing:
            raise KeyError(f"checkpoint missing keys: {missing[:5]}...")

        leaves, treedef = jax.tree_util.tree_flatten(state_like)
        flat_shard = (
            treedef.flatten_up_to(shardings) if shardings is not None else [None] * len(leaves)
        )
        new_leaves = []
        for key, ref, sh in zip(flat_keys, leaves, flat_shard):
            arr = arrays[key]
            if index["arrays"][key]["dtype"] == "bfloat16":
                import ml_dtypes

                arr = arr.view(ml_dtypes.bfloat16)
            if tuple(arr.shape) != tuple(ref.shape):
                raise ValueError(f"{key}: shape {arr.shape} != expected {ref.shape}")
            arr = arr.astype(ref.dtype)
            new_leaves.append(jax.device_put(arr, sh) if sh is not None else jax.device_put(arr))
        return treedef.unflatten(new_leaves), step
