"""Execution policy for the ``repro.ot`` façade.

An :class:`ExecutionPlan` says HOW a :class:`~repro.ot.problem.Problem`
runs — gradient backend, round schedule, inner-optimizer tolerances,
batching and device policy — and absorbs the two legacy static-config
dataclasses (:class:`repro.core.solver.SolveOptions` and
:class:`repro.core.lbfgs.LbfgsOptions`) into one flat, JSON-able spec.

The mapping to the legacy options is exact and bijective
(:meth:`ExecutionPlan.solve_options` / :meth:`ExecutionPlan.from_solve_options`),
which is what lets the deprecated shims route through the façade while
staying bitwise-identical: the same ``SolveOptions`` reaches the same
jitted program.
"""
from __future__ import annotations

import dataclasses
from typing import Union

from repro.core.lbfgs import LbfgsOptions
from repro.core.solver import SolveOptions

GRAD_IMPLS = ("dense", "screened", "pallas", "fused")
PALLAS_IMPLS = ("grid", "compact", "auto")
BATCHING = ("auto", "solo", "batched")
GEOMETRIES = ("auto", "dense", "on_the_fly")
PRECISIONS = ("f32", "bf16")
SOLVERS = ("lbfgs", "stochastic")


@dataclasses.dataclass(frozen=True)
class ExecutionPlan:
    """Static execution policy (compiled programs specialize on it).

    Parameters
    ----------
    grad_impl : {'dense', 'screened', 'pallas', 'fused'}
        Gradient oracle backend (see :mod:`repro.core.solver`).
        ``'fused'`` runs the single-launch screen+gradient mega-kernel
        (verdicts computed in-register, DESIGN.md §10).
    pallas_impl : {'grid', 'compact', 'auto'}
        Kernel grid mode for ``grad_impl='pallas'``; for
        ``grad_impl='fused'`` it selects between the fused dense grid
        ('grid'), the two-launch reference ('compact') and the runtime
        live-tile-density switch ('auto').
    precision : {'f32', 'bf16'}
        Cost-operand storage for the pallas/fused backends — 'bf16'
        stores the prepared cost (or sample blocks) in bfloat16 while
        kernels upcast on load and accumulate T/psi in f32
        (docs/api.md "precision"; rejected for dense/screened).
    snapshot_every : int
        ``r`` in Algorithm 1 — L-BFGS iterations per screening round.
    max_rounds : int
        Cap on the number of rounds.
    tight_active_refresh : bool
        Beyond-paper tighter active-set refresh (off for paper fidelity).
    batching : {'auto', 'solo', 'batched'}
        How ``Executor.solve_many`` runs: one fused batched program
        (``'batched'``), one program per problem (``'solo'``), or batched
        unless there is exactly one problem (``'auto'``).
    devices : {'single', 'all'} or int
        Device policy: ``'single'`` stays on one device; ``'all'`` (or an
        int device count) runs batched solves under ``shard_map`` with the
        problem axis over a 1-D mesh (:mod:`repro.core.sharded`).
    geometry : {'auto', 'dense', 'on_the_fly'}
        Cost representation (docs/geometry.md).  ``'dense'`` materializes
        the (m_pad, n) cost; ``'on_the_fly'`` keeps squared-l2 sample-mode
        problems factorized and rebuilds cost tiles inside the Pallas
        kernels (other problem/backend combinations fall back to a
        chunked materialization); ``'auto'`` picks on-the-fly exactly when
        the problem is sample-mode, the backend is pallas, and the dense
        cost would exceed ``repro.ot.geometry.AUTO_ONTHEFLY_BYTES``.
    solver : {'lbfgs', 'stochastic'}
        Dual solver.  ``'lbfgs'`` (default) is the exact screened
        Algorithm-1 loop; ``'stochastic'`` is the minibatch dual-ascent
        scheme of :mod:`repro.core.stochastic` (column-block-sampled
        gradients, epoch-averaged duals, deterministic given
        ``sgd_seed``) for training-time workloads at large n.  The
        stochastic solver runs solo/batched only — sharded meshes and
        the round-stepped ``stream`` path require the exact solver.
    sgd_epochs, sgd_batch_blocks, sgd_block_cols, sgd_step_size,
    sgd_decay, sgd_avg_fraction, sgd_seed :
        Stochastic-solver schedule, field-for-field
        :class:`repro.core.stochastic.StochasticOptions` (ignored under
        ``solver='lbfgs'``; docs/training.md lists tuning guidance).
    history, max_iters, gtol, ftol, c1, c2, max_linesearch, init_step :
        Inner L-BFGS configuration, field-for-field
        :class:`repro.core.lbfgs.LbfgsOptions`.
    """

    grad_impl: str = "screened"
    pallas_impl: str = "auto"
    precision: str = "f32"
    snapshot_every: int = 10
    max_rounds: int = 200
    tight_active_refresh: bool = False
    batching: str = "auto"
    devices: Union[str, int] = "single"
    geometry: str = "auto"
    # dual solver selection + stochastic schedule (core/stochastic.py)
    solver: str = "lbfgs"
    sgd_epochs: int = 60
    sgd_batch_blocks: int = 2
    sgd_block_cols: int = 128
    sgd_step_size: float = 0.5
    sgd_decay: float = 0.02
    sgd_avg_fraction: float = 0.5
    sgd_seed: int = 0
    # inner optimizer (absorbs LbfgsOptions field-for-field)
    history: int = 10
    max_iters: int = 500
    gtol: float = 1e-6
    ftol: float = 1e-10
    c1: float = 1e-4
    c2: float = 0.9
    max_linesearch: int = 25
    init_step: float = 1.0

    def __post_init__(self):
        if self.grad_impl not in GRAD_IMPLS:
            raise ValueError(
                f"grad_impl must be one of {GRAD_IMPLS}, got {self.grad_impl!r}"
            )
        if self.pallas_impl not in PALLAS_IMPLS:
            raise ValueError(
                f"pallas_impl must be one of {PALLAS_IMPLS}, got {self.pallas_impl!r}"
            )
        if self.precision not in PRECISIONS:
            raise ValueError(
                f"precision must be one of {PRECISIONS}, got {self.precision!r}"
            )
        if self.precision == "bf16" and self.grad_impl not in ("pallas", "fused"):
            raise ValueError(
                "precision='bf16' requires grad_impl='pallas' or 'fused' "
                f"(got grad_impl={self.grad_impl!r})"
            )
        if self.batching not in BATCHING:
            raise ValueError(
                f"batching must be one of {BATCHING}, got {self.batching!r}"
            )
        if self.geometry not in GEOMETRIES:
            raise ValueError(
                f"geometry must be one of {GEOMETRIES}, got {self.geometry!r}"
            )
        if isinstance(self.devices, str):
            if self.devices not in ("single", "all"):
                raise ValueError(
                    f"devices must be 'single', 'all' or an int, got {self.devices!r}"
                )
        elif self.devices < 1:
            raise ValueError(f"devices count must be >= 1, got {self.devices}")
        if self.solver not in SOLVERS:
            raise ValueError(
                f"solver must be one of {SOLVERS}, got {self.solver!r}"
            )
        for name in ("snapshot_every", "max_rounds", "history", "max_iters",
                     "max_linesearch"):
            if getattr(self, name) < 1:
                raise ValueError(f"{name} must be >= 1, got {getattr(self, name)}")
        # validate the stochastic slice eagerly (StochasticOptions raises)
        self.stochastic_options()

    # -- legacy-option mapping (exact, bijective) ------------------------------
    def stochastic_options(self):
        """The ``sgd_*`` slice as a ``StochasticOptions`` (static jit arg)."""
        from repro.core.stochastic import StochasticOptions

        return StochasticOptions(
            epochs=self.sgd_epochs,
            batch_blocks=self.sgd_batch_blocks,
            block_cols=self.sgd_block_cols,
            step_size=self.sgd_step_size,
            decay=self.sgd_decay,
            avg_fraction=self.sgd_avg_fraction,
            seed=self.sgd_seed,
        )

    def lbfgs_options(self) -> LbfgsOptions:
        """The inner-optimizer slice as a legacy ``LbfgsOptions``."""
        return LbfgsOptions(
            history=self.history, max_iters=self.max_iters, gtol=self.gtol,
            ftol=self.ftol, c1=self.c1, c2=self.c2,
            max_linesearch=self.max_linesearch, init_step=self.init_step,
        )

    def solve_options(self) -> SolveOptions:
        """The solver slice as a legacy ``SolveOptions`` (static jit arg)."""
        return SolveOptions(
            snapshot_every=self.snapshot_every,
            max_rounds=self.max_rounds,
            grad_impl=self.grad_impl,
            pallas_impl=self.pallas_impl,
            tight_active_refresh=self.tight_active_refresh,
            precision=self.precision,
            lbfgs=self.lbfgs_options(),
        )

    @staticmethod
    def from_solve_options(
        opts: SolveOptions, *, batching: str = "auto",
        devices: Union[str, int] = "single",
    ) -> "ExecutionPlan":
        """Lift legacy ``SolveOptions`` into a plan (shims use this).

        Round-trips exactly: ``from_solve_options(o).solve_options() == o``.
        """
        lb = opts.lbfgs
        return ExecutionPlan(
            grad_impl=opts.grad_impl,
            pallas_impl=opts.pallas_impl,
            precision=opts.precision,
            snapshot_every=opts.snapshot_every,
            max_rounds=opts.max_rounds,
            tight_active_refresh=opts.tight_active_refresh,
            batching=batching,
            devices=devices,
            history=lb.history, max_iters=lb.max_iters, gtol=lb.gtol,
            ftol=lb.ftol, c1=lb.c1, c2=lb.c2,
            max_linesearch=lb.max_linesearch, init_step=lb.init_step,
        )

    # -- (de)serialization -----------------------------------------------------
    def config(self) -> dict:
        """JSON-able description; :meth:`from_config` inverts it exactly."""
        return dataclasses.asdict(self)

    @staticmethod
    def from_config(cfg: dict) -> "ExecutionPlan":
        """Rebuild an :class:`ExecutionPlan` from its :meth:`config` dict."""
        known = {f.name for f in dataclasses.fields(ExecutionPlan)}
        extra = set(cfg) - known
        if extra:
            raise ValueError(f"unknown ExecutionPlan config keys: {sorted(extra)}")
        return ExecutionPlan(**cfg)
