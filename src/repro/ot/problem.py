"""Declarative problem specification for the ``repro.ot`` façade.

A :class:`Problem` is a frozen, validated description of ONE regularized
OT instance — what to solve, never how to solve it (that is
:class:`repro.ot.plan.ExecutionPlan`).  Three construction modes cover
every entry point the repo previously exposed:

  * **samples**  — raw features + class labels (``Problem.from_samples``):
    the paper's experimental pipeline (squared-Euclidean cost, optional
    max-normalization, uniform marginals), previously
    ``core.ot.solve_groupsparse_ot``,
  * **cost**     — a precomputed ``(m, n)`` cost matrix + labels in the
    caller's row order (the serving engine's request payload),
  * **padded**   — arrays already in the canonical padded group layout of
    :mod:`repro.core.groups` (``Problem.from_padded``), previously the raw
    operands of ``solver.solve_dual`` / ``solve_batch``.

Whatever the mode, :meth:`Problem.padded` lowers to ONE canonical padded
form — ``(C_pad, a_pad, b, spec, perm)`` — with exactly the op sequence the
legacy entry points used, so a solve routed through the façade is bitwise
identical to the legacy paths (asserted by tests/test_facade.py).

Problems round-trip through JSON-able dicts (:meth:`Problem.config` /
:meth:`Problem.from_config`) so they can ride fixtures and request wires.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional, Tuple

import numpy as np

from repro.core import groups as G
from repro.core.regularizers import Regularizer, from_config as reg_from_config


class PaddedArrays(NamedTuple):
    """The canonical padded lowering of a :class:`Problem`.

    Attributes
    ----------
    C : np.ndarray
        ``(m_pad, n)`` float32 cost, rows sorted by group and padded.
    a : np.ndarray
        ``(m_pad,)`` float32 source marginal (zero mass on padding).
    b : np.ndarray
        ``(n,)`` float32 target marginal.
    spec : repro.core.groups.GroupSpec
        The padded group layout.
    perm : np.ndarray
        ``(m_pad,)`` padded-row -> original-row map (-1 = padding).
    """

    C: np.ndarray
    a: np.ndarray
    b: np.ndarray
    spec: G.GroupSpec
    perm: np.ndarray


@dataclasses.dataclass(frozen=True)
class SubmitOptions:
    """Per-request serving SLOs a :class:`Problem` can carry.

    The solver layers ignore these; the serving engine
    (:class:`repro.serving.ot_engine.OTServingEngine`) reads them at
    submission — they are the declarative form of the engine's
    ``submit(problem, deadline=..., priority=...)`` keywords, so a
    Problem can travel with its SLO through fixtures and request wires.

    Parameters
    ----------
    deadline : int, optional
        Tick budget: the request must reach a terminal status within
        this many engine ticks of submission or it is retired as
        ``DEADLINE_EXCEEDED``.  ``None`` = no deadline.
    priority : int
        Priority class; higher-priority requests are admitted first and
        shed last under overload (see
        :class:`repro.serving.policy.ServingPolicy`).
    """

    deadline: Optional[int] = None
    priority: int = 0

    def __post_init__(self):
        if self.deadline is not None and int(self.deadline) < 1:
            raise ValueError(
                f"deadline must be >= 1 ticks (or None), got {self.deadline}"
            )
        if not isinstance(self.priority, int):
            raise ValueError(f"priority must be an int, got {self.priority!r}")

    def config(self) -> dict:
        """JSON-able description; ``SubmitOptions(**cfg)`` inverts it."""
        return {"deadline": self.deadline, "priority": self.priority}


def _opt_array(x, dtype=None) -> Optional[np.ndarray]:
    if x is None:
        return None
    return np.asarray(x) if dtype is None else np.asarray(x, dtype)


def _maybe_list(x: Optional[np.ndarray]):
    return None if x is None else np.asarray(x).tolist()


@dataclasses.dataclass(frozen=True, eq=False)
class Problem:
    """One regularized OT instance, declaratively.

    Use the mode constructors (:meth:`from_samples`, :meth:`from_padded`)
    or pass a precomputed cost directly; validation runs at construction,
    so a malformed problem fails fast — before it can reach a compiled
    executor or poison a serving bucket.

    Parameters
    ----------
    reg : Regularizer
        The regularizer (any member of the thresholded soft-scale family,
        see :mod:`repro.core.regularizers`).  Compiled programs specialize
        on it, so it is part of the problem's geometry key.
    C : np.ndarray, optional
        ``(m, n)`` cost matrix — caller's row order (cost mode), or the
        padded layout when ``spec`` is given (padded mode).
    labels : np.ndarray, optional
        ``(m,)`` integer class labels (samples / cost modes).
    X_S, X_T : np.ndarray, optional
        ``(m, d)`` / ``(n, d)`` raw features (samples mode; the cost is
        derived as normalized squared-Euclidean distances).
    a, b : np.ndarray, optional
        Marginals; default uniform.  In padded mode ``a`` must already be
        padded (``(m_pad,)``, zero mass on padding).
    spec : GroupSpec, optional
        Explicit padded layout — giving it switches to padded mode.
    normalize_cost : bool
        Samples mode only: divide the cost by its max (paper pipeline).
    pad_to : int
        Group-size padding granularity for the derived layout.
    submit : SubmitOptions, optional
        Serving SLOs (deadline in ticks, priority class); ignored by the
        solver layers, consumed by the serving engine at submission.
    """

    reg: Regularizer
    C: Optional[np.ndarray] = None
    labels: Optional[np.ndarray] = None
    X_S: Optional[np.ndarray] = None
    X_T: Optional[np.ndarray] = None
    a: Optional[np.ndarray] = None
    b: Optional[np.ndarray] = None
    spec: Optional[G.GroupSpec] = None
    normalize_cost: bool = True
    pad_to: int = 8
    submit: Optional[SubmitOptions] = None

    def __post_init__(self):
        for name in ("C", "labels", "X_S", "X_T", "a", "b"):
            object.__setattr__(self, name, _opt_array(getattr(self, name)))
        self.validate()

    # -- constructors ---------------------------------------------------------
    @staticmethod
    def from_samples(
        X_S, y_S, X_T, reg: Regularizer, *,
        a=None, b=None, normalize_cost: bool = True, pad_to: int = 8,
    ) -> "Problem":
        """The paper's pipeline: features + labels -> squared-Euclidean OT."""
        return Problem(
            reg=reg, X_S=X_S, labels=y_S, X_T=X_T, a=a, b=b,
            normalize_cost=normalize_cost, pad_to=pad_to,
        )

    @staticmethod
    def from_padded(C_pad, a_pad, b, spec: G.GroupSpec, reg: Regularizer) -> "Problem":
        """Adopt arrays already in the canonical padded group layout."""
        return Problem(reg=reg, C=C_pad, a=a_pad, b=b, spec=spec)

    # -- validation -----------------------------------------------------------
    @property
    def mode(self) -> str:
        """``'samples'`` | ``'cost'`` | ``'padded'`` — the construction mode."""
        if self.spec is not None:
            return "padded"
        return "samples" if self.X_S is not None else "cost"

    def validate(self) -> None:
        """Raise ``ValueError`` on any inconsistency (shapes, modes, reg)."""
        if not isinstance(self.reg, Regularizer):
            raise ValueError(f"reg must be a Regularizer, got {type(self.reg).__name__}")
        if self.pad_to < 1:
            raise ValueError(f"pad_to must be >= 1, got {self.pad_to}")
        has_samples = self.X_S is not None or self.X_T is not None
        if has_samples and (self.X_S is None or self.X_T is None):
            raise ValueError("samples mode needs both X_S and X_T")
        if has_samples and self.C is not None:
            raise ValueError("provide raw samples OR a precomputed cost, not both")
        if not has_samples and self.C is None:
            raise ValueError("provide raw samples (X_S, X_T) or a cost matrix C")
        if self.C is not None and self.C.ndim != 2:
            raise ValueError(f"C must be 2-D (m, n), got shape {self.C.shape}")

        if self.spec is not None:                      # padded mode
            if has_samples:
                raise ValueError("padded mode (spec given) is incompatible with samples")
            if self.labels is not None:
                raise ValueError("padded mode derives its layout from spec, not labels")
            if self.C.shape[0] != self.spec.m_pad:
                raise ValueError(
                    f"padded C has {self.C.shape[0]} rows, spec expects m_pad="
                    f"{self.spec.m_pad}"
                )
            if self.a is None or self.b is None:
                raise ValueError("padded mode requires explicit marginals a and b")
            if self.a.shape != (self.spec.m_pad,):
                raise ValueError(
                    f"padded a must have shape ({self.spec.m_pad},), got {self.a.shape}"
                )
        else:
            if self.labels is None:
                raise ValueError("samples/cost modes need integer class labels")
            m = self.X_S.shape[0] if has_samples else self.C.shape[0]
            if self.labels.shape != (m,):
                raise ValueError(
                    f"labels must have shape ({m},), got {self.labels.shape}"
                )
            if has_samples and self.X_S.shape[1:] != self.X_T.shape[1:]:
                raise ValueError(
                    f"X_S and X_T feature dims differ: {self.X_S.shape} vs "
                    f"{self.X_T.shape}"
                )
            if self.a is not None and self.a.shape != (m,):
                raise ValueError(f"a must have shape ({m},), got {self.a.shape}")
        n = self.num_target
        if self.b is not None and self.b.shape != (n,):
            raise ValueError(f"b must have shape ({n},), got {self.b.shape}")
        for name in ("a", "b"):
            v = getattr(self, name)
            if v is not None and np.any(np.asarray(v) < 0):
                raise ValueError(f"marginal {name} has negative entries")
        # non-finite inputs must fail HERE, with a nameable field — not
        # flow into the kernels and surface as a silent NaN objective (or
        # poison a serving bucket).  Admission-time validation is the
        # first rung of the serving engine's failure quarantine.
        for name in ("C", "X_S", "X_T", "a", "b"):
            v = getattr(self, name)
            if v is not None and not np.all(np.isfinite(v)):
                raise ValueError(
                    f"{name} contains non-finite entries (NaN or inf); "
                    "refusing to construct a Problem that cannot be solved"
                )
        if self.submit is not None and not isinstance(self.submit, SubmitOptions):
            raise ValueError(
                f"submit must be a SubmitOptions, got {type(self.submit).__name__}"
            )
        # per-group regularizer parameters must fit THIS problem's layout
        self.reg.mu_vec(self.group_spec().num_groups)

    # -- derived geometry -----------------------------------------------------
    @property
    def num_source(self) -> int:
        """``m`` — true (unpadded) number of source samples."""
        if self.spec is not None:
            return self.spec.m
        return self.X_S.shape[0] if self.X_S is not None else self.C.shape[0]

    @property
    def num_target(self) -> int:
        """``n`` — number of target samples / cost columns."""
        return self.X_T.shape[0] if self.X_T is not None else self.C.shape[1]

    def group_spec(self) -> G.GroupSpec:
        """The padded group layout (explicit, or derived from the labels).

        The derived spec is memoized on the instance (frozen fields never
        change), so the serving hot path — which consults the layout at
        validation, bucketing and admission — sorts the labels once.
        """
        if self.spec is not None:
            return self.spec
        cached = self.__dict__.get("_derived_spec")
        if cached is None:
            cached = G.spec_from_labels(self.labels, pad_to=self.pad_to)
            object.__setattr__(self, "_derived_spec", cached)
        return cached

    def geometry(self) -> Tuple[int, int, int]:
        """``(L, g_pad, n)`` — the static shape a program compiles for."""
        spec = self.group_spec()
        return (spec.num_groups, spec.group_size, self.num_target)

    # -- canonical lowering ---------------------------------------------------
    def cost(self, dtype=np.float32) -> np.ndarray:
        """The ``(m, n)`` cost in the problem's own row order.

        Samples mode computes it with exactly the legacy
        ``solve_groupsparse_ot`` op sequence (squared-Euclidean, float32
        cast, then max-normalization) so façade solves stay bitwise equal
        to the pre-façade pipeline; ``dtype`` (the serving engine passes
        its slot dtype) only recasts the final array.
        """
        if self.C is not None:
            return np.asarray(self.C, dtype)
        from repro.core.ot import squared_euclidean_cost

        C = squared_euclidean_cost(self.X_S, self.X_T).astype(np.float32)
        if self.normalize_cost:
            C = C / max(C.max(), 1e-12)
        return C if C.dtype == dtype else C.astype(dtype)

    def padded(self, dtype=np.float32) -> PaddedArrays:
        """Lower to the canonical padded form every solver layer consumes.

        ``dtype`` is the storage dtype of the returned arrays (default
        float32, the solver convention).  The serving engine passes its
        own slot dtype, so precomputed costs and marginals reach
        non-float32 engines untruncated; the samples-mode cost derivation
        stays pinned to the legacy float32 pipeline (bitwise parity) and
        is only recast afterwards.
        """
        spec = self.group_spec()
        m, n = self.num_source, self.num_target
        if self.spec is not None:                      # already padded
            perm = np.full((spec.m_pad,), -1, np.int64)
            perm[spec.row_mask().reshape(-1)] = np.arange(m)
            return PaddedArrays(
                np.asarray(self.C, dtype), np.asarray(self.a, dtype),
                np.asarray(self.b, dtype), spec, perm,
            )
        C = self.cost(dtype)
        a = self.a if self.a is not None else np.full((m,), 1.0 / m, dtype)
        b = self.b if self.b is not None else np.full((n,), 1.0 / n, dtype)
        return PaddedArrays(
            G.pad_cost_matrix(C, self.labels, spec),
            G.pad_marginal(np.asarray(a, dtype), self.labels, spec),
            np.asarray(b, dtype),
            spec,
            G.padded_perm(self.labels, spec),
        )

    def materialized(self, chunk_rows: Optional[int] = None) -> "Problem":
        """Samples-mode -> cost-mode Problem with the FACTORIZED-recipe cost.

        The returned problem carries the dense cost the materialization-
        free route would see — built chunk-wise with
        :meth:`repro.ot.geometry.SquaredL2Geometry.materialize` and
        un-permuted back to this problem's row order — so solving it on
        the dense geometry is bitwise-comparable to solving ``self`` on
        the on-the-fly geometry (the assertion examples/quickstart.py
        makes).  NOTE this is the kernels' f32 recipe, not the legacy f64
        ``core.ot.squared_euclidean_cost`` pipeline; the two agree only to
        f32 tolerance (docs/geometry.md).  Non-samples problems are
        returned unchanged.

        Parameters
        ----------
        chunk_rows : int, optional
            Row-chunk size for the streamed materialization (bounds peak
            memory; any value yields identical bits).
        """
        if self.mode != "samples":
            return self
        from repro.ot.geometry import SquaredL2Geometry

        spec = self.group_spec()
        geom = SquaredL2Geometry.from_samples(
            self.X_S, self.labels, self.X_T, spec,
            normalize_cost=self.normalize_cost, chunk_rows=chunk_rows,
        )
        C_pad = geom.materialize(chunk_rows)
        perm = G.padded_perm(self.labels, spec)
        real = perm >= 0
        C = np.empty((self.num_source, self.num_target), np.float32)
        C[perm[real]] = C_pad[real]
        return Problem(
            reg=self.reg, C=C, labels=self.labels, a=self.a, b=self.b,
            normalize_cost=self.normalize_cost, pad_to=self.pad_to,
            submit=self.submit,
        )

    # -- (de)serialization + equality -----------------------------------------
    def config(self) -> dict:
        """JSON-able description; :meth:`from_config` inverts it exactly."""
        cfg = {
            "mode": self.mode,
            "reg": self.reg.config(),
            "normalize_cost": bool(self.normalize_cost),
            "pad_to": int(self.pad_to),
        }
        dtypes = {}
        for name in ("C", "labels", "X_S", "X_T", "a", "b"):
            v = getattr(self, name)
            if v is not None:
                cfg[name] = _maybe_list(v)
                dtypes[name] = str(np.asarray(v).dtype)
        if dtypes:
            cfg["dtypes"] = dtypes
        if self.spec is not None:
            cfg["spec"] = {
                "num_groups": self.spec.num_groups,
                "group_size": self.spec.group_size,
                "sizes": list(self.spec.sizes),
                "m": self.spec.m,
            }
        if self.submit is not None:
            cfg["submit"] = self.submit.config()
        return cfg

    @staticmethod
    def from_config(cfg: dict) -> "Problem":
        """Rebuild a :class:`Problem` from its :meth:`config` dict."""
        cfg = dict(cfg)
        cfg.pop("mode", None)
        reg = reg_from_config(cfg.pop("reg"))
        submit = cfg.pop("submit", None)
        if submit is not None:
            submit = SubmitOptions(**submit)
        spec = cfg.pop("spec", None)
        if spec is not None:
            spec = G.GroupSpec(
                num_groups=int(spec["num_groups"]),
                group_size=int(spec["group_size"]),
                sizes=tuple(int(s) for s in spec["sizes"]),
                m=int(spec["m"]),
            )
        # restore each array at its recorded dtype — a float32-samples
        # problem must rebuild bitwise-identical (its cost derivation is
        # dtype-sensitive); older configs without the record fall back to
        # the canonical dtypes
        dtypes = cfg.pop("dtypes", {})
        defaults = {
            "C": np.float32, "labels": np.int64, "X_S": np.float64,
            "X_T": np.float64, "a": np.float32, "b": np.float32,
        }
        arrays = {}
        for name, default in defaults.items():
            if name in cfg:
                dtype = np.dtype(dtypes[name]) if name in dtypes else default
                arrays[name] = np.asarray(cfg.pop(name), dtype)
        return Problem(reg=reg, spec=spec, submit=submit, **arrays, **cfg)

    def __eq__(self, other) -> bool:
        """Field-wise equality (arrays compared by value)."""
        if not isinstance(other, Problem):
            return NotImplemented
        for f in dataclasses.fields(self):
            va, vb = getattr(self, f.name), getattr(other, f.name)
            if isinstance(va, np.ndarray) or isinstance(vb, np.ndarray):
                if va is None or vb is None or not np.array_equal(va, vb):
                    return False
            elif va != vb:
                return False
        return True

    def __hash__(self) -> int:
        """Value hash consistent with :meth:`__eq__` (array bytes included).

        Problems are frozen, so hashing over the field values is sound;
        this keeps them usable as dict/set keys (e.g. template caches)
        despite the custom ``__eq__``.  Arrays hash through a float64
        normalization so that value-equal arrays of different dtypes —
        which ``__eq__`` (``np.array_equal``) treats as equal — hash
        equal too.  Cost is O(total array bytes), so don't key hot
        per-tick maps on large problems.
        """
        parts = []
        for f in dataclasses.fields(self):
            v = getattr(self, f.name)
            if isinstance(v, np.ndarray):
                canon = np.ascontiguousarray(v, np.float64)
                parts.append((f.name, v.shape, canon.tobytes()))
            else:
                parts.append((f.name, v))
        return hash(tuple(parts))
