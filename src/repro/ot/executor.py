"""Compile a (Problem template, ExecutionPlan) into a reusable Executor.

The façade's execution layer: :func:`compile` fixes the static geometry —
group layout, column width, regularizer, solver options — and returns an
:class:`Executor` whose methods route to the SAME jitted programs the
legacy entry points used:

  * :meth:`Executor.solve`       -> the solo program (``solver._solve_jit``),
  * :meth:`Executor.solve_many`  -> the batched program
    (``solver._solve_batch_jit``), or the ``shard_map`` program of
    :mod:`repro.core.sharded` when a device mesh is attached,
  * :meth:`Executor.stream`      -> the round-step API
    (``init_batch_state`` / ``batch_round``), one fused round per step.

Because the static jit arguments and operands are constructed with exactly
the legacy op sequence, a solve routed through the façade is *bitwise*
identical to the corresponding legacy entry point — same objectives, same
plans, same round counts (asserted per regularizer kind and per
``grad_impl`` backend by tests/test_facade.py).

Executors own their diagnostics: :meth:`Executor.stats` counts program
launches and solves per executor instance (concurrent executors never
share mutable counter state), and :meth:`Executor.describe` renders the
geometry/backend diagnostic block.
"""
from __future__ import annotations

from typing import List, NamedTuple, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import groups as G
from repro.core import solver as slv
from repro.core.dual import DualProblem
from repro.core.regularizers import Regularizer
from repro.ot.plan import ExecutionPlan
from repro.ot.problem import Problem
from repro.ot.solution import Solution, build_solution
from repro.serving.policy import TERMINAL_STATUSES


class _Prepared(NamedTuple):
    """One problem lowered to the executor's template geometry.

    Exactly one of ``C`` / ``geom`` is set: dense-routed problems carry the
    materialized ``(m_pad, n_tpl)`` cost, on-the-fly-routed problems carry
    the factorized :class:`~repro.ot.geometry.SquaredL2Geometry` instead
    (the dense cost is only ever rebuilt chunk-wise at solution assembly).
    """

    C: Optional[np.ndarray]  # (m_pad, n_tpl) float32, columns padded if needed
    a: np.ndarray          # (m_pad,)
    b: np.ndarray          # (n_tpl,)
    spec: G.GroupSpec      # the problem's own layout (sizes may differ)
    perm: np.ndarray       # (m_pad,) padded-row -> original-row
    n: int                 # the problem's true column count
    geom: Optional[object] = None   # SquaredL2Geometry on the on-the-fly route


def compile(
    problem: Problem,
    plan: Optional[ExecutionPlan] = None,
    mesh=None,
) -> "Executor":
    """Compile a problem template + plan into a reusable :class:`Executor`.

    Parameters
    ----------
    problem : Problem
        The template: its group layout ``(L, g_pad)``, column count ``n``
        and regularizer become the static geometry every solve through
        this executor must match (columns may be narrower — they are
        padded up to the template width).
    plan : ExecutionPlan, optional
        Execution policy; defaults to ``ExecutionPlan()``.
    mesh : jax.sharding.Mesh, optional
        Explicit 1-D batch mesh for sharded execution.  When omitted, the
        plan's ``devices`` policy decides: ``'single'`` stays unsharded,
        ``'all'`` / an int builds a default mesh via
        :func:`repro.core.distributed.make_batch_mesh`.

    Returns
    -------
    Executor
        Ready to ``solve`` / ``solve_many`` / ``stream`` any compatible
        problem; jit compilation itself happens lazily on first use and is
        shared process-wide through the jax program cache.
    """
    plan = plan if plan is not None else ExecutionPlan()
    if mesh is None and plan.devices != "single":
        from repro.core.distributed import make_batch_mesh

        mesh = make_batch_mesh(None if plan.devices == "all" else int(plan.devices))
    return Executor(
        problem.group_spec(), problem.num_target, problem.reg, plan,
        mesh=mesh, template=problem,
    )


def solve(problem: Problem, plan: Optional[ExecutionPlan] = None, mesh=None) -> Solution:
    """One-shot convenience: ``compile(problem, plan, mesh).solve()``.

    The heavyweight work (jitted programs) is cached process-wide by jax,
    so repeated one-shot solves of same-geometry problems do not
    recompile; hold an :class:`Executor` only when you want its stats or
    the round-step stream.
    """
    return compile(problem, plan, mesh).solve(problem)


class Executor:
    """A compiled, reusable solver for one problem geometry.

    Built by :func:`compile`; see the module docstring for the routing
    map.  All methods accept any :class:`~repro.ot.problem.Problem` whose
    ``(L, g_pad)`` layout and regularizer match the template (narrower
    column counts are padded up to the template width with zero-mass
    ``PAD_COST`` columns, which is exact — padded columns carry an
    identically-zero plan column and dual gradient).
    """

    def __init__(self, spec: G.GroupSpec, n: int, reg: Regularizer,
                 plan: ExecutionPlan, mesh=None, template: Optional[Problem] = None):
        self._spec = spec
        self._n = int(n)
        self._reg = reg
        self._plan = plan
        self._mesh = mesh
        self._template = template
        self._prob = DualProblem(
            num_groups=spec.num_groups, group_size=spec.group_size,
            n=self._n, reg=reg,
        )
        self._opts = plan.solve_options()
        self._sopts = (
            plan.stochastic_options() if plan.solver == "stochastic" else None
        )
        if self._sopts is not None and mesh is not None:
            raise ValueError(
                "solver='stochastic' runs solo/batched only; sharded meshes "
                "require the exact solver (ExecutionPlan(solver='lbfgs'))."
            )
        self._counters = {
            "launches": 0, "solves": 0, "problems_solved": 0, "rounds_total": 0,
            "retry_attempts": 0,
            "status": {s.value: 0 for s in TERMINAL_STATUSES},
        }

    # -- introspection --------------------------------------------------------
    @property
    def plan(self) -> ExecutionPlan:
        """The execution plan this executor was compiled with."""
        return self._plan

    @property
    def spec(self) -> G.GroupSpec:
        """The template group layout ``(L, g_pad)``."""
        return self._spec

    @property
    def num_target(self) -> int:
        """The compiled column width ``n``."""
        return self._n

    @property
    def reg(self) -> Regularizer:
        """The regularizer the programs specialize on."""
        return self._reg

    @property
    def mesh(self):
        """The attached device mesh (None = unsharded)."""
        return self._mesh

    def stats(self) -> dict:
        """Per-executor counters (no module-global state is involved).

        Returns
        -------
        dict
            ``launches`` — host->device program launches issued by this
            executor; ``solves`` — ``solve``/``solve_many``/``stream``
            completions; ``problems_solved`` — problems across them;
            ``rounds_total`` — Algorithm-1 rounds summed over problems;
            ``status`` — per-terminal-status problem counts using the
            serving state machine's vocabulary (an executor only ever
            produces ``DONE`` — converged, or retired at the round cap —
            and ``FAILED`` — the L-BFGS failure flag or a non-finite
            objective; ``SHED`` / ``DEADLINE_EXCEEDED`` need the serving
            engine's admission queue and are always 0 here, kept so the
            two stats dicts share one schema); ``retry_attempts`` —
            always 0 here, same schema note (retries are the engine's
            quarantine ladder).  Concurrent executors never share this
            state (the legacy module-level ``solver.dispatch_count``
            keeps aggregating process-wide for back-compat).
        """
        out = dict(self._counters)
        out["status"] = dict(self._counters["status"])
        return out

    def describe(self, result=None) -> str:
        """Geometry/backend diagnostic block (see ``solver.describe``).

        Ends with this executor's lifetime health line: per-terminal-
        status problem counts (DONE / FAILED) and retry totals, in the
        same vocabulary :meth:`OTServingEngine.describe` uses.

        Parameters
        ----------
        result : Solution, OTResult or BatchOTResult, optional
            When given, appends convergence + screening-verdict totals.
        """
        if isinstance(result, Solution):
            result = result.result
        base = slv.describe(self._spec, self._n, self._reg, self._opts, result)
        geom = f"geometry: plan={self._plan.geometry}"
        if self._template is not None:
            geom += f" -> route={self._route(self._template)} (template)"
        base = f"{base}\n{geom}"
        st = self._counters["status"]
        return (
            f"{base}\n"
            f"health:   done={st['DONE']} failed={st['FAILED']} "
            f"retries={self._counters['retry_attempts']} "
            f"solves={self._counters['solves']}"
        )

    # -- launch bookkeeping ---------------------------------------------------
    def _launch(self, fn, *args):
        """Run one jitted program, counting it here AND process-wide."""
        self._counters["launches"] += 1
        slv._DISPATCHES["count"] += 1
        return fn(*args)

    def _record(self, rounds, failed=None) -> None:
        self._counters["solves"] += 1
        n = int(np.size(rounds))
        self._counters["problems_solved"] += n
        self._counters["rounds_total"] += int(np.sum(np.asarray(rounds)))
        # terminal-status split: the L-BFGS failed flag (which the solver
        # also raises on a non-finite objective) is FAILED, all else DONE
        nf = int(np.sum(np.asarray(failed))) if failed is not None else 0
        self._counters["status"]["FAILED"] += nf
        self._counters["status"]["DONE"] += n - nf

    # -- problem lowering -----------------------------------------------------
    def _route(self, problem: Problem) -> str:
        """Resolve the plan's geometry policy for ONE problem.

        Returns ``'dense'`` (legacy materialized cost), ``'factorized'``
        (keep samples factorized, lower in the Pallas kernels) or
        ``'materialize'`` (build the factorized geometry, then materialize
        it chunk-wise — the fallback that gives the dense/screened
        reference backends the exact same cost bits as the kernels see).
        See docs/geometry.md for the decision table.
        """
        from repro.ot import geometry as geo

        sel = self._plan.geometry
        if sel == "dense":
            return "dense"
        samples = problem.mode == "samples"
        pallas = self._plan.grad_impl in ("pallas", "fused")
        if sel == "on_the_fly":
            if not samples:
                return "dense"          # generic costs: nothing to factorize
            return "factorized" if pallas else "materialize"
        # 'auto': on-the-fly only where it pays — sample-mode problems on
        # the pallas backend whose dense cost would be HBM-significant;
        # everything else keeps the legacy dense numerics bit-for-bit.
        if samples and pallas:
            if self._spec.m_pad * self._n * 4 > geo.AUTO_ONTHEFLY_BYTES:
                return "factorized"
        return "dense"

    def _prepare_factorized(self, problem: Problem, route: str) -> _Prepared:
        """Sample-mode lowering that never builds the (m, n) cost.

        Marginals, permutation and layout checks replicate
        ``Problem.padded`` exactly; only the cost pipeline is swapped for
        :class:`~repro.ot.geometry.SquaredL2Geometry`.  ``route=
        'materialize'`` chunk-materializes the geometry at the end (for
        the non-pallas backends) so every backend sees identical bits.
        """
        from repro.ot.geometry import SquaredL2Geometry

        spec = problem.group_spec()
        L, g = spec.num_groups, spec.group_size
        if (L, g) != (self._spec.num_groups, self._spec.group_size):
            raise ValueError(
                f"problem layout (L={L}, g_pad={g}) does not match the "
                f"executor template (L={self._spec.num_groups}, "
                f"g_pad={self._spec.group_size})"
            )
        m, n = problem.num_source, problem.num_target
        if n > self._n:
            raise ValueError(
                f"problem has {n} target columns but the executor compiled "
                f"for {self._n}; re-compile with the wider template"
            )
        geom = SquaredL2Geometry.from_samples(
            problem.X_S, problem.labels, problem.X_T, spec,
            normalize_cost=problem.normalize_cost,
        )
        b = problem.b if problem.b is not None else np.full((n,), 1.0 / n, np.float32)
        b = np.asarray(b, np.float32)
        if n < self._n:                      # auto-pad columns up to template
            geom = geom.pad_columns(self._n)
            bf = np.zeros((self._n,), np.float32)
            bf[:n] = b
            b = bf
        a = problem.a if problem.a is not None else np.full((m,), 1.0 / m, np.float32)
        a_pad = G.pad_marginal(np.asarray(a, np.float32), problem.labels, spec)
        perm = G.padded_perm(problem.labels, spec)
        if route == "materialize":
            return _Prepared(geom.materialize(), a_pad, b, spec, perm, n)
        return _Prepared(None, a_pad, b, spec, perm, n, geom=geom)

    def _prepare(self, problem: Problem) -> _Prepared:
        """Validate compatibility and lower to the template geometry."""
        if problem.reg != self._reg:
            raise ValueError(
                f"problem regularizer {problem.reg!r} does not match the "
                f"executor's {self._reg!r} (programs specialize on it)"
            )
        route = self._route(problem)
        if route != "dense":
            return self._prepare_factorized(problem, route)
        pa = problem.padded()
        L, g = pa.spec.num_groups, pa.spec.group_size
        if (L, g) != (self._spec.num_groups, self._spec.group_size):
            raise ValueError(
                f"problem layout (L={L}, g_pad={g}) does not match the "
                f"executor template (L={self._spec.num_groups}, "
                f"g_pad={self._spec.group_size})"
            )
        n = int(pa.C.shape[1])
        if n > self._n:
            raise ValueError(
                f"problem has {n} target columns but the executor compiled "
                f"for {self._n}; re-compile with the wider template"
            )
        C, b = pa.C, pa.b
        if n < self._n:                      # auto-pad columns up to template
            Cf = np.full((C.shape[0], self._n), G.PAD_COST, np.float32)
            Cf[:, :n] = C
            bf = np.zeros((self._n,), np.float32)
            bf[:n] = b
            C, b = Cf, bf
        return _Prepared(C, pa.a, b, pa.spec, pa.perm, n)

    def _stack(self, problems: Sequence[Problem]):
        """Lower + stack a batch; the host cost stack is returned too (it
        is the largest allocation of a solve — build it exactly once).

        A batch where EVERY problem took the factorized route stacks the
        four sample/norm leaves into one batched
        :class:`~repro.kernels.ops.FactorizedCost` and returns
        ``C_host=None`` (no dense stack exists).  A mixed batch
        materializes its factorized members chunk-wise first — bitwise
        harmless, since materialization and the kernels share one cost
        recipe (docs/geometry.md)."""
        preps = [self._prepare(p) for p in problems]
        if any(p.geom is not None for p in preps) and not all(
            p.geom is not None for p in preps
        ):
            preps = [
                p._replace(C=p.geom.materialize(), geom=None)
                if p.geom is not None else p
                for p in preps
            ]
        if preps and all(p.geom is not None for p in preps):
            dims = {p.geom.dim for p in preps}
            if len(dims) > 1:
                raise ValueError(
                    f"cannot batch factorized problems with different "
                    f"feature dims {sorted(dims)}; materialize or split"
                )
            from repro.kernels import ops as kops

            C_host = None
            C = kops.FactorizedCost(
                x=jnp.asarray(np.stack([p.geom.x for p in preps])),
                x_sq=jnp.asarray(np.stack([p.geom.x_sq for p in preps])),
                y=jnp.asarray(np.stack([p.geom.y for p in preps])),
                y_sq=jnp.asarray(np.stack([p.geom.y_sq for p in preps])),
            )
        else:
            C_host = np.stack([p.C for p in preps])
            C = jnp.asarray(C_host)
        a = jnp.asarray(np.stack([p.a for p in preps]))
        b = jnp.asarray(np.stack([p.b for p in preps]))
        shared = all(p.spec == self._spec for p in preps)
        if shared:
            row_mask = jnp.asarray(self._spec.row_mask().reshape(-1))
            sqrt_g = jnp.asarray(self._spec.sqrt_sizes(), C.dtype)
        else:
            row_mask = jnp.asarray(
                np.stack([p.spec.row_mask().reshape(-1) for p in preps])
            )
            sqrt_g = jnp.asarray(
                np.stack([p.spec.sqrt_sizes() for p in preps]).astype(np.float32)
            )
        return preps, C_host, C, a, b, row_mask, sqrt_g

    # -- raw padded-batch launches (shims + solve_many share these) ------------
    def _solve_padded_batch(self, C, a, b, row_mask=None, sqrt_g=None):
        """One fused batched solve; legacy ``(lb, scr, rounds, stats)`` tuple.

        ``row_mask`` / ``sqrt_g`` default to the template's shared forms —
        exactly the operands the legacy ``solve_batch`` passed, so the
        jitted program (and its cache entry) is the same.
        """
        if row_mask is None:
            row_mask = jnp.asarray(self._spec.row_mask().reshape(-1))
        if sqrt_g is None:
            sqrt_g = jnp.asarray(self._spec.sqrt_sizes(), C.dtype)
        if self._sopts is not None:
            from repro.core import stochastic as sgd

            return self._launch(
                sgd._sgd_solve_batch_jit, C, a, b, row_mask, sqrt_g,
                self._prob, self._opts, self._sopts,
            )
        return self._launch(
            slv._solve_batch_jit, C, a, b, row_mask, sqrt_g, self._prob, self._opts
        )

    def _solve_padded_batch_sharded(self, C, a, b, row_mask=None, sqrt_g=None):
        """One sharded batched solve (mesh required); legacy output tuple.

        Replicates ``core.sharded.solve_batch_sharded`` step for step:
        per-problem broadcast, ragged-batch padding with zero-gradient
        dummies, mesh placement, ONE program launch, un-pad.
        """
        from repro.core import sharded as shd

        assert self._mesh is not None, "sharded launch without a mesh"
        assert (row_mask is None) == (sqrt_g is None), \
            "pass row_mask and sqrt_g together or not at all"
        B = C.shape[0]
        if row_mask is None:
            row_mask = jnp.asarray(self._spec.row_mask().reshape(-1))
            sqrt_g = jnp.asarray(self._spec.sqrt_sizes(), C.dtype)
        if row_mask.ndim == 1:
            # shared forms cannot shard over the problem axis; the exact
            # broadcast preserves bitwise parity (see core.sharded)
            row_mask = jnp.broadcast_to(row_mask, (B, self._prob.m_pad))
            sqrt_g = jnp.broadcast_to(sqrt_g, (B, self._prob.num_groups))
        C, a, b, row_mask, sqrt_g, B = shd.pad_batch_to_devices(
            jax.tree_util.tree_map(jnp.asarray, C),   # dense array OR
            jnp.asarray(a), jnp.asarray(b), row_mask, sqrt_g,   # FactorizedCost
            self._mesh.size,
        )
        args = shd.device_put_batch((C, a, b, row_mask, sqrt_g), self._mesh)
        solve_fn, _, _ = shd._sharded_programs(self._mesh, self._prob, self._opts)
        lb, scr, rounds, stats = self._launch(solve_fn, *args)
        if B != C.shape[0]:              # drop the dummy padding problems
            cut = lambda t: jax.tree_util.tree_map(lambda v: v[:B], t)
            lb, scr, rounds, stats = cut(lb), cut(scr), rounds[:B], stats[:B]
        return lb, scr, rounds, stats

    def _as_batch_result(self, lb, scr, rounds, stats) -> slv.BatchOTResult:
        """Wrap raw batched state into the legacy result container."""
        alpha, beta = slv._split(lb.x, self._prob.m_pad)
        return slv.BatchOTResult(alpha, beta, -lb.f, lb, scr, rounds, stats)

    def _wrap_batch(self, preps, C_host, batch: slv.BatchOTResult) -> List[Solution]:
        """Slice a batched result into per-problem :class:`Solution`\\ s.

        Plan recovery runs ONCE for the whole batch (one ``plan_from_duals``
        launch over the leading axis) instead of one small program + gather
        per problem — the dual ops are batch-polymorphic, so the per-problem
        slices are bitwise those of a solo recovery.

        On the factorized route (``C_host is None``) the dense cost exists
        nowhere until here: each problem's cost is chunk-materialized one
        at a time for plan recovery + solution assembly, bounding peak
        host memory at one ``(m_pad, n)`` block.  Per-problem recovery is
        bitwise the batched recovery's slice (same batch-polymorphic ops).
        """
        from repro.core.dual import plan_from_duals

        if C_host is None:
            out = []
            for i, p in enumerate(preps):
                C_i = p.geom.materialize()
                T_i = np.asarray(plan_from_duals(
                    batch.alpha[i], batch.beta[i], jnp.asarray(C_i), self._prob
                ))
                out.append(build_solution(
                    batch[i], self._reg, C_i, p.spec, p.perm, p.n, T_pad=T_i
                ))
            return out
        T_all = np.asarray(plan_from_duals(
            batch.alpha, batch.beta, jnp.asarray(C_host), self._prob
        ))
        return [
            build_solution(batch[i], self._reg, C_host[i], p.spec, p.perm, p.n,
                           T_pad=T_all[i])
            for i, p in enumerate(preps)
        ]

    # -- public execution -----------------------------------------------------
    def solve(self, problem: Optional[Problem] = None) -> Solution:
        """Solve ONE problem with the solo program (B = 1 slice).

        Parameters
        ----------
        problem : Problem, optional
            Defaults to the template problem the executor was compiled
            from.

        Returns
        -------
        Solution
            Bitwise-identical to the legacy ``solver.solve_dual`` on the
            same padded operands (same jitted program, same inputs).
        """
        problem = problem if problem is not None else self._template
        if problem is None:
            raise ValueError("no problem given and the executor has no template")
        p = self._prepare(problem)
        if p.geom is not None:
            from repro.kernels import ops as kops

            fc = kops.FactorizedCost(
                *(jnp.asarray(v) for v in p.geom.operands())
            )
            result = self._solve_solo(
                fc, jnp.asarray(p.a), jnp.asarray(p.b), p.spec
            )
            self._record(result.rounds, failed=result.lbfgs_state.failed)
            # the dense cost exists only here, chunk-built for assembly
            return build_solution(
                result, self._reg, p.geom.materialize(), p.spec, p.perm, p.n
            )
        result = self._solve_solo(
            jnp.asarray(p.C), jnp.asarray(p.a), jnp.asarray(p.b), p.spec
        )
        self._record(result.rounds, failed=result.lbfgs_state.failed)
        return build_solution(result, self._reg, p.C, p.spec, p.perm, p.n)

    def _solve_solo(self, C, a, b, spec) -> slv.OTResult:
        """Route one solo solve through the plan's dual solver."""
        if self._sopts is not None:
            from repro.core import stochastic as sgd

            return sgd.solve_solo(
                C, a, b, spec, self._reg, self._opts, self._sopts, self._launch
            )
        return slv._solve_solo(
            C, a, b, spec, self._reg, self._opts, self._launch
        )

    def solve_many(self, problems: Sequence[Problem]) -> List[Solution]:
        """Solve a list of problems, dispatching solo -> batched -> sharded.

        The plan's ``batching`` policy picks the route: ``'solo'`` loops
        the solo program; ``'batched'`` (or ``'auto'`` with more than one
        problem) fuses everything into ONE launch; with a mesh attached
        the fused launch is the ``shard_map`` program with the problem
        axis split over devices.  Mixed true group sizes and narrower
        column counts are auto-padded to the template geometry.

        Returns
        -------
        list of Solution
            One per problem, in input order; each bitwise-identical to
            the same problem solved through the legacy ``solve_batch`` /
            ``solve_batch_sharded`` (or solo) paths.
        """
        problems = list(problems)
        if not problems:
            return []
        solo = self._plan.batching == "solo" or (
            self._plan.batching == "auto" and len(problems) == 1
            and self._mesh is None
        )
        if solo:
            return [self.solve(p) for p in problems]
        preps, C_host, C, a, b, row_mask, sqrt_g = self._stack(problems)
        if self._mesh is not None:
            lb, scr, rounds, stats = self._solve_padded_batch_sharded(
                C, a, b,
                None if row_mask.ndim == 1 else row_mask,
                None if row_mask.ndim == 1 else sqrt_g,
            )
        else:
            lb, scr, rounds, stats = self._solve_padded_batch(
                C, a, b, row_mask, sqrt_g
            )
        self._record(rounds, failed=lb.failed)
        return self._wrap_batch(
            preps, C_host, self._as_batch_result(lb, scr, rounds, stats)
        )

    def stream(self, problems: Union[Problem, Sequence[Problem]]) -> "Stream":
        """Open a round-step :class:`Stream` over one or more problems.

        Each iteration runs ONE fused Algorithm-1 round (one program
        launch — the serving engine's tick granularity) and yields a
        diagnostics dict; :meth:`Stream.solutions` assembles the final
        :class:`Solution` list.  The round sequence is bitwise-identical
        to :meth:`solve_many` on the same problems.
        """
        if self._sopts is not None:
            raise ValueError(
                "solver='stochastic' has no round-step stream (epochs are "
                "not Algorithm-1 rounds); use solve/solve_many, or "
                "solver='lbfgs' for streaming."
            )
        if isinstance(problems, Problem):
            problems = [problems]
        return Stream(self, list(problems))


class Stream:
    """Round-step iteration over a batch of problems (one launch per round).

    Created by :meth:`Executor.stream`.  Iterating advances every
    unconverged problem by one fused round and yields a diagnostics dict
    (``round``, ``alive``, per-problem ``converged`` / ``failed`` /
    ``rounds``, cumulative verdict ``stats``); iteration stops when every
    problem is finished or the plan's ``max_rounds`` cap is hit —
    exactly the loop condition of the fused batched solve, so the final
    state is bitwise-identical to :meth:`Executor.solve_many`.
    """

    def __init__(self, executor: Executor, problems: Sequence[Problem]):
        self._ex = executor
        self._round = 0
        self._recorded = False
        if not problems:               # empty batch: a stream that is born done
            self._preps, self._C_host, self._B = [], None, 0
            self._state = None
            return
        preps, C_host, C, a, b, row_mask, sqrt_g = executor._stack(problems)
        self._preps = preps
        self._C_host = C_host
        self._B = len(preps)
        prob, opts, mesh = executor._prob, executor._opts, executor._mesh
        if mesh is not None:
            from repro.core import sharded as shd

            B = C.shape[0]
            if row_mask.ndim == 1:
                row_mask = jnp.broadcast_to(row_mask, (B, prob.m_pad))
                sqrt_g = jnp.broadcast_to(sqrt_g, (B, prob.num_groups))
            C, a, b, row_mask, sqrt_g, _ = shd.pad_batch_to_devices(
                C, a, b, row_mask, sqrt_g, mesh.size
            )
            C, a, b, row_mask, sqrt_g = shd.device_put_batch(
                (C, a, b, row_mask, sqrt_g), mesh
            )
            self._padded = (
                shd.prepare_padded_sharded(C, prob, mesh,
                                           precision=opts.precision)
                if opts.grad_impl in ("pallas", "fused") else None
            )
            self._state = executor._launch(
                shd.init_batch_state_sharded, C, a, b, row_mask, sqrt_g,
                prob, opts, mesh, self._padded,
            )
        else:
            self._padded = slv._prepare_padded(C, prob, opts)
            self._state = executor._launch(
                slv.init_batch_state, C, a, b, row_mask, sqrt_g,
                prob, opts, self._padded,
            )
        self._args = (C, a, b, row_mask, sqrt_g)

    # -- iteration ------------------------------------------------------------
    @property
    def done(self) -> bool:
        """True when every problem finished or the round cap was hit."""
        if self._B == 0 or self._round >= self._ex._opts.max_rounds:
            return True
        lb = self._state.lb
        alive = ~np.asarray(lb.converged)[: self._B] & ~np.asarray(lb.failed)[: self._B]
        return not bool(alive.any())

    def __iter__(self) -> "Stream":
        """Iterator protocol: the stream iterates itself."""
        return self

    def __next__(self) -> dict:
        """Run ONE fused round; return its diagnostics (or StopIteration)."""
        if self.done:
            self._maybe_record()
            raise StopIteration
        ex = self._ex
        prob, opts, mesh = ex._prob, ex._opts, ex._mesh
        if mesh is not None:
            from repro.core import sharded as shd

            self._state = ex._launch(
                shd.batch_round_sharded, self._state, *self._args,
                prob, opts, mesh, self._padded,
            )
        else:
            self._state = ex._launch(
                slv.batch_round, self._state, *self._args, prob, opts, self._padded,
            )
        self._round += 1
        lb = self._state.lb
        conv = np.asarray(lb.converged)[: self._B]
        failed = np.asarray(lb.failed)[: self._B]
        return {
            "round": self._round,
            "alive": int(np.sum(~conv & ~failed)),
            "converged": conv,
            "failed": failed,
            # per-problem lifecycle view, in the serving state machine's
            # vocabulary (FAILED wins over converged: a slot whose L-BFGS
            # failed is quarantine-bound even if a stale converged bit set)
            "status": [
                "FAILED" if f else ("DONE" if c else "RUNNING")
                for c, f in zip(conv, failed)
            ],
            "rounds": np.asarray(self._state.rounds)[: self._B],
            "stats": np.asarray(self._state.stats)[: self._B],
        }

    # -- results --------------------------------------------------------------
    def _maybe_record(self) -> None:
        """Count the drained stream in the executor's stats exactly once.

        Runs when iteration exhausts (so a ``for info in stream`` loop that
        never calls :meth:`solutions` still registers its work) and again
        defensively from :meth:`solutions`.
        """
        if self._recorded:
            return
        self._recorded = True
        if self._B:                    # an empty stream did no work to count
            self._ex._record(
                np.asarray(self._state.rounds)[: self._B],
                failed=np.asarray(self._state.lb.failed)[: self._B],
            )

    def _batch_result(self) -> slv.BatchOTResult:
        cut = lambda t: jax.tree_util.tree_map(lambda v: v[: self._B], t)
        return self._ex._as_batch_result(
            cut(self._state.lb), cut(self._state.scr),
            self._state.rounds[: self._B], self._state.stats[: self._B],
        )

    def solutions(self) -> List[Solution]:
        """Assemble the per-problem :class:`Solution` list (drains first).

        If the stream has not been iterated to completion yet, the
        remaining rounds run here (so ``stream(...).solutions()`` is the
        eager solve).
        """
        for _ in self:
            pass
        self._maybe_record()
        if self._B == 0:
            return []
        return self._ex._wrap_batch(
            self._preps, self._C_host, self._batch_result()
        )

    def describe(self) -> str:
        """The executor's diagnostic block + this stream's live progress."""
        if self._B == 0:
            return self._ex.describe()
        return self._ex.describe(self._batch_result())
