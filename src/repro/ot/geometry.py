"""Cost geometries: how the (m, n) ground cost is represented and lowered.

The facade historically had exactly one answer — materialize a dense
``(m_pad, n)`` float32 cost matrix on the host and ship it to the device —
which makes HBM the hard ceiling on problem size.  This module makes the
cost representation a first-class choice (docs/geometry.md):

:class:`DenseCost`
    Today's path, unchanged numerics: a dense host-side cost array.

:class:`SquaredL2Geometry`
    The materialization-free route.  Carries the raw source/target sample
    blocks plus precomputed squared norms and lowers the cost inside the
    Pallas kernels via the factorization ``|x|^2 + |y|^2 - 2 x^T y``
    (clamped at zero), so device memory holds ``O((m + n) d)`` operand
    bytes instead of ``O(m n)``.  Cost normalization (``1 / max C``) and
    the PAD_COST sentinels of the uniform group layout are folded into the
    stored samples/norms at construction, so the kernels need no extra
    scale or mask operands.

Numerics policy (stated in docs/geometry.md and asserted by
tests/test_geometry.py): :meth:`SquaredL2Geometry.materialize` uses the
same f32 recipe (:func:`repro.kernels.gradpsi.factorized_cost_tile`) as
the kernels — an elementwise product reduced over the feature axis, NOT a
matmul — so the on-the-fly route is BITWISE-equal to the dense route run
on the materialized cost, for any tiling or chunking.  Against the legacy
float64 NumPy pipeline (``core.ot.squared_euclidean_cost`` then cast)
agreement is tolerance-level only, because the legacy path squares in f64.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import groups as G
from repro.kernels.gradpsi import factorized_cost_tile

#: Row-chunk size for chunked/streamed materialization (the generic-cost
#: fallback): peak host memory per chunk is ``DEFAULT_CHUNK_ROWS * n * 4``
#: bytes instead of the full ``m_pad * n * 4``.
DEFAULT_CHUNK_ROWS = 2048

#: ``geometry='auto'`` switches a samples-mode problem to the on-the-fly
#: route once the dense cost would exceed this many bytes (64 MiB).  Below
#: it the dense route wins: one HBM-resident C beats re-computing tiles,
#: and existing small-problem callers keep their exact legacy numerics.
AUTO_ONTHEFLY_BYTES = 64 * 1024 * 1024


_cost_block = jax.jit(factorized_cost_tile)


class CostGeometry:
    """Base class for cost representations the executor can lower.

    Concrete geometries expose the equivalent dense cost through
    :meth:`row_block` / :meth:`materialize` and report their device-operand
    footprint through :meth:`hbm_bytes`; :class:`SquaredL2Geometry`
    additionally lowers directly into the factorized Pallas kernels.
    """

    @property
    def rows(self) -> int:
        """Number of rows of the equivalent dense cost."""
        raise NotImplementedError

    @property
    def cols(self) -> int:
        """Number of columns of the equivalent dense cost."""
        raise NotImplementedError

    def row_block(self, lo: int, hi: int) -> np.ndarray:
        """The dense cost rows ``[lo, hi)`` as an f32 array."""
        raise NotImplementedError

    def materialize(self, chunk_rows: Optional[int] = None) -> np.ndarray:
        """The full dense cost, built in row chunks of ``chunk_rows``.

        Chunking bounds peak working memory without changing a single bit
        of the result (asserted by tests/test_geometry.py): every element
        sees the identical f32 operation sequence regardless of chunk size.
        """
        if chunk_rows is None:
            chunk_rows = DEFAULT_CHUNK_ROWS
        m = self.rows
        blocks = [
            self.row_block(lo, min(lo + chunk_rows, m))
            for lo in range(0, m, max(chunk_rows, 1))
        ]
        return np.concatenate(blocks, axis=0) if len(blocks) > 1 else blocks[0]

    def hbm_bytes(self) -> int:
        """Device bytes the solve-time cost operand occupies."""
        raise NotImplementedError


@dataclasses.dataclass(frozen=True)
class DenseCost(CostGeometry):
    """A dense host-side cost matrix — the legacy geometry, unchanged.

    Parameters
    ----------
    C : np.ndarray
        The ``(rows, cols)`` float32 cost array (typically the padded cost
        from ``Problem.padded()``).
    """

    C: np.ndarray

    @property
    def rows(self) -> int:
        """Number of rows of ``C``."""
        return int(self.C.shape[0])

    @property
    def cols(self) -> int:
        """Number of columns of ``C``."""
        return int(self.C.shape[1])

    def row_block(self, lo: int, hi: int) -> np.ndarray:
        """Slice rows ``[lo, hi)`` of the stored array."""
        return np.asarray(self.C[lo:hi], np.float32)

    def hbm_bytes(self) -> int:
        """The full dense array rides in HBM: ``rows * cols * 4``."""
        return int(self.C.shape[0]) * int(self.C.shape[1]) * 4


@dataclasses.dataclass(frozen=True)
class SquaredL2Geometry(CostGeometry):
    """Factorized squared-l2 cost: samples + squared norms, no (m, n) array.

    Stored values are pre-scaled: normalization and PAD_COST sentinels are
    folded in at construction (see :meth:`from_samples`), so
    ``cost[i, j] = max(x_sq[i] + y_sq[j] - 2 <x[i], y[j]>, 0)`` — evaluated
    by :func:`repro.kernels.gradpsi.factorized_cost_tile` both on-device
    (kernel tiles) and here (:meth:`materialize`) — IS the normalized padded
    cost, bit for bit.

    Parameters
    ----------
    x : np.ndarray
        ``(m_pad, d)`` f32 scaled source samples in padded group order
        (zero rows on group padding).
    x_sq : np.ndarray
        ``(m_pad,)`` f32 scaled squared norms; PAD_COST on padded rows.
    y : np.ndarray
        ``(n, d)`` f32 scaled target samples.
    y_sq : np.ndarray
        ``(n,)`` f32 scaled squared norms; PAD_COST on padded columns
        (column padding is applied by :meth:`pad_columns`).
    n_real : int
        True (unpadded) target count — ``cols`` may exceed it after
        :meth:`pad_columns`.
    """

    x: np.ndarray
    x_sq: np.ndarray
    y: np.ndarray
    y_sq: np.ndarray
    n_real: int

    @classmethod
    def from_samples(
        cls,
        X_S: np.ndarray,
        labels: np.ndarray,
        X_T: np.ndarray,
        spec: G.GroupSpec,
        normalize_cost: bool = True,
        chunk_rows: Optional[int] = None,
    ) -> "SquaredL2Geometry":
        """Build the factorized geometry from raw samples.

        Rows are stable-sorted by label and padded to the uniform group
        layout exactly like the dense pipeline (``groups.pad_sources``).
        With ``normalize_cost`` the scale ``1 / max(C)`` is found by a
        chunked max pass over the real rows (never materializing C), then
        folded into the stored samples as ``sqrt(scale)`` and into the
        squared norms as ``scale``.
        """
        if chunk_rows is None:
            chunk_rows = DEFAULT_CHUNK_ROWS
        Xs = np.ascontiguousarray(np.asarray(X_S), dtype=np.float32)
        Y = np.ascontiguousarray(np.asarray(X_T), dtype=np.float32)
        Xp, _, row_mask = G.pad_sources(Xs, np.asarray(labels), spec)
        Xp = np.asarray(Xp, np.float32)
        x_sq0 = np.sum(Xp * Xp, axis=1, dtype=np.float32)
        y_sq0 = np.sum(Y * Y, axis=1, dtype=np.float32)

        scale = np.float32(1.0)
        if normalize_cost:
            real = np.flatnonzero(row_mask)
            cmax = np.float32(0.0)
            yj = jnp.asarray(Y)
            ysqj = jnp.asarray(y_sq0)
            for lo in range(0, real.size, max(chunk_rows, 1)):
                rows = real[lo:lo + chunk_rows]
                block = _cost_block(
                    jnp.asarray(Xp[rows]), jnp.asarray(x_sq0[rows]), yj, ysqj
                )
                cmax = np.maximum(cmax, np.float32(jnp.max(block)))
            scale = np.float32(1.0) / np.maximum(cmax, np.float32(1e-12))

        root = np.sqrt(scale).astype(np.float32)
        x = (Xp * root).astype(np.float32)
        y = (Y * root).astype(np.float32)
        x_sq = (x_sq0 * scale).astype(np.float32)
        y_sq = (y_sq0 * scale).astype(np.float32)
        x_sq = np.where(row_mask, x_sq, np.float32(G.PAD_COST))
        # padded rows carry zero samples so their cost is PAD_COST + y_sq
        x = np.where(row_mask[:, None], x, np.float32(0.0))
        return cls(x=x, x_sq=x_sq, y=y, y_sq=y_sq, n_real=int(Y.shape[0]))

    @property
    def rows(self) -> int:
        """Padded source count ``m_pad``."""
        return int(self.x.shape[0])

    @property
    def cols(self) -> int:
        """Target count (including any column padding)."""
        return int(self.y.shape[0])

    @property
    def dim(self) -> int:
        """Feature dimension ``d`` of the sample blocks."""
        return int(self.x.shape[1])

    def row_block(self, lo: int, hi: int) -> np.ndarray:
        """Cost rows ``[lo, hi)`` rebuilt with the kernel recipe."""
        return np.asarray(
            _cost_block(
                jnp.asarray(self.x[lo:hi]), jnp.asarray(self.x_sq[lo:hi]),
                jnp.asarray(self.y), jnp.asarray(self.y_sq),
            )
        )

    def pad_columns(self, n_target: int) -> "SquaredL2Geometry":
        """Pad the target side to ``n_target`` columns with PAD_COST.

        Padded columns carry zero samples and ``y_sq = PAD_COST`` — their
        cost is >= PAD_COST everywhere, matching the executor's dense
        column-padding recipe for narrower problems in a wider template.
        """
        n = self.cols
        if n_target == n:
            return self
        if n_target < n:
            raise ValueError(f"cannot shrink columns: {n} -> {n_target}")
        extra = n_target - n
        y = np.concatenate(
            [self.y, np.zeros((extra, self.dim), np.float32)], axis=0
        )
        y_sq = np.concatenate(
            [self.y_sq, np.full((extra,), G.PAD_COST, np.float32)], axis=0
        )
        return dataclasses.replace(self, y=y, y_sq=y_sq)

    def operands(self) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
        """The ``(x, x_sq, y, y_sq)`` leaves for a kernel FactorizedCost."""
        return (self.x, self.x_sq, self.y, self.y_sq)

    def hbm_bytes(self) -> int:
        """Device operand bytes: ``(m_pad + n)(d + 1) * 4`` — no (m, n) term."""
        return 4 * (
            self.x.size + self.x_sq.size + self.y.size + self.y_sq.size
        )
