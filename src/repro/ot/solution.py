"""The unified solution type returned by every ``repro.ot`` execution path.

One container whatever the route — solo, batched, sharded, or a serving
slot: the primal plan restored to the caller's original row order, the
padded plan and duals for bitwise comparisons, objective / transport cost /
group sparsity, and the convergence record.  The legacy result objects
(``OTResult`` et al.) remain reachable through :attr:`Solution.result` so
deprecated shims can re-wrap a façade solve without recomputation.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.core import groups as G
from repro.core.solver import OTResult


@dataclasses.dataclass
class Solution:
    """Result of solving one :class:`~repro.ot.problem.Problem`.

    Attributes
    ----------
    plan : np.ndarray
        ``(m, n)`` primal transport plan in the problem's original row
        order (padding rows/columns dropped).
    value : float
        Dual objective at convergence.
    distance : float
        Transport cost ``<T, C>_F`` over the real entries.
    group_sparsity : float
        Fraction of (group, target) blocks that are exactly zero — the
        structure the group-lasso term drives up.
    alpha, beta : arrays
        Optimal duals in the padded layout (``(m_pad,)`` / ``(n_solved,)``).
    plan_padded : np.ndarray
        ``(m_pad, n_solved)`` plan in the solver's padded layout (bitwise
        comparisons against legacy entry points).
    rounds : int
        Algorithm-1 rounds run.
    converged : bool
        Whether the dual solve converged (vs. failed / hit caps).
    iterations, n_evals : int
        L-BFGS iterations / oracle evaluations.
    stats : dict
        Screening verdict totals ``{'zero', 'check', 'active'}``.
    spec : GroupSpec
        The padded group layout the solve ran in.
    perm : np.ndarray
        ``(m_pad,)`` padded-row -> original-row map (-1 = padding).
    result : OTResult
        The underlying legacy container (duals, solver + screening state).
    """

    plan: np.ndarray
    value: float
    distance: float
    group_sparsity: float
    alpha: np.ndarray
    beta: np.ndarray
    plan_padded: np.ndarray
    rounds: int
    converged: bool
    iterations: int
    n_evals: int
    stats: dict
    spec: G.GroupSpec
    perm: np.ndarray
    result: Optional[OTResult] = None

    def transport_sources(self, X_S: np.ndarray) -> np.ndarray:
        """Barycentric map of targets: each target as the plan-weighted mean
        of the sources sending it mass, ``X_T_hat_j = (T^T X_S)_j / T_j``.

        With uniform marginals the column masses are ``1/n`` and this is
        the paper's ``n * T^T X_S`` (§Prelim); normalizing by the actual
        column sums keeps the map correct for non-uniform ``b`` too.
        Targets receiving no mass (possible only before convergence) map
        to the origin rather than dividing by zero.
        """
        mass = self.plan.sum(axis=0)
        scale = np.where(mass > 0, 1.0 / np.maximum(mass, 1e-38), 0.0)
        return scale[:, None] * (self.plan.T @ X_S)

    def summary(self) -> str:
        """One-line human-readable summary (logs / examples)."""
        return (
            f"Solution(value={self.value:.6f}, distance={self.distance:.6f}, "
            f"group_sparsity={self.group_sparsity:.1%}, rounds={self.rounds}, "
            f"converged={self.converged})"
        )


def build_solution(
    result: OTResult,
    reg,
    C_pad: np.ndarray,
    spec: G.GroupSpec,
    perm: np.ndarray,
    n: int,
    tol: float = 1e-9,
    T_pad: Optional[np.ndarray] = None,
) -> Solution:
    """Assemble a :class:`Solution` from a legacy ``OTResult``.

    ``C_pad`` is the ``(m_pad, n_solved)`` cost the solve actually ran on
    (``n_solved >= n`` when columns were padded up to a template width);
    ``n`` is the problem's true column count.  The plan is recovered from
    the duals (or taken from ``T_pad`` when the caller already recovered
    a whole batch in one launch — ``Executor._wrap_batch``), un-padded
    back to the original row order, and the derived quantities (transport
    cost, group sparsity) are computed with the same op sequence the
    legacy ``solve_groupsparse_ot`` used so shims reproduce its outputs
    exactly.
    """
    if T_pad is None:
        import jax.numpy as jnp

        from repro.core.dual import DualProblem, plan_from_duals

        prob = DualProblem(
            spec.num_groups, spec.group_size, int(C_pad.shape[1]), reg
        )
        T_pad = np.asarray(
            plan_from_duals(result.alpha, result.beta, jnp.asarray(C_pad), prob)
        )
    else:
        T_pad = np.asarray(T_pad)
    m = int(spec.m)
    real = perm >= 0
    T = np.zeros((m, n), T_pad.dtype)
    T[perm[real]] = T_pad[real][:, :n]
    C_real = np.zeros((m, n), np.float32)
    C_real[perm[real]] = np.asarray(C_pad, np.float32)[real][:, :n]
    distance = float(np.sum(T * C_real))

    # fraction of (group, target) blocks that are entirely zero, over the
    # REAL rows of each group (the padded-layout form of
    # ``core.ot.group_sparsity``)
    row_mask = spec.row_mask()
    Tg = T_pad[:, :n].reshape(spec.num_groups, spec.group_size, n)
    masked = np.where(row_mask[:, :, None], np.abs(Tg), 0.0)
    zero_blocks = int(np.sum(masked.max(axis=1) <= tol))
    gs = zero_blocks / float(max(spec.num_groups * n, 1))

    return Solution(
        plan=T,
        value=float(result.value),
        distance=distance,
        group_sparsity=gs,
        alpha=result.alpha,
        beta=result.beta,
        plan_padded=T_pad,
        rounds=int(result.rounds),
        converged=bool(result.converged),
        iterations=int(result.iterations),
        n_evals=int(result.n_evals),
        stats=dict(result.stats),
        spec=spec,
        perm=perm,
        result=result,
    )
