"""``repro.ot`` — THE public surface: declarative Problem -> compiled Executor.

One way in, whatever the scale::

    import repro.ot as ot

    problem = ot.Problem.from_samples(Xs, ys, Xt, reg=GroupSparseReg.from_rho(1.0, 0.6))
    ex = ot.compile(problem, ot.ExecutionPlan(grad_impl="screened"))

    sol  = ex.solve()                 # solo: one problem, one program
    sols = ex.solve_many(problems)    # batched: B problems, ONE program
    for info in ex.stream(problems):  # round-step: one fused round per tick
        print(info["alive"], "still solving")

Attach a device mesh (``ExecutionPlan(devices='all')`` or
``compile(..., mesh=...)``) and ``solve_many`` / ``stream`` run the same
batch under ``shard_map`` with the problem axis split across devices.
Every route returns the unified :class:`~repro.ot.solution.Solution` and
is bitwise-identical to the legacy entry points it replaced
(``core.ot.solve_groupsparse_ot``, ``solver.solve_batch``,
``sharded.solve_batch_sharded`` — all now deprecated shims over this
package).

For training-time workloads, :class:`~repro.ot.diff.OTLayer` /
:func:`~repro.ot.diff.ot_loss` expose the regularized OT value as a
differentiable function (exact Danskin gradients — ``jax.grad`` of the
value is the optimal plan, no unrolling through the solver), and
``ExecutionPlan(solver='stochastic')`` swaps in the minibatch dual-ascent
solver of :mod:`repro.core.stochastic` (docs/training.md).

``tools/check_api_surface.py`` gates ``__all__`` against docs/api.md.
"""
from repro.ot.diff import OTLayer, ot_loss
from repro.ot.executor import Executor, Stream, compile, solve
from repro.ot.geometry import CostGeometry, DenseCost, SquaredL2Geometry
from repro.ot.plan import ExecutionPlan
from repro.ot.problem import Problem, SubmitOptions
from repro.ot.solution import Solution

__all__ = [
    "Problem",
    "SubmitOptions",
    "ExecutionPlan",
    "Executor",
    "Stream",
    "Solution",
    "CostGeometry",
    "DenseCost",
    "SquaredL2Geometry",
    "OTLayer",
    "ot_loss",
    "compile",
    "solve",
]
