"""Differentiable OT layer: Danskin gradients through the screened dual.

The regularized OT value solved by this repo,

    W(C) = max_{alpha, beta}  alpha^T a + beta^T b - sum_j psi(alpha + beta_j - c_j),

is a maximum of functions that are affine in ``C`` (through ``f = alpha +
beta_j - c_j``), so Danskin's theorem gives its exact gradient *without
differentiating through the solver*:

    dW/dC = T*          (the optimal plan, T* = grad psi(f*) -- paper Eq. 6)
    dW/da = alpha*,   dW/db = beta*

(Blondel et al., "Smooth and Sparse Optimal Transport", arXiv 1710.06276.
The identities are the same Fenchel relations property-tested in
tests/test_regularizers.py.)  :class:`OTLayer` packages this as a
``jax.custom_vjp``: the forward pass launches the exact jitted solver
program the façade Executor runs (`repro.core.solver._solve_jit`, or the
stochastic twin for ``ExecutionPlan(solver='stochastic')``) under any
``grad_impl`` backend, and the backward pass is one closed-form plan
recovery — no unrolling, O(1) solver calls per training step, and the
plan (hence the cost gradient) inherits the group-block sparsity that
screening certifies.

Why not differentiate through the solver?  Unrolling L-BFGS + screening
through AD costs O(iters) memory for the saved trajectory, differentiates
non-smooth bookkeeping (line searches, active-set flags) that has zero
gradient signal, and is orders of magnitude slower.  The unrolled path
exists here only as a test oracle (:func:`unrolled_value`): a plain
gradient-ascent solver written as a ``lax.scan`` that AD *can* flow
through, used to cross-check the Danskin gradient.

Samples mode (:meth:`OTLayer.from_samples`) keeps squared-l2 problems
materialization-free end to end: the forward pass routes the factorized
cost straight to the on-the-fly Pallas kernels, and the backward pass
chain-rules ``dC_ij = 2 * scale * (x_i - y_j)`` through the plan with a
group-chunked ``lax.scan`` — peak memory O(g*n + n*d), never (m, n).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import groups as G
from repro.core import solver as slv
from repro.core.dual import DualProblem, plan_from_duals
from repro.core.regularizers import Regularizer
from repro.kernels.gradpsi import factorized_cost_tile
from repro.ot.plan import ExecutionPlan

_SOLVES = {"count": 0}


def solve_count() -> int:
    """Dual solves launched by the layer (fwd passes; eager re-executes)."""
    return _SOLVES["count"]


def reset_solve_count() -> None:
    """Reset the layer's solve counter (benchmarks)."""
    _SOLVES["count"] = 0


@dataclasses.dataclass(frozen=True)
class OTLayer:
    """A regularized-OT value as a differentiable function of its inputs.

    The layer is a frozen, hashable problem description (it rides through
    ``jax.custom_vjp`` as a static argument, so compiled programs
    specialize per layer exactly like the Executor specializes per plan):

    num_groups:  L source groups (classes / sequences).
    group_size:  padded uniform rows per group g.
    num_target:  target column count n.
    reg:         any :class:`repro.core.regularizers.Regularizer`.
    plan:        :class:`ExecutionPlan` — backend, precision, solver
                 (``'lbfgs'`` or ``'stochastic'``), iteration budgets.
    sizes:       optional true per-group sizes for ragged groups
                 (defaults to full groups).
    normalize_cost: samples mode only — rescale by ``1 / max(C)`` found
                 with a chunked max pass (the scale is a constant of the
                 backward pass, matching the training-stack convention).
    grad_refine: extra fixed-step exact ascent iterations appended after
                 the solver (step ``gamma / max(m_pad, n)``, the safe
                 inverse-curvature bound).  The f32 L-BFGS line search
                 floors out around ``||grad||_inf ~ 1e-4``, and the
                 Danskin gradient error tracks the dual residual
                 linearly; a few hundred refine steps push it to the
                 f32 noise floor (the FD harness in
                 tests/test_diff_layer.py measures this).  Default 0
                 keeps the forward value bitwise-identical to
                 ``Executor.solve`` on the same plan.

    Inputs use the padded uniform group layout of :mod:`repro.core.groups`
    (rows sorted by group, ``m_pad = L * g``); gradients come back in the
    same layout, with exact zeros on padded rows.  ``__call__`` takes a
    dense cost; :meth:`from_samples` takes raw sample coordinates and
    never materializes the (m, n) cost for the Pallas backends.  Both
    return the dual-optimal (maximization) value, so minimizing it drives
    source and target distributions together.
    """

    num_groups: int
    group_size: int
    num_target: int
    reg: Regularizer
    plan: ExecutionPlan = dataclasses.field(default_factory=ExecutionPlan)
    sizes: Optional[Tuple[int, ...]] = None
    normalize_cost: bool = False
    grad_refine: int = 0

    def __post_init__(self):
        if self.grad_refine < 0:
            raise ValueError(
                f"grad_refine must be >= 0, got {self.grad_refine}"
            )
        if self.num_groups < 1 or self.group_size < 1 or self.num_target < 1:
            raise ValueError(
                "num_groups, group_size and num_target must be positive, got "
                f"({self.num_groups}, {self.group_size}, {self.num_target})"
            )
        if self.sizes is not None:
            sizes = tuple(int(s) for s in self.sizes)
            if len(sizes) != self.num_groups:
                raise ValueError(
                    f"sizes has {len(sizes)} entries for {self.num_groups} groups"
                )
            if any(s < 1 or s > self.group_size for s in sizes):
                raise ValueError(
                    f"each group size must be in [1, {self.group_size}], got {sizes}"
                )
            object.__setattr__(self, "sizes", sizes)

    # -- static problem geometry ------------------------------------------

    def spec(self) -> G.GroupSpec:
        """The padded :class:`~repro.core.groups.GroupSpec` of this layer."""
        sizes = self.sizes or (self.group_size,) * self.num_groups
        return G.GroupSpec(
            num_groups=self.num_groups,
            group_size=self.group_size,
            sizes=tuple(sizes),
            m=int(sum(sizes)),
        )

    def dual_problem(self) -> DualProblem:
        """The static :class:`~repro.core.dual.DualProblem` of this layer."""
        return DualProblem(
            num_groups=self.num_groups,
            group_size=self.group_size,
            n=self.num_target,
            reg=self.reg,
        )

    def _marginals(self, a, b):
        spec = self.spec()
        if a is None:
            a = jnp.asarray(
                spec.row_mask().reshape(-1), jnp.float32
            ) / jnp.float32(spec.m)
        if b is None:
            b = jnp.full((self.num_target,), 1.0 / self.num_target, jnp.float32)
        return jnp.asarray(a, jnp.float32), jnp.asarray(b, jnp.float32)

    # -- dense cost entry points ------------------------------------------

    def __call__(self, C, a=None, b=None):
        """Regularized OT value of a dense padded cost; differentiable.

        ``jax.grad`` w.r.t. ``C`` is the optimal plan ``T*`` (Danskin);
        w.r.t. ``a`` / ``b`` the optimal duals.
        """
        a, b = self._marginals(a, b)
        value, _, _ = _solve_dense(self, jnp.asarray(C, jnp.float32), a, b)
        return value

    def loss_and_plan(self, C, a=None, b=None):
        """(value, T*) from ONE solve; the plan output is detached.

        The value is differentiable exactly like :meth:`__call__`; the
        plan is wrapped in ``stop_gradient`` (its only exact derivative
        story is second-order — out of scope) so it can be consumed as
        weights/routing without leaking bogus tangents.
        """
        a, b = self._marginals(a, b)
        C = jnp.asarray(C, jnp.float32)
        value, alpha, beta = _solve_dense(self, C, a, b)
        T = plan_from_duals(
            jax.lax.stop_gradient(alpha),
            jax.lax.stop_gradient(beta),
            jax.lax.stop_gradient(C),
            self.dual_problem(),
        )
        return value, jax.lax.stop_gradient(T)

    # -- samples (squared-l2) entry point ---------------------------------

    def from_samples(self, x, y, a=None, b=None):
        """OT value between sample clouds under the squared-l2 geometry.

        ``x`` is ``(m_pad, d)`` in the padded group layout (padded rows
        are ignored), ``y`` is ``(n, d)``.  Pallas backends solve through
        the factorized on-the-fly kernels and the backward pass
        chain-rules to the coordinates group-by-group, so no (m, n)
        array exists in either direction.  The dense/screened reference
        backends materialize the cost in-trace (they are O(m n) anyway).
        """
        a, b = self._marginals(a, b)
        x = jnp.asarray(x, jnp.float32)
        y = jnp.asarray(y, jnp.float32)
        if x.shape[0] != self.num_groups * self.group_size:
            raise ValueError(
                f"x has {x.shape[0]} rows, expected m_pad = "
                f"{self.num_groups * self.group_size}"
            )
        if y.shape[0] != self.num_target:
            raise ValueError(
                f"y has {y.shape[0]} rows, expected num_target = {self.num_target}"
            )
        value, _, _ = _solve_samples(self, x, y, a, b)
        return value


def ot_loss(
    C,
    a=None,
    b=None,
    *,
    num_groups: int,
    group_size: int,
    reg: Regularizer,
    plan: Optional[ExecutionPlan] = None,
    sizes: Optional[Tuple[int, ...]] = None,
):
    """Functional form of :class:`OTLayer` for a dense padded cost.

    ``jax.grad(ot_loss)(C, ...)`` is the optimal transport plan.  Equal
    keyword sets build equal (hash-equal) layers, so repeated calls reuse
    the same compiled solver program.
    """
    layer = OTLayer(
        num_groups=num_groups,
        group_size=group_size,
        num_target=int(C.shape[-1]),
        reg=reg,
        plan=plan if plan is not None else ExecutionPlan(),
        sizes=sizes,
    )
    return layer(C, a, b)


# -- forward solve (shared by both custom_vjp primitives) -----------------


def _solve_duals(layer: OTLayer, C, a, b):
    """Run the plan's solver program; return (value, alpha, beta).

    This is the SAME jitted program ``Executor.solve`` launches for this
    plan (``slv._solve_jit`` / ``stochastic._sgd_solve_jit``), so the
    layer's forward value is bitwise-identical to the façade's.
    """
    _SOLVES["count"] += 1
    prob = layer.dual_problem()
    spec = layer.spec()
    row_mask = jnp.asarray(spec.row_mask().reshape(-1))
    sqrt_g = jnp.asarray(spec.sqrt_sizes(), jnp.float32)
    opts = layer.plan.solve_options()
    if layer.plan.solver == "stochastic":
        from repro.core import stochastic as sgd

        lb, _, _, _ = sgd._sgd_solve_jit(
            C, a, b, row_mask, sqrt_g, prob, opts,
            layer.plan.stochastic_options(),
        )
    else:
        lb, _, _, _ = slv._solve_jit(C, a, b, row_mask, sqrt_g, prob, opts)
    alpha, beta = slv._split(lb.x, prob.m_pad)
    value = -lb.f
    if layer.grad_refine:
        oracle = _exact_oracle(C, a, b, prob)
        lr = float(layer.reg.gamma) / float(max(prob.m_pad, prob.n))

        def body(_, ab):
            al, be = ab
            _, ga, gb = oracle(al, be)
            return (al + lr * ga, be + lr * gb)

        alpha, beta = jax.lax.fori_loop(
            0, layer.grad_refine, body, (alpha, beta)
        )
        value, _, _ = oracle(alpha, beta)
    return value, alpha, beta


def _exact_oracle(C, a, b, prob):
    """Full (unscreened) exact dual oracle for the refine loop.

    Dense costs use the closed form; factorized costs run the on-the-fly
    kernel with an all-live flag grid, so refinement never materializes
    the cost either.
    """
    if slv._is_factorized(C):
        from repro.kernels import ops as kops

        fp = kops.prepare_factorized_problem(C, prob)
        flags = jnp.ones(fp.grid, jnp.int32)

        def oracle(al, be):
            return kops.dual_value_and_grad_factorized(
                al, be, a, b, flags, fp, prob, impl="grid"
            )

        return oracle

    from repro.core.dual import dual_value_and_grad

    def oracle(al, be):
        v, (ga, gb) = dual_value_and_grad(al, be, C, a, b, prob)
        return v, ga, gb

    return oracle


# -- dense custom_vjp -----------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _solve_dense(layer: OTLayer, C, a, b):
    return _solve_duals(layer, C, a, b)


def _solve_dense_fwd(layer, C, a, b):
    value, alpha, beta = _solve_duals(layer, C, a, b)
    return (value, alpha, beta), (C, alpha, beta)


def _solve_dense_bwd(layer, res, cts):
    C, alpha, beta = res
    ct = cts[0]  # duals are exposed detached; their cotangents are zero
    T = plan_from_duals(alpha, beta, C, layer.dual_problem())
    return (ct * T, ct * alpha, ct * beta)


_solve_dense.defvjp(_solve_dense_fwd, _solve_dense_bwd)


# -- samples custom_vjp ---------------------------------------------------


def _scaled_factors(layer: OTLayer, x, y):
    """In-trace twin of ``SquaredL2Geometry.from_samples`` (same recipe).

    Returns ``(xs, x_sq, ys, y_sq, scale)`` with normalization folded in
    as ``sqrt(scale)`` / ``scale`` and PAD_COST sentinels on padded rows;
    ``scale`` is detached (the chunked max is not differentiated).
    """
    spec = layer.spec()
    mask = jnp.asarray(spec.row_mask().reshape(-1))          # (m_pad,) static
    L, g = layer.num_groups, layer.group_size
    x = jnp.where(mask[:, None], x, 0.0)
    x_sq0 = jnp.sum(x * x, axis=1)
    y_sq0 = jnp.sum(y * y, axis=1)

    scale = jnp.float32(1.0)
    if layer.normalize_cost:
        xg = x.reshape(L, g, -1)
        xsqg = x_sq0.reshape(L, g)
        maskg = mask.reshape(L, g)

        def gmax(args):
            xr, xsqr, mr = args
            block = factorized_cost_tile(xr, xsqr, y, y_sq0)
            return jnp.max(jnp.where(mr[:, None], block, 0.0))

        cmax = jnp.max(jax.lax.map(gmax, (xg, xsqg, maskg)))
        scale = 1.0 / jnp.maximum(cmax, jnp.float32(1e-12))
        scale = jax.lax.stop_gradient(scale)

    root = jnp.sqrt(scale)
    xs = x * root
    ys = y * root
    x_sq = jnp.where(mask, x_sq0 * scale, jnp.float32(G.PAD_COST))
    y_sq = y_sq0 * scale
    return xs, x_sq, ys, y_sq, scale


def _samples_cost(layer: OTLayer, xs, x_sq, ys, y_sq):
    """Cost operand for the plan's backend: factorized or materialized."""
    if layer.plan.grad_impl in ("pallas", "fused"):
        from repro.kernels import ops as kops

        return kops.FactorizedCost(x=xs, x_sq=x_sq, y=ys, y_sq=y_sq)
    L, g = layer.num_groups, layer.group_size
    blocks = jax.lax.map(
        lambda args: factorized_cost_tile(args[0], args[1], ys, y_sq),
        (xs.reshape(L, g, -1), x_sq.reshape(L, g)),
    )
    return blocks.reshape(L * g, layer.num_target)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _solve_samples(layer: OTLayer, x, y, a, b):
    xs, x_sq, ys, y_sq, _ = _scaled_factors(layer, x, y)
    C = _samples_cost(layer, xs, x_sq, ys, y_sq)
    return _solve_duals(layer, C, a, b)


def _solve_samples_fwd(layer, x, y, a, b):
    xs, x_sq, ys, y_sq, scale = _scaled_factors(layer, x, y)
    C = _samples_cost(layer, xs, x_sq, ys, y_sq)
    value, alpha, beta = _solve_duals(layer, C, a, b)
    return (value, alpha, beta), (x, y, xs, x_sq, ys, y_sq, scale, alpha, beta)


def _solve_samples_bwd(layer, res, cts):
    """Materialization-free Danskin pullback to sample coordinates.

    With ``C_ij = scale * (|x_i|^2 + |y_j|^2 - 2 <x_i, y_j>)`` and the
    scale detached, ``dW/dx_i = 2 * scale * (r_i x_i - (T y)_i)`` and
    ``dW/dy_j = 2 * scale * (c_j y_j - (T^T x)_j)`` where r / c are the
    optimal plan's row / column sums.  T is rebuilt group-by-group in a
    two-pass ``lax.scan`` (pass 1: group norms Z -> shrink factors s;
    pass 2: T blocks folded into the four accumulators), so peak memory
    is O(g n + n d) — the (m, n) plan never exists.  The squared-l2 clamp
    ``max(., 0)`` is ignored (it binds only at numerically-zero
    distances, where T's support vanishes with it).
    """
    x, y, xs, x_sq, ys, y_sq, scale, alpha, beta = res
    ct = cts[0]
    prob = layer.dual_problem()
    L, g, n = layer.num_groups, layer.group_size, layer.num_target
    d = x.shape[1]
    gamma = layer.reg.gamma

    xg = xs.reshape(L, g, d)
    xsqg = x_sq.reshape(L, g)
    ag = alpha.reshape(L, g)

    def zrow(args):
        xr, xsqr, al = args
        F = al[:, None] + beta[None, :] - factorized_cost_tile(xr, xsqr, ys, y_sq)
        Fp = jnp.maximum(F, 0.0)
        return jnp.sqrt(
            jnp.maximum(jnp.sum(Fp * Fp, axis=0), jnp.finfo(F.dtype).tiny)
        )

    Z = jax.lax.map(zrow, (xg, xsqg, ag))                    # (L, n)
    s_over_gamma = layer.reg.scale_from_z(Z) / gamma         # (L, n)

    def body(carry, args):
        csum, tx = carry
        xr, xsqr, al, sl, xraw = args
        F = al[:, None] + beta[None, :] - factorized_cost_tile(xr, xsqr, ys, y_sq)
        T = sl[None, :] * jnp.maximum(F, 0.0)                # (g, n) plan block
        csum = csum + jnp.sum(T, axis=0)
        tx = tx + T.T @ xraw
        return (csum, tx), (jnp.sum(T, axis=1), T @ y)

    (csum, tx), (rows, ty) = jax.lax.scan(
        body,
        (jnp.zeros((n,), jnp.float32), jnp.zeros((n, d), jnp.float32)),
        (xg, xsqg, ag, s_over_gamma, x.reshape(L, g, d)),
    )
    r = rows.reshape(L * g)
    Ty = ty.reshape(L * g, d)
    two_scale = 2.0 * scale * ct
    gx = two_scale * (r[:, None] * x - Ty)
    gy = two_scale * (csum[:, None] * y - tx)
    return (gx, gy, ct * alpha, ct * beta)


_solve_samples.defvjp(_solve_samples_fwd, _solve_samples_bwd)


# -- unrolled test oracle -------------------------------------------------


def unrolled_value(
    C,
    a,
    b,
    *,
    num_groups: int,
    group_size: int,
    reg: Regularizer,
    steps: int = 3000,
    step_size: float = 0.05,
):
    """Reference OT value via fixed-step dual ascent AD *can* unroll.

    A deliberately plain solver — ``steps`` gradient-ascent steps on the
    smooth dual written as a ``lax.scan`` — whose value converges to the
    L-BFGS solution and whose ``jax.grad`` (checkpointing every step,
    O(steps) memory) is the AD-through-the-solver oracle the Danskin
    backward pass is tested against.  Never use this in training; it
    exists to certify :func:`ot_loss` (docs/training.md).
    """
    from repro.core.dual import dual_value_and_grad

    prob = DualProblem(
        num_groups=num_groups, group_size=group_size,
        n=int(C.shape[-1]), reg=reg,
    )
    m_pad = prob.m_pad
    alpha0 = jnp.zeros((m_pad,), jnp.float32)
    beta0 = jnp.zeros((C.shape[-1],), jnp.float32)

    def step(carry, _):
        alpha, beta = carry
        _, (ga, gb) = dual_value_and_grad(alpha, beta, C, a, b, prob)
        return (alpha + step_size * ga, beta + step_size * gb), None

    (alpha, beta), _ = jax.lax.scan(step, (alpha0, beta0), None, length=steps)
    value, _ = dual_value_and_grad(alpha, beta, C, a, b, prob)
    return value
