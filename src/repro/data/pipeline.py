"""Deterministic synthetic data pipeline.

Design requirements it satisfies:
  * reproducible across restarts: batch(step) is a pure function of
    (seed, step) — crash/restart resumes mid-run with identical data,
  * shardable: each data shard generates only its slice (no host fan-out),
  * domain-adaptation mode for the paper's OT loss: two domains with class
    structure (source labeled, target unlabeled).

Tokens follow a Zipf-like marginal with a per-sequence Markov drift so the
LM loss actually decreases during the example runs (pure-uniform tokens
would pin CE at log V).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator

import numpy as np


@dataclasses.dataclass
class SyntheticLMConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.3
    markov_order: int = 1
    num_classes: int = 8          # for DA mode


class SyntheticLM:
    """batch(step) -> {"tokens": (B, S+1) int32, "class": (B,) int32}."""

    def __init__(self, cfg: SyntheticLMConfig, shard_id: int = 0, num_shards: int = 1):
        assert cfg.global_batch % num_shards == 0
        self.cfg = cfg
        self.shard_id = shard_id
        self.num_shards = num_shards
        self.local_batch = cfg.global_batch // num_shards
        rng = np.random.default_rng(cfg.seed)
        # fixed random Markov transition biased toward a Zipf marginal
        V = cfg.vocab_size
        ranks = np.arange(1, V + 1)
        self.marginal = (ranks ** -cfg.zipf_a)
        self.marginal /= self.marginal.sum()
        self.shift = rng.integers(1, V, size=cfg.num_classes)

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.default_rng(
            (cfg.seed * 1_000_003 + step) * 4096 + self.shard_id
        )
        B, S, V = self.local_batch, cfg.seq_len, cfg.vocab_size
        cls = rng.integers(0, cfg.num_classes, size=B).astype(np.int32)
        base = rng.choice(V, size=(B, S + 1), p=self.marginal)
        # class-conditioned deterministic drift: makes next-token partially
        # predictable, so training curves move
        drift = np.cumsum(np.ones((B, S + 1), np.int64), axis=1) * self.shift[cls][:, None]
        tokens = ((base + drift) % V).astype(np.int32)
        # inject strong bigram structure: every even position repeats
        tokens[:, 2::2] = (tokens[:, 1:-1:2] + self.shift[cls][:, None]) % V
        return {"tokens": tokens, "class": cls}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1


@dataclasses.dataclass
class DomainPairConfig:
    """Two feature domains with shared class structure (paper's DA setup)."""

    num_classes: int = 10
    samples_per_class: int = 10
    dim: int = 2
    shift: float = 5.0
    seed: int = 0


def make_domain_pair(cfg: DomainPairConfig):
    """Paper-synthetic: class means (l*shift, -shift) vs (l*shift, +shift)."""
    rng = np.random.default_rng(cfg.seed)
    L, g = cfg.num_classes, cfg.samples_per_class
    m = L * g
    labels = np.repeat(np.arange(L), g)
    mean_s = np.stack([labels * cfg.shift, -cfg.shift * np.ones(m)], axis=1)
    mean_t = np.stack([labels * cfg.shift, +cfg.shift * np.ones(m)], axis=1)
    pad = cfg.dim - 2
    if pad > 0:
        mean_s = np.concatenate([mean_s, np.zeros((m, pad))], axis=1)
        mean_t = np.concatenate([mean_t, np.zeros((m, pad))], axis=1)
    Xs = rng.normal(size=(m, cfg.dim)) + mean_s
    Xt = rng.normal(size=(m, cfg.dim)) + mean_t
    return Xs.astype(np.float32), labels, Xt.astype(np.float32), labels.copy()
