"""Cost geometries: the materialization-free squared-l2 route (docs/geometry.md).

The load-bearing contract is bitwise *per backend*: a sample-mode problem
solved on ``geometry='on_the_fly'`` equals — bit for bit — the SAME
backend solving ``problem.materialized()`` on ``geometry='dense'``, because
materialization and the kernels share one f32 cost recipe
(``repro.kernels.gradpsi.factorized_cost_tile``).  Cross-backend equality
stays at the repo's existing tolerance contract (tests/test_core_ot.py).

Also gated here: chunked materialization is bitwise chunk-size-invariant,
the f64 factorized reference pins a committed golden fixture, solo ==
batched == sharded on the on-the-fly route, the ``auto`` HBM-bytes
threshold, the chunked dense fallback for non-pallas backends, plan
``geometry`` validation/round-trip, and the sample-preserving
``Problem.config`` round-trip (ISSUE 7 satellite fix).
"""
import json
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import numpy as np
import pytest

from conftest import FIXTURE_DIR

import repro.ot as ot
from repro.core import groups as G
from repro.core.cpu_baseline import factorized_squared_l2_cost
from repro.core.regularizers import GroupSparseReg
from repro.ot.geometry import DenseCost, SquaredL2Geometry

IMPLS = ("dense", "screened", "pallas")
SRC = str(Path(__file__).resolve().parents[1] / "src")


def sample_coords(seed=0, L=4, g=6, n=40, d=3):
    """Deterministic raw-sample problem (ragged groups exercise padding)."""
    rng = np.random.default_rng(seed)
    m = L * g + 3
    labels = np.concatenate([np.arange(L), rng.integers(0, L, m - L)])
    X_S = rng.normal(size=(m, d)) + labels[:, None]
    X_T = rng.normal(size=(n, d)) + rng.integers(0, L, n)[:, None]
    return X_S, labels, X_T


def sample_problem(seed=0, **kw):
    X_S, labels, X_T = sample_coords(seed)
    reg = kw.pop("reg", GroupSparseReg.from_rho(1.0, 0.6))
    return ot.Problem.from_samples(X_S, labels, X_T, reg, pad_to=4, **kw)


def make_plan(impl, geometry):
    return ot.ExecutionPlan(grad_impl=impl, geometry=geometry, max_iters=150)


def assert_solutions_bitwise(s1, s2):
    assert s1.value == s2.value
    assert np.array_equal(np.asarray(s1.alpha), np.asarray(s2.alpha))
    assert np.array_equal(np.asarray(s1.beta), np.asarray(s2.beta))
    assert np.array_equal(np.asarray(s1.plan), np.asarray(s2.plan))


# ------------------------------------------------------------- golden fixture
def test_factorized_f64_golden_fixture():
    """The f64 reference recipe pins committed values; f32 tracks it."""
    with open(os.path.join(FIXTURE_DIR, "golden_geometry.json")) as f:
        gold = json.load(f)
    c = gold["coords"]
    X_S, labels, X_T = sample_coords(c["seed"], c["L"], c["g"], c["n"], c["d"])
    C64 = factorized_squared_l2_cost(X_S, X_T)
    assert C64.sum() == pytest.approx(gold["sum"], rel=1e-12)
    assert C64.max() == pytest.approx(gold["max"], rel=1e-12)
    for i, j, v in gold["probes"]:
        assert C64[i, j] == pytest.approx(v, rel=1e-12, abs=1e-12)
    # the f32 on-the-fly recipe agrees with the f64 reference at f32 tol
    prob = ot.Problem.from_samples(
        X_S, labels, X_T, GroupSparseReg.from_rho(1.0, 0.6),
        pad_to=4, normalize_cost=False,
    )
    C32 = np.asarray(prob.materialized().C)
    np.testing.assert_allclose(C32, C64, rtol=2e-5, atol=2e-4)


def test_materialize_is_chunk_invariant_bitwise():
    prob = sample_problem(0)
    spec = prob.group_spec()
    geom = SquaredL2Geometry.from_samples(prob.X_S, prob.labels, prob.X_T, spec)
    full = geom.materialize()
    assert np.array_equal(geom.materialize(chunk_rows=7), full)
    assert np.array_equal(geom.materialize(chunk_rows=10**6), full)
    assert np.array_equal(geom.row_block(3, 9), full[3:9])
    # column padding appends PAD_COST columns without touching real ones
    wide = geom.pad_columns(geom.cols + 8)
    Cw = wide.materialize()
    assert np.array_equal(Cw[:, : geom.cols], full)
    assert np.all(Cw[:, geom.cols:] >= G.PAD_COST)
    with pytest.raises(ValueError, match="shrink"):
        geom.pad_columns(geom.cols - 1)


# ----------------------------------------------- per-backend bitwise parity
@pytest.mark.parametrize("impl", IMPLS)
def test_onthefly_matches_materialized_dense_bitwise(impl):
    """geometry='on_the_fly' == same backend on problem.materialized()."""
    prob = sample_problem(1)
    sf = ot.solve(prob, make_plan(impl, "on_the_fly"))
    sd = ot.solve(prob.materialized(), make_plan(impl, "dense"))
    assert_solutions_bitwise(sf, sd)


def test_solo_batched_parity_on_the_fly():
    prob = sample_problem(2)
    prob2 = ot.Problem.from_samples(
        prob.X_S, prob.labels, np.asarray(prob.X_T) * 1.1, prob.reg, pad_to=4
    )
    plan = make_plan("pallas", "on_the_fly")
    ex = ot.compile(prob, plan)
    solo = [ex.solve(prob), ex.solve(prob2)]
    batched = ex.solve_many([prob, prob2])
    for s, b in zip(solo, batched):
        assert_solutions_bitwise(s, b)
    streamed = ex.stream([prob, prob2]).solutions()
    for s, st in zip(batched, streamed):
        assert_solutions_bitwise(s, st)


def test_sharded_parity_on_the_fly():
    """4 forced host devices: sharded on-the-fly == unsharded, bitwise.

    Ragged B=3 over 4 devices also exercises the factorized dummy-problem
    padding (zero samples + PAD_COST norms).
    """
    code = textwrap.dedent("""
        import numpy as np, jax
        assert jax.device_count() == 4, jax.device_count()
        import repro.ot as ot
        from repro.core.regularizers import GroupSparseReg

        rng = np.random.default_rng(2)
        L, g, n, d = 4, 6, 40, 3
        m = L * g + 3
        labels = np.concatenate([np.arange(L), rng.integers(0, L, m - L)])
        X_S = rng.normal(size=(m, d)) + labels[:, None]
        X_T = rng.normal(size=(n, d)) + rng.integers(0, L, n)[:, None]
        reg = GroupSparseReg.from_rho(1.0, 0.6)
        probs = [
            ot.Problem.from_samples(X_S, labels, X_T * s, reg, pad_to=4)
            for s in (1.0, 1.1, 0.9)
        ]
        plan = ot.ExecutionPlan(grad_impl="pallas", geometry="on_the_fly",
                                max_iters=150)
        flat = ot.compile(probs[0], plan).solve_many(probs)
        shp = ot.ExecutionPlan(grad_impl="pallas", geometry="on_the_fly",
                               max_iters=150, devices="all")
        sh = ot.compile(probs[0], shp).solve_many(probs)
        for a, b in zip(flat, sh):
            assert a.value == b.value, (a.value, b.value)
            assert np.array_equal(np.asarray(a.alpha), np.asarray(b.alpha))
            assert np.array_equal(np.asarray(a.plan), np.asarray(b.plan))
        print("SHARDED-OK")
    """)
    env = dict(
        os.environ,
        XLA_FLAGS="--xla_force_host_platform_device_count=4",
        PYTHONPATH=SRC,
        JAX_PLATFORMS="cpu",
    )
    r = subprocess.run([sys.executable, "-c", code], env=env,
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "SHARDED-OK" in r.stdout


# --------------------------------------------------- routing + fallback paths
def test_auto_threshold_routes(monkeypatch):
    import repro.ot.geometry as geo

    prob = sample_problem(4)
    # small problem: auto stays dense (legacy numerics untouched)
    ex = ot.compile(prob, ot.ExecutionPlan(grad_impl="pallas"))
    assert ex._route(prob) == "dense"
    # above the byte threshold: auto flips to factorized — and the result
    # is the explicit on-the-fly route's, bit for bit
    monkeypatch.setattr(geo, "AUTO_ONTHEFLY_BYTES", 0)
    ex2 = ot.compile(prob, ot.ExecutionPlan(grad_impl="pallas", max_iters=150))
    assert ex2._route(prob) == "factorized"
    assert_solutions_bitwise(
        ex2.solve(), ot.solve(prob, make_plan("pallas", "on_the_fly"))
    )
    # non-pallas backends never factorize under auto
    assert ot.compile(
        prob, ot.ExecutionPlan(grad_impl="screened")
    )._route(prob) == "dense"
    # cost-mode problems have nothing to factorize even when asked
    assert ot.compile(
        prob.materialized(), ot.ExecutionPlan(grad_impl="pallas",
                                              geometry="on_the_fly")
    )._route(prob.materialized()) == "dense"


def test_chunked_fallback_smoke(monkeypatch):
    """Non-pallas backend + on_the_fly -> chunked dense materialization.

    This is the too-large-for-dense escape hatch driven at a tiny chunk
    size: the streamed build must be bitwise chunk-invariant end to end.
    """
    import repro.ot.geometry as geo

    prob = sample_problem(5)
    plan = make_plan("screened", "on_the_fly")
    s1 = ot.solve(prob, plan)
    monkeypatch.setattr(geo, "DEFAULT_CHUNK_ROWS", 5)
    s2 = ot.solve(prob, plan)
    assert_solutions_bitwise(s1, s2)
    # and the screened fallback equals the pallas kernel route at the
    # repo's cross-backend tolerance (same cost bits, different backend)
    sp = ot.solve(prob, make_plan("pallas", "on_the_fly"))
    np.testing.assert_allclose(sp.value, s1.value, rtol=2e-5, atol=2e-5)


def test_mixed_batch_materializes_factorized_members():
    prob = sample_problem(6)
    plan = make_plan("pallas", "on_the_fly")
    ex = ot.compile(prob, plan)
    mixed = ex.solve_many([prob, prob.materialized()])
    solo = ex.solve(prob)
    # the factorized member got materialized for stacking — same bits
    assert_solutions_bitwise(mixed[0], solo)


def test_solver_rejects_factorized_on_reference_backends():
    from repro.core.solver import SolveOptions, solve_dual
    from repro.kernels import ops as kops
    import jax.numpy as jnp

    prob = sample_problem(7)
    spec = prob.group_spec()
    geom = SquaredL2Geometry.from_samples(prob.X_S, prob.labels, prob.X_T, spec)
    fc = kops.FactorizedCost(*(jnp.asarray(v) for v in geom.operands()))
    assert fc.shape == (geom.rows, geom.cols)
    assert fc.d == geom.dim
    m = prob.num_source
    a = jnp.asarray(G.pad_marginal(
        np.full((m,), 1.0 / m, np.float32), prob.labels, spec))
    b = jnp.full((geom.cols,), np.float32(1.0 / geom.cols))
    for impl in ("dense", "screened"):
        with pytest.raises(TypeError, match="pallas"):
            solve_dual(fc, a, b, spec, prob.reg,
                       SolveOptions(grad_impl=impl))
    # DenseCost wraps the legacy representation faithfully
    C = geom.materialize()
    dc = DenseCost(C)
    assert (dc.rows, dc.cols) == C.shape
    assert dc.hbm_bytes() == C.size * 4
    assert np.array_equal(dc.materialize(chunk_rows=9), C)
    assert geom.hbm_bytes() < dc.hbm_bytes()


# ------------------------------------------------- config round-trips + plan
def test_plan_geometry_field_and_roundtrip():
    with pytest.raises(ValueError, match="geometry"):
        ot.ExecutionPlan(geometry="bogus")
    plan = ot.ExecutionPlan(geometry="on_the_fly")
    assert ot.ExecutionPlan.from_config(
        json.loads(json.dumps(plan.config()))
    ) == plan
    # geometry stays out of the legacy SolveOptions bijection
    opts = plan.solve_options()
    assert not hasattr(opts, "geometry")
    assert ot.ExecutionPlan.from_solve_options(opts).geometry == "auto"


def test_problem_config_roundtrip_preserves_samples():
    """ISSUE 7 satellite fix: serialized sample-mode problems re-resolve
    to the same geometry (raw samples + dtypes survive the round-trip)."""
    prob = sample_problem(8)
    cfg = json.loads(json.dumps(prob.config()))
    rebuilt = ot.Problem.from_config(cfg)
    assert rebuilt.mode == "samples"
    for name in ("X_S", "X_T", "labels"):
        v0, v1 = getattr(prob, name), getattr(rebuilt, name)
        assert v1.dtype == v0.dtype
        assert np.array_equal(v1, v0)
    assert rebuilt == prob
    # identical factorized geometry -> identical materialized bits
    assert np.array_equal(
        np.asarray(rebuilt.materialized().C), np.asarray(prob.materialized().C)
    )
    # and an identical on-the-fly solve
    plan = make_plan("pallas", "on_the_fly")
    assert_solutions_bitwise(ot.solve(rebuilt, plan), ot.solve(prob, plan))
