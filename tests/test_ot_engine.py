"""OT serving engine: bucketing, slot recycling, convergence to solo values."""
import numpy as np
import pytest

from repro.core.lbfgs import LbfgsOptions
from repro.core.ot import solve_groupsparse_ot, squared_euclidean_cost
from repro.core.regularizers import ElasticNetGroupReg, GroupSparseReg, L2Reg
from repro.core.solver import (
    SolveOptions,
    dispatch_count,
    reset_dispatch_count,
)
from repro.serving.ot_engine import OTRequest, OTServingEngine

# reference solves go through the deprecated solve_groupsparse_ot shim ON
# PURPOSE (engine results are compared against the legacy solo path)
pytestmark = pytest.mark.filterwarnings(
    "ignore:solve_groupsparse_ot:DeprecationWarning"
)

OPTS = SolveOptions(grad_impl="screened", lbfgs=LbfgsOptions(max_iters=150))


def _make_request(rng, rid, L, g, n):
    m = L * g
    labels = np.repeat(np.arange(L), g)
    Xs = rng.normal(size=(m, 2)) + labels[:, None] * 3.0
    Xt = rng.normal(size=(n, 2)) + rng.integers(0, L, n)[:, None] * 3.0
    C = squared_euclidean_cost(Xs, Xt).astype(np.float32)
    C /= C.max()
    return OTRequest(rid=rid, C=C, labels=labels), (Xs, labels, Xt)


def test_mixed_shape_stream_converges_to_solo_values():
    """Mixed-shape requests stream through bucketing; every request ends up
    at its solo-solve objective (and plan) despite row/column padding and
    batch-mates at different convergence stages."""
    rng = np.random.default_rng(0)
    shapes = [(4, 6, 30), (4, 6, 35), (5, 8, 50), (4, 6, 28), (5, 8, 40)]
    reqs, raws = [], []
    for rid, (L, g, n) in enumerate(shapes):
        req, raw = _make_request(rng, rid, L, g, n)
        reqs.append(req)
        raws.append(raw)

    engine = OTServingEngine(
        GroupSparseReg.from_rho(1.0, 0.6), OPTS, max_batch=2, n_quant=64
    )
    done = engine.run(reqs)
    assert sorted(r.rid for r in done) == list(range(len(shapes)))
    # two distinct (L, g_pad) geometries -> two buckets
    assert len(engine.buckets) == 2

    for req, (Xs, labels, Xt) in zip(reqs, raws):
        assert req.done and req.converged
        sol = solve_groupsparse_ot(
            Xs, labels, Xt, gamma=1.0, rho=0.6, opts=OPTS, pad_to=8
        )
        np.testing.assert_allclose(req.value, sol.value, rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(
            req.plan, sol.plan, rtol=1e-3, atol=2e-4
        )
        # marginals of the served plan match the request's (uniform) ones
        m, n = req.C.shape
        np.testing.assert_allclose(req.plan.sum(1), np.full(m, 1 / m), atol=5e-4)
        np.testing.assert_allclose(req.plan.sum(0), np.full(n, 1 / n), atol=5e-4)


def test_more_requests_than_slots_recycles():
    """5 same-bucket requests through 2 slots: all finish, in <= 1 bucket."""
    rng = np.random.default_rng(1)
    reqs = [_make_request(rng, rid, 4, 6, 32)[0] for rid in range(5)]
    engine = OTServingEngine(
        GroupSparseReg.from_rho(1.0, 0.6), OPTS, max_batch=2
    )
    done = engine.run(reqs)
    assert sorted(r.rid for r in done) == [0, 1, 2, 3, 4]
    assert len(engine.buckets) == 1
    assert all(r.converged for r in done)


def test_admission_preserves_inflight_neighbor():
    """Admitting into a bucket mid-solve must not perturb the neighbor:
    its final value equals a run without the late arrival."""
    rng = np.random.default_rng(2)
    r0, _ = _make_request(rng, 0, 4, 6, 30)
    r1, _ = _make_request(rng, 1, 4, 6, 31)
    reg = GroupSparseReg.from_rho(1.0, 0.6)

    # reference: r0 alone
    e0 = OTServingEngine(reg, OPTS, max_batch=2)
    ref = {r.rid: r.value for r in e0.run([OTRequest(r0.rid, r0.C, r0.labels)])}

    # r0 starts, r1 arrives after two ticks into the same bucket
    engine = OTServingEngine(reg, OPTS, max_batch=2)
    assert engine.try_admit(OTRequest(r0.rid, r0.C, r0.labels))
    finished = []
    finished += engine.tick()
    finished += engine.tick()
    assert engine.try_admit(OTRequest(r1.rid, r1.C, r1.labels))
    while len(finished) < 2:
        finished += engine.tick()
    vals = {r.rid: r.value for r in finished}
    assert vals[0] == pytest.approx(ref[0], abs=0.0)  # bitwise-preserved


def test_no_head_of_line_blocking_across_buckets():
    """A full bucket at the queue head must not starve other buckets:
    the lone bucket-B request finishes while surplus bucket-A requests are
    still waiting for slots."""
    rng = np.random.default_rng(4)
    reqs_a = [_make_request(rng, rid, 4, 6, 32)[0] for rid in range(3)]
    req_b, _ = _make_request(rng, 99, 5, 8, 32)
    engine = OTServingEngine(
        GroupSparseReg.from_rho(1.0, 0.6), OPTS, max_batch=1
    )
    done = engine.run(reqs_a + [req_b])
    assert sorted(r.rid for r in done) == [0, 1, 2, 99]
    # with max_batch=1 and 3 A-requests ahead of it, B can only have been
    # served concurrently if admission skipped over the blocked A queue
    assert req_b.done and req_b.converged


def test_mixed_regularizer_streams_do_not_share_buckets():
    """Requests with identical padded geometry but different regularizers
    must land in different buckets (the compiled program and the screening
    thresholds specialize per regularizer), and every retired request must
    match a solo solve with ITS regularizer."""
    rng = np.random.default_rng(5)
    regs = {
        0: None,                                            # engine default
        1: L2Reg(gamma=0.4),
        2: ElasticNetGroupReg(gamma=0.4, mu_weights=(0.0, 0.5, 1.0, 1.5)),
        3: None,                                            # shares bucket w/ 0
    }
    reqs, raws = [], []
    for rid, reg in regs.items():
        req, raw = _make_request(rng, rid, 4, 6, 30 + rid)  # same bucket geom
        req.reg = reg
        reqs.append(req)
        raws.append(raw)

    default = GroupSparseReg.from_rho(1.0, 0.6)
    engine = OTServingEngine(default, OPTS, max_batch=4, n_quant=64)
    done = engine.run(reqs)
    assert sorted(r.rid for r in done) == [0, 1, 2, 3]
    # one geometry, three regularizers -> exactly three buckets
    assert len(engine.buckets) == 3
    kinds = sorted(type(key[3]).kind for key in engine.buckets)
    assert kinds == ["elastic_net", "group_sparse", "l2"]

    for req, (Xs, labels, Xt) in zip(reqs, raws):
        assert req.done and req.converged
        reg = req.reg if req.reg is not None else default
        sol = solve_groupsparse_ot(Xs, labels, Xt, reg=reg, opts=OPTS, pad_to=8)
        np.testing.assert_allclose(req.value, sol.value, rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(req.plan, sol.plan, rtol=1e-3, atol=2e-4)

    # a malformed per-group regularizer is rejected at admission, BEFORE
    # any slot/bucket mutation — it must not poison the engine
    bad = reqs[0]
    malformed = OTRequest(
        rid=9, C=bad.C, labels=bad.labels,
        reg=ElasticNetGroupReg(gamma=1.0, mu_weights=(0.1, 0.2)),  # 2 != 4
    )
    with pytest.raises(ValueError, match="group"):
        engine.try_admit(malformed)
    assert len(engine.buckets) == 3                       # no new bucket
    assert all(not b.occupied() for b in engine.buckets.values())
    assert engine.tick() == []                            # engine still healthy


def test_retired_plan_matches_solo_solve_per_regularizer():
    """A request retired from a mixed-convergence bucket gets the same plan
    (bitwise value) as the same problem solved alone with the same
    regularizer — for the non-default kinds too."""
    rng = np.random.default_rng(6)
    for reg in (
        L2Reg(gamma=0.4),
        ElasticNetGroupReg(gamma=0.4, mu_weights=(0.0, 0.5, 1.0, 1.5)),
    ):
        r0, _ = _make_request(rng, 0, 4, 6, 30)
        r1, _ = _make_request(rng, 1, 4, 6, 31)
        r0.reg = r1.reg = reg

        # reference: r0 alone in its own engine
        e0 = OTServingEngine(GroupSparseReg.from_rho(1.0, 0.6), OPTS, max_batch=2)
        solo = OTRequest(r0.rid, r0.C, r0.labels, reg=reg)
        ref = {r.rid: (r.value, r.plan) for r in e0.run([solo])}

        # r0 + a late-arriving bucket-mate
        engine = OTServingEngine(GroupSparseReg.from_rho(1.0, 0.6), OPTS, max_batch=2)
        assert engine.try_admit(OTRequest(r0.rid, r0.C, r0.labels, reg=reg))
        finished = []
        finished += engine.tick()
        assert engine.try_admit(OTRequest(r1.rid, r1.C, r1.labels, reg=reg))
        while len(finished) < 2:
            finished += engine.tick()
        vals = {r.rid: (r.value, r.plan) for r in finished}
        assert vals[0][0] == pytest.approx(ref[0][0], abs=0.0), type(reg).kind
        np.testing.assert_array_equal(vals[0][1], ref[0][1])


def test_engine_dispatch_efficiency():
    """B requests in one bucket tick with ONE launch per round, not B."""
    rng = np.random.default_rng(3)
    reqs = [_make_request(rng, rid, 4, 6, 32)[0] for rid in range(4)]
    engine = OTServingEngine(
        GroupSparseReg.from_rho(1.0, 0.6), OPTS, max_batch=4
    )
    for r in reqs:
        assert engine.try_admit(r)
    reset_dispatch_count()
    engine.tick()
    # one fused batch_round for the whole bucket
    assert dispatch_count() == 1
