"""Sharded batched solving: shard_map over 4 host devices == unsharded, bitwise.

Same subprocess pattern as test_distributed.py: the host-platform device
count must be forced before jax initializes, so each test spawns a child
with its own XLA_FLAGS.  The contracts under test:

  * ``solve_batch_sharded`` over a 4-device mesh is bitwise-identical per
    problem to the unsharded ``solve_batch`` on all three ``grad_impl``
    backends (duals, objectives, round counts, screening stats),
  * a ragged batch (B not divisible by the mesh) pads with dummy problems
    and un-pads on return without perturbing real problems,
  * the multi-device serving engine packs slots across (device, lane),
    retires under mixed convergence times with ONE launch per tick, and
    serves every request to its solo-solve objective.
"""
import os
import subprocess
import sys
import textwrap
from pathlib import Path

SRC = str(Path(__file__).resolve().parents[1] / "src")


def _run(code: str, devices: int = 4, timeout: int = 600):
    env = dict(
        os.environ,
        XLA_FLAGS=f"--xla_force_host_platform_device_count={devices}",
        PYTHONPATH=SRC,
        JAX_PLATFORMS="cpu",
    )
    return subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        env=env, capture_output=True, text=True, timeout=timeout,
    )


_PROBLEM_SETUP = """
    import numpy as np, jax, jax.numpy as jnp
    from repro.core import groups as G
    from repro.core.regularizers import GroupSparseReg
    from repro.core.ot import squared_euclidean_cost
    from repro.core import solver as slv
    from repro.core.lbfgs import LbfgsOptions

    assert jax.device_count() == 4, jax.device_count()
    rng = np.random.default_rng(3)
    L, g, n = 5, 8, 40
    m = L * g
    labels = np.repeat(np.arange(L), g)
    spec = G.spec_from_labels(labels, pad_to=4)

    def make_batch(B):
        Cs, As, Bs = [], [], []
        for _ in range(B):
            Xs = rng.normal(size=(m, 2)) + labels[:, None] * 3.0
            Xt = rng.normal(size=(n, 2)) + rng.integers(0, L, n)[:, None] * 3.0
            C = squared_euclidean_cost(Xs, Xt).astype(np.float32)
            C /= C.max()
            Cs.append(G.pad_cost_matrix(C, labels, spec))
            As.append(G.pad_marginal(np.full(m, 1/m, np.float32), labels, spec))
            Bs.append(np.full(n, 1/n, np.float32))
        return (jnp.asarray(np.stack(Cs)), jnp.asarray(np.stack(As)),
                jnp.asarray(np.stack(Bs)))

    reg = GroupSparseReg.from_rho(1.0, 0.6)
"""


def test_sharded_solve_batch_bitwise_all_backends():
    """4-device sharded solve == unsharded solve_batch, bitwise, per backend.

    Bitwise means: identical dual iterates, identical objectives, identical
    per-problem round counts, identical screening-verdict stats — the
    sharding must be invisible to every problem's trajectory.
    """
    r = _run(_PROBLEM_SETUP + """
    from repro.core.sharded import solve_batch_sharded

    C, a, b = make_batch(8)
    for gi in ("dense", "screened", "pallas"):
        opts = slv.SolveOptions(
            grad_impl=gi, lbfgs=LbfgsOptions(max_iters=150)
        )
        rs = solve_batch_sharded(C, a, b, spec, reg, opts)
        rb = slv.solve_batch(C, a, b, spec, reg, opts)
        assert bool(jnp.all(rs.alpha == rb.alpha)), gi
        assert bool(jnp.all(rs.beta == rb.beta)), gi
        assert bool(jnp.all(rs.values == rb.values)), gi
        assert bool(jnp.all(rs.rounds == rb.rounds)), gi
        assert bool(jnp.all(rs.stats == rb.stats)), gi
        assert bool(jnp.all(rs.converged)), gi
        print("MATCH", gi)
    """)
    assert r.returncode == 0, r.stderr[-3000:]
    for gi in ("dense", "screened", "pallas"):
        assert f"MATCH {gi}" in r.stdout


def test_sharded_bitwise_parity_per_regularizer():
    """The PR 4 regularizer subsystem must be invisible to the sharding:
    sharded == unsharded bitwise for the pure-l2 and elastic-net kinds on
    all three backends (the group-sparse kind is covered exhaustively by
    test_sharded_solve_batch_bitwise_all_backends above)."""
    r = _run(_PROBLEM_SETUP + """
    from repro.core.regularizers import ElasticNetGroupReg, L2Reg
    from repro.core.sharded import solve_batch_sharded

    C, a, b = make_batch(4)
    regs = {
        "l2": L2Reg(gamma=0.4),
        "elastic_net": ElasticNetGroupReg(
            gamma=0.4, mu_weights=(0.0, 0.4, 0.8, 1.2, 1.6)
        ),
    }
    for kind, reg_k in regs.items():
        for gi in ("dense", "screened", "pallas"):
            opts = slv.SolveOptions(
                grad_impl=gi, lbfgs=LbfgsOptions(max_iters=150)
            )
            rs = solve_batch_sharded(C, a, b, spec, reg_k, opts)
            rb = slv.solve_batch(C, a, b, spec, reg_k, opts)
            assert bool(jnp.all(rs.alpha == rb.alpha)), (kind, gi)
            assert bool(jnp.all(rs.beta == rb.beta)), (kind, gi)
            assert bool(jnp.all(rs.values == rb.values)), (kind, gi)
            assert bool(jnp.all(rs.rounds == rb.rounds)), (kind, gi)
            assert bool(jnp.all(rs.stats == rb.stats)), (kind, gi)
            assert bool(jnp.all(rs.converged)), (kind, gi)
            print("MATCH", kind, gi)
    """)
    assert r.returncode == 0, r.stderr[-3000:]
    for kind in ("l2", "elastic_net"):
        for gi in ("dense", "screened", "pallas"):
            assert f"MATCH {kind} {gi}" in r.stdout


def test_sharded_ragged_batch_and_launch_count():
    """B=6 over 4 devices pads with dummies, un-pads, stays bitwise; the
    whole sharded solve is ONE program launch."""
    r = _run(_PROBLEM_SETUP + """
    from repro.core.sharded import solve_batch_sharded

    C, a, b = make_batch(6)
    opts = slv.SolveOptions(
        grad_impl="screened", lbfgs=LbfgsOptions(max_iters=150)
    )
    slv.reset_dispatch_count()
    rs = solve_batch_sharded(C, a, b, spec, reg, opts)
    assert slv.dispatch_count() == 1, slv.dispatch_count()
    rb = slv.solve_batch(C, a, b, spec, reg, opts)
    assert len(rs) == 6
    assert bool(jnp.all(rs.alpha == rb.alpha))
    assert bool(jnp.all(rs.values == rb.values))
    assert bool(jnp.all(rs.rounds == rb.rounds))
    # result slicing gathers coherently across shards
    assert float(rs[2].value) == float(rb[2].value)
    print("MATCH ragged")
    """)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "MATCH ragged" in r.stdout


def test_sharded_engine_slot_packing_and_retire():
    """Multi-device engine: slots pack over (device, lane) via least-loaded
    admission, ticks launch ONE sharded program, requests retire at their
    own (mixed) convergence rounds, and late admissions into a running
    sharded bucket don't perturb in-flight neighbours."""
    r = _run("""
        import numpy as np, jax
        from repro.core.distributed import make_batch_mesh
        from repro.core.lbfgs import LbfgsOptions
        from repro.core.ot import solve_groupsparse_ot, squared_euclidean_cost
        from repro.core.regularizers import GroupSparseReg
        from repro.core.solver import (
            SolveOptions, dispatch_count, reset_dispatch_count,
        )
        from repro.serving.ot_engine import OTRequest, OTServingEngine

        OPTS = SolveOptions(grad_impl="screened",
                            lbfgs=LbfgsOptions(max_iters=150))

        def mk(rng, rid, n):
            L, g = 4, 6
            m = L * g
            labels = np.repeat(np.arange(L), g)
            Xs = rng.normal(size=(m, 2)) + labels[:, None] * 3.0
            Xt = rng.normal(size=(n, 2)) + rng.integers(0, L, n)[:, None] * 3.0
            C = squared_euclidean_cost(Xs, Xt).astype(np.float32)
            C /= C.max()
            return OTRequest(rid=rid, C=C, labels=labels), (Xs, labels, Xt)

        mesh = make_batch_mesh(4)
        rng = np.random.default_rng(0)
        reqs, raws = [], []
        for rid in range(6):
            req, raw = mk(rng, rid, 30 + rid)
            reqs.append(req); raws.append(raw)

        engine = OTServingEngine(
            GroupSparseReg.from_rho(1.0, 0.6), OPTS, max_batch=2, mesh=mesh,
        )
        # admit 4 first: least-loaded policy must spread one per device
        for req in reqs[:4]:
            assert engine.try_admit(req)
        bucket = list(engine.buckets.values())[0]
        assert bucket.num_slots == 8, bucket.num_slots
        devs = sorted(bucket.slot_placement(i)[0] for i in bucket.occupied())
        assert devs == [0, 1, 2, 3], devs

        # run two rounds, then admit two more mid-flight
        reset_dispatch_count()
        done = []
        done += engine.tick(); done += engine.tick()
        assert dispatch_count() == 2          # one sharded launch per tick
        for req in reqs[4:]:
            assert engine.try_admit(req)
        ticks = 2
        while len(done) < 6:
            done += engine.tick(); ticks += 1
            assert ticks < 200
        assert sorted(r.rid for r in done) == list(range(6))

        rounds = sorted({r.rounds for r in done})
        assert len(rounds) > 1, rounds        # genuinely mixed retire times
        for req, (Xs, labels, Xt) in zip(reqs, raws):
            assert req.done and req.converged
            sol = solve_groupsparse_ot(
                Xs, labels, Xt, gamma=1.0, rho=0.6, opts=OPTS, pad_to=8,
            )
            np.testing.assert_allclose(
                req.value, sol.value, rtol=1e-5, atol=1e-6
            )
            m, n = req.C.shape
            np.testing.assert_allclose(
                req.plan.sum(1), np.full(m, 1/m), atol=5e-4
            )
        print("MATCH engine rounds=", rounds)
    """)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "MATCH engine" in r.stdout
