"""Core OT library: regularizer math, dual, screening exactness, solver."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import groups as G
from repro.core.dual import (
    DualProblem,
    dual_value_and_grad,
    primal_objective,
)
from repro.core.lbfgs import LbfgsOptions
from repro.core.ot import (
    group_sparsity,
    solve_groupsparse_ot,
    squared_euclidean_cost,
)
from repro.core.regularizers import GroupSparseReg, grad_psi, psi_value
from repro.core.sinkhorn import sinkhorn_log
from repro.core.solver import SolveOptions, recover_plan, solve_dual

# exercises the deprecated solve_groupsparse_ot shim ON PURPOSE (the
# façade's own coverage lives in test_facade.py)
pytestmark = pytest.mark.filterwarnings(
    "ignore:solve_groupsparse_ot:DeprecationWarning"
)


def _problem(rng, L=5, g=8, n=40, rho=0.6, gamma=1.0, pad_to=4):
    m = L * g
    labels = np.repeat(np.arange(L), g)
    Xs = rng.normal(size=(m, 2)) + labels[:, None] * 3.0
    Xt = rng.normal(size=(n, 2)) + rng.integers(0, L, n)[:, None] * 3.0
    C = squared_euclidean_cost(Xs, Xt).astype(np.float32)
    C /= C.max()
    spec = G.spec_from_labels(labels, pad_to=pad_to)
    C_pad = jnp.asarray(G.pad_cost_matrix(C, labels, spec))
    a = jnp.asarray(G.pad_marginal(np.full(m, 1 / m, np.float32), labels, spec))
    b = jnp.asarray(np.full(n, 1 / n, np.float32))
    reg = GroupSparseReg.from_rho(gamma, rho)
    prob = DualProblem(spec.num_groups, spec.group_size, n, reg)
    return spec, C_pad, a, b, reg, prob, labels, Xs, Xt


def test_conjugate_matches_bruteforce_sup():
    """psi(f) = sup_{g>=0} f.g - Psi(g): check against projected gradient."""
    rng = np.random.default_rng(0)
    L, g = 3, 4
    reg = GroupSparseReg(gamma=0.7, mu=0.4)
    f = jnp.asarray(rng.normal(size=(L * g,)).astype(np.float32))
    want = psi_value(f, L, reg)
    # numeric sup via projected gradient ascent on g >= 0
    gv = jnp.zeros_like(f)
    lr = 0.1
    for _ in range(3000):
        grad = f - reg.gamma * (
            gv
            + reg.mu
            * (gv.reshape(L, g) / jnp.maximum(
                jnp.linalg.norm(gv.reshape(L, g), axis=1, keepdims=True), 1e-12
            )).reshape(-1)
        )
        gv = jnp.maximum(gv + lr * grad, 0.0)
    from repro.core.regularizers import primal_regularizer

    got = f @ gv - primal_regularizer(gv[:, None], L, reg)
    np.testing.assert_allclose(float(want), float(got), rtol=1e-3, atol=1e-4)


def test_gradpsi_is_argmax_of_conjugate():
    rng = np.random.default_rng(1)
    L, g = 4, 5
    reg = GroupSparseReg(gamma=0.5, mu=0.3)
    f = jnp.asarray(rng.normal(size=(L * g,)).astype(np.float32))
    gstar = grad_psi(f, L, reg)
    assert bool(jnp.all(gstar >= 0))
    # AD of psi_value must equal the closed form (Danskin)
    gad = jax.grad(lambda ff: psi_value(ff, L, reg))(f)
    np.testing.assert_allclose(np.asarray(gstar), np.asarray(gad), atol=1e-5)


def test_closed_form_grad_matches_ad():
    rng = np.random.default_rng(2)
    spec, C, a, b, reg, prob, *_ = _problem(rng)
    alpha = jnp.asarray(rng.normal(size=spec.m_pad).astype(np.float32) * 0.3)
    beta = jnp.asarray(rng.normal(size=prob.n).astype(np.float32) * 0.3)
    v, (ga, gb) = dual_value_and_grad(alpha, beta, C, a, b, prob)
    ga_ad, gb_ad = jax.grad(
        lambda x, y: dual_value_and_grad(x, y, C, a, b, prob)[0], argnums=(0, 1)
    )(alpha, beta)
    np.testing.assert_allclose(np.asarray(ga), np.asarray(ga_ad), atol=2e-5)
    np.testing.assert_allclose(np.asarray(gb), np.asarray(gb_ad), atol=2e-5)


@pytest.mark.parametrize("rho", [0.2, 0.6, 0.8])
def test_screened_equals_dense_full_solve(rho):
    """Theorem 2: identical objective value and iterate trajectory."""
    rng = np.random.default_rng(3)
    spec, C, a, b, reg, prob, *_ = _problem(rng, rho=rho)
    opts_d = SolveOptions(grad_impl="dense", lbfgs=LbfgsOptions(max_iters=300))
    opts_s = SolveOptions(grad_impl="screened", lbfgs=LbfgsOptions(max_iters=300))
    rd = solve_dual(C, a, b, spec, reg, opts_d)
    rs = solve_dual(C, a, b, spec, reg, opts_s)
    assert rd.iterations == rs.iterations  # identical trajectory
    np.testing.assert_allclose(rd.value, rs.value, rtol=1e-6)
    np.testing.assert_allclose(
        np.asarray(rd.alpha), np.asarray(rs.alpha), atol=1e-6
    )


def test_pallas_impl_matches_dense_solution():
    rng = np.random.default_rng(4)
    spec, C, a, b, reg, prob, *_ = _problem(rng, L=4, g=8, n=32)
    opts_d = SolveOptions(grad_impl="dense", lbfgs=LbfgsOptions(max_iters=250))
    opts_p = SolveOptions(grad_impl="pallas", lbfgs=LbfgsOptions(max_iters=250))
    rd = solve_dual(C, a, b, spec, reg, opts_d)
    rp = solve_dual(C, a, b, spec, reg, opts_p)
    # fp32 summation-order differences may shift the trajectory slightly;
    # the converged objective must agree tightly.
    np.testing.assert_allclose(rd.value, rp.value, rtol=2e-5, atol=2e-5)


def test_tight_active_refresh_same_result():
    rng = np.random.default_rng(5)
    spec, C, a, b, reg, prob, *_ = _problem(rng)
    r1 = solve_dual(C, a, b, spec, reg, SolveOptions(grad_impl="screened"))
    r2 = solve_dual(
        C, a, b, spec, reg,
        SolveOptions(grad_impl="screened", tight_active_refresh=True),
    )
    np.testing.assert_allclose(r1.value, r2.value, rtol=1e-6)
    # the tighter refresh can only (weakly) grow the certified-active set
    assert r2.stats["active"] >= r1.stats["active"]


def test_marginals_and_duality_gap_at_convergence():
    rng = np.random.default_rng(6)
    spec, C, a, b, reg, prob, labels, Xs, Xt = _problem(rng)
    res = solve_dual(
        C, a, b, spec, reg,
        SolveOptions(lbfgs=LbfgsOptions(max_iters=800, gtol=1e-7)),
    )
    T = recover_plan(res, C, spec, reg)
    row = jnp.sum(T, axis=1)
    col = jnp.sum(T, axis=0)
    assert float(jnp.max(jnp.abs(row - a))) < 5e-4
    assert float(jnp.max(jnp.abs(col - b))) < 5e-4
    row_mask = jnp.asarray(spec.row_mask().reshape(-1))
    primal = primal_objective(T, C, prob, row_mask)
    # weak duality + small gap at convergence
    assert float(primal) >= float(res.value) - 1e-4
    assert float(primal) - float(res.value) < 5e-3


def test_group_sparsity_increases_with_rho():
    rng = np.random.default_rng(7)
    m = 40
    labels = np.repeat(np.arange(5), 8)
    Xs = rng.normal(size=(m, 2)) + labels[:, None] * 4.0
    Xt = rng.normal(size=(m, 2)) + labels[:, None] * 4.0
    sp = []
    for rho in (0.2, 0.8):
        sol = solve_groupsparse_ot(Xs, labels, Xt, gamma=1.0, rho=rho)
        sp.append(group_sparsity(sol, labels, tol=1e-7))
    assert sp[1] >= sp[0]
    assert sp[1] > 0.5  # strong regularization => strongly group-sparse plan


def test_barycentric_map_preserves_class_geometry():
    rng = np.random.default_rng(8)
    labels = np.repeat(np.arange(4), 6)
    Xs = rng.normal(size=(24, 2)) + np.stack([labels * 5.0, -5.0 * np.ones(24)], 1)
    Xt = rng.normal(size=(24, 2)) + np.stack([labels * 5.0, 5.0 * np.ones(24)], 1)
    sol = solve_groupsparse_ot(Xs, labels, Xt, gamma=10.0, rho=0.4)
    # barycentric map expresses each TARGET as the mean of the sources that
    # send it mass (paper: X^T recovered as n T^T X^S) — so the mapped points
    # sit at the SOURCE y-level, with x-coordinates matching the target's
    # class column (class structure preserved by the group-sparse plan).
    Xt_hat = sol.transport_sources(Xs)
    assert abs(float(np.mean(Xt_hat[:, 1])) + 5.0) < 1.5
    # class alignment: mapped x-coordinate correlates with the target's class
    corr = np.corrcoef(Xt_hat[:, 0], labels * 5.0)[0, 1]
    assert corr > 0.9


def test_sinkhorn_baseline_matches_uniform_marginals():
    rng = np.random.default_rng(9)
    m = n = 16
    C = jnp.asarray((rng.random((m, n)) ** 2).astype(np.float32))
    a = jnp.full((m,), 1 / m)
    b = jnp.full((n,), 1 / n)
    res = sinkhorn_log(C, a, b, eps=0.05, max_iters=3000, tol=1e-9)
    np.testing.assert_allclose(np.asarray(res.plan.sum(1)), np.asarray(a), atol=1e-5)
    np.testing.assert_allclose(np.asarray(res.plan.sum(0)), np.asarray(b), atol=1e-5)


def test_solver_stats_reflect_sparsity():
    rng = np.random.default_rng(10)
    spec, C, a, b, reg, prob, *_ = _problem(rng, rho=0.8)
    res = solve_dual(C, a, b, spec, reg, SolveOptions(grad_impl="screened"))
    total = sum(res.stats.values())
    assert total > 0
    assert res.stats["zero"] / total > 0.3  # screening actually fires
