"""Differentiable OT layer: Danskin gradients against ground truth.

Three independent referees certify ``jax.grad`` of the layer:

  * f64 central finite differences of the unscreened reference solver
    (committed in tests/fixtures/golden_diff.json; tools/gen_golden_diff.py
    regenerates them) — the strongest oracle, backend-free;
  * AD through :func:`repro.ot.diff.unrolled_value` — a plain dual-ascent
    solver written so JAX *can* differentiate through it;
  * bitwise cross-backend agreement — every grad_impl solves the same
    padded problem, so the refined layer value must be bit-identical.

Plus the stochastic minibatch solver's contract: deterministic given its
seed, and converging to the exact L-BFGS objective on the golden problem.
"""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.ot as ot
from repro.core import groups as G
from repro.core.regularizers import GroupSparseReg
from repro.ot import diff
from tests.conftest import FIXTURE_DIR

# (grad_impl, pallas_impl) combos that must agree bitwise and match FD
BACKENDS = [
    ("dense", "auto"),
    ("screened", "auto"),
    ("pallas", "grid"),
    ("pallas", "compact"),
    ("fused", "grid"),
]

# the FD harness needs the dual residual at the f32 noise floor; the plain
# f32 L-BFGS line search stalls around ||g||~1e-4, so the layer appends
# fixed-step exact ascent (OTLayer.grad_refine) — see the layer docstring
PLAN_KW = dict(gtol=1e-7, max_iters=2000, ftol=1e-12)
REFINE_DENSE = 1000
REFINE_SAMPLES = 2000


@pytest.fixture(scope="module")
def golden():
    with open(os.path.join(FIXTURE_DIR, "golden_diff.json")) as f:
        data = json.load(f)
    assert data["schema_version"] == 1
    return data


def _dense_problem(golden):
    c = golden["dense"]["coords"]
    L, g, n = c["L"], c["g"], c["n"]
    rng = np.random.default_rng(c["seed"])
    C = rng.random((L * g, n), dtype=np.float32)
    reg = GroupSparseReg.from_rho(golden["dense"]["gamma"],
                                  golden["dense"]["rho"])
    return C, L, g, n, reg


def _samples_problem(golden):
    c = golden["samples"]["coords"]
    L, g, n, d = c["L"], c["g"], c["n"], c["d"]
    rng = np.random.default_rng(c["seed"])
    X = rng.normal(size=(L * g, d)).astype(np.float32)
    Y = rng.normal(size=(n, d)).astype(np.float32)
    reg = GroupSparseReg.from_rho(golden["samples"]["gamma"],
                                  golden["samples"]["rho"])
    return X, Y, L, g, n, reg


def _layer(L, g, n, reg, grad_impl, pallas_impl, **kw):
    plan = ot.ExecutionPlan(grad_impl=grad_impl, pallas_impl=pallas_impl,
                            **PLAN_KW)
    return diff.OTLayer(L, g, n, reg, plan=plan, **kw)


# -- value: bitwise parity with the façade, cross-backend, vs f64 -------------

def test_layer_value_bitwise_equals_executor(golden):
    """grad_refine=0 runs the Executor's exact jitted program."""
    C, L, g, n, reg = _dense_problem(golden)
    spec = G.GroupSpec(num_groups=L, group_size=g, sizes=(g,) * L, m=L * g)
    a = np.full(L * g, 1.0 / (L * g), np.float32)
    b = np.full(n, 1.0 / n, np.float32)
    prob = ot.Problem.from_padded(C, a, b, spec, reg)
    for grad_impl, pallas_impl in BACKENDS:
        plan = ot.ExecutionPlan(grad_impl=grad_impl, pallas_impl=pallas_impl,
                                **PLAN_KW)
        sol = ot.compile(prob, plan).solve()
        layer = diff.OTLayer(L, g, n, reg, plan=plan)
        v = layer(C)
        assert float(v) == float(sol.value), (grad_impl, pallas_impl)


def test_refined_value_bitwise_across_backends(golden):
    """All five backends refine to the SAME f32 value, bit for bit, and it
    sits on the committed f64 optimum."""
    C, L, g, n, reg = _dense_problem(golden)
    vals = []
    for grad_impl, pallas_impl in BACKENDS:
        layer = _layer(L, g, n, reg, grad_impl, pallas_impl,
                       grad_refine=REFINE_DENSE)
        vals.append(float(layer(C)))
    assert len(set(vals)) == 1, vals
    assert vals[0] == pytest.approx(golden["dense"]["value_f64"], abs=5e-6)


# -- dense cost: Danskin grad vs committed f64 FD, every backend --------------

@pytest.mark.parametrize("grad_impl,pallas_impl", BACKENDS)
def test_danskin_grad_matches_f64_fd_dense(golden, grad_impl, pallas_impl):
    C, L, g, n, reg = _dense_problem(golden)
    layer = _layer(L, g, n, reg, grad_impl, pallas_impl,
                   grad_refine=REFINE_DENSE)
    val, grad = jax.jit(jax.value_and_grad(layer))(jnp.asarray(C))
    grad = np.asarray(grad)
    ginf = np.abs(grad).max()
    assert ginf > 0
    for i, j, fd in golden["dense"]["fd_probes"]:
        assert abs(grad[i, j] - fd) <= 1e-4 * ginf, (i, j, grad[i, j], fd)
    # the Danskin gradient IS the optimal plan: nonnegative, row sums = a
    assert grad.min() >= 0
    np.testing.assert_allclose(grad.sum(1), np.full(L * g, 1.0 / (L * g)),
                               atol=2e-4)


def test_ot_loss_functional_matches_layer(golden):
    C, L, g, n, reg = _dense_problem(golden)
    layer = _layer(L, g, n, reg, "screened", "auto")
    v1 = layer(C)
    v2 = ot.ot_loss(jnp.asarray(C), num_groups=L, group_size=g, reg=reg,
                    plan=layer.plan)
    assert float(v1) == float(v2)


def test_grad_wrt_marginals_are_optimal_duals(golden):
    """Danskin for the marginals: dW/da = alpha*, dW/db = beta* — checked
    against the duals the SAME refined solve reports."""
    C, L, g, n, reg = _dense_problem(golden)
    layer = _layer(L, g, n, reg, "dense", "auto", grad_refine=REFINE_DENSE)
    a = jnp.full((L * g,), 1.0 / (L * g), jnp.float32)
    b = jnp.full((n,), 1.0 / n, jnp.float32)
    ga = jax.grad(layer, argnums=1)(jnp.asarray(C), a, b)
    gb = jax.grad(layer, argnums=2)(jnp.asarray(C), a, b)
    _, alpha, beta = diff._solve_duals(layer, jnp.asarray(C), a, b)
    np.testing.assert_array_equal(np.asarray(ga), np.asarray(alpha))
    np.testing.assert_array_equal(np.asarray(gb), np.asarray(beta))


# -- dense cost: Danskin grad vs AD through an unrolled solver ----------------

def test_danskin_grad_matches_unrolled_ad(golden):
    """Differentiating THROUGH 3000 unrolled dual-ascent steps lands on the
    same gradient the envelope theorem gives in one backward pass."""
    C, L, g, n, reg = _dense_problem(golden)
    a = jnp.full((L * g,), 1.0 / (L * g), jnp.float32)
    b = jnp.full((n,), 1.0 / n, jnp.float32)

    layer = _layer(L, g, n, reg, "dense", "auto", grad_refine=REFINE_DENSE)
    v_d, g_d = jax.value_and_grad(layer)(jnp.asarray(C))

    unrolled = jax.jit(jax.value_and_grad(
        lambda Cm: diff.unrolled_value(Cm, a, b, num_groups=L, group_size=g,
                                       reg=reg)
    ))
    v_u, g_u = unrolled(jnp.asarray(C))
    assert np.all(np.isfinite(np.asarray(g_u)))
    assert float(v_u) == pytest.approx(float(v_d), abs=2e-6)
    # both sides carry their own f32 solver residual (~1e-6 each); the
    # envelope and unrolled gradients agree to the combined noise floor
    assert float(jnp.abs(g_u - g_d).max()) <= 1e-5


# -- samples mode: materialization-free pullback vs committed f64 FD ----------

@pytest.mark.parametrize("grad_impl,pallas_impl",
                         [("dense", "auto"), ("pallas", "grid"),
                          ("fused", "grid")])
def test_samples_grad_matches_f64_fd(golden, grad_impl, pallas_impl):
    """from_samples chain-rules dW/dC = T* to the coordinates (normalized
    geometry, scale frozen exactly like the fixture's FD reference)."""
    X, Y, L, g, n, reg = _samples_problem(golden)
    layer = _layer(L, g, n, reg, grad_impl, pallas_impl,
                   grad_refine=REFINE_SAMPLES, normalize_cost=True)
    f = jax.jit(jax.value_and_grad(
        lambda X_, Y_: layer.from_samples(X_, Y_), argnums=(0, 1)))
    val, (gX, gY) = f(jnp.asarray(X), jnp.asarray(Y))
    assert float(val) == pytest.approx(golden["samples"]["value_f64"],
                                       abs=5e-6)
    gX, gY = np.asarray(gX), np.asarray(gY)
    ginf = max(np.abs(gX).max(), np.abs(gY).max())
    assert ginf > 0
    for i, k, fd in golden["samples"]["fd_x_probes"]:
        assert abs(gX[i, k] - fd) <= 2e-4 * ginf, ("x", i, k, gX[i, k], fd)
    for j, k, fd in golden["samples"]["fd_y_probes"]:
        assert abs(gY[j, k] - fd) <= 2e-4 * ginf, ("y", j, k, gY[j, k], fd)


def test_samples_backends_agree(golden):
    """Factorized (pallas) and materialized (dense) sample routes compute
    the same value and the same coordinate gradients."""
    X, Y, L, g, n, reg = _samples_problem(golden)
    out = {}
    for grad_impl, pallas_impl in (("dense", "auto"), ("pallas", "grid")):
        layer = _layer(L, g, n, reg, grad_impl, pallas_impl,
                       grad_refine=REFINE_SAMPLES, normalize_cost=True)
        f = jax.value_and_grad(
            lambda X_, Y_: layer.from_samples(X_, Y_), argnums=(0, 1))
        out[grad_impl] = f(jnp.asarray(X), jnp.asarray(Y))
    v_d, (gx_d, gy_d) = out["dense"]
    v_p, (gx_p, gy_p) = out["pallas"]
    assert float(v_d) == pytest.approx(float(v_p), abs=1e-6)
    np.testing.assert_allclose(gx_d, gx_p, atol=1e-5)
    np.testing.assert_allclose(gy_d, gy_p, atol=1e-5)


def test_backward_pass_adds_no_solver_calls(golden):
    """O(1) solves per training step: value_and_grad = ONE forward solve,
    the backward pass is closed-form plan recovery."""
    C, L, g, n, reg = _dense_problem(golden)
    layer = _layer(L, g, n, reg, "screened", "auto")
    diff.reset_solve_count()
    jax.value_and_grad(layer)(jnp.asarray(C))   # eager: fwd rule runs once
    assert diff.solve_count() == 1


# -- stochastic minibatch solver ---------------------------------------------

def test_stochastic_converges_to_lbfgs_objective(golden):
    """The minibatch dual-ascent solver reaches the exact solver's
    objective on the golden problem (fixed seed, tolerance 1e-3)."""
    C, L, g, n, reg = _dense_problem(golden)
    spec = G.GroupSpec(num_groups=L, group_size=g, sizes=(g,) * L, m=L * g)
    a = np.full(L * g, 1.0 / (L * g), np.float32)
    b = np.full(n, 1.0 / n, np.float32)
    prob = ot.Problem.from_padded(C, a, b, spec, reg)

    exact = ot.compile(prob, ot.ExecutionPlan(grad_impl="dense",
                                              **PLAN_KW)).solve()
    plan = ot.ExecutionPlan(solver="stochastic", sgd_epochs=200,
                            sgd_batch_blocks=2, sgd_block_cols=4,
                            sgd_step_size=0.5, sgd_decay=0.02)
    sol1 = ot.compile(prob, plan).solve()
    assert abs(float(sol1.value) - float(exact.value)) <= 1e-3
    # deterministic given the seed: a rerun is bitwise identical
    sol2 = ot.compile(prob, plan).solve()
    assert float(sol1.value) == float(sol2.value)
    # a different seed takes a different path to the same neighborhood
    sol3 = ot.compile(prob, ot.ExecutionPlan(
        solver="stochastic", sgd_epochs=200, sgd_batch_blocks=2,
        sgd_block_cols=4, sgd_step_size=0.5, sgd_decay=0.02,
        sgd_seed=1)).solve()
    assert float(sol3.value) != float(sol1.value)
    assert abs(float(sol3.value) - float(exact.value)) <= 1e-3


def test_stochastic_layer_gradients_still_danskin(golden):
    """solver='stochastic' slots under the same custom_vjp: gradients are
    the plan recovered from ITS duals (row sums ~ a at convergence)."""
    C, L, g, n, reg = _dense_problem(golden)
    plan = ot.ExecutionPlan(solver="stochastic", sgd_epochs=200,
                            sgd_batch_blocks=2, sgd_block_cols=4,
                            sgd_step_size=0.5, sgd_decay=0.02)
    # the stochastic duals start farther from the optimum than L-BFGS's
    # (objective gap ~1e-4), so the polish loop needs more steps to reach
    # the same dual residual before the FD gate applies
    layer = diff.OTLayer(L, g, n, reg, plan=plan, grad_refine=4000)
    val, grad = jax.value_and_grad(layer)(jnp.asarray(C))
    grad = np.asarray(grad)
    assert grad.min() >= 0
    np.testing.assert_allclose(grad.sum(1), np.full(L * g, 1.0 / (L * g)),
                               atol=2e-4)
    for i, j, fd in golden["dense"]["fd_probes"]:
        assert abs(grad[i, j] - fd) <= 1e-4 * np.abs(grad).max()


def test_stochastic_rejects_stream_and_mesh(golden):
    C, L, g, n, reg = _dense_problem(golden)
    spec = G.GroupSpec(num_groups=L, group_size=g, sizes=(g,) * L, m=L * g)
    a = np.full(L * g, 1.0 / (L * g), np.float32)
    b = np.full(n, 1.0 / n, np.float32)
    prob = ot.Problem.from_padded(C, a, b, spec, reg)
    ex = ot.compile(prob, ot.ExecutionPlan(solver="stochastic"))
    with pytest.raises(ValueError, match="stream"):
        ex.stream([prob])
