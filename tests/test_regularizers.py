"""Differential harness for the pluggable regularizer subsystem.

Three layers of evidence, per regularizer kind (group-sparse / pure-l2 /
elastic-net group weights):

  * screened-Pallas vs dense NumPy reference: every ``pallas_impl`` mode
    (grid / compact / auto) must land on the same objective and plan as
    the f64 scipy reference in ``core.cpu_baseline`` (the "origin" method
    with the generalized per-group thresholds),
  * solo == batched bitwise on every ``grad_impl`` backend: the PR 2/3
    invariant — a problem solved alone and the same problem inside a
    batch take identical trajectories — must survive the regularizer
    abstraction,
  * golden known-answer fixtures: committed (seed, geometry, regularizer)
    -> expected objective values, so future refactors are gated on exact
    numbers, not just self-consistency.

Plus semantic checks that the new kinds mean what they claim (l2 plan
closed form; elastic-net per-group weights driving per-group sparsity).
"""
import numpy as np
import jax.numpy as jnp
import pytest

from conftest import make_ot_problem

from repro.core import cpu_baseline as cb
from repro.core.dual import DualProblem, plan_from_duals
from repro.core.lbfgs import LbfgsOptions
from repro.core.regularizers import (
    ElasticNetGroupReg,
    GroupSparseReg,
    L2Reg,
    from_config,
)
from repro.core.solver import SolveOptions, recover_plan, solve_batch, solve_dual

# the solo==batched layer exercises the deprecated solve_batch shim ON
# PURPOSE (façade-native parity lives in test_facade.py)
pytestmark = pytest.mark.filterwarnings(
    "ignore:solve_batch:DeprecationWarning"
)

REG_KINDS = ["group_sparse", "l2", "elastic_net"]

GEOM = dict(L=4, g=6, n=40, pad_to=8)


def _reg(kind: str, L: int):
    """One representative regularizer per kind (moderate strengths)."""
    if kind == "group_sparse":
        return GroupSparseReg.from_rho(1.0, 0.6)
    if kind == "l2":
        return L2Reg(gamma=0.4)
    if kind == "elastic_net":
        # mixed per-group weights, including an unpenalized group (mu=0)
        return ElasticNetGroupReg(
            gamma=0.4, mu_weights=tuple(0.5 * i for i in range(L))
        )
    raise ValueError(kind)


def _arrays(seed: int):
    Cp, a, b, spec, labels = make_ot_problem(seed, **GEOM)
    return jnp.asarray(Cp), jnp.asarray(a), jnp.asarray(b), spec


_CPU_REFS = {}


def _cpu_reference(kind: str):
    """f64 dense NumPy reference solve (cached per regularizer kind)."""
    if kind not in _CPU_REFS:
        Cp, a, b, spec, _ = make_ot_problem(0, **GEOM)
        reg = _reg(kind, spec.num_groups)
        ref = cb.origin_solve(Cp, a, b, spec, reg)
        prob = DualProblem(spec.num_groups, spec.group_size, Cp.shape[1], reg)
        plan = plan_from_duals(
            jnp.asarray(ref.alpha, jnp.float32),
            jnp.asarray(ref.beta, jnp.float32),
            jnp.asarray(Cp),
            prob,
        )
        _CPU_REFS[kind] = (ref, np.asarray(plan))
    return _CPU_REFS[kind]


# -- differential: screened Pallas vs dense NumPy reference -------------------

@pytest.mark.parametrize("kind", REG_KINDS)
def test_pallas_matches_dense_numpy_reference(kind):
    """All three kernel grid modes reproduce the f64 reference objective
    and plan; grid and compact stay bitwise-equal to each other."""
    C, a, b, spec = _arrays(0)
    reg = _reg(kind, spec.num_groups)
    ref, ref_plan = _cpu_reference(kind)

    results = {}
    for impl in ("grid", "compact", "auto"):
        opts = SolveOptions(
            grad_impl="pallas", pallas_impl=impl,
            lbfgs=LbfgsOptions(max_iters=200),
        )
        r = solve_dual(C, a, b, spec, reg, opts)
        assert r.converged, (kind, impl)
        np.testing.assert_allclose(
            float(r.value), ref.value, rtol=2e-5, atol=1e-6,
            err_msg=f"{kind}/{impl} objective drifted from the NumPy reference",
        )
        plan = np.asarray(recover_plan(r, C, spec, reg))
        np.testing.assert_allclose(plan, ref_plan, atol=5e-4)
        results[impl] = r

    # the two grid modes (and the density switch) are bitwise-equal
    for impl in ("compact", "auto"):
        assert float(results[impl].value) == float(results["grid"].value), kind
        assert bool(jnp.all(results[impl].alpha == results["grid"].alpha)), kind
        assert bool(jnp.all(results[impl].beta == results["grid"].beta)), kind


@pytest.mark.parametrize("kind", REG_KINDS)
def test_screened_backends_match_numpy_reference(kind):
    """'dense' and 'screened' XLA backends also land on the reference."""
    C, a, b, spec = _arrays(0)
    reg = _reg(kind, spec.num_groups)
    ref, _ = _cpu_reference(kind)
    for gi in ("dense", "screened"):
        opts = SolveOptions(grad_impl=gi, lbfgs=LbfgsOptions(max_iters=200))
        r = solve_dual(C, a, b, spec, reg, opts)
        assert r.converged, (kind, gi)
        np.testing.assert_allclose(
            float(r.value), ref.value, rtol=2e-5, atol=1e-6
        )
    # the screened oracle must actually skip for every kind (for l2 the
    # thresholds are zero, so this is pure nonnegativity skipping)
    assert r.stats["zero"] > 0, f"screening never fired for {kind}"


# -- solo == batched bitwise, per backend, per regularizer --------------------

@pytest.mark.parametrize("kind", REG_KINDS)
@pytest.mark.parametrize("grad_impl", ["dense", "screened", "pallas"])
def test_solo_equals_batched_bitwise(kind, grad_impl):
    C0, a0, b0, spec = _arrays(0)
    C1, a1, b1, _ = _arrays(1)
    reg = _reg(kind, spec.num_groups)
    opts = SolveOptions(grad_impl=grad_impl, lbfgs=LbfgsOptions(max_iters=200))

    rb = solve_batch(
        jnp.stack([C0, C1]), jnp.stack([a0, a1]), jnp.stack([b0, b1]),
        spec, reg, opts,
    )
    assert bool(jnp.all(rb.converged)), (kind, grad_impl)
    for i, (C, a, b) in enumerate([(C0, a0, b0), (C1, a1, b1)]):
        rs = solve_dual(C, a, b, spec, reg, opts)
        assert float(rs.value) == float(rb.values[i]), (kind, grad_impl, i)
        assert bool(jnp.all(rs.alpha == rb.alpha[i])), (kind, grad_impl, i)
        assert bool(jnp.all(rs.beta == rb.beta[i])), (kind, grad_impl, i)
        assert rs.rounds == int(rb.rounds[i]), (kind, grad_impl, i)


# -- golden known-answer fixtures ---------------------------------------------

def test_golden_fixture_values(golden_regularizer_cases):
    """Committed (seed, geometry, regularizer) -> expected objectives.

    Gates refactors on exact values: the jitted screened solve must land
    within float32-roundoff of the committed objective, the f64 scipy
    reference within f64 roundoff, and the plan's zero-block count (the
    group-sparsity structure) must match exactly.
    """
    for case in golden_regularizer_cases:
        Cp, a, b, spec, _ = make_ot_problem(
            case["seed"], case["L"], case["g"], case["n"],
            pad_to=case["pad_to"],
        )
        reg = from_config(case["reg"])
        opts = SolveOptions(
            grad_impl="screened", lbfgs=LbfgsOptions(max_iters=200)
        )
        r = solve_dual(
            jnp.asarray(Cp), jnp.asarray(a), jnp.asarray(b), spec, reg, opts
        )
        assert r.converged, case["name"]
        np.testing.assert_allclose(
            float(r.value), case["expected"]["value"], rtol=5e-6, atol=1e-9,
            err_msg=f"golden objective changed for {case['name']}",
        )
        ref = cb.origin_solve(Cp, a, b, spec, reg)
        np.testing.assert_allclose(
            ref.value, case["expected"]["cpu_value"], rtol=1e-7, atol=1e-10,
            err_msg=f"golden CPU objective changed for {case['name']}",
        )
        plan = np.asarray(recover_plan(r, jnp.asarray(Cp), spec, reg))
        L, g = spec.num_groups, spec.group_size
        blocks = plan.reshape(L, g, -1)
        zero_blocks = int(np.sum(np.max(np.abs(blocks), axis=1) <= 1e-9))
        assert zero_blocks == case["expected"]["zero_blocks"], case["name"]


# -- semantics of the new kinds -----------------------------------------------

def test_l2_plan_matches_closed_form():
    """Pure-l2 plan is exactly relu(alpha + beta - C) / gamma at the optimum."""
    C, a, b, spec = _arrays(0)
    reg = L2Reg(gamma=0.4)
    opts = SolveOptions(grad_impl="screened", lbfgs=LbfgsOptions(max_iters=200))
    r = solve_dual(C, a, b, spec, reg, opts)
    plan = np.asarray(recover_plan(r, C, spec, reg))
    f = np.asarray(r.alpha)[:, None] + np.asarray(r.beta)[None, :] - np.asarray(C)
    np.testing.assert_allclose(plan, np.maximum(f, 0.0) / reg.gamma, atol=1e-6)


def test_elastic_net_weights_drive_per_group_sparsity():
    """A heavily-weighted group is driven entirely to zero while an
    unpenalized group keeps transporting mass."""
    C, a, b, spec = _arrays(0)
    L, g = spec.num_groups, spec.group_size
    reg = ElasticNetGroupReg(gamma=0.5, mu_weights=(0.0, 0.3, 0.8, 8.0))
    opts = SolveOptions(grad_impl="screened", lbfgs=LbfgsOptions(max_iters=200))
    r = solve_dual(C, a, b, spec, reg, opts)
    assert r.converged
    plan = np.asarray(recover_plan(r, C, spec, reg)).reshape(L, g, -1)
    zero_frac = [float(np.mean(np.max(np.abs(blk), axis=0) <= 1e-9)) for blk in plan]
    assert zero_frac[3] > zero_frac[0], zero_frac     # heavier weight, sparser
    assert np.max(np.abs(plan[0])) > 0.0              # unpenalized group moves mass


def test_regularizer_config_roundtrip_and_validation():
    L = 5
    regs = [
        GroupSparseReg(gamma=0.7, mu=0.4),
        L2Reg(gamma=1.3),
        ElasticNetGroupReg(gamma=0.9, mu_weights=(0.0, 0.1, 0.2, 0.3, 0.4)),
    ]
    for reg in regs:
        back = from_config(reg.config())
        assert back == reg and type(back) is type(reg)
        tau = reg.tau_vec(L)
        np.testing.assert_allclose(
            tau, np.asarray(reg.mu_vec(L)) * reg.gamma, rtol=1e-6
        )
        assert np.all(tau >= 0)
    # per-group weights must match the group count
    with pytest.raises(ValueError):
        ElasticNetGroupReg(gamma=1.0, mu_weights=(0.1, 0.2)).mu_vec(3)
    with pytest.raises(ValueError):
        ElasticNetGroupReg(gamma=1.0, mu_weights=(-0.1, 0.2))
    with pytest.raises(ValueError):
        from_config({"kind": "nope", "gamma": 1.0})
    # uniform thresholds still expose the scalar paper parameterization
    assert GroupSparseReg(gamma=2.0, mu=0.5).tau == 1.0
    assert L2Reg(gamma=2.0).tau == 0.0
