"""Property-based tests (hypothesis) for the system's invariants.

The safety of the paper's screening is exactly the kind of invariant
hypothesis shines on: for ANY snapshot point and ANY current point, the
Eq. 6 value must upper-bound the true group norm and the Eq. 7 value must
lower-bound it — otherwise Lemma 2/5 break and the solver silently returns
wrong gradients.  With the pluggable regularizer subsystem the invariants
are quantified over the regularizer too: ANY member of the thresholded
soft-scale family (group-sparse / pure-l2 / per-group elastic-net weights)
must keep (i) "skip verdict => gradient block exactly zero" and (ii) the
closed-form conjugate gradient consistent with autodiff of psi.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import screening as S
from repro.core.dual import DualProblem, plan_from_duals, snapshot_norms
from repro.core.regularizers import (
    ElasticNetGroupReg,
    GroupSparseReg,
    L2Reg,
    grad_psi,
    psi_from_z,
    scale_from_z,
)
from repro.sharding.partition import fit_spec
from jax.sharding import PartitionSpec as P

_f32 = st.floats(-10.0, 10.0, allow_nan=False, width=32)


def _regularizers(L: int):
    """Strategy over all regularizer kinds, sized for L groups."""
    gamma = st.floats(0.05, 5.0)
    mu = st.floats(0.0, 5.0)
    return st.one_of(
        st.builds(GroupSparseReg, gamma=gamma, mu=mu),
        st.builds(L2Reg, gamma=gamma),
        st.builds(
            lambda g_, ws: ElasticNetGroupReg(gamma=g_, mu_weights=tuple(ws)),
            gamma,
            st.lists(mu, min_size=L, max_size=L),
        ),
    )


def _arrays(rng_seed, L, g, n, scale):
    rng = np.random.default_rng(rng_seed)
    C = (rng.random((L * g, n)) * scale).astype(np.float32)
    a0 = (rng.normal(size=L * g) * scale * 0.3).astype(np.float32)
    b0 = (rng.normal(size=n) * scale * 0.3).astype(np.float32)
    da = (rng.normal(size=L * g) * scale * 0.1).astype(np.float32)
    db = (rng.normal(size=n) * scale * 0.1).astype(np.float32)
    return C, a0, b0, da, db


@settings(max_examples=60, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    L=st.integers(1, 6),
    g=st.integers(1, 9),
    n=st.integers(1, 17),
    scale=st.floats(0.01, 100.0),
)
def test_bounds_always_valid(seed, L, g, n, scale):
    """Lemma 1 & 4 for arbitrary snapshot/current pairs."""
    C, a0, b0, da, db = _arrays(seed, L, g, n, scale)
    prob = DualProblem(L, g, n, GroupSparseReg(1.0, 1.0))
    row_mask = jnp.ones((L * g,), bool)
    sqrt_g = jnp.full((L,), np.sqrt(g), jnp.float32)

    alpha0, beta0 = jnp.asarray(a0), jnp.asarray(b0)
    z, k, o = snapshot_norms(alpha0, beta0, jnp.asarray(C), prob, row_mask)
    state = S.take_snapshot(
        S.init_state(L * g, n, L), alpha0, beta0, z, k, o
    )
    alpha1, beta1 = alpha0 + jnp.asarray(da), beta0 + jnp.asarray(db)
    zbar = S.upper_bound(state, alpha1, beta1, sqrt_g)
    zlow = S.lower_bound(state, alpha1, beta1, sqrt_g)
    z_true, _, _ = snapshot_norms(alpha1, beta1, jnp.asarray(C), prob, row_mask)
    tol = 1e-4 * max(scale, 1.0)
    assert bool(jnp.all(zbar >= z_true - tol)), "upper bound violated"
    assert bool(jnp.all(zlow <= z_true + tol)), "lower bound violated"


@settings(max_examples=60, deadline=None)
@given(
    z=st.lists(st.floats(0.0, 50.0, width=32), min_size=1, max_size=32),
    gamma=st.floats(0.01, 10.0),
    mu=st.floats(0.01, 10.0),
)
def test_soft_threshold_properties(z, gamma, mu):
    """scale in [0,1); psi >= 0 is NOT required, but psi(0)=0 and
    monotonicity of the scale in z must hold."""
    reg = GroupSparseReg(gamma=gamma, mu=mu)
    Z = jnp.asarray(sorted(z), jnp.float32)
    s = scale_from_z(Z, reg)
    assert bool(jnp.all(s >= 0)) and bool(jnp.all(s < 1.0))
    assert bool(jnp.all(jnp.diff(s) >= -1e-6))  # monotone in z
    assert float(scale_from_z(jnp.zeros((1,)), reg)[0]) == 0.0
    assert float(psi_from_z(jnp.zeros((1,)), reg)[0]) == 0.0


@settings(max_examples=30, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    L=st.integers(1, 5),
    g=st.integers(1, 8),
    n=st.integers(1, 16),
    scale=st.floats(0.01, 10.0),
    data=st.data(),
)
def test_skip_verdict_implies_zero_gradient_block(seed, L, g, n, scale, data):
    """Screening invariant, quantified over the regularizer family: a ZERO
    verdict must certify an exactly-zero gradient block.

    At the snapshot point the bound equals the true group norm bitwise, so
    the implication is asserted *exactly*; at a displaced point fp32
    rounding of the Eq. 6 terms admits an O(eps * scale) slack (the same
    slack the bounds-validity test tolerates)."""
    C, a0, b0, da, db = _arrays(seed, L, g, n, scale)
    reg = data.draw(_regularizers(L))
    prob = DualProblem(L, g, n, reg)
    row_mask = jnp.ones((L * g,), bool)
    sqrt_g = jnp.full((L,), np.sqrt(g), jnp.float32)
    tau = prob.tau_vec()

    alpha0, beta0 = jnp.asarray(a0), jnp.asarray(b0)
    z, k, o = snapshot_norms(alpha0, beta0, jnp.asarray(C), prob, row_mask)
    state = S.take_snapshot(
        S.init_state(L * g, n, L), alpha0, beta0, z, k, o
    )

    # (a) at the snapshot point: exact implication
    verd0 = S.verdicts(state, alpha0, beta0, sqrt_g, tau)
    T0 = plan_from_duals(alpha0, beta0, jnp.asarray(C), prob)
    blk0 = jnp.max(jnp.abs(T0.reshape(L, g, n)), axis=1)        # (L, n)
    assert bool(jnp.all(jnp.where(verd0 == S.ZERO, blk0, 0.0) == 0.0))

    # (b) displaced point: implication up to fp32 bound rounding
    alpha1, beta1 = alpha0 + jnp.asarray(da), beta0 + jnp.asarray(db)
    verd = S.verdicts(state, alpha1, beta1, sqrt_g, tau)
    T1 = plan_from_duals(alpha1, beta1, jnp.asarray(C), prob)
    blk = jnp.max(jnp.abs(T1.reshape(L, g, n)), axis=1)
    tol = 1e-4 * max(scale, 1.0) / reg.gamma
    assert bool(jnp.all(jnp.where(verd == S.ZERO, blk, 0.0) <= tol))


@settings(max_examples=30, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    L=st.integers(1, 5),
    g=st.integers(1, 8),
    scale=st.floats(0.01, 10.0),
    data=st.data(),
)
def test_conjugate_consistency_with_autodiff(seed, L, g, scale, data):
    """The closed-form conjugate gradient equals autodiff of psi for every
    regularizer kind (Danskin), on screened and unscreened blocks alike."""
    reg = data.draw(_regularizers(L))
    rng = np.random.default_rng(seed)
    f = jnp.asarray((rng.normal(size=L * g) * scale).astype(np.float32))

    def psi_of_f(ff):
        fg = ff.reshape(L, -1)
        # tiny clamp keeps sqrt' finite when a whole group is nonpositive
        Z = jnp.sqrt(jnp.sum(jnp.maximum(fg, 0.0) ** 2, axis=-1) + 1e-30)
        return jnp.sum(reg.psi_from_z(Z))

    gad = jax.grad(psi_of_f)(f)
    gcf = grad_psi(f, L, reg)
    assert bool(jnp.all(jnp.isfinite(gad)))
    tol = 2e-4 * max(scale, 1.0) / reg.gamma
    np.testing.assert_allclose(np.asarray(gad), np.asarray(gcf), atol=tol)
    # Fenchel identity at the maximizer: psi(f) = f.g* - Psi(g*)
    fen = float(jnp.dot(f, gcf) - reg.primal(gcf[:, None], L))
    np.testing.assert_allclose(
        float(psi_of_f(f)), fen,
        rtol=1e-4, atol=1e-4 * (1.0 + max(scale, 1.0) ** 2 * g / reg.gamma),
    )
    # psi itself vanishes below the threshold and at the origin
    assert float(psi_of_f(jnp.zeros_like(f))) == pytest.approx(0.0, abs=1e-12)


@settings(max_examples=80, deadline=None)
@given(
    dims=st.lists(st.integers(1, 64), min_size=1, max_size=4),
    data=st.integers(1, 16),
    model=st.integers(1, 16),
)
def test_fit_spec_always_divides(dims, data, model):
    """fit_spec output must always evenly tile the shape."""
    sizes = {"data": data, "model": model}
    spec = P(*(["data", "model", ("data", "model"), None][: len(dims)]))
    fitted = fit_spec(tuple(dims), spec, sizes)
    for dim, entry in zip(dims, tuple(fitted)):
        if entry is None:
            continue
        axes = (entry,) if isinstance(entry, str) else entry
        f = 1
        for a in axes:
            f *= sizes[a]
        assert dim % f == 0


@settings(max_examples=40, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), n=st.integers(1, 200))
def test_int8_error_feedback_bounded(seed, n):
    """EF residual stays bounded by one quantization step (contraction)."""
    from repro.training.compression import compress_decompress

    rng = np.random.default_rng(seed)
    g = jnp.asarray(rng.normal(size=(n,)).astype(np.float32))
    err = jnp.zeros_like(g)
    for _ in range(5):
        g_hat, err = compress_decompress(g, err)
        scale = float(jnp.max(jnp.abs(g + 0 * err))) / 127.0 + 1e-12
        assert float(jnp.max(jnp.abs(err))) <= 4.0 * scale + 1e-6
