"""Training substrate: optimizer, trainer loop, checkpoint restart,
compression, watchdog, OT-align loss integration."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.configs.base import OptimizerConfig, TrainConfig
from repro.data.pipeline import SyntheticLM, SyntheticLMConfig
from repro.training.compression import apply_error_feedback
from repro.training.elastic import StragglerWatchdog
from repro.training.losses import group_features_by_class, ot_alignment_loss
from repro.training.optim import adamw_update, init_opt_state, lr_schedule
from repro.training.trainer import Trainer


def test_adamw_converges_on_quadratic():
    cfg = OptimizerConfig(lr=0.05, warmup_steps=0, decay_steps=1000,
                          weight_decay=0.0, grad_clip=0.0)
    params = {"w": jnp.asarray([3.0, -2.0, 1.0])}
    state = init_opt_state(params, cfg)
    for _ in range(300):
        grads = {"w": 2 * params["w"]}
        params, state, _ = adamw_update(params, grads, state, cfg)
    assert float(jnp.max(jnp.abs(params["w"]))) < 1e-2


def test_adamw_master_weights_bf16():
    cfg = OptimizerConfig(lr=0.01, warmup_steps=0, master_weights=True,
                          weight_decay=0.0, grad_clip=0.0)
    params = {"w": jnp.ones((4,), jnp.bfloat16)}
    state = init_opt_state(params, cfg)
    assert "master" in state
    for _ in range(5):
        params, state, _ = adamw_update(
            params, {"w": jnp.ones((4,), jnp.bfloat16)}, state, cfg
        )
    assert params["w"].dtype == jnp.bfloat16
    assert state["master"]["w"].dtype == jnp.float32
    # master accumulates sub-bf16-precision updates
    assert float(jnp.max(jnp.abs(state["master"]["w"].astype(jnp.float32)
                                 - params["w"].astype(jnp.float32)))) < 0.01


def test_lr_schedule_shape():
    cfg = OptimizerConfig(lr=1e-3, warmup_steps=10, decay_steps=100)
    lrs = [float(lr_schedule(cfg, jnp.asarray(s))) for s in range(0, 120, 5)]
    assert lrs[0] < lrs[1]                      # warmup
    assert max(lrs) <= 1e-3 + 1e-9
    assert lrs[-1] >= cfg.lr * cfg.min_lr_ratio - 1e-9


def _tiny_trainer(tmp_path, steps=6, **tkw):
    cfg = get_config("smollm-135m").reduced(num_layers=2, d_model=64, d_ff=128,
                                            vocab_size=128)
    tcfg = TrainConfig(
        optimizer=OptimizerConfig(lr=1e-3, warmup_steps=2),
        steps=steps, log_every=2, checkpoint_every=3, **tkw,
    )
    data = SyntheticLM(SyntheticLMConfig(vocab_size=128, seq_len=32, global_batch=4))
    return Trainer(cfg, tcfg, data, ckpt_dir=str(tmp_path / "ckpt"))


def test_trainer_loss_decreases(tmp_path):
    tr = _tiny_trainer(tmp_path, steps=30)
    tr.run()
    hist = tr.metrics_history
    assert hist[-1]["ce"] < hist[0]["ce"]


def test_trainer_restart_resumes_identically(tmp_path):
    tr1 = _tiny_trainer(tmp_path, steps=6)
    tr1.run()
    w1 = np.asarray(tr1.state["params"]["embed"])
    # new trainer on the same dir restores the final checkpoint
    tr2 = _tiny_trainer(tmp_path, steps=6)
    assert tr2.start_step == 6
    w2 = np.asarray(tr2.state["params"]["embed"])
    np.testing.assert_allclose(w1, w2)
    # crash-restart mid-run: train 12 total in one go vs 6+6 resumed
    tr3 = _tiny_trainer(tmp_path, steps=12)
    tr3.run()
    tmp2 = tmp_path / "fresh"
    tr4 = _tiny_trainer(tmp2, steps=12)
    tr4.run()
    np.testing.assert_allclose(
        np.asarray(tr3.state["params"]["embed"]),
        np.asarray(tr4.state["params"]["embed"]),
        atol=1e-5,
    )


def test_compression_error_feedback_converges():
    """SGD + int8 EF still drives a quadratic to its optimum."""
    w = jnp.asarray([2.0, -3.0, 1.5])
    err = {"w": jnp.zeros(3)}
    params = {"w": w}
    for _ in range(400):
        g = {"w": 2 * params["w"]}
        g, err = apply_error_feedback(g, err)
        params = {"w": params["w"] - 0.02 * g["w"]}
    assert float(jnp.max(jnp.abs(params["w"]))) < 5e-2


def test_watchdog_flags_stragglers():
    wd = StragglerWatchdog(window=20, ratio_threshold=2.0, min_samples=5)
    for step in range(20):
        wd.observe(step, 0.1)
    ev = wd.observe(20, 0.5)
    assert ev is not None and ev.ratio == pytest.approx(5.0)
    assert wd.observe(21, 0.11) is None


def test_ot_alignment_loss_grad_flows():
    rng = np.random.default_rng(0)
    L, g, d = 4, 6, 8
    h_src = jnp.asarray(rng.normal(size=(L * g, d)).astype(np.float32))
    h_tgt = jnp.asarray(rng.normal(size=(L * g, d)).astype(np.float32) + 2.0)

    def loss(src):
        v, _ = ot_alignment_loss(src, h_tgt, num_classes=L, group_size=g,
                                 gamma=5.0, rho=0.5, max_iters=40)
        return v

    v = loss(h_src)
    gr = jax.grad(loss)(h_src)
    assert np.isfinite(float(v)) and float(v) > 0
    assert float(jnp.max(jnp.abs(gr))) > 0
    # moving sources toward targets reduces the OT distance
    v2 = loss(h_src + 0.5 * (jnp.mean(h_tgt, 0) - jnp.mean(h_src, 0)))
    assert float(v2) < float(v)


def test_group_features_by_class_layout():
    rng = np.random.default_rng(1)
    h = jnp.asarray(rng.normal(size=(10, 4)).astype(np.float32))
    labels = jnp.asarray([0, 1, 0, 2, 1, 0, 2, 1, 0, 2])
    out = group_features_by_class(h, labels, num_classes=3, group_size=4)
    assert out.shape == (12, 4)
    assert bool(jnp.all(jnp.isfinite(out)))


def test_trainer_with_ot_align(tmp_path):
    tr = _tiny_trainer(tmp_path, steps=4, ot_align=True, ot_align_weight=0.05)
    tr.run()
    assert "ot_distance" in tr.metrics_history[-1]


def test_trainer_with_compression(tmp_path):
    tr = _tiny_trainer(tmp_path, steps=4, grad_compression="int8_ef")
    tr.run()
    assert np.isfinite(tr.metrics_history[-1]["loss"])
