"""Pure-JAX L-BFGS vs scipy on standard problems."""
import numpy as np
import jax
import jax.numpy as jnp
from scipy import optimize

from repro.core.lbfgs import LbfgsOptions, run


def test_quadratic_exact():
    rng = np.random.default_rng(0)
    A = rng.normal(size=(12, 12))
    A = A @ A.T + 0.5 * np.eye(12)
    b = rng.normal(size=12)
    Aj, bj = jnp.asarray(A, jnp.float32), jnp.asarray(b, jnp.float32)

    def vag(x):
        g = Aj @ x - bj
        return 0.5 * x @ Aj @ x - bj @ x, g

    st = run(vag, jnp.zeros(12, jnp.float32), LbfgsOptions(max_iters=200, gtol=1e-6))
    x_star = np.linalg.solve(A, b)
    assert bool(st.converged)
    np.testing.assert_allclose(np.asarray(st.x), x_star, atol=1e-3)


def test_rosenbrock_matches_scipy():
    def f_np(x):
        return float(
            100 * (x[1] - x[0] ** 2) ** 2 + (1 - x[0]) ** 2
            + 100 * (x[3] - x[2] ** 2) ** 2 + (1 - x[2]) ** 2
        )

    def vag(x):
        v = (
            100 * (x[1] - x[0] ** 2) ** 2 + (1 - x[0]) ** 2
            + 100 * (x[3] - x[2] ** 2) ** 2 + (1 - x[2]) ** 2
        )
        return v, jax.grad(
            lambda y: 100 * (y[1] - y[0] ** 2) ** 2 + (1 - y[0]) ** 2
            + 100 * (y[3] - y[2] ** 2) ** 2 + (1 - y[2]) ** 2
        )(x)

    x0 = jnp.asarray([-1.2, 1.0, -1.2, 1.0], jnp.float32)
    st = run(vag, x0, LbfgsOptions(max_iters=500, gtol=1e-5))
    res = optimize.minimize(
        lambda x: f_np(x), np.asarray(x0), method="L-BFGS-B"
    )
    assert float(st.f) <= res.fun + 1e-4
    np.testing.assert_allclose(np.asarray(st.x), np.ones(4), atol=1e-2)


def test_history_cycling_stable():
    """More iterations than history size exercises the circular buffer."""

    def vag(x):
        return jnp.sum(jnp.cosh(x * 0.5)), jnp.sinh(x * 0.5) * 0.5

    x0 = jnp.linspace(-3, 3, 40).astype(jnp.float32)
    st = run(vag, x0, LbfgsOptions(history=4, max_iters=300, gtol=1e-6))
    assert bool(st.converged)
    assert float(jnp.max(jnp.abs(st.x))) < 1e-3


def test_segment_runs_match_single_run():
    """run_segment x k must follow the same trajectory as one run."""
    from repro.core.lbfgs import init_state, run_segment

    rng = np.random.default_rng(1)
    A = rng.normal(size=(8, 8))
    A = A @ A.T + np.eye(8)
    Aj = jnp.asarray(A, jnp.float32)

    def vag(x):
        return 0.5 * x @ Aj @ x, Aj @ x

    opts = LbfgsOptions(max_iters=1000, gtol=0.0, ftol=0.0)
    x0 = jnp.ones(8, jnp.float32)
    s1 = init_state(x0, vag, opts)
    for _ in range(4):
        s1 = run_segment(vag, s1, 5, opts)
    s2 = init_state(x0, vag, opts)
    s2 = run_segment(vag, s2, 20, opts)
    np.testing.assert_allclose(np.asarray(s1.x), np.asarray(s2.x), atol=1e-6)
