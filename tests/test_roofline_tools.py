"""Roofline tooling: analytic param model vs real trees, HLO parsing."""
import sys
from pathlib import Path

import jax
import pytest

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

from benchmarks.roofline import active_params, scan_trips
from repro.configs import get_config
from repro.launch.dryrun import _shape_bytes, parse_collectives
from repro.models import build_model
from repro.models.common import count_params


@pytest.mark.parametrize("arch", ["yi-9b", "yi-6b", "smollm-135m",
                                  "minicpm3-4b", "whisper-medium",
                                  "llama-3.2-vision-90b", "xlstm-1.3b"])
def test_active_params_matches_total_for_non_moe(arch):
    cfg = get_config(arch)
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0), abstract=True)
    total = count_params(params)
    act = active_params(cfg)
    assert abs(act - total) / total < 0.05, (act, total)


@pytest.mark.parametrize("arch", ["qwen2-moe-a2.7b", "phi3.5-moe-42b-a6.6b",
                                  "jamba-1.5-large-398b"])
def test_active_params_below_total_for_moe(arch):
    cfg = get_config(arch)
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0), abstract=True)
    total = count_params(params)
    act = active_params(cfg)
    assert act < 0.75 * total
    assert act > 0.02 * total


def test_scan_trips():
    assert scan_trips(get_config("yi-9b")) == 48
    assert scan_trips(get_config("jamba-1.5-large-398b")) == 9     # 72/8
    assert scan_trips(get_config("xlstm-1.3b")) == 6               # 48/8
    assert scan_trips(get_config("llama-3.2-vision-90b")) == 20    # 100/5


def test_shape_bytes():
    assert _shape_bytes("f32[128,256]") == 128 * 256 * 4
    assert _shape_bytes("bf16[8]{0}") == 16
    assert _shape_bytes("(f32[4], bf16[2,2])") == 16 + 8
    assert _shape_bytes("pred[10]") == 10


def test_parse_collectives():
    hlo = """
  %ag = bf16[4096,1024]{1,0} all-gather(%p0), replica_groups=...
  %ar.1 = f32[512]{0} all-reduce(%x), to_apply=%sum
  %ars = (f32[256]{0}, f32[256]{0}) all-reduce-start(%y)
  %ard = f32[256]{0} all-reduce-done(%ars)
  %cp = bf16[64]{0} collective-permute(%z), source_target_pairs=...
  %fusion.1 = f32[10] fusion(%w), calls=%comp
"""
    out = parse_collectives(hlo)
    assert out["all-gather"]["count"] == 1
    assert out["all-gather"]["result_bytes"] == 4096 * 1024 * 2
    assert out["all-reduce"]["count"] == 2          # sync + start (done skipped)
    assert out["all-reduce"]["result_bytes"] == 512 * 4 + (256 * 4 * 2) // 2
    assert out["collective-permute"]["count"] == 1
    # wire model: AR counts 2x
    assert out["all-reduce"]["wire_bytes"] == 2.0 * out["all-reduce"]["result_bytes"]
