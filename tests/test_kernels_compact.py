"""Compacted-grid gradient path: parity, scheduling, and the step-count
scaling contract (grid steps proportional to surviving tiles).

All kernels run in interpret mode; oracles are the pure-jnp refs plus the
dense closed form in core/dual.py.
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import groups as G
from repro.core import screening as S
from repro.core.dual import DualProblem, dual_value_and_grad, snapshot_norms
from repro.core.lbfgs import LbfgsOptions
from repro.core.ot import squared_euclidean_cost
from repro.core.regularizers import GroupSparseReg
from repro.core.solver import SolveOptions, solve_dual
from repro.kernels import ops as kops
from repro.kernels.gradpsi import (
    build_tile_schedule,
    gradpsi_pallas,
    gradpsi_pallas_compact,
    resolve_tile_l,
)
from repro.kernels.ref import build_tile_schedule_ref, gradpsi_ref


def _rand_problem(rng, L, g, n):
    alpha = jnp.asarray(rng.normal(size=L * g).astype(np.float32))
    beta = jnp.asarray(rng.normal(size=n).astype(np.float32))
    C = jnp.asarray((rng.normal(size=(L * g, n)) ** 2).astype(np.float32))
    return alpha, beta, C


def _flags(rng, grid, pattern):
    Lt, Nt = grid
    if pattern == "all_zero":
        f = np.zeros(grid, np.int32)
    elif pattern == "all_active":
        f = np.ones(grid, np.int32)
    elif pattern == "single":
        f = np.zeros(grid, np.int32)
        f[rng.integers(0, Lt), rng.integers(0, Nt)] = 1
    elif pattern == "random":
        f = (rng.random(grid) < 0.4).astype(np.int32)
    else:
        raise ValueError(pattern)
    return jnp.asarray(f)


PATTERNS = ["all_zero", "all_active", "single", "random"]


@pytest.mark.parametrize("L,g,n,tl,tn", [
    (16, 8, 256, 8, 128),
    (8, 16, 384, 4, 128),
    (32, 8, 128, 8, 128),
])
@pytest.mark.parametrize("pattern", PATTERNS)
def test_compact_matches_dense_grid_and_oracle(L, g, n, tl, tn, pattern):
    rng = np.random.default_rng(hash((L, g, n, pattern)) % 2**32)
    alpha, beta, C = _rand_problem(rng, L, g, n)
    grid = (L // tl, n // tn)
    flags = _flags(rng, grid, pattern)
    kw = dict(num_groups=L, group_size=g, tau=0.3, gamma=0.5,
              tile_l=tl, tile_n=tn)
    want = gradpsi_ref(alpha, beta, C, flags, **kw)
    dense = gradpsi_pallas(alpha, beta, C, flags, interpret=True, **kw)
    sched, nact = build_tile_schedule(flags)
    rs, cs, psi, steps = gradpsi_pallas_compact(
        alpha, beta, C, sched, nact, interpret=True, **kw
    )
    for w, d, c in zip(want, dense, (rs, cs, psi)):
        np.testing.assert_allclose(np.asarray(c), np.asarray(w),
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(np.asarray(c), np.asarray(d),
                                   rtol=1e-5, atol=1e-5)
    # scaling contract: the kernel issued one grid step per surviving tile
    # (one sentinel step when none survive), not one per (l, j) tile.
    assert int(steps) == max(int(nact), 1)


def test_step_count_scales_with_surviving_tiles():
    rng = np.random.default_rng(11)
    L, g, n, tl, tn = 16, 8, 512, 8, 128
    alpha, beta, C = _rand_problem(rng, L, g, n)
    grid = (L // tl, n // tn)
    total = grid[0] * grid[1]
    kw = dict(num_groups=L, group_size=g, tau=0.3, gamma=0.5,
              tile_l=tl, tile_n=tn)
    for k in [0, 1, 3, total]:
        f = np.zeros(total, np.int32)
        f[rng.choice(total, size=k, replace=False)] = 1
        flags = jnp.asarray(f.reshape(grid))
        sched, nact = build_tile_schedule(flags)
        *_, steps = gradpsi_pallas_compact(
            alpha, beta, C, sched, nact, interpret=True, **kw
        )
        assert int(steps) == max(k, 1), (k, int(steps))


@pytest.mark.parametrize("pattern", PATTERNS)
def test_build_tile_schedule_matches_ref(pattern):
    rng = np.random.default_rng(hash(pattern) % 2**32)
    flags = _flags(rng, (6, 7), pattern)
    sched, nact = build_tile_schedule(flags)
    sched_ref, nact_ref = build_tile_schedule_ref(flags)
    assert int(nact) == nact_ref
    np.testing.assert_array_equal(np.asarray(sched), np.asarray(sched_ref))


@pytest.mark.parametrize("L,g,n", [
    (16, 8, 200),      # ragged n
    (10, 8, 200),      # ragged L and n
    (3, 8, 50),        # tiny, heavy padding both axes
])
@pytest.mark.parametrize("impl", ["grid", "compact", "auto"])
def test_ops_impls_match_closed_form_ragged(L, g, n, impl):
    """Padded wrapper parity on non-tile-multiple shapes, all impls."""
    rng = np.random.default_rng(hash((L, g, n, impl)) % 2**32)
    m = L * g
    labels = np.repeat(np.arange(L), g)
    Xs = rng.normal(size=(m, 2)) + labels[:, None]
    Xt = rng.normal(size=(n, 2)) + rng.integers(0, L, n)[:, None]
    C = squared_euclidean_cost(Xs, Xt).astype(np.float32)
    C /= C.max()
    spec = G.spec_from_labels(labels, pad_to=8)
    C_pad = jnp.asarray(G.pad_cost_matrix(C, labels, spec))
    a = jnp.asarray(G.pad_marginal(np.full(m, 1 / m, np.float32), labels, spec))
    b = jnp.asarray(np.full(n, 1 / n, np.float32))
    reg = GroupSparseReg.from_rho(1.0, 0.6)
    prob = DualProblem(spec.num_groups, spec.group_size, n, reg)
    alpha = jnp.asarray(rng.normal(size=spec.m_pad).astype(np.float32) * 0.3)
    beta = jnp.asarray(rng.normal(size=n).astype(np.float32) * 0.3)

    verdict = jnp.full((spec.num_groups, n), S.CHECK, jnp.int32)
    v0, (ga0, gb0) = dual_value_and_grad(alpha, beta, C_pad, a, b, prob)
    v1, ga1, gb1 = kops.dual_value_and_grad(
        alpha, beta, C_pad, a, b, verdict, prob, impl=impl
    )
    np.testing.assert_allclose(float(v1), float(v0), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(ga1), np.asarray(ga0),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(gb1), np.asarray(gb0),
                               rtol=1e-5, atol=1e-5)


def test_padded_problem_and_fused_screening_path():
    """The solver-facing prepared path (prepare_padded_problem +
    pad_screen_state + screen_tile_flags + dual_value_and_grad_padded)
    reproduces the dense closed form at a real screened iterate."""
    rng = np.random.default_rng(21)
    L, g, n = 16, 8, 200
    m = L * g
    labels = np.repeat(np.arange(L), g)
    Xs = rng.normal(size=(m, 2)) + labels[:, None]
    Xt = rng.normal(size=(n, 2)) + rng.integers(0, L, n)[:, None]
    C = squared_euclidean_cost(Xs, Xt).astype(np.float32)
    C /= C.max()
    spec = G.spec_from_labels(labels, pad_to=8)
    C_pad = jnp.asarray(G.pad_cost_matrix(C, labels, spec))
    a = jnp.asarray(G.pad_marginal(np.full(m, 1 / m, np.float32), labels, spec))
    b = jnp.asarray(np.full(n, 1 / n, np.float32))
    reg = GroupSparseReg.from_rho(1.0, 0.6)
    prob = DualProblem(spec.num_groups, spec.group_size, n, reg)
    row_mask = jnp.asarray(spec.row_mask().reshape(-1))
    sqrt_g = jnp.asarray(spec.sqrt_sizes())

    alpha = jnp.asarray(rng.normal(size=spec.m_pad).astype(np.float32) * 0.3)
    beta = jnp.asarray(rng.normal(size=n).astype(np.float32) * 0.3)
    z, k, o = snapshot_norms(alpha, beta, C_pad, prob, row_mask)
    st = S.take_snapshot(S.init_state(spec.m_pad, n, L), alpha, beta, z, k, o)
    a2, b2 = alpha + 0.01, beta - 0.02

    pp = kops.prepare_padded_problem(C_pad, prob)
    pstate = kops.pad_screen_state(st, sqrt_g, pp)
    flags = kops.screen_tile_flags(pstate, a2, b2, pp, reg.tau)
    # fused flags agree with the XLA verdict reduction
    verd = S.verdicts(st, a2, b2, sqrt_g, reg.tau)
    np.testing.assert_array_equal(
        np.asarray(flags), np.asarray(S.tile_flags(verd, pp.tile_l, pp.tile_n))
    )
    assert int(jnp.sum(verd == S.ZERO)) > 0  # screening actually fires

    v0, (ga0, gb0) = dual_value_and_grad(a2, b2, C_pad, a, b, prob)
    for impl in ("grid", "compact", "auto"):
        v1, ga1, gb1 = kops.dual_value_and_grad_padded(
            a2, b2, a, b, flags, pp, prob, impl=impl
        )
        np.testing.assert_allclose(float(v1), float(v0), rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(ga1), np.asarray(ga0), atol=1e-4)
        np.testing.assert_allclose(np.asarray(gb1), np.asarray(gb0), atol=1e-4)


@pytest.mark.parametrize("pallas_impl", ["grid", "compact", "auto"])
def test_solver_pallas_impls_match_dense_solution(pallas_impl):
    rng = np.random.default_rng(4)
    L, g, n = 4, 8, 32
    m = L * g
    labels = np.repeat(np.arange(L), g)
    Xs = rng.normal(size=(m, 2)) + labels[:, None] * 2.0
    Xt = rng.normal(size=(n, 2)) + rng.integers(0, L, n)[:, None] * 2.0
    C = squared_euclidean_cost(Xs, Xt).astype(np.float32)
    C /= C.max()
    spec = G.spec_from_labels(labels, pad_to=8)
    C_pad = jnp.asarray(G.pad_cost_matrix(C, labels, spec))
    a = jnp.asarray(G.pad_marginal(np.full(m, 1 / m, np.float32), labels, spec))
    b = jnp.asarray(np.full(n, 1 / n, np.float32))
    reg = GroupSparseReg.from_rho(1.0, 0.6)
    rd = solve_dual(C_pad, a, b, spec, reg,
                    SolveOptions(grad_impl="dense",
                                 lbfgs=LbfgsOptions(max_iters=250)))
    rp = solve_dual(C_pad, a, b, spec, reg,
                    SolveOptions(grad_impl="pallas", pallas_impl=pallas_impl,
                                 lbfgs=LbfgsOptions(max_iters=250)))
    np.testing.assert_allclose(rd.value, rp.value, rtol=2e-5, atol=2e-5)


def test_resolve_tile_l_divides():
    for L in (1, 3, 8, 10, 12, 20, 64):
        for g in (8, 64, 512):
            t = resolve_tile_l(L, g, 128)
            assert t >= 1 and L % t == 0 or t == 1
            assert L % t == 0 or t == 1
