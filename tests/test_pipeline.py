"""Pipeline parallelism: GPipe schedule == sequential stage application."""
import os
import subprocess
import sys
import textwrap
from pathlib import Path

SRC = str(Path(__file__).resolve().parents[1] / "src")


def _run(code: str, devices: int = 4, timeout: int = 600):
    env = dict(
        os.environ,
        XLA_FLAGS=f"--xla_force_host_platform_device_count={devices}",
        PYTHONPATH=SRC,
        JAX_PLATFORMS="cpu",
    )
    return subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        env=env, capture_output=True, text=True, timeout=timeout,
    )


def test_gpipe_matches_sequential():
    r = _run("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.training.pipeline import gpipe_forward, bubble_fraction

        P, M, mb, d = 4, 6, 3, 16
        rng = np.random.default_rng(0)
        Ws = jnp.asarray(rng.normal(size=(P, d, d)).astype(np.float32) * 0.3)
        bs = jnp.asarray(rng.normal(size=(P, d)).astype(np.float32) * 0.1)
        params = {"w": Ws, "b": bs}
        x = jnp.asarray(rng.normal(size=(M, mb, d)).astype(np.float32))

        def stage_fn(p, h):
            return jnp.tanh(h @ p["w"] + p["b"])

        # sequential reference
        ref = x
        for s in range(P):
            ref = jax.vmap(lambda h: stage_fn({"w": Ws[s], "b": bs[s]}, h))(ref)

        from repro.utils.compat import make_mesh
        mesh = make_mesh((P,), ("pod",))
        # stage axis leading [P]: shard_map splits one stage per pod
        sp = {"w": Ws, "b": bs}
        out = gpipe_forward(stage_fn, sp, x, mesh, axis="pod")
        err = float(jnp.max(jnp.abs(out - ref)))
        assert err < 1e-5, err
        assert abs(bubble_fraction(4, 6) - 3/9) < 1e-9
        print("MATCH", err)
    """)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "MATCH" in r.stdout
