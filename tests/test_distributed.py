"""Multi-device tests via subprocess (host-platform device override must be
set before jax initializes, so these don't run in the main pytest process).
"""
import os
import subprocess
import sys
import textwrap
from pathlib import Path


SRC = str(Path(__file__).resolve().parents[1] / "src")


def _run(code: str, devices: int = 8, timeout: int = 600):
    env = dict(
        os.environ,
        XLA_FLAGS=f"--xla_force_host_platform_device_count={devices}",
        PYTHONPATH=SRC,
        JAX_PLATFORMS="cpu",
    )
    return subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        env=env, capture_output=True, text=True, timeout=timeout,
    )


def test_distributed_ot_matches_single_device():
    r = _run("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.core import groups as G
        from repro.core.regularizers import GroupSparseReg
        from repro.core.ot import squared_euclidean_cost
        from repro.core.solver import SolveOptions, solve_dual
        from repro.core.distributed import solve_dual_distributed
        from repro.core.lbfgs import LbfgsOptions

        rng = np.random.default_rng(2)
        L, g, n = 6, 10, 64
        m = L*g
        labels = np.repeat(np.arange(L), g)
        Xs = rng.normal(size=(m,2)) + labels[:,None]*3.0
        Xt = rng.normal(size=(n,2)) + rng.integers(0,L,n)[:,None]*3.0
        C = squared_euclidean_cost(Xs,Xt).astype(np.float32); C/=C.max()
        spec = G.spec_from_labels(labels, pad_to=8)
        C_pad = G.pad_cost_matrix(C, labels, spec)
        a = G.pad_marginal(np.full(m,1/m,np.float32), labels, spec)
        b = np.full(n,1/n,np.float32)
        reg = GroupSparseReg.from_rho(1.0, 0.6)
        opts = SolveOptions(lbfgs=LbfgsOptions(max_iters=300))
        res1 = solve_dual(jnp.asarray(C_pad), jnp.asarray(a), jnp.asarray(b), spec, reg, opts)
        from repro.utils.compat import make_mesh
        mesh = make_mesh((2,4), ("data","model"))
        res2 = solve_dual_distributed(C_pad, a, b, spec, reg, mesh, opts)
        assert abs(res1.value-res2.value) < 1e-5, (res1.value, res2.value)
        print("MATCH", res1.value, res2.value)
    """)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "MATCH" in r.stdout


def test_sharded_train_step_matches_single_device():
    r = _run("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.configs import get_config
        from repro.configs.base import OptimizerConfig, TrainConfig
        from repro.launch.steps import make_train_step
        from repro.models import build_model
        from repro.training.optim import init_opt_state
        from repro.sharding.partition import default_rules, sharding_tree, use_rules
        from repro.training.optim import opt_state_logical_axes

        cfg = get_config("smollm-135m").reduced(num_layers=2, d_model=64,
                                                d_ff=128, vocab_size=128,
                                                num_heads=4, num_kv_heads=2)
        tcfg = TrainConfig(optimizer=OptimizerConfig(lr=1e-3, warmup_steps=0))
        model = build_model(cfg)
        params, axes = model.init(jax.random.PRNGKey(0))
        opt = init_opt_state(params, tcfg.optimizer)
        state = {"params": params, "opt": opt}
        rng = np.random.default_rng(0)
        batch = {"tokens": jnp.asarray(rng.integers(0,128,(8,33)), jnp.int32)}
        step = make_train_step(cfg, tcfg)

        s1, m1 = jax.jit(step)(state, batch)

        from repro.utils.compat import make_mesh
        mesh = make_mesh((4,2), ("data","model"))
        rules = default_rules(mesh.axis_names)
        st_axes = {"params": axes, "opt": opt_state_logical_axes(axes, tcfg.optimizer, "master" in opt)}
        sh = sharding_tree(st_axes, rules, mesh, shapes=state)
        state_sh = jax.device_put(state, sh)
        with use_rules(rules, mesh), mesh:
            s2, m2 = jax.jit(step)(state_sh, batch)
        d = jax.tree_util.tree_map(
            lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32)-b.astype(jnp.float32)))),
            s1["params"], jax.device_get(s2["params"]))
        mx = max(jax.tree_util.tree_leaves(d))
        assert mx < 5e-3, mx
        print("MATCH maxdiff=", mx, "loss=", float(m1["loss"]), float(m2["loss"]))
    """)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "MATCH" in r.stdout


def test_elastic_remesh_preserves_values():
    r = _run("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.sharding.partition import default_rules
        from repro.training.elastic import remesh_state

        state = {"w": jnp.arange(64.0).reshape(8, 8)}
        axes = {"w": ("embed", "mlp")}
        from repro.utils.compat import make_mesh
        mesh1 = make_mesh((2,2), ("data","model"))
        mesh2 = make_mesh((4,2), ("data","model"))
        r1 = default_rules(mesh1.axis_names)
        s1 = remesh_state(state, mesh1, r1, axes)
        s2 = remesh_state(s1, mesh2, default_rules(mesh2.axis_names), axes)
        np.testing.assert_array_equal(np.asarray(s2["w"]), np.asarray(state["w"]))
        assert len(s2["w"].sharding.device_set) == 8
        print("MATCH")
    """)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "MATCH" in r.stdout


def test_dual_step_collectives_are_small():
    """The distributed OT gradient's cross-device traffic must be O(m+n),
    not O(mn) — the design claim in core/distributed.py."""
    r = _run("""
        import jax, jax.numpy as jnp
        from repro.core.distributed import lower_dual_step
        from repro.core.dual import DualProblem
        from repro.core.regularizers import GroupSparseReg

        from repro.utils.compat import make_mesh
        mesh = make_mesh((2,4), ("data","model"))
        prob = DualProblem(16, 8, 256, GroupSparseReg(1.0, 1.0))
        lowered = lower_dual_step(mesh, prob)
        compiled = lowered.compile()
        txt = compiled.as_text()
        import re
        big = 0
        for line in txt.splitlines():
            m = re.search(r"= (f32|bf16)\\[([\\d,]+)\\][^ ]* (all-reduce|all-gather)", line)
            if m:
                import numpy as np
                n = np.prod([int(x) for x in m.group(2).split(",")])
                big = max(big, int(n))
        # largest collective operand should be O(m_pad + n), far below m*n
        assert big <= 4 * (16*8 + 256), big
        print("MATCH biggest_collective_elems=", big)
    """)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "MATCH" in r.stdout
