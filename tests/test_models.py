"""Per-arch smoke tests: reduced configs, one forward/train step on CPU,
shape and finiteness assertions, prefill/decode consistency (assignment f).
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, list_archs
from repro.models import build_model

B, S = 2, 16


def _batch(r, rng):
    batch = {
        "tokens": jnp.asarray(rng.integers(0, r.vocab_size, (B, S + 1)), jnp.int32)
    }
    if r.family == "vlm":
        batch["memory"] = jnp.asarray(
            rng.normal(size=(B, r.num_image_tokens, r.d_model)).astype(np.float32)
        )
    if r.family == "encdec":
        batch["frames"] = jnp.asarray(
            rng.normal(size=(B, r.num_audio_frames, r.d_model)).astype(np.float32)
        )
    return batch


@pytest.mark.parametrize("arch", list_archs())
def test_smoke_train_step(arch):
    r = get_config(arch).reduced()
    model = build_model(r)
    params, axes = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    batch = _batch(r, rng)
    loss, metrics = model.train_loss(params, batch)
    assert np.isfinite(float(loss))
    assert float(loss) > 0
    grads = jax.grad(lambda p: model.train_loss(p, batch)[0])(params)
    leaves = jax.tree_util.tree_leaves(grads)
    assert all(bool(jnp.all(jnp.isfinite(x))) for x in leaves)
    # most param tensors receive nonzero gradient (vlm's zero-init cross
    # gates intentionally block their branch at init — llama-3.2 design)
    nz = sum(float(jnp.max(jnp.abs(x))) > 0 for x in leaves)
    assert nz / len(leaves) > (0.5 if r.family == "vlm" else 0.9)


@pytest.mark.parametrize("arch", list_archs())
def test_smoke_prefill_decode_consistency(arch):
    r = get_config(arch).reduced()
    model = build_model(r)
    params, _ = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(1)
    batch = _batch(r, rng)
    tokens = batch["tokens"]
    caches = model.init_cache(B, S + 4)

    if r.family == "encdec":
        memory = model.encode(params, batch["frames"])
    else:
        memory = batch.get("memory")

    logits_pf, caches = model.prefill(params, tokens[:, :S], caches, memory=memory)
    assert logits_pf.shape == (B, 1, r.vocab_size)

    if r.family != "encdec":
        logits_full, _ = model.forward(params, tokens[:, :S], memory=memory)
        np.testing.assert_allclose(
            np.asarray(logits_pf[:, 0]), np.asarray(logits_full[:, -1]),
            atol=2e-3, rtol=2e-3,
        )

    lg, caches = model.decode_step(
        params, tokens[:, S : S + 1], caches, jnp.asarray(S, jnp.int32),
        memory=None,
    )
    assert lg.shape == (B, 1, r.vocab_size)
    assert bool(jnp.all(jnp.isfinite(lg)))
    if r.family != "encdec":
        logits_full2, _ = model.forward(params, tokens[:, : S + 1], memory=memory)
        np.testing.assert_allclose(
            np.asarray(lg[:, 0]), np.asarray(logits_full2[:, -1]),
            atol=2e-3, rtol=2e-3,
        )


@pytest.mark.parametrize("arch", list_archs())
def test_full_config_abstract_param_count(arch):
    """Full configs are exercised abstractly (no allocation) + sane sizes."""
    expected_b = {
        "qwen2-moe-a2.7b": (13, 15),
        "phi3.5-moe-42b-a6.6b": (40, 44),
        "xlstm-1.3b": (1.0, 2.5),
        "whisper-medium": (0.7, 0.9),
        "yi-9b": (8.3, 9.3),
        "yi-6b": (5.6, 6.5),
        "smollm-135m": (0.12, 0.15),
        "minicpm3-4b": (3.8, 4.6),
        "jamba-1.5-large-398b": (380, 410),
        "llama-3.2-vision-90b": (80, 95),
    }[arch]
    cfg = get_config(arch)
    model = build_model(cfg)
    params, axes = model.init(jax.random.PRNGKey(0), abstract=True)
    from repro.models.common import count_params

    n = count_params(params) / 1e9
    assert expected_b[0] <= n <= expected_b[1], f"{arch}: {n:.3f}B"
    # every param leaf has matching logical axes
    flat_p = jax.tree_util.tree_leaves(params)
    flat_a = jax.tree_util.tree_leaves(
        axes, is_leaf=lambda x: isinstance(x, tuple) and not isinstance(x[0] if x else None, dict)
    )
    assert len(flat_p) == len(flat_a)


def test_moe_aux_losses_present():
    r = get_config("qwen2-moe-a2.7b").reduced()
    model = build_model(r)
    params, _ = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(2)
    loss, metrics = model.train_loss(params, _batch(r, rng))
    assert "moe_lb" in metrics
    assert float(metrics["moe_lb"]) > 0


def test_vector_index_decode_matches_scalar():
    """Continuous-batching path: per-slot index vector == scalar index."""
    r = get_config("yi-6b").reduced()
    model = build_model(r)
    params, _ = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(3)
    tokens = jnp.asarray(rng.integers(0, r.vocab_size, (B, S + 1)), jnp.int32)
    c1 = model.init_cache(B, S + 4)
    _, c1 = model.prefill(params, tokens[:, :S], c1)
    l_scalar, _ = model.decode_step(params, tokens[:, S:S+1], c1, jnp.asarray(S, jnp.int32))
    l_vec, _ = model.decode_step(
        params, tokens[:, S:S+1], c1, jnp.full((B,), S, jnp.int32)
    )
    np.testing.assert_allclose(
        np.asarray(l_scalar), np.asarray(l_vec), atol=1e-5
    )


def test_kv_int8_cache_decode_close_to_fp():
    """int8 KV cache: half the cache bytes, logits close to full precision."""
    import dataclasses

    r = get_config("yi-6b").reduced()
    rq = dataclasses.replace(r, kv_quant=True)
    rng = np.random.default_rng(5)
    tokens = jnp.asarray(rng.integers(0, r.vocab_size, (B, S + 1)), jnp.int32)

    model = build_model(r)
    params, _ = model.init(jax.random.PRNGKey(0))
    model_q = build_model(rq)

    c = model.init_cache(B, S + 4)
    cq = model_q.init_cache(B, S + 4)
    assert cq["k"].dtype == jnp.int8

    bytes_fp = sum(x.size * x.dtype.itemsize for x in jax.tree_util.tree_leaves(c))
    bytes_q = sum(x.size * x.dtype.itemsize for x in jax.tree_util.tree_leaves(cq))
    assert bytes_q < 0.65 * bytes_fp

    _, c = model.prefill(params, tokens[:, :S], c)
    _, cq = model_q.prefill(params, tokens[:, :S], cq)
    l_fp, _ = model.decode_step(params, tokens[:, S:S+1], c, jnp.asarray(S, jnp.int32))
    l_q, _ = model_q.decode_step(params, tokens[:, S:S+1], cq, jnp.asarray(S, jnp.int32))
    # quantization noise is small relative to logit scale
    denom = float(jnp.std(l_fp))
    rel = float(jnp.max(jnp.abs(l_q - l_fp))) / max(denom, 1e-6)
    assert rel < 0.2, rel
