"""Batched multi-problem solving: solve_batch == B solo solves, bitwise.

The batched solver is the B = 1 code path of solve_dual with a leading
axis, so each problem's trajectory must match its solo solve exactly —
objective values bitwise, plans bitwise, round counts equal — for every
gradient backend.  A dispatch-count test asserts the batching actually
collapses host->device program launches.
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import groups as G
from repro.core.lbfgs import LbfgsOptions
from repro.core.ot import squared_euclidean_cost
from repro.core.regularizers import GroupSparseReg
from repro.core.solver import (
    SolveOptions,
    dispatch_count,
    recover_plan,
    recover_plan_batch,
    reset_dispatch_count,
    solve_batch,
    solve_dual,
)

# this module tests the deprecated solve_batch shim ON PURPOSE (the façade
# parity suite lives in test_facade.py); silence just its deprecation
pytestmark = pytest.mark.filterwarnings(
    "ignore:solve_batch:DeprecationWarning"
)

B = 8


def _batch_problem(rng, L=5, g=8, n=40, B=B, pad_to=4):
    m = L * g
    labels = np.repeat(np.arange(L), g)
    spec = G.spec_from_labels(labels, pad_to=pad_to)
    Cs, As, Bs = [], [], []
    for _ in range(B):
        Xs = rng.normal(size=(m, 2)) + labels[:, None] * 3.0
        Xt = rng.normal(size=(n, 2)) + rng.integers(0, L, n)[:, None] * 3.0
        C = squared_euclidean_cost(Xs, Xt).astype(np.float32)
        C /= C.max()
        Cs.append(G.pad_cost_matrix(C, labels, spec))
        As.append(G.pad_marginal(np.full(m, 1 / m, np.float32), labels, spec))
        Bs.append(np.full(n, 1 / n, np.float32))
    return (
        spec,
        jnp.asarray(np.stack(Cs)),
        jnp.asarray(np.stack(As)),
        jnp.asarray(np.stack(Bs)),
    )


@pytest.mark.parametrize("grad_impl", ["dense", "screened", "pallas"])
def test_solve_batch_bitwise_matches_solo(grad_impl):
    """B = 8 batched objectives == 8 solo objectives, bitwise, per backend."""
    rng = np.random.default_rng(3)
    spec, C, a, b, = _batch_problem(rng)
    reg = GroupSparseReg.from_rho(1.0, 0.6)
    opts = SolveOptions(
        grad_impl=grad_impl, lbfgs=LbfgsOptions(max_iters=150)
    )
    rb = solve_batch(C, a, b, spec, reg, opts)
    Tb = recover_plan_batch(rb, C, spec, reg)
    assert len(rb) == B
    for i in range(B):
        rs = solve_dual(C[i], a[i], b[i], spec, reg, opts)
        # bitwise: identical trajectory, identical objective
        assert float(rb.values[i]) == float(rs.value), (grad_impl, i)
        np.testing.assert_array_equal(
            np.asarray(rb.alpha[i]), np.asarray(rs.alpha)
        )
        np.testing.assert_array_equal(
            np.asarray(rb.beta[i]), np.asarray(rs.beta)
        )
        # identical round counts (per-problem masking freezes, not diverges)
        assert int(rb.rounds[i]) == rs.rounds, (grad_impl, i)
        # plans recovered from identical duals are identical
        Ts = recover_plan(rs, C[i], spec, reg)
        np.testing.assert_array_equal(np.asarray(Tb[i]), np.asarray(Ts))


def test_solve_batch_result_slicing():
    """BatchOTResult[i] materializes a coherent solo OTResult view."""
    rng = np.random.default_rng(4)
    spec, C, a, b = _batch_problem(rng, B=3)
    reg = GroupSparseReg.from_rho(1.0, 0.6)
    rb = solve_batch(C, a, b, spec, reg, SolveOptions())
    for i in range(3):
        ri = rb[i]
        assert float(ri.value) == float(rb.values[i])
        assert ri.rounds == int(rb.rounds[i])
        assert ri.converged
        assert sum(ri.stats.values()) == int(jnp.sum(rb.stats[i]))


def test_batch_heterogeneous_convergence_masks():
    """Problems converging at different rounds freeze without interfering:
    an easy problem (tiny cost spread) and hard ones finish with their own
    round counts, and every problem reports convergence."""
    rng = np.random.default_rng(5)
    spec, C, a, b = _batch_problem(rng, B=4)
    # make problem 0 much easier: near-uniform costs converge in ~1 round
    C = C.at[0].set(jnp.where(C[0] > 1e6, C[0], 0.5))
    reg = GroupSparseReg.from_rho(1.0, 0.6)
    rb = solve_batch(C, a, b, spec, reg, SolveOptions())
    assert bool(jnp.all(rb.converged))
    rounds = [int(r) for r in rb.rounds]
    solo = [
        solve_dual(C[i], a[i], b[i], spec, reg, SolveOptions()).rounds
        for i in range(4)
    ]
    assert rounds == solo
    assert len(set(rounds)) > 1  # genuinely heterogeneous convergence


def test_batch_dispatch_count_collapses():
    """One batched solve must launch <= 1/4 the programs of the solo loop."""
    rng = np.random.default_rng(6)
    spec, C, a, b = _batch_problem(rng)
    reg = GroupSparseReg.from_rho(1.0, 0.6)
    opts = SolveOptions()

    reset_dispatch_count()
    for i in range(B):
        solve_dual(C[i], a[i], b[i], spec, reg, opts)
    solo_dispatches = dispatch_count()

    reset_dispatch_count()
    solve_batch(C, a, b, spec, reg, opts)
    batch_dispatches = dispatch_count()

    assert solo_dispatches == B
    assert batch_dispatches == 1
    assert batch_dispatches <= solo_dispatches // 4


def test_batch_stats_match_solo():
    """Screening verdict accounting is per problem and matches solo."""
    rng = np.random.default_rng(8)
    spec, C, a, b = _batch_problem(rng, B=3)
    reg = GroupSparseReg.from_rho(1.0, 0.8)
    opts = SolveOptions(grad_impl="screened")
    rb = solve_batch(C, a, b, spec, reg, opts)
    for i in range(3):
        rs = solve_dual(C[i], a[i], b[i], spec, reg, opts)
        assert rb[i].stats == rs.stats
