import json
import os
import sys

# Tests run single-device (the dry-run alone overrides the device count).
# Multi-device tests spawn subprocesses with their own XLA_FLAGS.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import pytest  # noqa: E402  (after the env/path setup above)

FIXTURE_DIR = os.path.join(os.path.dirname(__file__), "fixtures")


def make_ot_problem(seed: int, L: int, g: int, n: int, pad_to: int = 8):
    """Deterministic padded OT problem shared by tests and golden fixtures.

    The geometry mirrors the paper's domain-adaptation setup: L classes of
    g source samples each, class-shifted Gaussians, normalized squared-
    Euclidean costs, uniform marginals.  Everything derives from
    ``np.random.default_rng(seed)``, so a committed (seed, L, g, n) tuple
    pins the problem exactly — the golden fixtures store only those
    numbers plus the expected outputs.

    Returns ``(C_pad, a, b, spec, labels)`` in the padded group layout.
    """
    import numpy as np

    from repro.core import groups as G
    from repro.core.ot import squared_euclidean_cost

    rng = np.random.default_rng(seed)
    m = L * g
    labels = np.repeat(np.arange(L), g)
    Xs = rng.normal(size=(m, 2)) + labels[:, None] * 3.0
    Xt = rng.normal(size=(n, 2)) + rng.integers(0, L, n)[:, None] * 3.0
    C = squared_euclidean_cost(Xs, Xt).astype(np.float32)
    C /= C.max()
    spec = G.spec_from_labels(labels, pad_to=pad_to)
    C_pad = G.pad_cost_matrix(C, labels, spec)
    a = G.pad_marginal(np.full((m,), 1.0 / m, np.float32), labels, spec)
    b = np.full((n,), 1.0 / n, np.float32)
    return C_pad, a, b, spec, labels


@pytest.fixture(scope="session")
def golden_regularizer_cases():
    """Known-answer cases from tests/fixtures/golden_regularizers.json.

    Each case carries the problem coordinates (seed, L, g, n, pad_to), the
    regularizer config (rebuilt via ``repro.core.regularizers.from_config``)
    and the expected outputs; see tests/test_regularizers.py for the gate.
    """
    path = os.path.join(FIXTURE_DIR, "golden_regularizers.json")
    with open(path) as f:
        data = json.load(f)
    assert data["schema_version"] == 1
    return data["cases"]
