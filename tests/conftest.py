import os
import sys

# Tests run single-device (the dry-run alone overrides the device count).
# Multi-device tests spawn subprocesses with their own XLA_FLAGS.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
