"""The ``repro.ot`` façade: bitwise parity with every legacy entry point.

The façade routes, it never re-implements — so its contract is exact:
for EVERY regularizer kind (group-sparse / l2 / elastic-net) and EVERY
``grad_impl`` backend (dense / screened / pallas),

  * ``Executor.solve``      ==  ``solver.solve_dual``          bitwise,
  * ``Executor.solve_many`` ==  ``solver.solve_batch``         bitwise,
  * ``Executor.solve_many`` (mesh)  ==  ``sharded.solve_batch_sharded``
    bitwise (default mesh: every local device — 4 forced host devices in
    the CI sharded job),
  * ``Executor.stream``     ==  ``Executor.solve_many``        bitwise,

objectives, duals, plans, round counts and verdict stats all compared
exactly.  Plus: Problem/ExecutionPlan config round-trips, validation
errors, per-executor stats isolation, and the serving engine's
Problem-payload admission.
"""
import json

import numpy as np
import jax.numpy as jnp
import pytest

# the differential layer calls the deprecated shims ON PURPOSE — they are
# the reference implementations this suite gates the façade against
pytestmark = [
    pytest.mark.filterwarnings("ignore:solve_batch:DeprecationWarning"),
    pytest.mark.filterwarnings("ignore:solve_groupsparse_ot:DeprecationWarning"),
]

from conftest import make_ot_problem

import repro.ot as ot
from repro.core import solver as slv
from repro.core.lbfgs import LbfgsOptions
from repro.core.ot import solve_groupsparse_ot
from repro.core.regularizers import (
    ElasticNetGroupReg,
    GroupSparseReg,
    L2Reg,
)
from repro.core.sharded import solve_batch_sharded
from repro.core.solver import SolveOptions, recover_plan, solve_dual

KINDS = ("group_sparse", "l2", "elastic_net")
IMPLS = ("dense", "screened", "pallas")
L, GSZ, N = 3, 4, 24          # tiny geometry: parity is shape-independent


def make_reg(kind, num_groups=L):
    if kind == "group_sparse":
        return GroupSparseReg.from_rho(1.0, 0.6)
    if kind == "l2":
        return L2Reg(gamma=0.4)
    return ElasticNetGroupReg(
        gamma=0.4, mu_weights=tuple(0.5 + 0.25 * i for i in range(num_groups))
    )


def make_opts(grad_impl):
    return SolveOptions(grad_impl=grad_impl, lbfgs=LbfgsOptions(max_iters=150))


def padded_batch(B, seed0=0):
    Cs, As, Bs = [], [], []
    spec = None
    for s in range(B):
        C, a, b, spec, _ = make_ot_problem(seed0 + s, L, GSZ, N, pad_to=4)
        Cs.append(C), As.append(a), Bs.append(b)
    return Cs, As, Bs, spec


def assert_result_bitwise(sol: ot.Solution, legacy, C, spec, reg):
    """One Solution vs one legacy OTResult: everything exact."""
    assert sol.value == float(legacy.value)
    assert np.array_equal(np.asarray(sol.alpha), np.asarray(legacy.alpha))
    assert np.array_equal(np.asarray(sol.beta), np.asarray(legacy.beta))
    assert sol.rounds == legacy.rounds
    assert sol.stats == legacy.stats
    T_legacy = np.asarray(recover_plan(legacy, jnp.asarray(C), spec, reg))
    assert np.array_equal(sol.plan_padded, T_legacy)


# ---------------------------------------------------------------- solve (solo)
@pytest.mark.parametrize("kind", KINDS)
@pytest.mark.parametrize("impl", IMPLS)
def test_solve_matches_solve_dual_bitwise(kind, impl):
    C, a, b, spec, _ = make_ot_problem(0, L, GSZ, N, pad_to=4)
    reg, opts = make_reg(kind), make_opts(impl)
    legacy = solve_dual(jnp.asarray(C), jnp.asarray(a), jnp.asarray(b),
                        spec, reg, opts)
    problem = ot.Problem.from_padded(C, a, b, spec, reg)
    sol = ot.compile(problem, ot.ExecutionPlan.from_solve_options(opts)).solve()
    assert_result_bitwise(sol, legacy, C, spec, reg)


# ------------------------------------------------------------------ solve_many
@pytest.mark.parametrize("kind", KINDS)
@pytest.mark.parametrize("impl", IMPLS)
def test_solve_many_matches_solve_batch_bitwise(kind, impl):
    B = 3
    Cs, As, Bs, spec = padded_batch(B)
    reg, opts = make_reg(kind), make_opts(impl)
    rb = slv.solve_batch(
        jnp.asarray(np.stack(Cs)), jnp.asarray(np.stack(As)),
        jnp.asarray(np.stack(Bs)), spec, reg, opts,
    )
    problems = [ot.Problem.from_padded(Cs[i], As[i], Bs[i], spec, reg)
                for i in range(B)]
    ex = ot.compile(problems[0], ot.ExecutionPlan.from_solve_options(opts))
    sols = ex.solve_many(problems)
    assert np.array_equal(
        np.asarray(rb.lbfgs_state.x),
        np.stack([np.asarray(s.result.lbfgs_state.x) for s in sols]),
    )
    for i in range(B):
        assert_result_bitwise(sols[i], rb[i], Cs[i], spec, reg)
    # ONE fused launch for the whole batch
    assert ex.stats()["launches"] == 1
    assert ex.stats()["problems_solved"] == B


# --------------------------------------------------------------------- sharded
@pytest.mark.parametrize("kind", KINDS)
@pytest.mark.parametrize("impl", IMPLS)
def test_solve_many_sharded_matches_legacy_bitwise(kind, impl):
    B = 4
    Cs, As, Bs, spec = padded_batch(B)
    reg, opts = make_reg(kind), make_opts(impl)
    rs = solve_batch_sharded(
        jnp.asarray(np.stack(Cs)), jnp.asarray(np.stack(As)),
        jnp.asarray(np.stack(Bs)), spec, reg, opts,
    )
    problems = [ot.Problem.from_padded(Cs[i], As[i], Bs[i], spec, reg)
                for i in range(B)]
    ex = ot.compile(
        problems[0],
        ot.ExecutionPlan.from_solve_options(opts, devices="all"),
    )
    sols = ex.solve_many(problems)
    assert ex.mesh is not None
    assert ex.stats()["launches"] == 1
    for i in range(B):
        assert_result_bitwise(sols[i], rs[i], Cs[i], spec, reg)


# ---------------------------------------------------------------------- stream
@pytest.mark.parametrize("kind", KINDS)
def test_stream_matches_solve_many_bitwise(kind):
    B = 3
    Cs, As, Bs, spec = padded_batch(B)
    reg = make_reg(kind)
    problems = [ot.Problem.from_padded(Cs[i], As[i], Bs[i], spec, reg)
                for i in range(B)]
    ex = ot.compile(problems[0])
    sols = ex.solve_many(problems)

    stream = ot.compile(problems[0]).stream(problems)
    seen_alive = []
    for info in stream:
        seen_alive.append(info["alive"])
        assert info["converged"].shape == (B,)
    sols_st = stream.solutions()
    for i in range(B):
        assert sols_st[i].value == sols[i].value
        assert np.array_equal(
            np.asarray(sols_st[i].result.lbfgs_state.x),
            np.asarray(sols[i].result.lbfgs_state.x),
        )
        assert sols_st[i].rounds == sols[i].rounds
    # progress is monotone: problems only ever finish
    assert seen_alive == sorted(seen_alive, reverse=True)
    assert "rounds=" in stream.describe()


def test_stream_of_nothing_is_empty_not_an_error():
    C, a, b, spec, _ = make_ot_problem(0, L, GSZ, N, pad_to=4)
    ex = ot.compile(ot.Problem.from_padded(C, a, b, spec, make_reg("l2")))
    stream = ex.stream([])
    assert list(stream) == []
    assert stream.solutions() == []
    assert ex.stats()["launches"] == 0
    assert "grad_impl=" in stream.describe()


def test_stream_iteration_alone_records_stats():
    C, a, b, spec, _ = make_ot_problem(0, L, GSZ, N, pad_to=4)
    problem = ot.Problem.from_padded(C, a, b, spec, make_reg("group_sparse"))
    ex = ot.compile(problem)
    for _ in ex.stream([problem]):          # drained, solutions() never called
        pass
    stats = ex.stats()
    assert stats["solves"] == 1
    assert stats["problems_solved"] == 1
    assert stats["rounds_total"] > 0


def test_stream_respects_max_rounds_cap():
    C, a, b, spec, _ = make_ot_problem(0, L, GSZ, N, pad_to=4)
    reg = make_reg("group_sparse")
    problem = ot.Problem.from_padded(C, a, b, spec, reg)
    ex = ot.compile(problem, ot.ExecutionPlan(max_rounds=2))
    stream = ex.stream(problem)
    assert len(list(stream)) <= 2


# ---------------------------------------------------------- samples-mode shim
def test_from_samples_matches_legacy_solve_groupsparse_ot():
    rng = np.random.default_rng(0)
    m, n = 24, 20
    labels = np.repeat(np.arange(L), m // L)
    Xs = rng.normal(size=(m, 2)) + labels[:, None] * 3.0
    Xt = rng.normal(size=(n, 2)) + rng.integers(0, L, n)[:, None] * 3.0
    legacy = solve_groupsparse_ot(Xs, labels, Xt, gamma=1.0, rho=0.6)
    sol = ot.solve(ot.Problem.from_samples(
        Xs, labels, Xt, reg=GroupSparseReg.from_rho(1.0, 0.6)
    ))
    assert sol.value == legacy.value
    assert sol.distance == legacy.distance
    assert np.array_equal(sol.plan, legacy.plan)
    assert np.array_equal(sol.perm, legacy.perm)


# ---------------------------------------------------------- column auto-padding
def test_executor_auto_pads_narrower_columns():
    C, a, b, spec, _ = make_ot_problem(0, L, GSZ, N, pad_to=4)
    reg = make_reg("group_sparse")
    template = ot.Problem.from_padded(C, a, b, spec, reg)
    Cn, an, bn, spec_n, _ = make_ot_problem(0, L, GSZ, N - 8, pad_to=4)
    assert spec_n == spec                 # same row layout, narrower columns
    narrow = ot.Problem.from_padded(Cn, an, bn, spec, reg)
    ex = ot.compile(template)
    sol = ex.solve(narrow)
    # un-padded back to the problem's own width, marginals preserved
    assert sol.plan.shape == (spec.m, N - 8)
    np.testing.assert_allclose(
        sol.plan.sum(axis=0), np.asarray(bn), atol=5e-4
    )
    # the same problem solved at its own width agrees to solver tolerance
    solo = ot.solve(narrow)
    np.testing.assert_allclose(sol.value, solo.value, rtol=1e-5, atol=1e-6)


# ------------------------------------------------------------- config round-trip
def test_problem_config_roundtrip_all_modes():
    rng = np.random.default_rng(1)
    reg = make_reg("elastic_net")
    labels = np.repeat(np.arange(L), 5)
    Xs = rng.normal(size=(15, 2))
    Xt = rng.normal(size=(10, 2))
    C, a, b, spec, _ = make_ot_problem(0, L, GSZ, N, pad_to=4)
    cases = [
        ot.Problem.from_samples(Xs, labels, Xt, reg=reg, pad_to=4),
        ot.Problem(reg=reg, C=rng.random((15, 10), dtype=np.float32),
                   labels=labels),
        ot.Problem.from_padded(C, a, b, spec, reg),
    ]
    for p in cases:
        cfg = json.loads(json.dumps(p.config()))      # must be JSON-able
        assert ot.Problem.from_config(cfg) == p
        assert ot.Problem.from_config(cfg).mode == p.mode


def test_problem_config_roundtrip_preserves_sample_dtype():
    # a float32-samples problem must rebuild with a bitwise-identical cost
    # derivation (the squared-Euclidean expansion is dtype-sensitive)
    rng = np.random.default_rng(7)
    labels = np.repeat(np.arange(L), 5)
    p32 = ot.Problem.from_samples(
        rng.normal(size=(15, 2)).astype(np.float32), labels,
        rng.normal(size=(10, 2)).astype(np.float32),
        reg=make_reg("group_sparse"),
    )
    p2 = ot.Problem.from_config(json.loads(json.dumps(p32.config())))
    assert p2.X_S.dtype == np.float32
    assert np.array_equal(p2.cost(), p32.cost())


def test_problem_is_hashable_consistent_with_eq():
    rng = np.random.default_rng(9)
    labels = np.repeat(np.arange(L), 5)
    C = rng.random((15, 10), dtype=np.float32)
    reg = make_reg("group_sparse")
    p1 = ot.Problem(reg=reg, C=C, labels=labels)
    p2 = ot.Problem(reg=reg, C=C.copy(), labels=labels.copy())
    assert p1 == p2 and hash(p1) == hash(p2)
    assert len({p1, p2}) == 1                  # usable as a set/dict key
    p3 = ot.Problem(reg=reg, C=C + 1.0, labels=labels)
    assert p1 != p3
    # __eq__ is value-based across dtypes (np.array_equal); hash must agree
    p64 = ot.Problem(reg=reg, C=C.astype(np.float64), labels=labels)
    assert p1 == p64 and hash(p1) == hash(p64)


def test_problem_padded_respects_requested_dtype():
    rng = np.random.default_rng(10)
    labels = np.repeat(np.arange(L), 5)
    C64 = rng.random((15, 10)).astype(np.float64)
    p = ot.Problem(reg=make_reg("group_sparse"), C=C64, labels=labels)
    pa = p.padded(dtype=np.float64)
    assert pa.C.dtype == np.float64 and pa.a.dtype == np.float64
    # no float32 truncation on the real rows
    real = pa.perm >= 0
    assert np.array_equal(np.sort(pa.C[real], axis=0), np.sort(C64, axis=0))
    assert p.padded().C.dtype == np.float32     # solver default unchanged


def test_transport_sources_handles_nonuniform_marginals():
    rng = np.random.default_rng(8)
    m, n = 15, 10
    labels = np.repeat(np.arange(L), m // L)
    Xs = rng.normal(size=(m, 2)) + labels[:, None] * 3.0
    Xt = rng.normal(size=(n, 2))
    b = np.linspace(1.0, 2.0, n).astype(np.float32)
    b /= b.sum()
    sol = ot.solve(ot.Problem.from_samples(Xs, labels, Xt,
                                           reg=make_reg("l2"), b=b))
    mapped = sol.transport_sources(Xs)
    mass = sol.plan.sum(axis=0)
    expect = (sol.plan.T @ Xs) / mass[:, None]
    np.testing.assert_allclose(mapped, expect, rtol=1e-5)


def test_execution_plan_config_roundtrip():
    plan = ot.ExecutionPlan(grad_impl="pallas", pallas_impl="compact",
                            max_iters=77, devices="all", batching="batched")
    cfg = json.loads(json.dumps(plan.config()))
    assert ot.ExecutionPlan.from_config(cfg) == plan


def test_execution_plan_solve_options_bijection():
    opts = SolveOptions(
        grad_impl="pallas", pallas_impl="grid", snapshot_every=7,
        max_rounds=33, tight_active_refresh=True,
        lbfgs=LbfgsOptions(history=4, max_iters=99, gtol=1e-5),
    )
    assert ot.ExecutionPlan.from_solve_options(opts).solve_options() == opts


# ------------------------------------------------------------------- validation
def test_problem_validation_errors():
    rng = np.random.default_rng(2)
    reg = make_reg("group_sparse")
    labels = np.repeat(np.arange(L), 5)
    Xs, Xt = rng.normal(size=(15, 2)), rng.normal(size=(10, 2))
    C = rng.random((15, 10), dtype=np.float32)
    with pytest.raises(ValueError, match="not both"):
        ot.Problem(reg=reg, X_S=Xs, X_T=Xt, C=C, labels=labels)
    with pytest.raises(ValueError, match="samples .*or a cost"):
        ot.Problem(reg=reg, labels=labels)
    with pytest.raises(ValueError, match="both X_S and X_T"):
        ot.Problem(reg=reg, X_S=Xs, labels=labels)
    with pytest.raises(ValueError, match="labels"):
        ot.Problem(reg=reg, C=C, labels=labels[:-1])
    with pytest.raises(ValueError, match="negative"):
        ot.Problem(reg=reg, C=C, labels=labels, a=-np.ones(15, np.float32))
    with pytest.raises(ValueError, match="group weights"):
        ot.Problem(reg=ElasticNetGroupReg(gamma=0.4, mu_weights=(0.5,)),
                   C=C, labels=labels)
    Cp, a, b, spec, _ = make_ot_problem(0, L, GSZ, N, pad_to=4)
    with pytest.raises(ValueError, match="marginals"):
        ot.Problem(reg=reg, C=Cp, spec=spec)
    with pytest.raises(ValueError, match="rows"):
        ot.Problem.from_padded(Cp[:-1], a, b, spec, reg)


def test_execution_plan_validation_errors():
    with pytest.raises(ValueError, match="grad_impl"):
        ot.ExecutionPlan(grad_impl="magic")
    with pytest.raises(ValueError, match="pallas_impl"):
        ot.ExecutionPlan(pallas_impl="nope")
    with pytest.raises(ValueError, match="batching"):
        ot.ExecutionPlan(batching="sometimes")
    with pytest.raises(ValueError, match="devices"):
        ot.ExecutionPlan(devices="some")
    with pytest.raises(ValueError, match="devices"):
        ot.ExecutionPlan(devices=0)
    with pytest.raises(ValueError, match="snapshot_every"):
        ot.ExecutionPlan(snapshot_every=0)
    with pytest.raises(ValueError, match="unknown"):
        ot.ExecutionPlan.from_config({"grad_impl": "dense", "warp": 9})


def test_executor_rejects_incompatible_problems():
    C, a, b, spec, _ = make_ot_problem(0, L, GSZ, N, pad_to=4)
    reg = make_reg("group_sparse")
    ex = ot.compile(ot.Problem.from_padded(C, a, b, spec, reg))
    C2, a2, b2, spec2, _ = make_ot_problem(0, L + 1, GSZ, N, pad_to=4)
    with pytest.raises(ValueError, match="layout"):
        ex.solve(ot.Problem.from_padded(C2, a2, b2, spec2, reg))
    with pytest.raises(ValueError, match="regularizer"):
        ex.solve(ot.Problem.from_padded(C, a, b, spec, make_reg("l2")))
    Cw, aw, bw, specw, _ = make_ot_problem(0, L, GSZ, 2 * N, pad_to=4)
    with pytest.raises(ValueError, match="columns"):
        ex.solve(ot.Problem.from_padded(Cw, aw, bw, specw, reg))


# ------------------------------------------------------------------ stats / iso
def test_executor_stats_are_isolated_per_instance():
    C, a, b, spec, _ = make_ot_problem(0, L, GSZ, N, pad_to=4)
    reg = make_reg("group_sparse")
    problem = ot.Problem.from_padded(C, a, b, spec, reg)
    ex1, ex2 = ot.compile(problem), ot.compile(problem)
    slv.reset_dispatch_count()
    ex1.solve()
    assert ex1.stats()["launches"] == 1
    assert ex1.stats()["solves"] == 1
    assert ex2.stats() == {
        "launches": 0, "solves": 0, "problems_solved": 0, "rounds_total": 0,
        "retry_attempts": 0,
        "status": {"DONE": 0, "FAILED": 0, "SHED": 0, "DEADLINE_EXCEEDED": 0},
    }
    # the legacy module-level counter keeps aggregating process-wide
    assert slv.dispatch_count() == 1
    # stats() returns a snapshot, not a live reference
    snap = ex1.stats()
    snap["launches"] = 99
    assert ex1.stats()["launches"] == 1


def test_executor_describe_mentions_backend_and_geometry():
    C, a, b, spec, _ = make_ot_problem(0, L, GSZ, N, pad_to=4)
    problem = ot.Problem.from_padded(C, a, b, spec, make_reg("group_sparse"))
    ex = ot.compile(problem, ot.ExecutionPlan(grad_impl="pallas"))
    text = ex.describe()
    assert "grad_impl=pallas" in text
    assert f"L={L}" in text
    sol = ot.compile(problem).solve()
    assert "verdicts:" in ot.compile(problem).describe(sol)


# --------------------------------------------------------------- serving engine
def test_engine_admits_problem_payloads():
    from repro.serving.ot_engine import OTRequest, OTServingEngine

    rng = np.random.default_rng(3)
    reg = make_reg("group_sparse")
    opts = make_opts("screened")
    m, n = 12, 20
    labels = np.repeat(np.arange(L), m // L)
    C = rng.random((m, n)).astype(np.float32)

    raw = OTRequest(rid=0, C=C, labels=labels)
    eng1 = OTServingEngine(reg, opts, max_batch=2)
    done_raw = eng1.run([raw])

    problem = ot.Problem(reg=reg, C=C, labels=labels)
    eng2 = OTServingEngine(reg, opts, max_batch=2)
    handle = eng2.submit(problem)
    assert handle is not None and not handle.done
    done_p = eng2.run([])
    assert done_p[0] is handle and handle.done

    assert done_raw[0].value == handle.value
    assert np.array_equal(done_raw[0].plan, handle.plan)

    # run() accepts bare Problems too
    eng3 = OTServingEngine(reg, opts, max_batch=2)
    done_b = eng3.run([problem])
    assert done_b[0].value == handle.value


def test_engine_request_reuse_across_engines_resolves_fresh_defaults():
    """A raw request lifted under one engine's defaults must re-lift when
    reused with an engine whose default regularizer differs (the lift
    cache keys on the resolved (reg, pad_to))."""
    from repro.serving.ot_engine import OTRequest, OTServingEngine

    rng = np.random.default_rng(11)
    m, n = 12, 20
    labels = np.repeat(np.arange(L), m // L)
    C = rng.random((m, n)).astype(np.float32)
    opts = make_opts("screened")

    req = OTRequest(rid=0, C=C, labels=labels)
    eng_gs = OTServingEngine(make_reg("group_sparse"), opts, max_batch=2)
    eng_gs.run([req])
    value_gs = req.value

    req2 = OTRequest(rid=0, C=C, labels=labels)
    eng_l2 = OTServingEngine(make_reg("l2"), opts, max_batch=2)
    eng_l2.run([req2])

    # same raw request object through both engines: second engine's default
    # regularizer must apply, not the first's cached lift
    req3 = OTRequest(rid=1, C=C, labels=labels)
    eng_gs2 = OTServingEngine(make_reg("group_sparse"), opts, max_batch=2)
    eng_gs2.run([req3])               # lift cached under group_sparse
    eng_l2b = OTServingEngine(make_reg("l2"), opts, max_batch=2)
    req3.value, req3.done = None, False
    eng_l2b.run([req3])
    assert req3.value == req2.value   # solved under l2, not the cached gs
    assert req3.value != value_gs
