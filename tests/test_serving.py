"""Serving engine: slot admission/recycling, batched == sequential decode."""
import numpy as np
import jax
import pytest

from repro.configs import get_config
from repro.models import build_model
from repro.serving.engine import Request, ServingEngine


@pytest.fixture(scope="module")
def small_model():
    cfg = get_config("smollm-135m").reduced(num_layers=2, d_model=64, d_ff=128,
                                            vocab_size=256, num_heads=4,
                                            num_kv_heads=2)
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def test_engine_serves_more_requests_than_slots(small_model):
    cfg, model, params = small_model
    engine = ServingEngine(cfg, params, max_batch=2, max_len=64)
    rng = np.random.default_rng(0)
    reqs = [
        Request(rid=i, prompt=rng.integers(0, 256, 10).astype(np.int32),
                max_new_tokens=5)
        for i in range(5)
    ]
    done = engine.run(reqs)
    assert len(done) == 5
    assert all(len(r.out_tokens) == 5 for r in done)


def test_batched_decode_matches_sequential(small_model):
    """Tokens from the batched engine == tokens from a lone request."""
    cfg, model, params = small_model
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, 256, 8).astype(np.int32) for _ in range(3)]

    def solo(prompt):
        e = ServingEngine(cfg, params, max_batch=1, max_len=64)
        [r] = e.run([Request(rid=0, prompt=prompt, max_new_tokens=6)])
        return r.out_tokens

    solo_out = [solo(p) for p in prompts]

    e = ServingEngine(cfg, params, max_batch=3, max_len=64)
    reqs = [Request(rid=i, prompt=p, max_new_tokens=6) for i, p in enumerate(prompts)]
    batched = {r.rid: r.out_tokens for r in e.run(reqs)}
    for i in range(3):
        assert batched[i] == solo_out[i], (i, batched[i], solo_out[i])


def test_slot_recycling_isolated(small_model):
    """A recycled slot must not leak KV state from its previous occupant."""
    cfg, model, params = small_model
    rng = np.random.default_rng(2)
    p1 = rng.integers(0, 256, 12).astype(np.int32)
    p2 = rng.integers(0, 256, 12).astype(np.int32)

    e = ServingEngine(cfg, params, max_batch=1, max_len=64)
    [r1] = e.run([Request(rid=0, prompt=p1, max_new_tokens=4)])
    [r2] = e.run([Request(rid=1, prompt=p2, max_new_tokens=4)])

    e2 = ServingEngine(cfg, params, max_batch=1, max_len=64)
    [r2_fresh] = e2.run([Request(rid=1, prompt=p2, max_new_tokens=4)])
    assert r2.out_tokens == r2_fresh.out_tokens
