"""grad_impl='fused': the single-launch screen+gradient mega-kernel.

Contracts under test (DESIGN.md §10, docs/geometry.md numerics policy):

  * oracle level: ``dual_value_and_grad_fused`` is bitwise-identical
    across its 'grid' / 'compact' / 'auto' modes AND to the two-launch
    screen->gradient oracle, for dense/factorized × solo/batched,
  * solve level: a fused solve is bitwise-identical to the two-launch
    pallas solve and matches the screened/dense references at the
    documented cross-backend tolerance,
  * sharded: fused over 4 forced host devices == unsharded, bitwise
    (subprocess, same pattern as test_sharded.py),
  * launches: the steady-state oracle drops from 2 Pallas launches per
    L-BFGS evaluation to 1 (trace-time dispatch registry),
  * precision='bf16': within documented tolerance of the f64 cpu_baseline
    and the committed golden fixture; rejected off the kernel backends,
  * ``tile_working_set_bytes``: bytes-per-TILE_L formula pinned term by
    term so VMEM accounting cannot silently drift from the kernels.
"""
import json
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from conftest import FIXTURE_DIR, make_ot_problem
from repro.core.lbfgs import LbfgsOptions
from repro.core.regularizers import GroupSparseReg
from repro.core.solver import SolveOptions, solve_batch, solve_dual
from repro.kernels import ops as kops

SRC = str(Path(__file__).resolve().parents[1] / "src")

# solve_batch is the deprecated shim, but it is the direct (facade-free)
# window onto the batched fused oracle this module pins down
pytestmark = pytest.mark.filterwarnings(
    "ignore:solve_batch:DeprecationWarning"
)

L, GSZ, N = 5, 8, 40
REG = GroupSparseReg.from_rho(1.0, 0.6)
OPTS = dict(snapshot_every=5, lbfgs=LbfgsOptions(max_iters=60))


def _problem(seed=0):
    C, a, b, spec, _ = make_ot_problem(seed, L, GSZ, N, pad_to=4)
    return jnp.asarray(C), jnp.asarray(a), jnp.asarray(b), spec


def _mid_iterate(C, a, b, spec):
    """A real mid-optimization (screen state, duals) pair for oracle tests."""
    res = solve_dual(
        C, a, b, spec, REG,
        SolveOptions(grad_impl="screened", snapshot_every=5,
                     lbfgs=LbfgsOptions(max_iters=12, gtol=0.0)),
    )
    return res.screen_state, res.alpha, res.beta


# -- oracle-level parity -------------------------------------------------------
def test_fused_oracle_bitwise_dense_solo():
    """Fused grid == two-launch compact == auto, and == the legacy oracle."""
    C, a, b, spec = _problem()
    from repro.core.dual import DualProblem

    prob = DualProblem(spec.num_groups, spec.group_size, N, REG)
    st, alpha, beta = _mid_iterate(C, a, b, spec)
    pp = kops.prepare_padded_problem(C, prob)
    sqrt_g = jnp.asarray(spec.sqrt_sizes())
    pstate = kops.pad_screen_state(st, sqrt_g, pp)

    outs = {
        impl: kops.dual_value_and_grad_fused(
            alpha, beta, a, b, pstate, pp, prob, impl=impl
        )
        for impl in ("grid", "compact", "auto")
    }
    # legacy two-launch oracle: standalone screen pass + flagged gradient
    flags = kops.screen_tile_flags(pstate, alpha, beta, pp, REG.tau)
    outs["legacy"] = kops.dual_value_and_grad_padded(
        alpha, beta, a, b, flags, pp, prob
    )
    v0, ga0, gb0 = outs["grid"]
    assert float(v0) == float(v0)  # finite
    for name, (v, ga, gb) in outs.items():
        assert float(v) == float(v0), name
        assert np.array_equal(np.asarray(ga), np.asarray(ga0)), name
        assert np.array_equal(np.asarray(gb), np.asarray(gb0)), name


def test_fused_oracle_bitwise_batched():
    """Batched fused == vmapped-screen two-launch, per problem, bitwise."""
    C1, a1, b1, spec = _problem(0)
    C2, a2, b2, _ = _problem(1)
    from repro.core.dual import DualProblem

    prob = DualProblem(spec.num_groups, spec.group_size, N, REG)
    C = jnp.stack([C1, C2])
    a = jnp.stack([a1, a2])
    b = jnp.stack([b1, b2])
    res = solve_batch(
        C, a, b, spec, REG,
        SolveOptions(grad_impl="screened", snapshot_every=5,
                     lbfgs=LbfgsOptions(max_iters=12, gtol=0.0)),
    )
    pp = kops.prepare_padded_problem_batched(C, prob)
    sqb = jnp.broadcast_to(jnp.asarray(spec.sqrt_sizes()), (2, L))
    pstate = kops.pad_screen_state_batched(res.screen_state, sqb, pp)
    alpha, beta = res.alpha, res.beta

    outs = {
        impl: kops.dual_value_and_grad_fused_batched(
            alpha, beta, a, b, pstate, pp, prob, impl=impl
        )
        for impl in ("grid", "compact", "auto")
    }
    v0, ga0, gb0 = outs["grid"]
    for name, (v, ga, gb) in outs.items():
        assert np.array_equal(np.asarray(v), np.asarray(v0)), name
        assert np.array_equal(np.asarray(ga), np.asarray(ga0)), name
        assert np.array_equal(np.asarray(gb), np.asarray(gb0)), name


# -- solve-level parity --------------------------------------------------------
@pytest.mark.parametrize("pallas_impl", ["grid", "compact", "auto"])
def test_fused_solve_bitwise_vs_pallas(pallas_impl):
    """solve_dual(fused) == solve_dual(pallas) bitwise in every grid mode."""
    C, a, b, spec = _problem()
    rp = solve_dual(C, a, b, spec, REG,
                    SolveOptions(grad_impl="pallas",
                                 pallas_impl=pallas_impl, **OPTS))
    rf = solve_dual(C, a, b, spec, REG,
                    SolveOptions(grad_impl="fused",
                                 pallas_impl=pallas_impl, **OPTS))
    assert float(rf.value) == float(rp.value)
    assert np.array_equal(np.asarray(rf.alpha), np.asarray(rp.alpha))
    assert np.array_equal(np.asarray(rf.beta), np.asarray(rp.beta))
    assert rf.rounds == rp.rounds


def test_fused_solve_matches_reference_backends():
    """Fused vs the dense/screened references: documented tolerance."""
    C, a, b, spec = _problem()
    rf = solve_dual(C, a, b, spec, REG, SolveOptions(grad_impl="fused", **OPTS))
    for ref_impl in ("dense", "screened"):
        rr = solve_dual(C, a, b, spec, REG,
                        SolveOptions(grad_impl=ref_impl, **OPTS))
        # objective at the documented cross-backend tolerance; duals looser
        # (f32 trajectories diverge slightly across op orders, the argmax
        # set does not)
        np.testing.assert_allclose(float(rf.value), float(rr.value),
                                   rtol=2e-5, atol=2e-5)
        np.testing.assert_allclose(np.asarray(rf.alpha), np.asarray(rr.alpha),
                                   atol=5e-4)
        np.testing.assert_allclose(np.asarray(rf.beta), np.asarray(rr.beta),
                                   atol=5e-4)


def test_fused_solve_batched_bitwise():
    """Batched fused solve == batched pallas solve == stacked solo fused."""
    probs = [_problem(s) for s in (0, 1, 2)]
    spec = probs[0][3]
    C = jnp.stack([p[0] for p in probs])
    a = jnp.stack([p[1] for p in probs])
    b = jnp.stack([p[2] for p in probs])
    rf = solve_batch(C, a, b, spec, REG,
                     SolveOptions(grad_impl="fused", **OPTS))
    rp = solve_batch(C, a, b, spec, REG,
                     SolveOptions(grad_impl="pallas", **OPTS))
    assert np.array_equal(np.asarray(rf.alpha), np.asarray(rp.alpha))
    assert np.array_equal(np.asarray(rf.beta), np.asarray(rp.beta))
    for i, (Ci, ai, bi, _) in enumerate(probs):
        solo = solve_dual(Ci, ai, bi, spec, REG,
                          SolveOptions(grad_impl="fused", **OPTS))
        assert np.array_equal(np.asarray(rf.alpha[i]), np.asarray(solo.alpha))
        assert np.array_equal(np.asarray(rf.beta[i]), np.asarray(solo.beta))


def test_fused_facade_factorized_bitwise():
    """Facade on-the-fly geometry: fused == pallas bitwise, solo + many."""
    from repro import ot

    rng = np.random.default_rng(3)
    labels = np.repeat(np.arange(L), GSZ)
    Xs = rng.normal(size=(L * GSZ, 2)) + labels[:, None] * 3.0
    Xt = rng.normal(size=(N, 2)) + rng.integers(0, L, N)[:, None] * 3.0
    prob = ot.Problem.from_samples(Xs, labels, Xt, REG, pad_to=4)
    sols = {}
    for gi in ("pallas", "fused"):
        plan = ot.ExecutionPlan(grad_impl=gi, geometry="on_the_fly",
                                snapshot_every=5)
        sols[gi] = ot.compile(prob, plan).solve(prob)
    assert sols["fused"].value == sols["pallas"].value
    assert np.array_equal(np.asarray(sols["fused"].alpha),
                          np.asarray(sols["pallas"].alpha))
    assert np.array_equal(np.asarray(sols["fused"].beta),
                          np.asarray(sols["pallas"].beta))


# -- sharded parity (4 forced host devices, subprocess) ------------------------
def test_fused_sharded_bitwise():
    """solve_batch_sharded(fused) == unsharded fused == unsharded pallas."""
    code = """
    import numpy as np, jax, jax.numpy as jnp
    from repro.core import groups as G
    from repro.core.lbfgs import LbfgsOptions
    from repro.core.ot import squared_euclidean_cost
    from repro.core.regularizers import GroupSparseReg
    from repro.core.sharded import solve_batch_sharded
    from repro.core.solver import SolveOptions, solve_batch

    assert jax.device_count() == 4, jax.device_count()
    rng = np.random.default_rng(3)
    L, g, n = 5, 8, 40
    m = L * g
    labels = np.repeat(np.arange(L), g)
    spec = G.spec_from_labels(labels, pad_to=4)
    Cs, As, Bs = [], [], []
    for _ in range(8):
        Xs = rng.normal(size=(m, 2)) + labels[:, None] * 3.0
        Xt = rng.normal(size=(n, 2)) + rng.integers(0, L, n)[:, None] * 3.0
        C = squared_euclidean_cost(Xs, Xt).astype(np.float32)
        C /= C.max()
        Cs.append(G.pad_cost_matrix(C, labels, spec))
        As.append(G.pad_marginal(np.full(m, 1/m, np.float32), labels, spec))
        Bs.append(np.full(n, 1/n, np.float32))
    C = jnp.asarray(np.stack(Cs)); a = jnp.asarray(np.stack(As))
    b = jnp.asarray(np.stack(Bs))
    reg = GroupSparseReg.from_rho(1.0, 0.6)
    opts = SolveOptions(grad_impl="fused", snapshot_every=5,
                        lbfgs=LbfgsOptions(max_iters=60))
    rs = solve_batch_sharded(C, a, b, spec, reg, opts)
    ru = solve_batch(C, a, b, spec, reg, opts)
    rp = solve_batch(C, a, b, spec, reg,
                     SolveOptions(grad_impl="pallas", snapshot_every=5,
                                  lbfgs=LbfgsOptions(max_iters=60)))
    assert np.array_equal(np.asarray(rs.alpha), np.asarray(ru.alpha))
    assert np.array_equal(np.asarray(rs.beta), np.asarray(ru.beta))
    assert np.array_equal(np.asarray(rs.rounds), np.asarray(ru.rounds))
    assert np.array_equal(np.asarray(ru.alpha), np.asarray(rp.alpha))
    assert np.array_equal(np.asarray(ru.beta), np.asarray(rp.beta))
    print("FUSED-SHARDED-OK")
    """
    env = dict(
        os.environ,
        XLA_FLAGS="--xla_force_host_platform_device_count=4",
        PYTHONPATH=SRC,
        JAX_PLATFORMS="cpu",
    )
    r = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        env=env, capture_output=True, text=True, timeout=900,
    )
    assert r.returncode == 0, r.stderr[-2000:]
    assert "FUSED-SHARDED-OK" in r.stdout


# -- launch accounting: the 2 -> 1 claim ---------------------------------------
def test_fused_single_launch_per_eval():
    """Steady-state oracle: two-launch schedule traces 2 Pallas calls,
    the fused schedule exactly 1 (and it is the fused mega-kernel)."""
    from repro.kernels import gradpsi as gk

    C, a, b, spec = _problem()
    from repro.core.dual import DualProblem

    prob = DualProblem(spec.num_groups, spec.group_size, N, REG)
    st, alpha, beta = _mid_iterate(C, a, b, spec)
    pp = kops.prepare_padded_problem(C, prob)
    pstate = kops.pad_screen_state(st, jnp.asarray(spec.sqrt_sizes()), pp)

    counts = {}
    for impl in ("grid", "compact"):
        jax.clear_caches()
        gk.reset_launch_counts()
        jax.block_until_ready(kops.dual_value_and_grad_fused(
            alpha, beta, a, b, pstate, pp, prob, impl=impl
        ))
        counts[impl] = dict(gk.launch_counts())
    assert sum(counts["grid"].values()) == 1, counts["grid"]
    assert list(counts["grid"]) == ["gradpsi_fused_pallas"], counts["grid"]
    assert sum(counts["compact"].values()) == 2, counts["compact"]
    assert counts["compact"].get("screen_pallas") == 1, counts["compact"]


# -- bf16 mode -----------------------------------------------------------------
def test_bf16_requires_kernel_backend():
    from repro import ot

    with pytest.raises(ValueError, match="bf16"):
        ot.ExecutionPlan(grad_impl="screened", precision="bf16")
    with pytest.raises(ValueError):
        solve_dual(*_problem()[:3], _problem()[3], REG,
                   SolveOptions(grad_impl="dense", precision="bf16"))


@pytest.mark.parametrize("grad_impl", ["pallas", "fused"])
def test_bf16_tolerance_vs_f64_baseline(grad_impl):
    """bf16 cost storage: objective within the documented tolerance of the
    f64 cpu_baseline AND of the committed golden fixture (level 3 of the
    docs/geometry.md numerics scheme)."""
    from repro.core.cpu_baseline import fast_solve

    with open(os.path.join(FIXTURE_DIR, "golden_fused_bf16.json")) as f:
        gold = json.load(f)
    assert gold["schema_version"] == 1
    co = gold["coords"]
    C, a, b, spec, _ = make_ot_problem(
        co["seed"], co["L"], co["g"], co["n"], pad_to=co["pad_to"]
    )
    reg = GroupSparseReg.from_rho(co["gamma"], co["rho"])

    ref = fast_solve(np.asarray(C, np.float64), np.asarray(a, np.float64),
                     np.asarray(b, np.float64), spec, reg)
    # the f64 reference itself is pinned tight — drift here means the
    # baseline (not the bf16 path) changed
    np.testing.assert_allclose(ref.value, gold["f64_value"], rtol=1e-9)

    r16 = solve_dual(jnp.asarray(C), jnp.asarray(a), jnp.asarray(b), spec,
                     reg, SolveOptions(grad_impl=grad_impl,
                                       precision="bf16", **OPTS))
    # documented bf16 tolerance vs the f64 baseline (docs/api.md)
    np.testing.assert_allclose(float(r16.value), ref.value,
                               rtol=1e-3, atol=1e-3)
    # golden pin, cross-backend tolerant (bf16 rounding is deterministic
    # per backend but the accumulation order may differ on real TPUs)
    np.testing.assert_allclose(float(r16.value), gold["bf16_value"],
                               rtol=1e-4, atol=1e-4)


def test_bf16_prepared_operands_are_bf16():
    """_prepare_padded stores the cost (dense Cp / factorized leaves) in
    bf16 exactly once; f32 mode leaves everything f32."""
    from repro.core.dual import DualProblem
    from repro.core.solver import _prepare_padded

    C, a, b, spec = _problem()
    prob = DualProblem(spec.num_groups, spec.group_size, N, REG)
    o16 = SolveOptions(grad_impl="fused", precision="bf16")
    o32 = SolveOptions(grad_impl="fused", precision="f32")
    assert _prepare_padded(C[None], prob, o16).Cp.dtype == jnp.bfloat16
    assert _prepare_padded(C[None], prob, o32).Cp.dtype == jnp.float32

    from repro.ot.geometry import SquaredL2Geometry

    rng = np.random.default_rng(0)
    labels = np.repeat(np.arange(L), GSZ)
    geom = SquaredL2Geometry.from_samples(
        rng.normal(size=(L * GSZ, 3)), labels, rng.normal(size=(N, 3)), spec
    )
    fc = kops.FactorizedCost(
        x=jnp.asarray(geom.x), x_sq=jnp.asarray(geom.x_sq),
        y=jnp.asarray(geom.y), y_sq=jnp.asarray(geom.y_sq),
    )
    fp16 = _prepare_padded(fc, prob, o16)
    assert fp16.x.dtype == jnp.bfloat16 and fp16.y_sq.dtype == jnp.bfloat16


# -- VMEM byte-model pin (satellite: explicit per-route accounting) ------------
def test_tile_working_set_bytes_formula():
    """Pin the bytes-per-TILE_L formula term by term, both routes."""
    from repro.kernels.gradpsi import (
        pick_tile_l,
        pick_tile_l_factorized,
        tile_working_set_bytes,
    )

    def expected(tl, g, tn, d, db):
        ft = 2 * tl * g * tn * 4                       # F + T, f32
        if d is None:
            cost = tl * g * tn * db                    # dense cost tile
        else:                                          # factorized rebuild
            cost = tl * g * tn * d * 4 + (tl * g + tn) * (d + 1) * db
        duals = (tl * g + tn + tl) * 4                 # alpha, beta, tau
        outputs = (tl * g + tn + 1) * 4                # ga, gb, psi
        screen = (3 * tl * tn * 4                      # z/k/o tiles
                  + tl * tn                            # active, int8
                  + (4 * tl + tn) * 4                  # 3 da rows+sqrt_g, db
                  + 4)                                 # flag cell
        return ft + cost + duals + outputs + screen

    for tl in (1, 2, 4, 8):
        for g in (8, 16, 128):
            for tn in (128, 256):
                for d, db in ((None, 4), (None, 2), (3, 4), (16, 2)):
                    got = tile_working_set_bytes(tl, g, tn, d=d, dtype_bytes=db)
                    assert got == expected(tl, g, tn, d, db), (tl, g, tn, d, db)

    # the pickers consume this model: monotone in TILE_L, and the picked
    # tile must itself fit while 2x it (if <8) must not have been skipped
    from repro.kernels.gradpsi import VMEM_BUDGET_BYTES

    for g in (8, 64, 512):
        t = pick_tile_l(g, 128)
        assert tile_working_set_bytes(t, g, 128) <= VMEM_BUDGET_BYTES or t == 1
        if t < 8:
            assert tile_working_set_bytes(2 * t, g, 128) > VMEM_BUDGET_BYTES
    for g, d in ((8, 3), (64, 16)):
        t = pick_tile_l_factorized(g, 128, d)
        assert (tile_working_set_bytes(t, g, 128, d=d) <= VMEM_BUDGET_BYTES
                or t == 1)
