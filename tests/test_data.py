"""Data pipeline: determinism, sharding, restart reproducibility."""
import numpy as np

from repro.data.pipeline import (
    DomainPairConfig,
    SyntheticLM,
    SyntheticLMConfig,
    make_domain_pair,
)


def test_batch_is_pure_function_of_step():
    cfg = SyntheticLMConfig(vocab_size=100, seq_len=16, global_batch=4, seed=3)
    d1, d2 = SyntheticLM(cfg), SyntheticLM(cfg)
    for step in (0, 5, 17):
        b1, b2 = d1.batch(step), d2.batch(step)
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert not np.array_equal(d1.batch(0)["tokens"], d1.batch(1)["tokens"])


def test_shards_partition_the_global_batch():
    cfg = SyntheticLMConfig(vocab_size=100, seq_len=8, global_batch=8, seed=0)
    _full = SyntheticLM(cfg)  # shard 0 of 1 (constructor sanity)
    shards = [SyntheticLM(cfg, shard_id=i, num_shards=4) for i in range(4)]
    sizes = [s.batch(3)["tokens"].shape[0] for s in shards]
    assert sizes == [2, 2, 2, 2]
    # shard batches differ (different slices of the logical batch)
    assert not np.array_equal(shards[0].batch(3)["tokens"], shards[1].batch(3)["tokens"])


def test_tokens_have_learnable_structure():
    cfg = SyntheticLMConfig(vocab_size=97, seq_len=64, global_batch=8, seed=1)
    b = SyntheticLM(cfg).batch(0)
    t = b["tokens"]
    # even positions are a deterministic function of the previous token
    pred = (t[:, 1:-1:2] + np.asarray(SyntheticLM(cfg).shift)[b["class"]][:, None]) % 97
    np.testing.assert_array_equal(t[:, 2::2], pred)


def test_domain_pair_matches_paper_geometry():
    Xs, ys, Xt, yt = make_domain_pair(DomainPairConfig(num_classes=5, samples_per_class=10))
    assert Xs.shape == (50, 2) and Xt.shape == (50, 2)
    # source at y=-5, target at y=+5 (paper's synthetic setup)
    assert abs(Xs[:, 1].mean() + 5) < 1
    assert abs(Xt[:, 1].mean() - 5) < 1
