"""Beyond-paper OT MoE routing: balance + locality vs plain top-k."""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import build_model
from repro.training.ot_routing import ot_route, routing_stats


def test_ot_route_improves_balance_and_locality():
    rng = np.random.default_rng(0)
    B, S, E, k = 4, 32, 8, 2
    T = B * S
    # skewed router: most tokens prefer experts 0-1 (the imbalance regime)
    logits = rng.normal(size=(T, E)).astype(np.float32)
    logits[:, 0] += 2.0
    logits[:, 1] += 1.5
    logits = jnp.asarray(logits)

    topw, topi_base = jax.lax.top_k(jax.nn.softmax(logits, -1), k)
    base = routing_stats(topi_base, E, B, S)
    topi_ot, w_ot = ot_route(logits, num_seqs=B, seq_len=S, top_k=k,
                             gamma=5.0, rho=0.5)
    ot = routing_stats(topi_ot, E, B, S)

    assert float(ot["load_cv"]) < float(base["load_cv"])  # better balance
    assert bool(jnp.all(jnp.isfinite(w_ot)))
    assert bool(jnp.all(jnp.abs(jnp.sum(w_ot, -1) - 1.0) < 1e-4))


def test_moe_layer_with_ot_balance_runs():
    cfg = get_config("qwen2-moe-a2.7b").reduced()
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, ot_balance=True)
    )
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(1)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 17)), jnp.int32)}
    loss, metrics = model.train_loss(params, batch)
    assert np.isfinite(float(loss))
    g = jax.grad(lambda p: model.train_loss(p, batch)[0])(params)
    assert all(bool(jnp.all(jnp.isfinite(x))) for x in jax.tree_util.tree_leaves(g))
    # balanced marginals -> near-zero drop fraction at capacity 4.0
    assert float(metrics["moe_dropped"]) < 0.05
