"""Faithful CPU reproduction: origin == fast (Thm 2), counters, ablations."""
import numpy as np
import pytest

from repro.core import groups as G
from repro.core.cpu_baseline import fast_solve, origin_solve
from repro.core.ot import squared_euclidean_cost
from repro.core.regularizers import GroupSparseReg


def _paper_synthetic(L=20, g=10, seed=1):
    rng = np.random.default_rng(seed)
    m = n = L * g
    labels = np.repeat(np.arange(L), g)
    Xs = rng.normal(size=(m, 2)) + np.stack([labels * 5.0, -5.0 * np.ones(m)], 1)
    Xt = rng.normal(size=(n, 2)) + np.stack([labels * 5.0, 5.0 * np.ones(n)], 1)
    C = squared_euclidean_cost(Xs, Xt)
    C /= C.max()
    spec = G.spec_from_labels(labels, pad_to=8)
    return (
        G.pad_cost_matrix(C, labels, spec),
        G.pad_marginal(np.full(m, 1 / m), labels, spec),
        np.full(n, 1 / n),
        spec,
    )


@pytest.mark.parametrize("gamma,rho", [(0.1, 0.8), (1.0, 0.4), (10.0, 0.6)])
def test_fast_equals_origin(gamma, rho):
    C, a, b, spec = _paper_synthetic()
    reg = GroupSparseReg.from_rho(gamma, rho)
    r0 = origin_solve(C, a, b, spec, reg)
    r1 = fast_solve(C, a, b, spec, reg)
    np.testing.assert_allclose(r1.value, r0.value, rtol=1e-7, atol=1e-9)
    # alpha can drift within the dual's translation-degenerate subspace via
    # fp summation-order differences; the objective (above) and the unique
    # primal plan are the Theorem-2 quantities.
    np.testing.assert_allclose(r1.alpha, r0.alpha, atol=2e-3)


def test_fast_skips_most_blocks():
    C, a, b, spec = _paper_synthetic()
    reg = GroupSparseReg.from_rho(1.0, 0.8)
    r = fast_solve(C, a, b, spec, reg)
    total = r.n_blocks_skipped + r.n_blocks_computed + r.n_blocks_active
    assert r.n_blocks_skipped / total > 0.5


def test_lower_bound_ablation_matches():
    """Paper Fig. D: idea 2 off must still be exact (just slower)."""
    C, a, b, spec = _paper_synthetic(L=10)
    reg = GroupSparseReg.from_rho(0.1, 0.6)
    r0 = origin_solve(C, a, b, spec, reg)
    r1 = fast_solve(C, a, b, spec, reg, use_lower=False)
    np.testing.assert_allclose(r1.value, r0.value, rtol=1e-7, atol=1e-9)
    assert r1.n_blocks_active == 0  # no active set without lower bounds


def test_snapshot_interval_r_exactness():
    """Any snapshot interval r must preserve exactness."""
    C, a, b, spec = _paper_synthetic(L=10)
    reg = GroupSparseReg.from_rho(1.0, 0.8)
    r0 = origin_solve(C, a, b, spec, reg)
    for r in (1, 5, 25):
        rf = fast_solve(C, a, b, spec, reg, r=r)
        np.testing.assert_allclose(rf.value, r0.value, rtol=1e-7, atol=1e-9)


def test_origin_counts_all_blocks():
    C, a, b, spec = _paper_synthetic(L=10)
    reg = GroupSparseReg.from_rho(1.0, 0.6)
    r = origin_solve(C, a, b, spec, reg)
    L, n = spec.num_groups, C.shape[1]
    assert r.n_blocks_computed == r.n_evals * L * n
    assert r.n_blocks_skipped == 0
