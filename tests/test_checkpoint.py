"""Checkpoint manager: roundtrip, atomicity, retention, elastic reshard."""
import json

import numpy as np
import jax.numpy as jnp

from repro.training.checkpoint import CheckpointManager


def _state(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "params": {"w": jnp.asarray(rng.normal(size=(4, 8)).astype(np.float32)),
                   "b": jnp.asarray(rng.normal(size=(8,)).astype(np.float32)).astype(jnp.bfloat16)},
        "opt": {"m": {"w": jnp.zeros((4, 8)), "b": jnp.zeros((8,))},
                "step": jnp.asarray(7, jnp.int32)},
    }


def test_roundtrip(tmp_path):
    cm = CheckpointManager(tmp_path, async_write=False)
    st = _state()
    cm.save(st, 10)
    restored, step = cm.restore(_state(seed=99))
    assert step == 10
    np.testing.assert_allclose(
        np.asarray(restored["params"]["w"]), np.asarray(st["params"]["w"])
    )
    assert restored["params"]["b"].dtype == jnp.bfloat16
    assert int(restored["opt"]["step"]) == 7


def test_async_save_then_wait(tmp_path):
    cm = CheckpointManager(tmp_path, async_write=True)
    cm.save(_state(), 1)
    cm.wait()
    assert cm.latest_step() == 1


def test_uncommitted_checkpoint_ignored(tmp_path):
    cm = CheckpointManager(tmp_path, async_write=False)
    cm.save(_state(), 5)
    # simulate a crash mid-write of step 6: directory without COMMITTED
    d = tmp_path / "step_00000006"
    d.mkdir()
    (d / "index.json").write_text(json.dumps({"step": 6}))
    assert cm.latest_step() == 5
    _, step = cm.restore(_state())
    assert step == 5


def test_retention(tmp_path):
    cm = CheckpointManager(tmp_path, keep=2, async_write=False)
    for s in (1, 2, 3, 4):
        cm.save(_state(), s)
    assert cm.all_steps() == [3, 4]


def test_restore_shape_mismatch_raises(tmp_path):
    cm = CheckpointManager(tmp_path, async_write=False)
    cm.save(_state(), 1)
    bad = _state()
    bad["params"]["w"] = jnp.zeros((5, 8))
    try:
        cm.restore(bad)
        raised = False
    except ValueError:
        raised = True
    assert raised
