"""Serving robustness: SLOs, shedding, quarantine, and chaos invariants.

The contract under test (ISSUE: SLO-aware serving under failure): with
faults injected through ``repro.utils.faults``, the engine never crashes
or hangs, every request reaches EXACTLY ONE terminal status, and the
plans of retired-DONE requests are bitwise identical to a no-fault run of
the same healthy requests.
"""
import dataclasses

import numpy as np
import pytest

from repro.core.lbfgs import LbfgsOptions
from repro.core.regularizers import GroupSparseReg
from repro.core.solver import SolveOptions
from repro.ot.problem import Problem, SubmitOptions
from repro.serving.ot_engine import OTRequest, OTServingEngine
from repro.serving.policy import (
    PendingQueue,
    RequestStatus,
    ServingPolicy,
    TERMINAL_STATUSES,
)
from repro.serving.traffic import TrafficSpec, drive, make_trace
from repro.utils.faults import REGISTRY, FaultSpec, injected

OPTS = SolveOptions(grad_impl="screened", lbfgs=LbfgsOptions(max_iters=150))
REG = GroupSparseReg.from_rho(1.0, 0.6)


@pytest.fixture(autouse=True)
def _clean_registry():
    """No test may leak faults into its neighbours."""
    REGISTRY.reset()
    yield
    REGISTRY.reset()


def _request(rng, rid, L=4, g=6, n=30, **kw):
    m = L * g
    labels = np.repeat(np.arange(L), g)
    C = rng.random((m, n)).astype(np.float32)
    return OTRequest(rid=rid, C=C, labels=labels, **kw)


def _problem(rng, L=4, g=6, n=30, submit=None):
    m = L * g
    labels = np.repeat(np.arange(L), g)
    return Problem(reg=REG, C=rng.random((m, n)), labels=labels, pad_to=8,
                   submit=submit)


# -- lifecycle & SLO plumbing --------------------------------------------------

def test_submit_none_when_full_then_succeeds_after_tick():
    """submit() returns None while the bucket is full; the same problem is
    admitted once a slot frees up (the documented retry contract)."""
    rng = np.random.default_rng(0)
    engine = OTServingEngine(REG, OPTS, max_batch=1)
    p0, p1 = _problem(rng), _problem(rng)
    r0 = engine.submit(p0)
    assert r0 is not None and r0.status is RequestStatus.RUNNING
    assert engine.submit(p1) is None          # one slot, already taken
    finished = []
    while not finished:
        finished += engine.tick()
    assert finished[0].rid == r0.rid and finished[0].status is RequestStatus.DONE
    r1 = engine.submit(p1)                    # slot recycled: admits now
    assert r1 is not None and r1.status is RequestStatus.RUNNING
    while engine._in_flight():
        finished += engine.tick()
    assert {r.status for r in finished} == {RequestStatus.DONE}


def test_submit_options_thread_through_problem():
    """Problem.submit carries SLOs into the engine request; explicit
    keywords override; the policy default fills the rest."""
    rng = np.random.default_rng(1)
    engine = OTServingEngine(
        REG, OPTS, policy=ServingPolicy(default_deadline=99, default_priority=1)
    )
    p = _problem(rng, submit=SubmitOptions(deadline=7, priority=3))
    req, _ = engine.enqueue(p)
    assert (req.deadline, req.priority) == (7, 3)
    req2, _ = engine.enqueue(_problem(rng), deadline=5)
    assert (req2.deadline, req2.priority) == (5, 1)   # kwarg + policy default
    # round-trips through the declarative config wire too
    p3 = Problem.from_config(p.config())
    assert p3.submit == SubmitOptions(deadline=7, priority=3)


def test_problem_rejects_nonfinite_inputs():
    """Satellite: non-finite costs/marginals fail Problem validation with a
    clear error, and a poisoned raw request FAILS at admission without
    touching any bucket."""
    rng = np.random.default_rng(2)
    m, n = 24, 30
    labels = np.repeat(np.arange(4), 6)
    C = rng.random((m, n))
    C_bad = C.copy()
    C_bad[3, 4] = np.nan
    with pytest.raises(ValueError, match="non-finite"):
        Problem(reg=REG, C=C_bad, labels=labels)
    a_bad = np.full(m, 1.0 / m)
    a_bad[0] = np.inf
    with pytest.raises(ValueError, match="non-finite"):
        Problem(reg=REG, C=C, labels=labels, a=a_bad)

    engine = OTServingEngine(REG, OPTS)
    req = OTRequest(rid=0, C=C_bad, labels=labels)
    req, shed = engine.enqueue(req)
    assert shed == [req]
    assert req.status is RequestStatus.FAILED
    assert "rejected at admission" in req.error
    assert not engine.buckets                 # engine untouched


def test_deadline_expires_mid_flight():
    """A deadline-carrying request that cannot finish in time is retired
    DEADLINE_EXCEEDED mid-flight; its slot is recycled cleanly."""
    rng = np.random.default_rng(3)
    slow = SolveOptions(grad_impl="screened", lbfgs=LbfgsOptions(max_iters=3))
    engine = OTServingEngine(REG, slow, max_batch=2)
    done = engine.run([_request(rng, 0, n=40, deadline=2),
                       _request(rng, 1, n=41)])
    by_rid = {r.rid: r for r in done}
    assert by_rid[0].status is RequestStatus.DEADLINE_EXCEEDED
    assert "mid-flight" in by_rid[0].error
    assert by_rid[0].ticks_in_flight == 2
    assert by_rid[1].status is RequestStatus.DONE   # neighbour unaffected
    assert engine.stats()["status"]["DEADLINE_EXCEEDED"] == 1


def test_priority_shedding_at_double_capacity():
    """At 2x queue capacity the LOWEST-priority requests are shed (ties:
    youngest first) and every high-priority request survives."""
    rng = np.random.default_rng(4)
    engine = OTServingEngine(REG, OPTS, policy=ServingPolicy(max_pending=4))
    shed_all = []
    reqs = []
    for i in range(8):                        # 2x capacity, alternating prio
        req, shed = engine.enqueue(_request(rng, i, priority=i % 2))
        reqs.append(req)
        shed_all += shed
    assert len(shed_all) == 4
    assert all(r.status is RequestStatus.SHED for r in shed_all)
    assert all(r.priority == 0 for r in shed_all)          # low prio only
    survivors = list(engine.pending)
    assert all(r.priority == 1 for r in survivors)
    assert [r.rid for r in survivors] == [1, 3, 5, 7]      # FIFO within class
    # shed + queued partition the submissions: nothing lost, nothing twice
    assert {r.rid for r in shed_all} | {r.rid for r in survivors} == set(range(8))


def test_geometry_over_limits_is_shed_not_queued():
    """A request that can NEVER fit the engine's limits is shed at
    submission (enqueue) or rejected loudly (submit), not left pending."""
    rng = np.random.default_rng(5)
    engine = OTServingEngine(
        REG, OPTS, policy=ServingPolicy(max_groups=3)
    )
    req, shed = engine.enqueue(_request(rng, 0, L=4))
    assert shed == [req] and req.status is RequestStatus.SHED
    assert "exceeds engine limits" in req.error
    with pytest.raises(ValueError, match="exceeds engine limits"):
        engine.submit(_problem(rng, L=4))
    assert len(engine.pending) == 0


# -- quarantine & fallback -----------------------------------------------------

def test_failed_slot_keeps_done_neighbour_bitwise():
    """A quarantined slot (injected NaN, no usable fallback) must retire
    FAILED while its bucket neighbour's value AND plan stay bitwise equal
    to a no-fault run of the same healthy request."""
    rng = np.random.default_rng(6)
    C0 = rng.random((24, 30)).astype(np.float32)
    labels = np.repeat(np.arange(4), 6)
    policy = ServingPolicy(fallback_ladder=("restart",), max_attempts=2)

    ref_engine = OTServingEngine(REG, OPTS, max_batch=2, policy=policy)
    ref = ref_engine.run([OTRequest(rid=0, C=C0, labels=labels)])[0]
    assert ref.status is RequestStatus.DONE

    engine = OTServingEngine(REG, OPTS, max_batch=2, policy=policy)
    with injected(FaultSpec("nan_cost", rids={1})):
        done = engine.run([
            OTRequest(rid=0, C=C0, labels=labels),
            _request(rng, 1),
        ])
    by_rid = {r.rid: r for r in done}
    assert by_rid[1].status is RequestStatus.FAILED
    assert by_rid[1].attempts == 2            # initial + one in-slot restart
    assert "ladder exhausted" in by_rid[1].error
    assert by_rid[0].status is RequestStatus.DONE
    assert by_rid[0].value == ref.value       # bitwise
    np.testing.assert_array_equal(by_rid[0].plan, ref.plan)


def test_fallback_ladder_recovers_poisoned_slot():
    """With the full ladder, a NaN-poisoned slot walks restart -> dense and
    retires DONE via the dense fallback (the slot copy was poisoned, the
    validated payload is healthy), with attempts accounted."""
    rng = np.random.default_rng(7)
    engine = OTServingEngine(REG, OPTS, max_batch=2)
    with injected(FaultSpec("nan_cost", rids={0})):
        done = engine.run([_request(rng, 0)])
    (req,) = done
    assert req.status is RequestStatus.DONE
    assert req.route == "dense"
    assert req.attempts == 3                  # slot + restart + dense
    assert "recovered via dense fallback" in req.error
    assert np.all(np.isfinite(req.plan)) and np.isfinite(req.value)
    assert engine.stats()["retry_attempts"] == 2
    # sanity: the recovered value matches a clean engine solve of the same C
    clean = OTServingEngine(REG, OPTS, max_batch=2)
    ref = clean.run([OTRequest(rid=0, C=req.C, labels=req.labels)])[0]
    assert req.value == pytest.approx(ref.value, rel=1e-4)


def test_forced_lbfgs_failure_routes_to_cpu_rung():
    """A persistently failing device solve (forced L-BFGS failure + a
    ladder without the dense rung) lands on the CPU baseline and still
    returns a finite plan."""
    rng = np.random.default_rng(8)
    policy = ServingPolicy(fallback_ladder=("cpu",), max_attempts=2)
    engine = OTServingEngine(REG, OPTS, max_batch=1, policy=policy)
    with injected(FaultSpec("lbfgs_fail", rids={0})):
        done = engine.run([_request(rng, 0)])
    (req,) = done
    assert req.status is RequestStatus.DONE
    assert req.route == "cpu"
    assert np.all(np.isfinite(req.plan)) and np.isfinite(req.value)


# -- stall guards & hygiene ----------------------------------------------------

def test_run_stall_guard_sheds_unadmittable_work():
    """Satellite regression: with admission permanently failing, run() must
    terminate (shedding the queue) instead of looping forever."""
    rng = np.random.default_rng(9)
    engine = OTServingEngine(REG, OPTS, policy=ServingPolicy(stall_passes=2))
    with injected(FaultSpec("admit_fail")):   # unlimited budget
        done = engine.run([_request(rng, 0), _request(rng, 1)])
    assert len(done) == 2
    assert all(r.status is RequestStatus.SHED for r in done)
    assert all("stall guard" in r.error for r in done)
    assert engine.stats()["in_flight"] == 0


def test_run_safety_valve_fails_frozen_bucket():
    """A bucket frozen by a persistent slow fault cannot hang run(): the
    in-flight request is force-failed once the safety valve trips."""
    rng = np.random.default_rng(10)
    opts = SolveOptions(grad_impl="screened", max_rounds=5,
                        lbfgs=LbfgsOptions(max_iters=150))
    engine = OTServingEngine(REG, opts, policy=ServingPolicy(stall_passes=2))
    with injected(FaultSpec("slow_bucket")):  # every tick, forever
        done = engine.run([_request(rng, 0)])
    (req,) = done
    assert req.status is RequestStatus.FAILED
    assert "stall guard" in req.error


def test_slow_bucket_lets_deadlines_expire():
    """A slow bucket makes requests age without progress; deadline-carrying
    requests expire instead of hanging."""
    rng = np.random.default_rng(11)
    engine = OTServingEngine(REG, OPTS, max_batch=2)
    with injected(FaultSpec("slow_bucket")):
        done = engine.run([_request(rng, 0, deadline=3)])
    (req,) = done
    assert req.status is RequestStatus.DEADLINE_EXCEEDED
    assert req.ticks_in_flight == 3


def test_idle_buckets_are_evicted():
    """Buckets with no occupants are evicted after the policy's idle
    window, bounding the bucket dict under shifting traffic mixes."""
    rng = np.random.default_rng(12)
    engine = OTServingEngine(
        REG, OPTS, policy=ServingPolicy(idle_evict_after=2)
    )
    engine.run([_request(rng, 0)])
    assert len(engine.buckets) == 1           # still warm right after run()
    for _ in range(3):
        engine.tick()
    assert len(engine.buckets) == 0
    assert engine.stats()["evictions"] == 1
    # the engine still serves after eviction (programs re-attach from the
    # process-wide jit cache)
    done = engine.run([_request(rng, 1)])
    assert done[0].status is RequestStatus.DONE


def test_pending_queue_unit_behavior():
    """PendingQueue ordering + overflow shed rules, in isolation."""

    class R:
        def __init__(self, rid, priority, tick):
            self.rid, self.priority, self.submitted_tick = rid, priority, tick

    q = PendingQueue(3)
    assert q.push(R(0, 0, 0)) == []
    assert q.push(R(1, 2, 1)) == []
    assert q.push(R(2, 1, 2)) == []
    assert [r.rid for r in q] == [1, 2, 0]    # priority desc, FIFO in class
    shed = q.push(R(3, 0, 3))                 # overflow: lowest prio, youngest
    assert [r.rid for r in shed] == [3]
    shed = q.push(R(4, 3, 4))
    assert [r.rid for r in shed] == [0]       # now rid 0 is the victim
    assert [r.rid for r in q.drain()] == [4, 1, 2]
    assert len(q) == 0


def test_failed_slot_keeps_done_neighbour_bitwise_sharded():
    """The quarantine bitwise guarantee must hold across a device mesh too
    (slots on other devices are frozen through the same masked merges)."""
    import jax

    if jax.device_count() < 2:
        pytest.skip("needs a multi-device host (CI chaos job forces 4)")
    from repro.core.distributed import make_batch_mesh

    rng = np.random.default_rng(15)
    C0 = rng.random((24, 30)).astype(np.float32)
    labels = np.repeat(np.arange(4), 6)
    policy = ServingPolicy(fallback_ladder=("restart",), max_attempts=2)

    ref_engine = OTServingEngine(REG, OPTS, max_batch=1,
                                 mesh=make_batch_mesh(), policy=policy)
    ref = ref_engine.run([OTRequest(rid=0, C=C0, labels=labels)])[0]

    engine = OTServingEngine(REG, OPTS, max_batch=1,
                             mesh=make_batch_mesh(), policy=policy)
    with injected(FaultSpec("nan_cost", rids={1})):
        done = engine.run([
            OTRequest(rid=0, C=C0, labels=labels),
            _request(rng, 1),
        ])
    by_rid = {r.rid: r for r in done}
    assert by_rid[1].status is RequestStatus.FAILED
    assert by_rid[0].status is RequestStatus.DONE
    assert by_rid[0].value == ref.value       # bitwise across the mesh
    np.testing.assert_array_equal(by_rid[0].plan, ref.plan)


# -- chaos: everything at once -------------------------------------------------

def test_chaos_traffic_all_requests_terminal_exactly_once():
    """The headline invariant: seeded overload traffic + every fault kind
    at once; the engine neither crashes nor hangs, and each request ends
    in exactly one terminal status."""
    spec = TrafficSpec(
        num_requests=12, arrival_rate=4.0, seed=13,
        shapes=((12, 20, 3), (16, 24, 4)),
        deadline=6, deadline_fraction=0.5, priorities=(0, 1, 2),
    )
    trace = make_trace(spec)
    engine = OTServingEngine(
        REG, OPTS, max_batch=2,
        policy=ServingPolicy(max_pending=4, max_attempts=2,
                             fallback_ladder=("restart", "dense")),
    )
    with injected(
        FaultSpec("nan_cost", count=2),
        FaultSpec("lbfgs_fail", count=2, after_tick=1),
        FaultSpec("admit_fail", count=2),
        FaultSpec("slow_bucket", count=2, after_tick=2),
    ):
        done = drive(engine, trace, max_ticks=500)
    assert len(done) == spec.num_requests
    assert sorted(r.rid for r in done) == list(range(spec.num_requests))
    assert all(r.status in TERMINAL_STATUSES for r in done)
    stats = engine.stats()
    assert stats["pending"] == 0 and stats["in_flight"] == 0
    assert sum(stats["status"].values()) == spec.num_requests
    # every DONE result is finite and shaped for the caller
    for r in done:
        if r.status is RequestStatus.DONE:
            assert np.isfinite(r.value) and np.all(np.isfinite(r.plan))
            assert r.plan.shape == r.C.shape


def test_traffic_trace_is_deterministic():
    """Same spec -> identical trace (arrivals, payload bits, SLOs)."""
    spec = TrafficSpec(num_requests=6, arrival_rate=2.0, seed=21,
                       deadline=5, deadline_fraction=0.5, priorities=(0, 3))
    t1, t2 = make_trace(spec), make_trace(spec)
    assert [t for t, _ in t1] == [t for t, _ in t2]
    assert [t for t, _ in t1] == sorted(t for t, _ in t1)
    for (_, a), (_, b) in zip(t1, t2):
        np.testing.assert_array_equal(a.C, b.C)
        np.testing.assert_array_equal(a.labels, b.labels)
        assert (a.deadline, a.priority) == (b.deadline, b.priority)


def test_traffic_poisson_arrivals_deterministic_same_payloads():
    """Satellite: arrivals='poisson' — seeded exponential gaps give a
    reproducible bursty schedule, the payload stream is bit-identical to
    deterministic mode, and the mean rate is honored."""
    det = TrafficSpec(num_requests=64, arrival_rate=2.0, seed=21,
                      deadline=5, deadline_fraction=0.5, priorities=(0, 3))
    poi = dataclasses.replace(det, arrivals="poisson")
    tp1, tp2 = make_trace(poi), make_trace(poi)
    # deterministic given the seed, ticks sorted
    assert [t for t, _ in tp1] == [t for t, _ in tp2]
    assert [t for t, _ in tp1] == sorted(t for t, _ in tp1)
    # a different seed gives a different schedule; same seed+rate matches the
    # configured mean rate within a loose statistical band
    tp3 = make_trace(dataclasses.replace(poi, seed=22))
    assert [t for t, _ in tp3] != [t for t, _ in tp1]
    span = max(t for t, _ in tp1) + 1
    assert 0.5 * poi.num_requests / poi.arrival_rate <= span \
        <= 2.0 * poi.num_requests / poi.arrival_rate
    # payloads are untouched by the arrival mode
    td = make_trace(det)
    assert [t for t, _ in td] != [t for t, _ in tp1]  # schedules do differ
    for (_, a), (_, b) in zip(td, tp1):
        np.testing.assert_array_equal(a.C, b.C)
        np.testing.assert_array_equal(a.labels, b.labels)
        assert (a.deadline, a.priority) == (b.deadline, b.priority)
    # knob is validated and round-trips through config()
    assert poi.config()["arrivals"] == "poisson"
    with pytest.raises(ValueError, match="arrivals"):
        TrafficSpec(arrivals="uniform")


def test_traffic_poisson_drives_engine_to_terminal():
    """Poisson bursts still drain: every request reaches a terminal
    status exactly once under the same engine invariants."""
    spec = TrafficSpec(num_requests=10, arrival_rate=3.0, seed=7,
                       arrivals="poisson", priorities=(0, 1))
    engine = OTServingEngine(REG, OPTS, max_batch=2,
                             policy=ServingPolicy(max_pending=4))
    done = drive(engine, make_trace(spec), max_ticks=500)
    assert sorted(r.rid for r in done) == list(range(spec.num_requests))
    assert all(r.status in TERMINAL_STATUSES for r in done)


# -- facade observability ------------------------------------------------------

def test_executor_stats_and_stream_status():
    """Satellite: Executor.stats() reports per-terminal-status counts (the
    serving vocabulary), stream diagnostics carry per-problem status, and
    describe() ends with the health line."""
    import repro.ot as ot

    rng = np.random.default_rng(14)
    problems = [_problem(rng, n=31), _problem(rng, n=30)]
    ex = ot.compile(problems[0], ot.ExecutionPlan(grad_impl="screened"))
    ex.solve(problems[0])
    last = None
    for info in ex.stream(problems):
        assert set(info["status"]) <= {"RUNNING", "DONE", "FAILED"}
        last = info
    assert last["status"] == ["DONE", "DONE"]
    stats = ex.stats()
    assert stats["status"]["DONE"] == 3       # 1 solo + 2 streamed
    assert stats["status"]["FAILED"] == 0
    assert set(stats["status"]) == {s.value for s in TERMINAL_STATUSES}
    assert stats["retry_attempts"] == 0
    assert "health:" in ex.describe()
