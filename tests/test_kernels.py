"""Kernel-vs-oracle sweeps: shapes x dtypes x screening density.

Every Pallas kernel is validated in interpret mode against its pure-jnp
oracle in ref.py, per the kernel contract (same tile-masking semantics).
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import groups as G
from repro.core import screening as S
from repro.core.dual import DualProblem, dual_value_and_grad, snapshot_norms
from repro.core.ot import squared_euclidean_cost
from repro.core.regularizers import GroupSparseReg
from repro.kernels import ops as kops
from repro.kernels.gradpsi import gradpsi_pallas, pick_tile_l
from repro.kernels.ref import gradpsi_ref, screen_ref


def _rand_problem(rng, L, g, n, dtype=jnp.float32):
    alpha = jnp.asarray(rng.normal(size=L * g).astype(np.float32))
    beta = jnp.asarray(rng.normal(size=n).astype(np.float32))
    C = jnp.asarray((rng.normal(size=(L * g, n)) ** 2).astype(np.float32)).astype(dtype)
    return alpha, beta, C


SHAPES = [
    # (L, g, n, tile_l, tile_n)
    (8, 8, 128, 8, 128),       # single tile
    (16, 8, 256, 8, 128),      # 2x2 tiles
    (8, 16, 384, 4, 128),      # tall groups, 3 col tiles
    (32, 8, 128, 8, 128),      # many row tiles
    (2, 64, 256, 2, 128),      # few big groups
    (16, 8, 256, 8, 256),      # wide col tile
]


@pytest.mark.parametrize("L,g,n,tl,tn", SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("density", [1.0, 0.5, 0.0])
def test_gradpsi_matches_oracle(L, g, n, tl, tn, dtype, density):
    rng = np.random.default_rng(hash((L, g, n, str(dtype), density)) % 2**32)
    alpha, beta, C = _rand_problem(rng, L, g, n, dtype)
    grid = (L // tl, n // tn)
    flags = jnp.asarray(
        (rng.random(grid) < density).astype(np.int32)
        if density < 1.0
        else np.ones(grid, np.int32)
    )
    kw = dict(num_groups=L, group_size=g, tau=0.3, gamma=0.5,
              tile_l=tl, tile_n=tn)
    want = gradpsi_ref(alpha, beta, C, flags, **kw)
    got = gradpsi_pallas(alpha, beta, C, flags, interpret=True, **kw)
    tol = 2e-2 if dtype == jnp.bfloat16 else 5e-4
    for w, g_ in zip(want, got):
        np.testing.assert_allclose(np.asarray(g_), np.asarray(w), rtol=tol, atol=tol)


@pytest.mark.parametrize("L,n", [(8, 128), (20, 300), (64, 1024), (3, 50)])
def test_screen_matches_oracle(L, n):
    rng = np.random.default_rng(L * 1000 + n)
    z = jnp.asarray(np.abs(rng.normal(size=(L, n))).astype(np.float32))
    k, o = z * 1.5, z * 0.3
    act = jnp.asarray(rng.integers(0, 2, (L, n)).astype(np.int8))
    dap = jnp.asarray(np.abs(rng.normal(size=L)).astype(np.float32) * 0.1)
    daf, dan = dap * 1.2, dap * 0.5
    db = jnp.asarray(rng.normal(size=n).astype(np.float32) * 0.1)
    sg = jnp.asarray(np.sqrt(rng.integers(1, 20, L)).astype(np.float32))
    tau = 0.8
    tl, tn = 8, 128
    v1, f1 = kops.screen_verdicts(z, k, o, act, dap, daf, dan, db, sg, tau,
                                  tile_l=tl, tile_n=tn)
    Lp, Np = -(-L // tl) * tl, -(-n // tn) * tn
    pad2 = lambda x: jnp.pad(x, ((0, Lp - L), (0, Np - n)))
    pad_ = lambda x, t: jnp.pad(x, (0, t - x.shape[0]))
    v0, f0 = screen_ref(
        pad2(z), pad2(k), pad2(o), pad2(act),
        pad_(dap, Lp), pad_(daf, Lp), pad_(dan, Lp), pad_(db, Np), pad_(sg, Lp),
        tau=tau, tile_l=tl, tile_n=tn,
    )
    assert bool(jnp.all(v0[:L, :n] == v1))
    assert bool(jnp.all(f0 == f1))


def test_ops_dual_matches_dense_allcompute():
    """Pallas wrapper vs the dense closed form, no screening."""
    rng = np.random.default_rng(3)
    L, g, n = 16, 8, 200
    m = L * g
    labels = np.repeat(np.arange(L), g)
    Xs = rng.normal(size=(m, 2)) + labels[:, None]
    Xt = rng.normal(size=(n, 2)) + rng.integers(0, L, n)[:, None]
    C = squared_euclidean_cost(Xs, Xt).astype(np.float32)
    C /= C.max()
    spec = G.spec_from_labels(labels, pad_to=8)
    C_pad = jnp.asarray(G.pad_cost_matrix(C, labels, spec))
    a = jnp.asarray(G.pad_marginal(np.full(m, 1 / m, np.float32), labels, spec))
    b = jnp.asarray(np.full(n, 1 / n, np.float32))
    reg = GroupSparseReg.from_rho(1.0, 0.6)
    prob = DualProblem(spec.num_groups, spec.group_size, n, reg)
    alpha = jnp.asarray(rng.normal(size=spec.m_pad).astype(np.float32) * 0.3)
    beta = jnp.asarray(rng.normal(size=n).astype(np.float32) * 0.3)

    verdict = jnp.full((L, n), S.CHECK, jnp.int32)
    v0, (ga0, gb0) = dual_value_and_grad(alpha, beta, C_pad, a, b, prob)
    v1, ga1, gb1 = kops.dual_value_and_grad(alpha, beta, C_pad, a, b, verdict, prob)
    np.testing.assert_allclose(float(v1), float(v0), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(ga1), np.asarray(ga0), atol=1e-4)
    np.testing.assert_allclose(np.asarray(gb1), np.asarray(gb0), atol=1e-4)


def test_ops_dual_screened_exactness():
    """Masked Pallas eval == dense eval when the mask is a valid screen."""
    rng = np.random.default_rng(7)
    L, g, n = 16, 8, 200
    m = L * g
    labels = np.repeat(np.arange(L), g)
    Xs = rng.normal(size=(m, 2)) + labels[:, None]
    Xt = rng.normal(size=(n, 2)) + rng.integers(0, L, n)[:, None]
    C = squared_euclidean_cost(Xs, Xt).astype(np.float32)
    C /= C.max()
    spec = G.spec_from_labels(labels, pad_to=8)
    C_pad = jnp.asarray(G.pad_cost_matrix(C, labels, spec))
    a = jnp.asarray(G.pad_marginal(np.full(m, 1 / m, np.float32), labels, spec))
    b = jnp.asarray(np.full(n, 1 / n, np.float32))
    reg = GroupSparseReg.from_rho(1.0, 0.6)
    prob = DualProblem(spec.num_groups, spec.group_size, n, reg)
    row_mask = jnp.asarray(spec.row_mask().reshape(-1))
    sqrt_g = jnp.asarray(spec.sqrt_sizes())

    alpha = jnp.asarray(rng.normal(size=spec.m_pad).astype(np.float32) * 0.3)
    beta = jnp.asarray(rng.normal(size=n).astype(np.float32) * 0.3)
    z, k, o = snapshot_norms(alpha, beta, C_pad, prob, row_mask)
    st = S.take_snapshot(S.init_state(spec.m_pad, n, L), alpha, beta, z, k, o)
    a2, b2 = alpha + 0.01, beta - 0.02
    verd = S.verdicts(st, a2, b2, sqrt_g, reg.tau)
    assert int(jnp.sum(verd == S.ZERO)) > 0  # screening actually fires

    v0, (ga0, gb0) = dual_value_and_grad(a2, b2, C_pad, a, b, prob)
    v1, ga1, gb1 = kops.dual_value_and_grad(a2, b2, C_pad, a, b, verd, prob)
    np.testing.assert_allclose(float(v1), float(v0), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(ga1), np.asarray(ga0), atol=1e-4)
    np.testing.assert_allclose(np.asarray(gb1), np.asarray(gb0), atol=1e-4)


def test_pick_tile_l_fits_vmem():
    from repro.kernels.gradpsi import VMEM_BUDGET_BYTES

    for g in [8, 64, 512, 4096]:
        tl = pick_tile_l(g, 128)
        assert tl >= 1
        assert 2 * tl * g * 128 * 4 <= VMEM_BUDGET_BYTES or tl == 1
